# Policy artifacts: the versioned, serializable product of a profiling run
# (site table + policy + provenance + oracle verdict + warm-start hints)
# and the file-backed registry that moves it between search, serving,
# training, checkpoints, and CI.
from repro.artifacts.artifact import (
    PolicyArtifact, ScopeRow, ArtifactSchemaError, SCHEMA_VERSION,
)
from repro.artifacts.registry import (
    Registry, ArtifactRef, parse_ref, default_root,
    load_artifact_file, save_artifact_file,
)

__all__ = [
    "PolicyArtifact", "ScopeRow", "ArtifactSchemaError", "SCHEMA_VERSION",
    "Registry", "ArtifactRef", "parse_ref", "default_root",
    "load_artifact_file", "save_artifact_file",
]
