"""File-backed, versioned registry of policy artifacts.

Layout (one directory per artifact name, one per version):

    <root>/
        bench_model/
            v0001/artifact.json
            v0003/artifact.json
            LATEST              # "v0003", updated last, atomic rename
        sod/
            ...

Storage follows the checkpointer's durability discipline exactly: every
version is written to a dot-prefixed tmp directory and published with one
``os.rename`` (readers can never observe a partial artifact), the LATEST
pointer is itself rename-published *after* the version lands, and keep-k GC
never deletes the newest durable version. References are ``"name"``
(resolves through LATEST) or ``"name@v3"`` (pinned).
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
import shutil
import time
from typing import List, Optional, Tuple

from repro.artifacts.artifact import PolicyArtifact

_VDIR_RE = re.compile(r"^v(\d{4,})$")
_NAME_RE = re.compile(r"^[A-Za-z0-9][A-Za-z0-9._-]*$")

# default registry root: overridable per-process so launch entrypoints and
# CI agree on one location without threading a flag everywhere
DEFAULT_ROOT_ENV = "RAPTOR_REGISTRY"
DEFAULT_ROOT = "artifacts"


def default_root() -> str:
    return os.environ.get(DEFAULT_ROOT_ENV, DEFAULT_ROOT)


def parse_ref(ref: str) -> Tuple[str, Optional[int]]:
    """``"bench_model@v3"`` -> ``("bench_model", 3)``; bare name -> latest."""
    name, sep, ver = ref.partition("@")
    if not sep:
        return name, None
    if not ver.startswith("v") or not ver[1:].isdigit():
        raise ValueError(
            f"bad artifact reference {ref!r}: expected 'name' or 'name@vN'")
    return name, int(ver[1:])


@dataclasses.dataclass(frozen=True)
class ArtifactRef:
    """A saved artifact's durable identity: what a checkpoint manifest
    records and a CLI flag names."""

    name: str
    version: int
    digest: str

    @property
    def ref(self) -> str:
        return f"{self.name}@v{self.version}"

    def to_json(self) -> dict:
        return {"name": self.name, "version": self.version,
                "digest": self.digest}

    @staticmethod
    def from_json(data: dict) -> "ArtifactRef":
        return ArtifactRef(name=str(data["name"]),
                           version=int(data["version"]),
                           digest=str(data["digest"]))


class Registry:
    """A directory of versioned :class:`PolicyArtifact` files.

    ``keep_k`` bounds versions per name (0 = keep everything); GC runs on
    save and, like the checkpointer, never removes the newest version.

    ``retries``/``backoff``: a reader that races a concurrent publisher can
    observe the torn window between the two save renames — a LATEST pointer
    naming a version whose ``artifact.json`` has not landed yet, or a
    ``.tmp_v*`` staging dir mid-publish. ``load`` retries with exponential
    backoff *only* while the name dir shows that in-flight state; a
    genuinely missing artifact still fails fast.
    """

    def __init__(self, root: Optional[str] = None, keep_k: int = 0,
                 retries: int = 3, backoff: float = 0.05):
        self.root = root if root is not None else default_root()
        self.keep_k = keep_k
        self.retries = retries
        self.backoff = backoff
        os.makedirs(self.root, exist_ok=True)

    # ---- paths -------------------------------------------------------------
    def _name_dir(self, name: str) -> str:
        return os.path.join(self.root, name)

    def _version_dir(self, name: str, version: int) -> str:
        return os.path.join(self._name_dir(name), f"v{version:04d}")

    def path(self, name: str, version: int) -> str:
        return os.path.join(self._version_dir(name, version), "artifact.json")

    # ---- enumeration -------------------------------------------------------
    def names(self) -> List[str]:
        return sorted(
            d for d in os.listdir(self.root)
            if os.path.isdir(self._name_dir(d)) and not d.startswith("."))

    def versions(self, name: str) -> List[int]:
        base = self._name_dir(name)
        if not os.path.isdir(base):
            return []
        out = []
        for d in os.listdir(base):
            m = _VDIR_RE.match(d)
            if m and os.path.exists(os.path.join(base, d, "artifact.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_version(self, name: str) -> Optional[int]:
        """The LATEST pointer if durable, else the newest on-disk version
        (pointer write is the last step of save, so a crash between the
        two renames leaves a valid registry that self-heals here)."""
        ptr = os.path.join(self._name_dir(name), "LATEST")
        if os.path.exists(ptr):
            with open(ptr) as f:
                m = _VDIR_RE.match(f.read().strip())
            if m and os.path.exists(self.path(name, int(m.group(1)))):
                return int(m.group(1))
        vs = self.versions(name)
        return vs[-1] if vs else None

    # ---- save / load -------------------------------------------------------
    def save(self, artifact: PolicyArtifact,
             name: Optional[str] = None) -> ArtifactRef:
        """Publish a new version atomically; returns its durable ref.

        Every artifact is linted before publication
        (:func:`repro.analysis.lint.lint_artifact`): error-level findings
        raise :class:`repro.analysis.lint.ArtifactLintError` and block the
        save; warnings are recorded in the published artifact's provenance
        under ``"lint_warnings"``."""
        artifact = self._lint(artifact)
        name = name or artifact.name
        if not _NAME_RE.match(name or ""):
            raise ValueError(f"bad artifact name {name!r}")
        base = self._name_dir(name)
        os.makedirs(base, exist_ok=True)
        version = (self.latest_version(name) or 0) + 1
        while os.path.exists(self._version_dir(name, version)):
            version += 1
        tmp = os.path.join(base, f".tmp_v{version:04d}_{os.getpid()}")
        os.makedirs(tmp, exist_ok=True)
        text = artifact.dumps()
        with open(os.path.join(tmp, "artifact.json"), "w") as f:
            f.write(text)
        os.rename(tmp, self._version_dir(name, version))  # atomic publish
        ptr_tmp = os.path.join(base, f".LATEST_tmp_{os.getpid()}")
        with open(ptr_tmp, "w") as f:
            f.write(f"v{version:04d}")
        os.rename(ptr_tmp, os.path.join(base, "LATEST"))
        self._gc(name)
        return ArtifactRef(name=name, version=version,
                           digest=artifact.digest)

    @staticmethod
    def _lint(artifact: PolicyArtifact) -> PolicyArtifact:
        """Structural lint gate for publication. Clean artifacts pass
        through untouched (identical bytes, identical digest); warning
        findings are stamped into provenance so the published version
        carries its own lint report."""
        from repro.analysis.lint import ArtifactLintError, lint_artifact
        findings = lint_artifact(artifact)
        if any(f.level == "error" for f in findings):
            raise ArtifactLintError(findings)
        if findings:
            prov = dict(artifact.provenance)
            prov["lint_warnings"] = [f.render() for f in findings]
            artifact = dataclasses.replace(artifact, provenance=prov)
        return artifact

    def _publish_in_flight(self, name: str) -> bool:
        """True if the name dir shows a concurrent publisher's torn window:
        a LATEST pointer naming a version whose ``artifact.json`` has not
        landed, or an unpublished staging dir/pointer tmp."""
        base = self._name_dir(name)
        if not os.path.isdir(base):
            return False
        ptr = os.path.join(base, "LATEST")
        if os.path.exists(ptr):
            with open(ptr) as f:
                m = _VDIR_RE.match(f.read().strip())
            if m and not os.path.exists(self.path(name, int(m.group(1)))):
                return True
        return any(d.startswith(".tmp_v") or d.startswith(".LATEST_tmp")
                   for d in os.listdir(base))

    def _load_once(self, name: str, version: Optional[int]) -> PolicyArtifact:
        if version is None:
            version = self.latest_version(name)
            if version is None:
                known = ", ".join(self.names()) or "<empty registry>"
                raise FileNotFoundError(
                    f"no artifact named {name!r} in registry {self.root!r} "
                    f"(known: {known})")
        path = self.path(name, version)
        if not os.path.exists(path):
            have = self.versions(name)
            raise FileNotFoundError(
                f"artifact {name}@v{version} not in registry {self.root!r} "
                f"(versions on disk: {have or 'none'})")
        with open(path) as f:
            return PolicyArtifact.loads(f.read())

    def load(self, ref: str) -> PolicyArtifact:
        """Load ``"name"`` (latest) or ``"name@vN"`` (pinned), with bounded
        retry/backoff while a concurrent publisher's rename window is
        visibly open (see class docstring)."""
        name, version = parse_ref(ref)
        delay = self.backoff
        for attempt in range(self.retries + 1):
            try:
                return self._load_once(name, version)
            except FileNotFoundError:
                if attempt >= self.retries or not self._publish_in_flight(name):
                    raise
                time.sleep(delay)
                delay *= 2
        raise AssertionError("unreachable")  # pragma: no cover

    def load_ref(self, ref: str) -> Tuple[PolicyArtifact, ArtifactRef]:
        """Load plus the resolved durable identity (digest recomputed from
        the stored bytes, so a tampered file is detectable upstream)."""
        name, version = parse_ref(ref)
        if version is None:
            version = self.latest_version(name)
        art = self.load(ref)
        return art, ArtifactRef(name=name, version=int(version),
                                digest=art.digest)

    def digest(self, ref: str) -> str:
        return self.load(ref).digest

    def _gc(self, name: str) -> None:
        if not self.keep_k:
            return
        for v in self.versions(name)[:-self.keep_k]:
            shutil.rmtree(self._version_dir(name, v), ignore_errors=True)


def load_artifact_file(path: str) -> PolicyArtifact:
    """Load one artifact from a bare ``.json`` file — the committed-to-git
    form the CI policy-drift gate diffs against (``artifacts/<name>.json``
    at the repo root is a plain file, not a registry tree)."""
    with open(path) as f:
        return PolicyArtifact.loads(f.read())


def save_artifact_file(artifact: PolicyArtifact, path: str) -> None:
    """Atomically write one artifact as a bare ``.json`` file (pretty-printed
    but canonical-ordered, so git diffs stay readable and stable)."""
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    tmp = f"{path}.tmp_{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(artifact.to_json(), f, sort_keys=True, indent=2)
        f.write("\n")
    os.replace(tmp, path)


__all__ = ["Registry", "ArtifactRef", "parse_ref", "default_root",
           "load_artifact_file", "save_artifact_file"]
