"""PolicyArtifact: the deployable product of a profiling run.

RAPTOR's output is not a number, it is a *policy* — which scopes tolerate
which (e, m) formats — plus the evidence behind it. Until now that bundle
died with the process: a ``SearchResult`` lived in one interpreter,
serving re-parsed ad-hoc ``--policy`` flags, and warm-start hints from a
trajectory profile had to be recomputed per run. :class:`PolicyArtifact`
makes the whole bundle one versioned, JSON-serializable value:

  * ``policy``       — the :class:`TruncationPolicy` itself (lossless round
                       trip; mask-fn rules raise ``NotSerializableError``)
  * ``assignments``  — the per-scope site table of the search (mantissa
                       width, error at accept, excluded flag, FLOPs share)
  * ``provenance``   — threshold / budget / evals / dispatches / compile
                       counts and the search history (the audit trail)
  * ``hints``        — ladder warm-start hints (``scope -> man_bits`` or
                       ``None`` = pinned full precision) so a later
                       ``autosearch(warm_start=artifact.hints)`` re-search
                       skips the trajectory profile entirely
  * ``oracle``       — an FP64-oracle verdict attached by ``apps.oracle``
  * ``bench``        — an optional BENCH row (measured perf context)

Producers: ``SearchResult.to_artifact`` and ``OracleVerdict.attach``.
Consumers: the registry (``repro.artifacts.Registry``), the serving engine
(``Engine(policy=artifact)``), the trainer's runtime-table hot swap, the
checkpointer manifest, and the CI policy-drift gate.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Any, Dict, Mapping, Optional

from repro.core.policy import TruncationPolicy

# Bump when the JSON layout changes incompatibly. Loading an artifact with
# a NEWER schema than this library understands fails loudly (never a silent
# partial parse): the registry is shared between builds of the application,
# exactly the cross-build workflow the paper frames profiling around.
SCHEMA_VERSION = 1


class ArtifactSchemaError(ValueError):
    """The artifact's schema version is ahead of this library."""


@dataclasses.dataclass(frozen=True)
class ScopeRow:
    """One row of the artifact's scope table: the searched assignment for
    one frontier scope, with enough context to re-rank and re-render it."""

    man_bits: int
    error_at_accept: float
    excluded: bool = False
    flops: float = 0.0
    fraction: float = 0.0
    n_eqns: int = 0

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(data: dict) -> "ScopeRow":
        return ScopeRow(
            man_bits=int(data["man_bits"]),
            error_at_accept=float(data["error_at_accept"]),
            excluded=bool(data.get("excluded", False)),
            flops=float(data.get("flops", 0.0)),
            fraction=float(data.get("fraction", 0.0)),
            n_eqns=int(data.get("n_eqns", 0)))


@dataclasses.dataclass(frozen=True)
class PolicyArtifact:
    """The versioned, serializable bundle a profiling run produces."""

    name: str
    policy: TruncationPolicy
    assignments: Dict[str, ScopeRow] = dataclasses.field(default_factory=dict)
    provenance: Dict[str, Any] = dataclasses.field(default_factory=dict)
    hints: Dict[str, Optional[int]] = dataclasses.field(default_factory=dict)
    oracle: Optional[Dict[str, Any]] = None
    bench: Optional[Dict[str, Any]] = None
    schema_version: int = SCHEMA_VERSION

    # ---- derived additions (frozen -> return new artifacts) ---------------
    def with_oracle(self, verdict) -> "PolicyArtifact":
        """Attach an FP64-oracle verdict (an ``apps.oracle.OracleVerdict``
        or its JSON dict)."""
        data = verdict if isinstance(verdict, Mapping) \
            else verdict.to_json()
        return dataclasses.replace(self, oracle=dict(data))

    def with_bench(self, row: Mapping) -> "PolicyArtifact":
        """Attach a measured BENCH row (perf context for the policy)."""
        return dataclasses.replace(self, bench=dict(row))

    def with_hints(self, hints: Mapping) -> "PolicyArtifact":
        return dataclasses.replace(self, hints=dict(hints))

    def with_guardrail_log(self, log) -> "PolicyArtifact":
        """Attach a runtime intervention log (a
        ``repro.guardrails.GuardrailLog`` or its JSON list) under
        ``provenance["guardrail_log"]`` so checkpoints, serving, and CI can
        audit what the guardrail controller did under this policy."""
        data = log if isinstance(log, list) else log.to_json()
        prov = dict(self.provenance)
        prov["guardrail_log"] = data
        return dataclasses.replace(self, provenance=prov)

    # ---- JSON round trip ---------------------------------------------------
    def to_json(self) -> dict:
        return {
            "schema_version": self.schema_version,
            "name": self.name,
            "policy": self.policy.to_json(),
            "assignments": {p: r.to_json()
                            for p, r in self.assignments.items()},
            "provenance": dict(self.provenance),
            "hints": dict(self.hints),
            "oracle": self.oracle,
            "bench": self.bench,
        }

    @staticmethod
    def from_json(data: Mapping) -> "PolicyArtifact":
        version = int(data.get("schema_version", 0))
        if version > SCHEMA_VERSION:
            raise ArtifactSchemaError(
                f"artifact {data.get('name', '?')!r} has schema version "
                f"{version}, but this library understands at most "
                f"{SCHEMA_VERSION}; upgrade the library (a partial parse "
                "could silently deploy the wrong policy)")
        hints = {str(k): (None if v is None else int(v))
                 for k, v in dict(data.get("hints", {})).items()}
        return PolicyArtifact(
            name=str(data["name"]),
            policy=TruncationPolicy.from_json(data["policy"]),
            assignments={str(p): ScopeRow.from_json(r)
                         for p, r in dict(data.get("assignments", {})).items()},
            provenance=dict(data.get("provenance", {})),
            hints=hints,
            oracle=data.get("oracle"),
            bench=data.get("bench"),
            schema_version=version)

    def dumps(self) -> str:
        """Canonical text form: sorted keys, fixed separators — the digest
        is computed over exactly these bytes, so two equal artifacts always
        hash equal regardless of construction order."""
        return json.dumps(self.to_json(), sort_keys=True,
                          separators=(",", ":"))

    @staticmethod
    def loads(text: str) -> "PolicyArtifact":
        return PolicyArtifact.from_json(json.loads(text))

    @property
    def digest(self) -> str:
        """sha256 of the canonical JSON — the identity a checkpoint manifest
        records so a restored run can verify it resumes under the same
        policy."""
        return hashlib.sha256(self.dumps().encode("utf-8")).hexdigest()

    def table(self) -> str:
        """Render the scope table like ``SearchResult.table`` (the paper's
        per-region heatmap, textual form)."""
        lines = [f"  {'scope':<32} {'flops%':>7} {'m-bits':>7} "
                 f"{'err@accept':>11}  status"]
        for path, r in sorted(self.assignments.items()):
            status = ("excluded" if r.excluded
                      else ("full" if r.man_bits >= 23 else "truncated"))
            lines.append(f"  {path:<32} {r.fraction * 100:>6.1f}% "
                         f"{r.man_bits:>7d} {r.error_at_accept:>11.3e}  "
                         f"{status}")
        return "\n".join(lines)

    def __str__(self) -> str:
        prov = self.provenance
        bits = [f"PolicyArtifact {self.name!r} "
                f"({len(self.policy.rules)} rules, "
                f"{len(self.assignments)} scopes"]
        if "final_error" in prov and "threshold" in prov:
            bits.append(f", err {prov['final_error']:.3e} "
                        f"@ thr {prov['threshold']:.1e}")
        if self.oracle is not None:
            bits.append(f", oracle {'PASS' if self.oracle.get('passed') else 'FAIL'}")
        bits.append(")")
        return "".join(bits)


__all__ = ["PolicyArtifact", "ScopeRow", "ArtifactSchemaError",
           "SCHEMA_VERSION"]
