"""Train-step factory: remat + microbatch grad accumulation + optional
RAPTOR truncation policy + gradient compression, ready for pjit.

``make_train_step`` returns a pure function
    (params, opt_state, batch, step) -> (params, opt_state, metrics)
suitable for ``jax.jit`` with FSDP x TP shardings from
``distributed.sharding``. The RAPTOR integration point: when
``cfg.policy`` is set the *differentiated* loss (fwd+bwd jaxpr) is rewritten
op-by-op — RAPTOR's whole-call-tree LTO semantics.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.api import truncate
from repro.core.policy import TruncationPolicy
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig
from repro.optim import compression


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    grad_accum: int = 1
    policy: Optional[TruncationPolicy] = None       # RAPTOR truncation
    policy_impl: str = "auto"
    grad_compression: Optional[str] = None          # None | "bf16" | "int8"
    lr_schedule: Optional[Callable] = None          # step -> lr


def _split_micro_fn(accum: int):
    def split_micro(batch, i):
        def slice_one(x):
            if x.ndim == 0:
                return x
            # leading batch dim except (3,B,S) mrope positions
            if x.ndim >= 2 and x.shape[0] == 3 and x.shape[1] % accum == 0:
                b = x.shape[1] // accum
                return lax.dynamic_slice_in_dim(x, i * b, b, axis=1)
            b = x.shape[0] // accum
            return lax.dynamic_slice_in_dim(x, i * b, b, axis=0)
        return jax.tree_util.tree_map(slice_one, batch)
    return split_micro


def _build_train_step(tc: TrainConfig, grad_fn, grad_shardings):
    """The shared step body: microbatch accumulation, gradient compression,
    the optimizer update. ``grad_fn(params, micro_batch, *extra) ->
    (loss, grads)``; any ``*extra`` step arguments (e.g. the hot-swap
    format table) are threaded through to every microbatch call."""
    accum = max(tc.grad_accum, 1)
    split_micro = _split_micro_fn(accum)

    def constrain_grads(g):
        if grad_shardings is None:
            return g
        return jax.tree_util.tree_map(
            lambda t, sh: jax.lax.with_sharding_constraint(t, sh),
            g, grad_shardings)

    def train_step(params, opt_state, batch, step, *extra):
        if accum == 1:
            loss, grads = grad_fn(params, batch, *extra)
            grads = constrain_grads(grads)
        else:
            def body(carry, i):
                acc, loss_acc = carry
                loss_i, g_i = grad_fn(params, split_micro(batch, i), *extra)
                g_i = constrain_grads(g_i)
                acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), acc, g_i)
                return (constrain_grads(acc), loss_acc + loss_i), None
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            zeros = constrain_grads(zeros)
            (grads, loss), _ = lax.scan(
                body, (zeros, jnp.float32(0)), jnp.arange(accum))
            grads = jax.tree_util.tree_map(lambda g: g / accum, grads)
            loss = loss / accum

        if tc.grad_compression == "bf16":
            err = opt_state.get("err")
            grads, err = compression.compress_bf16(grads, err)
            opt_state = dict(opt_state, err=err)
        elif tc.grad_compression == "int8":
            err = opt_state.get("err")
            q, err = compression.compress_int8(grads, err)
            grads = compression.decompress_int8(q)
            opt_state = dict(opt_state, err=err)

        lr = (tc.lr_schedule(step) if tc.lr_schedule
              else jnp.float32(tc.optimizer.lr))
        inner = {k: opt_state[k] for k in ("step", "m", "v", "master")}
        params, inner, om = adamw.apply_updates(
            params, grads, inner, tc.optimizer, lr)
        new_state = dict(opt_state, **inner)
        # in-graph health flag for the guardrail monitor: the grad norm
        # already reduces every gradient leaf, so loss+gnorm finiteness
        # covers the whole backward pass at no extra cost
        healthy = jnp.isfinite(loss)
        if "grad_norm" in om:
            healthy = healthy & jnp.isfinite(om["grad_norm"])
        metrics = {"loss": loss, "lr": lr,
                   "nonfinite": jnp.logical_not(healthy), **om}
        return params, new_state, metrics

    return train_step


def make_train_step(model, tc: TrainConfig, grad_shardings=None):
    """``grad_shardings``: optional pytree of NamedShardings (same structure
    as params). Constraining gradients to the parameter sharding lets GSPMD
    reduce-scatter the data-parallel gradient reduction instead of
    all-reducing + re-sharding (EXPERIMENTS.md §Perf iteration 7)."""
    def loss_fn(params, micro_batch):
        return model.loss(params, micro_batch)

    grad_fn = jax.value_and_grad(loss_fn)
    if tc.policy is not None:
        grad_fn = truncate(grad_fn, tc.policy, impl=tc.policy_impl)

    return _build_train_step(tc, grad_fn, grad_shardings)


def make_hotswap_train_step(model, tc: TrainConfig, site_policy,
                            example_params, example_batch,
                            grad_shardings=None):
    """A train step whose truncation policy is a RUNTIME argument.

    ``make_train_step`` bakes ``tc.policy`` into the traced computation —
    deploying a different policy means a retrace and an XLA recompile.
    This factory instead enumerates every ``site_policy``-matched quantize
    site of the differentiated loss into a runtime ``(num_sites, 4)`` format
    table (PR 2's zero-recompile machinery, applied to training):

        step_fn, sites = make_hotswap_train_step(model, tc, site_policy,
                                                 params, batch)
        table = sites.table_for(artifact.policy)   # or sites.identity_table()
        params, opt, m = jit(step_fn)(params, opt, batch, step, table)
        ...
        table = sites.table_for(other_artifact.policy)   # hot swap: no
        params, opt, m = jit(step_fn)(params, opt, batch, step, table)  # recompile

    Returns ``(train_step, site_index)`` where ``train_step(params,
    opt_state, batch, step, table)`` and ``site_index`` lowers any policy
    whose matched set is a subset of ``site_policy``'s (e.g. a registry
    artifact's) to its table. Swapping policy artifacts mid-run is a new
    table *value* — same shapes, same executable, zero recompiles.

    The differentiated loss is traced once here against
    ``example_params``/``example_batch`` (a microbatch slice under grad
    accumulation), so the profiled fwd+bwd jaxpr — RAPTOR's whole-call-tree
    semantics — is exactly what the tables parameterize.
    """
    from repro.core import interpreter

    accum = max(tc.grad_accum, 1)
    micro = (example_batch if accum == 1
             else _split_micro_fn(accum)(example_batch, 0))

    grad_fn0 = jax.value_and_grad(
        lambda params, micro_batch: model.loss(params, micro_batch))
    closed, out_shape = jax.make_jaxpr(
        grad_fn0, return_shape=True)(example_params, micro)
    out_tree = jax.tree_util.tree_structure(out_shape)
    index = interpreter.enumerate_sites(closed, site_policy)

    def grad_fn(params, micro_batch, table):
        leaves = jax.tree_util.tree_leaves((params, micro_batch))
        outs = interpreter.eval_sites(
            closed.jaxpr, closed.consts, leaves,
            jnp.asarray(table, jnp.int32), index, tc.policy_impl)
        return jax.tree_util.tree_unflatten(out_tree, outs)

    return _build_train_step(tc, grad_fn, grad_shardings), index


def init_opt_state(model, params, tc: TrainConfig):
    state = adamw.init_state(params, tc.optimizer)
    if tc.grad_compression:
        state["err"] = compression.init_error_buffer(params)
    return state
