"""Train-step factory: remat + microbatch grad accumulation + optional
RAPTOR truncation policy + gradient compression, ready for pjit.

``make_train_step`` returns a pure function
    (params, opt_state, batch, step) -> (params, opt_state, metrics)
suitable for ``jax.jit`` with FSDP x TP shardings from
``distributed.sharding``. The RAPTOR integration point: when
``cfg.policy`` is set the *differentiated* loss (fwd+bwd jaxpr) is rewritten
op-by-op — RAPTOR's whole-call-tree LTO semantics.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.api import truncate
from repro.core.policy import TruncationPolicy
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig
from repro.optim import compression


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    grad_accum: int = 1
    policy: Optional[TruncationPolicy] = None       # RAPTOR truncation
    policy_impl: str = "auto"
    grad_compression: Optional[str] = None          # None | "bf16" | "int8"
    lr_schedule: Optional[Callable] = None          # step -> lr


def make_train_step(model, tc: TrainConfig, grad_shardings=None):
    """``grad_shardings``: optional pytree of NamedShardings (same structure
    as params). Constraining gradients to the parameter sharding lets GSPMD
    reduce-scatter the data-parallel gradient reduction instead of
    all-reducing + re-sharding (EXPERIMENTS.md §Perf iteration 7)."""
    cfg = model.cfg
    accum = max(tc.grad_accum, 1)

    def constrain_grads(g):
        if grad_shardings is None:
            return g
        return jax.tree_util.tree_map(
            lambda t, sh: jax.lax.with_sharding_constraint(t, sh),
            g, grad_shardings)

    def loss_fn(params, micro_batch):
        return model.loss(params, micro_batch)

    grad_fn = jax.value_and_grad(loss_fn)
    if tc.policy is not None:
        grad_fn = truncate(grad_fn, tc.policy, impl=tc.policy_impl)

    def split_micro(batch, i):
        def slice_one(x):
            if x.ndim == 0:
                return x
            # leading batch dim except (3,B,S) mrope positions
            if x.ndim >= 2 and x.shape[0] == 3 and x.shape[1] % accum == 0:
                b = x.shape[1] // accum
                return lax.dynamic_slice_in_dim(x, i * b, b, axis=1)
            b = x.shape[0] // accum
            return lax.dynamic_slice_in_dim(x, i * b, b, axis=0)
        return jax.tree_util.tree_map(slice_one, batch)

    def train_step(params, opt_state, batch, step):
        if accum == 1:
            loss, grads = grad_fn(params, batch)
            grads = constrain_grads(grads)
        else:
            def body(carry, i):
                acc, loss_acc = carry
                loss_i, g_i = grad_fn(params, split_micro(batch, i))
                g_i = constrain_grads(g_i)
                acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), acc, g_i)
                return (constrain_grads(acc), loss_acc + loss_i), None
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            zeros = constrain_grads(zeros)
            (grads, loss), _ = lax.scan(
                body, (zeros, jnp.float32(0)), jnp.arange(accum))
            grads = jax.tree_util.tree_map(lambda g: g / accum, grads)
            loss = loss / accum

        if tc.grad_compression == "bf16":
            err = opt_state.get("err")
            grads, err = compression.compress_bf16(grads, err)
            opt_state = dict(opt_state, err=err)
        elif tc.grad_compression == "int8":
            err = opt_state.get("err")
            q, err = compression.compress_int8(grads, err)
            grads = compression.decompress_int8(q)
            opt_state = dict(opt_state, err=err)

        lr = (tc.lr_schedule(step) if tc.lr_schedule
              else jnp.float32(tc.optimizer.lr))
        inner = {k: opt_state[k] for k in ("step", "m", "v", "master")}
        params, inner, om = adamw.apply_updates(
            params, grads, inner, tc.optimizer, lr)
        new_state = dict(opt_state, **inner)
        metrics = {"loss": loss, "lr": lr, **om}
        return params, new_state, metrics

    return train_step


def init_opt_state(model, params, tc: TrainConfig):
    state = adamw.init_state(params, tc.optimizer)
    if tc.grad_compression:
        state["err"] = compression.init_error_buffer(params)
    return state
