"""Data pipeline: deterministic synthetic stream + memmap token shards.

Production framing: each host reads its own slice of the global batch
(host-sharded loading), the loader cursor is a plain integer that rides the
checkpoint (exact resume), and a double-buffered prefetch thread hides host
latency. The synthetic stream is seeded by (step, host) so restarts and
elastic re-sharding reproduce identical batches — this is what the
fault-tolerance tests assert.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Dict, Iterator, Optional

import numpy as np

import jax


@dataclasses.dataclass
class DataConfig:
    seq_len: int
    global_batch: int
    vocab: int
    kind: str = "synthetic"          # synthetic | memmap
    path: Optional[str] = None       # token file for memmap
    d_model: int = 0                 # for embeds-input archs (stub frontends)
    input_mode: str = "tokens"       # tokens | embeds | encdec
    mrope: bool = False


def _host_slice(global_batch: int) -> slice:
    n_hosts = jax.process_count()
    idx = jax.process_index()
    per = global_batch // n_hosts
    return slice(idx * per, (idx + 1) * per)


class Pipeline:
    """Checkpointable, host-sharded batch source."""

    def __init__(self, cfg: DataConfig, start_step: int = 0):
        self.cfg = cfg
        self.step = start_step
        if cfg.kind == "memmap":
            assert cfg.path, "memmap pipeline needs a token file"
            self._tokens = np.memmap(cfg.path, dtype=np.int32, mode="r")

    # ---- state for checkpointing ------------------------------------------
    def state_dict(self) -> Dict[str, Any]:
        return {"step": self.step}

    def load_state_dict(self, d: Dict[str, Any]):
        self.step = int(d["step"])

    # ---- batch generation ---------------------------------------------------
    def _synthetic(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        sl = _host_slice(cfg.global_batch)
        rows = range(sl.start, sl.stop)
        rng = np.random.Generator(np.random.Philox(key=step))
        toks = rng.integers(0, cfg.vocab, (cfg.global_batch, cfg.seq_len + 1),
                            dtype=np.int32)[list(rows)]
        batch: Dict[str, np.ndarray] = {
            "tokens": toks[:, :-1], "labels": toks[:, 1:]}
        B = toks.shape[0]
        if cfg.input_mode == "embeds":
            emb = rng.standard_normal(
                (B, cfg.seq_len, cfg.d_model), dtype=np.float32)
            batch["embeds"] = emb
            if cfg.mrope:
                pos = np.broadcast_to(
                    np.arange(cfg.seq_len, dtype=np.int32)[None, None],
                    (3, B, cfg.seq_len)).copy()
                batch["positions"] = pos
            batch.pop("tokens")
        elif cfg.input_mode == "encdec":
            batch["src_embeds"] = rng.standard_normal(
                (B, cfg.seq_len, cfg.d_model), dtype=np.float32)
        return batch

    def _memmap(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        sl = _host_slice(cfg.global_batch)
        per_host = sl.stop - sl.start
        span = cfg.seq_len + 1
        n_windows = (len(self._tokens) - 1) // span
        base = (step * cfg.global_batch) % max(n_windows - cfg.global_batch, 1)
        rows = []
        for i in range(sl.start, sl.stop):
            off = ((base + i) % n_windows) * span
            rows.append(np.asarray(self._tokens[off:off + span]))
        toks = np.stack(rows)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def next(self) -> Dict[str, np.ndarray]:
        fn = self._synthetic if self.cfg.kind == "synthetic" else self._memmap
        batch = fn(self.step)
        self.step += 1
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next()


class Prefetcher:
    """Double-buffered background prefetch (hides host batch creation)."""

    def __init__(self, pipeline: Pipeline, depth: int = 2):
        self.pipeline = pipeline
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._work, daemon=True)
        self.thread.start()

    def _work(self):
        while not self._stop.is_set():
            batch = self.pipeline.next()
            while not self._stop.is_set():
                try:
                    self.q.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def next(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self.thread.join(timeout=2)


def write_token_file(path: str, tokens: np.ndarray):
    np.asarray(tokens, np.int32).tofile(path)
