"""Automated mixed-precision search (the paper's §6.3 loop, closed).

RAPTOR's workflow is manual: truncate a scope, look at the figure of merit,
exclude the scopes that break, re-run. ``autosearch`` automates it:

  1. **Trace once.** The profiled function is traced to a jaxpr a single
     time; every candidate policy is evaluated by re-walking that jaxpr
     under ``jax.jit`` (see ``interpreter.quantized_callable``), so each
     candidate costs one compile and each repeat costs a kernel launch.
  2. **Scope discovery.** ``named_scope`` subtrees are enumerated and cut
     into a disjoint frontier of regions ordered by FLOPs.
  3. **Per-scope bisection.** For each region *in isolation*, bisect the
     mantissa-width ladder for the narrowest format whose error metric
     stays under the threshold — the region's measured sensitivity, the
     quantitative form of the paper's per-module truncation experiments.
  4. **Greedy-exclusion refinement.** If the joint policy misses the
     threshold, rank regions by mem-mode flag counts (the paper's heatmap)
     and exclude the most fragile one; repeat until the metric fits or the
     evaluation budget runs out.

Every candidate evaluation is counted against ``budget``; the search
degrades gracefully — regions it never reached simply stay full precision.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax

from repro.core import interpreter, memmode
from repro.core.formats import FPFormat
from repro.core.policy import TruncationPolicy, TruncationRule, normalize_stack
from repro.search import metrics as _metrics
from repro.search.scopes import ScopeInfo, discover_scopes

# mantissa-width ladder, finest first; 23 at e8 is fp32 = identity
DEFAULT_WIDTHS: Tuple[int, ...] = (23, 15, 10, 7, 5, 3, 2)


@dataclasses.dataclass
class ScopeAssignment:
    scope: ScopeInfo
    man_bits: int                  # assigned mantissa width
    error_at_accept: float         # metric when this width was accepted
    excluded: bool = False         # knocked back to full by refinement

    def fmt(self, exp_bits: int) -> Optional[FPFormat]:
        """The format this assignment truncates to; None = full precision."""
        if self.excluded or self.man_bits >= 23:
            return None
        return FPFormat(exp_bits, self.man_bits)


@dataclasses.dataclass
class SearchResult:
    """Per-scope format assignment + the audit trail of the search."""

    assignments: Dict[str, ScopeAssignment]
    exp_bits: int
    threshold: float
    budget: int
    evals_used: int
    final_error: float
    converged: bool
    history: List[Tuple[str, float]]  # (event, metric value)

    def policy(self) -> TruncationPolicy:
        rules = tuple(
            TruncationRule(fmt=a.fmt(self.exp_bits), scope=path)
            for path, a in self.assignments.items()
            if a.fmt(self.exp_bits) is not None)
        return TruncationPolicy(rules=rules)

    def table(self) -> str:
        """Per-scope format table — the textual analogue of the paper's
        per-region heatmap."""
        lines = [f"  {'scope':<32} {'flops%':>7} {'format':>8} "
                 f"{'err@accept':>11}  status"]
        for path, a in self.assignments.items():
            fmt = a.fmt(self.exp_bits)
            status = ("excluded" if a.excluded
                      else ("full" if fmt is None else "truncated"))
            lines.append(
                f"  {path:<32} {a.scope.fraction * 100:>6.1f}% "
                f"{(fmt.key if fmt else 'fp32'):>8} "
                f"{a.error_at_accept:>11.3e}  {status}")
        lines.append(
            f"  -- metric {self.final_error:.3e} (threshold "
            f"{self.threshold:.1e}) in {self.evals_used}/{self.budget} evals; "
            f"{'converged' if self.converged else 'NOT converged'}")
        return "\n".join(lines)


def autosearch(fn: Callable, args: Sequence = (),
               metric: Optional[Callable] = None, budget: int = 64, *,
               kwargs: Optional[dict] = None, threshold: float = 1e-3,
               widths: Sequence[int] = DEFAULT_WIDTHS, exp_bits: int = 8,
               scopes: Optional[Sequence[ScopeInfo]] = None,
               min_fraction: float = 0.01, max_scopes: Optional[int] = None,
               memflag_threshold: Optional[float] = None,
               impl: str = "auto", refine: bool = True,
               verbose: bool = False) -> SearchResult:
    """Search a per-scope mixed-precision assignment for ``fn(*args)``.

    Returns a :class:`SearchResult`; ``result.policy()`` is directly usable
    with ``api.truncate``. ``metric(ref_out, cand_out) -> float`` defaults to
    the max relative output deviation; ``budget`` caps the total number of
    candidate evaluations (op-mode and mem-mode alike).
    """
    metric = metric or _metrics.default_metric
    kwargs = dict(kwargs or {})
    # index 0 of the ladder must always be full precision: scopes the search
    # never validates (budget exhaustion, all-rejected bisections) are
    # assigned widths[0] with error 0.0, which is only honest for identity.
    widths = tuple(sorted({int(w) for w in widths}, reverse=True))
    if not widths or widths[0] < 23:
        widths = (23,) + widths

    closed, out_shape = jax.make_jaxpr(fn, return_shape=True)(*args, **kwargs)
    out_tree = jax.tree_util.tree_structure(out_shape)
    leaves = jax.tree_util.tree_leaves((tuple(args), kwargs))

    identity = TruncationPolicy(rules=())
    ref_out = interpreter.quantized_callable(closed, out_tree, identity,
                                             impl)(leaves)

    if scopes is None:
        scopes = discover_scopes(closed, min_fraction=min_fraction,
                                 max_scopes=max_scopes)
    scopes = list(scopes)

    evals = 0
    history: List[Tuple[str, float]] = []

    def log(msg: str) -> None:
        if verbose:
            print(f"[autosearch] {msg}", flush=True)

    def evaluate(policy: TruncationPolicy, tag: str) -> float:
        nonlocal evals
        evals += 1
        run = interpreter.quantized_callable(closed, out_tree, policy, impl)
        err = metric(ref_out, run(leaves))
        history.append((tag, err))
        return err

    def policy_of(assign: Dict[str, ScopeAssignment],
                  extra: Optional[Tuple[str, int]] = None
                  ) -> TruncationPolicy:
        rules = []
        pending = dict(assign)
        if extra is not None:
            path, m = extra
            pending[path] = ScopeAssignment(
                scope=next(s for s in scopes if s.path == path),
                man_bits=m, error_at_accept=0.0)
        for path, a in pending.items():
            f = a.fmt(exp_bits)
            if f is not None:
                rules.append(TruncationRule(fmt=f, scope=path))
        return TruncationPolicy(rules=tuple(rules))

    # ---- phase 1: solo per-scope mantissa bisection, widest work first -----
    # Each candidate truncates ONE region; the accepted width is that
    # region's measured sensitivity. Composition errors are phase 2's job.
    # One evaluation stays reserved for the joint check so evals_used can
    # never exceed the budget.
    reserve = 1
    assignments: Dict[str, ScopeAssignment] = {}
    for si in scopes:
        if evals + reserve >= budget:
            assignments[si.path] = ScopeAssignment(si, widths[0], 0.0)
            continue
        lo, hi = 0, len(widths) - 1       # index into widths; lo admissible
        err_lo = 0.0
        # probe the coarsest width first: one eval often settles the scope
        err = evaluate(policy_of({}, (si.path, widths[hi])),
                       f"bisect:{si.path}:m{widths[hi]}")
        if err <= threshold:
            lo, err_lo = hi, err
        else:
            while hi - lo > 1 and evals + reserve < budget:
                mid = (lo + hi) // 2
                err = evaluate(policy_of({}, (si.path, widths[mid])),
                               f"bisect:{si.path}:m{widths[mid]}")
                if err <= threshold:
                    lo, err_lo = mid, err
                else:
                    hi = mid
        assignments[si.path] = ScopeAssignment(si, widths[lo], err_lo)
        log(f"{si.path} ({si.fraction * 100:.1f}% flops) -> "
            f"m{widths[lo]} (err {err_lo:.3e}, {evals} evals)")

    # ---- phase 2: joint check + greedy-exclusion refinement ----------------
    if policy_of(assignments).rules:
        final_err = evaluate(policy_of(assignments), "joint")
    else:
        final_err = 0.0  # nothing truncated -> trivially exact, no eval owed
        history.append(("joint", 0.0))
    log(f"joint policy err {final_err:.3e}")

    while (refine and final_err > threshold and evals + 2 <= budget
           and any(not a.excluded and a.fmt(exp_bits) is not None
                   for a in assignments.values())):
        victim = _most_fragile_scope(
            closed, out_tree, leaves, policy_of(assignments), assignments,
            memflag_threshold if memflag_threshold is not None else threshold,
            impl)
        evals += 1  # the mem-mode ranking pass is a paid evaluation
        if victim is None:
            # heatmap flagged nothing attributable; fall back to the
            # truncated scope carrying the most work
            cands = [(p, a) for p, a in assignments.items()
                     if not a.excluded and a.fmt(exp_bits) is not None]
            victim = max(cands, key=lambda pa: pa[1].scope.flops)[0]
        assignments[victim].excluded = True
        log(f"exclude {victim} (paper §6.3), re-run")
        final_err = evaluate(policy_of(assignments), f"exclude:{victim}")
        log(f"-> err {final_err:.3e}")

    return SearchResult(
        assignments=assignments, exp_bits=exp_bits, threshold=threshold,
        budget=budget, evals_used=evals, final_error=final_err,
        converged=final_err <= threshold, history=history)


def _most_fragile_scope(closed, out_tree, leaves, policy, assignments,
                        flag_threshold: float, impl: str) -> Optional[str]:
    """Rank assigned scopes by mem-mode flag counts under the joint policy
    and return the worst non-excluded one (the paper's heatmap -> exclusion
    step). Returns None when nothing attributable was flagged."""
    run = memmode.shadowed_callable(closed, out_tree, policy,
                                    flag_threshold, impl)
    _, report = run(leaves)
    flags = jax.device_get(report.flags)

    per_scope: Dict[str, int] = {}
    for i, desc in enumerate(report.locations):
        loc_scope = normalize_stack(desc.split(" ")[0])
        for path, a in assignments.items():
            if a.excluded or a.man_bits >= 23:
                continue
            if loc_scope == path or loc_scope.startswith(path + "/"):
                per_scope[path] = per_scope.get(path, 0) + int(flags[i])
                break
    live = {p: n for p, n in per_scope.items()
            if n > 0 and not assignments[p].excluded}
    if not live:
        return None
    return max(live, key=live.get)
