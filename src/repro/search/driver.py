"""Automated mixed-precision search (the paper's §6.3 loop, closed).

RAPTOR's workflow is manual: truncate a scope, look at the figure of merit,
exclude the scopes that break, re-run. ``autosearch`` automates it on top of
the runtime-parameterized quantize path (``api.truncate_sweep``):

  1. **Trace once, compile once.** The profiled function is traced to a
     jaxpr a single time and every policy-matched quantize site is indexed
     into a runtime ``(num_sites, 4)`` format table
     (``interpreter.enumerate_sites``). Candidate policies are just table
     values: the whole search runs through ONE ``vmap``-batched compiled
     executable — no per-candidate retrace, no per-candidate recompile.
  2. **Scope discovery.** ``named_scope`` subtrees are enumerated and cut
     into a disjoint frontier of regions ordered by FLOPs.
  3. **Per-scope ladder probe.** For each region *in isolation*, the whole
     mantissa-width ladder is evaluated in one batched call and the
     narrowest format whose error metric stays under the threshold is
     assigned — the region's measured sensitivity, the quantitative form of
     the paper's per-module truncation experiments. With ``warm_start``
     hints (from ``repro.profile``'s instability trajectories) the
     exhaustive ladder is replaced by a hint-seeded bisection of each
     scope's pass/fail boundary, batched across scopes per round.
  4. **Greedy-exclusion refinement.** If the joint policy misses the
     threshold, every single-scope exclusion candidate is evaluated (again
     batched through the same executable) and the most error-reducing one
     is excluded; repeat until the metric fits or the budget runs out.

Every candidate evaluation is counted against ``budget``; the search
degrades gracefully — regions it never reached simply stay full precision.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax

from repro.core import interpreter
from repro.core.formats import FPFormat
from repro.core.policy import TruncationPolicy, TruncationRule
from repro.search import metrics as _metrics
from repro.search.scopes import ScopeInfo, discover_scopes

# mantissa-width ladder, finest first; 23 at e8 is fp32 = identity
DEFAULT_WIDTHS: Tuple[int, ...] = (23, 15, 10, 7, 5, 3, 2)

_UNHINTED = object()


def _frontier_hints(warm_start, scopes) -> Dict[str, Optional[int]]:
    """Project user/profile warm-start hints onto the search frontier.

    Hint keys are scope paths (site scopes from ``profile.ladder_hints``,
    or coarser user-written prefixes); a frontier scope collects every hint
    at, below, or above it in the scope tree. Conflicts resolve
    conservatively: a pinned-high (``None``) hint dominates, otherwise the
    FINEST predicted width wins (a too-coarse prediction can only skip
    probes a sibling site needs)."""
    if warm_start is None:
        return {}
    if not hasattr(warm_start, "items") and hasattr(warm_start, "hints"):
        # a PolicyArtifact (or anything carrying persisted hints): the
        # blame-seeded warm start survives the process that computed it
        warm_start = warm_start.hints
    if not hasattr(warm_start, "items"):
        raise TypeError(
            "warm_start must be a mapping of scope path -> predicted "
            "mantissa width (None = pin to full precision), or a "
            "PolicyArtifact carrying such hints; lower a "
            "TrajectoryReport with repro.profile.ladder_hints first, "
            f"got {type(warm_start).__name__}")
    out: Dict[str, Optional[int]] = {}
    for si in scopes:
        applicable = [
            pred for path, pred in warm_start.items()
            if path == si.path or path.startswith(si.path + "/")
            or si.path.startswith(path + "/")]
        if not applicable:
            continue
        if any(p is None for p in applicable):
            out[si.path] = None
        else:
            out[si.path] = max(int(p) for p in applicable)
    return out


@dataclasses.dataclass
class ScopeAssignment:
    scope: ScopeInfo
    man_bits: int                  # assigned mantissa width
    error_at_accept: float         # metric when this width was accepted
    excluded: bool = False         # knocked back to full by refinement

    def fmt(self, exp_bits: int) -> Optional[FPFormat]:
        """The format this assignment truncates to; None = full precision."""
        if self.excluded or self.man_bits >= 23:
            return None
        return FPFormat(exp_bits, self.man_bits)


@dataclasses.dataclass
class SearchResult:
    """Per-scope format assignment + the audit trail of the search."""

    assignments: Dict[str, ScopeAssignment]
    exp_bits: int
    threshold: float
    budget: int
    evals_used: int
    final_error: float
    converged: bool
    history: List[Tuple[str, float]]  # (event, metric value)
    # distinct dispatch signatures of the search's (fresh) jitted batched
    # executable — exactly its XLA compilations under jit's caching contract
    # (independently pinned by the compile-cache-counter tests): grows past
    # 1 iff a signature regression (e.g. drifting batch width) sneaks in
    n_compiles: int = 0
    n_sites: int = 0                  # runtime-table rows (quantize sites)
    n_dispatches: int = 0             # batched-executable launches
    n_warm_hints: int = 0             # frontier scopes with a warm-start hint
    probe_batch: int = 0              # K: table rows per dispatch (padded)
    max_dispatch_rows: int = 0        # most REAL rows (ref + candidates)
                                      # any single dispatch carried —
                                      # identity padding never counted
    n_devices: int = 1                # probe-axis shards (1 = unsharded)
    # static-analysis pruning (autosearch(static_prune=...)): per-scope
    # per-rung verdicts ({path: {"m10": "EXACT", ...}}) and the number of
    # ladder rungs the abstract interpreter decided without a dispatch
    static_verdicts: Optional[Dict[str, Dict[str, str]]] = None
    n_pruned: int = 0

    @property
    def probes_per_dispatch_per_device(self) -> float:
        """Effective probe evaluations per device in the busiest dispatch:
        real rows (reference + candidates actually consumed, NOT identity
        padding) divided by the probe-axis shard count. > 1 means the
        sharded ladder still batches several real probes onto every device
        per launch (the tentpole's throughput contract)."""
        if self.n_devices <= 0:
            return 0.0
        return self.max_dispatch_rows / self.n_devices

    def policy(self) -> TruncationPolicy:
        rules = tuple(
            TruncationRule(fmt=a.fmt(self.exp_bits), scope=path)
            for path, a in self.assignments.items()
            if a.fmt(self.exp_bits) is not None)
        return TruncationPolicy(rules=rules)

    def hints(self) -> Dict[str, Optional[int]]:
        """This search's verdicts as warm-start hints for a later
        ``autosearch(warm_start=...)``: truncated scopes predict their
        assigned width; excluded or full-precision scopes pin high
        (``None``), seeding the next bisection at the finest rung."""
        return {path: (None if a.excluded or a.man_bits >= 23
                       else a.man_bits)
                for path, a in self.assignments.items()}

    def to_artifact(self, name: str, *, hints=None, oracle=None,
                    bench=None) -> "PolicyArtifact":
        """Package the search into a versioned, serializable
        :class:`repro.artifacts.PolicyArtifact`.

        ``hints`` defaults to :meth:`hints` (the measured assignments); pass
        the ``ladder_hints``/``MiniApp.warm_hints`` mapping that seeded this
        search to persist the trajectory-blame predictions instead.
        ``oracle`` takes an ``apps.oracle.OracleVerdict``; ``bench`` a BENCH
        row dict. Raises ``NotSerializableError`` if the policy carries
        mask-fn rules."""
        from repro.artifacts import PolicyArtifact, ScopeRow
        rows = {
            path: ScopeRow(
                man_bits=int(a.man_bits),
                error_at_accept=float(a.error_at_accept),
                excluded=bool(a.excluded),
                flops=float(a.scope.flops),
                fraction=float(a.scope.fraction),
                n_eqns=int(a.scope.n_eqns))
            for path, a in self.assignments.items()}
        prov = {
            "threshold": float(self.threshold),
            "budget": int(self.budget),
            "evals_used": int(self.evals_used),
            "final_error": float(self.final_error),
            "converged": bool(self.converged),
            "exp_bits": int(self.exp_bits),
            "n_compiles": int(self.n_compiles),
            "n_sites": int(self.n_sites),
            "n_dispatches": int(self.n_dispatches),
            "n_warm_hints": int(self.n_warm_hints),
            "probe_batch": int(self.probe_batch),
            "max_dispatch_rows": int(self.max_dispatch_rows),
            "n_devices": int(self.n_devices),
            "history": [[tag, float(v)] for tag, v in self.history],
        }
        if self.static_verdicts is not None:
            prov["static_pruned"] = int(self.n_pruned)
            prov["static_verdicts"] = {
                path: dict(rungs)
                for path, rungs in self.static_verdicts.items()}
        use_hints = dict(hints) if hints is not None else self.hints()
        art = PolicyArtifact(name=name, policy=self.policy(),
                             assignments=rows, provenance=prov,
                             hints=use_hints)
        if oracle is not None:
            art = art.with_oracle(oracle)
        if bench is not None:
            art = art.with_bench(bench)
        return art

    def table(self) -> str:
        """Per-scope format table — the textual analogue of the paper's
        per-region heatmap."""
        lines = [f"  {'scope':<32} {'flops%':>7} {'format':>8} "
                 f"{'err@accept':>11}  status"]
        for path, a in self.assignments.items():
            fmt = a.fmt(self.exp_bits)
            status = ("excluded" if a.excluded
                      else ("full" if fmt is None else "truncated"))
            lines.append(
                f"  {path:<32} {a.scope.fraction * 100:>6.1f}% "
                f"{(fmt.key if fmt else 'fp32'):>8} "
                f"{a.error_at_accept:>11.3e}  {status}")
        lines.append(
            f"  -- metric {self.final_error:.3e} (threshold "
            f"{self.threshold:.1e}) in {self.evals_used}/{self.budget} evals; "
            f"{'converged' if self.converged else 'NOT converged'}")
        return "\n".join(lines)


def autosearch(fn: Callable, args: Sequence = (),
               metric: _metrics.MetricSpec = None, budget: int = 64, *,
               kwargs: Optional[dict] = None, threshold: float = 1e-3,
               widths: Sequence[int] = DEFAULT_WIDTHS, exp_bits: int = 8,
               scopes: Optional[Sequence[ScopeInfo]] = None,
               min_fraction: float = 0.01, max_scopes: Optional[int] = None,
               memflag_threshold: Optional[float] = None,
               impl: str = "auto", refine: bool = True,
               warm_start: Optional[Dict[str, Optional[int]]] = None,
               static_prune: object = False,
               mesh=None, batch_axis: str = "probe", in_shardings=None,
               verbose: bool = False) -> SearchResult:
    """Search a per-scope mixed-precision assignment for ``fn(*args)``.

    Returns a :class:`SearchResult`; ``result.policy()`` is directly usable
    with ``api.truncate``. ``metric`` is resolved via
    ``metrics.resolve_metric``: ``None`` (max relative output deviation, the
    historical default), a registered name (``"max_rel"``, ``"mean_rel"``,
    ``"rel_l2"``, ``"loss"``), or any ``metric(ref_out, cand_out) -> float``
    callable — e.g. a mini-app's solver-level ``error_metric`` over
    observables. ``budget`` caps the total number of candidate evaluations.
    All candidates are evaluated through a single runtime-parameterized
    executable (probing every ladder width of a region in one vmapped
    call), so the search performs O(1) XLA compilations regardless of
    budget, scope count, or ladder length.

    ``mesh`` shards the candidate batches of BOTH phases — per-scope ladder
    probes and greedy-exclusion rounds — across ``mesh.shape[batch_axis]``
    devices: the fixed-width (K, num_sites, 4) table stack is partitioned on
    its leading candidate axis (rows replicated, profiled inputs placed per
    ``in_shardings``, default replicated), K rounded up to the shard
    multiple so every launch divides evenly. Budget accounting, probe order,
    and the returned assignments are identical to the single-device path —
    padded slots are identity rows whose outputs are never read.

    ``warm_start`` is the error-guided entry point: a mapping from scope
    path to a predicted mantissa width (``None`` = predicted inadmissible at
    every candidate width, i.e. pinned to full precision), typically built
    by ``repro.profile.ladder_hints`` from a ``profile_trajectory`` run.
    Hints reshape the *probe schedule*: instead of exhaustively probing
    every ladder rung per scope, each scope binary-searches the
    pass/fail boundary of its solo ladder, seeded at the hinted width, and
    every round batches all unresolved scopes into shared dispatches — so
    probe dispatches scale with the handful of bisection rounds instead of
    ``n_scopes`` (and good hints resolve most scopes in the very first
    round). The bisection trusts that a scope's solo error is monotone in
    mantissa width — exact for rounding-dominated workloads, and asserted
    against the unguided search on the mini-apps and the bench model in
    the test suite; a non-monotone ladder can make the guided pick differ
    (it is still a measured-admissible width, never an unvalidated one).

    ``static_prune`` turns on the abstract-interpretation pre-pass
    (``repro.analysis``): ``True`` calibrates input ranges from the
    concrete ``args``/``kwargs`` arrays; a sequence supplies one
    ``analysis.AbsVal`` (or concrete array) per traced input. Ladder rungs
    the analysis proves ``EXACT`` (solo run bit-identical to the
    reference; the dynamic probe would measure exactly 0.0) or
    ``OVERFLOW_CERTAIN`` (a non-finite provably reaches an output; the
    probe would fail) are decided without a dispatch; ``UNKNOWN`` rungs
    keep dynamic probing. Budget *accounting* mirrors the unpruned
    schedule (pruned rungs still consume their budget window), so the
    returned assignments are bit-identical to ``static_prune=False`` with
    strictly fewer ``evals_used`` and dispatches whenever anything was
    decided — assuming a metric that (a) is a deterministic function of
    the two observable pytrees (an EXACT rung's probe is substituted by
    the measured ``metric(ref, ref)``, which need not be 0.0: poisson's
    residual-excess metric grades the reference against its own
    convergence tolerance) and (b) rejects any candidate with a
    non-finite output leaf (all built-in metrics and the mini-app
    ``observable_error`` qualify; ``loss`` only inspects the first leaf,
    so overflow pruning relies on criticality reaching *some* output —
    use single-output loss fns with it). With ``warm_start`` hints,
    verdicts pre-seed the bisection brackets instead (same assignments
    under ample budget; a tight budget may legitimately assign
    differently since hint probes are not window-mirrored); the warm
    path additionally requires ``metric(ref, ref) == 0.0`` exactly —
    brackets are pre-seeded before any reference exists to measure — and
    raises otherwise. Verdicts land in ``SearchResult.static_verdicts``
    and artifact provenance.

    ``memflag_threshold`` is accepted for backward compatibility but unused:
    exclusion victims are now chosen by batched trial exclusion (which costs
    the same budget as the old mem-mode ranking pass but reuses the compiled
    sweep executable instead of compiling a shadow computation).
    """
    del memflag_threshold  # legacy knob of the mem-mode ranking pass
    metric = _metrics.resolve_metric(metric)
    kwargs = dict(kwargs or {})
    # index 0 of the ladder must always be full precision: scopes the search
    # never validates (budget exhaustion, all-rejected probes) are assigned
    # widths[0] with error 0.0, which is only honest for identity.
    widths = tuple(sorted({int(w) for w in widths}, reverse=True))
    if not widths or widths[0] < 23:
        widths = (23,) + widths

    from repro.distributed.sharding import pad_to_shards, probe_axis_size

    evals = 0
    history: List[Tuple[str, float]] = []
    compiles = 0
    dispatches = 0
    max_rows = 0
    ndev = probe_axis_size(mesh, batch_axis)
    dispatch_sigs: set = set()

    def log(msg: str) -> None:
        if verbose:
            print(f"[autosearch] {msg}", flush=True)

    closed, out_shape = jax.make_jaxpr(fn, return_shape=True)(*args, **kwargs)
    out_tree = jax.tree_util.tree_structure(out_shape)
    leaves = jax.tree_util.tree_leaves((tuple(args), kwargs))

    if scopes is None:
        scopes = discover_scopes(closed, min_fraction=min_fraction,
                                 max_scopes=max_scopes)
    scopes = list(scopes)

    hints = _frontier_hints(warm_start, scopes)
    sv = None      # analysis.StaticVerdicts when static_prune is active
    _V = None      # the Verdict enum, bound alongside sv
    virtual = 0    # unpruned-schedule budget charges (mirrors `evals`)

    def result(assignments, final_err):
        return SearchResult(
            assignments=assignments, exp_bits=exp_bits, threshold=threshold,
            budget=budget, evals_used=evals, final_error=final_err,
            converged=final_err <= threshold, history=history,
            n_compiles=compiles, n_sites=n_sites, n_dispatches=dispatches,
            n_warm_hints=len(hints),
            probe_batch=K, max_dispatch_rows=max_rows, n_devices=ndev,
            static_verdicts=sv.to_json() if sv is not None else None,
            n_pruned=sv.n_decided if sv is not None else 0)

    cand_widths = [w for w in widths if w < 23]
    n_sites = 0
    K = 0
    if not scopes or not cand_widths or budget < 2:
        # nothing searchable (or budget can't cover one probe + the joint
        # check): everything stays full precision, which is trivially exact
        assignments = {s.path: ScopeAssignment(s, widths[0], 0.0)
                       for s in scopes}
        history.append(("joint", 0.0))
        return result(assignments, 0.0)

    # ---- the one trace + one executable the whole search runs through ------
    # The site policy's matched set is the union of all candidate scopes;
    # its format is irrelevant (tables carry the formats at runtime).
    site_policy = TruncationPolicy(rules=tuple(
        TruncationRule(fmt=FPFormat(exp_bits, 0), scope=s.path)
        for s in scopes))
    index = interpreter.enumerate_sites(closed, site_policy)
    n_sites = len(index)
    from repro.distributed.sharding import flatten_arg_shardings
    _, run_batch = interpreter.parameterized_callable(
        closed, out_tree, index, impl,
        mesh=mesh, batch_axis=batch_axis,
        flat_shardings=flatten_arg_shardings(mesh, in_shardings,
                                             tuple(args), kwargs))
    # fixed batch width: every call shares one (K, num_sites, 4) signature,
    # so XLA compiles the batched evaluator exactly once. The LOGICAL width
    # fits a full per-scope ladder plus the reference row of the very first
    # call; under a mesh the physical K is rounded up so the sharded
    # candidate axis divides evenly. Chunking always fills at most k_logical
    # REAL rows per dispatch — the extra sharded slots only ever carry
    # identity padding (outputs never read), so n_dispatches,
    # max_dispatch_rows and every stat derived from them are bit-identical
    # to the unsharded path even when k_logical doesn't divide the shard
    # count.
    k_logical = len(cand_widths) + 1
    K = pad_to_shards(k_logical, mesh, batch_axis)

    if static_prune is not False and static_prune is not None:
        from repro.analysis import analyze_closed, scope_rung_verdicts
        from repro.analysis.verdicts import Verdict as _V
        calib = leaves if static_prune is True else list(static_prune)
        analysis = analyze_closed(closed, calib)
        sv = scope_rung_verdicts(analysis, index, [s.path for s in scopes],
                                 cand_widths, exp_bits)
        log(f"static analysis: {sv.n_decided} rungs decided, "
            f"{analysis.n_widened} carries widened, outputs "
            f"{'finite' if sv.outputs_finite else 'NOT provably finite'}")

    ref_host: List[Optional[object]] = [None]  # full-precision outputs (np)
    self_err: List[Optional[float]] = [None]   # metric(ref, ref), with ref

    def eval_candidates(cands: List[Tuple[str, TruncationPolicy]]
                        ) -> List[float]:
        """Evaluate candidate policies through the batched executable,
        chunked to the fixed width K; returns metric values and charges one
        budget eval per candidate."""
        nonlocal evals, compiles, dispatches, max_rows
        errs: List[float] = []
        pos = 0
        while pos < len(cands) or ref_host[0] is None:
            chunk = []
            rows = []
            if ref_host[0] is None:
                rows.append(index.identity_table())
            take = k_logical - len(rows)
            for tag, pol in cands[pos:pos + take]:
                chunk.append(tag)
                rows.append(index.table_for(pol))
            pos += len(chunk)
            max_rows = max(max_rows, len(rows))  # real rows, pre-padding
            while len(rows) < K:          # pad to the fixed signature
                rows.append(index.identity_table())
            stacked = np.stack(rows)
            sig = (stacked.shape, str(stacked.dtype))
            if sig not in dispatch_sigs:  # a new signature = a new compile
                dispatch_sigs.add(sig)
                compiles += 1
            dispatches += 1
            outs = run_batch(stacked, leaves)
            host = jax.device_get(outs)   # numpy pytree, leading K axis
            base = 0
            if ref_host[0] is None:
                ref_host[0] = jax.tree_util.tree_map(lambda a: a[0], host)
                base = 1
                if sv is not None:
                    # static pruning substitutes metric(ref, ref) for
                    # EXACT-rung probes: an EXACT rung's outputs are
                    # bit-identical to the reference, so this measured
                    # value IS what the probe would return. Usually 0.0;
                    # residual-style metrics (poisson) grade the reference
                    # against its own convergence tolerance and can return
                    # more (or NaN on a non-finite reference — either way
                    # the substitution matches the unpruned measurement).
                    self_err[0] = metric(ref_host[0], ref_host[0])
                    if hints and not self_err[0] == 0.0:  # '== ' vs NaN too
                        raise ValueError(
                            "static_prune with warm_start requires "
                            "metric(ref, ref) to be exactly 0.0, got "
                            f"{self_err[0]!r}: the warm bisection "
                            "pre-seeds EXACT rungs as passing before the "
                            "reference exists to measure — rerun with "
                            "warm_start=None or static_prune=False")
            for j, tag in enumerate(chunk):
                cand = jax.tree_util.tree_map(
                    lambda a, j=j: a[base + j], host)
                err = metric(ref_host[0], cand)
                history.append((tag, err))
                evals += 1
                errs.append(err)
        return errs

    def policy_of(assign: Dict[str, ScopeAssignment],
                  extra: Optional[Tuple[str, int]] = None,
                  minus: Optional[str] = None) -> TruncationPolicy:
        rules = []
        pending = dict(assign)
        if extra is not None:
            path, m = extra
            pending[path] = ScopeAssignment(
                scope=next(s for s in scopes if s.path == path),
                man_bits=m, error_at_accept=0.0)
        for path, a in pending.items():
            if path == minus:
                continue
            f = a.fmt(exp_bits)
            if f is not None:
                rules.append(TruncationRule(fmt=f, scope=path))
        return TruncationPolicy(rules=tuple(rules))

    # ---- phase 1: solo per-scope ladder probe, widest work first -----------
    # Each candidate truncates ONE region; the narrowest admissible width is
    # that region's measured sensitivity. Composition errors are phase 2's
    # job. One evaluation stays reserved for the joint check so evals_used
    # can never exceed the budget.
    reserve = 1
    assignments: Dict[str, ScopeAssignment] = {}

    def accept(si, w_pick, err_pick):
        assignments[si.path] = ScopeAssignment(si, w_pick, err_pick)
        log(f"{si.path} ({si.fraction * 100:.1f}% flops) -> "
            f"m{w_pick} (err {err_pick:.3e}, {evals} evals)")

    if hints:
        # ---- error-guided warm start (see the warm_start doc above) --------
        # Solo ladder error is monotone in mantissa width for rounding-
        # dominated workloads (each bit halves the local error), so the
        # narrowest admissible width is the boundary of a pass-prefix of the
        # finest-first ladder. Round 1 probes every scope's hinted rung plus
        # its next-narrower neighbour (an accurate hint brackets the
        # boundary immediately; pinned-high scopes seed at the finest rung,
        # so one failing probe confirms "nothing passes"); round 2 probes
        # whatever interval round 1 left undecided. Both rounds pack ALL
        # scopes into shared dispatches, so probe dispatches are bounded by
        # the two rounds — not by scopes x ladder length — and rungs are
        # only skipped when the measured boundary implies them.
        nw = len(cand_widths)
        lo = {si.path: -1 for si in scopes}   # largest index known passing
        hi = {si.path: nw for si in scopes}   # smallest index known failing
        err_at: Dict[Tuple[str, int], float] = {}

        if sv is not None:
            # static verdicts pre-tighten the bisection brackets: EXACT
            # rungs are known passing at exactly 0.0 error (solo run is
            # bit-identical to the reference; eval_candidates validates
            # metric(ref, ref) == 0.0 for this path on first dispatch),
            # OVERFLOW_CERTAIN rungs are known failing — neither probes
            for si in scopes:
                for i, w in enumerate(cand_widths):
                    v = sv.get(si.path, w)
                    if v == _V.EXACT:
                        err_at[(si.path, i)] = 0.0
                        lo[si.path] = max(lo[si.path], i)
                    elif v == _V.OVERFLOW_CERTAIN:
                        hi[si.path] = min(hi[si.path], i)

        def seed(si) -> int:
            pred = hints.get(si.path, _UNHINTED)
            if pred is _UNHINTED:
                return (nw - 1) // 2          # no information: start mid
            if pred is None:
                return 0                       # pinned high: finest rung
            if any(w >= pred for w in cand_widths):
                # narrowest candidate at/above the predicted width
                return max(i for i, w in enumerate(cand_widths) if w >= pred)
            return 0

        def probe_round(plan) -> None:
            batch: List[Tuple[ScopeInfo, int]] = []
            planned = 0
            for si in scopes:
                afford = budget - evals - reserve - planned
                if afford <= 0:
                    break
                idxs = [i for i in plan(si)
                        if lo[si.path] < i < hi[si.path]][:afford]
                planned += len(idxs)
                batch.extend((si, i) for i in idxs)
            if not batch:
                return
            errs = eval_candidates([
                (f"ladder:{si.path}:m{cand_widths[i]}",
                 policy_of({}, (si.path, cand_widths[i])))
                for si, i in batch])
            for (si, i), e in zip(batch, errs):
                err_at[(si.path, i)] = e
                if e <= threshold:
                    lo[si.path] = max(lo[si.path], i)
                else:
                    hi[si.path] = min(hi[si.path], i)

        def seed_plan(si):
            s = seed(si)
            if hints.get(si.path, _UNHINTED) is None:
                return [s]   # pinned high: the failing finest-rung probe
                             # alone confirms "nothing passes"
            return [i for i in (s, s + 1) if i < nw]

        probe_round(seed_plan)
        probe_round(lambda si: range(lo[si.path] + 1, hi[si.path]))
        for si in scopes:
            b = lo[si.path]
            if b >= 0:
                # narrowest width measured admissible (== the full-ladder
                # pick whenever solo error is monotone in width)
                accept(si, cand_widths[b], err_at[(si.path, b)])
            else:
                accept(si, widths[0], 0.0)     # nothing admissible: full
    elif sv is not None:
        # ---- statically pruned exhaustive ladder ---------------------------
        # Budget windows mirror the unpruned schedule exactly (`virtual`
        # charges what the unpruned search would have charged), so each
        # scope sees the identical probe window and the accepted widths are
        # bit-identical; only UNKNOWN rungs dispatch. All surviving probes
        # share one chunked eval_candidates call, so dispatches shrink too.
        plan: List[Tuple[ScopeInfo, Optional[List[int]], List[int]]] = []
        for si in scopes:
            afford = budget - virtual - reserve
            if afford <= 0:
                plan.append((si, None, []))   # window exhausted: full prec
                continue
            probe = cand_widths[:afford]
            virtual += len(probe)
            live = [w for w in probe if sv.get(si.path, w) == _V.UNKNOWN]
            exact = [w for w in probe if sv.get(si.path, w) == _V.EXACT]
            plan.append((si, live, exact))
        flat = [(si, w) for si, live, _ in plan if live for w in live]
        flat_errs = eval_candidates([
            (f"ladder:{si.path}:m{w}", policy_of({}, (si.path, w)))
            for si, w in flat]) if flat else []
        if ref_host[0] is None and any(exact for _, _, exact in plan):
            # every probe was statically decided but EXACT substitution
            # needs the measured metric(ref, ref): materialize the
            # reference (one dispatch the unpruned search also pays)
            eval_candidates([])
        z = self_err[0]
        pos = 0
        for si, live, exact in plan:
            if live is None:
                assignments[si.path] = ScopeAssignment(si, widths[0], 0.0)
                continue
            errs = flat_errs[pos:pos + len(live)]
            pos += len(live)
            passing = ([(w, e) for w, e in zip(live, errs) if e <= threshold]
                       + [(w, z) for w in exact if z <= threshold])
            if passing:
                accept(si, *min(passing))    # narrowest admissible width
            else:
                assignments[si.path] = ScopeAssignment(si, widths[0], 0.0)
    else:
        for si in scopes:
            afford = budget - evals - reserve
            if afford <= 0:
                assignments[si.path] = ScopeAssignment(si, widths[0], 0.0)
                continue
            # under a tight budget probe the finest widths (most likely to
            # be admissible, so the scope still gets some truncation)
            probe = cand_widths[:afford]
            errs = eval_candidates([
                (f"ladder:{si.path}:m{w}", policy_of({}, (si.path, w)))
                for w in probe])
            passing = [(w, e) for w, e in zip(probe, errs) if e <= threshold]
            if passing:
                accept(si, *min(passing))    # narrowest admissible width
            else:
                assignments[si.path] = ScopeAssignment(si, widths[0], 0.0)

    # ---- phase 2: joint check + greedy-exclusion refinement ----------------
    if sv is not None and hints:
        # hint probes are not window-mirrored (the bisection already adapts
        # its schedule to measurements); phase 2 mirrors from actual spend
        virtual = evals

    def spent() -> int:
        """Budget consumed for *control flow*: the unpruned schedule's
        charge count when static pruning is on (so windows and loop exits
        match the unpruned search decision-for-decision), actual evals
        otherwise."""
        return virtual if sv is not None else evals

    if policy_of(assignments).rules:
        if sv is not None and all(
                sv.get(p, a.man_bits) == _V.EXACT
                for p, a in assignments.items()
                if a.fmt(exp_bits) is not None):
            # every truncated scope sits on a statically EXACT rung: by
            # induction over program order every quantize in the joint
            # policy is the identity, so the joint run is bit-identical to
            # the reference and would measure metric(ref, ref) — no
            # dispatch needed (all-EXACT assignments imply the rungs were
            # accepted as passing, so the reference is already measured)
            if ref_host[0] is None:
                eval_candidates([])
            final_err = self_err[0]
            history.append(("joint", final_err))
            virtual += 1
        else:
            final_err = eval_candidates([("joint",
                                          policy_of(assignments))])[0]
            virtual += 1
    else:
        final_err = 0.0  # nothing truncated -> trivially exact, no eval owed
        history.append(("joint", 0.0))
    log(f"joint policy err {final_err:.3e}")

    while refine and final_err > threshold and spent() < budget:
        live = [p for p, a in assignments.items()
                if not a.excluded and a.fmt(exp_bits) is not None]
        if not live:
            break
        # most fragile first: the scope whose solo error was worst is the
        # likeliest culprit, so it is tried even under a clipped budget
        live.sort(key=lambda p: -assignments[p].error_at_accept)
        live = live[:budget - spent()]
        if sv is not None:
            virtual += len(live)
            # a scope whose assigned format is *universally* exact (grid
            # covers its sites' entire carrier dtype, not just the
            # reference values) quantizes nothing even inside a perturbed
            # joint policy: minus-that-scope is bit-identical to the
            # current joint, so its trial-exclusion error IS final_err
            measured = [p for p in live
                        if not sv.is_universal(p, assignments[p].man_bits)]
            m_errs = eval_candidates([
                (f"exclude?:{p}", policy_of(assignments, minus=p))
                for p in measured]) if measured else []
            by_scope = dict(zip(measured, m_errs))
            errs = []
            for p in live:
                if p in by_scope:
                    errs.append(by_scope[p])
                else:
                    errs.append(final_err)
                    history.append((f"exclude?:{p}", final_err))
        else:
            errs = eval_candidates([
                (f"exclude?:{p}", policy_of(assignments, minus=p))
                for p in live])
        best = int(np.argmin(errs))
        victim = live[best]
        assignments[victim].excluded = True
        final_err = errs[best]
        history.append((f"exclude:{victim}", final_err))
        log(f"exclude {victim} (paper §6.3) -> err {final_err:.3e}")

    if sv is not None and ref_host[0] is None:
        # degenerate all-static search (every rung decided, joint skipped,
        # every exclusion substituted): materialize the reference anyway so
        # the metric contract above is still validated before reporting
        eval_candidates([])

    return result(assignments, final_err)
