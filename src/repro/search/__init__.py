# Automated mixed-precision search over named scopes (paper §6.3 closed
# loop) — scope discovery, mantissa bisection, greedy-exclusion refinement.
from repro.search.driver import (
    autosearch, SearchResult, ScopeAssignment, DEFAULT_WIDTHS,
)
from repro.search.scopes import discover_scopes, scope_tree, ScopeInfo
from repro.search.metrics import (
    rel_error, mean_rel_error, rel_l2_error, loss_degradation,
    default_metric, resolve_metric, from_observables, NAMED_METRICS,
)

__all__ = [
    "autosearch", "SearchResult", "ScopeAssignment", "DEFAULT_WIDTHS",
    "discover_scopes", "scope_tree", "ScopeInfo",
    "rel_error", "mean_rel_error", "rel_l2_error", "loss_degradation",
    "default_metric", "resolve_metric", "from_observables", "NAMED_METRICS",
]
