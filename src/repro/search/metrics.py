"""Pluggable error metrics for the precision search.

A metric is any ``metric(ref_out, cand_out) -> float`` where smaller is
better and the search threshold bounds it. ``ref_out``/``cand_out`` are the
full pytree outputs of the profiled function (full-precision vs candidate
policy).
"""
from __future__ import annotations

import numpy as np

import jax

_EPS = 1e-12


def _leaves(tree):
    return [np.asarray(jax.device_get(x))
            for x in jax.tree_util.tree_leaves(tree)]


def rel_error(ref_out, cand_out) -> float:
    """Max relative deviation over all output leaves and elements.

    NaN/Inf appearing in the candidate where the reference is finite counts
    as infinite error — a policy that overflows must never be admissible."""
    worst = 0.0
    for r, c in zip(_leaves(ref_out), _leaves(cand_out)):
        r = r.astype(np.float64, copy=False)
        c = c.astype(np.float64, copy=False)
        ok = np.isfinite(r)
        if not np.all(np.isfinite(c[ok] if r.shape else c)):
            return float("inf")
        if r.size == 0:
            continue
        d = np.abs(c - r) / (np.abs(r) + _EPS)
        d = d[ok] if r.shape else d
        if d.size:
            worst = max(worst, float(np.max(d)))
    return worst


def loss_degradation(ref_out, cand_out) -> float:
    """|Δloss| / |loss| for scalar(-first) outputs — the metric of the
    paper's application studies ('accept if the figure of merit moves less
    than the budget')."""
    r = _leaves(ref_out)[0].astype(np.float64).ravel()
    c = _leaves(cand_out)[0].astype(np.float64).ravel()
    if not np.all(np.isfinite(c)):
        return float("inf")
    return float(np.abs(c[0] - r[0]) / max(np.abs(r[0]), _EPS))


def mean_rel_error(ref_out, cand_out) -> float:
    """Mean (not max) relative deviation — a softer target for noisy
    workloads where a handful of tiny denominators shouldn't veto."""
    num = 0.0
    den = 0
    for r, c in zip(_leaves(ref_out), _leaves(cand_out)):
        r = r.astype(np.float64, copy=False)
        c = c.astype(np.float64, copy=False)
        if not np.all(np.isfinite(c[np.isfinite(r)] if r.shape else c)):
            return float("inf")
        d = np.abs(c - r) / (np.abs(r) + _EPS)
        num += float(np.sum(d))
        den += d.size
    return num / max(den, 1)


default_metric = rel_error
