"""Pluggable error metrics for the precision search.

A metric is any ``metric(ref_out, cand_out) -> float`` where smaller is
better and the search threshold bounds it. ``ref_out``/``cand_out`` are the
full pytree outputs of the profiled function (full-precision vs candidate
policy).

``autosearch`` (and the app oracle layer) resolve their ``metric`` argument
through :func:`resolve_metric`, so a metric may be supplied as

  * ``None``                  — the default (max elementwise relative error),
  * a registered name         — ``"max_rel"``, ``"mean_rel"``, ``"rel_l2"``,
                                ``"loss"``,
  * any callable              — e.g. a mini-app's solver-level
                                ``error_metric`` over observables, or
  * :func:`from_observables`  — lift an observable map over raw outputs.
"""
from __future__ import annotations

from typing import Callable, Optional, Union

import numpy as np

import jax

_EPS = 1e-12


def _leaves(tree):
    return [np.asarray(jax.device_get(x))
            for x in jax.tree_util.tree_leaves(tree)]


def rel_error(ref_out, cand_out) -> float:
    """Max relative deviation over all output leaves and elements.

    NaN/Inf appearing in the candidate where the reference is finite counts
    as infinite error — a policy that overflows must never be admissible."""
    worst = 0.0
    for r, c in zip(_leaves(ref_out), _leaves(cand_out)):
        r = r.astype(np.float64, copy=False)
        c = c.astype(np.float64, copy=False)
        ok = np.isfinite(r)
        if not np.all(np.isfinite(c[ok] if r.shape else c)):
            return float("inf")
        if r.size == 0:
            continue
        d = np.abs(c - r) / (np.abs(r) + _EPS)
        d = d[ok] if r.shape else d
        if d.size:
            worst = max(worst, float(np.max(d)))
    return worst


def loss_degradation(ref_out, cand_out) -> float:
    """|Δloss| / |loss| for scalar(-first) outputs — the metric of the
    paper's application studies ('accept if the figure of merit moves less
    than the budget')."""
    r = _leaves(ref_out)[0].astype(np.float64).ravel()
    c = _leaves(cand_out)[0].astype(np.float64).ravel()
    if not np.all(np.isfinite(c)):
        return float("inf")
    return float(np.abs(c[0] - r[0]) / max(np.abs(r[0]), _EPS))


def rel_l2_error(ref_out, cand_out) -> float:
    """Worst per-leaf relative L2 deviation ||c - r||_2 / ||r||_2 — the
    field-level metric of the PDE mini-apps (a solution profile is judged as
    a whole, not by its worst cell). Scalar leaves degrade to the plain
    relative error; a non-finite candidate where the reference is finite is
    infinitely wrong."""
    worst = 0.0
    for r, c in zip(_leaves(ref_out), _leaves(cand_out)):
        r = r.astype(np.float64, copy=False)
        c = c.astype(np.float64, copy=False)
        if r.size == 0:
            continue
        if np.all(np.isfinite(r)) and not np.all(np.isfinite(c)):
            return float("inf")
        num = float(np.linalg.norm((c - r).ravel()))
        den = float(np.linalg.norm(r.ravel()))
        worst = max(worst, num / (den + _EPS))
    return worst


def mean_rel_error(ref_out, cand_out) -> float:
    """Mean (not max) relative deviation — a softer target for noisy
    workloads where a handful of tiny denominators shouldn't veto."""
    num = 0.0
    den = 0
    for r, c in zip(_leaves(ref_out), _leaves(cand_out)):
        r = r.astype(np.float64, copy=False)
        c = c.astype(np.float64, copy=False)
        if not np.all(np.isfinite(c[np.isfinite(r)] if r.shape else c)):
            return float("inf")
        d = np.abs(c - r) / (np.abs(r) + _EPS)
        num += float(np.sum(d))
        den += d.size
    return num / max(den, 1)


default_metric = rel_error

# names accepted anywhere a metric argument is resolved (autosearch, the
# app oracle layer, benchmarks); "max_rel" documents what the default was
# before metrics became user-suppliable
NAMED_METRICS = {
    "max_rel": rel_error,
    "rel": rel_error,
    "mean_rel": mean_rel_error,
    "rel_l2": rel_l2_error,
    "loss": loss_degradation,
}

MetricSpec = Union[None, str, Callable]


def resolve_metric(metric: MetricSpec = None) -> Callable:
    """Resolve a user-supplied metric spec to a callable.

    ``None`` keeps the historical behavior (max elementwise relative error);
    a string looks up :data:`NAMED_METRICS`; a callable — e.g. a mini-app's
    ``error_metric`` over solver observables — passes through unchanged."""
    if metric is None:
        return default_metric
    if callable(metric):
        return metric
    if isinstance(metric, str):
        try:
            return NAMED_METRICS[metric]
        except KeyError:
            raise ValueError(
                f"unknown metric name {metric!r}; "
                f"known: {sorted(NAMED_METRICS)}") from None
    raise TypeError(
        f"metric must be None, a name, or a callable, got {type(metric)}")


def from_observables(observables_fn: Callable,
                     metric: MetricSpec = None) -> Callable:
    """Lift a ``state -> observables`` map into a search metric over raw
    profiled-function outputs: both outputs are mapped to their solver-level
    observables and compared there. This is how an app whose profiled
    function returns raw state (instead of observables) still searches
    against physically meaningful quantities."""
    inner = resolve_metric(metric)

    def obs_metric(ref_out, cand_out) -> float:
        return inner(observables_fn(ref_out), observables_fn(cand_out))

    obs_metric.__name__ = f"from_observables({getattr(observables_fn, '__name__', '?')})"
    return obs_metric
