"""Scope discovery: enumerate ``named_scope`` subtrees of a traced jaxpr.

The search driver needs a work-list of regions to try truncating. RAPTOR
gets its region list from the symbol table (every function is a scope); our
analogue is the ``jax.named_scope`` name stack that models already use to
label every module ("layers/attn/qkv", ...). We walk the jaxpr — recursing
through higher-order primitives exactly like the counters do — and build a
scope tree annotated with FLOP counts, then cut a *frontier* through it:
the deepest scopes that each still carry a meaningful fraction of the total
work. Those frontier scopes are the search variables.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
from jax._src import core as jcore

from repro.core.counters import _eqn_flops
from repro.core.policy import join_stack, normalize_stack

_SUB_JAXPRS = {
    "jit": ("jaxpr",), "pjit": ("jaxpr",), "closed_call": ("call_jaxpr",),
    "core_call": ("call_jaxpr",), "remat2": ("jaxpr",),
    "checkpoint": ("jaxpr",),
    "custom_jvp_call": ("call_jaxpr",), "custom_vjp_call": ("call_jaxpr",),
}


@dataclasses.dataclass(frozen=True)
class ScopeInfo:
    """One named-scope subtree: its normalized path, the float FLOPs bound
    to it (including all children), and how many float-producing equations
    it contains."""

    path: str
    flops: float
    n_eqns: int
    fraction: float  # of total float FLOPs in the program


def _walk(jaxpr: jcore.Jaxpr, prefix: str, mult: float,
          flops: Dict[str, float], eqns: Dict[str, int]) -> None:
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        sub_prefix = join_stack(prefix, str(eqn.source_info.name_stack))
        if prim in _SUB_JAXPRS:
            for key in _SUB_JAXPRS[prim]:
                inner = eqn.params[key]
                inner = inner.jaxpr if isinstance(inner, jcore.ClosedJaxpr) else inner
                _walk(inner, sub_prefix, mult, flops, eqns)
            continue
        if prim == "scan":
            _walk(eqn.params["jaxpr"].jaxpr, sub_prefix,
                  mult * eqn.params["length"], flops, eqns)
            continue
        if prim == "while":
            _walk(eqn.params["body_jaxpr"].jaxpr, sub_prefix, mult, flops, eqns)
            continue
        if prim == "cond":
            # branches are mutually exclusive at runtime: credit only the
            # largest one (same upper-bound convention as counters)
            best = None
            for br in eqn.params["branches"]:
                bf: Dict[str, float] = {}
                be: Dict[str, int] = {}
                _walk(br.jaxpr, sub_prefix, mult, bf, be)
                if best is None or bf.get("", 0.0) > best[0].get("", 0.0):
                    best = (bf, be)
            if best is not None:
                for k, v in best[0].items():
                    flops[k] = flops.get(k, 0.0) + v
                for k, v in best[1].items():
                    eqns[k] = eqns.get(k, 0) + v
            continue

        # only float-producing eqns are candidates for truncation; integer
        # work must not drag a scope into the search space
        if not any(hasattr(v.aval, "dtype")
                   and jnp.issubdtype(v.aval.dtype, jnp.floating)
                   for v in eqn.outvars):
            continue
        f = _eqn_flops(eqn) * mult
        if f <= 0.0:
            continue
        path = normalize_stack(sub_prefix)
        # credit the eqn to every enclosing scope prefix
        segs = [s for s in path.split("/") if s]
        acc = ""
        for seg in segs:
            acc = f"{acc}/{seg}" if acc else seg
            flops[acc] = flops.get(acc, 0.0) + f
            eqns[acc] = eqns.get(acc, 0) + 1
        flops[""] = flops.get("", 0.0) + f
        eqns[""] = eqns.get("", 0) + 1


def scope_tree(closed: jcore.ClosedJaxpr) -> Dict[str, float]:
    """All normalized scope paths with their (multiplicity-weighted) float
    FLOPs. The empty path holds the program total."""
    flops: Dict[str, float] = {}
    eqns: Dict[str, int] = {}
    _walk(closed.jaxpr, "", 1.0, flops, eqns)
    return flops


def discover_scopes(closed: jcore.ClosedJaxpr, *,
                    min_fraction: float = 0.01,
                    max_scopes: Optional[int] = None) -> List[ScopeInfo]:
    """Cut the search frontier through the scope tree.

    A scope is *refined* into its children when at least one child carries
    ``min_fraction`` of the total work; otherwise it is kept whole. The
    result is a list of disjoint scopes ordered by descending FLOPs — the
    per-scope variables the precision search will assign formats to.
    """
    flops: Dict[str, float] = {}
    eqns: Dict[str, int] = {}
    _walk(closed.jaxpr, "", 1.0, flops, eqns)
    total = flops.get("", 0.0)
    if total <= 0.0:
        return []

    children: Dict[str, List[str]] = {}
    for path in flops:
        if not path:
            continue
        parent = path.rsplit("/", 1)[0] if "/" in path else ""
        children.setdefault(parent, []).append(path)

    frontier: List[str] = []

    def cut(path: str) -> None:
        kids = children.get(path, [])
        big = [k for k in kids if flops[k] / total >= min_fraction]
        if big:
            for k in big:
                cut(k)
            # siblings below the threshold stay unassigned (full precision)
            return
        if path:
            frontier.append(path)

    cut("")
    out = [ScopeInfo(p, flops[p], eqns[p], flops[p] / total)
           for p in frontier]
    out.sort(key=lambda s: -s.flops)
    if max_scopes is not None:
        out = out[:max_scopes]
    return out
