"""Static linting of truncation policies and policy artifacts.

Checks that need no execution, only the policy structure and (optionally)
the traced model it will be deployed against:

  * ``mask-not-serializable`` — a rule carries a dynamic-mask callable;
    such a policy cannot round-trip through a ``PolicyArtifact``
    (error when ``serializable_required``, warning otherwise).
  * ``shadowed-rule`` — a rule is fully covered by an earlier rule (or,
    with model sites, matches sites but first-match never selects it):
    dead configuration that silently diverges from the author's intent.
  * ``excluded-rule`` — a policy-level exclude covers a rule's whole
    scope, so the rule can never fire.
  * ``dead-rule`` — with model sites: a rule that matches zero enumerable
    quantize sites (typo'd scope, wrong dtype filter, ...).
  * ``dot-accumulator-risk`` — with range analysis: a
    ``quantize_dot_inputs`` rule on a dot site whose worst-case
    accumulator magnitude ``n * |lhs| * |rhs|`` exceeds the carrier's
    finite range — quantizing the inputs cannot make the accumulation
    safe, and saturating input formats can hide the overflow.
  * ``scope-drift-missing`` / ``scope-drift-new`` — an artifact's
    per-scope assignments vs the current model's enumerable scope
    frontier: a committed assignment whose scope no longer exists is an
    error (the deployed policy silently stopped truncating it); a new
    frontier scope the artifact has never judged is a warning.

``python -m repro.analysis.lint <paths...>`` lints committed artifact
JSON files; ``Registry.save`` runs ``lint_artifact`` before publishing
(errors block, warnings are recorded in provenance); the policy-drift
gate lints the committed artifact before diffing assignments.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.formats import FPFormat, parse_format
from repro.core.policy import (
    TruncationPolicy, TruncationRule, compile_scope, normalize_stack,
    scope_matches,
)
from repro.analysis.domain import carrier_format

ERROR = "error"
WARNING = "warning"

_DOT_PRIMS = frozenset({"dot_general", "conv_general_dilated", "ragged_dot"})


class ArtifactLintError(ValueError):
    """An artifact failed lint with error-level findings; raised by
    ``Registry.save`` to block publication."""

    def __init__(self, findings: Sequence["Finding"]):
        self.findings = list(findings)
        lines = [f.render() for f in self.findings if f.level == ERROR]
        super().__init__("policy artifact failed lint:\n  "
                         + "\n  ".join(lines))


@dataclasses.dataclass(frozen=True)
class Finding:
    code: str
    level: str                      # "error" | "warning"
    message: str
    scope: Optional[str] = None
    rule_index: Optional[int] = None

    def render(self) -> str:
        where = ""
        if self.rule_index is not None:
            where = f" [rule #{self.rule_index}]"
        elif self.scope is not None:
            where = f" [{self.scope}]"
        return f"{self.level.upper()} {self.code}{where}: {self.message}"


def errors(findings: Sequence[Finding]) -> List[Finding]:
    return [f for f in findings if f.level == ERROR]


# --------------------------------------------------------------------------
# structural rule coverage (no model needed)
# --------------------------------------------------------------------------

def _scope_covers(pa: str, pb: str) -> bool:
    """Conservative: True only when every stack pattern ``pb`` can match
    is also matched by ``pa``."""
    if pa == "**" or pa == pb:
        return True
    if "*" not in pb and "?" not in pb:
        # pb is concrete; scope matching extends over '/'-suffixes, and
        # pa matching pb implies pa matches every pb/... extension too
        return scope_matches(compile_scope(pa), pb)
    return False


def _ops_cover(a: TruncationRule, b: TruncationRule) -> bool:
    """True only when every primitive ``b`` can match is matched by ``a``."""
    if a.ops is None:
        if not a.exclude_ops:
            return True
        if b.ops is not None:
            return not (set(a.exclude_ops)
                        & (set(b.ops) - set(b.exclude_ops)))
        return set(a.exclude_ops) <= set(b.exclude_ops)
    if b.ops is None:
        return False
    return ((set(b.ops) - set(b.exclude_ops))
            <= (set(a.ops) - set(a.exclude_ops)))


def covers(a: TruncationRule, b: TruncationRule) -> bool:
    """``a`` earlier than ``b`` in a first-match-wins list: does ``a``
    match everything ``b`` matches (making ``b`` dead)? Conservative —
    False whenever coverage cannot be proven."""
    if a.from_width is not None and a.from_width != b.from_width:
        return False
    return _scope_covers(a.scope, b.scope) and _ops_cover(a, b)


# --------------------------------------------------------------------------
# policy lint
# --------------------------------------------------------------------------

def _rule_matches_site(policy: TruncationPolicy, rule_idx: int,
                       site: Any) -> bool:
    stack = normalize_stack(site.stack)
    for rx in policy._ex_rx:
        if scope_matches(rx, stack):
            return False
    return policy.rules[rule_idx].matches(stack, site.prim, site.dtype)


def _winning_rule(policy: TruncationPolicy, site: Any) -> Optional[int]:
    stack = normalize_stack(site.stack)
    for rx in policy._ex_rx:
        if scope_matches(rx, stack):
            return None
    for i, rule in enumerate(policy.rules):
        if rule.matches(stack, site.prim, site.dtype):
            return i
    return None


def lint_policy(policy: TruncationPolicy, *,
                sites: Optional[Sequence[Any]] = None,
                analysis_result: Any = None,
                index: Any = None,
                serializable_required: bool = False) -> List[Finding]:
    """Lint one policy. ``sites`` (``QuantizeSite``-like: ``.stack`` /
    ``.prim`` / ``.dtype`` / ``.index``) enables the model-aware checks;
    ``analysis_result`` + ``index`` (an ``AnalysisResult`` over the same
    trace and its ``SiteIndex``) enable the accumulator-risk check."""
    findings: List[Finding] = []

    for i, rule in enumerate(policy.rules):
        if rule.mask is not None:
            findings.append(Finding(
                code="mask-not-serializable",
                level=ERROR if serializable_required else WARNING,
                message=(f"rule scope={rule.scope!r} carries dynamic mask "
                         f"{getattr(rule.mask, '__name__', rule.mask)!r}; "
                         "it cannot be serialized into a policy artifact"),
                scope=rule.scope, rule_index=i))

    # structural shadowing / exclusion (first matching rule wins)
    for i, rule in enumerate(policy.rules):
        for pat in policy.excludes:
            if _scope_covers(pat, rule.scope):
                findings.append(Finding(
                    code="excluded-rule", level=WARNING,
                    message=(f"rule scope={rule.scope!r} is entirely "
                             f"covered by policy exclude {pat!r} and can "
                             "never fire"),
                    scope=rule.scope, rule_index=i))
                break
        else:
            for j in range(i):
                if covers(policy.rules[j], rule):
                    findings.append(Finding(
                        code="shadowed-rule", level=WARNING,
                        message=(f"rule scope={rule.scope!r} is fully "
                                 f"shadowed by earlier rule #{j} "
                                 f"(scope={policy.rules[j].scope!r}); "
                                 "first match wins, so it never fires"),
                        scope=rule.scope, rule_index=i))
                    break

    if sites is not None:
        structurally_dead = {f.rule_index for f in findings
                             if f.code in ("shadowed-rule", "excluded-rule")}
        wins: Dict[int, int] = {}
        for s in sites:
            w = _winning_rule(policy, s)
            if w is not None:
                wins[w] = wins.get(w, 0) + 1
        for i, rule in enumerate(policy.rules):
            if i in structurally_dead or wins.get(i):
                continue
            if any(_rule_matches_site(policy, i, s) for s in sites):
                findings.append(Finding(
                    code="shadowed-rule", level=WARNING,
                    message=(f"rule scope={rule.scope!r} matches sites in "
                             "this model, but an earlier rule wins every "
                             "one of them"),
                    scope=rule.scope, rule_index=i))
            else:
                findings.append(Finding(
                    code="dead-rule", level=WARNING,
                    message=(f"rule scope={rule.scope!r} matches zero "
                             "enumerable quantize sites in this model"),
                    scope=rule.scope, rule_index=i))

    if (sites is not None and analysis_result is not None
            and index is not None):
        findings.extend(_lint_dot_accumulators(policy, sites,
                                               analysis_result, index))
    return findings


def _lint_dot_accumulators(policy: TruncationPolicy, sites: Sequence[Any],
                           analysis_result: Any, index: Any
                           ) -> List[Finding]:
    findings: List[Finding] = []
    keys = index.site_keys()
    for s in sites:
        if s.prim not in _DOT_PRIMS:
            continue
        w = _winning_rule(policy, s)
        if w is None or not policy.rules[w].quantize_dot_inputs:
            continue
        d = analysis_result.dot_inputs.get(keys[s.index])
        carrier = carrier_format(s.dtype)
        if d is None or carrier is None:
            continue
        fmt = parse_format(policy.rules[w].fmt)
        qa = min(d.lhs.hi, fmt.max_finite)
        qb = min(d.rhs.hi, fmt.max_finite)
        acc = d.n * qa * qb
        if acc > carrier.max_finite or not math.isfinite(acc):
            findings.append(Finding(
                code="dot-accumulator-risk", level=WARNING,
                message=(f"quantize_dot_inputs on {s.prim} at "
                         f"{s.scope!r}: worst-case accumulator "
                         f"{d.n} * {qa:.3g} * {qb:.3g} exceeds the "
                         f"{carrier.key} carrier's finite range — input "
                         "quantization cannot keep the accumulation "
                         "finite"),
                scope=s.scope, rule_index=w))
    return findings


# --------------------------------------------------------------------------
# artifact lint
# --------------------------------------------------------------------------

def lint_artifact(artifact: Any, *,
                  scopes: Optional[Sequence[str]] = None,
                  sites: Optional[Sequence[Any]] = None,
                  analysis_result: Any = None,
                  index: Any = None) -> List[Finding]:
    """Lint a ``PolicyArtifact`` (duck-typed: ``.policy``,
    ``.assignments``, ``.name``). ``scopes`` is the current model's
    enumerable scope frontier (``discover_scopes`` paths) for the
    drift checks."""
    findings = lint_policy(artifact.policy, sites=sites,
                           analysis_result=analysis_result, index=index,
                           serializable_required=True)
    if scopes is not None:
        current = set(scopes)
        assigned = set(artifact.assignments)
        for path in sorted(assigned - current):
            findings.append(Finding(
                code="scope-drift-missing", level=ERROR,
                message=(f"artifact assigns scope {path!r} which is not on "
                         "the current model's scope frontier — the "
                         "deployed policy no longer matches the model it "
                         "was searched on"),
                scope=path))
        for path in sorted(current - assigned):
            findings.append(Finding(
                code="scope-drift-new", level=WARNING,
                message=(f"model scope {path!r} is not judged by the "
                         "artifact (stays full precision); re-search to "
                         "cover it"),
                scope=path))
    return findings


# --------------------------------------------------------------------------
# CLI: python -m repro.analysis.lint <paths...>
# --------------------------------------------------------------------------

def _all_sites(closed: Any) -> Any:
    """Enumerate every float quantize site of a traced computation."""
    from repro.core import interpreter
    everywhere = TruncationPolicy(rules=(
        TruncationRule(fmt=FPFormat(8, 0), scope="**"),))
    return interpreter.enumerate_sites(closed, everywhere)


def _bench_model_context() -> Tuple[List[str], Any]:
    """(scope frontier, SiteIndex) of the committed bench model — the
    model ``artifacts/bench_model.json`` is deployed against."""
    import jax
    from benchmarks.common import bench_model, bench_batch
    from repro.search.scopes import discover_scopes

    cfg, model, params = bench_model()
    batch = bench_batch(cfg)
    closed = jax.make_jaxpr(model.loss)(params, batch)
    paths = [s.path for s in discover_scopes(closed)]
    return paths, _all_sites(closed)


def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse
    import os
    import sys

    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="Statically lint policy artifact JSON files.")
    ap.add_argument("paths", nargs="+",
                    help="artifact JSON files or directories of them")
    ap.add_argument("--no-model", action="store_true",
                    help="skip the model-aware checks (scope drift, dead "
                         "rules) even for artifacts with a known model")
    ap.add_argument("--strict", action="store_true",
                    help="exit non-zero on warnings too")
    args = ap.parse_args(argv)

    from repro.artifacts import load_artifact_file

    files: List[str] = []
    for p in args.paths:
        if os.path.isdir(p):
            files.extend(sorted(
                os.path.join(root, f)
                for root, _, names in os.walk(p)
                for f in names if f.endswith(".json")))
        else:
            files.append(p)
    if not files:
        print("no artifact files found", file=sys.stderr)
        return 1

    bench_ctx: Any = None  # lazily traced, shared across files
    n_err = n_warn = 0
    for path in files:
        try:
            art = load_artifact_file(path)
        except Exception as e:
            print(f"{path}: ERROR unreadable artifact: {e}")
            n_err += 1
            continue
        kw: Dict[str, Any] = {}
        note = ""
        if not args.no_model and art.name == "bench_model":
            if bench_ctx is None:
                try:
                    bench_ctx = _bench_model_context()
                except Exception as e:
                    bench_ctx = e
            if isinstance(bench_ctx, tuple):
                paths_ctx, idx = bench_ctx
                kw = {"scopes": paths_ctx, "sites": idx.sites}
            else:
                note = (" (structural checks only: bench model "
                        f"unavailable: {bench_ctx})")
        findings = lint_artifact(art, **kw)
        errs = [f for f in findings if f.level == ERROR]
        warns = [f for f in findings if f.level == WARNING]
        n_err += len(errs)
        n_warn += len(warns)
        status = "clean" if not findings else \
            f"{len(errs)} error(s), {len(warns)} warning(s)"
        print(f"{path}: {status}{note}")
        for f in findings:
            print(f"  {f.render()}")
    print(f"lint: {len(files)} artifact(s), {n_err} error(s), "
          f"{n_warn} warning(s)")
    return 1 if n_err or (args.strict and n_warn) else 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
