"""Abstract value domain for static numerical analysis.

Every traced value is abstracted as an :class:`AbsVal` — a magnitude
interval plus *exactness* facts:

  * ``hi``       — upper bound on ``|x|`` over all elements. ``inf`` means
                   "unknown / possibly non-finite"; a finite ``hi`` is a
                   proof that every element is finite (no NaN, no inf).
  * ``lo``       — lower bound on ``max |x|`` (0 = no information). Only
                   consumed by the overflow verdict, which additionally
                   requires a finite ``hi``, so ``lo`` never needs to be
                   meaningful for possibly-non-finite values.
  * ``min_nz``   — lower bound on ``|x|`` of non-zero finite elements
                   (0 = no information).
  * ``ulp_exp``  — every finite element is an integer multiple of
                   ``2**ulp_exp`` (``-inf`` = unknown; float so the lattice
                   ops are plain min/max with sentinels).
  * ``rel_bits`` — every finite element is ``+/- a * 2**k`` with
                   ``1 <= a < 2`` and ``a`` having at most ``rel_bits``
                   fractional bits (``inf`` = unknown).
  * ``nonneg``   — all finite elements are >= 0.

The two grid facts are what make the EXACT verdict possible: a value whose
``rel_bits``/``ulp_exp`` fit a target format's mantissa/subnormal grid (and
whose ``hi`` fits its range) quantizes to itself bit-for-bit.

Soundness of :func:`seal` (meet with the carrier format after every
transfer): every finite value the carrier can store is an integer multiple
of the carrier's min subnormal, so ``ulp_exp`` is floored there; round-to-
nearest-even *preserves* multiple-of-``2**u`` facts (rounding onto a grid
at least as coarse keeps the value a multiple of ``2**u``; a finer grid
means the value was already exact) and never increases ``rel_bits`` (an
off-grid value with ``f`` fractional bits rounds to a neighbour with fewer;
a carry to the next binade gives ``rel = 0``). Magnitude bounds get a
``(1 +/- 2**-20)`` inflate/deflate margin, far above the carrier's relative
rounding error, so python-float slop in the transfer arithmetic can never
flip a bound.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.formats import FPFormat

_MARGIN = 1.0 + 2.0 ** -20

__all__ = [
    "AbsVal", "carrier_format", "of_aval", "top_for_dtype", "from_concrete",
    "join", "join_all", "leq", "seal", "transfer",
]


def _up(x: float) -> float:
    """Inflate an upper bound by the safety margin."""
    if not math.isfinite(x):
        return math.inf
    return x * _MARGIN if x > 0 else 0.0


def _dn(x: float) -> float:
    """Deflate a lower bound by the safety margin."""
    if not math.isfinite(x) or x <= 0:
        return 0.0
    return x / _MARGIN


@dataclasses.dataclass(frozen=True)
class AbsVal:
    hi: float = math.inf
    lo: float = 0.0
    min_nz: float = 0.0
    ulp_exp: float = -math.inf
    rel_bits: float = math.inf
    nonneg: bool = False

    def drop_lo(self) -> "AbsVal":
        """Forget the max-magnitude lower bound (element selection)."""
        if self.lo == 0.0:
            return self
        return dataclasses.replace(self, lo=0.0)

    @property
    def finite(self) -> bool:
        return math.isfinite(self.hi)


_TOP = AbsVal()

_BOOL = AbsVal(hi=1.0, lo=0.0, min_nz=1.0, ulp_exp=0.0, rel_bits=0.0,
               nonneg=True)


def _rel_from(hi: float, ulp_exp: float) -> float:
    """Fractional-mantissa-bit bound implied by ``|x| <= hi`` on the
    ``2**ulp_exp`` grid: the exponent of ``x`` is at most ``floor(log2 hi)``
    and its mantissa grid is ``2**ulp_exp``."""
    if not math.isfinite(hi) or not math.isfinite(ulp_exp):
        return math.inf
    if hi <= 0:
        return 0.0
    _, e = math.frexp(hi)  # hi = m * 2**e, m in [0.5, 1)
    return float(max(0, (e - 1) - int(ulp_exp)))


def carrier_format(dtype: Any) -> Optional[FPFormat]:
    """The FP format of a float dtype (None for non-floats)."""
    name = np.dtype(dtype).name if not isinstance(dtype, str) else dtype
    return _CARRIERS.get(name)


_CARRIERS: Dict[str, FPFormat] = {
    "float64": FPFormat(11, 52),
    "float32": FPFormat(8, 23),
    "float16": FPFormat(5, 10),
    "bfloat16": FPFormat(8, 7),
    "float8_e4m3fn": FPFormat(4, 3, ieee_inf=False),
    "float8_e5m2": FPFormat(5, 2),
}


def top_for_dtype(dtype: Any) -> AbsVal:
    """The no-information element for a dtype: everything the carrier can
    hold (including non-finites for float carriers)."""
    try:
        dt = np.dtype(dtype)
    except TypeError:
        return _TOP
    fmt = _CARRIERS.get(dt.name)
    if fmt is not None:
        return AbsVal(hi=math.inf, lo=0.0,
                      min_nz=fmt.min_subnormal,
                      ulp_exp=float(fmt.min_exp - fmt.man_bits),
                      rel_bits=float(fmt.man_bits), nonneg=False)
    if dt.kind in ("i", "u"):
        info = np.iinfo(dt)
        hi = float(max(abs(int(info.min)), int(info.max)))
        return AbsVal(hi=hi, lo=0.0, min_nz=1.0, ulp_exp=0.0,
                      rel_bits=float(dt.itemsize * 8),
                      nonneg=(dt.kind == "u"))
    if dt.kind == "b":
        return _BOOL
    return _TOP


def of_aval(aval: Any) -> AbsVal:
    dtype = getattr(aval, "dtype", None)
    return _TOP if dtype is None else top_for_dtype(dtype)


def from_concrete(x: Any) -> AbsVal:
    """Abstract a concrete array exactly (ulp/rel via bit analysis)."""
    try:
        a = np.asarray(x)
    except Exception:
        return _TOP
    if a.dtype.kind == "b":
        return _BOOL
    if a.dtype.kind in ("i", "u"):
        if a.size == 0:
            return AbsVal(hi=0.0, lo=0.0, min_nz=0.0, ulp_exp=0.0,
                          rel_bits=0.0, nonneg=True)
        a64 = a.astype(np.float64)
        mx = float(np.max(np.abs(a64)))
        nz = np.abs(a64[a64 != 0])
        return AbsVal(hi=mx, lo=mx,
                      min_nz=float(np.min(nz)) if nz.size else 0.0,
                      ulp_exp=0.0, rel_bits=_rel_from(mx, 0.0),
                      nonneg=bool(np.all(a64 >= 0)))
    if a.dtype.kind != "f":
        return _TOP
    a = a.astype(np.float64)
    if a.size == 0:
        return AbsVal(hi=0.0, lo=0.0, min_nz=0.0, ulp_exp=0.0, rel_bits=0.0,
                      nonneg=True)
    if not bool(np.all(np.isfinite(a))):
        return dataclasses.replace(top_for_dtype(x.dtype)
                                   if hasattr(x, "dtype") else _TOP,
                                   hi=math.inf)
    mags = np.abs(a)
    mx = float(np.max(mags))
    nzmask = mags > 0
    min_nz = float(np.min(mags[nzmask])) if bool(np.any(nzmask)) else 0.0
    nonneg = bool(np.all(a >= 0))
    nz = a[nzmask]
    if nz.size == 0:
        # all-zero array: exactly on every grid
        return AbsVal(hi=0.0, lo=0.0, min_nz=0.0, ulp_exp=0.0, rel_bits=0.0,
                      nonneg=nonneg)
    m, e = np.frexp(nz)  # nz = m * 2**e, |m| in [0.5, 1)
    scaled = np.round(np.abs(m) * 2.0 ** 53).astype(np.int64)  # in [2^52, 2^53)
    tz = np.zeros(scaled.shape, dtype=np.int64)
    v = scaled.copy()
    # trailing zero count, vectorized: strip factors of two in 6 passes
    for shift in (32, 16, 8, 4, 2, 1):
        mask = (v & ((np.int64(1) << shift) - 1)) == 0
        v = np.where(mask, v >> shift, v)
        tz = tz + np.where(mask, shift, 0)
    ulp = np.min(e.astype(np.int64) - 53 + tz)
    rel = np.max(52 - tz)
    return AbsVal(hi=mx, lo=mx, min_nz=min_nz, ulp_exp=float(ulp),
                  rel_bits=float(rel), nonneg=nonneg)


# --------------------------------------------------------------------------
# lattice ops
# --------------------------------------------------------------------------

def join(a: AbsVal, b: AbsVal) -> AbsVal:
    """Union over-approximation: facts that hold for both."""
    return AbsVal(hi=max(a.hi, b.hi), lo=min(a.lo, b.lo),
                  min_nz=min(a.min_nz, b.min_nz),
                  ulp_exp=min(a.ulp_exp, b.ulp_exp),
                  rel_bits=max(a.rel_bits, b.rel_bits),
                  nonneg=a.nonneg and b.nonneg)


def join_all(vals: Sequence[AbsVal]) -> AbsVal:
    out = vals[0]
    for v in vals[1:]:
        out = join(out, v)
    return out


def leq(a: AbsVal, b: AbsVal) -> bool:
    """True when ``a`` is at least as precise as ``b`` (a refines b)."""
    return (a.hi <= b.hi and a.lo >= b.lo and a.min_nz >= b.min_nz
            and a.ulp_exp >= b.ulp_exp and a.rel_bits <= b.rel_bits
            and (a.nonneg or not b.nonneg))


def seal(v: AbsVal, dtype: Any) -> AbsVal:
    """Meet a transfer result with its carrier dtype (see module doc)."""
    fmt = carrier_format(dtype)
    if fmt is None:
        return v
    hi = v.hi if v.hi <= fmt.max_finite else math.inf
    return AbsVal(
        hi=hi,
        lo=_dn(v.lo),
        min_nz=max(v.min_nz, fmt.min_subnormal),
        ulp_exp=max(v.ulp_exp, float(fmt.min_exp - fmt.man_bits)),
        rel_bits=min(v.rel_bits, float(fmt.man_bits)),
        nonneg=v.nonneg)


# --------------------------------------------------------------------------
# transfer functions
# --------------------------------------------------------------------------

def _shape(aval: Any) -> Tuple[int, ...]:
    return tuple(getattr(aval, "shape", ()) or ())


def _is_scalar(aval: Any) -> bool:
    return _shape(aval) == ()


def _mul_hi(a: float, b: float) -> float:
    if a == 0.0 or b == 0.0:
        return 0.0
    return _up(a * b)


def _passthrough(ins: List[AbsVal], *_a: Any) -> List[AbsVal]:
    return [ins[0]]


def _select(ins: List[AbsVal], *_a: Any) -> List[AbsVal]:
    return [ins[0].drop_lo()]


def _join_drop_lo(vals: Sequence[AbsVal]) -> AbsVal:
    return join_all(list(vals)).drop_lo()


def _t_concatenate(ins, eqn, in_avals, out_avals):
    out = join_all(ins)
    return [dataclasses.replace(out, lo=max(v.lo for v in ins))]


def _t_pad(ins, eqn, in_avals, out_avals):
    return [_join_drop_lo(ins[:2])]


def _t_select_n(ins, eqn, in_avals, out_avals):
    return [_join_drop_lo(ins[1:])]


def _t_clamp(ins, eqn, in_avals, out_avals):
    return [_join_drop_lo(ins)]


def _t_dus(ins, eqn, in_avals, out_avals):
    return [_join_drop_lo(ins[:2])]


def _t_max(ins, eqn, in_avals, out_avals):
    a, b = ins[0], ins[1]
    out = join(a, b)
    lo = 0.0
    if a.nonneg and b.nonneg:
        lo = max(a.lo, b.lo)
    elif a.nonneg:
        lo = a.lo
    elif b.nonneg:
        lo = b.lo
    return [dataclasses.replace(out, lo=lo, nonneg=a.nonneg or b.nonneg)]


def _t_min(ins, eqn, in_avals, out_avals):
    return [_join_drop_lo(ins[:2])]


def _t_abs(ins, eqn, in_avals, out_avals):
    return [dataclasses.replace(ins[0], nonneg=True)]


def _t_neg(ins, eqn, in_avals, out_avals):
    return [dataclasses.replace(ins[0], nonneg=ins[0].hi == 0.0)]


def _t_sign(ins, eqn, in_avals, out_avals):
    return [AbsVal(hi=1.0, lo=0.0, min_nz=1.0, ulp_exp=0.0, rel_bits=0.0,
                   nonneg=ins[0].nonneg)]


def _t_round(ins, eqn, in_avals, out_avals):
    a = ins[0]
    hi = _up(a.hi + 1.0)
    ulp = max(a.ulp_exp, 0.0)
    return [AbsVal(hi=hi, lo=0.0, min_nz=1.0 if a.finite else 0.0,
                   ulp_exp=ulp, rel_bits=_rel_from(hi, ulp),
                   nonneg=a.nonneg)]


def _t_iota(ins, eqn, in_avals, out_avals):
    shape = eqn.params.get("shape", _shape(out_avals[0]))
    dim = eqn.params.get("dimension", 0)
    n = int(shape[dim]) if shape else 1
    hi = float(max(n - 1, 0))
    return [AbsVal(hi=hi, lo=hi, min_nz=1.0 if n > 1 else 0.0, ulp_exp=0.0,
                   rel_bits=_rel_from(hi, 0.0), nonneg=True)]


def _t_add(ins, eqn, in_avals, out_avals):
    a, b = ins[0], ins[1]
    hi = _up(a.hi + b.hi)
    ulp = min(a.ulp_exp, b.ulp_exp)
    rel = min(_rel_from(hi, ulp), a.rel_bits + b.rel_bits + 54)
    nonneg = a.nonneg and b.nonneg
    lo = _dn(max(a.lo, b.lo)) if nonneg else 0.0
    return [AbsVal(hi=hi, lo=lo, min_nz=0.0, ulp_exp=ulp, rel_bits=rel,
                   nonneg=nonneg)]


def _t_sub(ins, eqn, in_avals, out_avals):
    a, b = ins[0], ins[1]
    hi = _up(a.hi + b.hi)
    ulp = min(a.ulp_exp, b.ulp_exp)
    return [AbsVal(hi=hi, lo=0.0, min_nz=0.0, ulp_exp=ulp,
                   rel_bits=_rel_from(hi, ulp),
                   nonneg=a.nonneg and b.hi == 0.0)]


def _t_mul(ins, eqn, in_avals, out_avals):
    a, b = ins[0], ins[1]
    hi = _mul_hi(a.hi, b.hi)
    ulp = a.ulp_exp + b.ulp_exp
    rel = a.rel_bits + b.rel_bits
    min_nz = _dn(a.min_nz * b.min_nz)
    lo = 0.0
    # a scalar factor of known magnitude scales the max element directly
    if _is_scalar(in_avals[1]) and b.nonneg and b.lo > 0:
        lo = _dn(a.lo * b.lo)
    elif _is_scalar(in_avals[0]) and a.nonneg and a.lo > 0:
        lo = _dn(b.lo * a.lo)
    return [AbsVal(hi=hi, lo=lo, min_nz=min_nz, ulp_exp=ulp, rel_bits=rel,
                   nonneg=a.nonneg and b.nonneg)]


def _t_div(ins, eqn, in_avals, out_avals):
    a, b = ins[0], ins[1]
    hi = math.inf
    lo = 0.0
    if _is_scalar(in_avals[1]) and b.nonneg and b.lo > 0:
        # scalar divisor bounded away from zero: |a/b| <= hi_a / b
        hi = _up(a.hi / b.lo) if math.isfinite(a.hi) else math.inf
        if math.isfinite(b.hi) and b.hi > 0:
            lo = _dn(a.lo / b.hi)
    min_nz = _dn(a.min_nz / b.hi) if (math.isfinite(b.hi) and b.hi > 0
                                      and a.min_nz > 0) else 0.0
    return [AbsVal(hi=hi, lo=lo, min_nz=min_nz, ulp_exp=-math.inf,
                   rel_bits=math.inf, nonneg=a.nonneg and b.nonneg)]


def _contraction_size(eqn, in_avals) -> int:
    dn = eqn.params.get("dimension_numbers")
    lhs_shape = _shape(in_avals[0])
    try:
        (lhs_c, _), _ = dn
        n = 1
        for d in lhs_c:
            n *= int(lhs_shape[d])
        return max(n, 1)
    except Exception:
        n = 1
        for d in _shape(in_avals[1]):
            n *= int(d)
        return max(n, 1)


def _t_dot(ins, eqn, in_avals, out_avals):
    a, b = ins[0], ins[1]
    n = _contraction_size(eqn, in_avals)
    hi = _mul_hi(float(n), _mul_hi(a.hi, b.hi))
    ulp = a.ulp_exp + b.ulp_exp
    return [AbsVal(hi=hi, lo=0.0, min_nz=0.0, ulp_exp=ulp,
                   rel_bits=_rel_from(hi, ulp),
                   nonneg=a.nonneg and b.nonneg)]


def _t_conv(ins, eqn, in_avals, out_avals):
    a, b = ins[0], ins[1]
    n = 1
    for d in _shape(in_avals[1]):
        n *= int(d)
    hi = _mul_hi(float(max(n, 1)), _mul_hi(a.hi, b.hi))
    ulp = a.ulp_exp + b.ulp_exp
    return [AbsVal(hi=hi, lo=0.0, min_nz=0.0, ulp_exp=ulp,
                   rel_bits=_rel_from(hi, ulp),
                   nonneg=a.nonneg and b.nonneg)]


def _reduced_size(eqn, in_avals) -> int:
    axes = eqn.params.get("axes", ())
    shape = _shape(in_avals[0])
    n = 1
    for d in axes:
        if d < len(shape):
            n *= int(shape[d])
    return max(n, 1)


def _t_reduce_sum(ins, eqn, in_avals, out_avals):
    a = ins[0]
    n = _reduced_size(eqn, in_avals)
    hi = _mul_hi(float(n), a.hi)
    lo = _dn(a.lo) if a.nonneg else 0.0
    min_nz = _dn(a.min_nz) if a.nonneg else 0.0
    return [AbsVal(hi=hi, lo=lo, min_nz=min_nz, ulp_exp=a.ulp_exp,
                   rel_bits=_rel_from(hi, a.ulp_exp), nonneg=a.nonneg)]


def _t_cumsum(ins, eqn, in_avals, out_avals):
    a = ins[0]
    axis = eqn.params.get("axis", 0)
    shape = _shape(in_avals[0])
    n = int(shape[axis]) if axis < len(shape) else 1
    hi = _mul_hi(float(max(n, 1)), a.hi)
    lo = _dn(a.lo) if a.nonneg else 0.0
    return [AbsVal(hi=hi, lo=lo, min_nz=0.0, ulp_exp=a.ulp_exp,
                   rel_bits=_rel_from(hi, a.ulp_exp), nonneg=a.nonneg)]


def _t_reduce_max(ins, eqn, in_avals, out_avals):
    a = ins[0]
    return [dataclasses.replace(a, lo=a.lo if a.nonneg else 0.0)]


def _t_reduce_min(ins, eqn, in_avals, out_avals):
    return [ins[0].drop_lo()]


def _t_reduce_prod(ins, eqn, in_avals, out_avals):
    a = ins[0]
    n = _reduced_size(eqn, in_avals)
    try:
        hi = _up(max(a.hi ** n, 1.0))
    except OverflowError:
        hi = math.inf
    ulp = a.ulp_exp * n if math.isfinite(a.ulp_exp) else -math.inf
    rel = a.rel_bits * n if math.isfinite(a.rel_bits) else math.inf
    return [AbsVal(hi=hi, lo=0.0, min_nz=0.0, ulp_exp=min(ulp, 0.0) if n
                   else 0.0, rel_bits=rel, nonneg=a.nonneg)]


def _safe_exp(x: float) -> float:
    if x > 700.0:
        return math.inf
    return math.exp(x)


def _t_exp(ins, eqn, in_avals, out_avals):
    a = ins[0]
    if not a.finite:
        return [AbsVal(hi=math.inf, nonneg=True)]
    hi = _up(_safe_exp(a.hi))
    floor = _dn(_safe_exp(-a.hi) if a.hi < 700.0 else 0.0)
    # every element satisfies x >= -hi, so exp(x) >= exp(-hi) > 0
    return [AbsVal(hi=hi, lo=floor, min_nz=floor, ulp_exp=-math.inf,
                   rel_bits=math.inf, nonneg=True)]


def _t_exp2(ins, eqn, in_avals, out_avals):
    a = ins[0]
    if not a.finite:
        return [AbsVal(hi=math.inf, nonneg=True)]
    hi = _up(_safe_exp(a.hi * math.log(2.0)))
    floor = _dn(1.0 / hi) if math.isfinite(hi) and hi > 0 else 0.0
    return [AbsVal(hi=hi, lo=floor, min_nz=floor, ulp_exp=-math.inf,
                   rel_bits=math.inf, nonneg=True)]


def _t_sqrt(ins, eqn, in_avals, out_avals):
    a = ins[0]
    if not (a.finite and a.nonneg):
        return [AbsVal(hi=math.inf, nonneg=True)]
    return [AbsVal(hi=_up(math.sqrt(a.hi)), lo=_dn(math.sqrt(a.lo)),
                   min_nz=_dn(math.sqrt(a.min_nz)), ulp_exp=-math.inf,
                   rel_bits=math.inf, nonneg=True)]


def _t_rsqrt(ins, eqn, in_avals, out_avals):
    a = ins[0]
    min_nz = _dn(1.0 / math.sqrt(a.hi)) if (a.finite and a.hi > 0) else 0.0
    return [AbsVal(hi=math.inf, lo=0.0, min_nz=min_nz, ulp_exp=-math.inf,
                   rel_bits=math.inf, nonneg=True)]


def _bounded(cap: float, keep_nonneg: bool = True
             ) -> Callable[..., List[AbsVal]]:
    def t(ins, eqn, in_avals, out_avals):
        a = ins[0]
        return [AbsVal(hi=min(_up(a.hi), cap), lo=0.0, min_nz=0.0,
                       ulp_exp=-math.inf, rel_bits=math.inf,
                       nonneg=a.nonneg and keep_nonneg)]
    return t


def _t_logistic(ins, eqn, in_avals, out_avals):
    return [AbsVal(hi=1.0, lo=0.0, min_nz=0.0, ulp_exp=-math.inf,
                   rel_bits=math.inf, nonneg=True)]


def _t_cos(ins, eqn, in_avals, out_avals):
    return [AbsVal(hi=1.0)]


def _t_integer_pow(ins, eqn, in_avals, out_avals):
    a = ins[0]
    y = int(eqn.params.get("y", 2))
    if y <= 0:
        return [AbsVal(hi=math.inf, nonneg=(y == 0))]
    try:
        hi = _up(a.hi ** y) if a.finite else math.inf
    except OverflowError:
        hi = math.inf
    ulp = a.ulp_exp * y if math.isfinite(a.ulp_exp) else -math.inf
    rel = a.rel_bits * y if math.isfinite(a.rel_bits) else math.inf
    try:
        min_nz = _dn(a.min_nz ** y)
        lo = _dn(a.lo ** y)
    except OverflowError:
        min_nz, lo = 0.0, 0.0
    return [AbsVal(hi=hi, lo=lo, min_nz=min_nz, ulp_exp=ulp, rel_bits=rel,
                   nonneg=a.nonneg or y % 2 == 0)]


def _t_convert(ins, eqn, in_avals, out_avals):
    a = ins[0]
    out_dt = np.dtype(out_avals[0].dtype)
    if out_dt.kind == "f":
        # rounding onto the new carrier can raise |x| by <= half an ulp,
        # comfortably inside the _up margin; grid facts are resealed below
        return [dataclasses.replace(a, hi=_up(a.hi))]
    if out_dt.kind in ("i", "u"):
        info = np.iinfo(out_dt)
        cap = float(max(abs(int(info.min)), int(info.max)))
        hi = min(a.hi, cap)
        return [AbsVal(hi=hi, lo=0.0, min_nz=0.0, ulp_exp=0.0,
                       rel_bits=_rel_from(hi, 0.0), nonneg=a.nonneg)]
    if out_dt.kind == "b":
        return [_BOOL]
    return [_TOP]


def _t_scatter_add(ins, eqn, in_avals, out_avals):
    op, upd = ins[0], ins[2]
    n = 1
    for d in _shape(in_avals[2]):
        n *= int(d)
    hi = _up(op.hi + max(n, 1) * upd.hi)
    ulp = min(op.ulp_exp, upd.ulp_exp)
    return [AbsVal(hi=hi, lo=0.0, min_nz=0.0, ulp_exp=ulp,
                   rel_bits=_rel_from(hi, ulp),
                   nonneg=op.nonneg and upd.nonneg)]


def _t_scatter(ins, eqn, in_avals, out_avals):
    return [_join_drop_lo([ins[0], ins[2]])]


def _t_bool(ins, eqn, in_avals, out_avals):
    return [_BOOL for _ in out_avals]


def _t_log1p(ins, eqn, in_avals, out_avals):
    a = ins[0]
    if a.finite and a.nonneg:
        return [AbsVal(hi=_up(math.log1p(a.hi)), nonneg=True)]
    return [AbsVal(hi=math.inf)]


def _t_expm1(ins, eqn, in_avals, out_avals):
    a = ins[0]
    if not a.finite:
        return [AbsVal(hi=math.inf, nonneg=a.nonneg)]
    hi = _up(max(_safe_exp(a.hi), 1.0))
    return [AbsVal(hi=hi, nonneg=a.nonneg)]


_TRANSFERS: Dict[str, Callable[..., List[AbsVal]]] = {
    # structure-preserving (all facts, including lo)
    "reshape": _passthrough, "transpose": _passthrough, "rev": _passthrough,
    "copy": _passthrough, "squeeze": _passthrough,
    "expand_dims": _passthrough, "broadcast_in_dim": _passthrough,
    "broadcast": _passthrough, "stop_gradient": _passthrough,
    "optimization_barrier": _passthrough, "sharding_constraint": _passthrough,
    "layout_constraint": _passthrough, "real": _passthrough,
    "device_put": _passthrough, "sort": _passthrough, "copy_p": _passthrough,
    "reduce_precision": _passthrough,
    # element selection (drop lo)
    "slice": _select, "gather": _select, "dynamic_slice": _select,
    "split": lambda ins, eqn, ia, oa: [ins[0].drop_lo() for _ in oa],
    "select_n": _t_select_n, "clamp": _t_clamp,
    "dynamic_update_slice": _t_dus, "scatter": _t_scatter,
    "concatenate": _t_concatenate, "pad": _t_pad,
    "max": _t_max, "min": _t_min,
    "reduce_max": _t_reduce_max, "reduce_min": _t_reduce_min,
    "cummax": _t_reduce_max, "cummin": _t_reduce_min,
    # sign-structure
    "abs": _t_abs, "neg": _t_neg, "sign": _t_sign,
    "floor": _t_round, "ceil": _t_round, "round": _t_round,
    "iota": _t_iota,
    # arithmetic
    "add": _t_add, "sub": _t_sub, "mul": _t_mul, "div": _t_div,
    "dot_general": _t_dot, "conv_general_dilated": _t_conv,
    "ragged_dot": _t_conv,
    "reduce_sum": _t_reduce_sum, "cumsum": _t_cumsum,
    "reduce_prod": _t_reduce_prod,
    "integer_pow": _t_integer_pow,
    "scatter-add": _t_scatter_add,
    # transcendental
    "exp": _t_exp, "exp2": _t_exp2, "log1p": _t_log1p, "expm1": _t_expm1,
    "sqrt": _t_sqrt, "rsqrt": _t_rsqrt,
    "tanh": _bounded(1.0), "erf": _bounded(1.0),
    "sin": _bounded(1.0, keep_nonneg=False), "cos": _t_cos,
    "logistic": _t_logistic,
    "atan": _bounded(1.5708, keep_nonneg=False),
    "atan2": lambda ins, eqn, ia, oa: [AbsVal(hi=3.1416)],
    "convert_element_type": _t_convert,
    # predicates
    "eq": _t_bool, "ne": _t_bool, "lt": _t_bool, "le": _t_bool,
    "gt": _t_bool, "ge": _t_bool, "and": _t_bool, "or": _t_bool,
    "not": _t_bool, "xor": _t_bool, "is_finite": _t_bool,
    "reduce_and": _t_bool, "reduce_or": _t_bool,
}


def transfer(eqn: Any, invals: List[AbsVal]) -> List[AbsVal]:
    """Abstractly evaluate one equation; results are sealed with each
    output's carrier dtype. Unknown primitives fall back to the carrier
    top — the conservative default that keeps everything sound."""
    out_avals = [v.aval for v in eqn.outvars]
    in_avals = [v.aval for v in eqn.invars]
    fn = _TRANSFERS.get(eqn.primitive.name)
    if fn is None:
        outs: List[AbsVal] = [of_aval(a) for a in out_avals]
    else:
        try:
            outs = fn(invals, eqn, in_avals, out_avals)
        except Exception:
            outs = [of_aval(a) for a in out_avals]
        if len(outs) != len(out_avals):
            outs = [of_aval(a) for a in out_avals]
    return [seal(o, a.dtype) if hasattr(a, "dtype") else o
            for o, a in zip(outs, out_avals)]
