"""Static numerical analysis: abstract interpretation of closed jaxprs
(range/exactness facts, overflow criticality), per-rung search verdicts,
and the policy/artifact linter."""
from repro.analysis.domain import (
    AbsVal, carrier_format, from_concrete, join, leq, of_aval, seal,
    top_for_dtype, transfer,
)
from repro.analysis.interp import AnalysisResult, DotInputs, analyze_closed
from repro.analysis.verdicts import (
    StaticVerdicts, Verdict, exact_in, overflow_certain,
    rne_overflow_boundary, scope_rung_verdicts, universally_exact,
)
from repro.analysis.lint import (
    ArtifactLintError, Finding, lint_artifact, lint_policy,
)

__all__ = [
    "AbsVal", "AnalysisResult", "ArtifactLintError", "DotInputs",
    "Finding", "StaticVerdicts", "Verdict", "analyze_closed",
    "carrier_format", "exact_in", "from_concrete", "join", "leq",
    "lint_artifact", "lint_policy", "of_aval", "overflow_certain",
    "rne_overflow_boundary", "scope_rung_verdicts", "seal",
    "top_for_dtype", "transfer", "universally_exact",
]
