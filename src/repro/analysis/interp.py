"""Abstract interpretation of closed jaxprs over the :mod:`domain` lattice.

``analyze_closed`` mirrors the structural walk of
``repro.core.interpreter`` — same higher-order-primitive handling, same
``(id(jaxpr), eqn_idx, out_idx, name_stack)`` record keys as ``SiteIndex``
— but evaluates every equation abstractly with :func:`domain.transfer`.
``scan``/``while`` carries run to a join fixpoint (``acc' = acc ⊔
body(acc)``) with widening to the carrier top after ``warm_iters``
non-converging rounds; carrier tops are post-fixpoints by construction
(every transfer seals its result at or below the carrier top), so one
more body pass after widening yields sound ``ys`` and per-site records.
``cond`` joins the branch outputs elementwise.

Records are accumulated with joins across every visit of a site (scan
fixpoint rounds, shared sub-jaxprs reached under several prefixes), which
only widens them; the final post-fixpoint pass guarantees each record
over-approximates every concrete execution of its site.

A second, backward pass computes *criticality*: a site is critical when a
non-finite value at its output provably propagates to some top-level
output (through primitives that preserve non-finiteness). Criticality is
what licenses the ``OVERFLOW_CERTAIN`` verdict to prune a rung — the
overflow must be observable in the search metric, not absorbed by a
``select``/``min``/``exp`` downstream. ``while`` bodies and ``cond``
branches never yield critical sites (an unexecuted branch makes the
quantize a no-op); scan bodies use a least-fixpoint over carry
criticality, sound because an overflow-certain site fires at *every*
step (its ``lo`` bound holds per-step), in particular the last.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
from jax._src import core as jcore

from repro.core.policy import join_stack
from repro.analysis.domain import (
    AbsVal, from_concrete, join, leq, of_aval, transfer,
)

RecordKey = Tuple[int, int, int, str]

_HOP_NAMES = frozenset({
    "jit", "pjit", "closed_call", "core_call", "scan", "while", "cond",
    "remat2", "checkpoint", "custom_jvp_call", "custom_vjp_call",
    "custom_vjp_call_jaxpr",
})

_DOT_PRIMS = frozenset({"dot_general", "conv_general_dilated", "ragged_dot"})


@dataclasses.dataclass
class DotInputs:
    """Abstract operands of a dot-like site (for accumulator-risk lint)."""
    lhs: AbsVal
    rhs: AbsVal
    n: int  # contraction size


@dataclasses.dataclass
class AnalysisResult:
    records: Dict[RecordKey, AbsVal]
    critical: Dict[RecordKey, bool]
    dot_inputs: Dict[RecordKey, DotInputs]
    out_vals: List[AbsVal]
    n_widened: int
    pinned: List[Any]

    @property
    def outputs_finite(self) -> bool:
        return all(v.finite for v in self.out_vals)

    def value_at(self, key: RecordKey) -> Optional[AbsVal]:
        return self.records.get(key)

    def critical_at(self, key: RecordKey) -> bool:
        return self.critical.get(key, False)


def _closed(eqn_param: Any) -> jcore.ClosedJaxpr:
    if isinstance(eqn_param, jcore.ClosedJaxpr):
        return eqn_param
    return jcore.ClosedJaxpr(eqn_param, ())


def _is_float(aval: Any) -> bool:
    return (hasattr(aval, "dtype")
            and jnp.issubdtype(aval.dtype, jnp.floating))


class _State:
    def __init__(self, warm_iters: int) -> None:
        self.records: Dict[RecordKey, AbsVal] = {}
        self.critical: Dict[RecordKey, bool] = {}
        self.dot_inputs: Dict[RecordKey, DotInputs] = {}
        self.n_widened = 0
        self.warm_iters = warm_iters
        self.pinned: List[Any] = []
        self._const_memo: Dict[int, Tuple[Any, AbsVal]] = {}

    def abs_const(self, c: Any) -> AbsVal:
        ent = self._const_memo.get(id(c))
        if ent is not None and ent[0] is c:
            return ent[1]
        v = from_concrete(c)
        self._const_memo[id(c)] = (c, v)  # pin c so its id stays valid
        return v

    def record(self, key: RecordKey, val: AbsVal) -> None:
        prev = self.records.get(key)
        self.records[key] = val if prev is None else join(prev, val)

    def record_dot(self, key: RecordKey, d: DotInputs) -> None:
        prev = self.dot_inputs.get(key)
        if prev is None:
            self.dot_inputs[key] = d
        else:
            self.dot_inputs[key] = DotInputs(join(prev.lhs, d.lhs),
                                             join(prev.rhs, d.rhs),
                                             max(prev.n, d.n))


# --------------------------------------------------------------------------
# forward pass
# --------------------------------------------------------------------------

def _contraction_size(eqn: Any) -> int:
    try:
        if eqn.primitive.name == "dot_general":
            (lhs_c, _), _ = eqn.params["dimension_numbers"]
            shape = eqn.invars[0].aval.shape
            n = 1
            for d in lhs_c:
                n *= int(shape[d])
            return max(n, 1)
        n = 1
        for d in eqn.invars[1].aval.shape:
            n *= int(d)
        return max(n, 1)
    except Exception:
        return 1


def _aeval(st: _State, jaxpr: jcore.Jaxpr, consts: Sequence[AbsVal],
           args: Sequence[AbsVal], prefix: str) -> List[AbsVal]:
    st.pinned.append(jaxpr)
    env: Dict[Any, AbsVal] = {}

    def read(v: Any) -> AbsVal:
        if isinstance(v, jcore.Literal):
            return st.abs_const(v.val)
        return env.get(v, of_aval(v.aval))

    for v, val in zip(jaxpr.constvars, consts):
        env[v] = val
    for v, val in zip(jaxpr.invars, args):
        env[v] = val

    for eqn_idx, eqn in enumerate(jaxpr.eqns):
        invals = [read(v) for v in eqn.invars]
        pname = eqn.primitive.name
        name_stack = join_stack(prefix, str(eqn.source_info.name_stack))
        handler = _A_HOPS.get(pname)
        if handler is not None:
            outvals = handler(st, eqn, invals, name_stack)
        else:
            outvals = transfer(eqn, invals)
            for out_idx, var in enumerate(eqn.outvars):
                if _is_float(var.aval):
                    key = (id(jaxpr), eqn_idx, out_idx, name_stack)
                    st.record(key, outvals[out_idx])
                    if pname in _DOT_PRIMS and out_idx == 0:
                        st.record_dot(key, DotInputs(
                            invals[0], invals[1], _contraction_size(eqn)))
        for var, val in zip(eqn.outvars, outvals):
            env[var] = val

    return [read(v) for v in jaxpr.outvars]


def _a_call(st: _State, eqn: Any, invals: List[AbsVal],
            prefix: str) -> List[AbsVal]:
    key = "call_jaxpr" if "call_jaxpr" in eqn.params else "jaxpr"
    closed = _closed(eqn.params[key])
    cvals = [st.abs_const(c) for c in closed.consts]
    return _aeval(st, closed.jaxpr, cvals, invals, prefix)


def _carry_fixpoint(st: _State, body: jcore.ClosedJaxpr,
                    body_consts: List[AbsVal], carry_in: List[AbsVal],
                    extra: List[AbsVal], ncarry: int,
                    prefix: str) -> Tuple[List[AbsVal], List[AbsVal]]:
    """Join-fixpoint over a loop carry; returns (carry_acc, final_res).

    ``final_res`` is one body evaluation under the converged/widened
    accumulator, so its ys and record joins over-approximate every step."""
    cvals = [st.abs_const(c) for c in body.consts]
    acc = list(carry_in)
    converged = False
    for _ in range(max(st.warm_iters, 1)):
        res = _aeval(st, body.jaxpr, cvals, body_consts + acc + extra,
                     prefix)
        new = [join(a, r) for a, r in zip(acc, res[:ncarry])]
        if all(leq(n, a) for n, a in zip(new, acc)):
            acc = new
            converged = True
            break
        acc = new
    if not converged:
        st.n_widened += 1
        carry_vars = body.jaxpr.invars[len(body_consts):
                                       len(body_consts) + ncarry]
        acc = [of_aval(v.aval) for v in carry_vars]
    final = _aeval(st, body.jaxpr, cvals, body_consts + acc + extra, prefix)
    return acc, final


def _a_scan(st: _State, eqn: Any, invals: List[AbsVal],
            prefix: str) -> List[AbsVal]:
    p = eqn.params
    body = _closed(p["jaxpr"])
    nc, ncarry = p["num_consts"], p["num_carry"]
    consts = invals[:nc]
    carry_in = invals[nc:nc + ncarry]
    xs = [v.drop_lo() for v in invals[nc + ncarry:]]
    if p.get("length") == 0:
        return list(carry_in) + [of_aval(v.aval)
                                 for v in eqn.outvars[ncarry:]]
    acc, final = _carry_fixpoint(st, body, consts, list(carry_in), xs,
                                 ncarry, prefix)
    if p.get("length") is None:
        # unknown trip count: a zero-trip scan passes the carry through
        acc = [join(a, c) for a, c in zip(acc, carry_in)]
    return acc + [v.drop_lo() for v in final[ncarry:]]


def _a_while(st: _State, eqn: Any, invals: List[AbsVal],
             prefix: str) -> List[AbsVal]:
    p = eqn.params
    cond_closed = _closed(p["cond_jaxpr"])
    body_closed = _closed(p["body_jaxpr"])
    cn, bn = p["cond_nconsts"], p["body_nconsts"]
    cond_consts = invals[:cn]
    body_consts = invals[cn:cn + bn]
    carry_in = list(invals[cn + bn:])
    acc, _ = _carry_fixpoint(st, body_closed, body_consts, carry_in,
                             [], len(carry_in), prefix)
    # the cond jaxpr's sites see every iterate: evaluate it under acc
    cvals = [st.abs_const(c) for c in cond_closed.consts]
    _aeval(st, cond_closed.jaxpr, cvals, cond_consts + acc, prefix)
    # acc joins carry_in, so the zero-iteration case is covered
    return acc


def _a_cond(st: _State, eqn: Any, invals: List[AbsVal],
            prefix: str) -> List[AbsVal]:
    branches = eqn.params["branches"]
    operands = invals[1:]
    outs: Optional[List[AbsVal]] = None
    for br in branches:
        closed = _closed(br)
        cvals = [st.abs_const(c) for c in closed.consts]
        res = _aeval(st, closed.jaxpr, cvals, operands, prefix)
        outs = res if outs is None else [join(a, b)
                                         for a, b in zip(outs, res)]
    assert outs is not None
    return outs


_A_HOPS = {
    "jit": _a_call,
    "pjit": _a_call,
    "closed_call": _a_call,
    "core_call": _a_call,
    "scan": _a_scan,
    "while": _a_while,
    "cond": _a_cond,
    "remat2": _a_call,
    "checkpoint": _a_call,
    "custom_jvp_call": _a_call,
    "custom_vjp_call": _a_call,
    "custom_vjp_call_jaxpr": _a_call,
}


# --------------------------------------------------------------------------
# backward pass: non-finite propagation (criticality)
# --------------------------------------------------------------------------

# primitives where a non-finite element in any operand position listed
# produces a non-finite element in the (single) output
_PRESERVE_ALL = frozenset({
    "add", "sub", "mul", "dot_general", "conv_general_dilated",
    "ragged_dot", "concatenate",
})
_PRESERVE_FIRST = frozenset({
    "neg", "abs", "log", "sqrt", "reduce_sum", "reduce_prod", "cumsum",
    "reshape", "transpose", "broadcast_in_dim", "broadcast", "rev",
    "squeeze", "expand_dims", "copy", "stop_gradient", "real",
    "device_put", "optimization_barrier", "sharding_constraint",
})


def _preserve_positions(eqn: Any) -> List[int]:
    """Operand positions whose non-finite elements provably survive into
    the output. Conservative: unknown primitives propagate nothing."""
    pname = eqn.primitive.name
    if len(eqn.outvars) != 1:
        return []
    out_aval = eqn.outvars[0].aval
    if not _is_float(out_aval):
        return []
    if pname in _PRESERVE_ALL:
        return list(range(len(eqn.invars)))
    if pname in _PRESERVE_FIRST:
        return [0]
    if pname == "div":
        return [0]  # a / inf == 0: the denominator does not preserve
    if pname == "integer_pow":
        return [0] if int(eqn.params.get("y", 0)) > 0 else []
    if pname == "convert_element_type":
        in_aval = eqn.invars[0].aval
        if _is_float(in_aval):
            return [0]
        return []
    if pname == "pad":
        cfg = eqn.params.get("padding_config", ())
        if all(lo >= 0 and hi >= 0 for lo, hi, _ in cfg):
            return [0]  # no cropping: every operand element survives
        return []
    if pname == "scatter-add":
        return [0]
    return []


def _mark(st: _State, jaxpr: jcore.Jaxpr, prefix: str,
          out_crit: Sequence[bool], live: bool) -> List[bool]:
    crit: Dict[Any, bool] = {}

    def get(v: Any) -> bool:
        return (not isinstance(v, jcore.Literal)) and crit.get(v, False)

    def setv(v: Any, c: bool) -> None:
        if c and not isinstance(v, jcore.Literal):
            crit[v] = True

    for v, c in zip(jaxpr.outvars, out_crit):
        setv(v, c)

    for eqn_idx in reversed(range(len(jaxpr.eqns))):
        eqn = jaxpr.eqns[eqn_idx]
        pname = eqn.primitive.name
        name_stack = join_stack(prefix, str(eqn.source_info.name_stack))
        ocrit = [get(v) for v in eqn.outvars]
        if pname in _HOP_NAMES:
            icrit = _mark_hop(st, eqn, name_stack, ocrit, live)
            for v, c in zip(eqn.invars, icrit):
                setv(v, c)
            continue
        for out_idx, var in enumerate(eqn.outvars):
            key = (id(jaxpr), eqn_idx, out_idx, name_stack)
            if key in st.records and ocrit[out_idx] and live:
                st.critical[key] = True
        if any(ocrit):
            for i in _preserve_positions(eqn):
                if i < len(eqn.invars):
                    setv(eqn.invars[i], True)

    return [get(v) for v in jaxpr.invars]


def _mark_hop(st: _State, eqn: Any, prefix: str, ocrit: List[bool],
              live: bool) -> List[bool]:
    pname = eqn.primitive.name
    p = eqn.params
    if pname == "while":
        # trip count unknown: nothing inside is guaranteed to reach output
        return [False] * len(eqn.invars)
    if pname == "cond":
        branches = p["branches"]
        agg: Optional[List[bool]] = None
        for br in branches:
            closed = _closed(br)
            # live=False: an unexecuted branch makes its quantizes no-ops,
            # so branch-internal sites can never be overflow-pruned
            inv = _mark(st, closed.jaxpr, prefix, ocrit, False)
            agg = inv if agg is None else [a and b
                                           for a, b in zip(agg, inv)]
        assert agg is not None
        return [False] + agg
    if pname == "scan":
        return _mark_scan(st, eqn, prefix, ocrit, live)
    key = "call_jaxpr" if "call_jaxpr" in p else "jaxpr"
    closed = _closed(p[key])
    return _mark(st, closed.jaxpr, prefix, ocrit, live)


def _mark_scan(st: _State, eqn: Any, prefix: str, ocrit: List[bool],
               live: bool) -> List[bool]:
    p = eqn.params
    body = _closed(p["jaxpr"])
    nc, ncarry = p["num_consts"], p["num_carry"]
    eqn_carry_crit = list(ocrit[:ncarry])
    ys_crit = list(ocrit[ncarry:])
    # A_carry[i]: non-finite in carry_i at the start of ANY step reaches a
    # critical top-level output. Least fixpoint from below; a carry output
    # position is critical only when it is BOTH eqn-critical (covers the
    # last step, whose carry-out is the eqn output) AND in A (covers every
    # earlier step, whose carry-out feeds the next step). Incremental site
    # marking across rounds is sound: out_crit only grows, so the final
    # round's marks dominate all earlier ones.
    a_carry = [False] * ncarry
    inv: List[bool] = [False] * len(body.jaxpr.invars)
    for _ in range(ncarry + 1):
        body_out_crit = ([a and e for a, e in zip(a_carry, eqn_carry_crit)]
                        + ys_crit)
        inv = _mark(st, body.jaxpr, prefix, body_out_crit, live)
        new_a = [a or c for a, c in zip(a_carry, inv[nc:nc + ncarry])]
        if new_a == a_carry:
            break
        a_carry = new_a
    const_crit = inv[:nc]
    xs_crit = inv[nc + ncarry:]
    if p.get("length") == 0:
        carry_crit = eqn_carry_crit
        const_crit = [False] * nc
        xs_crit = [False] * len(xs_crit)
    else:
        carry_crit = [a and e for a, e in zip(a_carry, eqn_carry_crit)] \
            if p.get("length") is None else a_carry
    return const_crit + carry_crit + xs_crit


# --------------------------------------------------------------------------
# entry point
# --------------------------------------------------------------------------

def analyze_closed(closed: jcore.ClosedJaxpr,
                   inputs: Optional[Sequence[Any]] = None, *,
                   warm_iters: int = 3) -> AnalysisResult:
    """Run the forward range/exactness pass and the backward criticality
    pass over ``closed``.

    ``inputs``: one entry per ``closed.jaxpr.invars`` — an :class:`AbsVal`,
    or a concrete array to calibrate from (abstracted exactly via
    ``from_concrete``). ``None`` analyzes from dtype tops (range facts then
    come only from constants and structure)."""
    st = _State(warm_iters)
    st.pinned.append(closed)
    jaxpr = closed.jaxpr
    if inputs is None:
        args = [of_aval(v.aval) for v in jaxpr.invars]
    else:
        if len(inputs) != len(jaxpr.invars):
            raise ValueError(
                f"analyze_closed: got {len(inputs)} inputs for "
                f"{len(jaxpr.invars)} invars")
        args = [x if isinstance(x, AbsVal) else from_concrete(x)
                for x in inputs]
    consts = [st.abs_const(c) for c in closed.consts]
    out_vals = _aeval(st, jaxpr, consts, args, "")
    _mark(st, jaxpr, "", [True] * len(jaxpr.outvars), True)
    return AnalysisResult(records=st.records, critical=st.critical,
                          dot_inputs=st.dot_inputs, out_vals=out_vals,
                          n_widened=st.n_widened, pinned=st.pinned)
