"""Per-scope, per-rung static verdicts over an :class:`AnalysisResult`.

For each candidate ``(exp_bits, man_bits)`` rung of each search scope:

  * ``EXACT`` — every site the rung's solo rule matches holds values that
    are bit-exactly representable in the rung's format, so the solo
    truncated run is bit-identical to the reference (quantize is the
    identity on every value that reaches it, by induction over program
    order). The dynamic probe would measure ``metric(ref, ref)``.
  * ``OVERFLOW_CERTAIN`` — some matched site provably reaches the format's
    round-to-inf boundary (its ``lo`` lower-bounds the max magnitude), the
    format maps overflow to ``inf`` (IEEE, non-saturating), and the
    non-finite provably propagates to a program output (the site is
    *critical*). The dynamic probe would measure a non-finite error.
  * ``UNKNOWN`` — keep dynamic probing.

Per-site records soundly over-approximate the concrete reference run
whether or not the *abstract* envelope of the program outputs stays
finite, so verdicts are decided from the records alone. The one fact
that cannot be established here — that ``metric(ref, ref)`` is exactly
``0.0``, which is what an EXACT rung's probe would measure — is
validated *dynamically* by the search driver against the concrete
reference outputs it computes anyway (a loud error on violation, never
a silent divergence from the unpruned search).

*Universal* exactness is the stronger, value-independent fact that the
rung's format can represent every value of the site's carrier dtype
(grid ⊇ carrier grid, range ⊇ carrier range, infs preserved): the
quantize is then the literal identity on ANY input — including inputs
already perturbed by truncation elsewhere — which is what licenses
skipping a scope's trial-exclusion eval inside a joint policy.
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Any, Dict, List, Optional, Sequence

from repro.core.formats import FPFormat
from repro.core.policy import TruncationPolicy, TruncationRule
from repro.analysis.domain import AbsVal, carrier_format, top_for_dtype
from repro.analysis.interp import AnalysisResult

_MARGIN = 1.0 + 2.0 ** -20


class Verdict(str, enum.Enum):
    EXACT = "EXACT"
    OVERFLOW_CERTAIN = "OVERFLOW_CERTAIN"
    UNKNOWN = "UNKNOWN"


def rne_overflow_boundary(fmt: FPFormat) -> float:
    """Magnitudes at or above this round to ``inf`` under round-to-nearest-
    even in ``fmt`` (the midpoint between ``max_finite`` and the first
    non-representable binade step)."""
    return float(2.0 ** fmt.max_exp * (2.0 - 2.0 ** -(fmt.man_bits + 1)))


def exact_in(fmt: FPFormat, v: AbsVal,
             carrier: Optional[FPFormat]) -> bool:
    """Every concrete value in ``v`` quantizes to itself in ``fmt``."""
    range_ok = v.hi <= fmt.max_finite or (
        carrier is not None
        and carrier.max_finite <= fmt.max_finite
        and fmt.ieee_inf and not fmt.saturate)
    return (range_ok
            and v.rel_bits <= fmt.man_bits
            and v.ulp_exp >= fmt.min_exp - fmt.man_bits)


def universally_exact(fmt: FPFormat, dtype: Any) -> bool:
    """``fmt`` represents every value of carrier ``dtype`` bit-exactly —
    quantize is the identity on arbitrary inputs of that dtype."""
    carrier = carrier_format(dtype)
    if carrier is None:
        return False
    return exact_in(fmt, top_for_dtype(dtype), carrier)


def overflow_certain(fmt: FPFormat, v: AbsVal, critical: bool) -> bool:
    """Quantizing ``v`` in ``fmt`` provably yields ``inf`` at every
    execution, and that inf provably reaches a program output."""
    return (critical
            and fmt.ieee_inf and not fmt.saturate
            and math.isfinite(v.hi)
            and v.lo >= rne_overflow_boundary(fmt) * _MARGIN)


@dataclasses.dataclass
class StaticVerdicts:
    """Rung verdicts for a search frontier, plus the universal-exact sets."""

    verdicts: Dict[str, Dict[int, Verdict]]
    universal: Dict[str, frozenset]
    outputs_finite: bool
    n_decided: int

    def get(self, path: str, man_bits: int) -> Verdict:
        return self.verdicts.get(path, {}).get(man_bits, Verdict.UNKNOWN)

    def is_universal(self, path: str, man_bits: int) -> bool:
        return man_bits in self.universal.get(path, frozenset())

    def to_json(self) -> Dict[str, Dict[str, str]]:
        return {path: {f"m{w}": v.value for w, v in sorted(rungs.items(),
                                                           reverse=True)}
                for path, rungs in self.verdicts.items()}


def scope_rung_verdicts(result: AnalysisResult, index: Any,
                        scope_paths: Sequence[str],
                        cand_widths: Sequence[int],
                        exp_bits: int) -> StaticVerdicts:
    """Judge every ``(scope, man_bits)`` rung of the search ladder.

    ``index`` is the search's ``SiteIndex`` (built from the same closed
    jaxpr as ``result``, so record keys line up). A rung is EXACT only if
    ALL sites its solo rule matches are exact (zero matched sites is
    vacuously exact: the rung's policy is a no-op); OVERFLOW_CERTAIN if
    ANY matched site certainly overflows into an output."""
    keys = index.site_keys()
    outputs_finite = result.outputs_finite
    verdicts: Dict[str, Dict[int, Verdict]] = {}
    universal: Dict[str, frozenset] = {}
    n_decided = 0
    probe_fmt = FPFormat(exp_bits, 0)
    for path in scope_paths:
        probe = TruncationPolicy(rules=(
            TruncationRule(fmt=probe_fmt, scope=path),))
        # rule matching is format-independent: resolve the matched site
        # set once per scope
        matched = [s for s in index.sites
                   if probe.rule_for(s.stack, s.prim, s.dtype) is not None]
        rungs: Dict[int, Verdict] = {}
        uni: List[int] = []
        for w in cand_widths:
            fmt = FPFormat(exp_bits, int(w))
            if all(universally_exact(fmt, s.dtype) for s in matched):
                uni.append(int(w))
            all_exact = True
            any_overflow = False
            for s in matched:
                key = keys[s.index]
                v = result.records.get(key)
                if v is None:
                    # no record for this site: exact only when the format
                    # covers the site's whole carrier grid (any sealed
                    # record would pass exact_in then, so this subsumes it)
                    if not universally_exact(fmt, s.dtype):
                        all_exact = False
                    continue
                if not exact_in(fmt, v, carrier_format(s.dtype)):
                    all_exact = False
                if overflow_certain(fmt, v, result.critical_at(key)):
                    any_overflow = True
            if any_overflow:
                rungs[int(w)] = Verdict.OVERFLOW_CERTAIN
            elif all_exact:
                rungs[int(w)] = Verdict.EXACT
            else:
                rungs[int(w)] = Verdict.UNKNOWN
        n_decided += sum(1 for v in rungs.values() if v != Verdict.UNKNOWN)
        verdicts[path] = rungs
        universal[path] = frozenset(uni)
    return StaticVerdicts(verdicts=verdicts, universal=universal,
                          outputs_finite=outputs_finite,
                          n_decided=n_decided)
