"""Dispatching wrapper for the WKV6 recurrence."""
from __future__ import annotations

import jax

from repro.kernels.rwkv6.kernel import wkv6_pallas
from repro.kernels.rwkv6.ref import wkv6_ref


def wkv6(r, k, v, w, u, s0, *, impl: str = "auto", chunk: int = 64):
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "pallas":
        return wkv6_pallas(r, k, v, w, u, s0, chunk=chunk)
    if impl == "interpret":
        return wkv6_pallas(r, k, v, w, u, s0, chunk=chunk, interpret=True)
    return wkv6_ref(r, k, v, w, u, s0)
