"""Dispatching wrapper for the WKV6 recurrence."""
from __future__ import annotations

import jax

from repro.kernels.quantize_em.ops import quantize_dynamic
from repro.kernels.rwkv6.kernel import wkv6_pallas
from repro.kernels.rwkv6.ref import wkv6_ref


def wkv6(r, k, v, w, u, s0, *, impl: str = "auto", chunk: int = 64,
         out_fmt=None):
    """``out_fmt``: optional (4,) int32 runtime format row applied to ``y``
    (fused in-kernel on the Pallas paths, composed on the ref path)."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "pallas":
        return wkv6_pallas(r, k, v, w, u, s0, chunk=chunk, out_fmt=out_fmt)
    if impl == "interpret":
        return wkv6_pallas(r, k, v, w, u, s0, chunk=chunk, interpret=True,
                           out_fmt=out_fmt)
    y, sT = wkv6_ref(r, k, v, w, u, s0)
    if out_fmt is not None:
        y = quantize_dynamic(y, out_fmt, impl="ref")
    return y, sT
