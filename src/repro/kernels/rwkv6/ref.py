"""Pure-jnp oracle for the WKV6 recurrence (plain scan over tokens)."""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def wkv6_ref(r, k, v, w, u, s0):
    """r/k/v/w: (B, H, S, hd); u: (H, hd); s0: (B, H, hd, hd).
    Returns (y (B, H, S, hd) f32, sT)."""
    f32 = jnp.float32
    r, k, v, w = (t.astype(f32) for t in (r, k, v, w))
    u = u.astype(f32)

    def step(s, t):
        r_t, k_t, v_t, w_t = t                      # (B,H,hd)
        kv = k_t[..., :, None] * v_t[..., None, :]  # (B,H,hd,hd)
        y = jnp.einsum("bhi,bhij->bhj", r_t, s + u[..., None] * kv)
        s = w_t[..., None] * s + kv
        return s, y

    xs = tuple(t.transpose(2, 0, 1, 3) for t in (r, k, v, w))
    sT, ys = lax.scan(step, s0.astype(f32), xs)
    return ys.transpose(1, 2, 0, 3), sT
