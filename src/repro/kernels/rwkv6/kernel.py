"""Pallas TPU kernel for the RWKV-6 (Finch) WKV recurrence, chunked.

Per head h with per-token, per-channel decay w_t in (0,1):
    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

Grid = (batch*heads,); each program owns one head's full sequence, scanning
chunks of length C resident in VMEM. Within a chunk the recurrence is the
exact step form (fori_loop over C tokens — numerically identical to the
reference; the decay products stay implicit so no 1/A overflow issues), and
only the (hd x hd) state crosses chunk boundaries. This is the TPU analogue
of the within-chunk/cross-chunk split used by GPU linear-attention kernels,
re-blocked for VMEM instead of shared memory.

VMEM per program at (C=64, hd=64): r/k/v/w 4x64x64x4 = 64 KiB + state
16 KiB + y 16 KiB — tiny; the win is HBM locality of the streamed chunks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.quantize_em import ref as _qref


def _wkv_kernel(*refs, chunk: int, seq_len: int, quantized: bool = False):
    if quantized:
        # fused epilogue: (4,) int32 runtime format row via SMEM scalar
        # prefetch, applied to the per-chunk y stores (the recurrence state
        # sT is a carry, not a truncation site — it stays exact)
        (fmt_ref, r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
         y_ref, sT_ref) = refs
    else:
        r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sT_ref = refs
    hd = r_ref.shape[-1]
    u = u_ref[0].astype(jnp.float32)                       # (hd,)
    nch = seq_len // chunk

    def chunk_body(c, state):
        r = r_ref[0, c].astype(jnp.float32)                # (C, hd)
        k = k_ref[0, c].astype(jnp.float32)
        v = v_ref[0, c].astype(jnp.float32)
        w = w_ref[0, c].astype(jnp.float32)

        def tok(t, carry):
            s, y = carry
            kt = jax.lax.dynamic_slice_in_dim(k, t, 1, 0)  # (1, hd)
            vt = jax.lax.dynamic_slice_in_dim(v, t, 1, 0)
            rt = jax.lax.dynamic_slice_in_dim(r, t, 1, 0)
            wt = jax.lax.dynamic_slice_in_dim(w, t, 1, 0)
            kv = kt.T @ vt                                 # (hd, hd)
            yt = rt @ (s + u[:, None] * kv)                # (1, hd)
            s = wt.T * s + kv
            y = jax.lax.dynamic_update_slice_in_dim(y, yt, t, 0)
            return s, y

        y0 = jnp.zeros((chunk, hd), jnp.float32)
        state, y = jax.lax.fori_loop(0, chunk, tok, (state, y0))
        y = y.astype(y_ref.dtype)
        if quantized:
            y = _qref.quantize_epilogue(y, fmt_ref)
        y_ref[0, c] = y
        return state

    state = s0_ref[0].astype(jnp.float32)
    state = jax.lax.fori_loop(0, nch, chunk_body, state)
    sT_ref[0] = state.astype(sT_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_pallas(r, k, v, w, u, s0, *, chunk: int = 64,
                interpret: bool = False, out_fmt=None):
    """r/k/v/w: (B, H, S, hd); u: (H, hd); s0: (B, H, hd, hd) f32.
    Returns (y (B, H, S, hd) f32, sT (B, H, hd, hd) f32).

    ``out_fmt`` (optional): a (4,) int32 runtime format row; when given, the
    dynamic quantize is fused into the per-chunk ``y`` stores (scalar
    prefetch — format swaps are data, zero recompiles). ``sT`` is returned
    unquantized either way."""
    B, H, S, hd = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nch = S // chunk

    def shape4(t):
        return t.reshape(B * H, nch, chunk, hd)

    u_r = jnp.broadcast_to(u[None], (B, H, hd)).reshape(B * H, hd)
    s0_r = s0.reshape(B * H, hd, hd)

    grid = (B * H,)
    kernel = functools.partial(_wkv_kernel, chunk=chunk, seq_len=S,
                               quantized=out_fmt is not None)
    in_blocks = [
        ((1, nch, chunk, hd), lambda i: (i, 0, 0, 0)),
        ((1, nch, chunk, hd), lambda i: (i, 0, 0, 0)),
        ((1, nch, chunk, hd), lambda i: (i, 0, 0, 0)),
        ((1, nch, chunk, hd), lambda i: (i, 0, 0, 0)),
        ((1, hd), lambda i: (i, 0)),
        ((1, hd, hd), lambda i: (i, 0, 0)),
    ]
    out_blocks = [
        ((1, nch, chunk, hd), lambda i: (i, 0, 0, 0)),
        ((1, hd, hd), lambda i: (i, 0, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((B * H, nch, chunk, hd), jnp.float32),
        jax.ShapeDtypeStruct((B * H, hd, hd), jnp.float32),
    ]
    operands = (shape4(r), shape4(k), shape4(v), shape4(w), u_r, s0_r)

    if out_fmt is None:
        y, sT = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[pl.BlockSpec(b, ix) for b, ix in in_blocks],
            out_specs=[pl.BlockSpec(b, ix) for b, ix in out_blocks],
            out_shape=out_shape,
            interpret=interpret,
        )(*operands)
    else:
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[pl.BlockSpec(b, lambda i, fmt, ix=ix: ix(i))
                      for b, ix in in_blocks],
            out_specs=[pl.BlockSpec(b, lambda i, fmt, ix=ix: ix(i))
                       for b, ix in out_blocks],
        )
        y, sT = pl.pallas_call(
            kernel,
            grid_spec=grid_spec,
            out_shape=out_shape,
            interpret=interpret,
        )(jnp.asarray(out_fmt, jnp.int32), *operands)
    return y.reshape(B, H, S, hd), sT.reshape(B, H, hd, hd)
