"""Native fp8 (e4m3) dot path for ``quantize_dot_inputs`` sites.

The emulated path rounds each dot operand onto the e4m3 grid but keeps the
values in the carrier dtype, so the MXU still runs at carrier width — the
profiler measures *accuracy* of the policy, not its speed. This module is
the execution path: operands are stored as ``float8_e4m3fn`` and the dot
accumulates in f32 (``preferred_element_type``), which is what actually
exercises a low-precision matrix unit.

Bit-exactness: XLA's ``f32 -> float8_e4m3fn`` convert double-rounds through
bf16 on CPU (observed on jax 0.4.37: ``astype`` disagrees with ml_dtypes'
correctly-rounded cast), so the hardware cast is NOT trusted to round.
Instead each operand is pre-rounded onto the e4m3 grid with the repo's
bit-exact quantizer — after which the storage cast is exact, because every
e4m3 grid value is exactly representable in bf16 and f32 (3 mantissa bits,
exponent range inside bf16's), making any double-rounding an identity. The
conformance tier sweeps this input quantize against the bit oracle.

Specials: ``float8_e4m3fn`` has no infinities, so an operand that is (or
pre-rounds to) +/-inf is stored as NaN — the same degradation real fp8
storage applies. Finite operands (every profiling configuration in this
repo) are bit-exact.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro.kernels.quantize_em import ref as _ref

F8_DTYPE = jnp.float8_e4m3fn


def is_native_fp8_format(fmt) -> bool:
    """True when ``fmt`` (an ``FPFormat``) maps onto float8_e4m3fn storage:
    (e=4, m=3) with fn overflow semantics — saturating (clamp to +/-448,
    still on the storage grid) or non-saturating (overflow -> NaN, the
    ml_dtypes cast behaviour). IEEE-inf e4m3 layouts have no storage type."""
    return (fmt.exp_bits == 4 and fmt.man_bits == 3 and not fmt.ieee_inf)


def quantize_dot_operand(x, *, saturate: bool = True):
    """Pre-round a dot operand onto the e4m3 grid (f32 carrier), matching
    the interpreter's emulated input quantize bit-for-bit."""
    return _ref.quantize_ref(x.astype(jnp.float32), 4, 3, saturate, False)


def encode_e4m3(xq):
    """Cast values already on the e4m3 grid to fp8 storage (exact)."""
    return xq.astype(F8_DTYPE)


def fp8_dot_general(lhs, rhs, dimension_numbers, *, saturate: bool = True,
                    precision=None, out_dtype=None):
    """``lax.dot_general`` with e4m3-quantized operands on native fp8
    storage, accumulating in f32. Input quantize is the bit oracle's
    rounding; the contraction itself runs on the fp8 execution path."""
    lq = encode_e4m3(quantize_dot_operand(lhs, saturate=saturate))
    rq = encode_e4m3(quantize_dot_operand(rhs, saturate=saturate))
    out = lax.dot_general(lq, rq, dimension_numbers, precision=precision,
                          preferred_element_type=jnp.float32)
    if out_dtype is not None:
        out = out.astype(out_dtype)
    return out
