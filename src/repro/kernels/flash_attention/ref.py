"""Pure-jnp oracle for the flash attention kernel: naive masked softmax
attention with explicit KV-head repetition (memory-hungry but obviously
correct; tests compare kernel vs this on swept shapes/dtypes)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window=None, scale=None):
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    kr = jnp.repeat(k, G, axis=1)
    vr = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * scale
    i = jnp.arange(S)[:, None]
    j = jnp.arange(k.shape[2])[None, :]
    mask = jnp.ones((S, k.shape[2]), bool)
    if causal:
        mask &= i >= j
    if window is not None:
        mask &= (i - j) < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      vr.astype(jnp.float32)).astype(v.dtype)
