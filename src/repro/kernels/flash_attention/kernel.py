"""Pallas TPU flash attention (causal / sliding-window / GQA).

Tiling: grid = (batch*kv_heads, q_head_group, q_blocks); each program owns
one q block (block_q x D) and loops over kv blocks with a fori_loop carrying
the running (max, denom, acc) — KV is read once per (group, q-block), never
materialized at Hq (the grouped-query memory win). Causal programs skip kv
blocks above the diagonal (the classic ~2x flop win).

VMEM per program at (block_q=512, block_k=512, D=128):
q 256 KiB + kv block 2x256 KiB + p 1 MiB + acc 256 KiB ~= 2 MiB,
double-bufferable inside the ~128 MiB v5e VMEM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.quantize_em import ref as _qref

NEG_INF = -1e30


def _attn_kernel(*refs, scale, block_q, block_k, seq_len, causal, window,
                 quantized=False):
    if quantized:
        # fused epilogue: the (4,) int32 runtime format row arrives first,
        # as an SMEM scalar-prefetch operand (same vector the standalone
        # quantize_em kernel prefetches)
        fmt_ref, q_ref, k_ref, v_ref, o_ref = refs
    else:
        q_ref, k_ref, v_ref, o_ref = refs
    qb = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * scale            # (block_q, D)

    nk = seq_len // block_k

    def body(kb, carry):
        m, l, acc = carry
        k = k_ref[0, kb].astype(jnp.float32)               # (block_k, D)
        v = v_ref[0, kb].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        qi = qb * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        ki = kb * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones(s.shape, jnp.bool_)
        if causal:
            mask &= qi >= ki
        if window is not None:
            mask &= (qi - ki) < window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[:, None] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    a0 = jnp.zeros((block_q, v_ref.shape[-1]), jnp.float32)
    # causal: only kv blocks intersecting the lower triangle
    nk_eff = ((qb + 1) * block_q + block_k - 1) // block_k if causal else nk
    m, l, acc = jax.lax.fori_loop(0, nk_eff, body, (m0, l0, a0))
    out = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)
    if quantized:
        # quantize the *stored* value (post output-dtype cast) so the fused
        # kernel is bit-identical to unfused kernel + quantize_dynamic
        out = _qref.quantize_epilogue(out, fmt_ref)
    o_ref[0, 0] = out


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_k",
                     "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True, window=None,
                           scale=None, block_q: int = 512, block_k: int = 512,
                           interpret: bool = False, out_fmt=None):
    """q: (B, Hq, S, D); k/v: (B, Hkv, S, D/Dv). Returns (B, Hq, S, Dv).

    ``out_fmt`` (optional): a (4,) int32 runtime format row
    (exp_bits, man_bits, saturate, ieee_inf | fault << 1). When given, the
    dynamic quantize runs as a fused epilogue on the output store — one
    kernel instead of kernel + separate quantize pass — and the row is
    runtime *data* (scalar prefetch), so swapping formats never recompiles.
    """
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    Dv = v.shape[-1]
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)

    qr = q.reshape(B * Hkv, G, S, D)
    kr = k.reshape(B * Hkv, S // block_k, block_k, D)
    vr = v.reshape(B * Hkv, S // block_k, block_k, Dv)
    grid = (B * Hkv, G, S // block_q)

    kernel = functools.partial(_attn_kernel, scale=scale, block_q=block_q,
                               block_k=block_k, seq_len=S, causal=causal,
                               window=window, quantized=out_fmt is not None)
    in_blocks = [
        ((1, 1, block_q, D), lambda bh, g, qb: (bh, g, qb, 0)),
        ((1, S // block_k, block_k, D), lambda bh, g, qb: (bh, 0, 0, 0)),
        ((1, S // block_k, block_k, Dv), lambda bh, g, qb: (bh, 0, 0, 0)),
    ]
    out_block = ((1, 1, block_q, Dv), lambda bh, g, qb: (bh, g, qb, 0))
    out_shape = jax.ShapeDtypeStruct((B * Hkv, G, S, Dv), q.dtype)

    if out_fmt is None:
        out = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[pl.BlockSpec(b, ix) for b, ix in in_blocks],
            out_specs=pl.BlockSpec(*out_block),
            out_shape=out_shape,
            interpret=interpret,
        )(qr, kr, vr)
    else:
        # index maps gain the trailing prefetch ref arg (unused for tiling)
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[pl.BlockSpec(b, lambda bh, g, qb, fmt, ix=ix: ix(bh, g, qb))
                      for b, ix in in_blocks],
            out_specs=pl.BlockSpec(
                out_block[0],
                lambda bh, g, qb, fmt, ix=out_block[1]: ix(bh, g, qb)),
        )
        out = pl.pallas_call(
            kernel,
            grid_spec=grid_spec,
            out_shape=out_shape,
            interpret=interpret,
        )(jnp.asarray(out_fmt, jnp.int32), qr, kr, vr)
    return out.reshape(B, Hq, S, Dv)
