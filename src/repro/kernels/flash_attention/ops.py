"""Dispatching wrapper: Pallas kernel on TPU, chunked-jnp flash elsewhere.

The chunked-jnp path (models/attention.flash_attention) shares the exact
blockwise-softmax contract, so dry-run HLO on CPU and kernel execution on
TPU describe the same algorithm.
"""
from __future__ import annotations

import jax

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.quantize_em.ops import quantize_dynamic
from repro.models.attention import flash_attention as flash_attention_xla


def flash_attention(q, k, v, *, causal: bool = True, window=None, scale=None,
                    impl: str = "auto", out_fmt=None, **kw):
    """``out_fmt``: optional (4,) int32 runtime format row. On the Pallas
    paths the dynamic quantize runs as a fused in-kernel epilogue; on the
    XLA path it composes as a separate pass — bit-identical either way."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl == "pallas":
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      scale=scale, out_fmt=out_fmt, **kw)
    if impl == "interpret":
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      scale=scale, interpret=True,
                                      out_fmt=out_fmt, **kw)
    out = flash_attention_xla(q, k, v, causal=causal, window=window,
                              scale=scale)
    if out_fmt is not None:
        out = quantize_dynamic(out, out_fmt, impl="ref")
    return out
