"""Pure-jnp oracle for arbitrary (e, m) round-to-nearest-even quantization.

This is the TPU-native replacement for RAPTOR's MPFR emulation: instead of a
scalar correctly-rounded library call per operation, we round the *carrier*
(f32/f64) result of each op onto the representable grid of the target
``FPFormat`` with pure bit manipulation — fully vectorizable.

Semantics (validated against ml_dtypes for every hardware format in tests):
  * round-to-nearest, ties-to-even on the target grid
  * gradual underflow onto the target's subnormal grid
  * overflow -> +/-inf (IEEE layouts) / NaN (fn layouts) / +/-max_finite
    (``saturate`` formats)
  * NaN preserved, +/-inf preserved, +/-0 preserved
Known carrier-precision floor: inputs that are subnormal *in the carrier*
combined with a target whose exponent range exceeds the carrier's cannot be
re-normalized (DESIGN.md §7); irrelevant for every profiling configuration
in this repo.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp
from jax import lax

_CARRIER = {
    jnp.dtype("float32"): (jnp.int32, 23),
    jnp.dtype("float64"): (jnp.int64, 52),
}


def _format_constants(exp_bits: int, man_bits: int, ieee_inf: bool):
    bias = (1 << (exp_bits - 1)) - 1
    max_exp = (1 << exp_bits) - (2 if ieee_inf else 1) - bias
    min_exp = 1 - bias
    if ieee_inf:
        max_finite = 2.0 ** max_exp * (2.0 - 2.0 ** (-min(man_bits, 52)))
    else:
        max_finite = 2.0 ** max_exp * (2.0 - 2.0 ** (1 - min(man_bits, 52)))
    min_normal = 2.0 ** min_exp
    sub_scale = 2.0 ** (min_exp - man_bits)
    return max_exp, max_finite, min_normal, sub_scale


def quantize_ref(x, exp_bits: int, man_bits: int, saturate: bool = False,
                 ieee_inf: bool = True):
    """Quantize ``x`` (f32 or f64) to the (exp_bits, man_bits) grid, RNE.

    Returns an array of the same dtype as ``x`` whose values all lie on the
    target format's representable grid.
    """
    dt = jnp.dtype(x.dtype)
    if dt not in _CARRIER:
        raise TypeError(f"carrier must be f32/f64, got {dt}")
    int_dtype, c_man = _CARRIER[dt]
    c_exp = 8 if c_man == 23 else 11
    np_int = np.int32 if c_man == 23 else np.int64

    _, max_finite, min_normal, sub_scale = _format_constants(
        exp_bits, man_bits, ieee_inf)
    k = c_man - man_bits  # mantissa bits to drop (<=0: nothing to drop)

    # ---- 1) normal-range mantissa RNE via the bit trick --------------------
    if k > 0:
        bits = lax.bitcast_convert_type(x, int_dtype)
        one = np_int(1)
        half = np_int(1 << (k - 1))
        lsb = lax.shift_right_logical(bits, np_int(k)) & one
        rounded = (bits + (half - one) + lsb) & np_int(~((1 << k) - 1))
        y = lax.bitcast_convert_type(rounded, dt)
    else:
        y = x

    # ---- 2) subnormal range: RNE onto the fixed-point grid -----------------
    # Only needed when the target exponent range is narrower than the
    # carrier's (otherwise the carrier-aligned bit trick already lands on the
    # right subnormal grid — see tests vs ml_dtypes bf16).
    finfo = np.finfo(dt)
    if exp_bits < c_exp and sub_scale >= float(finfo.tiny):
        ss = np.array(sub_scale, dt)
        mn = np.array(min_normal, dt)
        x_sub = jnp.rint(x / ss) * ss
        y = jnp.where(jnp.abs(x) < mn, x_sub, y)

    # ---- 3) overflow --------------------------------------------------------
    if max_finite <= float(finfo.max):
        mf = np.array(max_finite, dt)
        ovf = jnp.abs(y) > mf
        if saturate:
            y = jnp.where(ovf, jnp.sign(y) * mf, y)
        elif ieee_inf:
            y = jnp.where(ovf, jnp.sign(y) * np.array(np.inf, dt), y)
        else:  # fn layout, non-saturating: overflow is NaN (ml_dtypes cast)
            y = jnp.where(ovf, np.array(np.nan, dt), y)

    # ---- 4) specials ----------------------------------------------------------
    y = jnp.where(jnp.isnan(x), x, y)
    y = jnp.where(jnp.isinf(x), x, y)  # inf passes through even when saturating
    return y


def quantize_ref_fmt(x, fmt):
    """Convenience wrapper taking an ``FPFormat``."""
    return quantize_ref(x, fmt.exp_bits, fmt.man_bits, fmt.saturate, fmt.ieee_inf)
