"""Pure-jnp oracle for arbitrary (e, m) round-to-nearest-even quantization.

This is the TPU-native replacement for RAPTOR's MPFR emulation: instead of a
scalar correctly-rounded library call per operation, we round the *carrier*
(f32/f64) result of each op onto the representable grid of the target
``FPFormat`` with pure bit manipulation — fully vectorizable.

Semantics (validated against ml_dtypes for every hardware format in tests):
  * round-to-nearest, ties-to-even on the target grid
  * gradual underflow onto the target's subnormal grid
  * overflow -> +/-inf (IEEE layouts) / NaN (fn layouts) / +/-max_finite
    (``saturate`` formats)
  * NaN preserved, +/-inf preserved, +/-0 preserved
Known carrier-precision floor: inputs that are subnormal *in the carrier*
combined with a target whose exponent range exceeds the carrier's cannot be
re-normalized (DESIGN.md §7); irrelevant for every profiling configuration
in this repo.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp
from jax import lax

_CARRIER = {
    jnp.dtype("float32"): (jnp.int32, 23),
    jnp.dtype("float64"): (jnp.int64, 52),
}


def _format_constants(exp_bits: int, man_bits: int, ieee_inf: bool):
    bias = (1 << (exp_bits - 1)) - 1
    max_exp = (1 << exp_bits) - (2 if ieee_inf else 1) - bias
    min_exp = 1 - bias
    if ieee_inf:
        max_finite = 2.0 ** max_exp * (2.0 - 2.0 ** (-min(man_bits, 52)))
    else:
        max_finite = 2.0 ** max_exp * (2.0 - 2.0 ** (1 - min(man_bits, 52)))
    min_normal = 2.0 ** min_exp
    sub_scale = 2.0 ** (min_exp - man_bits)
    return max_exp, max_finite, min_normal, sub_scale


def quantize_ref(x, exp_bits: int, man_bits: int, saturate: bool = False,
                 ieee_inf: bool = True):
    """Quantize ``x`` (f32 or f64) to the (exp_bits, man_bits) grid, RNE.

    Returns an array of the same dtype as ``x`` whose values all lie on the
    target format's representable grid.
    """
    dt = jnp.dtype(x.dtype)
    if dt not in _CARRIER:
        raise TypeError(f"carrier must be f32/f64, got {dt}")
    int_dtype, c_man = _CARRIER[dt]
    c_exp = 8 if c_man == 23 else 11
    np_int = np.int32 if c_man == 23 else np.int64

    _, max_finite, min_normal, sub_scale = _format_constants(
        exp_bits, man_bits, ieee_inf)
    k = c_man - man_bits  # mantissa bits to drop (<=0: nothing to drop)

    # ---- 1) normal-range mantissa RNE via the bit trick --------------------
    if k > 0:
        bits = lax.bitcast_convert_type(x, int_dtype)
        one = np_int(1)
        half = np_int(1 << (k - 1))
        lsb = lax.shift_right_logical(bits, np_int(k)) & one
        rounded = (bits + (half - one) + lsb) & np_int(~((1 << k) - 1))
        y = lax.bitcast_convert_type(rounded, dt)
    else:
        y = x

    # ---- 2) subnormal range: RNE onto the fixed-point grid -----------------
    # Only needed when the target exponent range is narrower than the
    # carrier's (otherwise the carrier-aligned bit trick already lands on the
    # right subnormal grid — see tests vs ml_dtypes bf16).
    finfo = np.finfo(dt)
    if exp_bits < c_exp and sub_scale >= float(finfo.tiny):
        ss = np.array(sub_scale, dt)
        mn = np.array(min_normal, dt)
        x_sub = jnp.rint(x / ss) * ss
        y = jnp.where(jnp.abs(x) < mn, x_sub, y)

    # ---- 3) overflow --------------------------------------------------------
    if max_finite <= float(finfo.max):
        mf = np.array(max_finite, dt)
        ovf = jnp.abs(y) > mf
        if saturate:
            y = jnp.where(ovf, jnp.sign(y) * mf, y)
        elif ieee_inf:
            y = jnp.where(ovf, jnp.sign(y) * np.array(np.inf, dt), y)
        else:  # fn layout, non-saturating: overflow is NaN (ml_dtypes cast)
            y = jnp.where(ovf, np.array(np.nan, dt), y)

    # ---- 4) specials ----------------------------------------------------------
    y = jnp.where(jnp.isnan(x), x, y)
    y = jnp.where(jnp.isinf(x), x, y)  # inf passes through even when saturating
    return y


def quantize_ref_fmt(x, fmt):
    """Convenience wrapper taking an ``FPFormat``."""
    return quantize_ref(x, fmt.exp_bits, fmt.man_bits, fmt.saturate, fmt.ieee_inf)


# ---------------------------------------------------------------------------
# runtime-parameterized variant: (e, m, saturate, ieee_inf) as traced values
# ---------------------------------------------------------------------------
#
# ``quantize_ref`` above specializes the computation on the format at trace
# time (python branches, numpy constants), so every distinct format costs a
# retrace + recompile. ``quantize_ref_dynamic`` takes the format fields as
# *traced scalars*: one compiled executable serves every (e, m, saturate,
# ieee_inf), which is what collapses a precision-policy sweep to a single
# XLA compilation. All static branches become lane-wise ``where`` gates; the
# static identity fast path becomes the in-kernel ``man_bits >= carrier``
# gate. Kept free of python-level f64 branches when the carrier is f32 so
# the Pallas kernel can call it directly.


def _pow2(n, dt):
    """Exact 2**n in carrier dtype ``dt`` for traced int32 ``n``, built by
    writing the exponent field directly (bitcast) — no transcendentals, so it
    lowers inside a Pallas kernel. Saturates to 0 below the normal range and
    to +inf above it; both ends are gated off by the callers."""
    if jnp.dtype(dt) == jnp.dtype(jnp.float32):
        int_dtype, man, bias, emax = jnp.int32, 23, 127, 255
    else:
        int_dtype, man, bias, emax = jnp.int64, 52, 1023, 2047
    biased = jnp.clip(n + bias, 0, emax).astype(int_dtype)
    return lax.bitcast_convert_type(
        jnp.left_shift(biased, jnp.asarray(man, int_dtype)), jnp.dtype(dt))


def dynamic_row_params(exp_bits, man_bits, saturate, ieee_inf, fault=0,
                       dtype=jnp.float32):
    """Derived rounding constants for the runtime quantizer, elementwise.

    Every quantity ``quantize_ref_dynamic`` derives from the format fields —
    rounding masks, range bounds, gates, the fault XOR mask — but none of
    the array-side math. Inputs may be python ints, traced scalars, or
    whole ``(num_sites,)`` table columns: the math is elementwise, so one
    call derives the constants for an entire format table at once. That is
    the point of the split — a runtime-table sweep derives its ~30 scalar
    ops once per *table* instead of once per *site*, which is what keeps
    the swept executable's graph (and XLA compile time) near the static
    transform's. Returns a dict of arrays parallel to the inputs.
    """
    dt = jnp.dtype(dtype)
    if dt not in _CARRIER:
        raise TypeError(f"carrier must be f32/f64, got {dt}")
    int_dtype, c_man = _CARRIER[dt]
    c_exp = 8 if c_man == 23 else 11
    finfo = np.finfo(dt)

    e = jnp.asarray(exp_bits, jnp.int32)
    m = jnp.asarray(man_bits, jnp.int32)
    sat = jnp.asarray(saturate, jnp.bool_)
    inf = jnp.asarray(ieee_inf, jnp.bool_)
    fault = jnp.asarray(fault, jnp.int32)

    bias = jnp.left_shift(1, e - 1) - 1
    max_exp = jnp.left_shift(1, e) - jnp.where(inf, 2, 1) - bias
    min_exp = 1 - bias
    m_eff = jnp.minimum(m, c_man)
    two = np.array(2.0, dt)
    max_finite = _pow2(max_exp, dt) * (
        two - _pow2(jnp.where(inf, -m_eff, 1 - m_eff), dt))
    min_normal = _pow2(min_exp, dt)
    sub_scale = _pow2(min_exp - m, dt)

    one = jnp.asarray(1, int_dtype)
    k = jnp.clip(c_man - m, 0, c_man)
    kk = k.astype(int_dtype)
    half = jnp.left_shift(one, jnp.maximum(kk - one, 0))
    keep = jnp.bitwise_not(jnp.left_shift(one, kk) - one)
    tiny = np.array(finfo.tiny, dt)
    use_sub = (e < c_exp) & (sub_scale >= tiny)
    ss = jnp.where(use_sub, sub_scale, np.array(1.0, dt))
    # exact reciprocal: ss is a power of two >= the carrier's tiny, so 1/ss
    # is finite and x * (1/ss) == x / ss bit-for-bit. Multiplying is much
    # cheaper for XLA's CPU backend to compile than the division (the
    # subnormal section dominated the swept executable's compile time).
    ssinv = jnp.where(use_sub, np.array(1.0, dt) / ss, np.array(1.0, dt))
    ovf_gate = max_finite <= np.array(finfo.max, dt)
    # overflow magnitude for the sign-carrying cases; the fn (overflow->NaN)
    # case is selected separately in apply so the stored NaN stays the
    # positive quiet-NaN constant, never a sign-flipped product
    ovf_mag = jnp.where(sat, max_finite, np.array(np.inf, dt))
    ovf_nan = ~sat & ~inf
    identity = (m >= c_man) & (e >= c_exp) & inf & ~sat
    fshift = jnp.maximum(fault - 1, 0).astype(int_dtype)
    fmask = jnp.where(fault > 0, jnp.left_shift(one, fshift),
                      jnp.asarray(0, int_dtype))
    return dict(kk=kk, half=half, keep=keep, knz=k > 0,
                use_sub=use_sub, ss=ss, ssinv=ssinv, min_normal=min_normal,
                ovf_gate=ovf_gate, max_finite=max_finite, ovf_mag=ovf_mag,
                ovf_nan=ovf_nan, identity=identity, fmask=fmask)


def apply_row_params(x, p):
    """Quantize carrier array ``x`` with precomputed row constants ``p``
    (one row of :func:`dynamic_row_params`, i.e. scalar entries), including
    the fault-channel XOR (``fmask == 0`` is an exact bit no-op)."""
    dt = jnp.dtype(x.dtype)
    int_dtype, _ = _CARRIER[dt]
    one = jnp.asarray(1, int_dtype)

    # ---- 1) normal-range mantissa RNE, traced shift amounts ----------------
    # bit k of a two's-complement int is shift-direction agnostic, so the
    # arithmetic right_shift (which broadcasts) stands in for the logical one
    bits = lax.bitcast_convert_type(x, int_dtype)
    lsb = jnp.bitwise_and(jnp.right_shift(bits, p["kk"]), one)
    rounded = jnp.bitwise_and(bits + (p["half"] - one) + lsb, p["keep"])
    y = jnp.where(p["knz"], lax.bitcast_convert_type(rounded, dt), x)

    # ---- 2) subnormal range: RNE onto the fixed-point grid -----------------
    x_sub = jnp.rint(x * p["ssinv"]) * p["ss"]
    y = jnp.where(p["use_sub"] & (jnp.abs(x) < p["min_normal"]), x_sub, y)

    # ---- 3) overflow --------------------------------------------------------
    ovf = p["ovf_gate"] & (jnp.abs(y) > p["max_finite"])
    ovf_val = jnp.where(p["ovf_nan"], np.array(np.nan, dt),
                        jnp.copysign(p["ovf_mag"], y))
    y = jnp.where(ovf, ovf_val, y)

    # ---- 4) specials + identity gate (all branches restore x) --------------
    y = jnp.where(jnp.isnan(x) | jnp.isinf(x) | p["identity"], x, y)

    # ---- 5) fault channel ---------------------------------------------------
    yb = lax.bitcast_convert_type(y, int_dtype)
    return lax.bitcast_convert_type(jnp.bitwise_xor(yb, p["fmask"]), dt)


def quantize_ref_dynamic(x, exp_bits, man_bits, saturate, ieee_inf):
    """Quantize carrier array ``x`` (f32/f64) onto the (e, m) grid where the
    format fields are *runtime* scalars (python ints or traced int32).

    Bit-for-bit identical to ``quantize_ref`` for any format whose mantissa
    fits the carrier (``man_bits <= nmant``); formats at least as fine as the
    carrier grid (and with IEEE overflow) are returned unchanged via the
    in-kernel identity gate."""
    dt = jnp.dtype(x.dtype)
    if dt not in _CARRIER:
        raise TypeError(f"carrier must be f32/f64, got {dt}")
    p = dynamic_row_params(exp_bits, man_bits, saturate, ieee_inf, dtype=dt)
    return apply_row_params(x, p)


# ---------------------------------------------------------------------------
# fused-kernel epilogue: the full runtime row, applied to a store value
# ---------------------------------------------------------------------------
#
# The producing kernels (flash_attention, rwkv6) fuse the dynamic quantize as
# an epilogue on their output stores, driven by the same (4,) int32 SMEM
# scalar-prefetch row the standalone quantize kernel uses. The epilogue must
# be bit-identical to ``ops.quantize_dynamic`` applied to the *stored* value
# (quantize the value after it has been cast to the output dtype, on an f32
# carrier), including the fault channel packed into the row's fourth field —
# so it lives here, next to the quantizer, with no repro.core dependencies
# (kernels must stay importable before repro.core finishes initializing).


def bitflip32(y, fault):
    """XOR bit ``fault - 1`` into each element's f32 bit pattern; ``fault == 0``
    is an exact no-op. The in-kernel (f32-only) twin of ``ops._bitflip``."""
    fault = jnp.asarray(fault, jnp.int32)
    shift = jnp.maximum(fault - 1, 0)
    mask = jnp.where(fault > 0,
                     jnp.left_shift(jnp.asarray(1, jnp.int32), shift),
                     jnp.asarray(0, jnp.int32))
    bits = lax.bitcast_convert_type(y, jnp.int32)
    return lax.bitcast_convert_type(jnp.bitwise_xor(bits, mask), jnp.float32)


def quantize_epilogue(y, fmt_ref):
    """Apply a runtime format row to kernel output ``y`` just before the
    store: decode ``field3 = ieee_inf | (bit_index + 1) << 1``, quantize the
    f32 carrier, XOR the armed fault bit, cast back to ``y.dtype``.

    ``fmt_ref`` is indexable as a (4,) int32 vector — an SMEM scalar-prefetch
    ref inside a Pallas kernel, or a plain array under ``interpret=True``.
    Bit-identical to ``ops.quantize_dynamic(y, row, impl='ref')`` for any
    f32-carrier ``y``; the identity row (and any clean row with fault 0)
    passes values through unchanged, so the fused kernels can always run
    with the epilogue wired in."""
    e, m, s, f3 = fmt_ref[0], fmt_ref[1], fmt_ref[2], fmt_ref[3]
    fault = jnp.right_shift(f3, 1)
    inf = jnp.bitwise_and(f3, 1)
    p = dynamic_row_params(e, m, s, inf, fault)
    return apply_row_params(y.astype(jnp.float32), p).astype(y.dtype)
