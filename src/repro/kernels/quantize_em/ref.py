"""Pure-jnp oracle for arbitrary (e, m) round-to-nearest-even quantization.

This is the TPU-native replacement for RAPTOR's MPFR emulation: instead of a
scalar correctly-rounded library call per operation, we round the *carrier*
(f32/f64) result of each op onto the representable grid of the target
``FPFormat`` with pure bit manipulation — fully vectorizable.

Semantics (validated against ml_dtypes for every hardware format in tests):
  * round-to-nearest, ties-to-even on the target grid
  * gradual underflow onto the target's subnormal grid
  * overflow -> +/-inf (IEEE layouts) / NaN (fn layouts) / +/-max_finite
    (``saturate`` formats)
  * NaN preserved, +/-inf preserved, +/-0 preserved
Known carrier-precision floor: inputs that are subnormal *in the carrier*
combined with a target whose exponent range exceeds the carrier's cannot be
re-normalized (DESIGN.md §7); irrelevant for every profiling configuration
in this repo.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp
from jax import lax

_CARRIER = {
    jnp.dtype("float32"): (jnp.int32, 23),
    jnp.dtype("float64"): (jnp.int64, 52),
}


def _format_constants(exp_bits: int, man_bits: int, ieee_inf: bool):
    bias = (1 << (exp_bits - 1)) - 1
    max_exp = (1 << exp_bits) - (2 if ieee_inf else 1) - bias
    min_exp = 1 - bias
    if ieee_inf:
        max_finite = 2.0 ** max_exp * (2.0 - 2.0 ** (-min(man_bits, 52)))
    else:
        max_finite = 2.0 ** max_exp * (2.0 - 2.0 ** (1 - min(man_bits, 52)))
    min_normal = 2.0 ** min_exp
    sub_scale = 2.0 ** (min_exp - man_bits)
    return max_exp, max_finite, min_normal, sub_scale


def quantize_ref(x, exp_bits: int, man_bits: int, saturate: bool = False,
                 ieee_inf: bool = True):
    """Quantize ``x`` (f32 or f64) to the (exp_bits, man_bits) grid, RNE.

    Returns an array of the same dtype as ``x`` whose values all lie on the
    target format's representable grid.
    """
    dt = jnp.dtype(x.dtype)
    if dt not in _CARRIER:
        raise TypeError(f"carrier must be f32/f64, got {dt}")
    int_dtype, c_man = _CARRIER[dt]
    c_exp = 8 if c_man == 23 else 11
    np_int = np.int32 if c_man == 23 else np.int64

    _, max_finite, min_normal, sub_scale = _format_constants(
        exp_bits, man_bits, ieee_inf)
    k = c_man - man_bits  # mantissa bits to drop (<=0: nothing to drop)

    # ---- 1) normal-range mantissa RNE via the bit trick --------------------
    if k > 0:
        bits = lax.bitcast_convert_type(x, int_dtype)
        one = np_int(1)
        half = np_int(1 << (k - 1))
        lsb = lax.shift_right_logical(bits, np_int(k)) & one
        rounded = (bits + (half - one) + lsb) & np_int(~((1 << k) - 1))
        y = lax.bitcast_convert_type(rounded, dt)
    else:
        y = x

    # ---- 2) subnormal range: RNE onto the fixed-point grid -----------------
    # Only needed when the target exponent range is narrower than the
    # carrier's (otherwise the carrier-aligned bit trick already lands on the
    # right subnormal grid — see tests vs ml_dtypes bf16).
    finfo = np.finfo(dt)
    if exp_bits < c_exp and sub_scale >= float(finfo.tiny):
        ss = np.array(sub_scale, dt)
        mn = np.array(min_normal, dt)
        x_sub = jnp.rint(x / ss) * ss
        y = jnp.where(jnp.abs(x) < mn, x_sub, y)

    # ---- 3) overflow --------------------------------------------------------
    if max_finite <= float(finfo.max):
        mf = np.array(max_finite, dt)
        ovf = jnp.abs(y) > mf
        if saturate:
            y = jnp.where(ovf, jnp.sign(y) * mf, y)
        elif ieee_inf:
            y = jnp.where(ovf, jnp.sign(y) * np.array(np.inf, dt), y)
        else:  # fn layout, non-saturating: overflow is NaN (ml_dtypes cast)
            y = jnp.where(ovf, np.array(np.nan, dt), y)

    # ---- 4) specials ----------------------------------------------------------
    y = jnp.where(jnp.isnan(x), x, y)
    y = jnp.where(jnp.isinf(x), x, y)  # inf passes through even when saturating
    return y


def quantize_ref_fmt(x, fmt):
    """Convenience wrapper taking an ``FPFormat``."""
    return quantize_ref(x, fmt.exp_bits, fmt.man_bits, fmt.saturate, fmt.ieee_inf)


# ---------------------------------------------------------------------------
# runtime-parameterized variant: (e, m, saturate, ieee_inf) as traced values
# ---------------------------------------------------------------------------
#
# ``quantize_ref`` above specializes the computation on the format at trace
# time (python branches, numpy constants), so every distinct format costs a
# retrace + recompile. ``quantize_ref_dynamic`` takes the format fields as
# *traced scalars*: one compiled executable serves every (e, m, saturate,
# ieee_inf), which is what collapses a precision-policy sweep to a single
# XLA compilation. All static branches become lane-wise ``where`` gates; the
# static identity fast path becomes the in-kernel ``man_bits >= carrier``
# gate. Kept free of python-level f64 branches when the carrier is f32 so
# the Pallas kernel can call it directly.


def _pow2(n, dt):
    """Exact 2**n in carrier dtype ``dt`` for traced int32 ``n``, built by
    writing the exponent field directly (bitcast) — no transcendentals, so it
    lowers inside a Pallas kernel. Saturates to 0 below the normal range and
    to +inf above it; both ends are gated off by the callers."""
    if jnp.dtype(dt) == jnp.dtype(jnp.float32):
        int_dtype, man, bias, emax = jnp.int32, 23, 127, 255
    else:
        int_dtype, man, bias, emax = jnp.int64, 52, 1023, 2047
    biased = jnp.clip(n + bias, 0, emax).astype(int_dtype)
    return lax.bitcast_convert_type(
        jnp.left_shift(biased, jnp.asarray(man, int_dtype)), jnp.dtype(dt))


def quantize_ref_dynamic(x, exp_bits, man_bits, saturate, ieee_inf):
    """Quantize carrier array ``x`` (f32/f64) onto the (e, m) grid where the
    format fields are *runtime* scalars (python ints or traced int32).

    Bit-for-bit identical to ``quantize_ref`` for any format whose mantissa
    fits the carrier (``man_bits <= nmant``); formats at least as fine as the
    carrier grid (and with IEEE overflow) are returned unchanged via the
    in-kernel identity gate."""
    dt = jnp.dtype(x.dtype)
    if dt not in _CARRIER:
        raise TypeError(f"carrier must be f32/f64, got {dt}")
    int_dtype, c_man = _CARRIER[dt]
    c_exp = 8 if c_man == 23 else 11
    finfo = np.finfo(dt)

    e = jnp.asarray(exp_bits, jnp.int32)
    m = jnp.asarray(man_bits, jnp.int32)
    sat = jnp.asarray(saturate, jnp.bool_)
    inf = jnp.asarray(ieee_inf, jnp.bool_)

    bias = jnp.left_shift(1, e - 1) - 1
    max_exp = jnp.left_shift(1, e) - jnp.where(inf, 2, 1) - bias
    min_exp = 1 - bias
    m_eff = jnp.minimum(m, c_man)
    two = np.array(2.0, dt)
    max_finite = _pow2(max_exp, dt) * (
        two - _pow2(jnp.where(inf, -m_eff, 1 - m_eff), dt))
    min_normal = _pow2(min_exp, dt)
    sub_scale = _pow2(min_exp - m, dt)

    # ---- 1) normal-range mantissa RNE, traced shift amounts ----------------
    one = jnp.asarray(1, int_dtype)
    k = jnp.clip(c_man - m, 0, c_man)
    kk = k.astype(int_dtype)
    bits = lax.bitcast_convert_type(x, int_dtype)
    half = jnp.left_shift(one, jnp.maximum(kk - one, 0))
    keep = jnp.bitwise_not(jnp.left_shift(one, kk) - one)
    # bit k of a two's-complement int is shift-direction agnostic, so the
    # arithmetic right_shift (which broadcasts) stands in for the logical one
    lsb = jnp.bitwise_and(jnp.right_shift(bits, kk), one)
    rounded = jnp.bitwise_and(bits + (half - one) + lsb, keep)
    y = jnp.where(k > 0, lax.bitcast_convert_type(rounded, dt), x)

    # ---- 2) subnormal range: RNE onto the fixed-point grid -----------------
    tiny = np.array(finfo.tiny, dt)
    use_sub = (e < c_exp) & (sub_scale >= tiny)
    ss = jnp.where(use_sub, sub_scale, np.array(1.0, dt))
    x_sub = jnp.rint(x / ss) * ss
    y = jnp.where(use_sub & (jnp.abs(x) < min_normal), x_sub, y)

    # ---- 3) overflow --------------------------------------------------------
    ovf = (max_finite <= np.array(finfo.max, dt)) & (jnp.abs(y) > max_finite)
    sgn = jnp.sign(y)
    y = jnp.where(ovf & sat, sgn * max_finite, y)
    y = jnp.where(ovf & ~sat & inf, sgn * np.array(np.inf, dt), y)
    y = jnp.where(ovf & ~sat & ~inf, np.array(np.nan, dt), y)

    # ---- 4) specials + identity gate ---------------------------------------
    y = jnp.where(jnp.isnan(x), x, y)
    y = jnp.where(jnp.isinf(x), x, y)
    identity = (m >= c_man) & (e >= c_exp) & inf & ~sat
    return jnp.where(identity, x, y)
