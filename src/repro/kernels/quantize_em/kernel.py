"""Pallas TPU kernel for arbitrary (e, m) RNE quantization.

This is the hot path of the profiling runtime: in op-mode every FP primitive
result in a truncated scope passes through this quantizer, so it must run at
VPU rate. The kernel is pure elementwise integer bit manipulation on
``(8,128)``-aligned VMEM tiles — no MXU, no transcendentals, one pass.

Target layout: input flattened to (rows, 1024) f32, grid over row-blocks,
each block (block_rows, 1024) resident in VMEM (4 MiB in + 4 MiB out at the
default block_rows=1024 — comfortably inside the ~128 MiB v5e VMEM even with
double buffering).
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.quantize_em import ref as _ref

LANES = 1024  # 8 * 128 lane multiple


def _quantize_block(x, *, exp_bits: int, man_bits: int, saturate: bool,
                    ieee_inf: bool):
    """Elementwise (e,m) RNE quantization of an f32 block (traced inside the
    kernel; mirrors ref.quantize_ref, kept separate so the kernel never
    touches code with f64 branches)."""
    bias = (1 << (exp_bits - 1)) - 1
    max_exp = (1 << exp_bits) - (2 if ieee_inf else 1) - bias
    min_exp = 1 - bias
    if ieee_inf:
        max_finite = 2.0 ** max_exp * (2.0 - 2.0 ** (-man_bits))
    else:
        max_finite = 2.0 ** max_exp * (2.0 - 2.0 ** (1 - man_bits))
    min_normal = 2.0 ** min_exp
    sub_scale = 2.0 ** (min_exp - man_bits)
    k = 23 - man_bits

    y = x
    if k > 0:
        bits = lax.bitcast_convert_type(x, jnp.int32)
        one = np.int32(1)
        half = np.int32(1 << (k - 1))
        lsb = lax.shift_right_logical(bits, np.int32(k)) & one
        rounded = (bits + (half - one) + lsb) & np.int32(~((1 << k) - 1))
        y = lax.bitcast_convert_type(rounded, jnp.float32)

    f32 = np.finfo(np.float32)
    if exp_bits < 8 and sub_scale >= float(f32.tiny):
        ss = np.float32(sub_scale)
        mn = np.float32(min_normal)
        x_sub = jnp.rint(x / ss) * ss
        y = jnp.where(jnp.abs(x) < mn, x_sub, y)

    if max_finite <= float(f32.max):
        mf = np.float32(max_finite)
        ovf = jnp.abs(y) > mf
        if saturate:
            y = jnp.where(ovf, jnp.sign(y) * mf, y)
        elif ieee_inf:
            y = jnp.where(ovf, jnp.sign(y) * np.float32(np.inf), y)
        else:
            y = jnp.where(ovf, np.float32(np.nan), y)

    y = jnp.where(jnp.isnan(x), x, y)
    y = jnp.where(jnp.isinf(x), x, y)
    return y


def _kernel(x_ref, o_ref, *, exp_bits, man_bits, saturate, ieee_inf):
    o_ref[...] = _quantize_block(
        x_ref[...], exp_bits=exp_bits, man_bits=man_bits, saturate=saturate,
        ieee_inf=ieee_inf,
    )


@functools.partial(
    jax.jit,
    static_argnames=("exp_bits", "man_bits", "saturate", "ieee_inf",
                     "block_rows", "interpret"),
)
def quantize_2d(x, *, exp_bits: int, man_bits: int, saturate: bool = False,
                ieee_inf: bool = True, block_rows: int = 1024,
                interpret: bool = False):
    """Quantize a (rows, LANES) f32 array on the (e,m) grid via pallas_call."""
    assert x.ndim == 2 and x.shape[1] == LANES, x.shape
    rows = x.shape[0]
    br = min(block_rows, rows)
    grid = (pl.cdiv(rows, br),)
    return pl.pallas_call(
        functools.partial(_kernel, exp_bits=exp_bits, man_bits=man_bits,
                          saturate=saturate, ieee_inf=ieee_inf),
        grid=grid,
        in_specs=[pl.BlockSpec((br, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x)


# ---------------------------------------------------------------------------
# runtime-parameterized kernel: format read from SMEM, not baked into the code
# ---------------------------------------------------------------------------

def _dyn_kernel(fmt_ref, x_ref, o_ref):
    """``fmt_ref`` is the scalar-prefetched (4,) int32 format vector
    (exp_bits, man_bits, saturate, ieee_inf) living in SMEM; the block math
    is the shared traced-scalar path (pure bit ops + where gates, f32 only),
    so one compiled kernel serves every format."""
    o_ref[...] = _ref.quantize_ref_dynamic(
        x_ref[...], fmt_ref[0], fmt_ref[1], fmt_ref[2], fmt_ref[3])


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def quantize_2d_dynamic(x, fmt, *, block_rows: int = 1024,
                        interpret: bool = False):
    """Quantize a (rows, LANES) f32 array onto the grid described by the
    runtime (4,) int32 vector ``fmt`` — same layout/grid as ``quantize_2d``
    but compiled once for all formats."""
    assert x.ndim == 2 and x.shape[1] == LANES, x.shape
    rows = x.shape[0]
    br = min(block_rows, rows)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(pl.cdiv(rows, br),),
        in_specs=[pl.BlockSpec((br, LANES), lambda i, fmt_ref: (i, 0))],
        out_specs=pl.BlockSpec((br, LANES), lambda i, fmt_ref: (i, 0)),
    )
    return pl.pallas_call(
        _dyn_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(jnp.asarray(fmt, jnp.int32), x)
