"""Public jit'd quantization op with implementation dispatch.

``impl``:
  * ``'auto'``      — pallas on TPU, pure-jnp ref elsewhere (CPU dry-runs must
                      not lower pallas kernels; see DESIGN.md §4)
  * ``'ref'``       — pure-jnp oracle
  * ``'pallas'``    — compiled pallas kernel (TPU)
  * ``'interpret'`` — pallas kernel in interpret mode (CPU validation)

Fast paths (RAPTOR's zero-overhead hardware mode): when (e,m) matches a
hardware storage type and overflow semantics agree, emit a plain convert
pair instead of the bit-math.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.formats import FPFormat, parse_format
from repro.kernels.quantize_em import kernel as _kernel
from repro.kernels.quantize_em import ref as _ref

_HW_DTYPES = {(8, 7): jnp.bfloat16, (5, 10): jnp.float16}

# Runtime format vectors: (exp_bits, man_bits, saturate, ieee_inf) as int32.
# IDENTITY_ROW is at least as fine as any carrier grid and IEEE, so the
# dynamic quantizer's in-kernel identity gate passes values through
# unchanged — the runtime analogue of the static identity fast path.
IDENTITY_ROW = np.array([11, 52, 0, 1], np.int32)


def format_row(fmt) -> np.ndarray:
    """Lower an ``FPFormat`` (or spec string) to its (4,) int32 runtime row."""
    fmt = parse_format(fmt)
    return np.array([fmt.exp_bits, fmt.man_bits, int(fmt.saturate),
                     int(fmt.ieee_inf)], np.int32)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def quantize(x, fmt, *, impl: str = "auto"):
    """Round every element of float array ``x`` onto the (e,m) grid of ``fmt``.

    Non-float inputs pass through unchanged. The result dtype equals the
    input dtype (values merely lie on the coarser grid) — op-mode semantics.
    """
    fmt: FPFormat = parse_format(fmt)
    dt = jnp.dtype(x.dtype) if hasattr(x, "dtype") else None
    if dt is None or not jnp.issubdtype(dt, jnp.floating):
        return x

    # identity: target grid at least as fine as the storage grid. Derived
    # from finfo so any float dtype works (float8_*, future formats) instead
    # of KeyError-ing outside a hardcoded table.
    finfo = jnp.finfo(dt)
    storage_bits = finfo.nmant
    storage_exp = finfo.bits - 1 - finfo.nmant
    if (fmt.man_bits >= storage_bits and fmt.exp_bits >= storage_exp
            and not fmt.saturate and fmt.ieee_inf):
        return x

    # hardware convert-pair fast path
    hw = _HW_DTYPES.get((fmt.exp_bits, fmt.man_bits))
    if hw is not None and not fmt.saturate and fmt.ieee_inf:
        return x.astype(hw).astype(dt)

    # carrier selection: f64 stays f64 (CPU), everything else goes via f32
    if dt == jnp.dtype(jnp.float64):
        return _ref.quantize_ref_fmt(x, fmt)

    xf = x.astype(jnp.float32)
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"

    if impl == "ref":
        y = _ref.quantize_ref_fmt(xf, fmt)
    elif impl in ("pallas", "interpret"):
        y = _pallas_any_shape(xf, fmt, interpret=(impl == "interpret"))
    else:
        raise ValueError(f"unknown impl {impl!r}")
    return y.astype(dt)


def _bitflip(y, fault):
    """XOR bit ``fault - 1`` into every element's carrier bit pattern.

    ``fault == 0`` is an exact no-op (the XOR mask is zero), so unarmed rows
    are bit-identical to a quantizer without the fault channel. The bit
    index addresses the carrier layout: f32 for <=32-bit floats (31 = sign,
    30 = top exponent bit), f64 for f64 inputs."""
    itype = jnp.int64 if y.dtype == jnp.dtype(jnp.float64) else jnp.int32
    shift = jnp.maximum(fault - 1, 0).astype(itype)
    mask = jnp.where(fault > 0,
                     jnp.left_shift(jnp.asarray(1, itype), shift),
                     jnp.asarray(0, itype))
    bits = jax.lax.bitcast_convert_type(y, itype)
    return jax.lax.bitcast_convert_type(bits ^ mask, y.dtype)


def quantize_dynamic(x, fmt, *, impl: str = "auto"):
    """Runtime-parameterized ``quantize``: ``fmt`` is a (4,) int32 array
    (exp_bits, man_bits, saturate, ieee_inf) whose values are *runtime* data
    — python ints, concrete arrays, or tracers (e.g. a row of a vmapped
    format table).

    One compiled executable serves every format: the static identity and
    hardware-convert fast paths are replaced by the quantizer's in-kernel
    ``man_bits >= carrier`` identity gate, so sweeping formats never
    retraces or recompiles. Bit-for-bit identical to the static entry point
    for every format with ``man_bits <= 23`` on f32 carriers (``<= 52`` on
    f64) — see tests/test_quantize_dynamic.py. Non-float inputs pass
    through; the result dtype equals the input dtype.

    **Fault channel** (``repro.guardrails.faults``): the high bits of the
    row's fourth field carry an optional bit-flip fault,
    ``field3 = ieee_inf | (bit_index + 1) << 1``. The channel is decoded and
    stripped here — the quantizer impls always see a clean {0, 1} flag —
    then the chosen carrier bit is XORed into every (already quantized)
    element. A clean row (field3 in {0, 1}) decodes to fault 0 and the XOR
    is an exact no-op, so arming or disarming a fault is a table *value*
    change on the same compiled executable: zero recompiles."""
    dt = jnp.dtype(x.dtype) if hasattr(x, "dtype") else None
    if dt is None or not jnp.issubdtype(dt, jnp.floating):
        return x
    fmt = jnp.asarray(fmt, jnp.int32)
    # scalar unpack, no scatter: the old `fmt.at[3].set(fmt[3] & 1)` strip
    # emitted one (batched, under vmap) scatter per truncation site, which
    # dominated trace+compile time on table sweeps (hundreds of sites per
    # program — the batched sweep's first call regressed below the static
    # path on exactly this).
    e, m, s, f3 = fmt[0], fmt[1], fmt[2], fmt[3]
    fault = jnp.right_shift(f3, 1)
    inf = jnp.bitwise_and(f3, 1)

    # carrier selection mirrors the static path: f64 stays f64, rest via f32
    if dt == jnp.dtype(jnp.float64):
        p = _ref.dynamic_row_params(e, m, s, inf, fault, jnp.float64)
        return _ref.apply_row_params(x, p)

    xf = x.astype(jnp.float32)
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"

    if impl == "ref":
        p = _ref.dynamic_row_params(e, m, s, inf, fault)
        return _ref.apply_row_params(xf, p).astype(dt)
    if impl in ("pallas", "interpret"):
        y = _pallas_any_shape_dynamic(xf, jnp.stack([e, m, s, inf]),
                                      interpret=(impl == "interpret"))
        return _bitflip(y, fault).astype(dt)
    raise ValueError(f"unknown impl {impl!r}")


# --------------------------------------------------------------------------
# prepared-table path: derive row constants once, apply cheaply per site
# --------------------------------------------------------------------------
#
# A runtime-table sweep quantizes hundreds of sites per program, and the
# dynamic quantizer spends about as many graph ops deriving constants from
# the format fields (bias, bounds, masks — ~30 scalar ops) as it does on
# the array math. Inlining that derivation at every site made the swept
# executable's graph several times the static transform's and pushed its
# one-off XLA compile above SIX static compiles (the "first call slower
# than static" regression). ``prepare_dynamic`` derives the constants for
# the WHOLE table in one vectorized block; each site then slices its row
# and runs only the array-side math, jit-wrapped so tracing is paid once
# per distinct operand shape instead of once per site.

@jax.jit
def _apply_row(x, prep, site):
    """jit-shared slice + apply: the row index is a *traced* scalar, so one
    trace (and one compiled subgraph) serves every site with ``x``'s shape —
    per-site trace cost collapses from the whole quantizer to one call."""
    return _ref.apply_row_params(x, {k: v[site] for k, v in prep.items()})


def prepare_dynamic(table, dtype=jnp.float32):
    """Vectorized derived constants for every row of a ``(num_sites, 4)``
    format table (fault channel included): one dict of ``(num_sites,)``
    arrays consumed by :func:`quantize_prepared`."""
    t = jnp.asarray(table, jnp.int32)
    e, m, s, f3 = t[..., 0], t[..., 1], t[..., 2], t[..., 3]
    return _ref.dynamic_row_params(e, m, s, jnp.bitwise_and(f3, 1),
                                   jnp.right_shift(f3, 1), dtype)


def quantize_prepared(x, prep, site: int):
    """Quantize ``x`` onto row ``site`` of a prepared table — bit-identical
    to ``quantize_dynamic(x, table[site], impl='ref')``. ``prep`` must have
    been built for ``x``'s carrier (f32 for everything but f64 inputs)."""
    dt = jnp.dtype(x.dtype) if hasattr(x, "dtype") else None
    if dt is None or not jnp.issubdtype(dt, jnp.floating):
        return x
    if dt == jnp.dtype(jnp.float64):
        return _apply_row(x, prep, site)
    return _apply_row(x.astype(jnp.float32), prep, site).astype(dt)


def _to_rows(xf):
    """Flatten/pad an f32 array to (rows, LANES); no copy when lane-aligned."""
    lanes = _kernel.LANES
    n = xf.size
    rows = -(-n // lanes)
    pad = rows * lanes - n
    flat = jnp.ravel(xf)
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(rows, lanes), n, pad


def _from_rows(y2d, shape, n, pad):
    out = jnp.ravel(y2d)
    if pad:
        out = out[:n]
    return out.reshape(shape)


def _pallas_any_shape(xf, fmt: FPFormat, *, interpret: bool):
    """Flatten/pad to (rows, LANES), run the kernel, restore the shape."""
    if xf.size == 0:
        return xf
    x2d, n, pad = _to_rows(xf)
    y2d = _kernel.quantize_2d(
        x2d,
        exp_bits=fmt.exp_bits, man_bits=fmt.man_bits, saturate=fmt.saturate,
        ieee_inf=fmt.ieee_inf, interpret=interpret,
    )
    return _from_rows(y2d, xf.shape, n, pad)


def _pallas_any_shape_dynamic(xf, fmt, *, interpret: bool):
    if xf.size == 0:
        return xf
    x2d, n, pad = _to_rows(xf)
    y2d = _kernel.quantize_2d_dynamic(x2d, fmt, interpret=interpret)
    return _from_rows(y2d, xf.shape, n, pad)
