"""Public jit'd quantization op with implementation dispatch.

``impl``:
  * ``'auto'``      — pallas on TPU, pure-jnp ref elsewhere (CPU dry-runs must
                      not lower pallas kernels; see DESIGN.md §4)
  * ``'ref'``       — pure-jnp oracle
  * ``'pallas'``    — compiled pallas kernel (TPU)
  * ``'interpret'`` — pallas kernel in interpret mode (CPU validation)

Fast paths (RAPTOR's zero-overhead hardware mode): when (e,m) matches a
hardware storage type and overflow semantics agree, emit a plain convert
pair instead of the bit-math.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.formats import FPFormat, parse_format
from repro.kernels.quantize_em import kernel as _kernel
from repro.kernels.quantize_em import ref as _ref

_HW_DTYPES = {(8, 7): jnp.bfloat16, (5, 10): jnp.float16}


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def quantize(x, fmt, *, impl: str = "auto"):
    """Round every element of float array ``x`` onto the (e,m) grid of ``fmt``.

    Non-float inputs pass through unchanged. The result dtype equals the
    input dtype (values merely lie on the coarser grid) — op-mode semantics.
    """
    fmt: FPFormat = parse_format(fmt)
    dt = jnp.dtype(x.dtype) if hasattr(x, "dtype") else None
    if dt is None or not jnp.issubdtype(dt, jnp.floating):
        return x

    # identity: target grid at least as fine as the storage grid. Derived
    # from finfo so any float dtype works (float8_*, future formats) instead
    # of KeyError-ing outside a hardcoded table.
    finfo = jnp.finfo(dt)
    storage_bits = finfo.nmant
    storage_exp = finfo.bits - 1 - finfo.nmant
    if (fmt.man_bits >= storage_bits and fmt.exp_bits >= storage_exp
            and not fmt.saturate and fmt.ieee_inf):
        return x

    # hardware convert-pair fast path
    hw = _HW_DTYPES.get((fmt.exp_bits, fmt.man_bits))
    if hw is not None and not fmt.saturate and fmt.ieee_inf:
        return x.astype(hw).astype(dt)

    # carrier selection: f64 stays f64 (CPU), everything else goes via f32
    if dt == jnp.dtype(jnp.float64):
        return _ref.quantize_ref_fmt(x, fmt)

    xf = x.astype(jnp.float32)
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "ref"

    if impl == "ref":
        y = _ref.quantize_ref_fmt(xf, fmt)
    elif impl in ("pallas", "interpret"):
        y = _pallas_any_shape(xf, fmt, interpret=(impl == "interpret"))
    else:
        raise ValueError(f"unknown impl {impl!r}")
    return y.astype(dt)


def _pallas_any_shape(xf, fmt: FPFormat, *, interpret: bool):
    """Flatten/pad to (rows, LANES), run the kernel, restore the shape."""
    lanes = _kernel.LANES
    n = xf.size
    if n == 0:
        return xf
    rows = -(-n // lanes)
    pad = rows * lanes - n
    flat = jnp.ravel(xf)
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    y2d = _kernel.quantize_2d(
        flat.reshape(rows, lanes),
        exp_bits=fmt.exp_bits, man_bits=fmt.man_bits, saturate=fmt.saturate,
        ieee_inf=fmt.ieee_inf, interpret=interpret,
    )
    out = jnp.ravel(y2d)
    if pad:
        out = out[:n]
    return out.reshape(xf.shape)
