"""Recognition of fused-epilogue Pallas kernels inside traced jaxprs.

The flash_attention / rwkv6 kernels optionally take a (4,) int32 runtime
format row as an SMEM scalar-prefetch operand and apply the dynamic
quantize as an in-kernel epilogue on their output stores (see
``quantize_em.ref.quantize_epilogue``). When the interpreter's table/policy
transform meets such a ``pallas_call`` equation it can *route* the site's
format row into the existing epilogue — substituting the prefetch operand —
instead of appending a separate quantize kernel after it: a found policy
then executes as one fused kernel per site.

Routing is sound because the epilogue is bit-identical to
``ops.quantize_dynamic`` applied to the stored value, and model code wires
the hook with ``IDENTITY_ROW`` (an exact passthrough), so replacing the row
is exactly "quantize this site's output" with zero extra kernels. The
contract is that the call-site row is the *default* for an untruncated
site; the policy row replaces it.

Kept free of any ``repro.core`` import so both the interpreter and the
kernel modules can use it while ``repro.core`` is still initializing.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

# kernel-name marker -> output indices covered by the in-kernel epilogue
# (other outputs — e.g. rwkv6's recurrence state sT — are ordinary sites
# and keep the separate quantize pass)
FUSED_KERNELS = {
    "_attn_kernel": (0,),
    "_wkv_kernel": (0,),
}

_ROW_SHAPE = (4,)


def fused_outputs(eqn) -> Optional[Tuple[int, ...]]:
    """Output indices covered by a fused quantize epilogue, or ``None``.

    Recognition is structural: a ``pallas_call`` whose grid mapping
    prefetches exactly one scalar operand, whose first operand is a (4,)
    int32 row, and whose kernel is one of the known epilogue-bearing
    kernels (by ``name_and_src_info``)."""
    if eqn.primitive.name != "pallas_call":
        return None
    gm = eqn.params.get("grid_mapping")
    if gm is None or getattr(gm, "num_index_operands", None) != 1:
        return None
    aval = eqn.invars[0].aval
    if (getattr(aval, "shape", None) != _ROW_SHAPE
            or getattr(aval, "dtype", None) != np.dtype(np.int32)):
        return None
    info = eqn.params.get("name_and_src_info")
    kname = getattr(info, "name", None) or str(info)
    for marker, outs in FUSED_KERNELS.items():
        if marker in kname:
            return outs
    return None
