"""Serving: continuous batching + sampled shadow profiling of live traffic."""
from repro.serving.engine import Engine, Request
from repro.serving.shadow import DriftEvent, ShadowConfig, ShadowProfiler

__all__ = ["Engine", "Request", "ShadowConfig", "ShadowProfiler",
           "DriftEvent"]
