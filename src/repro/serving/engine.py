"""Continuous-batching serving engine with sampled shadow profiling.

A compact production shape: fixed-size decode batch, slot-based request
table, per-slot position cursors in the cache (``cache["pos"]`` is (B,)),
so a new request prefills into any free slot *while other slots keep
decoding* — no all-slots-free barrier, no equal-prompt-length waves.
Quarantined slots are immediately reusable (admission zeroes exactly that
slot's cache lanes). Every tick is one call of a single jit'd decode step
whose signature never changes; :meth:`Engine.assert_zero_recompile` checks
the executable cache stays at one entry, the same discipline as the
guarded trainer.

Shadow profiling rides on top: a sampled fraction of requests decode
through the ``memtrace``-shadowed step against the deployed policy (see
:mod:`repro.serving.shadow`) — the served tokens stay bit-identical, the
paired lane feeds per-request and rolling RaptorReports, and drift against
the deployed artifact's accepted error budget pages a re-search hook.
"""
from __future__ import annotations

import dataclasses
import warnings
from collections import deque
from typing import Dict, Iterator, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.api import truncate
from repro.core.policy import resolve_policy
from repro.models import Model
from repro.serving.shadow import ShadowConfig, ShadowProfiler


@dataclasses.dataclass
class Request:
    """The handle :meth:`Engine.submit` returns; fields fill in as the
    request moves through the batch. ``report`` is the merged per-request
    RaptorReport when the request was shadow-sampled."""

    rid: int
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int = 32
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    status: str = "ok"              # "ok" | "error_nonfinite"
    error: str = ""
    shadowed: bool = False
    report: Optional[object] = None  # merged RaptorReport (shadowed only)
    _fed: int = 0                    # prompt tokens already fed (prefill cursor)


class Engine:
    """``policy`` deploys the engine under a RAPTOR truncation policy —
    anything :func:`repro.core.policy.resolve_policy` accepts: a
    :class:`~repro.core.TruncationPolicy`, a flag string, a
    :class:`~repro.artifacts.PolicyArtifact`, or a registry ref like
    ``"bench_model@v3"``. The decode step is truncated once at
    construction; serving under an artifact is bit-identical to serving
    under its in-process policy because the artifact's JSON round trip is
    lossless.

    ``shadow`` (a :class:`~repro.serving.shadow.ShadowConfig`) enables
    sampled shadow profiling of live requests; the engine then exposes
    ``serving_report`` (rolling merged RaptorReport), ``drift_events``,
    and threads fired drift detections into ``self.artifact`` provenance.
    """

    def __init__(self, model: Model, params, batch_size: int = 8,
                 max_seq_len: int = 512, greedy: bool = True, policy=None,
                 shadow: Optional[ShadowConfig] = None, registry=None):
        self.model = model
        self.params = params
        self.B = batch_size
        self.S = max_seq_len
        self.greedy = greedy
        res = resolve_policy(policy, registry=registry)
        self.policy = res.policy
        self.artifact = res.artifact
        self.cache = model.init_cache(batch_size, max_seq_len)
        self.slots: List[Optional[Request]] = [None] * batch_size
        self.lengths = np.zeros(batch_size, np.int32)
        raw_step = model.decode_step
        step = raw_step
        if self.policy is not None:
            step = truncate(step, self.policy)
        # per-engine closures: jit caches key on the callable's identity, so
        # wrapping shared functions (the staticmethod reset, bound decode
        # methods) would alias executable caches across engines and break
        # the one-entry-per-engine assertion
        # settle steady-state layouts BEFORE counting executables: under a
        # serving mesh the first decode re-shards the cache, so a cache
        # whose layout changes between the first and second call would
        # retrace every jit'd path. One warmup decode through a throwaway
        # jit wrapper, then re-zero the warmed cache inside jit (keeps the
        # layout decode settled on) — the real paths below only ever see
        # steady-state shardings and stay at one executable each.
        _, warmed = jax.jit(lambda p, c, t, _fn=step: _fn(p, c, t))(
            params, self.cache, jnp.zeros((batch_size,), jnp.int32))
        self.cache = jax.tree_util.tree_map(
            lambda t: jax.device_put(jnp.zeros(t.shape, t.dtype),
                                     t.sharding), warmed)
        self._decode = jax.jit(lambda p, c, t, _fn=step: _fn(p, c, t))
        self._reset = jax.jit(lambda c, s, _fn=self._slot_reset: _fn(c, s))
        self._shadow: Optional[ShadowProfiler] = None
        if shadow is not None:
            self._shadow = ShadowProfiler(raw_step, self.policy, shadow,
                                          artifact=self.artifact)
        self._queue: deque = deque()
        self._done: Dict[int, Request] = {}
        self._finished: deque = deque()
        self._next_rid = 0
        self._tick = 0

    # ---- request management ------------------------------------------------
    def submit(self, prompt=None, _legacy_prompt=None, *,
               max_new_tokens: int = 32, rid: Optional[int] = None
               ) -> Request:
        """Queue a request; returns its :class:`Request` handle. Request ids
        are auto-assigned; passing one explicitly (or the legacy positional
        ``submit(rid, prompt, ...)`` form) still works but is deprecated."""
        if _legacy_prompt is not None:
            # legacy positional form: submit(rid, prompt, max_new_tokens=...)
            warnings.warn(
                "Engine.submit(rid, prompt) is deprecated; call "
                "submit(prompt) and use the returned Request handle "
                "(explicit ids: submit(prompt, rid=...))",
                DeprecationWarning, stacklevel=2)
            rid, prompt = int(prompt), _legacy_prompt
        if rid is None:
            rid = self._next_rid
        prompt = np.asarray(prompt, np.int32)
        # validate HERE, not deep inside admission: a prompt that can never
        # fit the fixed cache must be rejected at the API boundary with a
        # clear error instead of silently running a slot cursor past
        # max_seq_len requests later.
        if prompt.ndim != 1 or prompt.size == 0:
            raise ValueError(
                f"request {rid}: prompt must be a non-empty 1-D token "
                f"array, got shape {prompt.shape}")
        if prompt.size > self.S - 1:
            raise ValueError(
                f"request {rid}: prompt of {prompt.size} tokens does not "
                f"fit max_seq_len={self.S} (at most {self.S - 1} prompt "
                "tokens leave room to decode at least one token)")
        if max_new_tokens < 1:
            raise ValueError(
                f"request {rid}: max_new_tokens must be >= 1, "
                f"got {max_new_tokens}")
        req = Request(rid, prompt, max_new_tokens)
        if self._shadow is not None:
            req.shadowed = self._shadow.sample()
        self._next_rid = max(self._next_rid, rid + 1)
        self._queue.append(req)
        return req

    @staticmethod
    def _slot_reset(cache, slot):
        """Zero exactly one batch lane of every cache leaf (jit'd once; the
        slot index is a traced scalar so admission never retraces). Stacked
        ``layers`` / encdec cross leaves carry batch at axis 1, everything
        else (pos, lead, global, recurrent states) at axis 0."""
        def zero_lane(axis):
            def fn(t):
                lane = jax.lax.broadcasted_iota(jnp.int32, t.shape, axis)
                return jnp.where(lane == slot, jnp.zeros_like(t), t)
            return fn
        out = {}
        for key, sub in cache.items():
            axis = 1 if key in ("layers", "cross_k", "cross_v") else 0
            out[key] = jax.tree_util.tree_map(zero_lane(axis), sub)
        return out

    def _admit(self):
        """Admit queued requests into free slots — continuously: any free
        (including just-quarantined) slot takes the next request while the
        other slots keep decoding. The slot's cache lanes are zeroed so the
        new request starts from a fresh cursor."""
        free = [s for s in range(self.B) if self.slots[s] is None]
        for s in free:
            if not self._queue:
                break
            req = self._queue.popleft()
            self.cache = self._reset(self.cache, jnp.int32(s))
            self.slots[s] = req
            self.lengths[s] = 0
            req._fed = 0

    def _finish(self, slot: int, req: Request):
        req.done = True
        self._done[req.rid] = req
        self._finished.append(req)
        self.slots[slot] = None
        self.lengths[slot] = 0

    # ---- decode loop -------------------------------------------------------
    def step(self) -> bool:
        """One tick: admit into free slots, then one token of work for every
        live slot — prompt tokens for slots still prefilling, the previous
        output token for decoding slots — through a single batched decode
        call. A slot emits its next output token on the tick that feeds its
        final prompt token (masked prefill and decode interleave freely)."""
        self._admit()
        live = [s for s in range(self.B) if self.slots[s] is not None]
        if not live:
            return False
        tok = np.zeros((self.B,), np.int32)
        emitting = []
        for s in live:
            req = self.slots[s]
            if req._fed < len(req.prompt):
                tok[s] = req.prompt[req._fed]
                if req._fed == len(req.prompt) - 1:
                    emitting.append(s)
            else:
                tok[s] = req.out_tokens[-1]
                emitting.append(s)

        shadow_live = [s for s in live if self.slots[s].shadowed]
        if self._shadow is not None and shadow_live:
            logits, self.cache, report = self._shadow.step(
                self.params, self.cache, jnp.asarray(tok))
            self._shadow.observe(report,
                                 [self.slots[s] for s in shadow_live],
                                 self._tick)
            event = self._shadow.check(self._tick)
            if event is not None and self.artifact is not None:
                self.artifact = self._shadow.log.attach(self.artifact)
        else:
            logits, self.cache = self._decode(self.params, self.cache,
                                              jnp.asarray(tok))
        self.assert_zero_recompile()

        for s in live:
            req = self.slots[s]
            if req._fed < len(req.prompt):
                req._fed += 1
            self.lengths[s] += 1

        logits_np = np.asarray(logits)
        nxt = np.argmax(logits_np, axis=-1)
        # quarantine non-finite decode: a slot whose logits went NaN/Inf
        # (numerically broken policy, corrupted params) fails THAT request
        # with a clear status and frees the slot for the next admission — an
        # argmax over NaN logits would otherwise silently emit token 0 and
        # poison the stream
        finite = np.isfinite(logits_np).all(axis=-1)
        for s in emitting:
            req = self.slots[s]
            if not finite[s]:
                req.status = "error_nonfinite"
                req.error = (f"non-finite logits while decoding token "
                             f"{len(req.out_tokens) + 1} (slot {s}); "
                             "request quarantined")
                self._finish(s, req)
                continue
            req.out_tokens.append(int(nxt[s]))
            if (len(req.out_tokens) >= req.max_new_tokens
                    or self.lengths[s] >= self.S - 1):
                self._finish(s, req)
        self._tick += 1
        return True

    def run(self) -> Dict[int, Request]:
        while self._queue or any(s is not None for s in self.slots):
            self.step()
        return self._done

    def stream(self) -> Iterator[Request]:
        """Yield requests as they finish (completion order), instead of
        polling :meth:`run`'s dict."""
        while self._queue or any(s is not None for s in self.slots):
            self.step()
            while self._finished:
                yield self._finished.popleft()

    # ---- zero-recompile discipline ----------------------------------------
    def cache_sizes(self) -> Dict[str, Optional[int]]:
        """Executable-cache entry counts for every jit'd serving path
        (None before first use / where the runtime doesn't expose it)."""
        def size(fn):
            f = getattr(fn, "_cache_size", None)
            if f is None:
                return None
            n = int(f())
            return n if n else None
        out = {"decode": size(self._decode), "reset": size(self._reset)}
        if self._shadow is not None:
            n = self._shadow.cache_size()
            out["shadow"] = n if n else None
        return out

    def assert_zero_recompile(self):
        """The serving invariant: every jit'd path traced exactly once.
        Per-slot cursors keep the decode signature static across ragged
        admission, so any growth here is a bug (same check as the guarded
        trainer's)."""
        for name, n in self.cache_sizes().items():
            if n is not None and n > 1:
                raise AssertionError(
                    f"serving {name} step retraced: {n} executable cache "
                    "entries (expected 1) — the decode signature must not "
                    "depend on admission state")

    # ---- shadow-profiling surface ------------------------------------------
    @property
    def serving_report(self):
        """Rolling serving-side RaptorReport merged over every shadowed
        tick (None when shadow profiling is off / nothing sampled yet)."""
        return None if self._shadow is None else self._shadow.report

    @property
    def drift_events(self):
        return [] if self._shadow is None else list(self._shadow.events)

    @property
    def guardrail_log(self):
        return None if self._shadow is None else self._shadow.log
