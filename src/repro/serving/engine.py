"""Batched serving engine: continuous-batching prefill + decode.

A deliberately compact production shape: fixed-size decode batch, slot-based
request table, prefill admits new requests into free slots, one jit'd
decode_step per token across the whole batch. Cache memory is allocated
once (max_seq_len) — the decode dry-run cells measure exactly this step.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.api import truncate
from repro.models import Model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int = 32
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    status: str = "ok"              # "ok" | "error_nonfinite"
    error: str = ""


class Engine:
    """``policy`` deploys the engine under a RAPTOR truncation policy: a
    :class:`~repro.core.TruncationPolicy` or a
    :class:`~repro.artifacts.PolicyArtifact` (the registry-loaded product of
    a profiling run — ``Registry(root).load("bench_model@v3")``). The decode
    step is truncated once at construction; serving under an artifact is
    bit-identical to serving under its in-process policy because the
    artifact's JSON round trip is lossless."""

    def __init__(self, model: Model, params, batch_size: int = 8,
                 max_seq_len: int = 512, greedy: bool = True, policy=None):
        self.model = model
        self.params = params
        self.B = batch_size
        self.S = max_seq_len
        self.greedy = greedy
        self.policy = getattr(policy, "policy", policy)  # artifact -> policy
        self.cache = model.init_cache(batch_size, max_seq_len)
        self.slots: List[Optional[Request]] = [None] * batch_size
        self.lengths = np.zeros(batch_size, np.int32)
        step = model.decode_step
        if self.policy is not None:
            step = truncate(step, self.policy)
        self._decode = jax.jit(step)
        self._queue: List[Request] = []
        self._done: Dict[int, Request] = {}

    # ---- request management ------------------------------------------------
    def submit(self, rid: int, prompt: np.ndarray, max_new_tokens: int = 32):
        prompt = np.asarray(prompt, np.int32)
        # validate HERE, not deep inside _admit: a prompt that can never fit
        # the fixed cache must be rejected at the API boundary with a clear
        # error instead of tripping an admission assert (or silently running
        # the cache cursor past max_seq_len) requests later.
        if prompt.ndim != 1 or prompt.size == 0:
            raise ValueError(
                f"request {rid}: prompt must be a non-empty 1-D token "
                f"array, got shape {prompt.shape}")
        if prompt.size > self.S - 1:
            raise ValueError(
                f"request {rid}: prompt of {prompt.size} tokens does not "
                f"fit max_seq_len={self.S} (at most {self.S - 1} prompt "
                "tokens leave room to decode at least one token)")
        if max_new_tokens < 1:
            raise ValueError(
                f"request {rid}: max_new_tokens must be >= 1, "
                f"got {max_new_tokens}")
        self._queue.append(Request(rid, prompt, max_new_tokens))

    def _admit(self):
        """Admit a wave of queued requests into free slots. The cache keeps a
        single shared position cursor (aligned batching), so a wave is only
        admitted when all slots are free and prompts share one length —
        left-padding / per-slot cursors are future work, documented here."""
        if any(s is not None for s in self.slots) or not self._queue:
            return
        wave = self._queue[:self.B]
        self._queue = self._queue[self.B:]
        plen = len(wave[0].prompt)
        assert all(len(r.prompt) == plen for r in wave), \
            "aligned batching requires equal prompt lengths per wave"
        self.cache = self.model.init_cache(self.B, self.S)
        for slot, req in enumerate(wave):
            self.slots[slot] = req
        # batched prefill: column t of every prompt at once
        for t in range(plen):
            tok = np.zeros((self.B,), np.int32)
            for slot, req in enumerate(wave):
                tok[slot] = req.prompt[t]
            _, self.cache = self._decode(self.params, self.cache,
                                         jnp.asarray(tok))
        for slot, req in enumerate(wave):
            self.lengths[slot] = plen

    # ---- decode loop ----------------------------------------------------------
    def step(self):
        """One token for every live slot."""
        self._admit()
        live = [s for s in range(self.B) if self.slots[s] is not None]
        if not live:
            return False
        tok = np.zeros((self.B,), np.int32)
        for s in live:
            req = self.slots[s]
            tok[s] = (req.out_tokens[-1] if req.out_tokens
                      else req.prompt[-1])
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(tok))
        logits_np = np.asarray(logits)
        nxt = np.argmax(logits_np, axis=-1)
        # quarantine non-finite decode: a slot whose logits went NaN/Inf
        # (numerically broken policy, corrupted params) fails THAT request
        # with a clear status and frees the slot — an argmax over NaN logits
        # would otherwise silently emit token 0 and poison the stream
        finite = np.isfinite(logits_np).all(axis=-1)
        for s in live:
            req = self.slots[s]
            if not finite[s]:
                req.done = True
                req.status = "error_nonfinite"
                req.error = (f"non-finite logits while decoding token "
                             f"{len(req.out_tokens) + 1} (slot {s}); "
                             "request quarantined")
                self._done[req.rid] = req
                self.slots[s] = None
                self.lengths[s] = 0
        live = [s for s in live if self.slots[s] is not None]
        for s in live:
            req = self.slots[s]
            req.out_tokens.append(int(nxt[s]))
            self.lengths[s] += 1
            if (len(req.out_tokens) >= req.max_new_tokens
                    or self.lengths[s] >= self.S - 1):
                req.done = True
                self._done[req.rid] = req
                self.slots[s] = None
                self.lengths[s] = 0
        return True

    def run(self) -> Dict[int, Request]:
        while self._queue or any(s is not None for s in self.slots):
            self.step()
        return self._done
