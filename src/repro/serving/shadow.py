"""Sampled shadow profiling + drift detection for the serving engine.

RAPTOR's pitch is profiling the code you actually run. Serving-side that
means: a configurable fraction of live requests decode through the
``memtrace``/``profile_trajectory`` shadowed step against the *deployed*
policy (outputs stay the truncated lane, so shadowed requests serve
bit-identical tokens), their per-tick :class:`~repro.core.RaptorReport`\\ s
merge into per-request reports and one rolling serving-side report, and a
drift detector compares the rolling blame against the error level the
deployed :class:`~repro.artifacts.PolicyArtifact` was accepted at. When
live traffic exceeds that budget by ``drift_margin``, the detector fires a
re-search hook and records the event — reusing the guardrail
:class:`~repro.guardrails.GuardrailLog` shapes — into artifact provenance.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.api import memtrace, profile_trajectory
from repro.guardrails.log import GuardrailLog


@dataclasses.dataclass(frozen=True)
class ShadowConfig:
    """Knobs for serving-side shadow profiling.

    ``rate``: fraction of submitted requests sampled into shadow mode (every
    decode tick with at least one live shadowed slot runs the paired step).
    ``mode``: ``"memtrace"`` (whole-step report) or ``"trajectory"``
    (per-scan-step error trajectories; ~2.5x memtrace cost).
    ``drift_budget``: accepted error level; defaults to the deployed
    artifact's recorded ``provenance["threshold"]`` (the level its oracle
    verdict was accepted at), falling back to ``threshold``.
    ``drift_margin``: fire when the rolling report's worst relative
    deviation exceeds ``drift_margin * budget``.
    ``min_shadow_ticks``: don't judge drift before this many shadowed steps
    (a single early tick is too noisy to page a re-search on).
    ``on_drift``: the re-search hook — called once with a
    :class:`DriftEvent`; the detector latches after firing.
    """

    rate: float = 0.0625
    threshold: float = 1e-3
    mode: str = "memtrace"
    n_steps: int = 32
    seed: int = 0
    drift_budget: Optional[float] = None
    drift_margin: float = 4.0
    min_shadow_ticks: int = 8
    on_drift: Optional[Callable[["DriftEvent"], None]] = None


@dataclasses.dataclass(frozen=True)
class DriftEvent:
    """One fired drift detection: what drifted, by how much, vs what budget."""

    tick: int
    peak: float                       # worst max_rel in the rolling report
    budget: float                     # accepted error level being enforced
    blame: Tuple[Tuple[str, int, float], ...]   # (location, flags, max_rel)
    report: object                    # the merged serving-side RaptorReport

    def __str__(self):
        top = self.blame[0][0] if self.blame else "<none>"
        return (f"drift@tick{self.tick}: peak {self.peak:.2e} > "
                f"{self.budget:.1e} x margin (top blame: {top})")


class ShadowProfiler:
    """Owns the shadowed decode step, the sampling RNG, the rolling report,
    and the drift detector. The engine calls :meth:`sample` at submit,
    :meth:`step` on ticks with live shadowed slots, and :meth:`check` after
    every shadowed tick."""

    def __init__(self, step_fn, policy, config: ShadowConfig, artifact=None):
        if policy is None:
            raise ValueError(
                "shadow profiling traces deviation against a deployed "
                "truncation policy; construct the Engine with policy=... "
                "(or an artifact) to enable it")
        if config.mode == "trajectory":
            self._step = profile_trajectory(step_fn, policy,
                                            threshold=config.threshold,
                                            n_steps=config.n_steps)
        elif config.mode == "memtrace":
            self._step = memtrace(step_fn, policy,
                                  threshold=config.threshold)
        else:
            raise ValueError(f"unknown shadow mode {config.mode!r}; "
                             "expected 'memtrace' or 'trajectory'")
        self.config = config
        self.artifact = artifact
        self._rng = np.random.RandomState(config.seed)
        self.report = None            # rolling serving-side RaptorReport
        self.shadow_ticks = 0
        self.log = GuardrailLog()
        self.events: List[DriftEvent] = []
        self._fired = False
        prov = getattr(artifact, "provenance", None) or {}
        self.budget = float(
            config.drift_budget
            if config.drift_budget is not None
            else prov.get("threshold", config.threshold))

    # ---- sampling ----------------------------------------------------------
    def sample(self) -> bool:
        """Deterministic (seeded, submission-ordered) request sampling."""
        return bool(self._rng.random_sample() < self.config.rate)

    # ---- the shadowed step -------------------------------------------------
    def step(self, params, cache, tokens):
        """Paired truncated/shadow execution of one decode tick. Returns
        ``(logits, new_cache, report)`` — logits/cache are the truncated
        lane, bit-identical to the plain deployed step."""
        (logits, new_cache), report = self._step(params, cache, tokens)
        return logits, new_cache, report

    def observe(self, report, shadow_requests: Sequence, tick: int) -> None:
        """Merge one tick's report into the rolling serving report and into
        each live shadowed request's per-request report (exact reductions:
        SUM for flags/op_counts, MAX for max_rel)."""
        rep = getattr(report, "totals", report)   # TrajectoryReport -> totals
        self.report = rep if self.report is None else self.report.merge(rep)
        for req in shadow_requests:
            req.report = (rep if req.report is None
                          else req.report.merge(rep))
        self.shadow_ticks += 1

    # ---- drift detection ---------------------------------------------------
    def peak_rel(self) -> float:
        if self.report is None:
            return 0.0
        max_rel = np.asarray(self.report.max_rel, dtype=np.float64)
        finite = max_rel[np.isfinite(max_rel)]
        return float(finite.max()) if finite.size else 0.0

    def check(self, tick: int) -> Optional[DriftEvent]:
        """Fire (once) when live-traffic deviation breaks the deployed
        artifact's accepted budget. Records ``drift_detected`` (+
        ``research_paged`` when a hook runs) into the guardrail log."""
        if self._fired or self.shadow_ticks < self.config.min_shadow_ticks:
            return None
        peak = self.peak_rel()
        if peak <= self.config.drift_margin * self.budget:
            return None
        self._fired = True
        blame = tuple(self.report.top(5))
        event = DriftEvent(tick=tick, peak=peak, budget=self.budget,
                           blame=blame, report=self.report)
        self.events.append(event)
        self.log.record(tick, "drift_detected", peak=peak, budget=self.budget,
                        margin=self.config.drift_margin,
                        shadow_ticks=self.shadow_ticks,
                        blame=[{"location": loc, "flags": fl, "max_rel": mr}
                               for loc, fl, mr in blame])
        hook = self.config.on_drift
        if hook is not None:
            self.log.record(tick, "research_paged",
                            hook=getattr(hook, "__name__", repr(hook)))
            hook(event)
        return event

    def cache_size(self) -> Optional[int]:
        fn = getattr(self._step, "cache_size", None)
        return None if fn is None else int(fn())


__all__ = ["ShadowConfig", "ShadowProfiler", "DriftEvent"]
