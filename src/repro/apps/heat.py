"""2D heat diffusion: explicit stencil phase + implicit backward-Euler/CG.

A periodic unit square carrying a Gaussian temperature bump, evolved first
with the explicit 5-point FTCS stencil (diffusion number 0.24, just inside
the 0.25 stability bound) and then with backward-Euler steps whose linear
system ``(I - k L) u = u_old`` is solved by fixed-iteration CG — the
explicit/implicit pair every production diffusion module carries, with the
CG path dominating FLOPs exactly like the real thing.

Precision story: under periodic boundaries both the explicit update and the
implicit solve conserve total heat exactly in exact arithmetic (the stencil
is a divergence and CG preserves the mean of the right-hand side when the
operator does), so the total-heat drift is a pure rounding observable; the
final temperature field adds L2 solution sensitivity.

Scopes: ``heat/stencil`` (explicit phase), ``heat/implicit`` over the CG
machinery (``.../matvec``, ``.../coeffs``, ``.../update``).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

import jax.numpy as jnp
from jax import lax

from repro.apps.base import MiniApp, Observables, cg_solve
from repro.core.api import scope


def _lap_periodic(u):
    """5-point periodic Laplacian in grid units (dx = 1)."""
    return (jnp.roll(u, 1, 0) + jnp.roll(u, -1, 0)
            + jnp.roll(u, 1, 1) + jnp.roll(u, -1, 1) - 4.0 * u)


class HeatDiffusion(MiniApp):
    name = "heat"
    error_budget = 1e-2
    search_threshold = 2e-3
    uniform_low = "e8m3"

    def __init__(self, n: int = 32, n_explicit: int = 64,
                 n_implicit: int = 4, cg_iters: int = 24,
                 k_explicit: float = 0.24, k_implicit: float = 2.0):
        self.n = int(n)
        self.n_explicit = int(n_explicit)
        self.n_implicit = int(n_implicit)
        self.cg_iters = int(cg_iters)
        self.k_explicit = float(k_explicit)   # diffusion number, < 0.25
        self.k_implicit = float(k_implicit)   # unconditionally stable
        # protocol bookkeeping: one "step" = the whole explicit phase or one
        # implicit solve; run() overrides the generic scan (two phases)
        self.n_steps = self.n_explicit + self.n_implicit

    # ---- protocol --------------------------------------------------------
    def init_state(self, dtype=jnp.float32):
        """Gaussian bump, f64-computed then f32-rounded (see SodShockTube)."""
        n = self.n
        xy = (np.arange(n, dtype=np.float64) + 0.5) / n
        X, Y = np.meshgrid(xy, xy, indexing="ij")
        u = np.exp(-((X - 0.5) ** 2 + (Y - 0.5) ** 2) / 0.02)
        return jnp.asarray(u.astype(np.float32), dtype)

    def _explicit_step(self, u):
        with scope("heat"):
            with scope("stencil"):
                k = jnp.asarray(self.k_explicit, u.dtype)
                return u + k * _lap_periodic(u)

    def _implicit_step(self, u):
        k = jnp.asarray(self.k_implicit, u.dtype)

        def matvec(v):
            return v - k * _lap_periodic(v)

        with scope("heat"):
            with scope("implicit"):
                return cg_solve(matvec, u, jnp.zeros_like(u), self.cg_iters)

    def step(self, u):
        """Generic single step (explicit) — the scan-of-steps protocol entry;
        ``run`` composes the real two-phase trajectory."""
        return self._explicit_step(u)

    def run(self, u):
        def ex_body(s, _):
            return self._explicit_step(s), None

        def im_body(s, _):
            return self._implicit_step(s), None

        u, _ = lax.scan(ex_body, u, None, length=self.n_explicit)
        u, _ = lax.scan(im_body, u, None, length=self.n_implicit)
        return u

    def observables(self, u) -> Observables:
        return {
            "total_heat": jnp.sum(u),   # exactly conserved (periodic BC)
            "peak": jnp.max(u),         # bump decay (monotone under heat)
            "field": u,                 # solution accuracy (rel L2)
        }

    def default_policy_scopes(self) -> Tuple[str, ...]:
        return ("heat/stencil", "heat/implicit/matvec",
                "heat/implicit/coeffs", "heat/implicit/update")
