"""FP64 reference trajectories and acceptance verdicts for the mini-apps.

The verification contract of the suite: every candidate precision policy is
graded against the *same app run in float64* — the practical stand-in for
RAPTOR's MPFR ground truth. Because ``init_state`` rounds initial data
through f32 for every dtype, the f64 trajectory differs from the f32 one by
solver arithmetic alone, so

    error_metric(fp64 oracle obs, candidate obs)  <=  app.error_budget

is a pure statement about accumulated rounding in the candidate's
arithmetic. ``fp32_floor`` measures where plain f32 lands on that scale —
the buffer between it and the budget is the room a truncation policy may
spend.

Oracle observables are computed under ``jax.enable_x64`` and returned as
host numpy (f64) so they survive leaving the context.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

import jax
import jax.numpy as jnp

from repro import compat
from repro.apps.base import MiniApp, Observables


def fp64_reference(app: MiniApp) -> Dict[str, np.ndarray]:
    """The app's full trajectory in float64: the oracle observables."""
    with compat.enable_x64():
        state = app.init_state(jnp.float64)
        obs = app.run_observables(state)
        return {k: np.asarray(jax.device_get(v), dtype=np.float64)
                for k, v in obs.items()}


def fp32_observables(app: MiniApp) -> Observables:
    """The plain f32 workload run (no truncation) — the search's reference
    lane and the floor of ``oracle_error``."""
    return app.run_observables(app.init_state(jnp.float32))


def oracle_error(app: MiniApp, cand_obs: Observables,
                 ref_obs: Dict[str, np.ndarray] = None) -> float:
    """``app.error_metric`` of a candidate's observables against the FP64
    oracle (computed fresh unless ``ref_obs`` is supplied)."""
    if ref_obs is None:
        ref_obs = fp64_reference(app)
    return app.error_metric(ref_obs, cand_obs)


def fp32_floor(app: MiniApp,
               ref_obs: Dict[str, np.ndarray] = None) -> float:
    """Oracle error of the untruncated f32 run — how much of the budget
    plain single precision already spends on this app."""
    return oracle_error(app, fp32_observables(app), ref_obs)


@dataclasses.dataclass(frozen=True)
class OracleVerdict:
    """One acceptance check: candidate observables vs the FP64 trajectory."""

    app: str
    error: float
    budget: float
    floor: float          # the untruncated-f32 oracle error, for context

    @property
    def passed(self) -> bool:
        return self.error <= self.budget

    def __str__(self) -> str:
        return (f"[{self.app}] oracle error {self.error:.3e} "
                f"(budget {self.budget:.1e}, f32 floor {self.floor:.3e}) "
                f"-> {'PASS' if self.passed else 'FAIL'}")

    # ---- policy-artifact integration --------------------------------------
    def to_json(self) -> dict:
        return {"app": self.app, "error": float(self.error),
                "budget": float(self.budget), "floor": float(self.floor),
                "passed": self.passed}

    @staticmethod
    def from_json(data: dict) -> "OracleVerdict":
        return OracleVerdict(app=str(data["app"]),
                             error=float(data["error"]),
                             budget=float(data["budget"]),
                             floor=float(data["floor"]))

    def attach(self, artifact):
        """Stamp this verdict onto a ``PolicyArtifact`` (returns the new,
        verdict-bearing artifact — artifacts are immutable)."""
        return artifact.with_oracle(self)


def verdict(app: MiniApp, cand_obs: Observables,
            ref_obs: Dict[str, np.ndarray] = None) -> OracleVerdict:
    """Grade candidate observables against the oracle and the app budget."""
    if ref_obs is None:
        ref_obs = fp64_reference(app)
    return OracleVerdict(
        app=app.name,
        error=oracle_error(app, cand_obs, ref_obs),
        budget=app.error_budget,
        floor=fp32_floor(app, ref_obs))
