# Scientific mini-apps: PDE workloads with solver-level observables and an
# FP64 oracle — the paper's application-class scenarios (shock hydro, heat
# diffusion, Krylov Poisson) as self-contained profiling targets. Every app
# exposes the uniform MiniApp protocol, so truncate / truncate_sweep /
# memtrace / profile_counts / autosearch(mesh=...) run on them unmodified.
from repro.apps.base import (
    MiniApp, Observables, observable_error, cg_iteration, cg_solve,
)
from repro.apps.sod import SodShockTube
from repro.apps.heat import HeatDiffusion
from repro.apps.poisson import PoissonCG
from repro.apps import oracle

# default-size instances: the configurations the e2e conformance tests and
# benchmarks grade; tests needing speed construct smaller ones directly
APPS = {
    "sod": SodShockTube,
    "heat": HeatDiffusion,
    "poisson": PoissonCG,
}


def get_app(name: str, **kwargs) -> MiniApp:
    """Instantiate a registered mini-app by name (size knobs as kwargs)."""
    try:
        cls = APPS[name]
    except KeyError:
        raise ValueError(
            f"unknown app {name!r}; known: {sorted(APPS)}") from None
    return cls(**kwargs)


__all__ = [
    "MiniApp", "Observables", "observable_error", "cg_iteration", "cg_solve",
    "SodShockTube", "HeatDiffusion", "PoissonCG",
    "oracle", "APPS", "get_app",
]
