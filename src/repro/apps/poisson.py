"""Conjugate-gradient Poisson solver with a residual-norm observable.

``A u = b`` for the 5-point Dirichlet Laplacian on the unit square, with
``b`` manufactured from a smooth discrete solution ``u* = sin(pi x) sin(pi
y) + half-frequency detail`` so the exact discrete answer is known. One
protocol ``step`` is one CG iteration (the state carries ``x, r, p, b``),
so ``run`` is the familiar fixed-iteration Krylov loop under one scan.

Precision story: the *recurrence* residual ``r`` in low precision drifts
away from the *true* residual ``b - A x`` — the canonical mixed-precision
CG failure mode. The observables therefore recompute the true relative
residual (outside any truncatable scope) next to the solution field: a
policy can only pass by actually converging, not by lying in its carried
residual. ``error_metric`` adds a residual-excess term so that any
candidate whose true residual misses the app's convergence tolerance is
over budget even if its field error happens to be small.

Scopes: ``poisson/matvec`` (stencil — FLOPs bulk), ``poisson/coeffs`` (the
two global reductions — precision-critical), ``poisson/update`` (axpys).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

import jax.numpy as jnp

from repro.apps.base import MiniApp, Observables, cg_iteration, _host, _EPS
from repro.core.api import scope
from repro.search.metrics import rel_l2_error


def _lap_dirichlet(u):
    """5-point ``-Laplacian`` (SPD) in grid units with zero Dirichlet BC."""
    up = jnp.pad(u, 1)
    return (4.0 * u - up[:-2, 1:-1] - up[2:, 1:-1]
            - up[1:-1, :-2] - up[1:-1, 2:])


class PoissonCG(MiniApp):
    name = "poisson"
    error_budget = 2e-2
    search_threshold = 5e-3
    uniform_low = "e8m3"
    # convergence tolerance on the TRUE relative residual ||b - A x|| / ||b||
    # (f32 CG on this problem reaches ~1e-6; an admissible truncated run may
    # stall earlier but must still genuinely converge to this tolerance)
    residual_tol = 1e-3

    def __init__(self, n: int = 32, cg_iters: int = 48):
        self.n = int(n)
        self.n_steps = int(cg_iters)

    # ---- protocol --------------------------------------------------------
    def init_state(self, dtype=jnp.float32):
        """CG state ``(x, r, p, b)`` with x0 = 0, f64-computed b rounded
        through f32 (see SodShockTube) so every precision runs the same
        right-hand side bits."""
        n = self.n
        xy = (np.arange(n, dtype=np.float64) + 1.0) / (n + 1.0)
        X, Y = np.meshgrid(xy, xy, indexing="ij")
        u_star = (np.sin(np.pi * X) * np.sin(np.pi * Y)
                  + 0.25 * np.sin(2 * np.pi * X) * np.sin(2 * np.pi * Y))
        up = np.pad(u_star, 1)
        b = (4.0 * u_star - up[:-2, 1:-1] - up[2:, 1:-1]
             - up[1:-1, :-2] - up[1:-1, 2:])
        b = jnp.asarray(b.astype(np.float32), dtype)
        x0 = jnp.zeros_like(b)
        return (x0, b, b, b)  # x, r = b - A*0, p = r, b

    def step(self, state):
        x, r, p, b = state
        with scope("poisson"):
            x, r, p = cg_iteration(_lap_dirichlet, x, r, p)
        return (x, r, p, b)

    def observables(self, state) -> Observables:
        x, _r, _p, b = state
        # TRUE residual, recomputed outside every policy scope: the carried
        # recurrence residual _r is part of the (truncatable) workload and
        # must never be the convergence judge
        res = b - _lap_dirichlet(x)
        rel_res = (jnp.sqrt(jnp.sum(res * res))
                   / (jnp.sqrt(jnp.sum(b * b)) + _EPS))
        return {"rel_residual": rel_res, "solution": x}

    def error_metric(self, ref_obs: Observables,
                     cand_obs: Observables) -> float:
        """Field rel-L2 plus a residual-excess term: exceeding the app's
        convergence tolerance scales the metric past 1 regardless of how the
        reference's own (possibly tiny) residual compares."""
        field = rel_l2_error(ref_obs["solution"], cand_obs["solution"])
        res_c = float(_host(cand_obs["rel_residual"]))
        if not np.isfinite(res_c):
            return float("inf")
        excess = max(0.0, res_c - self.residual_tol) / self.residual_tol
        return max(field, excess)

    def default_policy_scopes(self) -> Tuple[str, ...]:
        return ("poisson/matvec", "poisson/coeffs", "poisson/update")
