"""The uniform mini-app protocol the whole profiling stack runs on.

RAPTOR's validation targets are real solvers (Flash-X Sod, Sedov, cellular
detonation) judged on *solver-level observables* — conserved quantities and
residual norms — not per-op deviations. :class:`MiniApp` captures exactly
the surface the profiling/search stack needs from such a workload:

  * ``init_state(dtype)``        — initial condition (a pytree of arrays)
  * ``step(state)``              — one solver step (pure, traceable JAX)
  * ``run(state)``               — the full trajectory (``lax.scan`` of steps)
  * ``observables(state)``       — dict of physically meaningful quantities
  * ``error_metric(ref, cand)``  — scalar "how wrong is this trajectory",
                                   smaller is better, inf = inadmissible
  * ``default_policy_scopes()``  — the named-scope regions truncation may
                                   legitimately target

Because ``run_observables`` is an ordinary traceable function of the state,
``truncate``, ``truncate_sweep``, ``memtrace``, ``profile_counts`` and
``autosearch(mesh=...)`` all apply to every app unmodified — the app's
``error_metric`` plugs straight into ``autosearch(metric=...)`` via
``search.metrics.resolve_metric``.

Observable computations are deliberately left OUTSIDE any named scope: they
are the measurement harness, not the workload, so scoped policies (and the
scope frontier ``autosearch`` discovers) can never truncate them.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.api import scope
from repro.core.policy import TruncationPolicy, TruncationRule
from repro.core.formats import parse_format

Observables = Dict[str, jnp.ndarray]

_EPS = 1e-12
# CG coefficient guard: keeps 0/0 out of alpha/beta once the residual hits
# the rounding floor; small enough to be invisible at any probed precision
_CG_EPS = 1e-30


class MiniApp:
    """Base class implementing the shared machinery of the protocol.

    Subclasses provide ``init_state``/``step``/``observables`` (and usually
    override ``error_metric``) plus the class attributes below. All solver
    arithmetic must derive its dtype from the state so the same code runs
    the f32 workload and the f64 oracle trajectory.
    """

    name: str = "?"
    n_steps: int = 1
    # acceptance threshold for error_metric(fp64 oracle, candidate) — the
    # app's physics budget, calibrated in tests/conformance/test_apps_e2e.py
    error_budget: float = 1e-2
    # autosearch threshold on the app's own f32 self-metric; tighter than
    # error_budget so "f32 floor + search slack" stays inside the budget
    search_threshold: float = 1e-3
    # the uniform-low-precision strawman a mixed assignment must beat
    uniform_low: str = "e8m3"
    # mid-ladder probe format for instability profiling / warm-start hint
    # calibration: coarse enough that instabilities show, fine enough that
    # both finer and coarser predictions stay on the search ladder
    probe_format: str = "e8m5"

    # ---- protocol --------------------------------------------------------
    def init_state(self, dtype=jnp.float32):
        raise NotImplementedError

    def step(self, state):
        raise NotImplementedError

    def observables(self, state) -> Observables:
        raise NotImplementedError

    def run(self, state):
        """The full trajectory: ``n_steps`` solver steps under one scan, so
        one jaxpr covers the whole run (scan trip counts multiply FLOPs in
        scope discovery and the op-mode walkers recurse through the body)."""
        def body(s, _):
            return self.step(s), None

        out, _ = lax.scan(body, state, None, length=self.n_steps)
        return out

    def run_observables(self, state) -> Observables:
        """The profiled function of record: state -> solver observables."""
        return self.observables(self.run(state))

    def error_metric(self, ref_obs: Observables,
                     cand_obs: Observables) -> float:
        """Default: worst observable deviation — relative error for scalars,
        relative L2 for fields (see :func:`observable_error`)."""
        return observable_error(ref_obs, cand_obs)

    def default_policy_scopes(self) -> Tuple[str, ...]:
        raise NotImplementedError

    # ---- conveniences ----------------------------------------------------
    def uniform_policy(self, fmt=None) -> TruncationPolicy:
        """Uniform low precision over every solver scope — the strawman the
        searched mixed assignment is graded against. Scoped (not
        ``everywhere``) so the observable harness itself stays exact."""
        f = parse_format(fmt if fmt is not None else self.uniform_low)
        return TruncationPolicy(rules=tuple(
            TruncationRule(fmt=f, scope=s)
            for s in self.default_policy_scopes()))

    # ---- instability profiling (repro.profile) ---------------------------
    def profile_trajectory(self, state=None, *, policy=None, threshold=None,
                           n_steps=None, **kwargs):
        """Trajectory-profile ``run_observables``: returns ``(observables,
        TrajectoryReport)``. The ring buffer defaults to ``self.n_steps + 1``
        rows — one per solver step plus one for the trailing observable
        harness — so every step of the trajectory gets its own row and the
        blame ranking's onsets are exact. ``policy`` defaults to the app's
        scopes uniformly at :attr:`probe_format`."""
        from repro.core.api import profile_trajectory as _profile
        if state is None:
            state = self.init_state()
        pol = policy if policy is not None \
            else self.uniform_policy(self.probe_format)
        thr = self.search_threshold if threshold is None else threshold
        steps = (self.n_steps + 1) if n_steps is None else n_steps
        return _profile(self.run_observables, pol, threshold=thr,
                        n_steps=steps, **kwargs)(state)

    def warm_hints(self, state=None, *, widths=None, threshold=None,
                   **kwargs):
        """One profiling run -> ``autosearch(warm_start=...)`` hints: blame
        the trajectory, calibrate site-level peaks against the measured
        solver-level metric of the probe run itself, and lower onto the
        search ladder (see ``repro.profile.ladder_hints``)."""
        from repro.core.formats import parse_format
        from repro.profile import ladder_hints
        from repro.search.driver import DEFAULT_WIDTHS
        if state is None:
            state = self.init_state()
        thr = self.search_threshold if threshold is None else threshold
        obs_lo, traj = self.profile_trajectory(state, threshold=thr, **kwargs)
        joint = self.error_metric(self.run_observables(state), obs_lo)
        return ladder_hints(traj, widths or DEFAULT_WIDTHS, thr,
                            parse_format(self.probe_format).man_bits,
                            joint_metric=joint)

    def __repr__(self) -> str:
        return (f"<{type(self).__name__} {self.name!r} steps={self.n_steps} "
                f"budget={self.error_budget:g}>")


# --------------------------------------------------------------------------
# observable comparison helpers
# --------------------------------------------------------------------------

def _host(x) -> np.ndarray:
    return np.asarray(jax.device_get(x)).astype(np.float64)


def observable_error(ref_obs: Observables, cand_obs: Observables) -> float:
    """Worst-key observable deviation: scalars compare by relative error,
    fields by relative L2; a non-finite candidate against a finite reference
    is infinitely wrong (a policy that overflows is never admissible)."""
    if set(ref_obs) != set(cand_obs):
        raise ValueError(f"observable keys differ: {sorted(ref_obs)} vs "
                         f"{sorted(cand_obs)}")
    worst = 0.0
    for key in ref_obs:
        r, c = _host(ref_obs[key]), _host(cand_obs[key])
        if np.all(np.isfinite(r)) and not np.all(np.isfinite(c)):
            return float("inf")
        if r.ndim == 0 or r.size == 1:
            d = abs(float(c.ravel()[0]) - float(r.ravel()[0])) \
                / (abs(float(r.ravel()[0])) + _EPS)
        else:
            d = float(np.linalg.norm((c - r).ravel())
                      / (np.linalg.norm(r.ravel()) + _EPS))
        worst = max(worst, d)
    return worst


# --------------------------------------------------------------------------
# shared conjugate-gradient building blocks (heat implicit path + poisson)
# --------------------------------------------------------------------------

def _dot(a, b):
    return jnp.sum(a * b)


def cg_iteration(matvec, x, r, p):
    """One textbook CG iteration under the standard scope split: ``matvec``
    (the stencil — the FLOPs bulk), ``coeffs`` (the two global reductions —
    small but famously precision-critical), ``update`` (axpys)."""
    with scope("matvec"):
        Ap = matvec(p)
    with scope("coeffs"):
        rs = _dot(r, r)
        alpha = rs / (_dot(p, Ap) + jnp.asarray(_CG_EPS, x.dtype))
    with scope("update"):
        x = x + alpha * p
        r_new = r - alpha * Ap
    with scope("coeffs"):
        beta = _dot(r_new, r_new) / (rs + jnp.asarray(_CG_EPS, x.dtype))
    with scope("update"):
        p = r_new + beta * p
    return x, r_new, p


def cg_solve(matvec, b, x0, iters: int):
    """Fixed-iteration CG (deterministic op count: the iteration count is
    part of the workload definition, exactly like a solver's max-iters)."""
    r0 = b - matvec(x0)

    def body(carry, _):
        x, r, p = carry
        return cg_iteration(matvec, x, r, p), None

    (x, r, p), _ = lax.scan(body, (x0, r0, r0), None, length=iters)
    return x


__all__ = [
    "MiniApp", "Observables", "observable_error",
    "cg_iteration", "cg_solve",
]
