"""Sod shock tube: 1D compressible Euler, first-order finite volume.

The canonical hydro verification problem (and the paper's first Flash-X
study): a diaphragm at x=0.5 separates (rho=1, p=1) from (rho=0.125,
p=0.1); the breakup produces a left rarefaction, contact, and right shock.
Scheme: Godunov-type finite volume with the Rusanov (local Lax-Friedrichs)
flux and transmissive boundaries.

Precision story: with transmissive boundaries and u=0 end states, the
boundary mass/energy fluxes are exactly zero until a wave reaches the ends,
so total mass and total energy are conserved *exactly* in exact arithmetic
— their drift over the run measures accumulated rounding alone, the
conserved-quantity observable the paper grades applications on. The density
profile L2 error adds solution-accuracy sensitivity on top.

Scopes: ``hydro/eos`` (primitive recovery: divisions, sqrt — fragile),
``hydro/flux`` (interface fluxes — the FLOPs bulk), ``hydro/update`` (the
conservative difference — where cancellation lives).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

import jax.numpy as jnp

from repro.apps.base import MiniApp, Observables
from repro.core.api import scope


class SodShockTube(MiniApp):
    name = "sod"
    error_budget = 2e-2
    search_threshold = 5e-3
    uniform_low = "e8m3"

    def __init__(self, n_cells: int = 128, t_end: float = 0.2,
                 cfl: float = 0.4, gamma: float = 1.4):
        self.n_cells = int(n_cells)
        self.gamma = float(gamma)
        self.dx = 1.0 / self.n_cells
        # fixed dt against the global wave-speed bound (max |u|+c in the Sod
        # fan is < 2.0 for gamma=1.4) keeps the op count static — dt is part
        # of the workload, not state-dependent control flow
        self.dt = cfl * self.dx / 2.0
        self.n_steps = max(1, int(round(t_end / self.dt)))

    # ---- protocol --------------------------------------------------------
    def init_state(self, dtype=jnp.float32):
        """Conserved state (rho, mom, E), each (n_cells,).

        Computed in f64 then rounded through f32 before the cast to the
        requested dtype, so the f32 workload and the f64 oracle start from
        bit-identical initial data — trajectory differences measure solver
        arithmetic only, never initialization rounding."""
        n, g = self.n_cells, self.gamma
        x = (np.arange(n, dtype=np.float64) + 0.5) * self.dx
        left = x < 0.5
        rho = np.where(left, 1.0, 0.125)
        p = np.where(left, 1.0, 0.1)
        u = np.zeros(n)
        mom = rho * u
        E = p / (g - 1.0) + 0.5 * rho * u * u
        return tuple(jnp.asarray(a.astype(np.float32), dtype)
                     for a in (rho, mom, E))

    def step(self, state):
        rho, mom, E = state
        g = self.gamma
        dt_dx = jnp.asarray(self.dt / self.dx, rho.dtype)

        def pad(a):  # transmissive ghost cells
            return jnp.concatenate([a[:1], a, a[-1:]])

        with scope("hydro"):
            rho_p, mom_p, E_p = pad(rho), pad(mom), pad(E)
            with scope("eos"):
                u = mom_p / rho_p
                p = (g - 1.0) * (E_p - 0.5 * mom_p * u)
                c = jnp.sqrt(g * p / rho_p)
            with scope("flux"):
                # physical fluxes per padded cell
                f_rho = mom_p
                f_mom = mom_p * u + p
                f_E = (E_p + p) * u
                smax = jnp.maximum((jnp.abs(u) + c)[:-1],
                                   (jnp.abs(u) + c)[1:])
                half = jnp.asarray(0.5, rho.dtype)

                def rusanov(f, q):
                    return (half * (f[:-1] + f[1:])
                            - half * smax * (q[1:] - q[:-1]))

                F_rho = rusanov(f_rho, rho_p)
                F_mom = rusanov(f_mom, mom_p)
                F_E = rusanov(f_E, E_p)
            with scope("update"):
                rho = rho - dt_dx * (F_rho[1:] - F_rho[:-1])
                mom = mom - dt_dx * (F_mom[1:] - F_mom[:-1])
                E = E - dt_dx * (F_E[1:] - F_E[:-1])
        return (rho, mom, E)

    def observables(self, state) -> Observables:
        rho, mom, E = state
        dx = jnp.asarray(self.dx, rho.dtype)
        return {
            "mass": jnp.sum(rho) * dx,       # exactly conserved pre-breakout
            "energy": jnp.sum(E) * dx,       # exactly conserved pre-breakout
            "rho_profile": rho,              # solution accuracy (rel L2)
        }

    def default_policy_scopes(self) -> Tuple[str, ...]:
        return ("hydro/eos", "hydro/flux", "hydro/update")
