"""Attention: GQA/MQA with RoPE/M-RoPE/partial-RoPE, sliding windows, MLA.

Training/prefill use a chunked, memory-bounded flash attention (pure jnp
scan over KV blocks with running max/denominator; the Pallas TPU kernel in
``repro.kernels.flash_attention`` implements the same contract and is
selected on TPU via ``repro.kernels.flash_attention.ops``). KV heads are
never materialized to Hq (grouped einsum) — a deliberate memory optimization
over the naive repeat-KV formulation.

Decode paths attend one new token against a pre-allocated cache; MLA decode
uses the absorbed low-rank form so the cache stays (kv_lora + rope_dim) wide.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.distributed.sharding import constrain
from repro.models import common
from repro.models.common import ParamDef


NEG_INF = -1e30


# ---------------------------------------------------------------------------
# chunked flash attention (jnp reference; contract shared with Pallas kernel)
# ---------------------------------------------------------------------------

def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, scale: Optional[float] = None,
                    q_chunk: int = 1024, kv_chunk: int = 1024):
    """q: (B, Hq, S, Dk); k: (B, Hkv, S, Dk); v: (B, Hkv, S, Dv).
    Grouped-query: Hq % Hkv == 0. Returns (B, Hq, S, Dv)."""
    B, Hq, S, Dk = q.shape
    Hkv = k.shape[1]
    Dv = v.shape[-1]
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(Dk)

    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, S)
    nq, nk = -(-S // q_chunk), -(-S // kv_chunk)
    assert S % q_chunk == 0 and S % kv_chunk == 0, (S, q_chunk, kv_chunk)

    qg = q.reshape(B, Hkv, G, S, Dk)
    qs = qg.reshape(B, Hkv, G, nq, q_chunk, Dk).transpose(3, 0, 1, 2, 4, 5)
    ks = k.reshape(B, Hkv, nk, kv_chunk, Dk).transpose(2, 0, 1, 3, 4)
    vs = v.reshape(B, Hkv, nk, kv_chunk, Dv).transpose(2, 0, 1, 3, 4)

    q_pos = jnp.arange(S).reshape(nq, q_chunk)
    k_pos = jnp.arange(S).reshape(nk, kv_chunk)

    # sliding-window block skipping: with a static window each q chunk only
    # needs the kv chunks covering [q0 - window + 1, q0 + Cq) — an O(S*W)
    # instead of O(S^2) schedule (the Pallas kernel additionally skips
    # above-diagonal blocks for plain causal).
    n_win = nk
    if causal and isinstance(window, int):
        n_win = min(nk, (window + q_chunk - 1 + kv_chunk - 1) // kv_chunk + 1)

    def per_q_chunk(carry, qc):
        del carry
        q_blk, qp = qc  # (B,Hkv,G,Cq,Dk), (Cq,)

        if n_win < nk:
            start = jnp.clip((qp[0] - (window - 1)) // kv_chunk, 0, nk - n_win)
            ks_l = lax.dynamic_slice_in_dim(ks, start, n_win, axis=0)
            vs_l = lax.dynamic_slice_in_dim(vs, start, n_win, axis=0)
            kp_l = lax.dynamic_slice_in_dim(k_pos, start, n_win, axis=0)
        else:
            ks_l, vs_l, kp_l = ks, vs, k_pos

        def per_kv_chunk(state, kc):
            m, l, acc = state
            k_blk, v_blk, kp = kc
            s = jnp.einsum("bhgqd,bhkd->bhgqk", q_blk.astype(jnp.float32),
                           k_blk.astype(jnp.float32)) * scale
            mask = jnp.ones((qp.shape[0], kp.shape[0]), bool)
            if causal:
                mask &= qp[:, None] >= kp[None, :]
            if window is not None:
                mask &= (qp[:, None] - kp[None, :]) < window
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, v_blk.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, q_blk.shape[-2]), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, q_blk.shape[-2]), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, q_blk.shape[-2], Dv), jnp.float32)
        # flash-style backward: recompute the (Cq,Ck) score/prob blocks in
        # the bwd pass instead of storing them per chunk pair (autodiff of a
        # plain scan would save every p matrix — the dominant train-memory
        # term; see EXPERIMENTS.md §Perf)
        per_kv = jax.checkpoint(per_kv_chunk, prevent_cse=False)
        (m, l, acc), _ = lax.scan(per_kv, (m0, l0, a0),
                                  (ks_l, vs_l, kp_l))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out

    per_q = jax.checkpoint(per_q_chunk, prevent_cse=False)
    _, outs = lax.scan(per_q, None, (qs, q_pos))
    # outs: (nq, B, Hkv, G, Cq, Dv) -> (B, Hq, S, Dv)
    out = outs.transpose(1, 2, 3, 0, 4, 5).reshape(B, Hq, S, Dv)
    return out.astype(v.dtype)


def decode_attention(q, k_cache, v_cache, pos, *, window: Optional[int] = None,
                     scale: Optional[float] = None, ring: bool = False):
    """One-token attention. q: (B, Hq, Dk); caches: (B, Hkv, S, D*);
    pos: int32 scalar or (B,) vector — per-slot count of valid cache entries
    (the new token's index in slot b is pos[b]-1 after the cache update).
    A scalar means every batch lane sits at the same cursor; the serving
    engine passes a ragged (B,) vector so slots decode independently.

    ``ring=True``: the cache is a ring buffer of size S == window; slot s
    holds the token at position pos - ((pos - s) mod S) — negative means the
    slot hasn't been written yet (masked). No separate window mask needed:
    the ring IS the window."""
    B, Hq, Dk = q.shape
    Hkv, S = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(Dk)
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))

    qg = q.reshape(B, Hkv, G, Dk)
    s = jnp.einsum("bhgd,bhkd->bhgk", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    idx = jnp.arange(S)[None, None, None, :]
    cur = pos_b[:, None, None, None]
    if ring:
        last = cur - 1  # index of the newest token (already inserted)
        slot_pos = last - jnp.mod(last - idx, S)
        valid = slot_pos >= 0
    else:
        valid = idx < cur
        if window is not None:
            valid &= idx >= (cur - window)
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bhkd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, Hq, -1).astype(v_cache.dtype)


# ---------------------------------------------------------------------------
# GQA block
# ---------------------------------------------------------------------------

def gqa_param_defs(cfg: ArchConfig) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, Hkv = cfg.n_heads, cfg.n_kv_heads
    scale = 0.02
    o_scale = 0.02 / math.sqrt(2 * cfg.n_layers)
    defs = {
        "wq": ParamDef((d, H * hd), ("embed", "heads"), scale=scale),
        "wk": ParamDef((d, Hkv * hd), ("embed", "kv_heads"), scale=scale),
        "wv": ParamDef((d, Hkv * hd), ("embed", "kv_heads"), scale=scale),
        "wo": ParamDef((H * hd, d), ("heads", "embed"), scale=o_scale),
    }
    if cfg.qkv_bias:
        defs["bq"] = ParamDef((H * hd,), ("heads",), init="zeros")
        defs["bk"] = ParamDef((Hkv * hd,), ("kv_heads",), init="zeros")
        defs["bv"] = ParamDef((Hkv * hd,), ("kv_heads",), init="zeros")
    return defs


def _project_qkv(p, x, cfg: ArchConfig, positions):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    H, Hkv = cfg.n_heads, cfg.n_kv_heads
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    k = k.reshape(B, S, Hkv, hd).transpose(0, 2, 1, 3)
    v = v.reshape(B, S, Hkv, hd).transpose(0, 2, 1, 3)
    if cfg.rope_type == "rope":
        q = common.apply_rope(q, positions, theta=cfg.rope_theta,
                              fraction=cfg.rope_fraction)
        k = common.apply_rope(k, positions, theta=cfg.rope_theta,
                              fraction=cfg.rope_fraction)
    elif cfg.rope_type == "mrope":
        q = common.apply_mrope(q, positions, theta=cfg.rope_theta,
                               sections=cfg.mrope_sections)
        k = common.apply_mrope(k, positions, theta=cfg.rope_theta,
                               sections=cfg.mrope_sections)
    return q, k, v


def gqa_forward(p, x, cfg: ArchConfig, *, positions, causal: bool = True,
                window: Optional[int] = None):
    """Training/prefill attention. x: (B, S, d). Returns ((B,S,d), kv)."""
    B, S, _ = x.shape
    with jax.named_scope("qkv"):
        q, k, v = _project_qkv(p, x, cfg, positions)
        q = constrain(q, "batch", "heads", "seq", None)
        k = constrain(k, "batch", "kv_heads", "seq", None)
        v = constrain(v, "batch", "kv_heads", "seq", None)
    with jax.named_scope("mix"):
        o = flash_attention(q, k, v, causal=causal, window=window)
        o = constrain(o, "batch", "heads", "seq", None)
    with jax.named_scope("proj"):
        o = o.transpose(0, 2, 1, 3).reshape(B, S, -1)
        out = o @ p["wo"].astype(x.dtype)
        out = constrain(out, "batch", "seq", "embed")
    return out, (k, v)


def gqa_decode(p, x1, cache, pos, cfg: ArchConfig, *,
               window: Optional[int] = None, positions3=None):
    """x1: (B, 1, d); cache: dict(k=(B,Hkv,S,hd), v=...). pos: scalar or
    (B,) count of tokens already in each slot's cache (ragged decode writes
    each lane at its own cursor). When the cache was allocated ring-sized
    (S == window < requested seq_len) the slot is pos mod S; a non-ring
    cursor past the cache end simply doesn't write (dead serving lanes)."""
    B = x1.shape[0]
    hd = cfg.resolved_head_dim
    S_cache = cache["k"].shape[2]
    ring = window is not None and S_cache == window
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    if cfg.rope_type == "mrope" and positions3 is None:
        positions3 = jnp.broadcast_to(pos_b[None, :, None], (3, B, 1))
    positions = pos_b[:, None]
    q, k, v = _project_qkv(
        p, x1, cfg, positions3 if cfg.rope_type == "mrope" else positions)
    slot = jnp.mod(pos_b, S_cache) if ring else pos_b
    onehot = jnp.arange(S_cache)[None, :] == slot[:, None]   # (B, S)
    k_cache = jnp.where(onehot[:, None, :, None],
                        k.astype(cache["k"].dtype), cache["k"])
    v_cache = jnp.where(onehot[:, None, :, None],
                        v.astype(cache["v"].dtype), cache["v"])
    o = decode_attention(q[:, :, 0], k_cache, v_cache, pos_b + 1,
                         window=None if ring else window, ring=ring)
    out = o.reshape(B, 1, -1) @ p["wo"].astype(x1.dtype)
    return out, {"k": k_cache, "v": v_cache}


def gqa_init_cache(cfg: ArchConfig, batch: int, seq_len: int, dtype,
                   window: Optional[int] = None):
    """``window``: allocate a ring buffer of that size instead of the full
    sequence (sliding-window layers never need more — the long_500k memory
    win, EXPERIMENTS.md §Perf iteration 13)."""
    hd = cfg.resolved_head_dim
    S = min(seq_len, window) if window else seq_len
    shape = (batch, cfg.n_kv_heads, S, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


# ---------------------------------------------------------------------------
# Multi-head Latent Attention (DeepSeek-V2)
# ---------------------------------------------------------------------------

def mla_param_defs(cfg: ArchConfig) -> dict:
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    o_scale = 0.02 / math.sqrt(2 * cfg.n_layers)
    return {
        "q_down": ParamDef((d, m.q_lora), ("embed", None)),
        "q_norm": ParamDef((m.q_lora,), (None,), init="ones"),
        "q_up": ParamDef((m.q_lora, H * (m.nope_head_dim + m.rope_head_dim)),
                         (None, "heads")),
        "kv_down": ParamDef((d, m.kv_lora + m.rope_head_dim), ("embed", None)),
        "kv_norm": ParamDef((m.kv_lora,), (None,), init="ones"),
        "kv_up": ParamDef((m.kv_lora, H * (m.nope_head_dim + m.v_head_dim)),
                          (None, "heads")),
        "wo": ParamDef((H * m.v_head_dim, d), ("heads", "embed"), scale=o_scale),
    }


def _mla_q(p, x, cfg, positions):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    cq = common.rmsnorm(x @ p["q_down"].astype(x.dtype), p["q_norm"], cfg.norm_eps)
    q = (cq @ p["q_up"].astype(x.dtype)).reshape(
        B, S, H, m.nope_head_dim + m.rope_head_dim).transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., :m.nope_head_dim], q[..., m.nope_head_dim:]
    q_rope = common.apply_rope(q_rope, positions, theta=cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(p, x, cfg, positions):
    m = cfg.mla
    kv = x @ p["kv_down"].astype(x.dtype)
    c_kv, k_rope = kv[..., :m.kv_lora], kv[..., m.kv_lora:]
    c_kv = common.rmsnorm(c_kv, p["kv_norm"], cfg.norm_eps)
    k_rope = common.apply_rope(k_rope[:, None], positions,
                               theta=cfg.rope_theta)[:, 0]
    return c_kv, k_rope          # (B,S,kv_lora), (B,S,rope_dim)


def mla_forward(p, x, cfg: ArchConfig, *, positions):
    """Training/prefill MLA in the expanded form."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    with jax.named_scope("mla_qkv"):
        q_nope, q_rope = _mla_q(p, x, cfg, positions)
        c_kv, k_rope = _mla_latent(p, x, cfg, positions)
        kv = (c_kv @ p["kv_up"].astype(x.dtype)).reshape(
            B, S, H, m.nope_head_dim + m.v_head_dim).transpose(0, 2, 1, 3)
        k_nope, v = kv[..., :m.nope_head_dim], kv[..., m.nope_head_dim:]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, None],
                                      (B, H, S, m.rope_head_dim))], axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        q = constrain(q, "batch", "heads", "seq", None)
        k = constrain(k, "batch", "heads", "seq", None)
        v = constrain(v, "batch", "heads", "seq", None)
    with jax.named_scope("mla_mix"):
        scale = 1.0 / math.sqrt(m.nope_head_dim + m.rope_head_dim)
        o = flash_attention(q, k, v, causal=True, scale=scale)
    with jax.named_scope("mla_proj"):
        o = o.transpose(0, 2, 1, 3).reshape(B, S, H * m.v_head_dim)
        out = o @ p["wo"].astype(x.dtype)
    return out, (c_kv, k_rope)


def mla_decode(p, x1, cache, pos, cfg: ArchConfig):
    """Absorbed-form decode: cache holds only (c_kv, k_rope). pos: scalar
    or (B,) per-slot cursor, matching ``gqa_decode``."""
    m = cfg.mla
    B = x1.shape[0]
    H = cfg.n_heads
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    positions = pos_b[:, None]
    q_nope, q_rope = _mla_q(p, x1, cfg, positions)     # (B,H,1,dn),(B,H,1,dr)
    c_new, kr_new = _mla_latent(p, x1, cfg, positions)
    onehot = jnp.arange(cache["c_kv"].shape[1])[None, :] == pos_b[:, None]
    c_cache = jnp.where(onehot[..., None],
                        c_new.astype(cache["c_kv"].dtype), cache["c_kv"])
    r_cache = jnp.where(onehot[..., None],
                        kr_new.astype(cache["k_rope"].dtype), cache["k_rope"])

    # kv_up columns interleave [nope | v] per head
    w_up = p["kv_up"].reshape(m.kv_lora, H, m.nope_head_dim + m.v_head_dim)
    w_uk = w_up[..., :m.nope_head_dim]
    w_uv = w_up[..., m.nope_head_dim:]
    f32 = jnp.float32
    # absorb W_uk into q: (B,H,dn) x (kv_lora,H,dn) -> (B,H,kv_lora)
    q_lat = jnp.einsum("bhd,lhd->bhl", q_nope[:, :, 0].astype(f32),
                       w_uk.astype(f32))
    s = jnp.einsum("bhl,bsl->bhs", q_lat, c_cache.astype(f32))
    s = s + jnp.einsum("bhd,bsd->bhs", q_rope[:, :, 0].astype(f32),
                       r_cache.astype(f32))
    s = s / math.sqrt(m.nope_head_dim + m.rope_head_dim)
    idx = jnp.arange(c_cache.shape[1])
    s = jnp.where(idx[None, None, :] <= pos_b[:, None, None], s, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    ctx_lat = jnp.einsum("bhs,bsl->bhl", pr, c_cache.astype(f32))
    o = jnp.einsum("bhl,lhd->bhd", ctx_lat, w_uv.astype(f32))
    out = o.reshape(B, 1, H * m.v_head_dim).astype(x1.dtype) @ p["wo"].astype(x1.dtype)
    return out, {"c_kv": c_cache, "k_rope": r_cache}


def mla_init_cache(cfg: ArchConfig, batch: int, seq_len: int, dtype):
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, seq_len, m.kv_lora), dtype),
        "k_rope": jnp.zeros((batch, seq_len, m.rope_head_dim), dtype),
    }
