"""Model facade: binds an ArchConfig to init / loss / prefill / decode."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer, encdec
from repro.models.common import (
    init_tree, abstract_tree, axes_tree, count_params,
)


class Model:
    """A thin, stateless namespace of pure functions bound to ``cfg``."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self._mod = encdec if cfg.family == "encdec" else transformer

    # ---- parameters -------------------------------------------------------
    def param_defs(self):
        return self._mod.model_param_defs(self.cfg)

    def init(self, key) -> Dict[str, Any]:
        return init_tree(self.param_defs(), key, jnp.dtype(self.cfg.dtype))

    def abstract_params(self):
        return abstract_tree(self.param_defs(), jnp.dtype(self.cfg.dtype))

    def param_axes(self):
        return axes_tree(self.param_defs())

    def n_params(self) -> int:
        return count_params(self.param_defs())

    def n_active_params(self) -> int:
        """Active parameters per token (MoE discount) for 6ND roofline."""
        cfg = self.cfg
        total = self.n_params()
        if cfg.moe is None:
            return total
        mc = cfg.moe
        n_stack = cfg.n_layers - mc.first_k_dense
        per_expert = 3 * cfg.d_model * mc.d_expert  # swiglu wi(2x) + wo
        inactive = n_stack * (mc.n_experts - mc.top_k) * per_expert
        return total - inactive

    # ---- execution --------------------------------------------------------
    def loss(self, params, batch):
        return self._mod.loss_fn(params, batch, self.cfg)

    def forward(self, params, batch):
        return self._mod.forward(params, batch, self.cfg)

    def prefill(self, params, batch):
        if self.cfg.family == "encdec":
            return self._mod.forward(params, batch, self.cfg,
                                     last_only=True)[:, 0]
        return self._mod.prefill(params, batch, self.cfg)

    def init_cache(self, batch_size: int, seq_len: int):
        if self.cfg.family == "encdec":
            return encdec.init_cache(self.cfg, batch_size, seq_len)
        return transformer.init_cache(self.cfg, batch_size, seq_len)

    def decode_step(self, params, cache, tokens, embeds=None):
        if self.cfg.family == "encdec":
            return encdec.decode_step(params, cache, tokens, self.cfg)
        return transformer.decode_step(params, cache, tokens, self.cfg,
                                       embeds=embeds)
