"""Decoder stack: embeds -> scanned layers -> norm -> logits.

One generic implementation hosts all assigned decoder families:
  * dense GQA (glm4, deepseek-coder, internlm2, h2o-danube/SWA, qwen2-vl/M-RoPE)
  * MoE (olmoe; deepseek-v2 with MLA + shared experts + leading dense layers)
  * hybrid (hymba: parallel GQA-SWA + Mamba heads per layer, 3 global layers)
  * attn-free (rwkv6: time-mix + channel-mix)

Layers are stacked into a single (L, ...) param pytree and executed with
``lax.scan`` (+ per-layer remat) so the HLO stays compact at 60+ layers;
heterogeneous per-layer behaviour (sliding-window vs global attention) rides
the scan as a traced flag array. Every module is wrapped in
``jax.named_scope`` — these names are the truncation-policy surface of the
profiling engine (core/policy.py).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.distributed.sharding import constrain
from repro.models import attention, moe as moe_mod, ssm
from repro.models.common import (
    ParamDef, ACTIVATIONS, rmsnorm, layernorm, init_tree, abstract_tree,
    axes_tree, count_params,
)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_param_defs(cfg: ArchConfig, d_ff: int) -> dict:
    d = cfg.d_model
    o_scale = 0.02 / math.sqrt(2 * cfg.n_layers)
    mult = 2 if cfg.act == "swiglu" else 1
    return {
        "wi": ParamDef((d, mult * d_ff), ("embed", "mlp")),
        "wo": ParamDef((d_ff, d), ("mlp", "embed"), scale=o_scale),
    }


def mlp_forward(p, x, cfg: ArchConfig):
    with jax.named_scope("mlp"):
        h = x @ p["wi"].astype(x.dtype)
        h = constrain(h, "batch", "seq", "mlp")
        h = ACTIVATIONS[cfg.act](h)
        out = h @ p["wo"].astype(x.dtype)
        return constrain(out, "batch", "seq", "embed")


# ---------------------------------------------------------------------------
# norm dispatch
# ---------------------------------------------------------------------------

def norm_defs(cfg: ArchConfig) -> dict:
    if cfg.norm == "layernorm":
        return {"scale": ParamDef((cfg.d_model,), ("embed",), init="ones"),
                "bias": ParamDef((cfg.d_model,), ("embed",), init="zeros")}
    return {"scale": ParamDef((cfg.d_model,), ("embed",), init="ones")}


def apply_norm(p, x, cfg: ArchConfig):
    if cfg.norm == "layernorm":
        return layernorm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rmsnorm(x, p["scale"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# layer definitions
# ---------------------------------------------------------------------------

def layer_param_defs(cfg: ArchConfig, kind: str) -> dict:
    """kind: 'dense' | 'moe' — which feed-forward the layer carries."""
    defs: Dict[str, Any] = {"norm1": norm_defs(cfg), "norm2": norm_defs(cfg)}
    if cfg.attn_type == "gqa":
        defs["attn"] = attention.gqa_param_defs(cfg)
    elif cfg.attn_type == "mla":
        defs["attn"] = attention.mla_param_defs(cfg)
    elif cfg.attn_type == "hymba":
        defs["attn"] = attention.gqa_param_defs(cfg)
        defs["mamba"] = ssm.mamba_param_defs(cfg)
        defs["branch_norm_attn"] = ParamDef((cfg.d_model,), ("embed",), init="ones")
        defs["branch_norm_ssm"] = ParamDef((cfg.d_model,), ("embed",), init="ones")
        defs["branch_beta"] = ParamDef((2,), (None,), init="ones")
    elif cfg.attn_type == "rwkv6":
        defs["time_mix"] = ssm.rwkv6_param_defs(cfg)
    else:
        raise ValueError(cfg.attn_type)

    if cfg.attn_type == "rwkv6":
        defs["channel_mix"] = ssm.rwkv6_channel_defs(cfg)
    elif kind == "moe":
        defs["moe"] = moe_mod.moe_param_defs(cfg)
    else:
        d_ff = cfg.moe.d_ff_dense if (cfg.moe and cfg.moe.d_ff_dense and
                                      kind == "dense_lead") else cfg.d_ff
        defs["mlp"] = mlp_param_defs(cfg, d_ff)
    return defs


def _seq_mix(cfg: ArchConfig, p, x, positions, is_global, mix_state,
             decode: bool, pos):
    """Dispatch the sequence-mixing block. Returns (y, new_mix_state).
    ``is_global=True`` lifts the sliding window (global-attention layers are
    executed as their own unrolled segments so the window stays static and
    the flash path can skip out-of-window KV blocks)."""
    if cfg.attn_type == "gqa":
        window = None if is_global else cfg.sliding_window
        if decode:
            return attention.gqa_decode(p["attn"], x, mix_state, pos, cfg,
                                        window=window)
        with jax.named_scope("attn"):
            y, _ = attention.gqa_forward(p["attn"], x, cfg, positions=positions,
                                         window=window)
        return y, mix_state

    if cfg.attn_type == "mla":
        if decode:
            return attention.mla_decode(p["attn"], x, mix_state, pos, cfg)
        with jax.named_scope("attn"):
            y, _ = attention.mla_forward(p["attn"], x, cfg, positions=positions)
        return y, mix_state

    if cfg.attn_type == "hymba":
        window = None if is_global else cfg.sliding_window
        if decode:
            ya, kv = attention.gqa_decode(p["attn"], x, mix_state["kv"], pos,
                                          cfg, window=window)
            ym, ms = ssm.mamba_decode(p["mamba"], x, mix_state["mamba"], cfg)
            new_state = {"kv": kv, "mamba": ms}
        else:
            with jax.named_scope("attn"):
                ya, _ = attention.gqa_forward(p["attn"], x, cfg,
                                              positions=positions, window=window)
            with jax.named_scope("mamba"):
                ym, _ = ssm.mamba_forward(p["mamba"], x, cfg)
            new_state = mix_state
        ya = rmsnorm(ya, p["branch_norm_attn"], cfg.norm_eps)
        ym = rmsnorm(ym, p["branch_norm_ssm"], cfg.norm_eps)
        beta = p["branch_beta"].astype(x.dtype)
        return 0.5 * (beta[0] * ya + beta[1] * ym), new_state

    if cfg.attn_type == "rwkv6":
        with jax.named_scope("time_mix"):
            if decode:
                y, x_last, s = ssm._rwkv6_mix(
                    p["time_mix"], x, mix_state["tm_shift"], cfg,
                    mix_state["tm_state"])
                new_state = dict(mix_state, tm_shift=x_last, tm_state=s)
                return y, new_state
            B = x.shape[0]
            x_prev = jnp.zeros((B, 1, x.shape[-1]), x.dtype)
            s0 = jnp.zeros((B, cfg.n_heads, cfg.d_model // cfg.n_heads,
                            cfg.d_model // cfg.n_heads), jnp.float32)
            y, _, _ = ssm._rwkv6_mix(p["time_mix"], x, x_prev, cfg, s0)
            return y, mix_state

    raise ValueError(cfg.attn_type)


def layer_forward(cfg: ArchConfig, p, x, positions, kind: str,
                  is_global=None, mix_state=None, decode: bool = False,
                  pos=None):
    """One decoder layer. Returns (x, new_mix_state)."""
    with jax.named_scope("pre_norm"):
        h = apply_norm(p["norm1"], x, cfg)
    y, new_state = _seq_mix(cfg, p, h, positions, is_global, mix_state,
                            decode, pos)
    x = x + y
    with jax.named_scope("post_norm"):
        h = apply_norm(p["norm2"], x, cfg)
    if cfg.attn_type == "rwkv6":
        with jax.named_scope("channel_mix"):
            if decode:
                y2, cm_last = ssm.rwkv6_channel_mix(
                    p["channel_mix"], h, new_state["cm_shift"], cfg)
                new_state = dict(new_state, cm_shift=cm_last)
            else:
                x_prev = jnp.zeros((h.shape[0], 1, h.shape[-1]), h.dtype)
                y2, _ = ssm.rwkv6_channel_mix(p["channel_mix"], h, x_prev, cfg)
    elif "moe" in p:
        with jax.named_scope("moe"):
            y2 = moe_mod.moe_forward(p["moe"], h, cfg)
    else:
        y2 = mlp_forward(p["mlp"], h, cfg)
    return x + y2, new_state


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------

def _n_lead(cfg: ArchConfig) -> int:
    return cfg.moe.first_k_dense if cfg.moe else 0


def _stack_kind(cfg: ArchConfig) -> str:
    return "moe" if cfg.moe else "dense"


def model_param_defs(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    n_lead = _n_lead(cfg)
    n_stack = cfg.n_layers - n_lead

    def stacked(defs):  # prepend the layer-stack dim to every ParamDef
        return jax.tree_util.tree_map(
            lambda pd: ParamDef((n_stack,) + pd.shape, ("layers",) + pd.axes,
                                pd.init, pd.scale),
            defs, is_leaf=lambda v: isinstance(v, ParamDef))

    defs: Dict[str, Any] = {
        "embed": ParamDef((cfg.vocab, d), ("vocab", "embed"), scale=0.02),
        "final_norm": norm_defs(cfg),
        "layers": stacked(layer_param_defs(cfg, _stack_kind(cfg))),
    }
    if n_lead:
        defs["lead_layers"] = [layer_param_defs(cfg, "dense_lead")
                               for _ in range(n_lead)]
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((d, cfg.vocab), ("embed", "vocab"),
                                   scale=0.02)
    return defs


def segments(cfg: ArchConfig):
    """Execution plan over the scanned stack: homogeneous ("scan", lo, hi)
    runs + unrolled ("global", idx) layers (hymba's 3 full-attention
    layers). Keeps per-segment sliding windows static so the flash path can
    skip out-of-window KV blocks."""
    n_stack = cfg.n_layers - _n_lead(cfg)
    globals_ = sorted(i - _n_lead(cfg) for i in cfg.global_layers
                      if i >= _n_lead(cfg))
    if cfg.sliding_window is None or not globals_:
        return [("scan", 0, n_stack)]
    segs = []
    prev = 0
    for g in globals_:
        if g > prev:
            segs.append(("scan", prev, g))
        segs.append(("global", g, g + 1))
        prev = g + 1
    if prev < n_stack:
        segs.append(("scan", prev, n_stack))
    return segs


def _tree_slice(tree, lo, hi):
    return jax.tree_util.tree_map(lambda t: t[lo:hi], tree)


def _tree_index(tree, i):
    return jax.tree_util.tree_map(lambda t: t[i], tree)


def _embed_inputs(params, batch, cfg: ArchConfig):
    """tokens -> embeddings, or pass through stub-frontend embeddings."""
    if cfg.input_mode == "embeds":
        x = batch["embeds"].astype(jnp.dtype(cfg.dtype))
    else:
        with jax.named_scope("embed"):
            x = params["embed"].astype(jnp.dtype(cfg.dtype))[batch["tokens"]]
    return constrain(x, "batch", "seq", "embed")


def _positions(batch, cfg: ArchConfig, S: int, B: int):
    if cfg.rope_type == "mrope":
        if "positions" in batch:
            return batch["positions"]
        p = jnp.arange(S, dtype=jnp.int32)[None]
        return jnp.broadcast_to(p, (3, B, S))
    return jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))


def forward(params, batch, cfg: ArchConfig, last_only: bool = False):
    """Full forward to logits. batch: tokens/embeds (+labels elsewhere).
    ``last_only`` computes the LM head for the final position only (prefill
    fast path: avoids materializing (B, S, vocab) logits)."""
    x = _embed_inputs(params, batch, cfg)
    B, S = x.shape[:2]
    positions = _positions(batch, cfg, S, B)

    for i in range(_n_lead(cfg)):
        with jax.named_scope(f"lead_layer{i}"):
            x, _ = layer_forward(cfg, params["lead_layers"][i], x, positions,
                                 "dense_lead", is_global=None)

    stack = params["layers"]
    n_stack = cfg.n_layers - _n_lead(cfg)

    def body(x, p_l):
        with jax.named_scope("layer"):
            x, _ = layer_forward(cfg, p_l, x, positions, _stack_kind(cfg),
                                 is_global=False)
        return x, None

    body_fn = jax.checkpoint(body, prevent_cse=False) if cfg.remat else body
    def global_layer(x, p_l):
        with jax.named_scope("global_layer"):
            y, _ = layer_forward(cfg, p_l, x, positions, _stack_kind(cfg),
                                 is_global=True)
        return y

    if cfg.remat:
        global_layer = jax.checkpoint(global_layer, prevent_cse=False)

    if cfg.scan_layers:
        for kind, lo, hi in segments(cfg):
            if kind == "scan":
                x, _ = lax.scan(body_fn, x, _tree_slice(stack, lo, hi))
            else:
                x = global_layer(x, _tree_index(stack, lo))
    else:
        globals_set = {i - _n_lead(cfg) for i in cfg.global_layers}
        for i in range(n_stack):
            with jax.named_scope(f"layer{i}"):
                x, _ = layer_forward(cfg, _tree_index(stack, i), x, positions,
                                     _stack_kind(cfg),
                                     is_global=i in globals_set)

    if last_only:
        x = x[:, -1:]
    with jax.named_scope("final_norm"):
        x = apply_norm(params["final_norm"], x, cfg)
    with jax.named_scope("logits"):
        head = (params["embed"].T if cfg.tie_embeddings
                else params["lm_head"])
        logits = x.astype(jnp.float32) @ head.astype(jnp.float32)
        logits = constrain(logits, "batch", "seq", "vocab")
    return logits


def loss_fn(params, batch, cfg: ArchConfig):
    """Mean token cross-entropy (f32)."""
    logits = forward(params, batch, cfg)
    labels = batch["labels"]
    with jax.named_scope("loss"):
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None].astype(jnp.int32),
                                   axis=-1)[..., 0]
        nll = logz - gold
        mask = batch.get("mask")
        if mask is not None:
            nll = nll * mask
            return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
        return jnp.mean(nll)


# ---------------------------------------------------------------------------
# caches + decode
# ---------------------------------------------------------------------------

def init_layer_cache(cfg: ArchConfig, batch: int, seq_len: int, dtype,
                     window=None):
    if cfg.attn_type == "gqa":
        return attention.gqa_init_cache(cfg, batch, seq_len, dtype,
                                        window=window)
    if cfg.attn_type == "mla":
        return attention.mla_init_cache(cfg, batch, seq_len, dtype)
    if cfg.attn_type == "hymba":
        return {"kv": attention.gqa_init_cache(cfg, batch, seq_len, dtype,
                                               window=window),
                "mamba": ssm.mamba_init_cache(cfg, batch, dtype)}
    if cfg.attn_type == "rwkv6":
        return ssm.rwkv6_init_state(cfg, batch, dtype)
    raise ValueError(cfg.attn_type)


def _stack_caches(one, n):
    return jax.tree_util.tree_map(
        lambda t: jnp.broadcast_to(t[None], (n,) + t.shape).copy(), one)


def init_cache(cfg: ArchConfig, batch: int, seq_len: int):
    """Stacked (L, ...) cache pytree (+ per-lead-layer caches).

    Sliding-window layers get RING caches sized min(seq_len, window) — the
    long-context memory win (a 500k-token danube decode cache shrinks
    window/seq = 128x). Global-attention layers (hymba) keep full-length
    caches in a separate ``global`` list aligned with the execution
    segments.

    ``pos`` is a (batch,) per-slot cursor so a continuous-batching server
    can prefill one slot while others decode; aligned decode simply keeps
    all lanes equal."""
    dtype = jnp.dtype(cfg.dtype)
    n_lead = _n_lead(cfg)
    n_stack = cfg.n_layers - n_lead
    win = cfg.sliding_window
    segs = segments(cfg)
    n_globals = sum(1 for k, _, _ in segs if k == "global")
    cache = {"pos": jnp.zeros((batch,), jnp.int32)}
    if n_globals:
        ring_one = init_layer_cache(cfg, batch, seq_len, dtype, window=win)
        cache["layers"] = _stack_caches(ring_one, n_stack - n_globals)
        cache["global"] = [init_layer_cache(cfg, batch, seq_len, dtype)
                           for _ in range(n_globals)]
    else:
        one = init_layer_cache(cfg, batch, seq_len, dtype, window=win)
        cache["layers"] = _stack_caches(one, n_stack)
    if n_lead:
        cache["lead"] = [init_layer_cache(cfg, batch, seq_len, dtype)
                         for _ in range(n_lead)]
    return cache


def decode_step(params, cache, tokens, cfg: ArchConfig, embeds=None):
    """One decode step. tokens: (B,) int32 (or embeds (B,1,d) for stub
    frontends). Returns (logits (B, vocab), new cache)."""
    dtype = jnp.dtype(cfg.dtype)
    pos = cache["pos"]
    if cfg.input_mode == "embeds" and embeds is not None:
        x = embeds.astype(dtype)
    else:
        with jax.named_scope("embed"):
            x = params["embed"].astype(dtype)[tokens][:, None]
    x = constrain(x, "batch", "seq", "embed")
    B = x.shape[0]
    positions = None  # decode paths derive positions from pos

    new_cache: Dict[str, Any] = {"pos": pos + 1}
    if _n_lead(cfg):
        new_lead = []
        for i in range(_n_lead(cfg)):
            with jax.named_scope(f"lead_layer{i}"):
                x, st = layer_forward(cfg, params["lead_layers"][i], x,
                                      positions, "dense_lead", is_global=None,
                                      mix_state=cache["lead"][i], decode=True,
                                      pos=pos)
            new_lead.append(st)
        new_cache["lead"] = new_lead

    n_stack = cfg.n_layers - _n_lead(cfg)

    def make_body(is_global):
        def body(x, xs):
            p_l, cache_l = xs
            with jax.named_scope("layer"):
                x, st = layer_forward(cfg, p_l, x, positions,
                                      _stack_kind(cfg), is_global=is_global,
                                      mix_state=cache_l, decode=True, pos=pos)
            return x, st
        return body

    if cfg.scan_layers:
        scan_caches = []
        new_globals = []
        c_off = 0          # cursor into the compacted ring-cache stack
        for kind, lo, hi in segments(cfg):
            p_seg = _tree_slice(params["layers"], lo, hi)
            if kind == "scan":
                n_seg = hi - lo
                c_seg = _tree_slice(cache["layers"], c_off, c_off + n_seg)
                x, st = lax.scan(make_body(False), x, (p_seg, c_seg))
                scan_caches.append(st)
                c_off += n_seg
            else:
                c_l = cache["global"][len(new_globals)]
                x, st1 = make_body(True)(x, (_tree_index(params["layers"], lo),
                                             c_l))
                new_globals.append(st1)
        new_stack = (jax.tree_util.tree_map(
            lambda *ts: jnp.concatenate(ts, axis=0), *scan_caches)
            if len(scan_caches) > 1 else scan_caches[0])
        if new_globals:
            new_cache["global"] = new_globals
    else:
        globals_set = {i - _n_lead(cfg) for i in cfg.global_layers}
        outs = []
        new_globals = []
        c_off = 0
        for i in range(n_stack):
            p_l = _tree_index(params["layers"], i)
            if i in globals_set and "global" in cache:
                c_l = cache["global"][len(new_globals)]
                x, st = make_body(True)(x, (p_l, c_l))
                new_globals.append(st)
                continue
            c_l = _tree_index(cache["layers"], c_off)
            x, st = make_body(i in globals_set)(x, (p_l, c_l))
            outs.append(st)
            c_off += 1
        new_stack = jax.tree_util.tree_map(lambda *ts: jnp.stack(ts), *outs)
        if new_globals:
            new_cache["global"] = new_globals
    new_cache["layers"] = new_stack

    with jax.named_scope("final_norm"):
        x = apply_norm(params["final_norm"], x, cfg)
    with jax.named_scope("logits"):
        head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
        logits = x[:, 0].astype(jnp.float32) @ head.astype(jnp.float32)
        logits = constrain(logits, "batch", "vocab")
    return logits, new_cache


def prefill(params, batch, cfg: ArchConfig):
    """Inference forward over a full prompt; returns last-token logits.
    (Cache population for subsequent decode reuses the training path's
    compute shape — the dry-run prefill cell measures this forward.)"""
    logits = forward(params, batch, cfg, last_only=True)
    return logits[:, 0]
