"""Mixture-of-Experts: top-k router + capacity-grouped expert matmuls.

Dispatch strategy (TPU-native, FLOP-faithful): token->expert assignments are
sorted, truncated to a per-expert capacity C = tokens*top_k/E * cf, gathered
into a dense (E, C, d) block and processed with batched einsums — the same
compute shape a grouped-matmul kernel (ragged_dot / Megablox) would see, so
roofline numbers are honest (top_k * tokens * cf useful rows, not E * tokens
as a dense-all-experts formulation would burn). Expert dim shards over the
``model`` mesh axis (EP); GSPMD inserts the token all-to-all.

Router math is f32 (precision-fragile — a profiling target in the paper's
module-truncation study; see benchmarks/table2_memmode.py).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig, MoEConfig
from repro.distributed.sharding import constrain
from repro.models.common import ParamDef, ACTIVATIONS


def moe_param_defs(cfg: ArchConfig) -> dict:
    mc = cfg.moe
    d = cfg.d_model
    o_scale = 0.02 / math.sqrt(2 * cfg.n_layers)
    defs = {
        "router": ParamDef((d, mc.n_experts), ("embed", None)),
        "wi": ParamDef((mc.n_experts, d, 2 * mc.d_expert),
                       ("experts", "embed", "mlp")),
        "wo": ParamDef((mc.n_experts, mc.d_expert, d),
                       ("experts", "mlp", "embed"), scale=o_scale),
    }
    if mc.n_shared:
        defs["shared_wi"] = ParamDef((d, 2 * mc.n_shared * mc.d_expert),
                                     ("embed", "mlp"))
        defs["shared_wo"] = ParamDef((mc.n_shared * mc.d_expert, d),
                                     ("mlp", "embed"), scale=o_scale)
    return defs


def _routing(p, x, mc: MoEConfig):
    """Returns (expert_ids, gates) with shapes (T, k), router probs in f32."""
    logits = (x.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = lax.top_k(probs, mc.top_k)
    if mc.renormalize:
        gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    return ids, gates


def moe_forward(p, x, cfg: ArchConfig, capacity: Optional[int] = None):
    """x: (B, S, d) -> (B, S, d). Capacity-dropped top-k MoE."""
    mc = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, K = mc.n_experts, mc.top_k
    if capacity is None:
        capacity = int(math.ceil(T * K / E * mc.capacity_factor))
        capacity = max(8, -(-capacity // 8) * 8)

    xf = x.reshape(T, d)
    with jax.named_scope("router"):
        ids, gates = _routing(p, xf, mc)              # (T,K)

    with jax.named_scope("dispatch"):
        flat_ids = ids.reshape(-1)                    # (T*K,)
        flat_tok = jnp.repeat(jnp.arange(T), K)       # token index per slot
        order = jnp.argsort(flat_ids, stable=True)
        sorted_ids = flat_ids[order]
        sorted_tok = flat_tok[order]
        counts = jnp.bincount(flat_ids, length=E)
        offsets = jnp.cumsum(counts) - counts          # start of each expert
        pos_in_expert = jnp.arange(T * K) - offsets[sorted_ids]
        keep = pos_in_expert < capacity
        dest = jnp.where(keep, sorted_ids * capacity + pos_in_expert, E * capacity)
        # slot -> source token (sentinel row T = zeros)
        slot_tok = jnp.full((E * capacity + 1,), T, jnp.int32)
        slot_tok = slot_tok.at[dest].set(sorted_tok.astype(jnp.int32),
                                         mode="drop")[:E * capacity]
        x_pad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], axis=0)
        x_grp = x_pad[slot_tok].reshape(E, capacity, d)
        x_grp = constrain(x_grp, "experts", None, "embed")

    with jax.named_scope("experts"):
        h = jnp.einsum("ecd,edf->ecf", x_grp, p["wi"].astype(x.dtype))
        h = constrain(h, "experts", None, "mlp")
        h = ACTIVATIONS["swiglu"](h)
        y_grp = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(x.dtype))
        y_grp = constrain(y_grp, "experts", None, "embed")

    with jax.named_scope("combine"):
        y_flat = y_grp.reshape(E * capacity, d)
        y_flat = jnp.concatenate([y_flat, jnp.zeros((1, d), y_flat.dtype)])
        # per (token, k) slot: value gathered back from its expert slot
        slot_of = jnp.full((T * K,), E * capacity, jnp.int32)
        slot_of = slot_of.at[order].set(
            jnp.where(keep, dest, E * capacity).astype(jnp.int32))
        y_tk = y_flat[slot_of].reshape(T, K, d)
        g = gates.astype(jnp.float32)[..., None]
        y = jnp.sum(y_tk.astype(jnp.float32) * g, axis=1).astype(x.dtype)

    if mc.n_shared:
        with jax.named_scope("shared"):
            hs = ACTIVATIONS["swiglu"](xf @ p["shared_wi"].astype(x.dtype))
            y = y + hs @ p["shared_wo"].astype(x.dtype)

    return y.reshape(B, S, d)


def aux_load_balance_loss(p, x, cfg: ArchConfig) -> jnp.ndarray:
    """Switch-style load-balance auxiliary loss (f32)."""
    mc = cfg.moe
    T = x.shape[0] * x.shape[1]
    xf = x.reshape(T, -1)
    logits = xf.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    _, ids = lax.top_k(probs, mc.top_k)
    occupancy = jnp.mean(
        jax.nn.one_hot(ids, mc.n_experts, dtype=jnp.float32), axis=(0, 1))
    importance = jnp.mean(probs, axis=0)
    return mc.n_experts * jnp.sum(occupancy * importance)
