"""Encoder-decoder backbone (seamless-m4t-large-v2 cell).

The speech frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings (B, T_src, d); the encoder is a bidirectional
transformer stack over them (the conformer conv module is out of backbone
scope — DESIGN.md §5). The text decoder is causal self-attention +
cross-attention; decode shapes exercise the decoder with a growing self-KV
cache and a fixed cross-attention memory.
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.distributed.sharding import constrain
from repro.models import attention
from repro.models.common import ParamDef
from repro.models.transformer import (
    mlp_param_defs, mlp_forward, norm_defs, apply_norm,
)

# fixed source length for decode cells (prompt memory)
CROSS_MEMORY_LEN = 4096


def _enc_layer_defs(cfg: ArchConfig) -> dict:
    return {
        "norm1": norm_defs(cfg),
        "attn": attention.gqa_param_defs(cfg),
        "norm2": norm_defs(cfg),
        "mlp": mlp_param_defs(cfg, cfg.d_ff),
    }


def _dec_layer_defs(cfg: ArchConfig) -> dict:
    return {
        "norm1": norm_defs(cfg),
        "self_attn": attention.gqa_param_defs(cfg),
        "norm_x": norm_defs(cfg),
        "cross_attn": attention.gqa_param_defs(cfg),
        "norm2": norm_defs(cfg),
        "mlp": mlp_param_defs(cfg, cfg.d_ff),
    }


def _stacked(defs, n):
    return jax.tree_util.tree_map(
        lambda pd: ParamDef((n,) + pd.shape, ("layers",) + pd.axes,
                            pd.init, pd.scale),
        defs, is_leaf=lambda v: isinstance(v, ParamDef))


def model_param_defs(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    return {
        "embed": ParamDef((cfg.vocab, d), ("vocab", "embed")),
        "enc_layers": _stacked(_enc_layer_defs(cfg), cfg.enc_layers),
        "enc_norm": norm_defs(cfg),
        "dec_layers": _stacked(_dec_layer_defs(cfg), cfg.n_layers),
        "final_norm": norm_defs(cfg),
        "lm_head": ParamDef((d, cfg.vocab), ("embed", "vocab")),
    }


def encode(params, src_embeds, cfg: ArchConfig):
    x = constrain(src_embeds.astype(jnp.dtype(cfg.dtype)),
                  "batch", "seq", "embed")
    B, T = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))

    def body(x, p_l):
        with jax.named_scope("enc_layer"):
            h = apply_norm(p_l["norm1"], x, cfg)
            with jax.named_scope("self_attn"):
                y, _ = attention.gqa_forward(p_l["attn"], h, cfg,
                                             positions=positions, causal=False)
            x = x + y
            h = apply_norm(p_l["norm2"], x, cfg)
            x = x + mlp_forward(p_l["mlp"], h, cfg)
        return x, None

    body_fn = jax.checkpoint(body, prevent_cse=False) if cfg.remat else body
    x, _ = lax.scan(body_fn, x, params["enc_layers"])
    with jax.named_scope("enc_norm"):
        return apply_norm(params["enc_norm"], x, cfg)


def _cross_attend(p, x, memory, cfg: ArchConfig):
    """q from decoder x, kv from encoder memory (non-causal)."""
    B, S, _ = x.shape
    T = memory.shape[1]
    hd = cfg.resolved_head_dim
    H, Hkv = cfg.n_heads, cfg.n_kv_heads
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    k = (memory @ p["wk"].astype(x.dtype)).reshape(B, T, Hkv, hd).transpose(0, 2, 1, 3)
    v = (memory @ p["wv"].astype(x.dtype)).reshape(B, T, Hkv, hd).transpose(0, 2, 1, 3)
    o = attention.flash_attention(
        q, k, v, causal=False,
        q_chunk=min(1024, S), kv_chunk=min(1024, T))
    o = o.transpose(0, 2, 1, 3).reshape(B, S, -1)
    return o @ p["wo"].astype(x.dtype)


def _dec_layer(cfg, p_l, x, memory, positions, mix_state=None,
               decode=False, pos=None, cross_kv=None):
    h = apply_norm(p_l["norm1"], x, cfg)
    if decode:
        y, new_kv = attention.gqa_decode(p_l["self_attn"], h, mix_state, pos,
                                         cfg)
    else:
        with jax.named_scope("self_attn"):
            y, _ = attention.gqa_forward(p_l["self_attn"], h, cfg,
                                         positions=positions, causal=True)
        new_kv = mix_state
    x = x + y
    h = apply_norm(p_l["norm_x"], x, cfg)
    with jax.named_scope("cross_attn"):
        if decode:
            k, v = cross_kv
            o = attention.decode_attention(
                (h[:, 0] @ p_l["cross_attn"]["wq"].astype(h.dtype)).reshape(
                    h.shape[0], cfg.n_heads, cfg.resolved_head_dim),
                k, v, jnp.int32(k.shape[2]))
            y = (o.reshape(h.shape[0], 1, -1)
                 @ p_l["cross_attn"]["wo"].astype(h.dtype))
        else:
            y = _cross_attend(p_l["cross_attn"], h, memory, cfg)
    x = x + y
    h = apply_norm(p_l["norm2"], x, cfg)
    return x + mlp_forward(p_l["mlp"], h, cfg), new_kv


def forward(params, batch, cfg: ArchConfig, last_only: bool = False):
    """batch: src_embeds (B,T,d), tokens (B,S) -> logits (B,S,V)."""
    memory = encode(params, batch["src_embeds"], cfg)
    dtype = jnp.dtype(cfg.dtype)
    with jax.named_scope("embed"):
        x = params["embed"].astype(dtype)[batch["tokens"]]
    x = constrain(x, "batch", "seq", "embed")
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(x, p_l):
        with jax.named_scope("dec_layer"):
            x, _ = _dec_layer(cfg, p_l, x, memory, positions)
        return x, None

    body_fn = jax.checkpoint(body, prevent_cse=False) if cfg.remat else body
    x, _ = lax.scan(body_fn, x, params["dec_layers"])
    if last_only:
        x = x[:, -1:]
    with jax.named_scope("final_norm"):
        x = apply_norm(params["final_norm"], x, cfg)
    with jax.named_scope("logits"):
        logits = x.astype(jnp.float32) @ params["lm_head"].astype(jnp.float32)
        return constrain(logits, "batch", "seq", "vocab")


def loss_fn(params, batch, cfg: ArchConfig):
    logits = forward(params, batch, cfg)
    labels = batch["labels"]
    with jax.named_scope("loss"):
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
        return jnp.mean(logz - gold)


def init_cache(cfg: ArchConfig, batch: int, seq_len: int,
               memory_len: int = CROSS_MEMORY_LEN):
    """Decoder self-KV cache + per-layer cross K/V (computed at prefill;
    zeros stand in for the dry-run)."""
    dtype = jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim
    self_kv = attention.gqa_init_cache(cfg, batch, seq_len, dtype)
    stack = jax.tree_util.tree_map(
        lambda t: jnp.broadcast_to(t[None], (cfg.n_layers,) + t.shape).copy(),
        self_kv)
    cross_shape = (cfg.n_layers, batch, cfg.n_kv_heads, memory_len, hd)
    return {
        "layers": stack,
        "cross_k": jnp.zeros(cross_shape, dtype),
        "cross_v": jnp.zeros(cross_shape, dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def decode_step(params, cache, tokens, cfg: ArchConfig):
    dtype = jnp.dtype(cfg.dtype)
    pos = cache["pos"]
    with jax.named_scope("embed"):
        x = params["embed"].astype(dtype)[tokens][:, None]
    x = constrain(x, "batch", "seq", "embed")

    def body(x, xs):
        p_l, kv_l, ck, cv = xs
        with jax.named_scope("dec_layer"):
            x, new_kv = _dec_layer(cfg, p_l, x, None, None, mix_state=kv_l,
                                   decode=True, pos=pos, cross_kv=(ck, cv))
        return x, new_kv

    x, new_stack = lax.scan(
        body, x, (params["dec_layers"], cache["layers"],
                  cache["cross_k"], cache["cross_v"]))
    with jax.named_scope("final_norm"):
        x = apply_norm(params["final_norm"], x, cfg)
    logits = x[:, 0].astype(jnp.float32) @ params["lm_head"].astype(jnp.float32)
    new_cache = dict(cache, layers=new_stack, pos=pos + 1)
    return constrain(logits, "batch", "vocab"), new_cache
