"""Shared model building blocks: param definitions, norms, RoPE family."""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain


# --------------------------------------------------------------------------
# parameter definitions: one source of truth for shape + logical axes + init
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]   # logical axis names, len == rank
    init: str = "normal"              # normal | zeros | ones | small_normal
    scale: float = 0.02

    def initializer(self, key, dtype):
        if self.init == "zeros":
            return jnp.zeros(self.shape, dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, dtype)
        std = self.scale
        return (jax.random.normal(key, self.shape, jnp.float32) * std).astype(dtype)


def init_tree(defs, key, dtype):
    """Initialize a pytree of ParamDef into arrays (deterministic per path)."""
    leaves, treedef = jax.tree_util.tree_flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(key, len(leaves))
    arrs = [d.initializer(k, dtype) for d, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, arrs)


def abstract_tree(defs, dtype):
    """ShapeDtypeStruct pytree (no allocation) for dry-runs."""
    return jax.tree_util.tree_map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype),
        defs, is_leaf=lambda x: isinstance(x, ParamDef))


def axes_tree(defs):
    return jax.tree_util.tree_map(
        lambda d: d.axes, defs, is_leaf=lambda x: isinstance(x, ParamDef))


def count_params(defs) -> int:
    leaves = jax.tree_util.tree_leaves(
        defs, is_leaf=lambda x: isinstance(x, ParamDef))
    return sum(math.prod(d.shape) for d in leaves)


# --------------------------------------------------------------------------
# norms (f32 internal math regardless of activation dtype)
# --------------------------------------------------------------------------

def rmsnorm(x, scale, eps: float = 1e-5):
    with jax.named_scope("rmsnorm"):
        xf = x.astype(jnp.float32)
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
        return (y * scale.astype(jnp.float32)).astype(x.dtype)


def layernorm(x, scale, bias, eps: float = 1e-5):
    with jax.named_scope("layernorm"):
        xf = x.astype(jnp.float32)
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------
# activations
# --------------------------------------------------------------------------

def swiglu(gate_up):
    gate, up = jnp.split(gate_up, 2, axis=-1)
    return jax.nn.silu(gate) * up


ACTIVATIONS = {
    "swiglu": swiglu,                    # expects fused (…, 2*d_ff)
    "gelu": jax.nn.gelu,
    "relu": jax.nn.relu,
}


# --------------------------------------------------------------------------
# RoPE family: standard, partial, and M-RoPE (Qwen2-VL)
# --------------------------------------------------------------------------

def rope_freqs(rotary_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, rotary_dim, 2, dtype=jnp.float32)
                            / rotary_dim))


def _rotate(x, sin, cos):
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_rope(x, positions, *, theta: float = 1e4, fraction: float = 1.0):
    """x: (B, H, S, D); positions: (B, S) int. Rotary applied to the first
    ``fraction`` of D (GLM-4 uses 0.5)."""
    d = x.shape[-1]
    rd = int(d * fraction)
    rd -= rd % 2
    if rd == 0:
        return x
    inv = rope_freqs(rd, theta)                                  # (rd/2,)
    ang = positions.astype(jnp.float32)[:, None, :, None] * inv  # (B,1,S,rd/2)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    xr, xp = x[..., :rd], x[..., rd:]
    xr = _rotate(xr.astype(jnp.float32), sin, cos).astype(x.dtype)
    return jnp.concatenate([xr, xp], axis=-1) if rd < d else xr


def apply_mrope(x, positions, *, theta: float, sections: Sequence[int]):
    """Multimodal RoPE (Qwen2-VL): ``positions`` is (3, B, S) for the
    temporal/height/width indices; ``sections`` split the rd/2 frequency
    channels among the three position streams."""
    d = x.shape[-1]
    rd = 2 * sum(sections)
    assert rd <= d, (rd, d)
    inv = rope_freqs(rd, theta)                                   # (rd/2,)
    ang_tHW = positions.astype(jnp.float32)[:, :, None, :, None] * inv
    # select per-channel which stream drives the angle
    stream = jnp.repeat(jnp.arange(3), jnp.asarray(sections),
                        total_repeat_length=rd // 2)              # (rd/2,)
    ang = jnp.take_along_axis(
        ang_tHW, stream[None, None, None, None, :].astype(jnp.int32),
        axis=0)[0]                                                # (B,1,S,rd/2)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    xr, xp = x[..., :rd], x[..., rd:]
    xr = _rotate(xr.astype(jnp.float32), sin, cos).astype(x.dtype)
    return jnp.concatenate([xr, xp], axis=-1) if rd < d else xr
