"""State-space sequence mixers: Mamba (Hymba's parallel SSM heads) and
RWKV-6 "Finch" (data-dependent decay linear attention).

Both are implemented as linear recurrences scanned over the sequence for the
reference path; the chunked RWKV-6 Pallas kernel in
``repro.kernels.rwkv6`` implements the identical contract for TPU. Decode
paths carry O(1)-per-token state — this is why these archs run the
``long_500k`` cell (DESIGN.md §5).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.distributed.sharding import constrain
from repro.models.common import ParamDef, rmsnorm


# ---------------------------------------------------------------------------
# Mamba (selective SSM) — Hymba's parallel-head branch
# ---------------------------------------------------------------------------

def mamba_param_defs(cfg: ArchConfig) -> dict:
    sc = cfg.ssm
    d = cfg.d_model
    di = sc.expand * d
    dt_rank = sc.dt_rank or -(-d // 16)
    o_scale = 0.02 / math.sqrt(2 * cfg.n_layers)
    return {
        "in_proj": ParamDef((d, 2 * di), ("embed", "mlp")),
        "conv_w": ParamDef((sc.conv_width, di), ("conv", "mlp"), scale=0.1),
        "conv_b": ParamDef((di,), ("mlp",), init="zeros"),
        "x_proj": ParamDef((di, dt_rank + 2 * sc.state_dim), ("mlp", None)),
        "dt_proj": ParamDef((dt_rank, di), (None, "mlp"), scale=0.1),
        "dt_bias": ParamDef((di,), ("mlp",), init="zeros"),
        "a_log": ParamDef((di, sc.state_dim), ("mlp", "state"), init="zeros"),
        "d_skip": ParamDef((di,), ("mlp",), init="ones"),
        "out_proj": ParamDef((di, d), ("mlp", "embed"), scale=o_scale),
    }


def _mamba_core(p, xz, cfg: ArchConfig, conv_state, ssm_state, *, decode: bool):
    """xz: (B, S, 2*di). Returns (y (B,S,di), conv_state, ssm_state)."""
    sc = cfg.ssm
    di = sc.expand * cfg.d_model
    dt_rank = sc.dt_rank or -(-cfg.d_model // 16)
    x, z = jnp.split(xz, 2, axis=-1)
    B_, S, _ = x.shape

    # causal depthwise conv (width W): state carries the last W-1 inputs
    W = sc.conv_width
    if decode:
        hist = jnp.concatenate([conv_state, x], axis=1)        # (B, W, di)
        new_conv_state = hist[:, 1:]
        xc = jnp.einsum("bwd,wd->bd", hist, p["conv_w"].astype(x.dtype))[:, None]
    else:
        pad = jnp.zeros((B_, W - 1, di), x.dtype)
        hist = jnp.concatenate([pad, x], axis=1)
        new_conv_state = hist[:, S:]                            # last W-1
        xc = sum(hist[:, i:i + S] * p["conv_w"][i].astype(x.dtype)
                 for i in range(W))
    xc = jax.nn.silu(xc + p["conv_b"].astype(x.dtype))

    proj = xc @ p["x_proj"].astype(x.dtype)                     # (B,S,r+2N)
    dt_r, Bc, Cc = jnp.split(proj, [dt_rank, dt_rank + sc.state_dim], axis=-1)
    dt = jax.nn.softplus(dt_r @ p["dt_proj"].astype(x.dtype)
                         + p["dt_bias"].astype(x.dtype))        # (B,S,di)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))                # (di,N)

    f32 = jnp.float32

    def step(h, t):
        da_t, dbx_t, c_t = t
        h = da_t * h + dbx_t                                    # (B,di,N)
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    if decode:
        da = jnp.exp(dt.astype(f32)[..., None] * A)             # (B,1,di,N)
        db_x = (dt.astype(f32) * xc.astype(f32))[..., None] \
            * Bc.astype(f32)[..., None, :]
        ssm_state, y1 = step(ssm_state.astype(f32),
                             (da[:, 0], db_x[:, 0], Cc.astype(f32)[:, 0]))
        y = y1[:, None].astype(x.dtype)
    else:
        # chunked over the sequence: bounds the (B,c,di,N) working set and
        # (with per-chunk remat) caps autodiff residuals at one chunk
        c = min(64, S)
        assert S % c == 0, (S, c)
        nch = S // c

        @jax.checkpoint
        def chunk_body(h, t):
            dt_c, xc_c, b_c, cc_c = t                           # (B,c,...)
            da = jnp.exp(dt_c.astype(f32)[..., None] * A)       # (B,c,di,N)
            dbx = (dt_c.astype(f32) * xc_c.astype(f32))[..., None] \
                * b_c.astype(f32)[..., None, :]
            h, ys = lax.scan(step, h,
                             (da.transpose(1, 0, 2, 3),
                              dbx.transpose(1, 0, 2, 3),
                              cc_c.astype(f32).transpose(1, 0, 2)))
            return h, ys.transpose(1, 0, 2)                     # (B,c,di)

        chunks = lambda t: t.reshape(B_, nch, c, *t.shape[2:]).transpose(
            1, 0, 2, *range(3, t.ndim + 1))
        ssm_state, ys = lax.scan(
            chunk_body, ssm_state.astype(f32),
            (chunks(dt), chunks(xc), chunks(Bc), chunks(Cc)))
        y = ys.transpose(1, 0, 2, 3).reshape(B_, S, di).astype(x.dtype)

    y = y + xc * p["d_skip"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    return y, new_conv_state, ssm_state


def mamba_forward(p, x, cfg: ArchConfig):
    """Training/prefill: x (B,S,d) -> (y (B,S,di->d), final states)."""
    sc = cfg.ssm
    di = sc.expand * cfg.d_model
    B = x.shape[0]
    xz = x @ p["in_proj"].astype(x.dtype)
    conv0 = jnp.zeros((B, sc.conv_width - 1, di), x.dtype)
    ssm0 = jnp.zeros((B, di, sc.state_dim), jnp.float32)
    y, conv_state, ssm_state = _mamba_core(p, xz, cfg, conv0, ssm0, decode=False)
    out = y @ p["out_proj"].astype(x.dtype)
    return out, {"conv": conv_state, "ssm": ssm_state}


def mamba_decode(p, x1, cache, cfg: ArchConfig):
    xz = x1 @ p["in_proj"].astype(x1.dtype)
    y, conv_state, ssm_state = _mamba_core(
        p, xz, cfg, cache["conv"], cache["ssm"], decode=True)
    out = y @ p["out_proj"].astype(x1.dtype)
    return out, {"conv": conv_state, "ssm": ssm_state}


def mamba_init_cache(cfg: ArchConfig, batch: int, dtype):
    sc = cfg.ssm
    di = sc.expand * cfg.d_model
    return {
        "conv": jnp.zeros((batch, sc.conv_width - 1, di), dtype),
        "ssm": jnp.zeros((batch, di, sc.state_dim), jnp.float32),
    }


# ---------------------------------------------------------------------------
# RWKV-6 (Finch)
# ---------------------------------------------------------------------------

_LORA_DIM = 64


def rwkv6_param_defs(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    o_scale = 0.02 / math.sqrt(2 * cfg.n_layers)
    H = cfg.n_heads if cfg.n_heads else d // 64
    hd = d // H
    return {
        # token-shift interpolation vectors for r,k,v,w,g
        "mu": ParamDef((5, d), (None, "embed"), scale=0.1),
        "wr": ParamDef((d, d), ("embed", "heads")),
        "wk": ParamDef((d, d), ("embed", "heads")),
        "wv": ParamDef((d, d), ("embed", "heads")),
        "wg": ParamDef((d, d), ("embed", "heads")),
        # data-dependent decay LoRA:  w = exp(-exp(w0 + tanh(x A) B))
        "w0": ParamDef((d,), ("embed",), init="zeros"),
        "w_a": ParamDef((d, _LORA_DIM), ("embed", None), scale=0.1),
        "w_b": ParamDef((_LORA_DIM, d), (None, "embed"), scale=0.1),
        "bonus": ParamDef((H, hd), ("heads", None), scale=0.1),
        "ln_scale": ParamDef((d,), ("embed",), init="ones"),
        "wo": ParamDef((d, d), ("heads", "embed"), scale=o_scale),
    }


def _rwkv6_mix(p, x, x_prev, cfg: ArchConfig, state):
    """Sequence mix. x: (B,S,d); x_prev: (B,1,d) last token of the previous
    chunk (token shift); state: (B,H,hd,hd) f32. Returns (y, x_last, state)."""
    B, S, d = x.shape
    H = cfg.n_heads if cfg.n_heads else d // 64
    hd = d // H

    xs = jnp.concatenate([x_prev, x[:, :-1]], axis=1)           # shifted
    mu = p["mu"].astype(x.dtype)
    xr, xk, xv, xw, xg = (x + mu[i] * (xs - x) for i in range(5))

    r = (xr @ p["wr"].astype(x.dtype)).reshape(B, S, H, hd)
    k = (xk @ p["wk"].astype(x.dtype)).reshape(B, S, H, hd)
    v = (xv @ p["wv"].astype(x.dtype)).reshape(B, S, H, hd)
    g = jax.nn.silu(xg @ p["wg"].astype(x.dtype))

    f32 = jnp.float32
    w_log = p["w0"].astype(f32) + jnp.tanh(
        xw.astype(f32) @ p["w_a"].astype(f32)) @ p["w_b"].astype(f32)
    w = jnp.exp(-jnp.exp(w_log)).reshape(B, S, H, hd)           # decay in (0,1)
    u = p["bonus"].astype(f32)                                  # (H,hd)

    def step(s, t):
        r_t, k_t, v_t, w_t = t                                  # (B,H,hd)
        kv = k_t[..., :, None] * v_t[..., None, :]              # (B,H,hd,hd)
        y = jnp.einsum("bhi,bhij->bhj", r_t, s + u[..., None] * kv)
        s = w_t[..., None] * s + kv
        return s, y

    # chunked over sequence with per-chunk remat (matches the Pallas kernel's
    # chunk structure; caps autodiff residuals at one chunk of states)
    c = min(64, S)
    assert S % c == 0, (S, c)
    nch = S // c

    @jax.checkpoint
    def chunk_body(s, t):
        r_c, k_c, v_c, w_c = t                                  # (c,B,H,hd)
        s, ys = lax.scan(step, s, (r_c, k_c, v_c, w_c))
        return s, ys

    def chunks(t):  # (B,S,H,hd) -> (nch,c,B,H,hd)
        return t.astype(f32).transpose(1, 0, 2, 3).reshape(
            nch, c, B, H, hd)

    state, ys = lax.scan(chunk_body, state.astype(f32),
                         (chunks(r), chunks(k), chunks(v), chunks(w)))
    y = ys.reshape(S, B, H, hd).transpose(1, 0, 2, 3).reshape(B, S, d)

    # per-head group norm (RWKV uses GroupNorm(H); rms per head here)
    yh = y.reshape(B, S, H, hd).astype(f32)
    yh = yh * lax.rsqrt(jnp.mean(yh * yh, axis=-1, keepdims=True) + 1e-5)
    y = (yh.reshape(B, S, d) * p["ln_scale"].astype(f32)).astype(x.dtype)
    y = y * g
    out = y @ p["wo"].astype(x.dtype)
    return out, x[:, -1:], state


def rwkv6_channel_defs(cfg: ArchConfig) -> dict:
    d, dff = cfg.d_model, cfg.d_ff
    o_scale = 0.02 / math.sqrt(2 * cfg.n_layers)
    return {
        "mu": ParamDef((2, d), (None, "embed"), scale=0.1),
        "wk": ParamDef((d, dff), ("embed", "mlp")),
        "wv": ParamDef((dff, d), ("mlp", "embed"), scale=o_scale),
        "wr": ParamDef((d, d), ("embed", None)),
    }


def rwkv6_channel_mix(p, x, x_prev, cfg: ArchConfig):
    xs = jnp.concatenate([x_prev, x[:, :-1]], axis=1)
    mu = p["mu"].astype(x.dtype)
    xk = x + mu[0] * (xs - x)
    xr = x + mu[1] * (xs - x)
    k = jnp.square(jax.nn.relu(xk @ p["wk"].astype(x.dtype)))
    kv = k @ p["wv"].astype(x.dtype)
    return jax.nn.sigmoid(xr @ p["wr"].astype(x.dtype)) * kv, x[:, -1:]


def rwkv6_init_state(cfg: ArchConfig, batch: int, dtype):
    d = cfg.d_model
    H = cfg.n_heads if cfg.n_heads else d // 64
    hd = d // H
    return {
        "tm_state": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "tm_shift": jnp.zeros((batch, 1, d), dtype),
        "cm_shift": jnp.zeros((batch, 1, d), dtype),
    }
