"""Fault tolerance + elasticity scaffolding for multi-pod runs.

What is mechanically testable on this CPU container is tested
(tests/test_checkpoint_ft.py): checkpoint/restart equivalence, elastic
re-shard onto a different mesh shape, data-cursor resume determinism, and
the supervisor retry loop. The pieces that need real fleets are implemented
as thin, documented seams:

  * **Node failure detection** — on Cloud TPU, a died worker surfaces as a
    collective timeout; `run_supervised` wraps the step loop, catches the
    configured exception classes, restores the latest durable checkpoint and
    re-enters the loop. At 1000+ nodes the restart path is identical — JAX
    re-initializes the runtime with the surviving slice topology via
    ``jax.distributed.initialize`` and the elastic re-mesh below.
  * **Elastic scaling** — ``remesh`` builds a new mesh from the currently
    visible device set (possibly fewer pods) and re-shards a checkpoint onto
    it; the data pipeline's step cursor keeps batches aligned.
  * **Straggler mitigation** — within a step, XLA collectives are bulk-
    synchronous; mitigation happens across steps: the supervisor tracks a
    rolling p50 step time and flags hosts exceeding ``straggle_factor`` x
    p50 so the scheduler can evict them at the next restart boundary
    (`StragglerMonitor`). This is the standard TPU-fleet pattern (no
    in-step work stealing on a synchronous mesh).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

import jax
from jax.sharding import Mesh

from repro import compat


def best_mesh_shape(n_devices: int, model_parallel: int) -> Tuple[int, int]:
    """Largest (data, model) grid for the currently visible devices."""
    model = model_parallel
    while model > 1 and n_devices % model:
        model //= 2
    return n_devices // model, model


def remesh(model_parallel: int = 16, axis_names=("data", "model")) -> Mesh:
    devs = jax.devices()
    data, model = best_mesh_shape(len(devs), model_parallel)
    return compat.make_mesh((data, model), axis_names)


@dataclasses.dataclass
class StragglerMonitor:
    straggle_factor: float = 2.0
    window: int = 50
    _times: List[float] = dataclasses.field(default_factory=list)

    def record(self, seconds: float) -> bool:
        """Returns True when this step straggled vs the rolling median."""
        self._times.append(seconds)
        if len(self._times) > self.window:
            self._times.pop(0)
        if len(self._times) < 5:
            return False
        return seconds > self.straggle_factor * float(np.median(self._times))


@dataclasses.dataclass
class SupervisorConfig:
    max_restarts: int = 10
    save_every: int = 100
    retry_exceptions: Tuple = (RuntimeError,)  # jaxlib collective timeouts etc.


def run_supervised(step_fn: Callable[[int], float],
                   save_fn: Callable[[int], None],
                   restore_fn: Callable[[], int],
                   total_steps: int,
                   cfg: SupervisorConfig = SupervisorConfig(),
                   monitor: Optional[StragglerMonitor] = None):
    """Checkpoint-restart supervisor. ``step_fn(step) -> loss`` runs one
    step; ``restore_fn() -> step`` reloads the latest durable state.
    Returns (final_step, n_restarts, straggle_count)."""
    restarts = 0
    straggles = 0
    step = restore_fn()
    while step < total_steps:
        try:
            t0 = time.perf_counter()
            step_fn(step)
            dt = time.perf_counter() - t0
            if monitor is not None and monitor.record(dt):
                straggles += 1
            step += 1
            if step % cfg.save_every == 0 or step == total_steps:
                save_fn(step)
        except cfg.retry_exceptions:
            restarts += 1
            if restarts > cfg.max_restarts:
                raise
            step = restore_fn()
    return step, restarts, straggles
