"""Logical-axis sharding rules (FSDP x TP x EP x (pod)DP).

Models annotate activations with *logical* axis names via ``constrain``;
parameters carry logical axes in their ``ParamDef``. A ``ShardingRules``
table maps logical names onto mesh axes at lower time. When no mesh is
active (CPU smoke tests) every annotation is a no-op.

Conventions (see DESIGN.md §6):
  activations:  batch -> (pod?, data), heads/kv/mlp/experts -> model,
                embed/seq -> replicated (seq -> model for long-context KV
                caches: context parallelism)
  parameters:   embed -> data (FSDP), heads/mlp/vocab/experts -> model,
                layer stack dim -> replicated
Divisibility guard: an annotation on a dim not divisible by its mesh axis is
dropped (e.g. kv_heads=2 on a 16-way model axis falls back to replicated and
attention re-shards on q-heads instead).
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
import threading
from typing import Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]

# logical name -> mesh axis (or tuple) for ACTIVATIONS
DEFAULT_ACT_RULES = {
    "batch": ("pod", "data"),
    "seq": None,
    "cache_seq": "model",   # context-parallel KV cache for decode
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "qk": None,
    "mlp": "model",
    "experts": "model",
    "vocab": "model",
    "state": None,
}

# logical name -> mesh axis for PARAMETERS (training: FSDP x TP)
DEFAULT_PARAM_RULES = {
    "layers": None,
    "embed": "data",        # FSDP
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "experts": "model",
    "vocab": "model",
    "qk": None,
    "state": None,
    "conv": None,
}


# serving: TP-only — no per-layer FSDP all-gathers on the decode critical
# path (used when bf16 params fit a single model-parallel shard group)
SERVE_PARAM_RULES = {**DEFAULT_PARAM_RULES, "embed": None}


@dataclasses.dataclass
class ShardingContext:
    mesh: Optional[Mesh] = None
    act_rules: dict = dataclasses.field(default_factory=lambda: dict(DEFAULT_ACT_RULES))
    param_rules: dict = dataclasses.field(default_factory=lambda: dict(DEFAULT_PARAM_RULES))


_state = threading.local()


def _ctx() -> ShardingContext:
    if not hasattr(_state, "ctx"):
        _state.ctx = ShardingContext()
    return _state.ctx


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], act_rules: Optional[dict] = None,
             param_rules: Optional[dict] = None):
    old = getattr(_state, "ctx", None)
    _state.ctx = ShardingContext(
        mesh=mesh,
        act_rules=dict(act_rules or DEFAULT_ACT_RULES),
        param_rules=dict(param_rules or DEFAULT_PARAM_RULES),
    )
    try:
        if mesh is not None:
            with mesh:
                yield _state.ctx
        else:
            yield _state.ctx
    finally:
        if old is None:
            del _state.ctx
        else:
            _state.ctx = old


def _mesh_axis_size(mesh: Mesh, axis: Axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, str):
        return mesh.shape[axis] if axis in mesh.shape else 0
    return math.prod(mesh.shape[a] for a in axis if a in mesh.shape)


def _resolve(mesh: Mesh, rules: dict, logical: Tuple[Optional[str], ...],
             shape: Tuple[int, ...]) -> P:
    spec = []
    used = set()
    for name, dim in zip(logical, shape):
        axis = rules.get(name) if name is not None else None
        if axis is not None:
            if isinstance(axis, tuple):
                axis = tuple(a for a in axis if a in mesh.shape and a not in used)
                axis = axis or None
            elif axis not in mesh.shape or axis in used:
                axis = None
        if axis is not None:
            size = _mesh_axis_size(mesh, axis)
            if size <= 1 or dim % size != 0:
                axis = None  # divisibility guard
        if axis is not None:
            for a in (axis if isinstance(axis, tuple) else (axis,)):
                used.add(a)
        if isinstance(axis, tuple) and len(axis) == 1:
            axis = axis[0]  # P(('x',)) != P('x') on older jax
        spec.append(axis)
    while spec and spec[-1] is None:
        spec.pop()
    return P(*spec)


def constrain(x, *logical: Optional[str]):
    """Apply a logical-axis sharding constraint to an activation. No-op
    without an active mesh (single-device smoke tests)."""
    ctx = _ctx()
    if ctx.mesh is None or ctx.mesh.size == 1:
        return x
    if len(logical) != x.ndim:
        raise ValueError(f"rank mismatch: {logical} vs {x.shape}")
    spec = _resolve(ctx.mesh, ctx.act_rules, logical, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


def param_sharding(shape: Tuple[int, ...], logical: Tuple[Optional[str], ...],
                   mesh: Mesh) -> NamedSharding:
    ctx = _ctx()
    spec = _resolve(mesh, ctx.param_rules, logical, shape)
    return NamedSharding(mesh, spec)


def param_pspec(shape, logical, mesh) -> P:
    return _resolve(_ctx().mesh or mesh, _ctx().param_rules, logical, shape)


# ---------------------------------------------------------------------------
# profiling-stack shardings (mesh-parallel truncate / mem-mode / autosearch)
# ---------------------------------------------------------------------------
# The sharded profiling path partitions work along exactly two axes:
#   * the CANDIDATE axis — the leading K axis of a (K, num_sites, 4) format
#     table batch. Each candidate policy is independent, so sharding K over
#     `probe_axis` evaluates K/ndev candidates per device concurrently.
#   * the DATA axis — ordinary data parallelism over the profiled inputs.
# The (num_sites, 4) table rows themselves are always replicated: every
# device sees its candidates' full site tables.

def replicated(mesh: Mesh) -> NamedSharding:
    """Fully-replicated sharding (format tables, small operands)."""
    return NamedSharding(mesh, P())


def probe_sharding(mesh: Mesh, axis: str = "probe") -> NamedSharding:
    """Shard the leading candidate axis of a table batch over ``axis``.

    Falls back to replication when the mesh has no such axis (so a
    data-only mesh can still call the sharded entry points)."""
    if axis not in mesh.shape:
        return replicated(mesh)
    return NamedSharding(mesh, P(axis))


def batch_sharding(mesh: Mesh, axis: str = "data") -> NamedSharding:
    """Shard the leading (batch) dim of profiled inputs over ``axis``."""
    if axis not in mesh.shape:
        return replicated(mesh)
    return NamedSharding(mesh, P(axis))


def probe_axis_size(mesh: Optional[Mesh], axis: str = "probe") -> int:
    """Number of shards the candidate axis is split into (1 = unsharded)."""
    if mesh is None or axis not in mesh.shape:
        return 1
    return int(mesh.shape[axis])


def pad_to_shards(n: int, mesh: Optional[Mesh], axis: str = "probe") -> int:
    """Round a candidate-batch width up so the leading axis divides evenly
    across the mesh's ``axis`` (GSPMD rejects uneven named shardings)."""
    size = probe_axis_size(mesh, axis)
    return -(-n // size) * size


def drop_padded_rows(tree, n_real: int):
    """Slice identity-padded rows off the leading (candidate) axis of every
    leaf of a batched result. Padded probes exist only to satisfy the fixed
    sharded signature — they must be masked out before results are read,
    compared, or merged, so padded and unpadded paths stay bit-identical."""
    return jax.tree_util.tree_map(lambda a: a[:n_real], tree)


def _is_sharding_leaf(x) -> bool:
    return (x is None or isinstance(x, P)
            or isinstance(x, jax.sharding.Sharding))


def flatten_arg_shardings(mesh: Optional[Mesh], in_shardings,
                          args, kwargs) -> Optional[list]:
    """Resolve a user-facing ``in_shardings`` to the flat per-leaf list the
    profiling callables jit with (their traced signature is one flat list
    of input leaves, not the original arguments).

    ``in_shardings`` follows jit's convention: a single sharding /
    ``PartitionSpec`` / ``None`` broadcasts to every POSITIONAL leaf, or a
    pytree prefix of the positional-args tuple whose entries broadcast over
    their argument's subtree (so ``[None, batch_sharding(mesh)]`` shards
    the whole second argument however deep its pytree is). Keyword-argument
    leaves always replicate (jit's in_shardings covers positional args
    only, and kwargs are typically scalars/config that can't take a spec).
    ``None`` entries and ``PartitionSpec`` entries resolve against ``mesh``
    (``None`` -> replicated); concrete ``Sharding`` objects pass through.
    Returns ``None`` when there is nothing to shard (no mesh and no
    shardings)."""
    if mesh is None and in_shardings is None:
        return None

    def resolve(s):
        if s is None:
            return NamedSharding(mesh, P()) if mesh is not None else None
        if isinstance(s, P):
            if mesh is None:
                raise ValueError("PartitionSpec in_shardings need a mesh= "
                                 "to resolve against")
            return NamedSharding(mesh, s)
        return s

    if _is_sharding_leaf(in_shardings):
        n_args = len(jax.tree_util.tree_leaves(tuple(args)))
        n_kw = len(jax.tree_util.tree_leaves(kwargs))
        return ([resolve(in_shardings)] * n_args
                + [resolve(None)] * n_kw)

    prefix = tuple(in_shardings) if isinstance(in_shardings, list) \
        else in_shardings
    flat: list = []
    # tree_map flattens ``prefix`` and hands each of its leaves the
    # CORRESPONDING SUBTREE of args (flatten_up_to semantics): one prefix
    # entry per argument, broadcast over that argument's leaves
    def spread(s, arg_subtree):
        n = jax.tree_util.tree_structure(arg_subtree).num_leaves
        flat.extend([resolve(s)] * n)
        return s

    try:
        jax.tree_util.tree_map(spread, prefix, tuple(args),
                               is_leaf=_is_sharding_leaf)
    except ValueError as e:
        raise ValueError(
            "in_shardings must be a single sharding/PartitionSpec/None or "
            f"a pytree prefix of the positional-args tuple: {e}") from e
    flat.extend([resolve(None)] * len(jax.tree_util.tree_leaves(kwargs)))
    return flat
