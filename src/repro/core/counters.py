"""Static FLOP/byte counters per scope, split truncated vs full precision.

RAPTOR's runtime counts executed FP ops and touched bytes in truncated and
non-truncated regions (the bars in Fig. 7, inputs to the §7.2 co-design
model). In XLA-land the jaxpr is a faithful static description of the work —
scan trip counts are static — so we count by walking the jaxpr instead of
paying runtime instrumentation.
"""
from __future__ import annotations

import collections
import dataclasses
import math
from typing import Dict, Optional, Tuple

import jax.numpy as jnp
from jax._src import core as jcore

from repro.core.policy import TruncationPolicy, STRUCTURAL_PRIMS, join_stack

# primitives that perform `weight` FLOPs per output element
_ELEMENTWISE_WEIGHT = {
    "exp": 4.0, "log": 4.0, "sin": 4.0, "cos": 4.0, "tanh": 4.0,
    "logistic": 4.0, "erf": 4.0, "rsqrt": 2.0, "sqrt": 2.0, "div": 1.0,
    "pow": 4.0, "cbrt": 4.0, "exp2": 4.0, "log1p": 4.0, "expm1": 4.0,
    "atan2": 4.0, "erf_inv": 4.0,
}


def _size(aval) -> int:
    return int(math.prod(aval.shape)) if hasattr(aval, "shape") else 0


def _bytes(aval) -> int:
    if not hasattr(aval, "dtype"):
        return 0
    return _size(aval) * jnp.dtype(aval.dtype).itemsize


def _eqn_flops(eqn) -> float:
    prim = eqn.primitive.name
    if prim in STRUCTURAL_PRIMS:
        return 0.0
    if prim == "dot_general":
        dims = eqn.params["dimension_numbers"]
        (lc, _), (lb, _) = dims
        lhs = eqn.invars[0].aval
        out = eqn.outvars[0].aval
        k = math.prod(lhs.shape[d] for d in lc)
        return 2.0 * _size(out) * k
    if prim == "conv_general_dilated":
        out = eqn.outvars[0].aval
        rhs = eqn.invars[1].aval
        return 2.0 * _size(out) * math.prod(rhs.shape[1:])
    if prim in ("reduce_sum", "reduce_prod", "cumsum", "cumprod", "cumlogsumexp"):
        return float(_size(eqn.invars[0].aval))
    if prim in ("add", "sub", "mul", "max", "min", "integer_pow", "neg",
                "select_n", "convert_element_type"):
        return float(sum(_size(v.aval) for v in eqn.outvars))
    w = _ELEMENTWISE_WEIGHT.get(prim)
    if w is not None:
        return w * sum(_size(v.aval) for v in eqn.outvars)
    # default: one flop per output element for any other math primitive
    return float(sum(_size(v.aval) for v in eqn.outvars))


@dataclasses.dataclass
class CountReport:
    """Per-format FLOP and byte totals + per-scope breakdown."""

    flops_by_fmt: Dict[str, float]
    bytes_by_fmt: Dict[str, float]
    by_scope: Dict[Tuple[str, str], float]  # (scope, fmt) -> flops

    @property
    def total_flops(self) -> float:
        return sum(self.flops_by_fmt.values())

    @property
    def truncated_fraction(self) -> float:
        t = self.total_flops
        full = self.flops_by_fmt.get("full", 0.0)
        return 0.0 if t == 0 else (t - full) / t

    @staticmethod
    def merge_all(reports) -> "CountReport":
        """Cross-shard/process reduction: FLOP and byte tallies are pure
        sums, so the global census of a data-parallel run is the elementwise
        sum of per-shard reports (the counters analogue of
        ``RaptorReport.merge_all``). Counting is static — a jaxpr walk — so
        per-shard reports of an SPMD program differ only by their shard's
        batch slice; summing them reproduces the global-batch census
        exactly."""
        reports = list(reports)
        if not reports:
            raise ValueError("merge_all needs at least one report")
        out = reports[0]
        for r in reports[1:]:
            out = out.merged(r)
        return out

    def merged(self, other: "CountReport") -> "CountReport":
        r = CountReport(dict(self.flops_by_fmt), dict(self.bytes_by_fmt),
                        dict(self.by_scope))
        for k, v in other.flops_by_fmt.items():
            r.flops_by_fmt[k] = r.flops_by_fmt.get(k, 0.0) + v
        for k, v in other.bytes_by_fmt.items():
            r.bytes_by_fmt[k] = r.bytes_by_fmt.get(k, 0.0) + v
        for k, v in other.by_scope.items():
            r.by_scope[k] = r.by_scope.get(k, 0.0) + v
        return r

    def summary(self) -> str:
        lines = [f"  {'format':>10} {'GFLOPs':>14} {'GBytes':>14}"]
        for fmt in sorted(self.flops_by_fmt):
            lines.append(
                f"  {fmt:>10} {self.flops_by_fmt[fmt] / 1e9:>14.4f} "
                f"{self.bytes_by_fmt.get(fmt, 0.0) / 1e9:>14.4f}")
        lines.append(f"  truncated fraction of FLOPs: "
                     f"{self.truncated_fraction * 100:.2f}%")
        return "\n".join(lines)


_HOPS_WITH_JAXPR = {"jit": "jaxpr", "pjit": "jaxpr", "closed_call": "call_jaxpr",
                    "remat2": "jaxpr", "checkpoint": "jaxpr"}


_MEMORY_HEAVY = frozenset({
    "dot_general", "conv_general_dilated", "gather", "scatter", "scatter-add",
    "reduce_sum", "reduce_max", "reduce_min", "dynamic_slice",
    "dynamic_update_slice", "concatenate", "sort",
})


def count_jaxpr(jaxpr: jcore.Jaxpr, policy: Optional[TruncationPolicy],
                mult: float = 1.0, prefix: str = "", fused: bool = False
                ) -> CountReport:
    """``fused=True`` models post-fusion HBM traffic: elementwise chains are
    assumed producer-consumer fused (outputs counted once, operands free);
    matmuls/gathers/reductions pay for operands + results. ``fused=False``
    is the raw per-op operand+result census (un-fused upper bound)."""
    flops = collections.defaultdict(float)
    nbytes = collections.defaultdict(float)
    by_scope = collections.defaultdict(float)

    def add(report: CountReport):
        for k, v in report.flops_by_fmt.items():
            flops[k] += v
        for k, v in report.bytes_by_fmt.items():
            nbytes[k] += v
        for k, v in report.by_scope.items():
            by_scope[k] += v

    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        sub_prefix = join_stack(prefix, str(eqn.source_info.name_stack))
        if prim in _HOPS_WITH_JAXPR:
            inner = eqn.params[_HOPS_WITH_JAXPR[prim]]
            inner = inner.jaxpr if isinstance(inner, jcore.ClosedJaxpr) else inner
            add(count_jaxpr(inner, policy, mult, sub_prefix, fused))
            continue
        if prim == "scan":
            inner = eqn.params["jaxpr"].jaxpr
            add(count_jaxpr(inner, policy, mult * eqn.params["length"],
                            sub_prefix, fused))
            continue
        if prim == "while":
            # trip count unknowable statically; count one iteration and flag
            inner = eqn.params["body_jaxpr"].jaxpr
            add(count_jaxpr(inner, policy, mult, sub_prefix, fused))
            continue
        if prim == "cond":
            # count the largest branch (upper bound)
            reports = [count_jaxpr(_b.jaxpr, policy, mult, sub_prefix, fused)
                       for _b in eqn.params["branches"]]
            if reports:
                add(max(reports, key=lambda r: r.total_flops))
            continue
        if prim in ("custom_jvp_call", "custom_vjp_call"):
            add(count_jaxpr(eqn.params["call_jaxpr"].jaxpr, policy, mult,
                            sub_prefix, fused))
            continue

        f = _eqn_flops(eqn) * mult
        if f == 0.0:
            continue
        if fused and prim not in _MEMORY_HEAVY:
            b = sum(_bytes(v.aval) for v in eqn.outvars) * mult
        else:
            b = (sum(_bytes(v.aval) for v in eqn.invars
                     if not isinstance(v, jcore.Literal))
                 + sum(_bytes(v.aval) for v in eqn.outvars)) * mult
        name_stack = sub_prefix
        out_dtype = (eqn.outvars[0].aval.dtype
                     if eqn.outvars and hasattr(eqn.outvars[0].aval, "dtype")
                     else jnp.float32)
        rule = policy.rule_for(name_stack, prim, out_dtype) if policy else None
        key = rule.fmt.key if rule is not None else "full"
        flops[key] += f
        nbytes[key] += b
        scope_key = name_stack.split("/")[0] if name_stack else "<root>"
        by_scope[(scope_key, key)] += f

    return CountReport(dict(flops), dict(nbytes), dict(by_scope))
