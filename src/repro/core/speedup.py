"""Hardware co-design speedup model (paper §7.2, Table 4, Fig. 8).

The paper models a CPU whose die area is split between FP64 and one
low-precision FPU, with per-precision performance densities extrapolated from
FPNew, then predicts speedup as  T = sum_i N_i / (A_i * P_i)  for the op
counts N_i collected by the runtime, plus a memory-traffic model and a
roofline crossover to pick which bound applies.

We re-parameterize for the TPU v5e target:
  * compute: MXU peak scales with operand width (bf16 197 TFLOP/s baseline;
    fp8 2x; f32 ~1/3 — vector ops scale similarly on the VPU)
  * memory: HBM 819 GB/s; truncated formats move proportionally fewer bytes
  * the same A_i * P_i area trade is exposed for co-design studies: given a
    truncated-fraction profile, what MXU precision mix maximizes throughput
    under a fixed silicon budget?
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional, Tuple

from repro.core.counters import CountReport
from repro.core.formats import FPFormat, parse_format

# ---- hardware constants (TPU v5e) -------------------------------------------
PEAK_BF16_FLOPS = 197e12         # per chip
HBM_BW = 819e9                   # bytes/s per chip
ICI_BW = 50e9                    # bytes/s per link

# FPNew performance-density table from the paper (Table 4), normalized to
# fp64 = 1.0 — used for the CPU-style co-design variant.
FPNEW_PERF_DENSITY = {
    "fp64": 1.00,
    "fp32": 2.65,
    "fp16": 7.30,
    "e5m2": 18.41,
}


def _width_bits(fmt: FPFormat) -> int:
    return 1 + fmt.exp_bits + fmt.man_bits


def tpu_relative_throughput(fmt: FPFormat) -> float:
    """Relative FLOP/s of ops on values storable in ``fmt`` vs bf16 = 1.0.

    TPU generations roughly double matrix throughput per halving of operand
    width; emulated widths snap up to the next hardware container
    (<=8 -> fp8 2x, <=16 -> bf16 1x, else f32 1/3)."""
    w = _width_bits(fmt)
    if w <= 8:
        return 2.0
    if w <= 16:
        return 1.0
    return 1.0 / 3.0


def container_bytes(fmt: FPFormat) -> int:
    w = _width_bits(fmt)
    if w <= 8:
        return 1
    if w <= 16:
        return 2
    return 4


@dataclasses.dataclass
class SpeedupEstimate:
    compute_bound: float         # predicted speedup if compute bound
    memory_bound: float          # predicted speedup if memory bound
    operational_intensity: float  # flops/byte of the *baseline* workload
    bound: str                   # which side of the roofline the baseline is on

    @property
    def predicted(self) -> float:
        return self.compute_bound if self.bound == "compute" else self.memory_bound


def estimate_speedup(report: CountReport,
                     baseline_fmt: str = "fp32",
                     peak_flops: float = PEAK_BF16_FLOPS,
                     hbm_bw: float = HBM_BW) -> SpeedupEstimate:
    """Paper Fig. 8: predicted speedup of a truncation profile vs running
    everything in ``baseline_fmt``.

    compute model:  T = sum_i N_i / (peak * rel_throughput_i)
    memory model:   T = sum_i B_i * (container_i / baseline_container) / bw
    """
    base = parse_format(baseline_fmt)
    base_tp = tpu_relative_throughput(base)
    base_bytes = container_bytes(base)

    total_flops = report.total_flops
    total_bytes = sum(report.bytes_by_fmt.values())
    if total_flops == 0:
        return SpeedupEstimate(1.0, 1.0, 0.0, "compute")

    t_base_c = total_flops / (peak_flops * base_tp)
    t_base_m = total_bytes / hbm_bw

    t_mix_c = 0.0
    t_mix_m = 0.0
    for key, flops in report.flops_by_fmt.items():
        fmt = base if key == "full" else parse_format(key)
        t_mix_c += flops / (peak_flops * tpu_relative_throughput(fmt))
        nbytes = report.bytes_by_fmt.get(key, 0.0)
        t_mix_m += nbytes * (container_bytes(fmt) / base_bytes) / hbm_bw

    oi = total_flops / max(total_bytes, 1.0)
    ridge = (peak_flops * base_tp) / hbm_bw
    bound = "compute" if oi >= ridge else "memory"
    return SpeedupEstimate(
        compute_bound=t_base_c / max(t_mix_c, 1e-30),
        memory_bound=t_base_m / max(t_mix_m, 1e-30),
        operational_intensity=oi,
        bound=bound,
    )


@dataclasses.dataclass
class Reconciliation:
    """Measured-vs-modeled speedup reconciliation for one experiment.

    ``gap`` is the fraction of the modeled win the measurement realized
    (measured / modeled): 1.0 means the model was exact, < 1.0 means the
    backend under-delivers (e.g. no fp8 matrix unit on the measuring host),
    > 1.0 means the model was conservative (e.g. fusion savings the compute
    term does not credit)."""
    measured: float
    modeled: float

    @property
    def gap(self) -> float:
        return self.measured / max(self.modeled, 1e-30)

    def within(self, tol: float) -> bool:
        """True when the measurement is within ``tol`` (relative) of the
        model on either side."""
        return abs(self.gap - 1.0) <= tol


def reconcile(measured: float, modeled: float) -> Reconciliation:
    """Pair a measured wall-clock speedup with its model prediction.

    The benchmarks emit both numbers side by side (BENCH rows) so every
    predicted speedup in the repo — the roofline's compute term, Fig. 8's
    co-design model — is validated against a measured ratio on the same
    artifact, and the gap between them is a tracked, gateable quantity
    rather than prose."""
    return Reconciliation(measured=float(measured), modeled=float(modeled))


def fpu_area_model(counts_by_fmt: Mapping[str, float],
                   density: Mapping[str, float] = FPNEW_PERF_DENSITY,
                   area_ratio_dbl_low: Optional[float] = None,
                   ) -> Dict[str, float]:
    """The paper's exact CPU-style model: two FPUs (double + one low
    precision) in a fixed area budget; time = sum N_i / (A_i * P_i).

    ``area_ratio_dbl_low`` defaults to the paper's A_dbl : A_low = 1.39
    (derived from a 1:2 fp64:fp32 compute-capability split, A64FX-style).
    Returns times per configuration, normalized to all-double = 1.0.
    """
    ratio = 1.39 if area_ratio_dbl_low is None else area_ratio_dbl_low
    a_dbl = ratio / (1.0 + ratio)
    a_low = 1.0 / (1.0 + ratio)
    p_dbl = density["fp64"]

    n_total = sum(counts_by_fmt.values())
    t_all_dbl = n_total / (a_dbl * p_dbl)

    out = {}
    for key, dens in density.items():
        if key == "fp64":
            continue
        t = 0.0
        for fmt_key, n in counts_by_fmt.items():
            if fmt_key == "full":
                t += n / (a_dbl * p_dbl)
            else:
                t += n / (a_low * dens)
        out[key] = t_all_dbl / max(t, 1e-30)
    return out
