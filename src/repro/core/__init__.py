# The paper's primary contribution: transparent, scoped, arbitrary-precision
# numerical profiling of JAX computations (RAPTOR, SC'25), adapted to TPU.
from repro.core.formats import (
    FPFormat, parse_format, FP64, FP32, TF32, BF16, FP16, E5M2, E4M3, E4M3FN,
)
from repro.core.policy import (
    TruncationPolicy, TruncationRule, magnitude_below, magnitude_above,
    parse_policy, resolve_policy, ResolvedPolicy, NotSerializableError,
)
from repro.core.api import (
    truncate, truncate_sweep, SweepHandle, memtrace, profile_counts,
    profile_trajectory, scope,
)
from repro.core.counters import CountReport
from repro.core.memmode import RaptorReport


def __getattr__(name):
    # lazy: repro.profile.trajectory imports repro.core submodules, which
    # triggers this package __init__ — an eager import back into the
    # partially-initialized trajectory module would be circular
    if name == "TrajectoryReport":
        from repro.profile.trajectory import TrajectoryReport
        return TrajectoryReport
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
from repro.core.speedup import estimate_speedup, fpu_area_model, SpeedupEstimate

__all__ = [
    "FPFormat", "parse_format", "FP64", "FP32", "TF32", "BF16", "FP16",
    "E5M2", "E4M3", "E4M3FN",
    "TruncationPolicy", "TruncationRule", "magnitude_below", "magnitude_above",
    "parse_policy", "resolve_policy", "ResolvedPolicy",
    "NotSerializableError",
    "truncate", "truncate_sweep", "SweepHandle", "memtrace",
    "profile_counts", "profile_trajectory", "scope",
    "CountReport", "RaptorReport", "TrajectoryReport",
    "estimate_speedup", "fpu_area_model", "SpeedupEstimate",
]
