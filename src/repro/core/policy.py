"""Truncation policies: *where* and *what* to truncate.

Mirrors RAPTOR's configuration surface:
  * program scope      -> rule with scope="**"
  * function/module    -> scope glob over the ``jax.named_scope`` name stack
                          (our models name every module: "layer/attn/qkv", ...)
  * width-conditional  -> ``from_width`` (RAPTOR's "64_to_5_14;32_to_3_8")
  * granular           -> ``ops`` / ``exclude_ops`` primitive filters
  * fenced-off regions -> policy-level ``excludes`` (paper §6.3 module
                          exclusion flow: "exclude Recon, re-run")
  * dynamic truncation -> ``mask`` rule field: truncate only elements where a
                          runtime predicate holds (the AMR M-l analogue)
"""
from __future__ import annotations

import dataclasses
import itertools
import re
import weakref
from typing import Any, Callable, ClassVar, Dict, Optional, Sequence, Tuple

import jax.numpy as jnp

from repro.core.formats import FPFormat, parse_format

# --------------------------------------------------------------------------
# scope glob matching over name stacks ("a/b/c"), '**' crosses '/' boundaries
# --------------------------------------------------------------------------


def _translate(pattern: str) -> str:
    out = []
    i = 0
    while i < len(pattern):
        c = pattern[i]
        if c == "*":
            if pattern[i:i + 2] == "**":
                out.append(".*")
                i += 2
                if i < len(pattern) and pattern[i] == "/":
                    i += 1  # '**/' also matches zero segments
            else:
                out.append("[^/]*")
                i += 1
        elif c == "?":
            out.append("[^/]")
            i += 1
        else:
            out.append(re.escape(c))
            i += 1
    return "".join(out)


def compile_scope(pattern: str):
    """Compile a scope glob. A pattern matches if it matches the full name
    stack or any of its prefixes at '/' boundaries (so ``layer/attn`` matches
    eqns whose stack is ``layer/attn/qkv/...`` — RAPTOR's "truncate the whole
    call tree below the marked function")."""
    rx = re.compile(_translate(pattern) + r"(/.*)?$")
    return rx


def scope_matches(rx, name_stack: str) -> bool:
    return rx.match(name_stack) is not None


_WRAPPER_RE = re.compile(
    r"^(?:jvp|transpose|vmap|pmap|remat|checkpoint|custom_jvp|custom_vjp)"
    r"\((.*)\)$")
_DROP_SEGMENTS = frozenset({"", "rematted_computation", "checkpoint"})


def normalize_stack(name_stack: str) -> str:
    """Strip autodiff/remat decorations so user scopes are stable under
    jax.grad / jax.checkpoint: "transpose(jvp(mlp))/dot" -> "mlp/dot".
    RAPTOR's function scopes must keep matching in the backward pass."""
    out = []
    for seg in name_stack.split("/"):
        while True:
            m = _WRAPPER_RE.match(seg)
            if not m:
                break
            seg = m.group(1)
        if seg not in _DROP_SEGMENTS:
            out.append(seg)
    return "/".join(out)


def join_stack(prefix: str, name_stack: str) -> str:
    """Join an outer HOP scope prefix with an inner (relative) name stack —
    eqns inside scan/cond/jit bodies carry stacks relative to the HOP eqn."""
    if prefix and name_stack:
        return f"{prefix}/{name_stack}"
    return prefix or name_stack


# --------------------------------------------------------------------------
# dynamic (state-dependent) truncation masks — paper's "dynamic truncation"
# --------------------------------------------------------------------------

MaskFn = Callable[[jnp.ndarray], jnp.ndarray]


def magnitude_below(threshold: float) -> MaskFn:
    """Truncate only elements with |x| < threshold — the transformer analogue
    of 'truncate AMR blocks where the solution is smooth'."""
    def fn(x):
        return jnp.abs(x) < threshold
    fn.__name__ = f"magnitude_below_{threshold}"
    return fn


def magnitude_above(threshold: float) -> MaskFn:
    def fn(x):
        return jnp.abs(x) > threshold
    fn.__name__ = f"magnitude_above_{threshold}"
    return fn


# process-unique, never-reused tokens for mask callables. ``id(mask)`` is NOT
# a stable identity: CPython reuses addresses as soon as the object is
# collected, so a cache key built on a dead mask's id would alias a later,
# different mask and poison every trace cache keyed on policies (the cached
# executable quantizes with the *old* predicate). Tokens are handed out once
# per live object and the WeakKeyDictionary forgets them only when the mask
# itself dies — after which the token number is never issued again.
_MASK_TOKENS: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()
_MASK_PINS: Dict[int, Tuple[object, int]] = {}   # non-weakrefable fallback
_mask_counter = itertools.count()


def _mask_token(mask) -> int:
    try:
        tok = _MASK_TOKENS.get(mask)
        if tok is None:
            tok = next(_mask_counter)
            _MASK_TOKENS[mask] = tok
        return tok
    except TypeError:
        # callable instance without __weakref__ support: pin it for the
        # process lifetime so its id can never be recycled, and re-check
        # identity in case a pin-table hit is a different object (cannot
        # happen while pinned, but cheap to assert)
        ent = _MASK_PINS.get(id(mask))
        if ent is None or ent[0] is not mask:
            ent = (mask, next(_mask_counter))
            _MASK_PINS[id(mask)] = ent
        return ent[1]


class NotSerializableError(TypeError):
    """A policy carries state that cannot round-trip through JSON — today
    that means a rule with a ``mask`` callable (dynamic truncation
    predicates are arbitrary Python closures). Raised loudly instead of
    silently dropping the rule: a persisted artifact must reproduce the
    policy bit-for-bit or refuse to exist."""


# --------------------------------------------------------------------------
# rules & policy
# --------------------------------------------------------------------------

# structural primitives never produce new FP values — skipping them is
# exact and keeps op-mode overhead at one quantize per *arithmetic* op.
STRUCTURAL_PRIMS = frozenset({
    "reshape", "transpose", "broadcast_in_dim", "slice", "dynamic_slice",
    "dynamic_update_slice", "concatenate", "gather", "pad", "rev", "squeeze",
    "select_n", "copy", "stop_gradient", "iota", "split",
    "reduce_max", "reduce_min", "max", "min", "abs", "neg", "sign",
    "expand_dims", "real", "imag", "device_put", "broadcast",
    "clamp", "sort", "argmax", "argmin", "reduce_and", "reduce_or",
    "eq", "ne", "lt", "le", "gt", "ge", "and", "or", "not", "xor",
    "is_finite", "floor", "ceil", "round", "sharding_constraint",
    "optimization_barrier", "layout_constraint",
})


@dataclasses.dataclass(frozen=True)
class TruncationRule:
    """One truncation instruction: ops in ``scope`` whose output dtype width
    matches ``from_width`` are rounded onto ``fmt``'s grid."""

    fmt: FPFormat
    scope: str = "**"
    from_width: Optional[int] = None          # 16/32/64; None = any float
    ops: Optional[Tuple[str, ...]] = None     # whitelist of primitive names
    exclude_ops: Tuple[str, ...] = ()
    quantize_dot_inputs: bool = False         # emulate low-precision MXU inputs
    mask: Optional[MaskFn] = None             # dynamic truncation predicate

    # set per-instance in __post_init__ via object.__setattr__; ClassVar so
    # the dataclass machinery (fields/eq/hash/asdict) never sees it
    _rx: ClassVar[Any]

    def __post_init__(self):
        object.__setattr__(self, "fmt", parse_format(self.fmt))
        object.__setattr__(self, "_rx", compile_scope(self.scope))

    def cache_key(self) -> tuple:
        """Stable hashable identity for trace caches. Mask functions are
        identified by (__name__, registry token): two policies sharing the
        same mask object alias, distinct closures never do — and unlike a
        raw ``id()`` the token is never reused after the mask is collected
        (see ``_mask_token``)."""
        mask_id = (None if self.mask is None
                   else (getattr(self.mask, "__name__", "<mask>"),
                         _mask_token(self.mask)))
        return (self.fmt.cache_key, self.scope, self.from_width, self.ops,
                self.exclude_ops, self.quantize_dot_inputs, mask_id)

    def to_json(self) -> dict:
        """Lossless JSON form. Mask-bearing rules raise
        :class:`NotSerializableError` — a runtime predicate is a closure,
        not data, and silently dropping it would persist a *different*
        policy than the one in memory."""
        if self.mask is not None:
            raise NotSerializableError(
                f"rule (scope={self.scope!r}) carries a dynamic mask fn "
                f"{getattr(self.mask, '__name__', self.mask)!r}; mask "
                "predicates are Python callables and cannot be serialized "
                "into a policy artifact")
        return {
            "fmt": self.fmt.to_json(),
            "scope": self.scope,
            "from_width": self.from_width,
            "ops": list(self.ops) if self.ops is not None else None,
            "exclude_ops": list(self.exclude_ops),
            "quantize_dot_inputs": self.quantize_dot_inputs,
        }

    @staticmethod
    def from_json(data: dict) -> "TruncationRule":
        ops = data.get("ops")
        return TruncationRule(
            fmt=FPFormat.from_json(data["fmt"]),
            scope=data["scope"],
            from_width=data.get("from_width"),
            ops=tuple(ops) if ops is not None else None,
            exclude_ops=tuple(data.get("exclude_ops", ())),
            quantize_dot_inputs=bool(data.get("quantize_dot_inputs", False)))

    def matches(self, name_stack: str, prim_name: str, out_dtype) -> bool:
        if prim_name in STRUCTURAL_PRIMS:
            return False
        if self.ops is not None and prim_name not in self.ops:
            return False
        if prim_name in self.exclude_ops:
            return False
        if not jnp.issubdtype(out_dtype, jnp.floating):
            return False
        if self.from_width is not None:
            if jnp.dtype(out_dtype).itemsize * 8 != self.from_width:
                return False
        return scope_matches(self._rx, name_stack)


# module-level census of *uncached* matcher evaluations: every rule_for call
# that actually ran normalization + regex matching (memo hits and the
# empty-policy short circuit in the interpreter don't count). Tests assert on
# deltas of this counter to pin the fast paths down.
MATCHER_EVALS = 0

_MEMO_MISS = object()


@dataclasses.dataclass(frozen=True)
class TruncationPolicy:
    """An ordered rule list plus fenced-off scopes. The *first* matching rule
    wins; ``excludes`` override everything (paper's iterative exclusion)."""

    rules: Tuple[TruncationRule, ...]
    excludes: Tuple[str, ...] = ()

    # set per-instance in __post_init__ via object.__setattr__ (ClassVar:
    # excluded from fields/eq/hash, see the memo comment below)
    _ex_rx: ClassVar[Tuple[Any, ...]]
    _match_memo: ClassVar[Dict[Any, Optional[TruncationRule]]]

    def __post_init__(self):
        if isinstance(self.rules, TruncationRule):
            object.__setattr__(self, "rules", (self.rules,))
        object.__setattr__(self, "rules", tuple(self.rules))
        object.__setattr__(self, "excludes", tuple(self.excludes))
        object.__setattr__(
            self, "_ex_rx", tuple(compile_scope(p) for p in self.excludes))
        # per-policy matcher memo: jaxprs repeat (name_stack, prim, dtype)
        # triples heavily (every eqn of a scanned layer shares a stack), so
        # the precompiled-regex walk runs once per distinct triple, not once
        # per equation-outvar. Not a dataclass field: excluded from eq/hash.
        object.__setattr__(self, "_match_memo", {})

    def cache_key(self) -> tuple:
        return (tuple(r.cache_key() for r in self.rules), self.excludes)

    def rule_for(self, name_stack: str, prim_name: str, out_dtype
                 ) -> Optional[TruncationRule]:
        key = (name_stack, prim_name, out_dtype)
        hit = self._match_memo.get(key, _MEMO_MISS)
        if hit is not _MEMO_MISS:
            return hit
        global MATCHER_EVALS
        MATCHER_EVALS += 1
        rule = self._rule_for_uncached(name_stack, prim_name, out_dtype)
        self._match_memo[key] = rule
        return rule

    def _rule_for_uncached(self, name_stack: str, prim_name: str, out_dtype
                           ) -> Optional[TruncationRule]:
        name_stack = normalize_stack(name_stack)
        for rx in self._ex_rx:
            if scope_matches(rx, name_stack):
                return None
        for rule in self.rules:
            if rule.matches(name_stack, prim_name, out_dtype):
                return rule
        return None

    def excluding(self, *scopes: str) -> "TruncationPolicy":
        return dataclasses.replace(self, excludes=self.excludes + tuple(scopes))

    # ---- lossless JSON round trip -----------------------------------------
    def to_json(self) -> dict:
        """Serialize the full rule list + excludes. Raises
        :class:`NotSerializableError` for mask-bearing rules (see
        :meth:`TruncationRule.to_json`)."""
        return {"rules": [r.to_json() for r in self.rules],
                "excludes": list(self.excludes)}

    @staticmethod
    def from_json(data: dict) -> "TruncationPolicy":
        return TruncationPolicy(
            rules=tuple(TruncationRule.from_json(r) for r in data["rules"]),
            excludes=tuple(data.get("excludes", ())))

    # ---- constructors -----------------------------------------------------
    @staticmethod
    def everywhere(fmt, **kw) -> "TruncationPolicy":
        """Program-scope truncation (RAPTOR --raptor-truncate-all)."""
        return TruncationPolicy(rules=(TruncationRule(fmt=fmt, **kw),))

    @staticmethod
    def scoped(scope: str, fmt, **kw) -> "TruncationPolicy":
        return TruncationPolicy(rules=(TruncationRule(fmt=fmt, scope=scope, **kw),))

    @staticmethod
    def from_flag(flag: str) -> "TruncationPolicy":
        """Parse RAPTOR's flag syntax, e.g. ``"64_to_5_14;32_to_3_8"``."""
        rules = []
        for part in flag.split(";"):
            part = part.strip()
            if not part:
                continue
            width, _, em = part.partition("_to_")
            e, m = em.split("_")
            rules.append(TruncationRule(
                fmt=FPFormat(int(e), int(m)), from_width=int(width)))
        return TruncationPolicy(rules=tuple(rules))


def parse_policy(spec) -> Optional["TruncationPolicy"]:
    """Parse a CLI policy spec into a :class:`TruncationPolicy`.

    The one flag grammar shared by every launch entrypoint (train, serve):
      * ``None`` / ``""``          -> ``None`` (no truncation)
      * ``"scope:**/mlp=e5m7"``    -> scoped single-rule policy
      * ``"64_to_5_14;32_to_3_8"`` -> RAPTOR width-conditional rules
    Already-constructed policies pass through unchanged.
    """
    if not spec:
        return None
    if isinstance(spec, TruncationPolicy):
        return spec
    if spec.startswith("scope:"):
        scope, fmt = spec[len("scope:"):].split("=")
        return TruncationPolicy.scoped(scope, fmt)
    return TruncationPolicy.from_flag(spec)


# --------------------------------------------------------------------------
# shared policy resolution — the one profile→policy→deploy entrypoint
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ResolvedPolicy:
    """What :func:`resolve_policy` hands every consumer: the runnable policy,
    plus the deployed artifact (and its registry ref) when one was named —
    serving threads the artifact through to provenance logging, the trainer
    records its ref in checkpoint manifests."""

    policy: Optional[TruncationPolicy] = None
    artifact: Optional[object] = None      # repro.artifacts.PolicyArtifact
    ref: Optional[object] = None           # repro.artifacts.ArtifactRef


def _looks_like_ref(spec: str) -> bool:
    """Registry refs (``"name"`` / ``"name@v3"``) vs flag grammar: every flag
    spelling carries ``scope:``, ``_to_`` or ``=``; a bare identifier is a
    registry name."""
    return (not spec.startswith("scope:") and "_to_" not in spec
            and "=" not in spec)


def resolve_policy(spec=None, artifact_ref=None, *,
                   registry=None) -> ResolvedPolicy:
    """Resolve *anything callers deploy a policy as* into one shape.

    ``spec`` accepts a :class:`TruncationPolicy`, a
    :class:`~repro.artifacts.PolicyArtifact`, a flag string
    (``"scope:**/mlp=e5m7"`` / ``"64_to_5_14"``), or a registry ref string
    (``"bench_model"`` / ``"bench_model@v3"``). ``artifact_ref`` names a
    registry artifact explicitly and is exclusive with ``spec``.
    ``registry`` is a :class:`~repro.artifacts.Registry`, a root path, or
    ``None`` for the default root. Used by ``launch.serve``,
    ``launch.train``, the guardrails controller, and the serving engine —
    the single place the flag-vs-artifact grammar lives.
    """
    if isinstance(spec, str) and not spec:
        spec = None
    if spec is not None and artifact_ref:
        raise ValueError("--policy and --policy-artifact are exclusive")
    if spec is None and not artifact_ref:
        return ResolvedPolicy()

    if spec is not None and not isinstance(spec, str):
        if isinstance(spec, TruncationPolicy):
            return ResolvedPolicy(policy=spec)
        policy = getattr(spec, "policy", None)
        if policy is not None:  # a PolicyArtifact (duck-typed: no import)
            return ResolvedPolicy(policy=policy, artifact=spec)
        raise TypeError(f"cannot resolve a policy from {type(spec).__name__}")

    if isinstance(spec, str) and not _looks_like_ref(spec):
        return ResolvedPolicy(policy=parse_policy(spec))

    ref = artifact_ref or spec
    if not ref:
        return ResolvedPolicy()
    from repro.artifacts import Registry  # lazy: artifacts imports us
    if registry is None or isinstance(registry, str):
        registry = Registry(registry)
    artifact, aref = registry.load_ref(ref)
    return ResolvedPolicy(policy=artifact.policy, artifact=artifact, ref=aref)
