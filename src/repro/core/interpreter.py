"""Op-mode jaxpr interpreter — the JAX analogue of RAPTOR's LLVM pass.

``eval_quantized`` walks a jaxpr and re-binds every equation, rounding the
result of each matched floating-point primitive onto the policy's (e,m) grid
(compute-in-carrier + correctly-round-result = MPFR op-mode semantics, see
DESIGN.md §2). Because the walk happens *inside* a trace, the transformed
function can be jit'ed, differentiated, pjit-sharded, and scanned like any
other JAX function — the profiling instrument rides the normal compilation
pipeline just as RAPTOR rides LTO.

Higher-order primitives are handled recursively: ``jit``/``closed_call`` are
inlined; ``scan``/``while``/``cond`` are rebuilt through their high-level
APIs with transformed bodies; ``remat2`` is re-wrapped in ``jax.checkpoint``
(preserving memory behaviour); ``custom_jvp/vjp_call`` evaluate their primal
jaxpr (grad-then-truncate sees plain primitives anyway).
"""
from __future__ import annotations

import functools
from typing import Any, List, Sequence

import jax
import jax.numpy as jnp
from jax import lax
from jax._src import core as jcore

from repro.core.policy import TruncationPolicy, TruncationRule, join_stack


def _safe_map(f, *xs):
    ls = [list(x) for x in xs]
    assert len({len(l) for l in ls}) == 1, 'length mismatch'
    return list(map(f, *ls))
from repro.kernels.quantize_em.ops import quantize

# primitives whose *inputs* we optionally quantize to emulate a low-precision
# matrix unit with full-precision accumulation (TPU-realistic scenario)
_DOT_PRIMS = frozenset({"dot_general", "conv_general_dilated", "ragged_dot"})


def _maybe_quantize(val, rule: TruncationRule, impl: str):
    if not isinstance(val, jax.Array) and not hasattr(val, "dtype"):
        return val
    if not jnp.issubdtype(val.dtype, jnp.floating):
        return val
    q = quantize(val, rule.fmt, impl=impl)
    if rule.mask is not None:
        q = jnp.where(rule.mask(val), q, val)
    return q


def quantized_callable(closed: jcore.ClosedJaxpr, out_tree,
                       policy: TruncationPolicy, impl: str = "auto"):
    """jit-close the transformed computation once. The jaxpr walk (and its
    per-equation policy matching) happens a single time, at trace; every
    subsequent call with the same avals hits XLA's executable cache, so
    repeated evaluations — the precision-search inner loop — pay only the
    kernel launch, not a re-interpretation."""
    @jax.jit
    def run(flat):
        outs = eval_quantized(closed.jaxpr, closed.consts, list(flat),
                              policy, impl)
        return jax.tree_util.tree_unflatten(out_tree, outs)

    return run


def eval_quantized(jaxpr: jcore.Jaxpr, consts: Sequence[Any], args: Sequence[Any],
                   policy: TruncationPolicy, impl: str = "auto",
                   prefix: str = "") -> List[Any]:
    """Evaluate ``jaxpr`` with op-mode truncation under ``policy``."""
    env = {}

    def read(v):
        return v.val if isinstance(v, jcore.Literal) else env[v]

    def write(v, val):
        env[v] = val

    _safe_map(write, jaxpr.constvars, consts)
    _safe_map(write, jaxpr.invars, args)

    for eqn in jaxpr.eqns:
        invals = [read(v) for v in eqn.invars]
        prim = eqn.primitive
        name_stack = join_stack(prefix, str(eqn.source_info.name_stack))
        handler = _HOP_HANDLERS.get(prim.name)
        if handler is not None:
            outvals = handler(eqn, invals, policy, impl, name_stack)
        else:
            # input-side quantization for matrix units
            rule0 = None
            if prim.name in _DOT_PRIMS and eqn.outvars:
                rule0 = policy.rule_for(name_stack, prim.name,
                                        eqn.outvars[0].aval.dtype)
                if rule0 is not None and rule0.quantize_dot_inputs:
                    invals = [_maybe_quantize(v, rule0, impl) for v in invals]
            outvals = prim.bind(*invals, **eqn.params)
            if not prim.multiple_results:
                outvals = [outvals]
            outvals = list(outvals)
            for i, (ov, var) in enumerate(zip(outvals, eqn.outvars)):
                aval = var.aval
                if not hasattr(aval, "dtype"):
                    continue
                rule = rule0 if rule0 is not None else policy.rule_for(
                    name_stack, prim.name, aval.dtype)
                if rule is not None and jnp.issubdtype(aval.dtype, jnp.floating):
                    if not (rule.quantize_dot_inputs and prim.name in _DOT_PRIMS):
                        outvals[i] = _maybe_quantize(ov, rule, impl)
        if not isinstance(outvals, (list, tuple)):
            outvals = [outvals]
        _safe_map(write, eqn.outvars, outvals)

    return [read(v) for v in jaxpr.outvars]


# --------------------------------------------------------------------------
# higher-order primitive handlers
# --------------------------------------------------------------------------

def _closed(eqn_param) -> jcore.ClosedJaxpr:
    if isinstance(eqn_param, jcore.ClosedJaxpr):
        return eqn_param
    return jcore.ClosedJaxpr(eqn_param, ())


def _handle_call(eqn, invals, policy, impl, prefix):
    key = "call_jaxpr" if "call_jaxpr" in eqn.params else "jaxpr"
    closed = _closed(eqn.params[key])
    return eval_quantized(closed.jaxpr, closed.consts, invals, policy, impl,
                          prefix)


def _handle_scan(eqn, invals, policy, impl, prefix):
    p = eqn.params
    closed = _closed(p["jaxpr"])
    nc, ncarry = p["num_consts"], p["num_carry"]
    body_consts = invals[:nc]
    carry_in = tuple(invals[nc:nc + ncarry])
    xs = tuple(invals[nc + ncarry:])

    def body_fn(carry, x):
        res = eval_quantized(closed.jaxpr, closed.consts,
                             list(body_consts) + list(carry) + list(x),
                             policy, impl, prefix)
        return tuple(res[:ncarry]), tuple(res[ncarry:])

    carry_out, ys = lax.scan(body_fn, carry_in, xs, length=p["length"],
                             reverse=p["reverse"], unroll=p["unroll"])
    return list(carry_out) + list(ys)


def _handle_while(eqn, invals, policy, impl, prefix):
    p = eqn.params
    cond_closed = _closed(p["cond_jaxpr"])
    body_closed = _closed(p["body_jaxpr"])
    cn, bn = p["cond_nconsts"], p["body_nconsts"]
    cond_consts = invals[:cn]
    body_consts = invals[cn:cn + bn]
    carry_in = tuple(invals[cn + bn:])

    def cond_fn(carry):
        res = eval_quantized(cond_closed.jaxpr, cond_closed.consts,
                             list(cond_consts) + list(carry), policy, impl,
                             prefix)
        return res[0]

    def body_fn(carry):
        res = eval_quantized(body_closed.jaxpr, body_closed.consts,
                             list(body_consts) + list(carry), policy, impl,
                             prefix)
        return tuple(res)

    out = lax.while_loop(cond_fn, body_fn, carry_in)
    return list(out)


def _handle_cond(eqn, invals, policy, impl, prefix):
    branches = eqn.params["branches"]
    index, *operands = invals

    def make_branch(br):
        closed = _closed(br)
        return lambda *ops: tuple(
            eval_quantized(closed.jaxpr, closed.consts, list(ops), policy,
                           impl, prefix))

    out = lax.switch(index, [make_branch(b) for b in branches], *operands)
    return list(out)


def _handle_remat(eqn, invals, policy, impl, prefix):
    closed = _closed(eqn.params["jaxpr"])

    @functools.partial(jax.checkpoint, policy=eqn.params.get("policy"),
                       prevent_cse=eqn.params.get("prevent_cse", True))
    def inner(*args):
        return tuple(eval_quantized(closed.jaxpr, closed.consts, list(args),
                                    policy, impl, prefix))

    return list(inner(*invals))


def _handle_custom_call(eqn, invals, policy, impl, prefix):
    closed = _closed(eqn.params["call_jaxpr"])
    return eval_quantized(closed.jaxpr, closed.consts, invals, policy, impl,
                          prefix)


_HOP_HANDLERS = {
    "jit": _handle_call,
    "pjit": _handle_call,
    "closed_call": _handle_call,
    "core_call": _handle_call,
    "scan": _handle_scan,
    "while": _handle_while,
    "cond": _handle_cond,
    "remat2": _handle_remat,
    "checkpoint": _handle_remat,
    "custom_jvp_call": _handle_custom_call,
    "custom_vjp_call": _handle_custom_call,
    "custom_vjp_call_jaxpr": _handle_custom_call,
}
