"""Op-mode jaxpr interpreter — the JAX analogue of RAPTOR's LLVM pass.

``eval_quantized`` walks a jaxpr and re-binds every equation, rounding the
result of each matched floating-point primitive onto the policy's (e,m) grid
(compute-in-carrier + correctly-round-result = MPFR op-mode semantics, see
DESIGN.md §2). Because the walk happens *inside* a trace, the transformed
function can be jit'ed, differentiated, pjit-sharded, and scanned like any
other JAX function — the profiling instrument rides the normal compilation
pipeline just as RAPTOR rides LTO.

Two transforms share one walker:

  * **policy-driven** (``eval_quantized``): formats are trace-time constants
    — a new policy is a new trace + compile. Retains the static fast paths
    and the full rule feature set (masks, dot-input quantization).
  * **table-driven** (``eval_sites``): the walk only fixes *where* to
    quantize (the sites matched by a site policy); *what* format each site
    gets is a runtime ``(num_sites, 4)`` int32 table argument. One compile
    per input signature serves every candidate policy — swap the table, or
    ``vmap`` over a leading table axis to evaluate a whole ladder of
    policies in one batched call (see ``api.truncate_sweep``).

Higher-order primitives are handled recursively: ``jit``/``closed_call`` are
inlined; ``scan``/``while``/``cond`` are rebuilt through their high-level
APIs with transformed bodies; ``remat2`` is re-wrapped in ``jax.checkpoint``
(preserving memory behaviour); ``custom_jvp/vjp_call`` evaluate their primal
jaxpr (grad-then-truncate sees plain primitives anyway).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax._src import core as jcore

from repro.core.formats import parse_format
from repro.core.policy import TruncationPolicy, TruncationRule, join_stack


def _safe_map(f, *xs):
    ls = [list(x) for x in xs]
    assert len({len(l) for l in ls}) == 1, 'length mismatch'
    return list(map(f, *ls))
from repro.kernels import fp8_dot as _fp8
from repro.kernels.fused import fused_outputs
from repro.kernels.quantize_em.ops import (
    quantize, quantize_dynamic, quantize_prepared, prepare_dynamic,
    format_row, IDENTITY_ROW,
)

# primitives whose *inputs* we optionally quantize to emulate a low-precision
# matrix unit with full-precision accumulation (TPU-realistic scenario)
_DOT_PRIMS = frozenset({"dot_general", "conv_general_dilated", "ragged_dot"})


def _maybe_quantize(val, rule: TruncationRule, impl: str):
    if not isinstance(val, jax.Array) and not hasattr(val, "dtype"):
        return val
    if not jnp.issubdtype(val.dtype, jnp.floating):
        return val
    q = quantize(val, rule.fmt, impl=impl)
    if rule.mask is not None:
        q = jnp.where(rule.mask(val), q, val)
    return q


# --------------------------------------------------------------------------
# per-equation transform contexts
# --------------------------------------------------------------------------

class _PolicyCtx:
    """Trace-time-constant formats: the original op-mode transform."""

    __slots__ = ("policy", "impl", "live", "native_fp8")

    def __init__(self, policy: TruncationPolicy, impl: str,
                 native_fp8: bool = False):
        self.policy = policy
        self.impl = impl
        self.native_fp8 = native_fp8
        # fast path: a policy with no rules can never match — skip the
        # per-equation-per-outvar matcher calls entirely (they are the
        # dominant python cost of walking big jaxprs; see test_interpreter).
        self.live = bool(policy.rules)

    def eqn_outputs(self, jaxpr, eqn_idx, eqn, invals, name_stack):
        prim = eqn.primitive
        rule0 = None
        if self.live and prim.name in _DOT_PRIMS and eqn.outvars:
            rule0 = self.policy.rule_for(name_stack, prim.name,
                                         eqn.outvars[0].aval.dtype)
            if rule0 is not None and rule0.quantize_dot_inputs:
                fp8 = self._native_fp8_rule(rule0, prim, eqn)
                if fp8 is not None:
                    return [_fp8.fp8_dot_general(
                        invals[0], invals[1],
                        eqn.params["dimension_numbers"],
                        saturate=fp8.saturate,
                        precision=eqn.params.get("precision"),
                        out_dtype=eqn.outvars[0].aval.dtype)]
                invals = [_maybe_quantize(v, rule0, self.impl) for v in invals]
        routed = ()
        if self.live:
            fused_outs = fused_outputs(eqn)
            if fused_outs is not None and len(fused_outs) == 1:
                fi = fused_outs[0]
                rule = self.policy.rule_for(name_stack, prim.name,
                                            eqn.outvars[fi].aval.dtype)
                if (rule is not None and rule.mask is None
                        and not rule.quantize_dot_inputs):
                    # route the static rule into the kernel's fused epilogue:
                    # the format row replaces the scalar-prefetch operand and
                    # the separate quantize pass for that output is dropped
                    invals = [jnp.asarray(format_row(rule.fmt), jnp.int32),
                              *invals[1:]]
                    routed = (fi,)
        outvals = prim.bind(*invals, **eqn.params)
        if not prim.multiple_results:
            outvals = [outvals]
        outvals = list(outvals)
        if not self.live:
            return outvals
        for i, (ov, var) in enumerate(zip(outvals, eqn.outvars)):
            if i in routed:
                continue
            aval = var.aval
            if not hasattr(aval, "dtype"):
                continue
            rule = rule0 if rule0 is not None else self.policy.rule_for(
                name_stack, prim.name, aval.dtype)
            if rule is not None and jnp.issubdtype(aval.dtype, jnp.floating):
                if not (rule.quantize_dot_inputs and prim.name in _DOT_PRIMS):
                    outvals[i] = _maybe_quantize(ov, rule, self.impl)
        return outvals

    def _native_fp8_rule(self, rule, prim, eqn):
        """The parsed format when this dot eqn should take the native fp8
        execution path (e4m3-storable format, plain two-operand dot with a
        floating output), else None — emulated input quantize otherwise."""
        if not self.native_fp8 or prim.name != "dot_general":
            return None
        if rule.mask is not None or len(eqn.invars) != 2:
            return None
        fmt = parse_format(rule.fmt)
        if not _fp8.is_native_fp8_format(fmt):
            return None
        out_dt = eqn.outvars[0].aval.dtype
        if not jnp.issubdtype(out_dt, jnp.floating):
            return None
        return fmt


class _TableCtx:
    """Runtime-table formats: matching was pre-resolved into a SiteIndex, so
    the traced computation only carries static row indices into the traced
    ``table`` argument.

    On the ref impl (CPU, and every sweep) f32-carrier sites quantize
    through the prepared-table path: the format-field derivation runs once
    for the whole table (``prepare_dynamic``) and each site only slices its
    row and runs the array math — without this, hundreds of inlined
    derivations made the swept executable's one-off compile slower than
    recompiling the static transform per candidate. The prep is derived
    EAGERLY here, at the outer trace level: deriving it lazily at the first
    site leaked tracers when that site sat inside a scan/while body (the
    cached arrays belonged to the body's inner trace but outlived it).
    Inner-scope sites closing over the outer-level prep is plain closure
    capture and fine. f64 sites (rare: x64 oracle runs) and the pallas
    impls keep the per-site ``quantize_dynamic`` call."""

    __slots__ = ("table", "index", "impl", "_prep32")

    def __init__(self, table, index: "SiteIndex", impl: str):
        self.table = table
        self.index = index
        self.impl = impl
        self._prep32 = prepare_dynamic(table, jnp.float32)

    def _quantize_site(self, val, site: int):
        impl = self.impl
        if impl == "auto":
            impl = "pallas" if jax.default_backend() == "tpu" else "ref"
        if (impl != "ref" or not hasattr(val, "dtype")
                or jnp.dtype(val.dtype) == jnp.dtype(jnp.float64)):
            return quantize_dynamic(val, self.table[site], impl=impl)
        if not jnp.issubdtype(jnp.dtype(val.dtype), jnp.floating):
            return val
        return quantize_prepared(val, self._prep32, site)

    def eqn_outputs(self, jaxpr, eqn_idx, eqn, invals, name_stack):
        prim = eqn.primitive
        routed = ()
        fused_outs = fused_outputs(eqn)
        if fused_outs is not None and len(fused_outs) == 1:
            fi = fused_outs[0]
            site = self.index.lookup(jaxpr, eqn_idx, fi, name_stack)
            if site is not None:
                # route the site's table row into the kernel's fused quantize
                # epilogue (replacing the scalar-prefetch operand) instead of
                # appending a separate quantize kernel after the call
                invals = [jnp.asarray(self.table[site], jnp.int32),
                          *invals[1:]]
                routed = (fi,)
        outvals = prim.bind(*invals, **eqn.params)
        if not prim.multiple_results:
            outvals = [outvals]
        outvals = list(outvals)
        for i in range(len(outvals)):
            if i in routed:
                continue
            site = self.index.lookup(jaxpr, eqn_idx, i, name_stack)
            if site is not None:
                outvals[i] = self._quantize_site(outvals[i], site)
        return outvals


def _jit_sharded(fn, flat_shardings):
    """``jax.jit`` over a flat-leaf-list callable with pre-resolved per-leaf
    shardings (the output of ``distributed.sharding.flatten_arg_shardings``;
    ``None`` = no sharding constraints)."""
    if flat_shardings is None:
        return jax.jit(fn)
    return jax.jit(fn, in_shardings=(flat_shardings,))


def quantized_callable(closed: jcore.ClosedJaxpr, out_tree,
                       policy: TruncationPolicy, impl: str = "auto",
                       *, flat_shardings=None, native_fp8: bool = False):
    """jit-close the transformed computation once. The jaxpr walk (and its
    per-equation policy matching) happens a single time, at trace; every
    subsequent call with the same avals hits XLA's executable cache, so
    repeated evaluations — the precision-search inner loop — pay only the
    kernel launch, not a re-interpretation.

    ``flat_shardings`` (pre-resolved per-leaf, see ``distributed.sharding.
    flatten_arg_shardings``) GSPMD-partition the executable: inputs are
    placed per the shardings and the truncated computation runs
    data-parallel across the mesh — profiling rides the normal SPMD
    pipeline, formats and semantics unchanged."""
    def run(flat):
        outs = eval_quantized(closed.jaxpr, closed.consts, list(flat),
                              policy, impl, native_fp8=native_fp8)
        return jax.tree_util.tree_unflatten(out_tree, outs)

    return _jit_sharded(run, flat_shardings)


def eval_quantized(jaxpr: jcore.Jaxpr, consts: Sequence[Any], args: Sequence[Any],
                   policy: TruncationPolicy, impl: str = "auto",
                   prefix: str = "", *, native_fp8: bool = False) -> List[Any]:
    """Evaluate ``jaxpr`` with op-mode truncation under ``policy``.

    ``native_fp8``: run ``quantize_dot_inputs`` dot sites whose format maps
    onto ``float8_e4m3fn`` on the native fp8 execution path (fp8 storage,
    f32 accumulation) instead of emulating the rounding in the carrier."""
    return _eval(jaxpr, consts, args, _PolicyCtx(policy, impl, native_fp8),
                 prefix)


def _eval(jaxpr: jcore.Jaxpr, consts: Sequence[Any], args: Sequence[Any],
          ctx, prefix: str = "") -> List[Any]:
    env = {}

    def read(v):
        return v.val if isinstance(v, jcore.Literal) else env[v]

    def write(v, val):
        env[v] = val

    _safe_map(write, jaxpr.constvars, consts)
    _safe_map(write, jaxpr.invars, args)

    for eqn_idx, eqn in enumerate(jaxpr.eqns):
        invals = [read(v) for v in eqn.invars]
        prim = eqn.primitive
        name_stack = join_stack(prefix, str(eqn.source_info.name_stack))
        handler = _HOP_HANDLERS.get(prim.name)
        if handler is not None:
            outvals = handler(eqn, invals, ctx, name_stack)
        else:
            outvals = ctx.eqn_outputs(jaxpr, eqn_idx, eqn, invals, name_stack)
        if not isinstance(outvals, (list, tuple)):
            outvals = [outvals]
        _safe_map(write, eqn.outvars, outvals)

    return [read(v) for v in jaxpr.outvars]


# --------------------------------------------------------------------------
# quantize-site enumeration (runtime-parameterized formats)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class QuantizeSite:
    """One policy-matched (equation, output) position in the jaxpr forest.

    ``stack`` is the raw (un-normalized) joined name stack exactly as the
    walker sees it, so re-matching a candidate policy against the site
    reproduces the static transform's decision bit-for-bit."""

    index: int
    stack: str
    prim: str
    dtype: Any

    @property
    def scope(self) -> str:
        from repro.core.policy import normalize_stack
        return normalize_stack(self.stack)


class SiteIndex:
    """Order-stable site enumeration for one traced computation.

    Maps (sub-jaxpr identity, eqn position, outvar position, name stack) ->
    row of the runtime format table. The name stack is part of the key
    because jax's tracing caches share sub-jaxpr *objects* across call
    sites: one jitted helper called under two scopes is a single
    ClosedJaxpr reached with two different stack prefixes, and each prefix
    needs its own policy-matched rows. The jaxpr objects are pinned so the
    id()-based keys can never be recycled while the index is alive."""

    def __init__(self, sites: List[QuantizeSite], by_key: Dict, pinned: List):
        self.sites = sites
        self._by_key = by_key
        self._pinned = pinned

    def __len__(self) -> int:
        return len(self.sites)

    def lookup(self, jaxpr, eqn_idx: int, out_idx: int,
               name_stack: str) -> Optional[int]:
        return self._by_key.get((id(jaxpr), eqn_idx, out_idx, name_stack))

    def identity_table(self) -> np.ndarray:
        """The (num_sites, 4) table that quantizes nothing."""
        return np.tile(IDENTITY_ROW, (len(self.sites), 1))

    def site_keys(self) -> List[Tuple]:
        """Per-site lookup keys, in site order — the inverse of the
        ``(id(jaxpr), eqn_idx, out_idx, name_stack) -> row`` mapping.
        Lets analyses built over the same jaxpr forest (``repro.analysis``)
        address their per-value records by site."""
        keys: List = [None] * len(self.sites)
        for k, i in self._by_key.items():
            keys[i] = k
        return keys

    def table_for(self, policy: TruncationPolicy) -> np.ndarray:
        """Lower a candidate policy to its (num_sites, 4) int32 format table.

        Sites the policy does not match get the identity row; matched sites
        get the matching rule's format. Raises for rules the runtime path
        cannot represent (masks, dot-input quantization)."""
        rows = np.tile(IDENTITY_ROW, (len(self.sites), 1))
        for s in self.sites:
            rule = policy.rule_for(s.stack, s.prim, s.dtype)
            if rule is None:
                continue
            if rule.mask is not None or rule.quantize_dot_inputs:
                raise ValueError(
                    "runtime format tables support plain output-quantize "
                    f"rules only (offending rule scope={rule.scope!r})")
            rows[s.index] = format_row(rule.fmt)
        return rows


def enumerate_sites(closed: jcore.ClosedJaxpr,
                    site_policy: TruncationPolicy) -> SiteIndex:
    """Single structural walk enumerating every quantize site the
    ``site_policy`` matches, in the same traversal order as the evaluator.

    The site policy fixes *where* quantization may happen (its formats are
    irrelevant); any candidate policy whose matched set is a subset of the
    site policy's can then be lowered to a table via ``table_for``."""
    for r in site_policy.rules:
        if r.mask is not None or r.quantize_dot_inputs:
            raise ValueError("site policies support plain output-quantize "
                             "rules only")

    sites: List[QuantizeSite] = []
    by_key: Dict = {}
    pinned: List = []
    seen: set = set()

    def walk(jaxpr: jcore.Jaxpr, prefix: str) -> None:
        # a shared sub-jaxpr object must be walked once per distinct prefix:
        # each call site carries its own stack and may match different rules
        # (two call sites with an identical prefix collapse to the same
        # keys/rows, which is exactly the static transform's decision too)
        if (id(jaxpr), prefix) in seen:
            return
        seen.add((id(jaxpr), prefix))
        pinned.append(jaxpr)
        for eqn_idx, eqn in enumerate(jaxpr.eqns):
            pname = eqn.primitive.name
            name_stack = join_stack(prefix, str(eqn.source_info.name_stack))
            if pname in _HOP_HANDLERS:
                if pname == "cond":
                    for br in eqn.params["branches"]:
                        walk(_closed(br).jaxpr, name_stack)
                elif pname == "while":
                    walk(_closed(eqn.params["cond_jaxpr"]).jaxpr, name_stack)
                    walk(_closed(eqn.params["body_jaxpr"]).jaxpr, name_stack)
                else:
                    key = ("call_jaxpr" if "call_jaxpr" in eqn.params
                           else "jaxpr")
                    walk(_closed(eqn.params[key]).jaxpr, name_stack)
                continue
            for out_idx, var in enumerate(eqn.outvars):
                aval = var.aval
                if (not hasattr(aval, "dtype")
                        or not jnp.issubdtype(aval.dtype, jnp.floating)):
                    continue
                if site_policy.rule_for(name_stack, pname, aval.dtype) is None:
                    continue
                site = QuantizeSite(len(sites), name_stack, pname, aval.dtype)
                by_key[(id(jaxpr), eqn_idx, out_idx, name_stack)] = site.index
                sites.append(site)

    pinned.append(closed)  # keep consts/jaxpr alive alongside the ids
    walk(closed.jaxpr, "")
    return SiteIndex(sites, by_key, pinned)


def eval_sites(jaxpr: jcore.Jaxpr, consts: Sequence[Any], args: Sequence[Any],
               table, index: SiteIndex, impl: str = "auto") -> List[Any]:
    """Evaluate ``jaxpr`` quantizing each enumerated site onto the format in
    its ``table`` row — the runtime-parameterized twin of
    ``eval_quantized``."""
    return _eval(jaxpr, consts, args, _TableCtx(table, index, impl), "")


def parameterized_callable(closed: jcore.ClosedJaxpr, out_tree,
                           index: SiteIndex, impl: str = "auto",
                           *, mesh=None, batch_axis: str = "probe",
                           flat_shardings=None):
    """Compile-once runtime-parameterized transform.

    Returns ``(run, run_batch)``: ``run(table, flat)`` evaluates one
    candidate format table; ``run_batch(tables, flat)`` vmaps over a leading
    candidate axis, evaluating a whole ladder of policies in one batched
    call. Either is compiled once per input signature — a new candidate
    policy is just a new table value.

    With ``mesh`` the batched executable is GSPMD-partitioned: the leading
    K (candidate) axis of ``tables`` is sharded over ``mesh.shape[batch_axis]``
    devices — a W-candidate ladder evaluates on W/ndev devices concurrently —
    while each candidate's ``(num_sites, 4)`` table rows stay replicated.
    Profiled inputs follow ``flat_shardings`` (pre-resolved per-leaf, see
    ``distributed.sharding.flatten_arg_shardings``; default replicated).
    K must divide evenly across the axis — pad ladders with
    ``index.identity_table()`` rows (``distributed.sharding.pad_to_shards``)
    and drop the padded outputs."""
    def _run(table, flat):
        outs = eval_sites(closed.jaxpr, closed.consts, list(flat),
                          jnp.asarray(table, jnp.int32), index, impl)
        return jax.tree_util.tree_unflatten(out_tree, outs)

    vb = jax.vmap(_run, in_axes=(0, None))
    if mesh is None and flat_shardings is None:
        return jax.jit(_run), jax.jit(vb)

    from repro.distributed.sharding import probe_sharding, replicated

    if mesh is not None:
        data_sh = (flat_shardings if flat_shardings is not None
                   else replicated(mesh))
        table_sh = probe_sharding(mesh, batch_axis)
        repl = replicated(mesh)
    else:  # concrete shardings given, no mesh for the table axis
        data_sh = flat_shardings
        table_sh = repl = None
    run = jax.jit(_run, in_shardings=(repl, data_sh))
    run_batch = jax.jit(vb, in_shardings=(table_sh, data_sh))
    return run, run_batch


# --------------------------------------------------------------------------
# higher-order primitive handlers
# --------------------------------------------------------------------------

def _closed(eqn_param) -> jcore.ClosedJaxpr:
    if isinstance(eqn_param, jcore.ClosedJaxpr):
        return eqn_param
    return jcore.ClosedJaxpr(eqn_param, ())


def _handle_call(eqn, invals, ctx, prefix):
    key = "call_jaxpr" if "call_jaxpr" in eqn.params else "jaxpr"
    closed = _closed(eqn.params[key])
    return _eval(closed.jaxpr, closed.consts, invals, ctx, prefix)


def _handle_scan(eqn, invals, ctx, prefix):
    p = eqn.params
    closed = _closed(p["jaxpr"])
    nc, ncarry = p["num_consts"], p["num_carry"]
    body_consts = invals[:nc]
    carry_in = tuple(invals[nc:nc + ncarry])
    xs = tuple(invals[nc + ncarry:])

    def body_fn(carry, x):
        res = _eval(closed.jaxpr, closed.consts,
                    list(body_consts) + list(carry) + list(x), ctx, prefix)
        return tuple(res[:ncarry]), tuple(res[ncarry:])

    carry_out, ys = lax.scan(body_fn, carry_in, xs, length=p["length"],
                             reverse=p["reverse"], unroll=p["unroll"])
    return list(carry_out) + list(ys)


def _handle_while(eqn, invals, ctx, prefix):
    p = eqn.params
    cond_closed = _closed(p["cond_jaxpr"])
    body_closed = _closed(p["body_jaxpr"])
    cn, bn = p["cond_nconsts"], p["body_nconsts"]
    cond_consts = invals[:cn]
    body_consts = invals[cn:cn + bn]
    carry_in = tuple(invals[cn + bn:])

    def cond_fn(carry):
        res = _eval(cond_closed.jaxpr, cond_closed.consts,
                    list(cond_consts) + list(carry), ctx, prefix)
        return res[0]

    def body_fn(carry):
        res = _eval(body_closed.jaxpr, body_closed.consts,
                    list(body_consts) + list(carry), ctx, prefix)
        return tuple(res)

    out = lax.while_loop(cond_fn, body_fn, carry_in)
    return list(out)


def _handle_cond(eqn, invals, ctx, prefix):
    branches = eqn.params["branches"]
    index, *operands = invals

    def make_branch(br):
        closed = _closed(br)
        return lambda *ops: tuple(
            _eval(closed.jaxpr, closed.consts, list(ops), ctx, prefix))

    out = lax.switch(index, [make_branch(b) for b in branches], *operands)
    return list(out)


def _handle_remat(eqn, invals, ctx, prefix):
    closed = _closed(eqn.params["jaxpr"])

    @functools.partial(jax.checkpoint, policy=eqn.params.get("policy"),
                       prevent_cse=eqn.params.get("prevent_cse", True))
    def inner(*args):
        return tuple(_eval(closed.jaxpr, closed.consts, list(args), ctx,
                           prefix))

    return list(inner(*invals))


def _handle_custom_call(eqn, invals, ctx, prefix):
    closed = _closed(eqn.params["call_jaxpr"])
    return _eval(closed.jaxpr, closed.consts, invals, ctx, prefix)


_HOP_HANDLERS = {
    "jit": _handle_call,
    "pjit": _handle_call,
    "closed_call": _handle_call,
    "core_call": _handle_call,
    "scan": _handle_scan,
    "while": _handle_while,
    "cond": _handle_cond,
    "remat2": _handle_remat,
    "checkpoint": _handle_remat,
    "custom_jvp_call": _handle_custom_call,
    "custom_vjp_call": _handle_custom_call,
    "custom_vjp_call_jaxpr": _handle_custom_call,
}
