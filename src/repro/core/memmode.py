"""Mem-mode: shadow-value tracking — the numerical debugger (paper §3.5/§6.3).

Every value flows through the computation as a pair ``(truncated, shadow)``.
The shadow lane replays the identical op sequence at full carrier precision —
"as if the entire application had been run in full precision up to that
point". After each truncated op we measure the elementwise deviation with the
hybrid symmetric metric

    |low - shadow| / max(|shadow|, |low|, _ABS_FLOOR)

which degrades to an absolute-error comparison (in units of ``_ABS_FLOOR``)
when the shadow value is zero or denormal — a raw ``|low-shadow|/|shadow|``
would divide by zero there and poison the per-location max with ``inf``/
``nan``. The metric is bounded by 2 for finite lanes; ``inf`` is reserved for
genuine lane disagreement on finiteness (one lane overflowed or went NaN).
Elements above the user threshold are *flagged* and accumulated per source
location. The result is the paper's heatmap of code locations that do not
react well to truncation.

Unlike RAPTOR's pointer-swizzling shadow structs (shared-memory only, crashes
on MPI reductions), the report is a pure pytree of counters that rides the
normal SPMD data path — mem-mode here works under jit, scan, cond, while and
across meshes.

Trajectory mode (``traj_len > 0``, see ``repro.profile.trajectory``) widens
the accumulators to ``(traj_len, n_loc)`` ring buffers indexed by a step
counter that advances once per iteration of every OUTERMOST loop (the app's
``step`` scan / solver ``while``), so the report records *when* each site's
error appears, not just how large it got. The step counter and the ring
buffers ride the same functional carry as the scalar stats — never a Python
closure — so all iterations of scan/while/cond bodies are reflected.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax._src import core as jcore

from repro import compat
from repro.core.policy import TruncationPolicy, join_stack
from repro.kernels.quantize_em.ops import quantize

# Hybrid deviation floor: below this magnitude (on BOTH lanes) deviations are
# measured absolutely in units of the floor instead of relatively, so an
# exactly-zero or denormal shadow value can never manufacture an inf/nan
# "relative" error (the zero-crossing poisoning bug).
_ABS_FLOOR = 1e-6


def deviation(lowf, shf):
    """Elementwise hybrid symmetric deviation between the truncated and
    shadow lanes (both float32): bounded by 2 for finite inputs, exactly 0
    for bitwise-equal lanes (including inf==inf), and inf only when the
    lanes disagree on finiteness or the shadow itself is NaN."""
    diff = jnp.abs(lowf - shf)
    denom = jnp.maximum(jnp.maximum(jnp.abs(shf), jnp.abs(lowf)),
                        jnp.float32(_ABS_FLOOR))
    rel = diff / denom
    rel = jnp.where(lowf == shf, jnp.zeros_like(rel), rel)
    # inf-vs-finite gives inf/inf = nan, nan in either lane propagates:
    # both are maximal disagreement, not missing data
    return jnp.where(jnp.isnan(rel), jnp.full_like(rel, jnp.inf), rel)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class RaptorReport:
    """Per-location numerical deviation statistics (a pytree of arrays)."""

    locations: Tuple[str, ...] = dataclasses.field(
        metadata=dict(static=True))       # static: loc id -> description
    flags: Any = None                     # i64[n_loc] elements over threshold
    max_rel: Any = None                   # f32[n_loc] max relative deviation
    op_counts: Any = None                 # i64[n_loc] truncated elements seen

    def top(self, k: int = 10) -> List[Tuple[str, int, float]]:
        flags = jax.device_get(self.flags)
        max_rel = jax.device_get(self.max_rel)
        order = sorted(range(len(self.locations)), key=lambda i: -int(flags[i]))
        return [(self.locations[i], int(flags[i]), float(max_rel[i]))
                for i in order[:k]]

    def summary(self, k: int = 10) -> str:
        lines = [f"  {'flags':>12} {'max_rel_err':>12}  location"]
        for loc, f, m in self.top(k):
            lines.append(f"  {f:>12d} {m:>12.3e}  {loc}")
        return "\n".join(lines)

    # ---- cross-shard reductions (SPMD mem-mode) ---------------------------
    # Exactness contract under data parallelism: ``flags`` and ``op_counts``
    # are sums of per-element predicates, so the global report is the
    # elementwise SUM of per-shard reports; ``max_rel`` is a MAX. Reducing
    # per-shard reports with either method below therefore reproduces the
    # single-device report bit-for-bit (integer sums are exact; float max is
    # order-invariant). Note the jit/GSPMD path (``memtrace(mesh=...)``)
    # needs NO explicit reduction — XLA already emits the cross-device
    # collectives for the in-graph sums/maxes.

    def allreduce(self, axis_name: str) -> "RaptorReport":
        """In-SPMD reduction for per-shard reports built INSIDE a
        ``shard_map``/``pmap`` body: ``psum`` of flags/op_counts, ``pmax``
        of max_rel over the mapped mesh axis.

        A shard_map body computes per-SHARD semantics, so the reduced
        report equals the global one exactly when each shard's execution is
        a slice of the global program (per-example models, contractions
        along unsharded dims). Programs with cross-batch reductions (a
        global mean/loss) should use ``memtrace(mesh=...)`` instead, where
        GSPMD keeps the reduction — and hence the report — global."""
        return RaptorReport(
            self.locations,
            lax.psum(self.flags, axis_name),
            lax.pmax(self.max_rel, axis_name),
            lax.psum(self.op_counts, axis_name))

    def merge(self, other: "RaptorReport") -> "RaptorReport":
        """Host-side pairwise reduction (e.g. across processes/ranks)."""
        if self.locations != other.locations:
            raise ValueError("RaptorReport.merge: location tables differ "
                             "(reports come from different computations)")
        return RaptorReport(
            self.locations,
            jnp.asarray(self.flags) + jnp.asarray(other.flags),
            jnp.maximum(jnp.asarray(self.max_rel),
                        jnp.asarray(other.max_rel)),
            jnp.asarray(self.op_counts) + jnp.asarray(other.op_counts))

    @staticmethod
    def merge_all(reports: Sequence["RaptorReport"]) -> "RaptorReport":
        if not reports:
            raise ValueError("merge_all needs at least one report")
        out = reports[0]
        for r in reports[1:]:
            out = out.merge(r)
        return out


def _tree_flags():
    return jax.tree_util.tree_structure((0, 0, 0))


class _Recorder:
    """Mutable-during-trace location table; emits functional accumulators.

    ``traj_len > 0`` switches the stats carry into trajectory mode: the
    tuple grows ring buffers plus a step counter (see module docstring).
    ``traj_sites`` (substring patterns over location descriptions) narrows
    which locations get trajectory columns — blamed/selected sites only —
    shrinking the per-step carry; unselected sites keep their whole-run
    totals and simply have no temporal row."""

    def __init__(self, threshold: float, traj_len: int = 0, traj_sites=None):
        self.threshold = threshold
        self.traj_len = int(traj_len)
        self.traj_sites = (tuple(traj_sites) if traj_sites is not None
                           else None)
        self.locations: List[str] = []
        self.loc_index: Dict[str, int] = {}
        self.traj_cols: Dict[int, int] = {}
        self.n_traj = 1

    def loc_id(self, desc: str) -> int:
        if desc not in self.loc_index:
            self.loc_index[desc] = len(self.locations)
            self.locations.append(desc)
        return self.loc_index[desc]

    def freeze_traj_cols(self) -> None:
        """Assign trajectory columns once the location table is complete."""
        if self.traj_sites is None:
            self.traj_cols = {i: i for i in range(len(self.locations))}
        else:
            self.traj_cols = {}
            for i, desc in enumerate(self.locations):
                if any(pat in desc for pat in self.traj_sites):
                    self.traj_cols[i] = len(self.traj_cols)
        self.n_traj = max(len(self.traj_cols), 1)

    def traj_col(self, idx: int):
        return self.traj_cols.get(idx)


def _count_dtype():
    return jnp.int64 if jax.config.jax_enable_x64 else jnp.int32


def _zero_stats(n: int, traj_len: int = 0, n_traj=None):
    cdt = _count_dtype()
    base = (jnp.zeros((n,), cdt),
            jnp.zeros((n,), jnp.float32),
            jnp.zeros((n,), cdt))
    if not traj_len:
        return base
    nt = n if n_traj is None else n_traj
    return base + (jnp.zeros((traj_len, nt), jnp.float32),  # ring: max dev
                   jnp.zeros((traj_len, nt), jnp.float32),  # ring: |err| sum
                   jnp.zeros((traj_len, nt), jnp.float32),  # ring: |shadow| sum
                   jnp.zeros((traj_len, nt), cdt),          # ring: elements
                   jnp.zeros((nt,), jnp.float32),           # step row: max dev
                   jnp.zeros((nt,), jnp.float32),           # step row: |err|
                   jnp.zeros((nt,), jnp.float32),           # step row: |shadow|
                   jnp.zeros((nt,), cdt),                   # step row: elements
                   jnp.zeros((), jnp.int32))                # step counter


def _accumulate(stats, idx: int, low, shadow, threshold: float, tcol=None):
    flags, max_rel, op_counts, *traj = stats
    lowf = low.astype(jnp.float32)
    shf = shadow.astype(jnp.float32)
    rel = deviation(lowf, shf)
    n_flag = jnp.sum(rel > threshold).astype(flags.dtype)
    m = (jnp.max(rel) if rel.size else jnp.float32(0)).astype(jnp.float32)
    flags = flags.at[idx].add(n_flag)
    max_rel = max_rel.at[idx].max(m)
    op_counts = op_counts.at[idx].add(jnp.asarray(low.size, op_counts.dtype))
    if not traj:
        return (flags, max_rel, op_counts)
    if tcol is None:
        return (flags, max_rel, op_counts, *traj)
    (t_max, t_abs, t_mag, t_cnt,
     r_max, r_abs, r_mag, r_cnt, step) = traj
    # Per-op writes touch only the small (n_traj,) current-step row at a
    # STATIC column (a cheap size-1 update, not a dynamic-index scatter on
    # the (traj_len, n_traj) ring); the ring buffers are written once per
    # step by _fold_step_row. This is what keeps trajectory mode close to
    # plain memtrace cost.
    aerr = jnp.abs(lowf - shf)
    aerr = jnp.where(lowf == shf, jnp.zeros_like(aerr), aerr)
    aerr = jnp.where(jnp.isnan(aerr), jnp.full_like(aerr, jnp.inf), aerr)
    err_sum = (jnp.sum(aerr) if rel.size else jnp.float32(0))
    mag_sum = (jnp.sum(jnp.abs(shf)) if rel.size else jnp.float32(0))
    r_max = r_max.at[tcol].max(m)
    r_abs = r_abs.at[tcol].add(err_sum.astype(jnp.float32))
    r_mag = r_mag.at[tcol].add(mag_sum.astype(jnp.float32))
    r_cnt = r_cnt.at[tcol].add(jnp.asarray(low.size, r_cnt.dtype))
    return (flags, max_rel, op_counts, t_max, t_abs, t_mag, t_cnt,
            r_max, r_abs, r_mag, r_cnt, step)


def _fold_step_row(stats):
    """Fold the current-step row accumulators into the ring buffers at
    ``step % traj_len`` and clear them. Values land in the same rows the
    old per-op ring writes used (max-of-maxes / sum-of-sums, and untouched
    columns fold max(.., 0)/+0 — exact no-ops on the non-negative stats),
    so the report is unchanged; only the write traffic moves."""
    if len(stats) == 3:
        return stats
    (flags, max_rel, op_counts, t_max, t_abs, t_mag, t_cnt,
     r_max, r_abs, r_mag, r_cnt, step) = stats
    row = jnp.remainder(step, t_max.shape[0])
    t_max = t_max.at[row].max(r_max)
    t_abs = t_abs.at[row].add(r_abs)
    t_mag = t_mag.at[row].add(r_mag)
    t_cnt = t_cnt.at[row].add(r_cnt)
    return (flags, max_rel, op_counts, t_max, t_abs, t_mag, t_cnt,
            jnp.zeros_like(r_max), jnp.zeros_like(r_abs),
            jnp.zeros_like(r_mag), jnp.zeros_like(r_cnt), step)


def _bump_step(stats):
    """Advance the trajectory step counter (end of one outermost-loop
    iteration), folding the finished step's row into the ring first;
    identity for non-trajectory stats."""
    if len(stats) == 3:
        return stats
    stats = _fold_step_row(stats)
    return stats[:-1] + (stats[-1] + jnp.int32(1),)


def shadowed_callable(closed: jcore.ClosedJaxpr, out_tree,
                      policy: TruncationPolicy, threshold: float,
                      impl: str = "auto", *, flat_shardings=None,
                      traj_len: int = 0, traj_sites=None):
    """jit-close the paired (truncated, shadow) evaluation once — the
    mem-mode analogue of ``interpreter.quantized_callable``. The RaptorReport
    rides out of jit as a pytree (static location table, array stats).

    ``flat_shardings`` (pre-resolved per-leaf, see ``distributed.sharding.
    flatten_arg_shardings``) GSPMD-partition the paired evaluation over the
    mesh; the report's in-graph sums/maxes become global collectives so it
    is exact under data parallelism (see ``RaptorReport`` reduction
    notes). ``traj_len > 0`` returns a ``TrajectoryReport`` instead (per-step
    ring buffers, same exactness contract)."""
    from repro.core.interpreter import _jit_sharded

    def run(flat):
        outs, report = eval_shadowed(closed.jaxpr, closed.consts, list(flat),
                                     policy, threshold, impl,
                                     traj_len=traj_len, traj_sites=traj_sites)
        return jax.tree_util.tree_unflatten(out_tree, outs), report

    return _jit_sharded(run, flat_shardings)


def eval_shadowed(jaxpr: jcore.Jaxpr, consts: Sequence[Any], args: Sequence[Any],
                  policy: TruncationPolicy, threshold: float, impl: str = "auto",
                  *, traj_len: int = 0,
                  traj_sites=None) -> Tuple[List[Any], Any]:
    """Two-pass evaluation: first a dry trace to build the static location
    table (so the stats arrays have a fixed shape), then the paired eval.

    Returns ``(outs, RaptorReport)``; with ``traj_len > 0`` the report is a
    :class:`repro.profile.trajectory.TrajectoryReport` whose ring buffers
    hold one row per outermost-loop iteration (modulo ``traj_len``).
    ``traj_sites`` (substring patterns over location descriptions) narrows
    the trajectory columns to the matching locations."""
    rec = _Recorder(threshold, traj_len, traj_sites)
    _collect_locations(jaxpr, policy, rec, "")
    n = max(len(rec.locations), 1)
    if not rec.locations:
        rec.loc_id("<no truncated locations>")
    rec.freeze_traj_cols()

    stats = _zero_stats(n, traj_len, rec.n_traj if traj_len else None)
    outs, _, stats = _eval(jaxpr, consts, args, args, policy, threshold, impl,
                           rec, stats)
    # residual fold: ops after (or outside) the outermost loops accumulated
    # into the current-step row since the last bump — land them in the ring
    stats = _fold_step_row(stats)
    report = RaptorReport(tuple(rec.locations), stats[0], stats[1], stats[2])
    if traj_len:
        from repro.profile.trajectory import TrajectoryReport, scope_of_location
        cols = sorted(rec.traj_cols, key=rec.traj_cols.get)
        report = TrajectoryReport(
            totals=report,
            scopes=tuple(scope_of_location(rec.locations[i]) for i in cols),
            max_rel=stats[3], abs_sum=stats[4], mag_sum=stats[5],
            op_counts=stats[6], steps_seen=stats[-1],
            columns=tuple(cols))
    return outs, report


def _loc_desc(eqn, prefix: str) -> str:
    ns = str(eqn.source_info.name_stack)
    frame = compat.user_frame(eqn.source_info)
    src = f"{frame.file_name.split('/')[-1]}:{frame.start_line}" if frame else "?"
    scope = f"{prefix}/{ns}" if prefix and ns else (prefix or ns or "<root>")
    return f"{scope} {eqn.primitive.name} @ {src}"


_SUB_JAXPRS = {
    "jit": ("jaxpr",), "pjit": ("jaxpr",), "closed_call": ("call_jaxpr",),
    "remat2": ("jaxpr",), "checkpoint": ("jaxpr",),
    "scan": ("jaxpr",), "while": ("cond_jaxpr", "body_jaxpr"),
    "custom_jvp_call": ("call_jaxpr",), "custom_vjp_call": ("call_jaxpr",),
}


def _collect_locations(jaxpr: jcore.Jaxpr, policy, rec: _Recorder, prefix: str):
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        sub_prefix = join_stack(prefix, str(eqn.source_info.name_stack))
        if prim in _SUB_JAXPRS:
            for key in _SUB_JAXPRS[prim]:
                inner = eqn.params[key]
                inner = inner.jaxpr if isinstance(inner, jcore.ClosedJaxpr) else inner
                _collect_locations(inner, policy, rec, sub_prefix)
            continue
        if prim == "cond":
            for br in eqn.params["branches"]:
                _collect_locations(br.jaxpr, policy, rec, sub_prefix)
            continue
        for var in eqn.outvars:
            aval = var.aval
            if not hasattr(aval, "dtype"):
                continue
            rule = policy.rule_for(sub_prefix, prim, aval.dtype)
            if rule is not None and jnp.issubdtype(aval.dtype, jnp.floating):
                rec.loc_id(_loc_desc(eqn, prefix))
                break


def _eval(jaxpr, consts, low_args, shadow_args, policy, threshold, impl,
          rec: _Recorder, stats, prefix: str = "", depth: int = 0):
    """``depth`` counts enclosing scan/while bodies: iterations of depth-0
    loops are the trajectory "steps" (the app's outermost step loop); inner
    solver loops accumulate into their enclosing step's row."""
    low_env, sh_env = {}, {}

    def read(v):
        if isinstance(v, jcore.Literal):
            return v.val, v.val
        return low_env[v], sh_env[v]

    def write(v, lo, sh):
        low_env[v] = lo
        sh_env[v] = sh

    for v, c in zip(jaxpr.constvars, consts):
        write(v, c, c)
    for v, lo, sh in zip(jaxpr.invars, low_args, shadow_args):
        write(v, lo, sh)

    for eqn in jaxpr.eqns:
        pairs = [read(v) for v in eqn.invars]
        lows = [p[0] for p in pairs]
        shadows = [p[1] for p in pairs]
        prim = eqn.primitive
        ns = join_stack(prefix, str(eqn.source_info.name_stack))
        handler = _MEM_HOPS.get(prim.name)
        if handler is not None:
            louts, shouts, stats = handler(eqn, lows, shadows, policy,
                                           threshold, impl, rec, stats, ns,
                                           depth)
        else:
            louts = prim.bind(*lows, **eqn.params)
            shouts = prim.bind(*shadows, **eqn.params)
            if not prim.multiple_results:
                louts, shouts = [louts], [shouts]
            louts, shouts = list(louts), list(shouts)
            for i, var in enumerate(eqn.outvars):
                aval = var.aval
                if not hasattr(aval, "dtype"):
                    continue
                rule = policy.rule_for(ns, prim.name, aval.dtype)
                if rule is not None and jnp.issubdtype(aval.dtype, jnp.floating):
                    q = quantize(louts[i], rule.fmt, impl=impl)
                    if rule.mask is not None:
                        q = jnp.where(rule.mask(louts[i]), q, louts[i])
                    louts[i] = q
                    idx = rec.loc_id(_loc_desc(eqn, prefix))
                    stats = _accumulate(stats, idx, q, shouts[i], threshold,
                                        rec.traj_col(idx))
        for var, lo, sh in zip(eqn.outvars, louts, shouts):
            write(var, lo, sh)

    lo_outs = [read(v)[0] for v in jaxpr.outvars]
    sh_outs = [read(v)[1] for v in jaxpr.outvars]
    return lo_outs, sh_outs, stats


# ---- mem-mode HOP handlers (stats ride the carry) --------------------------
# The stats tuple is ALWAYS threaded through the functional carry of the
# rebuilt HOP — never captured from the enclosing Python closure — so every
# iteration of scan/while (and whichever cond branch runs) contributes to the
# per-site accumulators; an error that only appears at iteration k>1 is
# recorded exactly like one at iteration 0 (pinned by tests/test_memmode.py).

def _mem_call(eqn, lows, shadows, policy, threshold, impl, rec, stats,
              prefix="", depth=0):
    closed = eqn.params.get("call_jaxpr", eqn.params.get("jaxpr"))
    closed = closed if isinstance(closed, jcore.ClosedJaxpr) else jcore.ClosedJaxpr(closed, ())
    outs, shouts, stats = _eval(closed.jaxpr, closed.consts, lows, shadows,
                                policy, threshold, impl, rec, stats, prefix,
                                depth)
    return outs, shouts, stats


def _mem_scan(eqn, lows, shadows, policy, threshold, impl, rec, stats,
              prefix="", depth=0):
    p = eqn.params
    closed = p["jaxpr"]
    nc, ncarry = p["num_consts"], p["num_carry"]
    lo_c, sh_c = lows[:nc], shadows[:nc]
    lo_carry, sh_carry = tuple(lows[nc:nc + ncarry]), tuple(shadows[nc:nc + ncarry])
    lo_xs, sh_xs = tuple(lows[nc + ncarry:]), tuple(shadows[nc + ncarry:])

    def body(carry, xs):
        lo_car, sh_car, st = carry
        lo_x, sh_x = xs
        env_low = list(lo_c) + list(lo_car) + list(lo_x)
        env_sh = list(sh_c) + list(sh_car) + list(sh_x)
        lo_out, sh_out, st2 = _eval(closed.jaxpr, closed.consts, env_low,
                                    env_sh, policy, threshold, impl, rec, st,
                                    prefix, depth + 1)
        if depth == 0:
            st2 = _bump_step(st2)   # one outermost scan trip = one step
        lo_out = tuple(lo_out)
        sh_out = tuple(sh_out)
        return ((lo_out[:ncarry], sh_out[:ncarry], st2),
                (lo_out[ncarry:], sh_out[ncarry:]))

    (lo_fin, sh_fin, stats), (lo_ys, sh_ys) = lax.scan(
        body, (lo_carry, sh_carry, stats), (lo_xs, sh_xs),
        length=p["length"], reverse=p["reverse"], unroll=p["unroll"])
    return list(lo_fin) + list(lo_ys), list(sh_fin) + list(sh_ys), stats


def _mem_while(eqn, lows, shadows, policy, threshold, impl, rec, stats,
               prefix="", depth=0):
    p = eqn.params
    cond_closed = _as_closed(p["cond_jaxpr"])
    body_closed = _as_closed(p["body_jaxpr"])
    cn, bn = p["cond_nconsts"], p["body_nconsts"]
    lo_cc, sh_cc = lows[:cn], shadows[:cn]
    lo_bc, sh_bc = lows[cn:cn + bn], shadows[cn:cn + bn]
    lo_car, sh_car = tuple(lows[cn + bn:]), tuple(shadows[cn + bn:])

    def cond_fn(carry):
        lo_c, sh_c, st = carry
        # the truncated program decides control flow; the shadow lane rides
        # along the same path (RAPTOR runs ONE binary — shadows are values,
        # not an alternate execution). Stats from cond-body ops are dropped:
        # a predicate can't update the carry.
        lo, _, _ = _eval(cond_closed.jaxpr, cond_closed.consts,
                         list(lo_cc) + list(lo_c), list(sh_cc) + list(sh_c),
                         policy, threshold, impl, rec, st, prefix, depth + 1)
        return lo[0]

    def body_fn(carry):
        lo_c, sh_c, st = carry
        lo, sh, st2 = _eval(body_closed.jaxpr, body_closed.consts,
                            list(lo_bc) + list(lo_c),
                            list(sh_bc) + list(sh_c),
                            policy, threshold, impl, rec, st, prefix,
                            depth + 1)
        if depth == 0:
            st2 = _bump_step(st2)   # one outermost while trip = one step
        return tuple(lo), tuple(sh), st2

    lo_fin, sh_fin, stats = lax.while_loop(
        cond_fn, body_fn, (lo_car, sh_car, stats))
    return list(lo_fin), list(sh_fin), stats


def _mem_cond(eqn, lows, shadows, policy, threshold, impl, rec, stats,
              prefix="", depth=0):
    idx, *lo_ops = lows
    _, *sh_ops = shadows

    def make_branch(br):
        closed = _as_closed(br)

        def branch(ops):
            lo_in, sh_in, st = ops
            lo, sh, st2 = _eval(closed.jaxpr, closed.consts, list(lo_in),
                                list(sh_in), policy, threshold, impl, rec,
                                st, prefix, depth)
            return tuple(lo), tuple(sh), st2

        return branch

    lo_outs, sh_outs, stats = lax.switch(
        idx, [make_branch(b) for b in eqn.params["branches"]],
        (tuple(lo_ops), tuple(sh_ops), stats))
    return list(lo_outs), list(sh_outs), stats


def _as_closed(jx):
    return jx if isinstance(jx, jcore.ClosedJaxpr) else jcore.ClosedJaxpr(jx, ())


_MEM_HOPS = {
    "jit": _mem_call, "pjit": _mem_call, "closed_call": _mem_call,
    "remat2": _mem_call, "checkpoint": _mem_call,
    "custom_jvp_call": _mem_call, "custom_vjp_call": _mem_call,
    "scan": _mem_scan,
    "while": _mem_while,
    "cond": _mem_cond,
}
