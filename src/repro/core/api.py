"""Public RAPTOR-JAX API.

    from repro.core import api as raptor

    policy = raptor.TruncationPolicy.scoped("model/*/mlp", "e5m7")
    lossy_step = raptor.truncate(train_step, policy)       # op-mode
    out, report = raptor.memtrace(step, policy, 1e-3)(...) # mem-mode
    counts = raptor.profile_counts(step, policy)(...)      # speedup inputs
"""
from __future__ import annotations

import functools
from typing import Callable

import jax

from repro.core import interpreter, memmode, counters
from repro.core.formats import FPFormat, parse_format  # re-export
from repro.core.policy import (  # re-export
    TruncationPolicy, TruncationRule, magnitude_below, magnitude_above,
)

scope = jax.named_scope  # region marker, the _raptor_trunc_func_* analogue


def _flatten_like_make_jaxpr(args, kwargs):
    return jax.tree_util.tree_leaves((args, kwargs))


def truncate(fn: Callable, policy: TruncationPolicy, *, impl: str = "auto"
             ) -> Callable:
    """Return ``fn`` with op-mode truncation applied under ``policy``.

    The wrapper is an ordinary traceable JAX function: compose freely with
    ``jax.jit``, ``jax.grad`` (grad-then-truncate covers the backward pass),
    ``shard_map``/``pjit`` meshes, etc.
    """
    @functools.wraps(fn)
    def wrapped(*args, **kwargs):
        closed, out_shape = jax.make_jaxpr(fn, return_shape=True)(*args, **kwargs)
        flat = _flatten_like_make_jaxpr(args, kwargs)
        outs = interpreter.eval_quantized(
            closed.jaxpr, closed.consts, flat, policy, impl)
        out_tree = jax.tree_util.tree_structure(out_shape)
        return jax.tree_util.tree_unflatten(out_tree, outs)

    return wrapped


def memtrace(fn: Callable, policy: TruncationPolicy, threshold: float = 1e-3,
             *, impl: str = "auto") -> Callable:
    """mem-mode: returns ``(outputs, RaptorReport)`` where the report carries
    per-source-location flag counts and max relative deviations of the
    truncated values against full-precision shadow values."""
    @functools.wraps(fn)
    def wrapped(*args, **kwargs):
        closed, out_shape = jax.make_jaxpr(fn, return_shape=True)(*args, **kwargs)
        flat = _flatten_like_make_jaxpr(args, kwargs)
        outs, report = memmode.eval_shadowed(
            closed.jaxpr, closed.consts, flat, policy, threshold, impl)
        out_tree = jax.tree_util.tree_structure(out_shape)
        return jax.tree_util.tree_unflatten(out_tree, outs), report

    return wrapped


def profile_counts(fn: Callable, policy: TruncationPolicy) -> Callable:
    """Static operation/byte counting (the paper's runtime counters, derived
    from the jaxpr instead): returns a CountReport of truncated vs
    full-precision FLOPs and bytes per scope."""
    def wrapped(*args, **kwargs):
        closed = jax.make_jaxpr(fn)(*args, **kwargs)
        return counters.count_jaxpr(closed.jaxpr, policy)

    return wrapped
