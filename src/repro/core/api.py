"""Public RAPTOR-JAX API.

    from repro.core import api as raptor

    policy = raptor.TruncationPolicy.scoped("model/*/mlp", "e5m7")
    lossy_step = raptor.truncate(train_step, policy)       # op-mode
    out, report = raptor.memtrace(step, policy, 1e-3)(...) # mem-mode
    counts = raptor.profile_counts(step, policy)(...)      # speedup inputs

Op-mode and mem-mode wrappers cache the transformed, ``jax.jit``-closed
computation keyed on (input pytree structure, input avals, policy identity):
the jaxpr is walked and the policy matched once per distinct signature, and
every further call is a compiled-executable dispatch. This is what makes the
automated precision search (``repro.search``) affordable — each candidate
policy costs one trace, each repeat evaluation costs ~a kernel launch.
"""
from __future__ import annotations

import functools
from typing import Callable

import numpy as np

import jax
from jax._src import core as jcore

from repro.core import interpreter, memmode, counters
from repro.core.formats import FPFormat, parse_format  # re-export
from repro.core.policy import (  # re-export
    TruncationPolicy, TruncationRule, magnitude_below, magnitude_above,
)

scope = jax.named_scope  # region marker, the _raptor_trunc_func_* analogue


def _flatten_like_make_jaxpr(args, kwargs):
    return jax.tree_util.tree_leaves((args, kwargs))


def _leaf_key(x):
    """Cache-key component for one input leaf: shape + dtype + weak_type
    (python scalars and weak-typed arrays promote differently than strong
    arrays of the same dtype, so they must not share a cache entry)."""
    if hasattr(x, "shape") and hasattr(x, "dtype"):
        return (tuple(x.shape), str(x.dtype),
                bool(getattr(x, "weak_type", False)))
    return (np.shape(x), str(np.result_type(x)), True)


def _has_tracer(xs) -> bool:
    return any(isinstance(x, jcore.Tracer) for x in xs)


def _cached_transform(fn: Callable, build: Callable, fallback: Callable,
                      key_suffix: tuple, cache: bool) -> Callable:
    """Shared trace-cache machinery for ``truncate``/``memtrace``.

    ``build(closed, out_tree)`` -> jit-closed callable taking flat leaves;
    ``fallback(closed, out_tree, leaves)`` -> direct (uncached) evaluation,
    used under an outer trace where caching a jaxpr would leak tracers.
    """
    @functools.wraps(fn)
    def wrapped(*args, **kwargs):
        leaves, in_tree = jax.tree_util.tree_flatten((args, kwargs))
        use_cache = cache and not _has_tracer(leaves)
        key = None
        if use_cache:
            key = (in_tree, tuple(_leaf_key(l) for l in leaves)) + key_suffix
            entry = wrapped._cache.get(key)
            if entry is not None:
                return entry(leaves)
        wrapped.n_traces += 1
        closed, out_shape = jax.make_jaxpr(fn, return_shape=True)(*args, **kwargs)
        out_tree = jax.tree_util.tree_structure(out_shape)
        if not use_cache or _has_tracer(closed.consts):
            return fallback(closed, out_tree, leaves)
        entry = build(closed, out_tree)
        wrapped._cache[key] = entry
        return entry(leaves)

    wrapped._cache = {}
    wrapped.n_traces = 0          # times the jaxpr walk actually ran
    wrapped.cache_clear = wrapped._cache.clear
    wrapped.cache_size = lambda: len(wrapped._cache)
    return wrapped


def truncate(fn: Callable, policy: TruncationPolicy, *, impl: str = "auto",
             cache: bool = True) -> Callable:
    """Return ``fn`` with op-mode truncation applied under ``policy``.

    The wrapper is an ordinary traceable JAX function: compose freely with
    ``jax.jit``, ``jax.grad`` (grad-then-truncate covers the backward pass),
    ``shard_map``/``pjit`` meshes, etc. Under an outer trace it falls back to
    direct interpretation; called concretely it reuses a jit-closed transform
    per input signature (``wrapper.n_traces`` counts actual jaxpr walks)."""
    def build(closed, out_tree):
        return interpreter.quantized_callable(closed, out_tree, policy, impl)

    def fallback(closed, out_tree, leaves):
        outs = interpreter.eval_quantized(
            closed.jaxpr, closed.consts, leaves, policy, impl)
        return jax.tree_util.tree_unflatten(out_tree, outs)

    return _cached_transform(fn, build, fallback,
                             (policy.cache_key(), impl), cache)


def memtrace(fn: Callable, policy: TruncationPolicy, threshold: float = 1e-3,
             *, impl: str = "auto", cache: bool = True) -> Callable:
    """mem-mode: returns ``(outputs, RaptorReport)`` where the report carries
    per-source-location flag counts and max relative deviations of the
    truncated values against full-precision shadow values."""
    def build(closed, out_tree):
        return memmode.shadowed_callable(closed, out_tree, policy, threshold,
                                         impl)

    def fallback(closed, out_tree, leaves):
        outs, report = memmode.eval_shadowed(
            closed.jaxpr, closed.consts, leaves, policy, threshold, impl)
        return jax.tree_util.tree_unflatten(out_tree, outs), report

    return _cached_transform(fn, build, fallback,
                             (policy.cache_key(), threshold, impl), cache)


def profile_counts(fn: Callable, policy: TruncationPolicy) -> Callable:
    """Static operation/byte counting (the paper's runtime counters, derived
    from the jaxpr instead): returns a CountReport of truncated vs
    full-precision FLOPs and bytes per scope."""
    def wrapped(*args, **kwargs):
        closed = jax.make_jaxpr(fn)(*args, **kwargs)
        return counters.count_jaxpr(closed.jaxpr, policy)

    return wrapped
