"""Public RAPTOR-JAX API.

    from repro.core import api as raptor

    policy = raptor.TruncationPolicy.scoped("model/*/mlp", "e5m7")
    lossy_step = raptor.truncate(train_step, policy)       # op-mode
    out, report = raptor.memtrace(step, policy,
                                  threshold=1e-3)(...)     # mem-mode
    counts = raptor.profile_counts(step, policy)(...)      # speedup inputs

Op-mode and mem-mode wrappers cache the transformed, ``jax.jit``-closed
computation keyed on (input pytree structure, input avals, policy identity):
the jaxpr is walked and the policy matched once per distinct signature, and
every further call is a compiled-executable dispatch. This is what makes the
automated precision search (``repro.search``) affordable — each candidate
policy costs one trace, each repeat evaluation costs ~a kernel launch.

``truncate_sweep`` goes one step further: the cache is keyed on quantize
*sites* rather than policy identity, and the formats become a runtime
``(num_sites, 4)`` table argument. One compile per input signature serves
every candidate policy — a new policy is a new table, and a whole ladder of
policies evaluates in one ``vmap``-batched call. That is the zero-recompile
policy-sweep path the batched precision search runs on.

Canonical surface — one shape for every transform. Positional arguments are
``(fn, policy)`` only; everything else is a keyword-only tail shared across
the surface (``memtrace``'s historical positional ``threshold`` is accepted
behind a deprecation shim):

    transform            returns                    keyword-only tail
    -------------------  -------------------------  ---------------------------
    truncate             fn'                        impl, cache, mesh,
                                                    in_shardings
    truncate_sweep       SweepHandle factory        impl, cache, mesh,
                                                    batch_axis, in_shardings
    memtrace             (out, RaptorReport)        threshold, impl, cache,
                                                    mesh, in_shardings
    profile_trajectory   (out, TrajectoryReport)    threshold, n_steps, impl,
                                                    cache, mesh, in_shardings
    profile_counts       CountReport                cache, mesh, in_shardings

All five trace-cache per input signature and expose ``n_traces`` /
``cache_size()`` / ``cache_clear()``. ``mesh``/``in_shardings`` partition
the cached executable across a device mesh (``profile_counts`` accepts them
for surface uniformity; static counts are partition-invariant).
"""
from __future__ import annotations

import functools
import warnings
from typing import Callable

import numpy as np

import jax
from jax._src import core as jcore

from repro.core import interpreter, memmode, counters
from repro.core.formats import FPFormat, parse_format  # re-export
from repro.core.policy import (  # re-export
    TruncationPolicy, TruncationRule, magnitude_below, magnitude_above,
)

scope = jax.named_scope  # region marker, the _raptor_trunc_func_* analogue


def _flatten_like_make_jaxpr(args, kwargs):
    return jax.tree_util.tree_leaves((args, kwargs))


def _leaf_key(x):
    """Cache-key component for one input leaf: shape + dtype + weak_type
    (python scalars and weak-typed arrays promote differently than strong
    arrays of the same dtype, so they must not share a cache entry)."""
    if hasattr(x, "shape") and hasattr(x, "dtype"):
        return (tuple(x.shape), str(x.dtype),
                bool(getattr(x, "weak_type", False)))
    return (np.shape(x), str(np.result_type(x)), True)


def _has_tracer(xs) -> bool:
    return any(isinstance(x, jcore.Tracer) for x in xs)


def _mesh_key(mesh, in_shardings, *extra) -> tuple:
    """Hashable cache-key component for a (mesh, shardings) pair. Shardings
    may arrive as an arbitrary pytree (lists are unhashable), so flatten to
    (treedef, leaves) — NamedSharding/PartitionSpec leaves hash fine."""
    if mesh is None and in_shardings is None and not any(extra):
        return (None,)
    leaves, tree = jax.tree_util.tree_flatten(
        in_shardings, is_leaf=lambda x: x is None)
    return (mesh, tree, tuple(leaves)) + extra


def _signature_key(in_tree, leaves, suffix: tuple) -> tuple:
    """The shared trace-cache key scheme: input pytree structure + per-leaf
    aval signature + transform identity. Both the policy-keyed caches
    (truncate/memtrace) and the sites-keyed cache (truncate_sweep) use this
    so leaf/weak-type semantics can never diverge between them."""
    return (in_tree, tuple(_leaf_key(l) for l in leaves)) + suffix


def _cached_transform(fn: Callable, build: Callable, fallback: Callable,
                      key_suffix: tuple, cache: bool) -> Callable:
    """Shared trace-cache machinery for ``truncate``/``memtrace``.

    ``build(closed, out_tree, args, kwargs)`` -> jit-closed callable taking
    flat leaves (args/kwargs are the example call, for resolving
    per-argument shardings against the input structure);
    ``fallback(closed, out_tree, leaves)`` -> direct (uncached) evaluation,
    used under an outer trace where caching a jaxpr would leak tracers.
    """
    @functools.wraps(fn)
    def wrapped(*args, **kwargs):
        leaves, in_tree = jax.tree_util.tree_flatten((args, kwargs))
        use_cache = cache and not _has_tracer(leaves)
        key = None
        if use_cache:
            key = _signature_key(in_tree, leaves, key_suffix)
            entry = wrapped._cache.get(key)
            if entry is not None:
                return entry(leaves)
        wrapped.n_traces += 1
        closed, out_shape = jax.make_jaxpr(fn, return_shape=True)(*args, **kwargs)
        out_tree = jax.tree_util.tree_structure(out_shape)
        if not use_cache or _has_tracer(closed.consts):
            return fallback(closed, out_tree, leaves)
        entry = build(closed, out_tree, args, kwargs)
        wrapped._cache[key] = entry
        return entry(leaves)

    wrapped._cache = {}
    wrapped.n_traces = 0          # times the jaxpr walk actually ran
    wrapped.cache_clear = wrapped._cache.clear
    wrapped.cache_size = lambda: len(wrapped._cache)
    return wrapped


def truncate(fn: Callable, policy: TruncationPolicy, *, impl: str = "auto",
             cache: bool = True, mesh=None, in_shardings=None,
             native_fp8: bool = False) -> Callable:
    """Return ``fn`` with op-mode truncation applied under ``policy``.

    The wrapper is an ordinary traceable JAX function: compose freely with
    ``jax.jit``, ``jax.grad`` (grad-then-truncate covers the backward pass),
    ``shard_map``/``pjit`` meshes, etc. Under an outer trace it falls back to
    direct interpretation; called concretely it reuses a jit-closed transform
    per input signature (``wrapper.n_traces`` counts actual jaxpr walks).

    ``mesh``/``in_shardings`` SPMD-partition the cached executable: inputs
    are placed per the shardings (jit's convention — a single sharding or
    ``PartitionSpec`` broadcasts to every leaf, or a pytree prefix of the
    positional-args tuple; ``None`` replicates) and the truncated
    computation runs data-parallel across the mesh. The fallback path under
    an outer trace ignores them (the enclosing jit owns the partitioning).

    ``native_fp8``: execute ``quantize_dot_inputs`` dot sites whose rule
    format maps onto ``float8_e4m3fn`` (e4m3, fn overflow) on native fp8
    storage with f32 accumulation (``repro.kernels.fp8_dot``) instead of
    emulating the rounding in the carrier dtype — same bit-exact input
    quantize, but the contraction actually exercises the low-precision
    unit."""
    from repro.distributed.sharding import flatten_arg_shardings

    def build(closed, out_tree, bargs, bkwargs):
        return interpreter.quantized_callable(
            closed, out_tree, policy, impl, native_fp8=native_fp8,
            flat_shardings=flatten_arg_shardings(
                mesh, in_shardings, bargs, bkwargs))

    def fallback(closed, out_tree, leaves):
        outs = interpreter.eval_quantized(
            closed.jaxpr, closed.consts, leaves, policy, impl,
            native_fp8=native_fp8)
        return jax.tree_util.tree_unflatten(out_tree, outs)

    return _cached_transform(
        fn, build, fallback,
        (policy.cache_key(), impl, native_fp8,
         _mesh_key(mesh, in_shardings)), cache)


class SweepHandle:
    """One input signature's runtime-parameterized executable plus its site
    layout. Every candidate policy runs through the same compiled callable —
    only the ``(num_sites, 4)`` int32 format table changes.

    * ``handle(table)`` — evaluate one candidate table.
    * ``handle.batch(tables)`` — evaluate a ``(K, num_sites, 4)`` stack of
      candidates in one vmapped call (outputs gain a leading K axis).
    * ``handle.table(policy)`` — lower a :class:`TruncationPolicy` to its
      table (unmatched sites get the identity row).

    Under a sharded sweep (``truncate_sweep(..., mesh=...)``) the leading K
    axis of ``batch`` is partitioned across the mesh's probe axis; ladders
    whose K doesn't divide the axis are padded with identity rows and the
    padded outputs sliced off, so results are positionally identical to the
    unsharded path.
    """

    def __init__(self, index, run, run_batch, leaves, shard_multiple=1):
        self._index = index
        self._run = run
        self._run_batch = run_batch
        self._leaves = leaves
        self._shard_multiple = shard_multiple

    @property
    def sites(self):
        return self._index.sites

    @property
    def num_sites(self) -> int:
        return len(self._index)

    def table(self, policy: TruncationPolicy) -> np.ndarray:
        return self._index.table_for(policy)

    def tables(self, policies) -> np.ndarray:
        """Stack several candidate policies into a (K, num_sites, 4) batch."""
        return np.stack([self._index.table_for(p) for p in policies])

    def identity_table(self) -> np.ndarray:
        return self._index.identity_table()

    def __call__(self, table):
        return self._run(table, self._leaves)

    def batch(self, tables):
        from repro.distributed.sharding import drop_padded_rows
        k = int(np.shape(tables)[0])
        mult = self._shard_multiple
        pad = -k % mult
        if pad:
            tables = np.concatenate(
                [np.asarray(tables),
                 np.tile(self._index.identity_table(), (pad, 1, 1))])
        outs = self._run_batch(tables, self._leaves)
        if pad:
            outs = drop_padded_rows(outs, k)
        return outs


def truncate_sweep(fn: Callable, site_policy: TruncationPolicy, *,
                   impl: str = "auto", cache: bool = True, mesh=None,
                   batch_axis: str = "probe",
                   in_shardings=None) -> Callable:
    """Runtime-parameterized op-mode: compile once, sweep policies for free.

    ``site_policy`` fixes *where* quantization may happen — every equation
    output it matches becomes an indexed quantize site (its formats are
    irrelevant; use e.g. ``TruncationPolicy.everywhere("e5m2")`` for "any
    float op", or one rule per search scope). Calling the returned wrapper
    with concrete inputs yields a :class:`SweepHandle` bound to those
    inputs; any candidate policy whose matched set is a subset of the site
    policy's lowers to a format table and evaluates WITHOUT retracing or
    recompiling. ``wrapper.n_traces`` counts actual jaxpr walks (one per
    input signature).

    ``mesh`` makes the sweep candidate-parallel: ``handle.batch`` shards the
    leading K (candidate) axis over ``mesh.shape[batch_axis]`` devices —
    table rows replicated, inputs placed per ``in_shardings`` (default
    replicated) — so a W-candidate ladder evaluates on W/ndev devices
    concurrently. Results stay bit-for-bit identical to the unsharded path
    (ladders are identity-padded to the shard multiple and sliced back)."""
    def wrapped(*args, **kwargs) -> SweepHandle:
        leaves, in_tree = jax.tree_util.tree_flatten((args, kwargs))
        if _has_tracer(leaves):
            raise TypeError(
                "truncate_sweep handles concrete inputs only; compose "
                "jit/grad with `truncate` instead")
        key = _signature_key(
            in_tree, leaves,
            (site_policy.cache_key(), impl,
             _mesh_key(mesh, in_shardings, batch_axis)))
        entry = wrapped._cache.get(key) if cache else None
        if entry is None:
            wrapped.n_traces += 1
            closed, out_shape = jax.make_jaxpr(
                fn, return_shape=True)(*args, **kwargs)
            if _has_tracer(closed.consts):
                # a closure captured a tracer from an enclosing trace: the
                # handle would outlive that trace, and caching it would
                # poison every later concrete call of the same signature
                raise TypeError(
                    "truncate_sweep traced a function that closes over a "
                    "value from an enclosing jit/grad trace; call it "
                    "outside the trace or pass the value as an argument")
            out_tree = jax.tree_util.tree_structure(out_shape)
            index = interpreter.enumerate_sites(closed, site_policy)
            from repro.distributed.sharding import flatten_arg_shardings
            run, run_batch = interpreter.parameterized_callable(
                closed, out_tree, index, impl,
                mesh=mesh, batch_axis=batch_axis,
                flat_shardings=flatten_arg_shardings(
                    mesh, in_shardings, args, kwargs))
            entry = (index, run, run_batch)
            if cache:
                wrapped._cache[key] = entry
        index, run, run_batch = entry
        from repro.distributed.sharding import probe_axis_size
        return SweepHandle(index, run, run_batch, leaves,
                           shard_multiple=probe_axis_size(mesh, batch_axis))

    wrapped._cache = {}
    wrapped.n_traces = 0
    wrapped.cache_clear = wrapped._cache.clear
    wrapped.cache_size = lambda: len(wrapped._cache)
    return wrapped


def _legacy_threshold_shim(name: str, legacy, threshold: float) -> float:
    """One deprecation cycle for the historical positional ``threshold``:
    ``memtrace(fn, policy, 1e-4)`` keeps working but warns; the canonical
    spelling is keyword-only (``threshold=1e-4``), uniform across the
    surface table above."""
    if legacy is None:
        return threshold
    warnings.warn(
        f"{name}(fn, policy, threshold) with a positional threshold is "
        f"deprecated; pass threshold= as a keyword",
        DeprecationWarning, stacklevel=3)
    return float(legacy)


def memtrace(fn: Callable, policy: TruncationPolicy, _threshold=None,
             *, threshold: float = 1e-3, impl: str = "auto",
             cache: bool = True, mesh=None, in_shardings=None) -> Callable:
    """mem-mode: returns ``(outputs, RaptorReport)`` where the report carries
    per-source-location flag counts and max relative deviations of the
    truncated values against full-precision shadow values.

    ``mesh``/``in_shardings`` run the paired (truncated, shadow) evaluation
    data-parallel across a device mesh. The report stays EXACT under data
    parallelism: flag/op counts are global sums and max_rel a global max,
    reduced by XLA inside the partitioned executable — the thing RAPTOR's
    pointer-swizzled shadow structs cannot do across ranks (paper §6.3).
    For hand-rolled ``shard_map``/``pmap`` bodies, reduce per-shard reports
    with ``RaptorReport.allreduce(axis_name)`` (in-SPMD) or
    ``RaptorReport.merge_all(reports)`` (host-side)."""
    threshold = _legacy_threshold_shim("memtrace", _threshold, threshold)
    from repro.distributed.sharding import flatten_arg_shardings

    def build(closed, out_tree, bargs, bkwargs):
        return memmode.shadowed_callable(
            closed, out_tree, policy, threshold, impl,
            flat_shardings=flatten_arg_shardings(
                mesh, in_shardings, bargs, bkwargs))

    def fallback(closed, out_tree, leaves):
        outs, report = memmode.eval_shadowed(
            closed.jaxpr, closed.consts, leaves, policy, threshold, impl)
        return jax.tree_util.tree_unflatten(out_tree, outs), report

    return _cached_transform(
        fn, build, fallback,
        (policy.cache_key(), threshold, impl,
         _mesh_key(mesh, in_shardings)), cache)


def profile_trajectory(fn: Callable, policy: TruncationPolicy,
                       _threshold=None, *, threshold: float = 1e-3,
                       n_steps: int = 128, sites=None, impl: str = "auto",
                       cache: bool = True, mesh=None,
                       in_shardings=None) -> Callable:
    """Temporal mem-mode: returns ``(outputs, TrajectoryReport)`` where the
    report holds an ``(n_steps, n_loc)`` per-step deviation trajectory on
    top of the usual whole-run totals (see ``repro.profile.trajectory``).

    ``n_steps`` sizes the ring buffer; one row per iteration of the
    program's outermost ``scan``/``while`` loops (the app step loop — size
    it to ``MiniApp.n_steps`` for an exact trajectory; longer runs wrap).
    Inner solver loops accumulate into their enclosing step's row, and a
    straight-line program lands entirely in row 0.

    ``sites`` restricts the per-step trajectory to matching truncated sites
    (substring patterns over site location descriptions, same matching as
    ``TruncationPolicy`` rules): only matching sites get a trajectory
    column, which cuts the ring-buffer memory and per-step bookkeeping for
    wide tables to the handful of blamed sites under study. Whole-run
    totals still cover every truncated site; ``TrajectoryReport.columns``
    records the column -> location mapping. ``None`` keeps every site.

    Trace-cached and meshable exactly like ``memtrace``: with
    ``mesh``/``in_shardings`` the trajectory's sums/maxes are reduced by
    XLA's collectives inside the partitioned executable. Every signal the
    temporal analysis decides on — per-step max deviation, op counts, the
    step counter — is bit-identical to the single-device run (integer sums
    and float maxima are order-invariant); the float magnitude sums
    reproduce up to cross-shard summation order, the usual float-reduction
    contract. Hand-rolled ``shard_map`` bodies reduce with
    ``TrajectoryReport.allreduce``/``merge_all``."""
    threshold = _legacy_threshold_shim("profile_trajectory", _threshold,
                                       threshold)
    if n_steps < 1:
        raise ValueError(f"n_steps must be >= 1, got {n_steps}")
    from repro.distributed.sharding import flatten_arg_shardings

    def build(closed, out_tree, bargs, bkwargs):
        return memmode.shadowed_callable(
            closed, out_tree, policy, threshold, impl,
            traj_len=n_steps, traj_sites=sites,
            flat_shardings=flatten_arg_shardings(
                mesh, in_shardings, bargs, bkwargs))

    def fallback(closed, out_tree, leaves):
        outs, report = memmode.eval_shadowed(
            closed.jaxpr, closed.consts, leaves, policy, threshold, impl,
            traj_len=n_steps, traj_sites=sites)
        return jax.tree_util.tree_unflatten(out_tree, outs), report

    return _cached_transform(
        fn, build, fallback,
        ("trajectory", policy.cache_key(), threshold, impl, n_steps,
         tuple(sites) if sites is not None else None,
         _mesh_key(mesh, in_shardings)), cache)


def profile_counts(fn: Callable, policy: TruncationPolicy, *,
                   cache: bool = True, mesh=None,
                   in_shardings=None) -> Callable:
    """Static operation/byte counting (the paper's runtime counters, derived
    from the jaxpr instead): returns a CountReport of truncated vs
    full-precision FLOPs and bytes per scope.

    Trace-cached per input signature like the other transforms (counts are
    pure functions of the jaxpr, so a cache hit skips the trace + jaxpr walk
    entirely). ``mesh``/``in_shardings`` are accepted for surface uniformity
    and only contribute to the cache key — static counts are
    partition-invariant."""
    def wrapped(*args, **kwargs):
        leaves, in_tree = jax.tree_util.tree_flatten((args, kwargs))
        use_cache = cache and not _has_tracer(leaves)
        key = None
        if use_cache:
            key = _signature_key(
                in_tree, leaves,
                ("counts", policy.cache_key(), _mesh_key(mesh, in_shardings)))
            hit = wrapped._cache.get(key)
            if hit is not None:
                return hit
        wrapped.n_traces += 1
        closed = jax.make_jaxpr(fn)(*args, **kwargs)
        report = counters.count_jaxpr(closed.jaxpr, policy)
        if use_cache and not _has_tracer(closed.consts):
            wrapped._cache[key] = report
        return report

    wrapped._cache = {}
    wrapped.n_traces = 0
    wrapped.cache_clear = wrapped._cache.clear
    wrapped.cache_size = lambda: len(wrapped._cache)
    return wrapped
