"""Floating-point format algebra.

The paper's truncation target is a pair ``(exponent_bits, mantissa_bits)``
(RAPTOR flag ``--raptor-truncate-all=64_to_5_14``).  ``FPFormat`` captures
that pair plus the overflow convention, and knows how to describe its own
representable grid (bias, min/max exponent, subnormal spacing) — everything
the quantizer and the speedup model need.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class FPFormat:
    """An IEEE-754-style binary format with 1 sign bit, ``exp_bits`` exponent
    bits and ``man_bits`` *stored* mantissa bits (implicit leading one).

    ``saturate``: on overflow, clamp to the max finite value (OCP e4m3
    convention) instead of producing ±inf (e5m2 / IEEE convention).
    """

    exp_bits: int
    man_bits: int
    saturate: bool = False
    ieee_inf: bool = True  # False = "fn" layout: no inf, top exponent reclaimed
    name: Optional[str] = None

    def __post_init__(self):
        if not (1 <= self.exp_bits <= 11):
            raise ValueError(f"exp_bits must be in [1, 11], got {self.exp_bits}")
        if not (0 <= self.man_bits <= 52):
            raise ValueError(f"man_bits must be in [0, 52], got {self.man_bits}")

    # --- derived constants -------------------------------------------------
    @property
    def bias(self) -> int:
        return (1 << (self.exp_bits - 1)) - 1

    @property
    def max_exp(self) -> int:
        """Largest unbiased exponent of a finite normal number."""
        off = 2 if self.ieee_inf else 1
        return (1 << self.exp_bits) - off - self.bias

    @property
    def min_exp(self) -> int:
        """Smallest unbiased exponent of a normal number."""
        return 1 - self.bias

    @property
    def max_finite(self) -> float:
        if self.ieee_inf:
            return float(2.0 ** self.max_exp * (2.0 - 2.0 ** (-self.man_bits)))
        # fn layout: all-ones exponent+mantissa is NaN, so the top mantissa
        # slot at the top exponent is lost (e4m3fn max = 448).
        return float(2.0 ** self.max_exp * (2.0 - 2.0 ** (1 - self.man_bits)))

    @property
    def min_normal(self) -> float:
        return float(2.0 ** self.min_exp)

    @property
    def min_subnormal(self) -> float:
        return float(2.0 ** (self.min_exp - self.man_bits))

    @property
    def bits(self) -> int:
        return 1 + self.exp_bits + self.man_bits

    # --- identity ----------------------------------------------------------
    @property
    def key(self) -> str:
        sat = "s" if self.saturate else ""
        return self.name or f"e{self.exp_bits}m{self.man_bits}{sat}"

    @property
    def cache_key(self) -> str:
        """Unambiguous identity string: unlike ``key`` it always spells out
        the overflow convention, so two formats that round differently can
        never alias in a trace cache."""
        return (f"e{self.exp_bits}m{self.man_bits}"
                f"{'s' if self.saturate else ''}"
                f"{'' if self.ieee_inf else 'fn'}")

    def __str__(self) -> str:
        return self.key

    def with_mantissa(self, man_bits: int) -> "FPFormat":
        return dataclasses.replace(self, man_bits=man_bits, name=None)

    # --- lossless JSON round trip -------------------------------------------
    def to_json(self) -> dict:
        """Every field spelled out — unlike ``key`` (which elides the inf
        convention) this can never alias two formats that round differently."""
        return {"exp_bits": self.exp_bits, "man_bits": self.man_bits,
                "saturate": self.saturate, "ieee_inf": self.ieee_inf,
                "name": self.name}

    @staticmethod
    def from_json(data: dict) -> "FPFormat":
        return FPFormat(exp_bits=int(data["exp_bits"]),
                        man_bits=int(data["man_bits"]),
                        saturate=bool(data["saturate"]),
                        ieee_inf=bool(data["ieee_inf"]),
                        name=data.get("name"))


# --- registry of common formats ---------------------------------------------
FP64 = FPFormat(11, 52, name="fp64")
FP32 = FPFormat(8, 23, name="fp32")
TF32 = FPFormat(8, 10, name="tf32")
BF16 = FPFormat(8, 7, name="bf16")
FP16 = FPFormat(5, 10, name="fp16")
E5M2 = FPFormat(5, 2, name="e5m2")
# OCP 8-bit formats. Both e4m3 entries use the "fn" exponent layout (no inf,
# top exponent reclaimed, max finite = 448 = ml_dtypes.float8_e4m3fn.max);
# they differ ONLY in overflow handling:
#   E4M3FN — overflow -> NaN, exactly the ml_dtypes/OCP cast convention
#            (cross-checked bit-for-bit in tests/test_formats_fp8.py)
#   E4M3   — overflow saturates to +/-448, the training-friendly convention
#            hardware quantizers use (e.g. TE's saturating cast)
E4M3 = FPFormat(4, 3, saturate=True, ieee_inf=False, name="e4m3")
E4M3FN = FPFormat(4, 3, saturate=False, ieee_inf=False, name="e4m3fn")

_REGISTRY = {f.key: f for f in (FP64, FP32, TF32, BF16, FP16, E5M2, E4M3, E4M3FN)}


def parse_format(spec) -> FPFormat:
    """Parse ``'bf16'``, ``'e5m14'``, ``'5_14'`` or an FPFormat instance."""
    if isinstance(spec, FPFormat):
        return spec
    s = str(spec).strip().lower()
    if s in _REGISTRY:
        return _REGISTRY[s]
    if s.startswith("e") and "m" in s:
        e, m = s[1:].split("m")
        sat = m.endswith("s")
        m = m.rstrip("s")
        return FPFormat(int(e), int(m), saturate=sat)
    if "_" in s:  # RAPTOR-style "5_14"
        e, m = s.split("_")
        return FPFormat(int(e), int(m))
    raise ValueError(f"unknown FP format spec: {spec!r}")


def is_hardware_format(fmt: FPFormat) -> bool:
    """True when ``fmt`` matches a TPU-native storage type, in which case
    truncation can be a plain convert pair (RAPTOR's zero-overhead hardware
    path)."""
    return (fmt.exp_bits, fmt.man_bits) in {(8, 23), (8, 7), (5, 10)}
