"""Runtime numerical guardrails: fault injection, divergence-triggered
precision escalation, and checkpoint-rollback recovery.

The closed loop over PR 6's zero-recompile hot-swap machinery:

  * :mod:`~repro.guardrails.faults` — inject faults as runtime transforms
    of the ``(num_sites, 4)`` format table (plus the quantizer-level
    bit-flip channel): zero recompiles, so chaos campaigns are cheap.
  * :mod:`~repro.guardrails.monitor` — detect divergence online: non-finite
    flags, loss-spike z-scores, and a windowed filter over sampled
    trajectory probes (PR 5's machinery) that predicts budget crossings.
  * :mod:`~repro.guardrails.controller` — recover via the escalation
    ladder: widen blamed sites in the live table, roll back to the last
    durable checkpoint under the escalated policy, finally degrade to the
    FP32 baseline — every intervention recorded in a
    :class:`~repro.guardrails.log.GuardrailLog` attachable to the deployed
    :class:`~repro.artifacts.PolicyArtifact`'s provenance.

See README.md §"Numerical guardrails" for a worked Sod-shock example and
tests/test_chaos.py for the acceptance tier.
"""
# import the core package first: kernels/quantize_em/ops.py participates in
# the repro.core import cycle and must not be the chain's entry point
import repro.core  # noqa: F401

from repro.guardrails.controller import (
    EscalationLadder, GuardedLoop, GuardedTrainer, GuardrailConfig,
    GuardResult, NumericalFaultError, make_guarded_app_loop,
)
from repro.guardrails.faults import (
    FaultPlan, FaultSpec, bitflip_row, clean_row, overflow_row,
    sites_for_scope,
)
from repro.guardrails.log import GuardrailLog, Intervention
from repro.guardrails.monitor import (
    StepMonitor, TrendFilter, Verdict, probe_blame,
)

__all__ = [
    "EscalationLadder", "GuardedLoop", "GuardedTrainer", "GuardrailConfig",
    "GuardResult", "NumericalFaultError", "make_guarded_app_loop",
    "FaultPlan", "FaultSpec", "bitflip_row", "clean_row", "overflow_row",
    "sites_for_scope", "GuardrailLog", "Intervention",
    "StepMonitor", "TrendFilter", "Verdict", "probe_blame",
]
