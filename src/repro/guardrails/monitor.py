"""Online divergence detection: cheap per-step signals plus a windowed
trajectory filter.

Three detectors, in increasing cost and decreasing latency-to-alarm:

  * **non-finite flags** — the train step's in-graph ``nonfinite`` metric
    (or a host-side isfinite of the loss): one step of latency, catches
    overflow-to-inf and NaN poisoning the moment it reaches the loss.
  * **loss statistics** (:class:`StepMonitor`) — rolling-window z-score and
    a hard spike-vs-median test over the per-step loss; catches finite
    blowups a few steps after onset, well before the loss is unrecoverable.
  * **sampled trajectory filter** (:class:`TrendFilter` + :func:`probe_blame`)
    — every ``probe_every`` steps the controller runs a short
    ``profile_trajectory`` probe (PR 5's shadow machinery) on the live
    params; the per-scope blame ranking localizes *which* sites to widen,
    and the filter fits log2(peak deviation) over a window of probes —
    exactly the ``growth_slopes`` fit — to predict when the deviation will
    cross the error budget, alarms ahead of the crossing.
"""
from __future__ import annotations

import collections
import math
from typing import List, Optional, Tuple

import dataclasses
import numpy as np

from repro.profile.trajectory import fit_log2_trend


@dataclasses.dataclass(frozen=True)
class Verdict:
    """One monitor decision. ``alarm`` hands control to the escalation
    ladder; ``nonfinite`` verdicts skip the in-place rung (the params are
    already poisoned, only a rollback helps)."""

    ok: bool
    reason: str = ""
    nonfinite: bool = False
    z: float = 0.0

    @property
    def alarm(self) -> bool:
        return not self.ok


OK = Verdict(True)


class StepMonitor:
    """Cheap per-step divergence monitor over the scalar loss.

    Maintains a rolling window of recent *healthy* losses (alarmed samples
    are not admitted, so a blowup cannot drag its own baseline up) and
    alarms on, in order: a non-finite loss (or an explicit in-graph
    ``nonfinite`` flag), a hard spike above ``spike_factor`` x the rolling
    median, or a z-score excursion above ``z_threshold``. The z-score
    denominator is floored at a fraction of the mean so a flat plateau
    (std ~ 0) does not turn noise into alarms."""

    def __init__(self, window: int = 32, warmup: int = 8,
                 z_threshold: float = 6.0, spike_factor: float = 10.0):
        if warmup < 2:
            raise ValueError("warmup must be >= 2")
        self.warmup = warmup
        self.z_threshold = z_threshold
        self.spike_factor = spike_factor
        self._losses: collections.deque = collections.deque(maxlen=window)

    def update(self, step: int, loss, nonfinite: bool = False) -> Verdict:
        loss = float(loss)
        if nonfinite or not math.isfinite(loss):
            return Verdict(False, f"non-finite loss at step {step}",
                           nonfinite=True)
        if len(self._losses) >= self.warmup:
            arr = np.asarray(self._losses, np.float64)
            med = float(np.median(arr))
            mean = float(arr.mean())
            std = max(float(arr.std()), 1e-3 * abs(mean), 1e-12)
            z = (loss - mean) / std
            if loss > self.spike_factor * max(abs(med), 1e-12):
                return Verdict(
                    False, f"loss spike at step {step}: {loss:.4g} > "
                           f"{self.spike_factor:g}x median {med:.4g}", z=z)
            if z > self.z_threshold:
                return Verdict(
                    False, f"loss z-score {z:.1f} > {self.z_threshold:g} "
                           f"at step {step}", z=z)
            self._losses.append(loss)
            return Verdict(True, z=z)
        self._losses.append(loss)
        return OK

    def reset(self) -> None:
        """Forget the window — called after a checkpoint rollback so the
        replayed steps rebuild a baseline instead of diffing against the
        pre-fault trajectory."""
        self._losses.clear()


class TrendFilter:
    """Windowed filter over a sampled trajectory signal.

    Feed it ``(step, value)`` pairs — e.g. the peak relative deviation of
    each :func:`probe_blame` probe — and it fits log2(value) against the
    step index over the last ``window`` samples (the
    ``profile.trajectory.fit_log2_trend`` fit, i.e. the same statistic the
    offline blame ranking sorts by, applied online). A positive slope means
    the deviation is compounding; :meth:`predicted_crossing` extrapolates
    the fit to estimate how many steps remain until a budget is crossed."""

    def __init__(self, window: int = 8):
        self.window = window
        self._steps: collections.deque = collections.deque(maxlen=window)
        self._values: collections.deque = collections.deque(maxlen=window)

    def update(self, step: int, value: float) -> float:
        """Record a sample; returns the current slope (bits/step)."""
        self._steps.append(float(step))
        self._values.append(float(value))
        return self.slope()

    def slope(self) -> float:
        return fit_log2_trend(self._steps, self._values)[0]

    def predicted_crossing(self, budget: float) -> Optional[int]:
        """Estimated steps (from the latest sample) until the fitted signal
        crosses ``budget``: 0 when already above, ``None`` when the signal
        is not growing or is under-sampled."""
        if len(self._steps) < 2 or budget <= 0:
            return None
        slope, level = fit_log2_trend(self._steps, self._values)
        target = math.log2(budget)
        if level >= target:
            return 0
        if slope <= 0:
            return None
        return int(math.ceil((target - level) / slope))

    def reset(self) -> None:
        self._steps.clear()
        self._values.clear()


def probe_blame(fn, policy, args, threshold: float, *, n_steps: int = 4,
                signal: str = "mean") -> Tuple[List, float]:
    """One sampled trajectory probe: run ``fn(*args)`` under ``policy`` with
    PR 5's shadow-trajectory profiler and return ``(blame, peak)`` — the
    per-scope blame ranking (most unstable first) and the worst relative
    deviation seen. The controller uses the ranking to pick *which* table
    rows to widen and feeds the peak into a :class:`TrendFilter`."""
    from repro.core.api import profile_trajectory

    _, traj = profile_trajectory(fn, policy, threshold=threshold,
                                 n_steps=n_steps)(*args)
    blame = traj.blame(threshold, signal=signal)
    m = traj.rel_traj(signal)
    finite = m[np.isfinite(m)] if m.size else m
    peak = float(finite.max()) if finite.size else 0.0
    return blame, peak


__all__ = ["Verdict", "StepMonitor", "TrendFilter", "probe_blame"]
