"""The closed loop: fault-aware stepping, divergence monitoring, and the
escalation ladder, wired into the checkpoint-restart supervisor.

The escalation ladder (one rung per alarm, never descending):

  1. **widen in place** — rewrite the suspect sites' rows of the live
     ``(num_sites, 4)`` table to the identity row and keep stepping. Pure
     table-value surgery on the hot-swap executable: zero recompiles
     (asserted via the jit cache size).
  2. **widen + roll back** — same table surgery, then raise
     :class:`NumericalFaultError` so ``fault_tolerance.run_supervised``
     restores the last durable checkpoint; training resumes under the
     escalated table. Non-finite alarms land here directly — once inf/NaN
     reached the params, widening alone cannot un-poison them.
  3. **degrade to FP32** — replace the whole table with the identity table
     (the artifact's FP32 baseline: every site full precision) and roll
     back one final time.

Suspect ranking: rows that differ from the deployed baseline table rank
first (a corrupted row — e.g. an injected fault — is its own confession),
then sites under scopes blamed by the latest sampled trajectory probe,
then the narrowest remaining rows. Every action lands in the
:class:`~repro.guardrails.log.GuardrailLog`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.policy import resolve_policy
from repro.distributed.fault_tolerance import SupervisorConfig, run_supervised
from repro.guardrails.faults import FaultPlan, sites_for_scope
from repro.guardrails.log import GuardrailLog
from repro.guardrails.monitor import (
    StepMonitor, TrendFilter, Verdict, probe_blame,
)
from repro.kernels.quantize_em.ops import IDENTITY_ROW


class NumericalFaultError(RuntimeError):
    """Raised inside the guarded loop to hand control to the supervisor:
    ``run_supervised`` catches it (a ``RuntimeError`` subclass, so the
    default ``SupervisorConfig.retry_exceptions`` applies too), restores
    the latest durable checkpoint, and re-enters the loop — which now runs
    under the escalated table."""


@dataclasses.dataclass(frozen=True)
class GuardrailConfig:
    window: int = 32            # loss-monitor rolling window
    warmup: int = 8             # steps before statistical alarms arm
    z_threshold: float = 6.0
    spike_factor: float = 10.0
    save_every: int = 10        # supervisor checkpoint cadence
    max_rollbacks: int = 3
    top_k: int = 4              # sites widened per rung when blame is vague
    probe_every: int = 0        # 0 = no sampled trajectory probes
    probe_steps: int = 3        # ring-buffer rows per probe
    probe_threshold: float = 1e-3
    predict_budget: float = 0.0   # alarm when the filter predicts crossing
    predict_horizon: int = 20     # ... within this many steps


class EscalationLadder:
    """Table-level escalation policy, shared by :class:`GuardedLoop` and the
    launch entrypoint. Stateful: ``level`` only climbs (0 nominal, 1 after
    an in-place widen, 2 after a rollback, 3 once degraded to FP32)."""

    def __init__(self, baseline_table, site_index=None,
                 cfg: Optional[GuardrailConfig] = None,
                 log: Optional[GuardrailLog] = None):
        self.baseline = np.asarray(baseline_table, np.int32).copy()
        self.identity = np.tile(IDENTITY_ROW, (len(self.baseline), 1))
        self.site_index = site_index
        self.cfg = cfg or GuardrailConfig()
        self.log = log if log is not None else GuardrailLog()
        self.level = 0
        self.suspect_scopes: List[str] = []

    def _scope_of(self, i: int) -> Optional[str]:
        if self.site_index is None:
            return None
        return self.site_index.sites[i].scope

    def suspects(self, table) -> List[int]:
        """Ranked suspect rows; rows already at identity never qualify."""
        tab = np.asarray(table, np.int32)
        not_identity = [i for i in range(len(tab))
                        if not np.array_equal(tab[i], IDENTITY_ROW)]
        # 1) corruption: rows that drifted from the deployed baseline
        diff = [i for i in not_identity
                if not np.array_equal(tab[i], self.baseline[i])]
        if diff:
            return diff
        # 2) scopes blamed by the latest trajectory probe
        if self.suspect_scopes and self.site_index is not None:
            out: List[int] = []
            for scope in self.suspect_scopes:
                out.extend(i for i in sites_for_scope(self.site_index, scope)
                           if i in not_identity and i not in out)
            if out:
                return out[:self.cfg.top_k]
        # 3) the narrowest remaining rows (fewest mantissa, then exp bits)
        not_identity.sort(key=lambda i: (int(tab[i][1]), int(tab[i][0])))
        return not_identity[:self.cfg.top_k]

    def escalate(self, table, step: int,
                 verdict: Verdict) -> Tuple[np.ndarray, bool]:
        """One rung up: returns ``(new_table, rollback)``. Records the alarm
        and the escalation in the log; the caller owns raising
        :class:`NumericalFaultError` when ``rollback`` is True."""
        self.log.record(step, "alarm", reason=verdict.reason,
                        level=self.level, z=round(verdict.z, 3))
        tab = np.array(table, np.int32, copy=True)
        sus = self.suspects(tab)
        if self.level >= 2 or not sus:
            # final rung: the artifact's FP32 baseline — identity everywhere
            tab = self.identity.copy()
            self.log.record(step, "degrade_fp32", reason=verdict.reason)
            self.level = 3
            return tab, True
        rollback = bool(verdict.nonfinite or self.level >= 1)
        scopes = sorted({s for s in (self._scope_of(i) for i in sus)
                         if s is not None})
        for i in sus:
            tab[i] = IDENTITY_ROW
        self.log.record(step, "escalate_sites", sites=[int(i) for i in sus],
                        scopes=scopes, reason=verdict.reason,
                        rollback=rollback)
        self.level = 2 if rollback else 1
        return tab, rollback


@dataclasses.dataclass
class GuardResult:
    final_step: int
    final_loss: Optional[float]
    rollbacks: int
    table: np.ndarray
    log: GuardrailLog
    state: Any = None


class GuardedLoop:
    """Run ``step_fn(state, step, table) -> (state, loss, nonfinite)`` for
    ``n_steps`` under the monitor, the escalation ladder, an optional
    :class:`FaultPlan`, and the checkpoint-restart supervisor.

    ``step_fn`` must be deterministic in ``step`` (a rollback replays
    steps). ``probe_fn(state, step) -> (blame, peak)``, when given, is the
    sampled trajectory probe (see :func:`~repro.guardrails.monitor
    .probe_blame`) run every ``cfg.probe_every`` steps."""

    def __init__(self, step_fn: Callable, init_state: Any, table, *,
                 site_index=None, checkpointer=None,
                 cfg: Optional[GuardrailConfig] = None,
                 fault_plan: Optional[FaultPlan] = None,
                 monitor: Optional[StepMonitor] = None,
                 log: Optional[GuardrailLog] = None,
                 probe_fn: Optional[Callable] = None,
                 artifact=None):
        self.cfg = cfg or GuardrailConfig()
        self.log = log if log is not None else GuardrailLog()
        self.monitor = monitor or StepMonitor(
            window=self.cfg.window, warmup=self.cfg.warmup,
            z_threshold=self.cfg.z_threshold,
            spike_factor=self.cfg.spike_factor)
        self.trend = TrendFilter()
        self.ladder = EscalationLadder(table, site_index, self.cfg, self.log)
        self.table = np.asarray(table, np.int32).copy()
        self.state = init_state
        self._init_state = init_state
        self._step_fn = step_fn
        self._probe_fn = probe_fn
        self.ck = checkpointer
        self.fault_plan = fault_plan
        self.artifact = artifact
        self.rollbacks = 0
        self.last_loss: Optional[float] = None

    # ---- supervisor plumbing ----------------------------------------------
    def _save(self, step: int) -> None:
        if self.ck is None:
            return
        self.ck.save(step, self.state,
                     extra={"table": np.asarray(self.table).tolist()},
                     policy_artifact=self.artifact)

    def _restore(self) -> int:
        self.monitor.reset()
        if self.ck is None or self.ck.latest_step() is None:
            self.state = self._init_state   # no durable ckpt: from the top
            return 0
        self.ck.wait()
        self.state, manifest = self.ck.restore(self.state)
        return int(manifest["step"])

    def _probe(self, step: int) -> None:
        blame, peak = self._probe_fn(self.state, step)
        self.ladder.suspect_scopes = [
            b.scope for b in blame[:self.cfg.top_k] if b.scope]
        self.trend.update(step, peak)
        if self.cfg.predict_budget > 0:
            eta = self.trend.predicted_crossing(self.cfg.predict_budget)
            if eta is not None and eta <= self.cfg.predict_horizon:
                self._on_alarm(step, Verdict(
                    False, f"trajectory filter predicts deviation crossing "
                           f"{self.cfg.predict_budget:g} within {eta} steps"))

    def _on_alarm(self, step: int, verdict: Verdict) -> None:
        self.table, rollback = self.ladder.escalate(self.table, step, verdict)
        if rollback:
            self.rollbacks += 1
            self.log.record(step, "rollback", reason=verdict.reason,
                            rollbacks=self.rollbacks)
            raise NumericalFaultError(verdict.reason)

    # ---- the loop ----------------------------------------------------------
    def _one_step(self, step: int) -> float:
        if self.fault_plan is not None:
            table, fired = self.fault_plan.apply(self.table, step)
            for f in fired:
                self.log.record(step, "fault_injected", site=int(f.site),
                                fault=f.kind,
                                row=[int(v) for v in table[f.site]])
            self.table = table
        if (self._probe_fn is not None and self.cfg.probe_every > 0
                and step > 0 and step % self.cfg.probe_every == 0):
            self._probe(step)
        self.state, loss, nonfinite = self._step_fn(
            self.state, step, self.table)
        self.last_loss = loss
        verdict = self.monitor.update(step, loss, nonfinite=nonfinite)
        if verdict.alarm:
            self._on_alarm(step, verdict)
        return loss

    def run(self, n_steps: int) -> GuardResult:
        sup = SupervisorConfig(save_every=self.cfg.save_every,
                               max_restarts=self.cfg.max_rollbacks + 1,
                               retry_exceptions=(NumericalFaultError,))
        final, _restarts, _ = run_supervised(
            self._one_step, self._save, self._restore, n_steps, sup)
        if self.ck is not None:
            self.ck.wait()
        return GuardResult(final_step=int(final), final_loss=self.last_loss,
                           rollbacks=self.rollbacks, table=self.table,
                           log=self.log, state=self.state)


class GuardedTrainer:
    """Guardrails around the zero-recompile hot-swap train step.

    ``data_fn(step) -> batch`` must be deterministic per step (rollbacks
    replay). ``policy_or_artifact`` is a TruncationPolicy or a
    PolicyArtifact; an artifact's identity is recorded in every checkpoint
    manifest and its FP32 baseline is the ladder's final rung.

        trainer = GuardedTrainer(model, tc, artifact, params, data_fn,
                                 checkpointer=ck, cfg=GuardrailConfig(),
                                 fault_plan=plan)
        result = trainer.run(n_steps)
        audited = trainer.log.attach(artifact)   # provenance + log

    Escalation is table-only: the step stays one compiled executable, and
    every step asserts the jit cache has exactly one entry."""

    def __init__(self, model, tc, policy_or_artifact, params, data_fn, *,
                 checkpointer=None, cfg: Optional[GuardrailConfig] = None,
                 fault_plan: Optional[FaultPlan] = None, site_policy=None):
        from repro.train.trainer import make_hotswap_train_step, \
            init_opt_state

        res = resolve_policy(policy_or_artifact)
        policy, artifact = res.policy, res.artifact
        self.cfg = cfg or GuardrailConfig()
        example = data_fn(0)
        raw_step, self.sites = make_hotswap_train_step(
            model, tc, site_policy if site_policy is not None else policy,
            params, example)
        self._jit_step = jax.jit(raw_step)
        self._loss_fn = model.loss    # one bound method: probes trace-cache
        self._policy = policy
        opt = init_opt_state(model, params, tc)
        table = self.sites.table_for(policy)

        def step_fn(state, step, table):
            p, o, m = self._jit_step(
                state["params"], state["opt"], data_fn(step),
                jnp.int32(step), jnp.asarray(table, jnp.int32))
            self.assert_zero_recompile()
            loss = float(m["loss"])
            nonfinite = bool(m["nonfinite"]) if "nonfinite" in m \
                else not np.isfinite(loss)
            return {"params": p, "opt": o}, loss, nonfinite

        probe_fn = None
        if self.cfg.probe_every > 0:
            def probe_fn(state, step):
                return probe_blame(
                    self._loss_fn, self._policy,
                    (state["params"], data_fn(step)),
                    self.cfg.probe_threshold, n_steps=self.cfg.probe_steps)

        self.loop = GuardedLoop(
            step_fn, {"params": params, "opt": opt}, table,
            site_index=self.sites, checkpointer=checkpointer, cfg=self.cfg,
            fault_plan=fault_plan, probe_fn=probe_fn, artifact=artifact)

    @property
    def log(self) -> GuardrailLog:
        return self.loop.log

    @property
    def table(self) -> np.ndarray:
        return self.loop.table

    def cache_size(self) -> Optional[int]:
        fn = getattr(self._jit_step, "_cache_size", None)
        return None if fn is None else int(fn())

    def assert_zero_recompile(self) -> None:
        cs = self.cache_size()
        if cs is not None and cs > 1:
            raise AssertionError(
                f"hot-swap train step retraced ({cs} jit cache entries); "
                "site escalation must be table-only — zero recompiles")

    def run(self, n_steps: int) -> GuardResult:
        return self.loop.run(n_steps)


def make_guarded_app_loop(app, policy_or_artifact, *, checkpointer=None,
                          cfg: Optional[GuardrailConfig] = None,
                          fault_plan: Optional[FaultPlan] = None,
                          signal_fn: Optional[Callable] = None
                          ) -> Tuple[GuardedLoop, Any]:
    """Guardrails around a mini-app integration: each supervised step is one
    ``app.step`` evaluated through ``truncate_sweep``'s runtime-table path
    (one trace for the whole run). Returns ``(loop, sweep)``; run with
    ``loop.run(app.n_steps)``.

    The monitored scalar defaults to max|state| — overflow-to-inf and NaN
    poisoning surface on the very step they happen; pass ``signal_fn(state)
    -> float`` for an app-specific residual."""
    from repro.core.api import truncate_sweep

    res = resolve_policy(policy_or_artifact)
    policy, artifact = res.policy, res.artifact
    sweep = truncate_sweep(app.step, policy)
    state0 = app.init_state()
    handle0 = sweep(state0)
    table = handle0.table(policy)

    if signal_fn is None:
        def signal_fn(state):
            leaves = [jnp.max(jnp.abs(l))
                      for l in jax.tree_util.tree_leaves(state)
                      if hasattr(l, "dtype")
                      and jnp.issubdtype(l.dtype, jnp.floating)]
            return float(jnp.max(jnp.stack(leaves))) if leaves else 0.0

    def step_fn(state, step, table):
        handle = sweep(state)
        new_state = handle(jnp.asarray(table, jnp.int32))
        sig = signal_fn(new_state)
        return new_state, sig, not np.isfinite(sig)

    # SweepHandle exposes the same ``.sites`` surface the ladder needs
    loop = GuardedLoop(step_fn, state0, table, site_index=handle0,
                       checkpointer=checkpointer, cfg=cfg,
                       fault_plan=fault_plan, artifact=artifact)
    return loop, sweep


__all__ = ["NumericalFaultError", "GuardrailConfig", "EscalationLadder",
           "GuardResult", "GuardedLoop", "GuardedTrainer",
           "make_guarded_app_loop"]
