"""Numerical fault injection over the runtime format table.

A fault is a *table transform*: PR 6's hot-swap machinery already routes
every policy decision through a ``(num_sites, 4)`` int32 row table that is a
step argument of one compiled executable, so corrupting a site — swapping
its row to a catastrophically narrow rung, forcing overflow-to-inf, or
arming the quantizer's bit-flip channel — is a table *value* change with
zero recompiles. That makes fault campaigns cheap enough to run inside the
acceptance tier (tests/test_chaos.py) and realistic: the injected state is
exactly what a bad policy deployment or a corrupted registry row would
produce at runtime.

Three fault kinds:

  * ``"overflow"`` — swap the site's row to :data:`OVERFLOW_ROW` (1 exponent
    bit, IEEE overflow): any value above ~1.5 becomes inf, the classic
    range-underprovisioning failure RAPTOR profiles for.
  * ``"swap_row"`` — swap to an arbitrary narrow rung (``row=`` a format
    spec or a (4,) row), e.g. ``"e2m1"`` for catastrophic rounding.
  * ``"bitflip"`` — arm the quantizer-level fault channel
    (:func:`bitflip_row`): ``quantize_dynamic`` XORs the chosen carrier bit
    into every element the site emits. Bit 30 (the f32 top exponent bit)
    models an SDC that silently scales values by ~2^64.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.kernels.quantize_em.ops import format_row

# catastrophically narrow rung: one exponent bit leaves max_finite at 1.0,
# and non-saturating IEEE semantics send anything larger straight to inf
# (while anything below ~0.5 flushes to zero) — the classic
# range-underprovisioning failure, at a range where any real tensor trips it
OVERFLOW_ROW = np.array([1, 1, 0, 1], np.int32)

F32_SIGN_BIT = 31
F32_TOP_EXP_BIT = 30


def overflow_row() -> np.ndarray:
    """The (4,) row that forces overflow-to-inf for O(1)-scale data."""
    return OVERFLOW_ROW.copy()


def bitflip_row(base_row, bit: int) -> np.ndarray:
    """Arm the bit-flip fault channel on ``base_row``: pack ``bit`` into the
    high bits of the ieee_inf field (``field3 = ieee_inf | (bit+1) << 1``,
    decoded and stripped by ``quantize_dynamic``). The format the site
    quantizes to is unchanged — only the post-quantize XOR is armed."""
    if not 0 <= bit <= 62:
        raise ValueError(f"bit index must be in [0, 62], got {bit}")
    row = np.asarray(base_row, np.int32).copy()
    row[3] = (row[3] & 1) | ((bit + 1) << 1)
    return row


def clean_row(row) -> np.ndarray:
    """Strip any armed fault channel from a row (the inverse of
    :func:`bitflip_row`)."""
    row = np.asarray(row, np.int32).copy()
    row[3] &= 1
    return row


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault: corrupt table row ``site`` at ``step``."""

    site: int
    step: int
    kind: str = "overflow"          # "overflow" | "bitflip" | "swap_row"
    bit: int = F32_TOP_EXP_BIT      # for "bitflip"
    row: Optional[Tuple[int, ...]] = None   # for "swap_row": format spec/row

    def fault_row(self, current_row) -> np.ndarray:
        if self.kind == "overflow":
            return overflow_row()
        if self.kind == "bitflip":
            return bitflip_row(current_row, self.bit)
        if self.kind == "swap_row":
            if self.row is None:
                raise ValueError("swap_row fault needs row=")
            if isinstance(self.row, str):
                return format_row(self.row)
            return np.asarray(self.row, np.int32)
        raise ValueError(f"unknown fault kind {self.kind!r}")


class FaultPlan:
    """Scheduled corruption of a live format table.

    Each spec fires once, at the first applied step >= its trigger step,
    and the corrupted row then *persists* — modelling a deployment whose
    policy goes bad mid-run — until something (the guardrail controller)
    rewrites it. ``apply`` never mutates its input table."""

    def __init__(self, faults: Sequence[FaultSpec] = ()):
        self.faults = list(faults)
        self._fired: set = set()

    def __len__(self) -> int:
        return len(self.faults)

    def pending(self) -> List[FaultSpec]:
        return [f for i, f in enumerate(self.faults) if i not in self._fired]

    def apply(self, table, step: int) -> Tuple[np.ndarray, List[FaultSpec]]:
        """Returns ``(table', fired)`` — the (possibly new) table and the
        specs that fired at this step."""
        out = None
        fired: List[FaultSpec] = []
        for i, f in enumerate(self.faults):
            if i in self._fired or step < f.step:
                continue
            if out is None:
                out = np.array(table, np.int32, copy=True)
            if not 0 <= f.site < len(out):
                raise IndexError(
                    f"fault site {f.site} out of range for "
                    f"{len(out)}-site table")
            out[f.site] = f.fault_row(out[f.site])
            self._fired.add(i)
            fired.append(f)
        return (np.asarray(table, np.int32) if out is None else out), fired

    def reset(self) -> None:
        self._fired.clear()


def sites_for_scope(site_index, scope: str) -> List[int]:
    """Table rows of ``site_index`` whose normalized scope equals ``scope``
    or nests under it — maps a trajectory-blame scope to its rows."""
    out = []
    for s in site_index.sites:
        sc = s.scope
        if sc == scope or sc.startswith(scope + "/"):
            out.append(s.index)
    return out


__all__ = ["FaultSpec", "FaultPlan", "overflow_row", "bitflip_row",
           "clean_row", "sites_for_scope", "OVERFLOW_ROW",
           "F32_SIGN_BIT", "F32_TOP_EXP_BIT"]
