"""GuardrailLog: the audit trail of every runtime intervention.

Every action the controller takes — injected faults (during chaos runs),
monitor alarms, site escalations, checkpoint rollbacks, the final FP32
degrade — is appended as an :class:`Intervention` and survives as JSON:
attached to the :class:`~repro.artifacts.PolicyArtifact` provenance
(``artifact.with_guardrail_log(log)``) so serving and CI can audit what the
controller did under a deployed policy, and dumped to
``$RAPTOR_ARTIFACTS_DIR`` by the chaos tier so a red CI run explains
itself.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, Iterator, List, Mapping, Optional

KINDS = ("fault_injected", "alarm", "escalate_sites", "rollback",
         "degrade_fp32", "drift_detected", "research_paged")


@dataclasses.dataclass
class Intervention:
    """One logged controller action."""

    step: int
    kind: str                    # one of KINDS
    detail: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_json(self) -> dict:
        return {"step": int(self.step), "kind": self.kind,
                "detail": dict(self.detail)}

    @staticmethod
    def from_json(data: Mapping) -> "Intervention":
        return Intervention(step=int(data["step"]), kind=str(data["kind"]),
                            detail=dict(data.get("detail") or {}))


class GuardrailLog:
    """Append-only list of interventions with a lossless JSON round trip."""

    def __init__(self, interventions: Optional[List[Intervention]] = None):
        self.interventions: List[Intervention] = list(interventions or [])

    def record(self, step: int, kind: str, **detail) -> Intervention:
        if kind not in KINDS:
            raise ValueError(f"unknown intervention kind {kind!r}; "
                             f"known: {KINDS}")
        iv = Intervention(step=int(step), kind=kind, detail=detail)
        self.interventions.append(iv)
        return iv

    def __len__(self) -> int:
        return len(self.interventions)

    def __iter__(self) -> Iterator[Intervention]:
        return iter(self.interventions)

    def by_kind(self, kind: str) -> List[Intervention]:
        return [iv for iv in self.interventions if iv.kind == kind]

    def kinds(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for iv in self.interventions:
            out[iv.kind] = out.get(iv.kind, 0) + 1
        return out

    # ---- JSON round trip ---------------------------------------------------
    def to_json(self) -> list:
        return [iv.to_json() for iv in self.interventions]

    @staticmethod
    def from_json(data) -> "GuardrailLog":
        return GuardrailLog([Intervention.from_json(d) for d in data])

    def save(self, path: str) -> None:
        """Atomic single-file dump (the chaos tier's CI artifact)."""
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        tmp = f"{path}.tmp_{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)
            f.write("\n")
        os.replace(tmp, path)

    @staticmethod
    def load(path: str) -> "GuardrailLog":
        with open(path) as f:
            return GuardrailLog.from_json(json.load(f))

    # ---- artifact attachment -----------------------------------------------
    def attach(self, artifact):
        """``artifact.with_guardrail_log(self)`` — a new frozen artifact
        whose provenance carries this log."""
        return artifact.with_guardrail_log(self)

    @staticmethod
    def from_artifact(artifact) -> Optional["GuardrailLog"]:
        data = artifact.provenance.get("guardrail_log")
        return None if data is None else GuardrailLog.from_json(data)

    def summary(self) -> str:
        counts = self.kinds()
        head = ", ".join(f"{k}={counts[k]}" for k in KINDS if k in counts) \
            or "no interventions"
        lines = [f"guardrail log: {head}"]
        for iv in self.interventions:
            extras = " ".join(f"{k}={v}" for k, v in iv.detail.items())
            lines.append(f"  step {iv.step:>6d}  {iv.kind:<15s} {extras}")
        return "\n".join(lines)


__all__ = ["Intervention", "GuardrailLog", "KINDS"]
