"""Version-compatibility shims for the range of JAX versions we support.

The repo targets the container's pinned jaxlib but the public API it uses
has moved between releases (``jax.sharding.AxisType`` and the explicit-mesh
types landed after 0.4.x; ``user_frame`` changed its argument type;
``jax.enable_x64`` graduated from ``jax.experimental``). Everything that is
version-sensitive funnels through here so the rest of the codebase reads as
if it were written against one API.
"""
from __future__ import annotations

import jax


def make_mesh(axis_shapes, axis_names, *, devices=None):
    """``jax.make_mesh`` with ``axis_types=Auto`` on versions that have
    explicit sharding types, and without the kwarg on versions that don't
    (everything was implicitly Auto there)."""
    kwargs = {}
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        kwargs["axis_types"] = (axis_type.Auto,) * len(axis_names)
    if devices is not None:
        kwargs["devices"] = devices
    return jax.make_mesh(axis_shapes, axis_names, **kwargs)


def user_frame(source_info):
    """Most-user-relevant stack frame of an eqn's source_info, across the
    signature change (SourceInfo-taking vs Traceback-taking)."""
    util = jax._src.source_info_util
    try:
        return util.user_frame(source_info)
    except (AttributeError, TypeError):
        return util.user_frame(source_info.traceback)


def enable_x64():
    """Context manager enabling f64, wherever this release keeps it."""
    ctx = getattr(jax, "enable_x64", None)
    if ctx is not None:
        try:
            return ctx(True)
        except TypeError:
            pass
    from jax.experimental import enable_x64 as _ex64
    return _ex64()
