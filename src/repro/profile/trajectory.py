"""Temporal instability profiling: per-site error *trajectories*.

Plain mem-mode collapses a whole run into one scalar per location, so a
solver that diverges at step 400 is indistinguishable from one that is
uniformly sloppy from step 1. RAPTOR's real promise is *reasoning about
numerical instabilities*, and for stepped scientific workloads the signal
that makes precision selection cheap is *when and where* error grows
(cf. Nathan et al., "Profile-Driven Automated Mixed Precision"; the
runtime-reconfigurable-precision PDE study arXiv:2409.15073).

:class:`TrajectoryReport` widens the mem-mode accumulators to
``(n_steps, n_loc)`` ring buffers: one row per iteration of the program's
OUTERMOST loops (the app ``step`` scan / solver ``while``), one column per
truncated source location. On top of the raw buffers it offers

  * **divergence-onset detection** — the first step at which a site's
    deviation crosses a budget-derived threshold (:meth:`onset_steps`),
  * **error-growth slopes** — least-squares d(log2 err)/d(step)
    (:meth:`growth_slopes`),
  * a per-scope **blame ranking** (:meth:`blame`) ordering scopes most
    unstable first, and
  * :func:`ladder_hints` — the bridge into ``search.autosearch``'s
    error-guided warm start: stable scopes get aggressive initial mantissa
    guesses, unstable scopes are pinned high.

Reductions mirror ``RaptorReport``: ``merge``/``merge_all`` host-side,
``allreduce`` inside ``shard_map``/``pmap`` bodies, and the GSPMD path
(``profile_trajectory(mesh=...)``) needs no explicit reduction at all —
XLA's collectives keep the sums/maxes global. Exactness under data
parallelism: per-step max deviations, op counts and the step counter (the
signals onset detection and blame rank on) reduce bit-for-bit; the float
magnitude sums reproduce up to cross-shard summation order.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.memmode import RaptorReport
from repro.core.policy import normalize_stack


def scope_of_location(desc: str) -> str:
    """Normalized scope path of a mem-mode location description
    (``"{scope} {prim} @ {file}:{line}"``)."""
    head = desc.split(" ", 1)[0]
    if head.startswith("<"):            # "<root>", "<no truncated locations>"
        return ""
    return normalize_stack(head)


def fit_log2_trend(steps, values):
    """Least-squares fit of ``log2(values)`` against ``steps`` over finite
    positive samples: ``(slope, level)`` where ``level`` is the fitted
    log2 value at the *last* sample. The :meth:`TrajectoryReport.growth_slopes`
    fit, exposed as a module function so the online guardrail filter
    (``repro.guardrails.TrendFilter``) extrapolates exactly the signal the
    offline blame ranking sorts by. ``(0.0, -inf)`` when under-sampled."""
    steps = np.asarray(steps, np.float64)
    values = np.asarray(values, np.float64)
    ok = np.isfinite(steps) & np.isfinite(values) & (values > 0)
    if ok.sum() < 2:
        last = float(np.log2(values[ok][-1])) if ok.any() else float("-inf")
        return 0.0, last
    t, y = steps[ok], np.log2(values[ok])
    t0 = t - t.mean()
    denom = float(np.sum(t0 * t0))
    slope = float(np.sum(t0 * (y - y.mean())) / denom) if denom > 0 else 0.0
    level = float(y.mean() + slope * (t[-1] - t.mean()))
    return slope, level


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrajectoryReport:
    """Per-(step, location) deviation statistics (a pytree of arrays).

    Two temporal signals per (step, location):

      * ``max_rel[t, i]`` — the worst elementwise hybrid deviation site
        ``i`` produced during step ``t`` (see ``memmode.deviation``;
        bounded by 2, so a single tiny-magnitude cell can spike it), and
      * the **mean relative** signal :meth:`rel_traj` —
        ``abs_sum / mag_sum``, total absolute error over total shadow
        magnitude, the rel-L1 analogue of the apps' solver-level metrics.
        This is the default for onset/blame/hints: it sees *accumulated*
        error at the scale of the actual solution, not the worst
        background cell.

    Rows are a ring: step ``s`` lands in row ``s % n_steps``, so buffers
    sized to the workload's step count (``MiniApp.n_steps``) are exact and
    shorter buffers fold late steps onto early rows (``steps_seen`` tells
    how many steps actually ran). ``totals`` carries the ordinary whole-run
    :class:`RaptorReport` (location table, flags, per-site maxima).
    """

    totals: RaptorReport
    scopes: Tuple[str, ...] = dataclasses.field(
        metadata=dict(static=True))       # per-COLUMN normalized scope path
    max_rel: Any = None                   # f32[n_steps, n_cols]
    abs_sum: Any = None                   # f32[n_steps, n_cols] sum |low-shadow|
    mag_sum: Any = None                   # f32[n_steps, n_cols] sum |shadow|
    op_counts: Any = None                 # i[n_steps, n_cols]
    steps_seen: Any = None                # i32[] outermost-loop trips run
    # trajectory column -> location id. ``None`` means the identity (one
    # column per location, the default); a site-filtered profile
    # (``profile_trajectory(sites=...)``) carries columns for the selected
    # locations only — their whole-run totals still cover every site.
    columns: Any = dataclasses.field(default=None, metadata=dict(static=True))

    # ---- shape/bookkeeping ------------------------------------------------
    @property
    def locations(self) -> Tuple[str, ...]:
        return self.totals.locations

    def column_locations(self) -> Tuple[int, ...]:
        """Location id of each trajectory column."""
        if self.columns is None:
            return tuple(range(self.n_locations))
        return tuple(self.columns)

    @property
    def n_steps(self) -> int:
        """Ring-buffer rows (NOT necessarily the number of steps run)."""
        return int(np.shape(self.max_rel)[0])

    @property
    def n_locations(self) -> int:
        return len(self.totals.locations)

    @property
    def mean_abs(self):
        """Mean absolute deviation per (step, location)."""
        cnt = jnp.maximum(jnp.asarray(self.op_counts), 1)
        return jnp.asarray(self.abs_sum) / cnt.astype(jnp.float32)

    def rel_traj(self, signal: str = "mean") -> np.ndarray:
        """The ``(used_rows, n_loc)`` temporal error signal (host numpy):
        ``"mean"`` = total |error| over total |shadow| magnitude (the
        solver-level default), ``"max"`` = worst elementwise deviation."""
        rows = self.used_rows()
        if signal == "max":
            return np.asarray(jax.device_get(self.max_rel),
                              dtype=np.float64)[:rows]
        if signal != "mean":
            raise ValueError(f"unknown trajectory signal {signal!r}; "
                             "known: 'mean', 'max'")
        err = np.asarray(jax.device_get(self.abs_sum), np.float64)[:rows]
        mag = np.asarray(jax.device_get(self.mag_sum), np.float64)[:rows]
        cnt = np.asarray(jax.device_get(self.op_counts), np.float64)[:rows]
        # magnitude floor: a site whose shadow values are all ~0 measures
        # its error absolutely, mirroring memmode's hybrid deviation
        floor = 1e-6 * np.maximum(cnt, 1.0)
        return err / np.maximum(mag, floor)

    def used_rows(self) -> int:
        """Rows that can carry data: the ``steps_seen`` loop rows PLUS the
        trailing row where post-loop ops (the observable harness after the
        final step) accumulate — that's why ``MiniApp.profile_trajectory``
        sizes the buffer ``n_steps + 1``. At least 1 (straight-line
        programs land entirely in row 0), at most the buffer length."""
        seen = int(jax.device_get(self.steps_seen))
        return max(1, min(seen + 1, self.n_steps))

    # ---- reductions (same exactness contract as RaptorReport) -------------
    def allreduce(self, axis_name: str) -> "TrajectoryReport":
        """In-SPMD reduction for per-shard trajectories built inside a
        ``shard_map``/``pmap`` body: psum sums, pmax maxima. Exact under
        data parallelism for per-example programs (see RaptorReport)."""
        return TrajectoryReport(
            totals=self.totals.allreduce(axis_name),
            scopes=self.scopes,
            max_rel=lax.pmax(self.max_rel, axis_name),
            abs_sum=lax.psum(self.abs_sum, axis_name),
            mag_sum=lax.psum(self.mag_sum, axis_name),
            op_counts=lax.psum(self.op_counts, axis_name),
            steps_seen=lax.pmax(self.steps_seen, axis_name),
            columns=self.columns)

    def merge(self, other: "TrajectoryReport") -> "TrajectoryReport":
        """Host-side pairwise reduction (across processes/ranks)."""
        if np.shape(self.max_rel) != np.shape(other.max_rel):
            raise ValueError(
                "TrajectoryReport.merge: step buffers differ "
                f"({np.shape(self.max_rel)} vs {np.shape(other.max_rel)}); "
                "profile both shards with the same n_steps")
        if self.column_locations() != other.column_locations():
            raise ValueError(
                "TrajectoryReport.merge: trajectory columns differ; profile "
                "both shards with the same site selection")
        totals = self.totals.merge(other.totals)  # validates location tables
        return TrajectoryReport(
            totals=totals,
            scopes=self.scopes,
            max_rel=jnp.maximum(jnp.asarray(self.max_rel),
                                jnp.asarray(other.max_rel)),
            abs_sum=jnp.asarray(self.abs_sum) + jnp.asarray(other.abs_sum),
            mag_sum=jnp.asarray(self.mag_sum) + jnp.asarray(other.mag_sum),
            op_counts=(jnp.asarray(self.op_counts)
                       + jnp.asarray(other.op_counts)),
            steps_seen=jnp.maximum(jnp.asarray(self.steps_seen),
                                   jnp.asarray(other.steps_seen)),
            columns=self.columns)

    @staticmethod
    def merge_all(reports: Sequence["TrajectoryReport"]) -> "TrajectoryReport":
        if not reports:
            raise ValueError("merge_all needs at least one report")
        out = reports[0]
        for r in reports[1:]:
            out = out.merge(r)
        return out

    # ---- temporal analysis ------------------------------------------------
    def onset_steps(self, threshold: float,
                    signal: str = "mean") -> np.ndarray:
        """Per-location divergence onset: the first step whose deviation
        exceeds ``threshold`` (-1 = never crossed). With a wrapped ring the
        reported step is the earliest ROW, a lower bound."""
        m = self.rel_traj(signal)
        crossed = m > threshold
        first = np.argmax(crossed, axis=0)
        return np.where(crossed.any(axis=0), first, -1).astype(np.int64)

    def growth_slopes(self, signal: str = "mean") -> np.ndarray:
        """Per-location error-growth slope: least-squares fit of
        log2(deviation) against the step index over rows with finite
        positive deviation (0.0 when fewer than two such rows). Positive
        slopes mean the site's error is still growing at run end —
        instability, not an equilibrated rounding floor."""
        m = self.rel_traj(signal)
        rows = np.arange(m.shape[0], dtype=np.float64)
        out = np.zeros(m.shape[1])
        for i in range(m.shape[1]):
            out[i] = fit_log2_trend(rows, m[:, i])[0]
        return out

    def blame(self, threshold: float,
              signal: str = "mean") -> List["ScopeBlame"]:
        """Per-scope instability ranking, most unstable first: scopes whose
        sites cross ``threshold`` rank before those that never do, earlier
        onsets before later ones, larger peaks break ties. ``threshold``
        is budget-derived — typically the search threshold or a fraction of
        the app's error budget."""
        onsets = self.onset_steps(threshold, signal)
        slopes = self.growth_slopes(signal)
        traj = self.rel_traj(signal)
        peaks = traj.max(axis=0) if traj.size else np.zeros(len(self.scopes))
        flags = np.asarray(jax.device_get(self.totals.flags))
        cols = self.column_locations()
        per: Dict[str, ScopeBlame] = {}
        for c, sc in enumerate(self.scopes):
            i = cols[c]                     # the column's location id
            if self.totals.locations[i].startswith("<no truncated"):
                continue                    # the empty-table sentinel row
            b = per.get(sc)
            onset = int(onsets[c]) if onsets[c] >= 0 else None
            if b is None:
                per[sc] = ScopeBlame(scope=sc, peak_rel=float(peaks[c]),
                                     onset=onset, slope=float(slopes[c]),
                                     flags=int(flags[i]), n_sites=1)
            else:
                if onset is not None:
                    b.onset = onset if b.onset is None else min(b.onset, onset)
                b.peak_rel = max(b.peak_rel, float(peaks[c]))
                b.slope = max(b.slope, float(slopes[c]))
                b.flags += int(flags[i])
                b.n_sites += 1
        ranked = sorted(per.values(), key=lambda b: b.sort_key())
        return ranked

    def summary(self, threshold: float, k: int = 10) -> str:
        """The textual blame table — the temporal analogue of
        ``RaptorReport.summary``'s heatmap."""
        lines = [f"  {'onset':>6} {'slope':>8} {'peak_dev':>9} "
                 f"{'flags':>10}  scope"]
        for b in self.blame(threshold)[:k]:
            onset = f"{b.onset}" if b.onset is not None else "-"
            lines.append(f"  {onset:>6} {b.slope:>8.3f} {b.peak_rel:>9.2e} "
                         f"{b.flags:>10d}  {b.scope or '<root>'}")
        lines.append(f"  -- {self.n_locations} sites over "
                     f"{int(jax.device_get(self.steps_seen))} steps "
                     f"({self.n_steps}-row buffer), onset threshold "
                     f"{threshold:.1e}")
        return "\n".join(lines)


@dataclasses.dataclass
class ScopeBlame:
    """One scope's instability verdict in a blame ranking."""

    scope: str
    peak_rel: float          # worst whole-run deviation over the scope's sites
    onset: Optional[int]     # earliest step any site crossed the threshold
    slope: float             # steepest per-site log2-error growth (bits/step)
    flags: int               # total flagged elements
    n_sites: int

    def sort_key(self):
        # crossed-threshold scopes first, earliest onset first, then peak
        return (0 if self.onset is not None else 1,
                self.onset if self.onset is not None else math.inf,
                -self.peak_rel)

    @property
    def divergent(self) -> bool:
        """Crossed the threshold AND still growing — the classic
        step-400-blowup signature, as opposed to a flat rounding floor."""
        return self.onset is not None and self.slope > 0.0


def ladder_hints(traj: TrajectoryReport, widths: Sequence[int],
                 threshold: float, probe_man_bits: int, *,
                 joint_metric: Optional[float] = None,
                 margin: int = 1,
                 pin_slope: Optional[float] = None
                 ) -> Dict[str, Optional[int]]:
    """Lower a trajectory profile into per-scope warm-start hints for
    ``search.autosearch(warm_start=...)``.

    The profile must have been taken with every scope truncated to
    ``probe_man_bits`` mantissa bits (e.g. the app's uniform probe policy).
    Each extra mantissa bit halves rounding error, so a scope whose peak
    deviation at the probe width is ``peak`` is predicted to meet
    ``threshold`` at ``probe_man_bits + log2(peak / threshold)`` bits
    (plus ``margin`` bits of safety). The prediction is clamped onto the
    candidate ladder:

      * stable scopes (tiny peak) -> the narrowest candidate width — the
        aggressive guess the warm start probes first,
      * mid scopes -> the narrowest ladder width predicted admissible,
      * unstable scopes (prediction off the ladder's fine end, non-finite
        peak, or — when ``pin_slope`` is set — threshold-crossing error
        still growing faster than ``pin_slope`` bits/step) -> ``None`` —
        pinned high, i.e. predicted full precision, so the warm start
        seeds its bisection at the finest rung instead of wasting narrow
        probes.

    Site-level deviations over-estimate solver-level metrics (elementwise
    errors cancel in conserved-quantity observables, and the shadow measures
    the whole trajectory's accumulated drift, not one scope's marginal
    contribution). ``joint_metric`` corrects for this: pass the search
    metric evaluated between the profile run's truncated outputs and the
    full-precision outputs (what the joint probe-width policy actually
    scores), and every scope's peak is rescaled so the worst scope predicts
    that measured value.

    Hints are predictions, not decisions: the warm-started search probes
    every assignment it accepts (see ``autosearch``), so a wrong hint costs
    extra bisection rounds, not an unvalidated assignment.
    """
    cand = sorted({int(w) for w in widths if 0 <= int(w) < 23})
    if not cand:
        return {}
    blame = traj.blame(threshold)
    scale = 1.0
    if joint_metric is not None:
        peaks = [b.peak_rel for b in blame if np.isfinite(b.peak_rel)]
        top = max(peaks, default=0.0)
        if top > 0 and np.isfinite(joint_metric) and joint_metric > 0:
            scale = joint_metric / top
    hints: Dict[str, Optional[int]] = {}
    for b in blame:
        if not b.scope:
            continue
        if not np.isfinite(b.peak_rel):
            hints[b.scope] = None           # overflowed at the probe width
            continue
        if (pin_slope is not None and b.onset is not None
                and b.slope > pin_slope):
            hints[b.scope] = None           # diverging, pin high
            continue
        if b.peak_rel <= 0.0:
            hints[b.scope] = cand[0]        # bit-exact at the probe width
            continue
        pred = probe_man_bits + math.log2(b.peak_rel * scale / threshold)
        pred = int(math.ceil(pred)) + margin
        if pred <= cand[0]:
            hints[b.scope] = cand[0]
        elif pred > cand[-1]:
            hints[b.scope] = None           # beyond the finest candidate
        else:
            hints[b.scope] = min(w for w in cand if w >= pred)
    return hints


__all__ = [
    "TrajectoryReport", "ScopeBlame", "ladder_hints", "scope_of_location",
]
