# Instability profiling: per-site error trajectories over the program's
# step loops, divergence-onset detection, per-scope blame ranking, and the
# error-guided warm start feeding repro.search.autosearch.
from repro.profile.trajectory import (
    TrajectoryReport, ScopeBlame, fit_log2_trend, ladder_hints,
    scope_of_location,
)

__all__ = [
    "TrajectoryReport", "ScopeBlame", "fit_log2_trend", "ladder_hints",
    "scope_of_location",
]
