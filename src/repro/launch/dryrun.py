import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: for each cell
we build abstract inputs (ShapeDtypeStruct, no allocation), lower the
train/prefill/serve step under the production mesh, compile it, and record
``memory_analysis`` (fits-per-device proof), ``cost_analysis`` (FLOPs/bytes
for the roofline) and the collective-op byte census parsed from the
optimized HLO.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only]
Artifacts land in experiments/dryrun/<arch>__<shape>__<mesh>.json.
"""
import argparse
import json
import re
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import (
    ArchConfig, InputShape, SHAPES, ARCH_IDS, get_config, cells,
)
from repro.core import counters
from repro.search.scopes import discover_scopes
from repro.distributed import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.launch import specs as sp
from repro.models import Model
from repro.train.trainer import TrainConfig, make_train_step
from repro.optim.adamw import AdamWConfig

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

# m/v dtype per arch (memory fit for the 236B single-pod case)
_STATE_DTYPE = {"deepseek-v2-236b": "bfloat16"}

_COLLECTIVE_RE = re.compile(
    r"(\w[\w\.\-]*) = (\([^)]*\)|\S+) (all-reduce|all-gather|reduce-scatter|"
    r"all-to-all|collective-permute)(-start)?\(")
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|s32|u32|s8|u8|pred|"
                       r"s64|u64|s16|u16)\[([0-9,]*)\]")

_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
          "s64": 8, "u64": 8, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
          "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


_COMP_RE = re.compile(r"^(?:ENTRY )?%?([\w\.\-]+)[^\n{]*\{", re.M)
_WHILE_RE = re.compile(
    r"while\(.*?\), condition=%?([\w\.\-]+), body=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r"constant\((\d+)\)")


def _split_computations(hlo_text: str) -> Dict[str, str]:
    comps: Dict[str, str] = {}
    pos = 0
    for m in _COMP_RE.finditer(hlo_text):
        start = m.end()
        depth = 1
        i = start
        while depth and i < len(hlo_text):
            c = hlo_text[i]
            if c == "{":
                depth += 1
            elif c == "}":
                depth -= 1
            i += 1
        comps[m.group(1)] = hlo_text[start:i]
    return comps


def _direct_bytes(body: str) -> Dict[str, float]:
    by_kind: Dict[str, float] = {}
    for m in _COLLECTIVE_RE.finditer(body):
        shape_str, kind = m.group(2), m.group(3)
        nbytes = 0
        for t, dims in _SHAPE_RE.findall(shape_str):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _BYTES.get(t, 4)
        by_kind[kind] = by_kind.get(kind, 0) + nbytes
    return by_kind


def collective_census(hlo_text: str) -> Dict[str, Any]:
    """Sum result-shape bytes of every collective in the optimized HLO,
    expanding while-loop bodies by their trip counts (scan collectives
    execute `length` times; a static census would undercount a scanned layer
    stack by ~n_layers x)."""
    comps = _split_computations(hlo_text)
    entry = max(comps, key=lambda k: ("ENTRY %" + k in hlo_text
                                      or "ENTRY " + k in hlo_text,
                                      len(comps[k])))

    def trip_count(cond_name: str) -> int:
        body = comps.get(cond_name, "")
        consts = [int(x) for x in _TRIP_RE.findall(body)]
        return max(consts) if consts else 1

    def expand(name: str, seen) -> Dict[str, float]:
        if name in seen or name not in comps:
            return {}
        seen = seen | {name}
        total = dict(_direct_bytes(comps[name]))
        for m in _WHILE_RE.finditer(comps[name]):
            cond, body = m.group(1), m.group(2)
            trips = trip_count(cond)
            inner = expand(body, seen)
            for k, v in inner.items():
                total[k] = total.get(k, 0) + trips * v
        return total

    by_kind = expand(entry, frozenset())
    count = {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        count[m.group(3)] = count.get(m.group(3), 0) + 1
    return {"bytes_by_kind": by_kind, "count_by_kind": count,
            "total_bytes": sum(by_kind.values())}


def _trainable_step(model: Model, cfg: ArchConfig):
    tc = TrainConfig(
        optimizer=AdamWConfig(
            state_dtype=_STATE_DTYPE.get(cfg.name, "float32")),
        grad_accum=cfg.grad_accum)
    return make_train_step(model, tc), tc


def _serve_rules(model: Model):
    """TP-only param sharding for serving when bf16 weights fit one
    model-parallel shard group (<=12 GB/dev leaves room for the cache);
    otherwise keep FSDP (deepseek-v2-236b)."""
    bytes_per_dev = model.n_params() * 2 / 16
    if bytes_per_dev <= 12e9:
        return shd.SERVE_PARAM_RULES
    return None


def lower_cell(arch_id: str, shape: InputShape, multi_pod: bool):
    cfg = get_config(arch_id)
    model = Model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    param_rules = (None if shape.kind == "train" else _serve_rules(model))
    with shd.use_mesh(mesh, param_rules=param_rules):
        if shape.kind == "train":
            step_fn, tc = _trainable_step(model, cfg)
            params = sp.params_specs(model, mesh)
            opt = sp.opt_state_specs(
                model, mesh, _STATE_DTYPE.get(cfg.name, "float32"))
            batch = sp.input_specs(cfg, shape, mesh)
            step = jax.ShapeDtypeStruct((), jnp.int32)
            args = (params, opt, batch, step)
            lowered = jax.jit(step_fn).lower(*args)
            fn = step_fn
        elif shape.kind == "prefill":
            params = sp.params_specs(model, mesh)
            batch = sp.input_specs(cfg, shape, mesh, with_labels=False)
            args = (params, batch)
            lowered = jax.jit(model.prefill).lower(*args)
            fn = model.prefill
        else:  # decode
            params = sp.params_specs(model, mesh)
            cache = sp.cache_specs(model, shape, mesh)
            toks, emb = sp.decode_token_specs(cfg, shape, mesh)
            if emb is not None:
                fn = lambda p, c, t, e: model.decode_step(p, c, t, embeds=e)
                args = (params, cache, toks, emb)
            else:
                fn = model.decode_step
                args = (params, cache, toks)
            lowered = jax.jit(fn).lower(*args)
    return lowered, mesh, model, fn, args


def jaxpr_counts(fn, args):
    """Global FLOP/byte totals with scan trip counts folded in (XLA's
    cost_analysis counts while-loop bodies once — see DESIGN.md). Returns
    (flops, bytes_unfused, bytes_fused, scope_census): the census is the
    precision-search work-list for this cell — the ``named_scope`` frontier
    ``repro.search.autosearch`` would assign formats to, with FLOP shares."""
    closed = jax.make_jaxpr(fn)(*args)
    rep = counters.count_jaxpr(closed.jaxpr, policy=None)
    rep_f = counters.count_jaxpr(closed.jaxpr, policy=None, fused=True)
    census = [
        {"scope": s.path, "flops": s.flops, "n_eqns": s.n_eqns,
         "fraction": round(s.fraction, 4)}
        for s in discover_scopes(closed, min_fraction=0.02, max_scopes=16)]
    return (rep.total_flops, sum(rep.bytes_by_fmt.values()),
            sum(rep_f.bytes_by_fmt.values()), census)


def model_flops(model: Model, shape: InputShape) -> float:
    """Paper-style MODEL_FLOPS: 6·N_active·D for training, 2·N_active·D for
    a prefill forward, 2·N_active·B per decoded token."""
    n = model.n_active_params()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             out_dir: str = OUT_DIR) -> Dict[str, Any]:
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    tag = f"{arch_id}__{shape_name}__{mesh_name}"
    rec: Dict[str, Any] = {"arch": arch_id, "shape": shape_name,
                           "mesh": mesh_name, "ok": False}
    t0 = time.time()
    try:
        lowered, mesh, model, fn, args = lower_cell(arch_id, shape, multi_pod)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        jflops, jbytes, jbytes_fused, scope_census = jaxpr_counts(fn, args)

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):  # pre-0.5 jax: list of one dict
            cost = cost[0] if cost else {}
        census = collective_census(compiled.as_text())
        rec.update(
            ok=True,
            lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            n_devices=mesh.size,
            n_params=model.n_params(),
            n_active_params=model.n_active_params(),
            jaxpr_flops=jflops,
            jaxpr_bytes=jbytes,
            jaxpr_bytes_fused=jbytes_fused,
            precision_search_scopes=scope_census,
            model_flops=model_flops(model, shape),
            memory={
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "code_bytes": mem.generated_code_size_in_bytes,
            },
            flops=cost.get("flops", 0.0),
            bytes_accessed=cost.get("bytes accessed", 0.0),
            collectives=census,
        )
    except Exception as e:  # noqa: BLE001 — a failed cell is a bug; record it
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = round(time.time() - t0, 2)
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, f"{tag}.json"), "w") as f:
        json.dump(rec, f, indent=1)
    status = "OK " if rec["ok"] else "FAIL"
    print(f"[{status}] {tag}  ({rec['total_s']}s)"
          + ("" if rec["ok"] else f"  {rec['error']}"), flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args()

    jobs = []
    archs = [args.arch] if args.arch else list(ARCH_IDS)
    for arch in archs:
        for shape, runnable in cells(arch):
            if args.shape and shape.name != args.shape:
                continue
            if not runnable:
                print(f"[SKIP] {arch}__{shape.name} — full-attention arch, "
                      f"long-context cell skipped per DESIGN.md §5", flush=True)
                continue
            meshes = []
            if not args.multi_pod_only:
                meshes.append(False)
            if not args.single_pod_only:
                meshes.append(True)
            if args.multi_pod:
                meshes = [True]
            for mp in meshes:
                jobs.append((arch, shape.name, mp))

    results = [run_cell(a, s, m, args.out) for a, s, m in jobs]
    ok = sum(r["ok"] for r in results)
    print(f"\n{ok}/{len(results)} cells compiled", flush=True)
    if ok < len(results):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
