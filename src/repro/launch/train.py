"""Production training entrypoint.

On a real fleet each host runs:
    python -m repro.launch.train --arch glm4-9b --steps 100000 \
        --ckpt gs://bucket/run1 [--coordinator host:port --num-hosts N]
and the same command on this CPU container runs the identical code path on
the host mesh with a smoke-scaled config (--smoke, default here).

Covers the large-scale-runnability contract end-to-end: distributed init,
production mesh, FSDP×TP param placement, deterministic host-sharded data,
grad accumulation, checkpoint/restart supervision with straggler
monitoring, and an optional RAPTOR truncation policy as a first-class
config.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp

from repro.artifacts import ArtifactRef, Registry, default_root
from repro.guardrails import (
    EscalationLadder, FaultPlan, FaultSpec, GuardrailLog,
    NumericalFaultError, StepMonitor,
)
from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import get_config
from repro.core import TruncationPolicy
# parse_policy/resolve_policy live in repro.core.policy (one grammar for
# every entrypoint); parse_policy re-exported for backward compatibility
from repro.core.policy import parse_policy, resolve_policy  # noqa: F401
from repro.data.pipeline import DataConfig, Pipeline, Prefetcher
from repro.distributed import sharding as shd
from repro.distributed.fault_tolerance import (
    StragglerMonitor, SupervisorConfig, run_supervised,
)
from repro.launch.mesh import make_production_mesh, make_host_mesh
from repro.models import Model
from repro.models.common import ParamDef
from repro.optim.adamw import AdamWConfig, warmup_cosine
from repro.train.trainer import (
    TrainConfig, make_hotswap_train_step, make_train_step, init_opt_state,
)


def _parse_fault(spec: str) -> FaultSpec:
    """``--inject-fault SITE:STEP[:KIND]`` (KIND: overflow | bitflip)."""
    parts = spec.split(":")
    if len(parts) not in (2, 3):
        raise SystemExit(
            f"bad --inject-fault {spec!r}; want SITE:STEP[:KIND]")
    kind = parts[2] if len(parts) == 3 else "overflow"
    return FaultSpec(site=int(parts[0]), step=int(parts[1]), kind=kind)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=1000)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--global-batch", type=int, default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=100)
    ap.add_argument("--policy", default=None,
                    help='RAPTOR spec: "32_to_5_14" or "scope:**/mlp=e5m7"')
    ap.add_argument("--policy-artifact", default=None,
                    help='registry ref ("name" or "name@v3"): train under '
                         "the artifact's searched policy via runtime format "
                         "tables (hot-swappable, zero recompile)")
    ap.add_argument("--swap-artifact", action="append", default=[],
                    metavar="STEP:REF",
                    help="hot-swap to registry artifact REF at STEP "
                         "(repeatable; requires --policy-artifact)")
    ap.add_argument("--guardrails", action="store_true",
                    help="runtime numerical guardrails: per-step divergence "
                         "monitor + precision-escalation ladder + "
                         "checkpoint rollback (requires --policy-artifact)")
    ap.add_argument("--inject-fault", action="append", default=[],
                    metavar="SITE:STEP[:KIND]",
                    help="chaos demo: corrupt table row SITE at STEP "
                         "(KIND: overflow | bitflip; repeatable; requires "
                         "--guardrails)")
    ap.add_argument("--registry", default=None,
                    help=f"artifact registry root (default $RAPTOR_REGISTRY "
                         f"or {default_root()!r})")
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="reduced config on the host mesh (CPU container)")
    ap.add_argument("--production", dest="smoke", action="store_false")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--coordinator", default=None)
    ap.add_argument("--num-hosts", type=int, default=1)
    ap.add_argument("--host-id", type=int, default=0)
    args = ap.parse_args()

    if args.coordinator:
        jax.distributed.initialize(args.coordinator, args.num_hosts,
                                   args.host_id)

    variant = "smoke" if args.smoke else "full"
    cfg = get_config(args.arch, variant)
    model = Model(cfg)
    mesh = (make_host_mesh(model_parallel=2) if args.smoke
            else make_production_mesh(multi_pod=args.multi_pod))
    seq = args.seq or (128 if args.smoke else 4096)
    gbatch = args.global_batch or (8 if args.smoke else 256)
    print(f"arch={cfg.name} params={model.n_params()/1e6:.1f}M mesh={dict(mesh.shape)} "
          f"seq={seq} batch={gbatch}", flush=True)

    # ---- precision-policy resolution --------------------------------------
    # --policy bakes a flag policy into the trace; --policy-artifact loads a
    # registry artifact and routes through runtime format tables instead, so
    # --swap-artifact can deploy a different artifact mid-run with zero
    # recompiles (the table is a step argument, not trace state). Both flags
    # funnel through the shared repro.core.policy.resolve_policy grammar.
    if args.swap_artifact and not args.policy_artifact:
        raise SystemExit("--swap-artifact requires --policy-artifact "
                         "(the runtime-table training path)")
    if args.guardrails and not args.policy_artifact:
        raise SystemExit("--guardrails requires --policy-artifact (the "
                         "escalation ladder rewrites the runtime table)")
    if args.inject_fault and not args.guardrails:
        raise SystemExit("--inject-fault requires --guardrails")
    registry = Registry(args.registry) if args.policy_artifact else None
    try:
        res = resolve_policy(args.policy, args.policy_artifact,
                             registry=registry)
    except ValueError as e:
        raise SystemExit(str(e))
    artifact, artifact_ref = res.artifact, res.ref
    swap_schedule = {}
    if artifact_ref is not None:
        print(f"policy artifact: {artifact_ref.ref} "
              f"(digest {artifact_ref.digest[:12]})", flush=True)
        for spec in args.swap_artifact:
            at, _, ref = spec.partition(":")
            swap_schedule[int(at)] = registry.load_ref(ref)

    tc = TrainConfig(
        optimizer=AdamWConfig(lr=args.lr),
        grad_accum=1 if args.smoke else cfg.grad_accum,
        # artifact policies deploy via runtime tables below, not the trace
        policy=res.policy if artifact is None else None,
        lr_schedule=lambda s: warmup_cosine(
            s, peak_lr=args.lr, warmup=min(2000, args.steps // 10 + 1),
            total=args.steps))
    data = Pipeline(DataConfig(
        seq_len=seq, global_batch=gbatch, vocab=cfg.vocab,
        d_model=cfg.d_model,
        input_mode=("encdec" if cfg.family == "encdec" else cfg.input_mode),
        mrope=cfg.rope_type == "mrope"))
    ck = Checkpointer(args.ckpt, keep_k=3)

    with shd.use_mesh(mesh):
        defs = model.param_defs()
        sh = jax.tree_util.tree_map(
            lambda pd: shd.param_sharding(pd.shape, pd.axes, mesh),
            defs, is_leaf=lambda x: isinstance(x, ParamDef))
        params = jax.tree_util.tree_map(
            jax.device_put, model.init(jax.random.PRNGKey(0)), sh)
        opt = init_opt_state(model, params, tc)
        state = {"params": params, "opt": opt}
        pf = Prefetcher(data)
        peeked = []   # first prefetched batch, reused as the trace example

        if artifact is not None:
            peeked.append({k: jnp.asarray(v) for k, v in pf.next().items()})
            # sites = the union of every artifact this run may deploy, so a
            # swap is always a subset of the enumerated table rows
            site_rules = tuple(artifact.policy.rules) + tuple(
                r for art, _ in swap_schedule.values()
                for r in art.policy.rules)
            hot_step, sites = make_hotswap_train_step(
                model, tc, TruncationPolicy(rules=site_rules),
                state["params"], peeked[0])
            step_fn = jax.jit(hot_step)
            active = {"ref": artifact_ref,
                      "table": sites.table_for(artifact.policy)}
        else:
            step_fn = jax.jit(make_train_step(model, tc))
            sites = active = None

        # ---- runtime numerical guardrails ---------------------------------
        # Monitor every step's loss/finiteness; on alarm, escalate blamed
        # sites in the live table (zero recompiles) and roll back through the
        # existing run_supervised machinery (NumericalFaultError is a
        # RuntimeError, the supervisor's default retry class).
        guard = None
        if args.guardrails:
            glog = GuardrailLog()
            guard = {
                "monitor": StepMonitor(),
                "ladder": EscalationLadder(active["table"], site_index=sites,
                                           log=glog),
                "plan": FaultPlan([_parse_fault(s)
                                   for s in args.inject_fault]),
                "log": glog,
                "escalated": None,
            }

        def restore_fn() -> int:
            latest = ck.latest_step()
            if guard is not None:
                guard["monitor"].reset()
            if latest is None:
                return 0
            (state["params"], state["opt"]), manifest = ck.restore(
                (state["params"], state["opt"]))
            data.load_state_dict(manifest["extra"]["data"])
            rec = manifest.get("policy_artifact")
            if rec and active is not None:
                # resume under the exact policy the checkpoint trained on:
                # reload by recorded name and verify the content digest
                art = registry.load(f"{rec['name']}@v{rec['version']}")
                if art.digest != rec["digest"]:
                    raise RuntimeError(
                        f"registry artifact {rec['name']}@v{rec['version']} "
                        f"digest {art.digest[:12]} != checkpoint-recorded "
                        f"{rec['digest'][:12]}; refusing to resume under a "
                        "different policy than the one trained on")
                active["ref"] = ArtifactRef.from_json(rec)
                active["table"] = sites.table_for(art.policy)
                print(f"[supervisor] resumed policy {active['ref'].ref}",
                      flush=True)
            if guard is not None and guard["escalated"] is not None:
                # the ladder's widened rows survive the rollback — resuming
                # under the pre-escalation table would just diverge again
                active["table"] = guard["escalated"]
            print(f"[supervisor] restored step {latest}", flush=True)
            return latest

        def save_fn(step: int):
            ck.save(step, (state["params"], state["opt"]),
                    extra={"data": data.state_dict()},
                    policy_artifact=active["ref"] if active else None)

        t0 = time.time()

        def step_fn_supervised(step: int):
            if active is not None and step in swap_schedule:
                art, ref = swap_schedule[step]
                active["ref"] = ref
                active["table"] = sites.table_for(art.policy)
                print(f"[policy] step {step}: hot-swapped to {ref.ref} "
                      "(runtime table, zero recompile)", flush=True)
            if guard is not None:
                table, fired = guard["plan"].apply(active["table"], step)
                for f in fired:
                    guard["log"].record(
                        step, "fault_injected", site=f.site, fault=f.kind,
                        row=[int(x) for x in table[f.site]])
                    print(f"[guardrail] step {step}: injected {f.kind} "
                          f"fault at site {f.site}", flush=True)
                active["table"] = table
            batch = (peeked.pop() if peeked
                     else {k: jnp.asarray(v) for k, v in pf.next().items()})
            extra = (active["table"],) if active is not None else ()
            state["params"], state["opt"], m = step_fn(
                state["params"], state["opt"], batch, jnp.int32(step), *extra)
            loss = float(m["loss"])
            if guard is not None:
                v = guard["monitor"].update(
                    step, loss, nonfinite=bool(m.get("nonfinite", False)))
                if v.alarm:
                    print(f"[guardrail] step {step}: ALARM — {v.reason}",
                          flush=True)
                    table, rollback = guard["ladder"].escalate(
                        active["table"], step, v)
                    active["table"] = guard["escalated"] = table
                    if rollback:
                        guard["log"].record(step, "rollback", reason=v.reason)
                        raise NumericalFaultError(v.reason)
            if step % 10 == 0:
                print(f"step {step:6d} loss {loss:.4f} "
                      f"gnorm {float(m['grad_norm']):.3f} "
                      f"({(time.time()-t0):.0f}s)", flush=True)
            return loss

        try:
            final, restarts, straggles = run_supervised(
                step_fn_supervised, save_fn, restore_fn, args.steps,
                SupervisorConfig(save_every=args.save_every),
                monitor=StragglerMonitor())
            ck.wait()
            print(f"done: step={final} restarts={restarts} "
                  f"straggles={straggles}", flush=True)
            if guard is not None:
                glog = guard["log"]
                log_path = os.path.join(args.ckpt, "guardrail_log.json")
                glog.save(log_path)
                print(glog.summary(), flush=True)
                print(f"[guardrail] log saved to {log_path}", flush=True)
                if artifact is not None and len(glog):
                    # the audited artifact: the deployed policy plus what the
                    # controller did while it ran
                    audited = glog.attach(artifact)
                    art_path = os.path.join(args.ckpt,
                                            "guardrail_artifact.json")
                    with open(art_path, "w") as f:
                        f.write(audited.dumps() + "\n")
                    print(f"[guardrail] audited artifact saved to "
                          f"{art_path}", flush=True)
        finally:
            pf.close()


if __name__ == "__main__":
    main()
