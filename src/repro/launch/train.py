"""Production training entrypoint.

On a real fleet each host runs:
    python -m repro.launch.train --arch glm4-9b --steps 100000 \
        --ckpt gs://bucket/run1 [--coordinator host:port --num-hosts N]
and the same command on this CPU container runs the identical code path on
the host mesh with a smoke-scaled config (--smoke, default here).

Covers the large-scale-runnability contract end-to-end: distributed init,
production mesh, FSDP×TP param placement, deterministic host-sharded data,
grad accumulation, checkpoint/restart supervision with straggler
monitoring, and an optional RAPTOR truncation policy as a first-class
config.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import get_config
from repro.core import TruncationPolicy
from repro.data.pipeline import DataConfig, Pipeline, Prefetcher
from repro.distributed import sharding as shd
from repro.distributed.fault_tolerance import (
    StragglerMonitor, SupervisorConfig, run_supervised,
)
from repro.launch.mesh import make_production_mesh, make_host_mesh
from repro.models import Model
from repro.models.common import ParamDef
from repro.optim.adamw import AdamWConfig, warmup_cosine
from repro.train.trainer import TrainConfig, make_train_step, init_opt_state


def parse_policy(spec):
    if not spec:
        return None
    if spec.startswith("scope:"):
        scope, fmt = spec[len("scope:"):].split("=")
        return TruncationPolicy.scoped(scope, fmt)
    return TruncationPolicy.from_flag(spec)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=1000)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--global-batch", type=int, default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=100)
    ap.add_argument("--policy", default=None,
                    help='RAPTOR spec: "32_to_5_14" or "scope:**/mlp=e5m7"')
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="reduced config on the host mesh (CPU container)")
    ap.add_argument("--production", dest="smoke", action="store_false")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--coordinator", default=None)
    ap.add_argument("--num-hosts", type=int, default=1)
    ap.add_argument("--host-id", type=int, default=0)
    args = ap.parse_args()

    if args.coordinator:
        jax.distributed.initialize(args.coordinator, args.num_hosts,
                                   args.host_id)

    variant = "smoke" if args.smoke else "full"
    cfg = get_config(args.arch, variant)
    model = Model(cfg)
    mesh = (make_host_mesh(model_parallel=2) if args.smoke
            else make_production_mesh(multi_pod=args.multi_pod))
    seq = args.seq or (128 if args.smoke else 4096)
    gbatch = args.global_batch or (8 if args.smoke else 256)
    print(f"arch={cfg.name} params={model.n_params()/1e6:.1f}M mesh={dict(mesh.shape)} "
          f"seq={seq} batch={gbatch}", flush=True)

    tc = TrainConfig(
        optimizer=AdamWConfig(lr=args.lr),
        grad_accum=1 if args.smoke else cfg.grad_accum,
        policy=parse_policy(args.policy),
        lr_schedule=lambda s: warmup_cosine(
            s, peak_lr=args.lr, warmup=min(2000, args.steps // 10 + 1),
            total=args.steps))
    data = Pipeline(DataConfig(
        seq_len=seq, global_batch=gbatch, vocab=cfg.vocab,
        d_model=cfg.d_model,
        input_mode=("encdec" if cfg.family == "encdec" else cfg.input_mode),
        mrope=cfg.rope_type == "mrope"))
    ck = Checkpointer(args.ckpt, keep_k=3)

    with shd.use_mesh(mesh):
        defs = model.param_defs()
        sh = jax.tree_util.tree_map(
            lambda pd: shd.param_sharding(pd.shape, pd.axes, mesh),
            defs, is_leaf=lambda x: isinstance(x, ParamDef))
        params = jax.tree_util.tree_map(
            jax.device_put, model.init(jax.random.PRNGKey(0)), sh)
        opt = init_opt_state(model, params, tc)
        step_fn = jax.jit(make_train_step(model, tc))

        state = {"params": params, "opt": opt}
        pf = Prefetcher(data)

        def restore_fn() -> int:
            latest = ck.latest_step()
            if latest is None:
                return 0
            (state["params"], state["opt"]), manifest = ck.restore(
                (state["params"], state["opt"]))
            data.load_state_dict(manifest["extra"]["data"])
            print(f"[supervisor] restored step {latest}", flush=True)
            return latest

        def save_fn(step: int):
            ck.save(step, (state["params"], state["opt"]),
                    extra={"data": data.state_dict()})

        t0 = time.time()

        def step_fn_supervised(step: int):
            batch = {k: jnp.asarray(v) for k, v in pf.next().items()}
            state["params"], state["opt"], m = step_fn(
                state["params"], state["opt"], batch, jnp.int32(step))
            if step % 10 == 0:
                print(f"step {step:6d} loss {float(m['loss']):.4f} "
                      f"gnorm {float(m['grad_norm']):.3f} "
                      f"({(time.time()-t0):.0f}s)", flush=True)
            return float(m["loss"])

        try:
            final, restarts, straggles = run_supervised(
                step_fn_supervised, save_fn, restore_fn, args.steps,
                SupervisorConfig(save_every=args.save_every),
                monitor=StragglerMonitor())
            ck.wait()
            print(f"done: step={final} restarts={restarts} "
                  f"straggles={straggles}", flush=True)
        finally:
            pf.close()


if __name__ == "__main__":
    main()
