"""Roofline analysis from dry-run artifacts (deliverable g).

Three terms per (arch x shape) cell on the single-pod mesh (256 x v5e):

  compute    T_c = FLOPs_global / (chips * 197e12 bf16 FLOP/s)
  memory     T_m = bytes_global / (chips * 819e9 B/s HBM)
  collective T_x = collective_bytes_per_device / 50e9 B/s ICI link

FLOPs/bytes come from the jaxpr walk (scan trip counts folded in — XLA's
cost_analysis counts while bodies once, see dryrun.py); collective bytes
come from the result-shape census over the SPMD-partitioned HLO (shapes in
the partitioned module are already per-device shards). The byte term is an
un-fused upper bound on HBM traffic (every op's operands+results counted),
so T_m is pessimistic; T_c is exact for the jaxpr; the dominant-term calls
below are robust to that bias (noted per-cell).

    PYTHONPATH=src python -m repro.launch.roofline [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(dirname: str, mesh: str = "pod16x16") -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dirname, f"*__{mesh}.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def analyze(rec: Dict) -> Dict:
    chips = rec["n_devices"]
    t_c = rec["jaxpr_flops"] / (chips * PEAK_FLOPS)
    # fused-traffic model when available (un-fused census is a ~2-6x
    # overcount; EXPERIMENTS.md §Perf iteration 1)
    nbytes = rec.get("jaxpr_bytes_fused", rec["jaxpr_bytes"])
    t_m = nbytes / (chips * HBM_BW)
    t_x = rec["collectives"]["total_bytes"] / LINK_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dom = max(terms, key=terms.get)
    useful = rec["model_flops"] / max(rec["jaxpr_flops"], 1.0)
    # roofline fraction: useful model flops vs what the dominant term allows
    t_ideal = rec["model_flops"] / (chips * PEAK_FLOPS)
    frac = t_ideal / max(terms[dom], 1e-30)
    return {
        "arch": rec["arch"], "shape": rec["shape"],
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
        "dominant": dom, "useful_ratio": useful,
        "roofline_frac": frac,
        "hbm_gb_per_dev": (rec["memory"]["argument_bytes"]
                           + rec["memory"]["temp_bytes"]) / 1e9,
    }


_ADVICE = {
    ("compute",): "raise useful-FLOP ratio (less remat recompute, tighter "
                  "capacity factor, fp8 matmul inputs)",
    ("memory",): "cut bytes: fuse elementwise chains, larger microbatch, "
                 "bf16 collectives/state, ring SWA cache",
    ("collective",): "reshard: keep FSDP gathers off the critical path, "
                     "bf16 gradient all-reduce, 2D all-gather",
}


def advice(dom: str) -> str:
    return _ADVICE[(dom,)]


def table(rows: List[Dict]) -> str:
    rows = sorted(rows, key=lambda r: (r["arch"],
                                       SHAPE_ORDER.index(r["shape"])))
    out = ["| arch | shape | T_compute (s) | T_memory (s) | T_collective (s) "
           "| dominant | 6ND/HLO | roofline frac | HBM GB/dev |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3f} | "
            f"{r['t_memory_s']:.3f} | {r['t_collective_s']:.3f} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['roofline_frac']:.2f} | {r['hbm_gb_per_dev']:.1f} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun"))
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    recs = [r for r in load(args.dir, args.mesh) if r.get("ok")]
    rows = [analyze(r) for r in recs]
    md = table(rows)
    print(md)
    doms = {}
    for r in rows:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    print(f"\ndominant-term census: {doms}")
    if args.out:
        with open(args.out, "w") as f:
            f.write(md + "\n")


if __name__ == "__main__":
    main()
