"""ShapeDtypeStruct input factories for dry-runs (no device allocation).

``input_specs`` produces weak-type-correct, shardable stand-ins for every
model input of a given (arch x shape) cell; ``params_specs``/``cache_specs``
do the same for weights, optimizer state and decode caches, with
NamedShardings resolved through the logical-axis rules (FSDP x TP x EP; the
divisibility guard downgrades kv-head sharding to context-parallel cache
sharding automatically — DESIGN.md §6).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, InputShape
from repro.distributed import sharding as shd
from repro.models import Model
from repro.models.common import ParamDef


def _sds(shape, dtype, mesh: Optional[Mesh], logical):
    if mesh is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    spec = shd._resolve(mesh, shd._ctx().act_rules, logical, shape)
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def input_specs(cfg: ArchConfig, shape: InputShape, mesh: Optional[Mesh],
                with_labels: bool = True) -> Dict[str, Any]:
    """Batch stand-ins for a train/prefill cell."""
    B, S = shape.global_batch, shape.seq_len
    batch: Dict[str, Any] = {}
    if cfg.input_mode == "embeds":
        batch["embeds"] = _sds((B, S, cfg.d_model), jnp.bfloat16, mesh,
                               ("batch", "seq", "embed"))
        if cfg.rope_type == "mrope":
            batch["positions"] = _sds((3, B, S), jnp.int32, mesh,
                                      (None, "batch", "seq"))
    else:
        batch["tokens"] = _sds((B, S), jnp.int32, mesh, ("batch", "seq"))
    if cfg.family == "encdec":
        batch["src_embeds"] = _sds((B, S, cfg.d_model), jnp.bfloat16, mesh,
                                   ("batch", "seq", "embed"))
        batch["tokens"] = _sds((B, S), jnp.int32, mesh, ("batch", "seq"))
    if with_labels:
        batch["labels"] = _sds((B, S), jnp.int32, mesh, ("batch", "seq"))
    return batch


def decode_token_specs(cfg: ArchConfig, shape: InputShape, mesh):
    B = shape.global_batch
    toks = _sds((B,), jnp.int32, mesh, ("batch",))
    if cfg.input_mode == "embeds":
        emb = _sds((B, 1, cfg.d_model), jnp.bfloat16, mesh,
                   ("batch", None, "embed"))
        return toks, emb
    return toks, None


def params_specs(model: Model, mesh: Optional[Mesh]):
    """Abstract params with FSDP x TP shardings."""
    defs = model.param_defs()

    def one(pd: ParamDef):
        dt = jnp.dtype(model.cfg.dtype)
        if mesh is None:
            return jax.ShapeDtypeStruct(pd.shape, dt)
        return jax.ShapeDtypeStruct(
            pd.shape, dt, sharding=shd.param_sharding(pd.shape, pd.axes, mesh))

    return jax.tree_util.tree_map(
        one, defs, is_leaf=lambda x: isinstance(x, ParamDef))


def _zero1_spec(pd: ParamDef, mesh):
    """TP sharding + 'data' on the first remaining divisible dim: optimizer
    state fully sharded even when params are replicated over data (ZeRO-1)."""
    base = shd._resolve(mesh, shd.SERVE_PARAM_RULES, pd.axes, pd.shape)
    spec = list(base) + [None] * (len(pd.shape) - len(base))
    dsize = mesh.shape.get("data", 1)
    for i, (dim, cur) in enumerate(zip(pd.shape, spec)):
        if cur is None and dsize > 1 and dim % dsize == 0:
            spec[i] = "data"
            break
    from jax.sharding import PartitionSpec as P2
    return NamedSharding(mesh, P2(*spec))


def opt_state_specs(model: Model, mesh, state_dtype: str = "float32",
                    zero1: bool = False):
    """AdamW state stand-ins with param-aligned shardings (FSDP mode) or
    fully data-sharded state over TP-only params (ZeRO-1 mode)."""
    defs = model.param_defs()
    sd = jnp.dtype(state_dtype)
    half = jnp.dtype(model.cfg.dtype) in (jnp.bfloat16, jnp.float16)

    def mk(pd: ParamDef, dt):
        if mesh is None:
            return jax.ShapeDtypeStruct(pd.shape, dt)
        if zero1:
            return jax.ShapeDtypeStruct(pd.shape, dt,
                                        sharding=_zero1_spec(pd, mesh))
        return jax.ShapeDtypeStruct(
            pd.shape, dt, sharding=shd.param_sharding(pd.shape, pd.axes, mesh))

    leaf = lambda x: isinstance(x, ParamDef)
    scalar = (jax.ShapeDtypeStruct((), jnp.int32,
                                   sharding=NamedSharding(mesh, P()))
              if mesh is not None else jax.ShapeDtypeStruct((), jnp.int32))
    return {
        "step": scalar,
        "m": jax.tree_util.tree_map(lambda pd: mk(pd, sd), defs, is_leaf=leaf),
        "v": jax.tree_util.tree_map(lambda pd: mk(pd, sd), defs, is_leaf=leaf),
        "master": jax.tree_util.tree_map(
            lambda pd: mk(pd, jnp.float32) if half else None, defs,
            is_leaf=leaf),
    }


_CACHE_AXES_BY_KEY = {
    "k": ("batch", "kv_heads", "cache_seq", None),
    "v": ("batch", "kv_heads", "cache_seq", None),
    "c_kv": ("batch", "cache_seq", None),
    "k_rope": ("batch", "cache_seq", None),
    "conv": ("batch", None, "mlp"),
    "ssm": ("batch", "mlp", "state"),
    "tm_state": ("batch", "heads", None, None),
    "tm_shift": ("batch", None, "embed"),
    "cm_shift": ("batch", None, "embed"),
    "cross_k": ("batch", "kv_heads", "cache_seq", None),
    "cross_v": ("batch", "kv_heads", "cache_seq", None),
}


def cache_specs(model: Model, shape: InputShape, mesh: Optional[Mesh]):
    """Abstract decode cache with context-parallel-aware shardings."""
    cfg = model.cfg
    B, S = shape.global_batch, shape.seq_len
    tmpl = jax.eval_shape(lambda: model.init_cache(B, S))

    flat, treedef = jax.tree_util.tree_flatten_with_path(tmpl)
    out = []
    for path, sds in flat:
        key = None
        for p in reversed(path):
            name = getattr(p, "key", getattr(p, "name", None))
            if isinstance(name, str) and name in _CACHE_AXES_BY_KEY:
                key = name
                break
        if mesh is None or key is None:
            out.append(jax.ShapeDtypeStruct(sds.shape, sds.dtype))
            continue
        axes = _CACHE_AXES_BY_KEY[key]
        # stacked layer caches carry a leading (L,) dim
        if len(sds.shape) == len(axes) + 1:
            axes = ("layers",) + axes
        spec = shd._resolve(mesh, {**shd._ctx().act_rules, "layers": None},
                            axes, sds.shape)
        out.append(jax.ShapeDtypeStruct(
            sds.shape, sds.dtype, sharding=NamedSharding(mesh, spec)))
    return jax.tree_util.tree_unflatten(treedef, out)
