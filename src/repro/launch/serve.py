"""Production serving entrypoint: batched prefill + decode with optional
RAPTOR truncation policy (mixed-precision deployment study).

    PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b \
        [--policy "scope:**/mlp=fp16"] [--requests 8] [--new-tokens 16]
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax

from repro.configs.base import get_config
from repro.core import truncate
from repro.distributed import sharding as shd
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.train import parse_policy
from repro.models import Model
from repro.models.common import ParamDef
from repro.serving.engine import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--policy", default=None)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--production", dest="smoke", action="store_false")
    args = ap.parse_args()

    cfg = get_config(args.arch, "smoke" if args.smoke else "full")
    model = Model(cfg)
    # serving uses TP-only params when they fit (DESIGN.md §6 / §Perf it.6)
    mesh = (make_host_mesh(model_parallel=2) if args.smoke
            else make_production_mesh())
    with shd.use_mesh(mesh, param_rules=shd.SERVE_PARAM_RULES):
        defs = model.param_defs()
        sh = jax.tree_util.tree_map(
            lambda pd: shd.param_sharding(pd.shape, pd.axes, mesh),
            defs, is_leaf=lambda x: isinstance(x, ParamDef))
        params = jax.tree_util.tree_map(
            jax.device_put, model.init(jax.random.PRNGKey(0)), sh)

        policy = parse_policy(args.policy)
        if policy is not None:
            model.decode_step = truncate(model.decode_step, policy)  # type: ignore

        eng = Engine(model, params, batch_size=args.batch,
                     max_seq_len=args.max_seq)
        rng = np.random.RandomState(0)
        for rid in range(args.requests):
            eng.submit(rid, rng.randint(1, cfg.vocab, args.prompt_len),
                       max_new_tokens=args.new_tokens)
        t0 = time.time()
        done = eng.run()
        dt = time.time() - t0
        total = sum(len(r.out_tokens) for r in done.values())
        print(f"served {len(done)} requests, {total} tokens in {dt:.1f}s "
              f"({total / dt:.1f} tok/s on {mesh.size} devices)")
        for rid in sorted(done):
            print(f"  req {rid}: {done[rid].out_tokens}")


if __name__ == "__main__":
    main()
