"""Production serving entrypoint: continuous batching with optional RAPTOR
truncation policy and sampled shadow profiling of live traffic.

    PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b \
        [--policy "scope:**/mlp=fp16"] [--requests 8] [--new-tokens 16] \
        [--shadow-rate 0.0625] [--drift-margin 4.0]

Requests stream in with mixed prompt lengths and token budgets; the
engine admits each one into any free decode slot while the other slots
keep decoding (no aligned waves — see :mod:`repro.serving.engine`).

Policies deploy through :func:`repro.core.policy.resolve_policy`, the
single resolution path shared with ``launch.train`` and the guardrails:
an explicit ``--policy`` flag string, or — the profile→policy→deploy
handoff — a registry ref (``--policy-artifact bench_model@v3
[--registry artifacts]``) whose searched policy is applied to the decode
step, so the exact assignment a profiling run produced is what serves
traffic.

With ``--shadow-rate > 0`` a sampled fraction of requests decode through
the memtrace-shadowed step (served tokens stay bit-identical); the merged
serving-side RaptorReport is printed at drain, and drift past the
deployed artifact's accepted error budget pages a re-search suggestion
(top-blamed sites as an autosearch warm start) and is recorded in the
artifact's provenance.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax

from repro.artifacts import default_root
from repro.configs.base import get_config
from repro.core.policy import resolve_policy as _core_resolve_policy
from repro.distributed import sharding as shd
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import Model
from repro.models.common import ParamDef
from repro.serving import Engine, ShadowConfig


def resolve_policy(policy_flag, artifact_ref, registry_root=None):
    """Back-compat wrapper over :func:`repro.core.policy.resolve_policy`.
    Returns (policy, artifact_or_None) like the old serve-local helper."""
    try:
        res = _core_resolve_policy(policy_flag, artifact_ref,
                                   registry=registry_root)
    except ValueError as e:
        raise SystemExit(str(e))
    if res.ref is not None:
        print(f"loaded {res.artifact} from registry "
              f"{registry_root or default_root()!r}", flush=True)
    return res.policy, res.artifact


def _print_drift(event):
    """Re-search hook: surface the blame ranking as an autosearch warm
    start so the on-call can page a re-search with the live evidence."""
    print(f"DRIFT {event}", flush=True)
    warm = ",".join(loc for loc, _flags, _err in event.blame[:4])
    print(f"  re-search warm start: --warm-sites '{warm}'", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=8,
                    help="mean prompt length (actual lengths are ragged)")
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--policy", default=None,
                    help='raw spec: "scope:**/mlp=fp16" or "32_to_5_14"')
    ap.add_argument("--policy-artifact", default=None,
                    help='registry ref: "name" (latest) or "name@v3"')
    ap.add_argument("--registry", default=None,
                    help=f"registry root (default $RAPTOR_REGISTRY or "
                         f"{default_root()!r})")
    ap.add_argument("--shadow-rate", type=float, default=0.0,
                    help="fraction of requests shadow-profiled (0 = off)")
    ap.add_argument("--shadow-threshold", type=float, default=1e-3,
                    help="memtrace flagging threshold for shadowed steps")
    ap.add_argument("--drift-margin", type=float, default=4.0,
                    help="page when peak shadow error exceeds margin x "
                         "the deployed artifact's accepted budget")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--production", dest="smoke", action="store_false")
    args = ap.parse_args()

    cfg = get_config(args.arch, "smoke" if args.smoke else "full")
    model = Model(cfg)
    # serving uses TP-only params when they fit (DESIGN.md §6 / §Perf it.6)
    mesh = (make_host_mesh(model_parallel=2) if args.smoke
            else make_production_mesh())
    with shd.use_mesh(mesh, param_rules=shd.SERVE_PARAM_RULES):
        defs = model.param_defs()
        sh = jax.tree_util.tree_map(
            lambda pd: shd.param_sharding(pd.shape, pd.axes, mesh),
            defs, is_leaf=lambda x: isinstance(x, ParamDef))
        params = jax.tree_util.tree_map(
            jax.device_put, model.init(jax.random.PRNGKey(0)), sh)

        policy, artifact = resolve_policy(args.policy, args.policy_artifact,
                                          args.registry)
        shadow = None
        if args.shadow_rate > 0 and policy is not None:
            shadow = ShadowConfig(rate=args.shadow_rate,
                                  threshold=args.shadow_threshold,
                                  drift_margin=args.drift_margin,
                                  on_drift=_print_drift)
        eng = Engine(model, params, batch_size=args.batch,
                     max_seq_len=args.max_seq,
                     policy=artifact if artifact is not None else policy,
                     shadow=shadow)
        rng = np.random.RandomState(0)
        for _ in range(args.requests):
            # ragged workload: prompts vary around --prompt-len so serving
            # exercises masked prefill into busy batches, not aligned waves
            plen = max(1, int(rng.randint(max(1, args.prompt_len // 2),
                                          args.prompt_len * 2)))
            eng.submit(rng.randint(1, cfg.vocab, plen),
                       max_new_tokens=args.new_tokens)
        t0 = time.time()
        done = eng.run()
        dt = time.time() - t0
        total = sum(len(r.out_tokens) for r in done.values())
        print(f"served {len(done)} requests, {total} tokens in {dt:.1f}s "
              f"({total / dt:.1f} tok/s on {mesh.size} devices)")
        for rid in sorted(done):
            req = done[rid]
            tag = " [shadowed]" if req.shadowed else ""
            tag += f" [{req.status}]" if req.status != "ok" else ""
            print(f"  req {rid}: {req.out_tokens}{tag}")
        if eng.serving_report is not None:
            top = eng.serving_report.top(3)
            print("shadow serving report (top sites):")
            for loc, flags, err in top:
                print(f"  {loc}: flags={flags} max_rel={err:.2e}")
        for ev in eng.drift_events:
            print(f"drift event recorded at tick {ev.tick} "
                  f"(peak {ev.peak:.2e} vs budget {ev.budget:.2e})")


if __name__ == "__main__":
    main()
