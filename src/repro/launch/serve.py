"""Production serving entrypoint: batched prefill + decode with optional
RAPTOR truncation policy (mixed-precision deployment study).

    PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b \
        [--policy "scope:**/mlp=fp16"] [--requests 8] [--new-tokens 16]

Policies deploy either as raw flag strings (``--policy``) or — the
profile→policy→deploy handoff — by registry name (``--policy-artifact
bench_model@v3 [--registry artifacts]``): the named
:class:`repro.artifacts.PolicyArtifact` is loaded from the file-backed
registry and its searched policy applied to the decode step, so the exact
assignment a profiling run produced is what serves traffic.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax

from repro.artifacts import Registry, default_root
from repro.configs.base import get_config
from repro.core.policy import parse_policy
from repro.distributed import sharding as shd
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import Model
from repro.models.common import ParamDef
from repro.serving.engine import Engine


def resolve_policy(policy_flag, artifact_ref, registry_root=None):
    """The serve-side policy resolution: an explicit ``--policy`` flag, or a
    registry artifact by name. Returns (policy, artifact_or_None)."""
    if policy_flag and artifact_ref:
        raise SystemExit("--policy and --policy-artifact are exclusive")
    if artifact_ref:
        art = Registry(registry_root).load(artifact_ref)
        print(f"loaded {art} from registry "
              f"{registry_root or default_root()!r}", flush=True)
        return art.policy, art
    return parse_policy(policy_flag), None


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--policy", default=None,
                    help='raw spec: "scope:**/mlp=fp16" or "32_to_5_14"')
    ap.add_argument("--policy-artifact", default=None,
                    help='registry ref: "name" (latest) or "name@v3"')
    ap.add_argument("--registry", default=None,
                    help=f"registry root (default $RAPTOR_REGISTRY or "
                         f"{default_root()!r})")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--production", dest="smoke", action="store_false")
    args = ap.parse_args()

    cfg = get_config(args.arch, "smoke" if args.smoke else "full")
    model = Model(cfg)
    # serving uses TP-only params when they fit (DESIGN.md §6 / §Perf it.6)
    mesh = (make_host_mesh(model_parallel=2) if args.smoke
            else make_production_mesh())
    with shd.use_mesh(mesh, param_rules=shd.SERVE_PARAM_RULES):
        defs = model.param_defs()
        sh = jax.tree_util.tree_map(
            lambda pd: shd.param_sharding(pd.shape, pd.axes, mesh),
            defs, is_leaf=lambda x: isinstance(x, ParamDef))
        params = jax.tree_util.tree_map(
            jax.device_put, model.init(jax.random.PRNGKey(0)), sh)

        policy, _ = resolve_policy(args.policy, args.policy_artifact,
                                   args.registry)
        eng = Engine(model, params, batch_size=args.batch,
                     max_seq_len=args.max_seq, policy=policy)
        rng = np.random.RandomState(0)
        for rid in range(args.requests):
            eng.submit(rid, rng.randint(1, cfg.vocab, args.prompt_len),
                       max_new_tokens=args.new_tokens)
        t0 = time.time()
        done = eng.run()
        dt = time.time() - t0
        total = sum(len(r.out_tokens) for r in done.values())
        print(f"served {len(done)} requests, {total} tokens in {dt:.1f}s "
              f"({total / dt:.1f} tok/s on {mesh.size} devices)")
        for rid in sorted(done):
            print(f"  req {rid}: {done[rid].out_tokens}")


if __name__ == "__main__":
    main()
