"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the leading ``pod`` axis
is pure data parallelism across pods (slow DCI links carry only the gradient
all-reduce; params/optimizer are FSDP-sharded within a pod).

A FUNCTION, not a module constant: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Small mesh over whatever devices exist (tests / CPU runs)."""
    n = len(jax.devices())
    mp = model_parallel
    while mp > 1 and n % mp:
        mp //= 2
    return compat.make_mesh((n // mp, mp), ("data", "model"))
