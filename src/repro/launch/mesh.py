"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the leading ``pod`` axis
is pure data parallelism across pods (slow DCI links carry only the gradient
all-reduce; params/optimizer are FSDP-sharded within a pod).

A FUNCTION, not a module constant: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Small mesh over whatever devices exist (tests / CPU runs)."""
    n = len(jax.devices())
    mp = model_parallel
    while mp > 1 and n % mp:
        mp //= 2
    return compat.make_mesh((n // mp, mp), ("data", "model"))


def make_probe_mesh(n_devices: int | None = None, axis: str = "probe"):
    """1-D mesh for mesh-parallel profiling: the leading candidate axis of a
    (K, num_sites, 4) format-table batch is sharded over ``axis`` so a
    W-candidate ladder evaluates on W/ndev devices concurrently (see
    ``api.truncate_sweep(mesh=...)`` / ``search.autosearch(mesh=...)``).

    ``n_devices`` takes a prefix of ``jax.devices()`` (useful for measuring
    per-device-count throughput); default is every visible device. On CPU,
    emulate a multi-device host with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``."""
    devs = jax.devices()
    if n_devices is not None:
        if n_devices > len(devs):
            raise ValueError(
                f"make_probe_mesh: {n_devices} devices requested, "
                f"{len(devs)} visible")
        devs = devs[:n_devices]
    return compat.make_mesh((len(devs),), (axis,), devices=devs)


def make_profile_mesh(probe: int, data: int = 1, *,
                      axes=("probe", "data")):
    """2-D (probe, data) mesh: candidate-parallel x data-parallel profiling.
    ``probe * data`` must not exceed the visible device count."""
    n = probe * data
    devs = jax.devices()
    if n > len(devs):
        raise ValueError(f"make_profile_mesh: {n} devices requested, "
                         f"{len(devs)} visible")
    return compat.make_mesh((probe, data), tuple(axes), devices=devs[:n])
