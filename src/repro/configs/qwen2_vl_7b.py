"""qwen2-vl-7b — VLM decoder backbone with M-RoPE.

[arXiv:2409.12191; hf] 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064; M-RoPE sections (16, 24, 24); dynamic-resolution ViT frontend
is a STUB: input_specs() provides precomputed patch embeddings + 3D position
ids (DESIGN.md §5).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, head_dim=128,
    d_ff=18944, vocab=152064,
    qkv_bias=True, rope_type="mrope", mrope_sections=(16, 24, 24),
    rope_theta=1e6, input_mode="embeds", grad_accum=8,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=160,
    vocab=256, mrope_sections=(2, 3, 3), dtype="float32", grad_accum=1,
)
