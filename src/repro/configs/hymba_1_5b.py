"""hymba-1.5b — hybrid: parallel attention + Mamba heads per layer.

[arXiv:2411.13676; hf] 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16. Sliding-window attention everywhere except the
first/middle/last layers (global); meta-token mechanism is out of backbone
scope (DESIGN.md §5).
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
    d_ff=5504, vocab=32001,
    attn_type="hymba", ssm=SSMConfig(state_dim=16, conv_width=4, expand=1),
    sliding_window=1024, global_layers=(0, 15, 31),
    rope_theta=1e4, grad_accum=8,
)

SMOKE = CONFIG.replace(
    n_layers=3, d_model=80, n_heads=5, n_kv_heads=1, head_dim=16, d_ff=192,
    vocab=256, sliding_window=16, global_layers=(0, 2),
    ssm=SSMConfig(state_dim=8, conv_width=4, expand=1),
    dtype="float32", grad_accum=1,
)
