"""internlm2-20b — dense llama-arch decoder, GQA kv=8.

[arXiv:2403.17297; hf] 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92544.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internlm2-20b", family="dense",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab=92544,
    rope_theta=1e6, grad_accum=8,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=48, n_heads=6, n_kv_heads=1, head_dim=8, d_ff=128,
    vocab=256, dtype="float32", grad_accum=1,
)
