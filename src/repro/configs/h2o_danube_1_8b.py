"""h2o-danube-1.8b — dense decoder, llama+mistral mix, sliding-window attn.

[arXiv:2401.16818; hf] 24L d_model=2560 32H (GQA kv=8) d_ff=6912
vocab=32000, SWA window 4096.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-1.8b", family="dense",
    n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8, head_dim=80,
    d_ff=6912, vocab=32000,
    sliding_window=4096, rope_theta=1e4, grad_accum=4,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16, d_ff=160,
    vocab=256, sliding_window=16, dtype="float32", grad_accum=1,
)
