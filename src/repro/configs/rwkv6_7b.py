"""rwkv6-7b (Finch) — attention-free, data-dependent decay linear attention.

[arXiv:2404.05892; hf] 32L d_model=4096 d_ff=14336 vocab=65536; 64 heads
of size 64.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-7b", family="ssm",
    n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64, head_dim=64,
    d_ff=14336, vocab=65536,
    attn_type="rwkv6", rope_type="none", grad_accum=8,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32, d_ff=160,
    vocab=256, dtype="float32", grad_accum=1,
)
