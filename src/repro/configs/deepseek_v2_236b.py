"""deepseek-v2-236b — MoE decoder with Multi-head Latent Attention.

[arXiv:2405.04434; hf] 60L d_model=5120 128H d_ff=1536 (per routed expert)
vocab=102400; MLA kv_lora=512 (q_lora=1536, rope_dim=64, nope=128, v=128);
2 shared + 160 routed experts, top-6; first layer dense (d_ff 12288).
"""
from repro.configs.base import ArchConfig, MoEConfig, MLAConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128, head_dim=128,
    d_ff=1536, vocab=102400,
    attn_type="mla",
    mla=MLAConfig(q_lora=1536, kv_lora=512, rope_head_dim=64,
                  nope_head_dim=128, v_head_dim=128),
    moe=MoEConfig(n_experts=160, top_k=6, d_expert=1536, n_shared=2,
                  first_k_dense=1, d_ff_dense=12288, renormalize=False),
    rope_theta=1e4, grad_accum=16,
)

SMOKE = CONFIG.replace(
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=32,
    vocab=256,
    mla=MLAConfig(q_lora=32, kv_lora=32, rope_head_dim=8, nope_head_dim=16,
                  v_head_dim=16),
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=32, n_shared=2,
                  first_k_dense=1, d_ff_dense=128, renormalize=False),
    dtype="float32", grad_accum=1,
)
