"""Architecture config schema + the assigned input-shape set + registry."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0
    first_k_dense: int = 0
    d_ff_dense: int = 0           # d_ff of the leading dense layers
    renormalize: bool = True
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora: int
    kv_lora: int
    rope_head_dim: int
    nope_head_dim: int
    v_head_dim: int


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 16
    conv_width: int = 4
    dt_rank: int = 0              # 0 -> ceil(d_model/16)
    expand: int = 1               # d_inner = expand * d_model


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    attn_type: str = "gqa"        # gqa | mla | rwkv6 | hymba
    qkv_bias: bool = False
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    act: str = "swiglu"
    rope_type: str = "rope"       # rope | mrope | none
    rope_theta: float = 1e4
    rope_fraction: float = 1.0
    mrope_sections: Tuple[int, ...] = ()
    sliding_window: Optional[int] = None
    global_layers: Tuple[int, ...] = ()   # layer indices using global attn
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    enc_layers: int = 0                   # encoder-decoder only
    cross_attention: bool = False
    input_mode: str = "tokens"            # tokens | embeds (stub frontends)
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    # execution knobs
    scan_layers: bool = True
    remat: bool = True
    grad_accum: int = 1

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


# the assigned LM shape set (identical for all 10 archs)
SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

# archs with at least one sub-quadratic sequence-mixing path run long_500k;
# pure full-attention archs skip it (DESIGN.md §5)
LONG_CONTEXT_ARCHS = ("hymba-1.5b", "h2o-danube-1.8b", "rwkv6-7b")

ARCH_IDS = (
    "hymba-1.5b", "glm4-9b", "deepseek-coder-33b", "internlm2-20b",
    "h2o-danube-1.8b", "olmoe-1b-7b", "deepseek-v2-236b", "rwkv6-7b",
    "seamless-m4t-large-v2", "qwen2-vl-7b",
)

_MODULES = {
    "hymba-1.5b": "hymba_1_5b",
    "glm4-9b": "glm4_9b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "internlm2-20b": "internlm2_20b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "rwkv6-7b": "rwkv6_7b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "qwen2-vl-7b": "qwen2_vl_7b",
}


def get_config(arch_id: str, variant: str = "full") -> ArchConfig:
    """Load an architecture config: ``variant`` is "full" or "smoke"."""
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    if variant == "full":
        return mod.CONFIG
    if variant == "smoke":
        return mod.SMOKE
    raise ValueError(f"unknown variant {variant!r}")


def cells(arch_id: str):
    """The (shape, runnable) list for one arch — 4 assigned shapes with the
    long_500k skip rule applied."""
    out = []
    for s in SHAPES.values():
        runnable = (s.name != "long_500k") or (arch_id in LONG_CONTEXT_ARCHS)
        out.append((s, runnable))
    return out
