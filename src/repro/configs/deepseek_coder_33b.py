"""deepseek-coder-33b — dense llama-arch decoder, GQA kv=8.

[arXiv:2401.14196; hf] 62L d_model=7168 56H (GQA kv=8) d_ff=19200
vocab=32256.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-coder-33b", family="dense",
    n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=19200, vocab=32256,
    rope_theta=1e5, grad_accum=16,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=56, n_heads=7, n_kv_heads=1, head_dim=8, d_ff=144,
    vocab=256, dtype="float32", grad_accum=1,
)
