"""seamless-m4t-large-v2 — encoder-decoder multimodal backbone.

[arXiv:2308.11596; hf] 24L (enc) + 24L (dec) d_model=1024 16H (kv=16)
d_ff=8192 vocab=256206. Speech frontend is a STUB: input_specs() provides
precomputed frame embeddings (DESIGN.md §5).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2", family="encdec",
    n_layers=24, enc_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    head_dim=64, d_ff=8192, vocab=256206,
    cross_attention=True, norm="layernorm", act="gelu",
    rope_theta=1e4, grad_accum=4,
)

SMOKE = CONFIG.replace(
    n_layers=2, enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    head_dim=16, d_ff=128, vocab=256, dtype="float32", grad_accum=1,
)
