"""glm4-9b — dense decoder, GQA kv=2, partial RoPE, qkv bias.

[hf:THUDM/glm-4-9b; hf] 40L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=151552.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="glm4-9b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2, head_dim=128,
    d_ff=13696, vocab=151552,
    qkv_bias=True, rope_fraction=0.5, rope_theta=1e4, grad_accum=8,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=160,
    vocab=256, dtype="float32", grad_accum=1,
)
