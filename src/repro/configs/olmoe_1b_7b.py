"""olmoe-1b-7b — MoE decoder: 64 experts, top-8, MHA.

[arXiv:2409.02060; hf] 16L d_model=2048 16H (kv=16) d_ff=1024 (per expert)
vocab=50304, MoE 64e top-8.
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1024, vocab=50304,
    moe=MoEConfig(n_experts=64, top_k=8, d_expert=1024, renormalize=False),
    rope_theta=1e4, grad_accum=4,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=32,
    vocab=256,
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=32, renormalize=False),
    dtype="float32", grad_accum=1,
)
