"""Fault-tolerant checkpointing: atomic, async, sharded, elastic-restorable.

Layout per step:
    <dir>/step_000123/
        arrays.npz          flattened pytree leaves (host-local shards)
        manifest.json       step, tree structure, mesh shape, data cursor
    <dir>/LATEST            atomic pointer file (rename())

Guarantees exercised by tests/test_checkpoint_ft.py:
  * a kill between save() calls never corrupts the latest checkpoint
    (write to tmp dir + atomic rename, LATEST updated last)
  * restore() onto a *different* mesh re-shards via device_put with the new
    NamedShardings (elastic scaling)
  * keep_k garbage collection never deletes the newest durable step
  * async mode overlaps serialization with the next train step
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import numpy as np

import jax


SEP = "##"


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        if leaf is None:
            continue
        key = SEP.join(str(p) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


class Checkpointer:
    def __init__(self, directory: str, keep_k: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep_k = keep_k
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ---- save ----------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[Dict] = None,
             block: bool = False, policy_artifact: Optional[Any] = None):
        """``policy_artifact``: the active precision policy's durable
        identity — an ``repro.artifacts.ArtifactRef``, a ``PolicyArtifact``
        (name + content digest recorded), or a plain ``{name, version,
        digest}`` dict. Recorded in ``manifest.json`` so a restored run can
        re-load (and hash-verify) the exact policy it was training under."""
        flat = _flatten(tree)   # device_get on the caller thread (consistent)
        treedef = jax.tree_util.tree_structure(tree)
        if policy_artifact is not None and not isinstance(
                policy_artifact, dict):
            if hasattr(policy_artifact, "to_json") and hasattr(
                    policy_artifact, "version"):
                policy_artifact = policy_artifact.to_json()   # ArtifactRef
            else:                                             # PolicyArtifact
                policy_artifact = {"name": policy_artifact.name,
                                   "version": None,
                                   "digest": policy_artifact.digest}
        manifest = {
            "step": int(step),
            "treedef": str(treedef),
            "extra": extra or {},
            "process_count": jax.process_count(),
            "policy_artifact": policy_artifact,
        }
        self.wait()
        if self.async_save and not block:
            self._thread = threading.Thread(
                target=self._write, args=(step, flat, manifest), daemon=True)
            self._thread.start()
        else:
            self._write(step, flat, manifest)

    def _write(self, step: int, flat, manifest):
        name = f"step_{step:09d}"
        tmp = os.path.join(self.dir, f".tmp_{name}_{os.getpid()}")
        final = os.path.join(self.dir, name)
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                      # atomic publish
        latest_tmp = os.path.join(self.dir, ".LATEST_tmp")
        with open(latest_tmp, "w") as f:
            f.write(name)
        os.rename(latest_tmp, os.path.join(self.dir, "LATEST"))
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(d for d in os.listdir(self.dir) if d.startswith("step_"))
        for d in steps[:-self.keep_k] if self.keep_k else []:
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    # ---- restore ---------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        path = os.path.join(self.dir, "LATEST")
        if not os.path.exists(path):
            return None
        with open(path) as f:
            return int(f.read().strip().split("_")[-1])

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None):
        """Restore into the structure of ``template``; ``shardings`` (same
        structure or prefix) re-shards for the *current* mesh — elastic."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        base = os.path.join(self.dir, f"step_{step:09d}")
        with np.load(os.path.join(base, "arrays.npz")) as z:
            data = {k: z[k] for k in z.files}
        with open(os.path.join(base, "manifest.json")) as f:
            manifest = json.load(f)

        paths = jax.tree_util.tree_flatten_with_path(template)[0]
        leaves = []
        for path, leaf in paths:
            key = SEP.join(str(p) for p in path)
            if leaf is None:
                leaves.append(None)
                continue
            if key not in data:
                raise KeyError(f"checkpoint missing leaf {key}")
            arr = data[key]
            leaves.append(arr)
        treedef = jax.tree_util.tree_structure(template)
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree_util.tree_map(
                lambda x, s: x if x is None else jax.device_put(x, s),
                tree, shardings)
        else:
            tree = jax.tree_util.tree_map(
                lambda x, t: None if x is None else
                jax.numpy.asarray(x, getattr(t, "dtype", None)),
                tree, template)
        return tree, manifest
