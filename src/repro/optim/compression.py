"""Gradient compression for the data-parallel all-reduce.

Two levels (distributed-optimization tricks for the collective-bound
regime — measured in EXPERIMENTS.md §Perf):
  * bf16 gradient reduction — halves DP all-reduce bytes; error feedback
    keeps the quantization residual in a local buffer so long-run training
    is unbiased.
  * int8 per-tensor-scaled reduction — 4x fewer bytes; same error feedback.

In GSPMD-land "compressing the all-reduce" = casting the per-microbatch
gradient contribution before the psum implied by the batch-sharded loss.
The trainer applies ``compress`` to gradients inside the accumulation loop
and ``decompress`` after; the error-feedback buffer rides the optimizer
state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error_buffer(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)


def compress_bf16(grads, err):
    """g_q = bf16(g + e); new_e = (g + e) - g_q (error feedback)."""
    def one(g, e):
        gf = g.astype(jnp.float32) + e.astype(jnp.float32)
        gq = gf.astype(jnp.bfloat16)
        return gq, (gf - gq.astype(jnp.float32)).astype(jnp.bfloat16)
    flat = jax.tree_util.tree_map(one, grads, err)
    gq = jax.tree_util.tree_map(lambda t: t[0], flat,
                                is_leaf=lambda t: isinstance(t, tuple))
    ne = jax.tree_util.tree_map(lambda t: t[1], flat,
                                is_leaf=lambda t: isinstance(t, tuple))
    return gq, ne


def compress_int8(grads, err):
    """Per-tensor absmax int8 with error feedback. Returns ((q, scale), e)."""
    def one(g, e):
        gf = g.astype(jnp.float32) + e.astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale
        return (q, scale), (gf - deq).astype(jnp.bfloat16)
    pairs = jax.tree_util.tree_map(one, grads, err)
    qs = jax.tree_util.tree_map(lambda t: t[0], pairs,
                                is_leaf=lambda t: isinstance(t, tuple) and len(t) == 2)
    ne = jax.tree_util.tree_map(lambda t: t[1], pairs,
                                is_leaf=lambda t: isinstance(t, tuple) and len(t) == 2)
    return qs, ne


def decompress_int8(qs):
    return jax.tree_util.tree_map(
        lambda t: t[0].astype(jnp.float32) * t[1],
        qs, is_leaf=lambda t: isinstance(t, tuple) and len(t) == 2)
