"""AdamW in pure JAX with dtype-configurable state (ZeRO-friendly).

State layout mirrors the parameter tree so the trainer can assign it the
same FSDP x TP shardings (ZeRO-1/3 falls out of the param sharding). For
very large models (deepseek-v2-236b single-pod) ``state_dtype="bfloat16"``
halves the m/v footprint; the fp32 master copy is kept whenever params are
half precision.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"     # m/v dtype
    master_dtype: str = "float32"    # master copy (when params half prec)


def _is_half(x):
    return x.dtype in (jnp.bfloat16, jnp.float16)


def init_state(params, cfg: AdamWConfig):
    sd = jnp.dtype(cfg.state_dtype)
    md = jnp.dtype(cfg.master_dtype)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, sd), params),
        "v": jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, sd), params),
        "master": jax.tree_util.tree_map(
            lambda p: p.astype(md) if _is_half(p) else None, params),
    }


def global_norm(tree):
    sq = jax.tree_util.tree_map(
        lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree)
    return jnp.sqrt(jax.tree_util.tree_reduce(jnp.add, sq, jnp.float32(0)))


def apply_updates(params, grads, state, cfg: AdamWConfig, lr):
    """One AdamW step. ``lr`` may be a traced scalar (schedule)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if cfg.grad_clip else jnp.float32(1.0)

    c1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, master):
        gf = g.astype(jnp.float32) * scale
        mf = m.astype(jnp.float32) * cfg.b1 + gf * (1.0 - cfg.b1)
        vf = v.astype(jnp.float32) * cfg.b2 + gf * gf * (1.0 - cfg.b2)
        base = (master if master is not None else p).astype(jnp.float32)
        step_ = (mf / c1) / (jnp.sqrt(vf / c2) + cfg.eps)
        new_base = base - lr * (step_ + cfg.weight_decay * base)
        new_p = new_base.astype(p.dtype)
        new_master = new_base.astype(master.dtype) if master is not None else None
        return new_p, mf.astype(m.dtype), vf.astype(v.dtype), new_master

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    flat_ma = tdef.flatten_up_to(state["master"])
    outs = [upd(p, g, m, v, ma) for p, g, m, v, ma in
            zip(flat_p, flat_g, flat_m, flat_v, flat_ma)]
    new_params = tdef.unflatten([o[0] for o in outs])
    new_state = {
        "step": step,
        "m": tdef.unflatten([o[1] for o in outs]),
        "v": tdef.unflatten([o[2] for o in outs]),
        "master": tdef.unflatten([o[3] for o in outs]),
    }
    return new_params, new_state, {"grad_norm": gnorm}


def warmup_cosine(step, *, peak_lr: float, warmup: int = 2000,
                  total: int = 100_000, floor: float = 0.1):
    s = step.astype(jnp.float32)
    warm = peak_lr * s / max(warmup, 1)
    prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(s < warmup, warm, cos)
