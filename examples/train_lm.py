"""End-to-end training driver: data pipeline -> sharded train step ->
checkpoint/restart -> optional RAPTOR truncation policy, on whatever
devices exist (CPU here; the same code under launch/train.py + the
production mesh is what the dry-run compiles for 256/512 chips).

Default: a ~13M-param GLM4-family model for 60 steps (CPU-friendly).
Scale up:
    PYTHONPATH=src python examples/train_lm.py --arch glm4-9b --steps 300 \
        --d-model 768 --layers 12      # ~100M params

Demonstrates fault tolerance: the run saves every --save-every steps; rerun
the same command and it resumes from the latest checkpoint.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import get_config
from repro.core import TruncationPolicy
from repro.data.pipeline import DataConfig, Pipeline, Prefetcher
from repro.distributed import sharding as shd
from repro.launch.mesh import make_host_mesh
from repro.models import Model
from repro.models.common import ParamDef
from repro.optim.adamw import AdamWConfig, warmup_cosine
from repro.train.trainer import TrainConfig, make_train_step, init_opt_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--save-every", type=int, default=25)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--policy", default=None,
                    help="RAPTOR flag, e.g. 32_to_8_10 or scope:mlp=e5m7")
    args = ap.parse_args()

    base = get_config(args.arch, "smoke")
    cfg = base.replace(d_model=args.d_model,
                       n_layers=args.layers,
                       d_ff=args.d_model * 3,
                       vocab=4096, dtype="float32")
    model = Model(cfg)
    print(f"arch={cfg.name} family={cfg.family} params={model.n_params()/1e6:.1f}M")

    policy = None
    if args.policy:
        if args.policy.startswith("scope:"):
            scope, fmt = args.policy[len("scope:"):].split("=")
            policy = TruncationPolicy.scoped(f"**/{scope}", fmt)
        else:
            policy = TruncationPolicy.from_flag(args.policy)

    mesh = make_host_mesh(model_parallel=1)
    tc = TrainConfig(
        optimizer=AdamWConfig(lr=args.lr),
        policy=policy,
        lr_schedule=lambda s: warmup_cosine(s, peak_lr=args.lr, warmup=20,
                                            total=max(args.steps, 100)))
    data = Pipeline(DataConfig(seq_len=args.seq, global_batch=args.batch,
                               vocab=cfg.vocab,
                               d_model=cfg.d_model,
                               input_mode=("encdec" if cfg.family == "encdec"
                                           else cfg.input_mode),
                               mrope=cfg.rope_type == "mrope"))
    ck = Checkpointer(args.ckpt_dir, keep_k=2)

    with shd.use_mesh(mesh):
        step_fn = jax.jit(make_train_step(model, tc))
        params = model.init(jax.random.PRNGKey(0))
        defs = model.param_defs()
        sh = jax.tree_util.tree_map(
            lambda pd: shd.param_sharding(pd.shape, pd.axes, mesh),
            defs, is_leaf=lambda x: isinstance(x, ParamDef))
        params = jax.tree_util.tree_map(jax.device_put, params, sh)
        opt = init_opt_state(model, params, tc)

        start = 0
        if ck.latest_step() is not None:
            (params, opt), manifest = ck.restore((params, opt))
            data.load_state_dict(manifest["extra"]["data"])
            start = manifest["step"]
            print(f"resumed from step {start}")

        pf = Prefetcher(data)
        t0 = time.time()
        try:
            for step in range(start, args.steps):
                batch = {k: jnp.asarray(v) for k, v in pf.next().items()}
                params, opt, m = step_fn(params, opt, batch, jnp.int32(step))
                if step % 10 == 0 or step == args.steps - 1:
                    dt = (time.time() - t0) / max(step - start + 1, 1)
                    print(f"step {step:5d} loss {float(m['loss']):.4f} "
                          f"lr {float(m['lr']):.2e} "
                          f"gnorm {float(m['grad_norm']):.2f} "
                          f"({dt*1e3:.0f} ms/step)", flush=True)
                if (step + 1) % args.save_every == 0:
                    ck.save(step + 1, (params, opt),
                            extra={"data": data.state_dict()})
            ck.save(args.steps, (params, opt),
                    extra={"data": data.state_dict()}, block=True)
            print("done; checkpoint at", args.ckpt_dir)
        finally:
            pf.close()


if __name__ == "__main__":
    main()
