"""Quickstart: profile a model's numerical sensitivity in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core import (
    truncate, memtrace, profile_counts, TruncationPolicy, estimate_speedup,
)
from repro.models import Model

# 1. any assigned architecture, reduced config for the laptop
cfg = get_config("olmoe-1b-7b", "smoke")
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))
r = np.random.RandomState(0)
toks = r.randint(0, cfg.vocab, (4, 65))
batch = {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
         "labels": jnp.asarray(toks[:, 1:], jnp.int32)}

# 2. hypothesis: the MoE experts tolerate 8 mantissa bits (the router won't)
policy = TruncationPolicy.scoped("**/moe/experts", "e8m8")

# 3. op-mode: run the truncated model, measure the damage
full = float(model.loss(params, batch))
lossy = float(truncate(model.loss, policy)(params, batch))
print(f"loss full={full:.6f}  truncated={lossy:.6f}  delta={lossy-full:+.2e}")

# 4. counters -> predicted speedup (paper §7.2)
rep = profile_counts(model.loss, policy)(params, batch)
print(rep.summary())
print("predicted:", estimate_speedup(rep))

# 5. mem-mode: where does it hurt? (numerical heatmap)
out, heat = memtrace(model.loss, TruncationPolicy.everywhere("e8m8"),
                     threshold=1e-3)(params, batch)
print("\ntop numerically-fragile locations:")
print(heat.summary(8))
