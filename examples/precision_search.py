"""Automated precision search: the paper's manual hypothesis loop, closed.

Built on ``repro.search.autosearch``: trace the loss once, discover the
``named_scope`` regions, bisect each region's mantissa width in isolation,
then compose the joint policy and greedily exclude fragile regions until the
loss degradation fits the budget (paper §6.3's "exclude Recon, re-run").
Ends with the Fig. 7-style cost-benefit readout: the per-scope format table,
the truncated-FLOP census, and the predicted speedup.

    PYTHONPATH=src python examples/precision_search.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core import truncate, profile_counts, estimate_speedup
from repro.models import Model
from repro import search

ERROR_BUDGET = 5e-3       # max acceptable relative loss degradation
EVAL_BUDGET = 48          # candidate evaluations the search may spend

cfg = get_config("h2o-danube-1.8b", "smoke")
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))
r = np.random.RandomState(0)
toks = r.randint(0, cfg.vocab, (8, 65))
batch = {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
         "labels": jnp.asarray(toks[:, 1:], jnp.int32)}
full = float(model.loss(params, batch))
print(f"baseline loss {full:.6f}; budget {ERROR_BUDGET:.0e} relative, "
      f"{EVAL_BUDGET} evaluations\n")

result = search.autosearch(
    model.loss, (params, batch),
    search.loss_degradation, EVAL_BUDGET,
    threshold=ERROR_BUDGET, verbose=True)

print("\nper-scope assignment (paper heatmap analogue):")
print(result.table())

policy = result.policy()
lossy = float(truncate(model.loss, policy)(params, batch))
rep = profile_counts(model.loss, policy)(params, batch)
print(f"\nfinal policy loss {lossy:.6f} (rel err "
      f"{abs(lossy - full) / abs(full):.2e})")
print(rep.summary())
print("predicted speedup:", estimate_speedup(rep))
