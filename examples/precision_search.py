"""Automated precision search: the paper's manual hypothesis loop, closed.

Greedy per-scope mantissa descent: starting from fp32 everywhere, walk the
module scopes; for each, lower the mantissa while the validation-loss
degradation stays inside the error budget, then keep the lowest admissible
width. Produces a mixed-precision policy + its predicted speedup — i.e. the
Fig. 7 "cost-benefit analysis" done automatically.

    PYTHONPATH=src python examples/precision_search.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core import (
    truncate, profile_counts, TruncationPolicy, TruncationRule, FPFormat,
    estimate_speedup,
)
from repro.models import Model

ERROR_BUDGET = 5e-3       # max acceptable relative loss degradation
SCOPES = ["**/attn", "**/mlp", "**/pre_norm", "**/post_norm",
          "final_norm", "logits"]
WIDTHS = [23, 16, 10, 7, 5, 3, 2]

cfg = get_config("h2o-danube-1.8b", "smoke")
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))
r = np.random.RandomState(0)
toks = r.randint(0, cfg.vocab, (8, 65))
batch = {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
         "labels": jnp.asarray(toks[:, 1:], jnp.int32)}
full = float(model.loss(params, batch))
print(f"baseline loss {full:.6f}; budget {ERROR_BUDGET:.0e} relative\n")

chosen = {}
for sc in SCOPES:
    best = 23
    for m in WIDTHS:
        rules = tuple(TruncationRule(fmt=FPFormat(8, mm), scope=s)
                      for s, mm in {**chosen, sc: m}.items())
        pol = TruncationPolicy(rules=rules)
        lossy = float(truncate(model.loss, pol)(params, batch))
        rel = abs(lossy - full) / max(abs(full), 1e-9)
        if rel <= ERROR_BUDGET:
            best = m
        else:
            break
    chosen[sc] = best
    print(f"  {sc:15s} -> e8m{best}")

rules = tuple(TruncationRule(fmt=FPFormat(8, m), scope=s)
              for s, m in chosen.items())
policy = TruncationPolicy(rules=rules)
lossy = float(truncate(model.loss, policy)(params, batch))
rep = profile_counts(model.loss, policy)(params, batch)
print(f"\nfinal policy loss {lossy:.6f} (rel err "
      f"{abs(lossy-full)/abs(full):.2e})")
print(rep.summary())
print("predicted speedup:", estimate_speedup(rep))
