"""Mini-app end-to-end benchmark: solver throughput + search cost + oracle
error on the three PDE workloads.

Per app (Sod shock tube / 2D heat / CG Poisson):

  * ``<app>_run``            — steady-state jit'd f32 trajectory wall time
  * ``<app>_truncated_run``  — the same trajectory through the op-mode
                               interpreter under the uniform-low policy
                               (the profiling-overhead number, paper tab. 3)
  * ``<app>_autosearch``     — full mixed-precision search wall time, with
                               evals/compiles and the achieved oracle error
                               in the derived column

The oracle errors in ``derived`` track the scientific claim next to the
perf trajectory: the searched assignment must stay inside the app budget
while uniform-low busts it (asserted here too — a benchmark that stops
demonstrating the claim fails loudly, same contract as benchmarks/run.py).

    PYTHONPATH=src python -m benchmarks.apps_e2e
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, timeit
from repro import search
from repro.apps import get_app, oracle
from repro.core import truncate


def bench_app(name: str, budget: int = 32):
    app = get_app(name)
    state = app.init_state(jnp.float32)
    ref64 = oracle.fp64_reference(app)

    run = jax.jit(app.run_observables)
    t_run, obs32 = timeit(run, state, warmup=1, iters=3)
    floor = app.error_metric(ref64, obs32)
    csv_row(f"{name}_run", t_run * 1e6,
            f"steps={app.n_steps};floor={floor:.3e}")

    tr = truncate(app.run_observables, app.uniform_policy())
    t_tr, obs_uni = timeit(tr, state, warmup=1, iters=3)
    err_uni = app.error_metric(ref64, obs_uni)
    csv_row(f"{name}_truncated_run", t_tr * 1e6,
            f"overhead={t_tr / t_run:.1f}x;uniform_err={err_uni:.3e}")

    t0 = time.perf_counter()
    res = search.autosearch(app.run_observables, (state,),
                            metric=app.error_metric, budget=budget,
                            threshold=app.search_threshold)
    t_search = time.perf_counter() - t0
    obs_mixed = truncate(app.run_observables, res.policy())(state)
    err_mixed = app.error_metric(ref64, obs_mixed)
    csv_row(f"{name}_autosearch", t_search * 1e6,
            f"evals={res.evals_used};compiles={res.n_compiles}"
            f";scopes={len(res.assignments)}"
            f";mixed_err={err_mixed:.3e};budget={app.error_budget:.1e}")

    assert res.converged, f"{name}: search did not converge\n{res.table()}"
    assert err_mixed <= app.error_budget < err_uni, (
        f"{name}: oracle ordering broken "
        f"(mixed {err_mixed:.3e}, budget {app.error_budget:.1e}, "
        f"uniform {err_uni:.3e})")
    return res


def run():
    for name in ("sod", "heat", "poisson"):
        bench_app(name)


if __name__ == "__main__":
    run()
