"""Search-convergence benchmark: trace-cache hit rate + autosearch cost.

Two numbers the tentpole promises, measured on the ~10M-param bench model:

  1. trace caching — first call of a cached ``truncate`` wrapper (trace +
     jaxpr walk + compile) vs its steady-state call (executable-cache hit).
     The ratio is the payoff of caching the transformed computation.
  2. search convergence — evaluations and wall time ``autosearch`` needs to
     land a per-scope assignment under the error threshold.

    PYTHONPATH=src python -m benchmarks.search_convergence
"""
import time

import jax

from benchmarks.common import bench_model, bench_batch, csv_row, timeit
from repro import search
from repro.core import truncate, TruncationPolicy, profile_counts, \
    estimate_speedup


def bench_trace_cache():
    cfg, model, params = bench_model()
    batch = bench_batch(cfg)
    pol = TruncationPolicy.everywhere("e5m7")
    tr = truncate(model.loss, pol)

    t0 = time.perf_counter()
    jax.block_until_ready(tr(params, batch))
    first = time.perf_counter() - t0
    steady, _ = timeit(tr, params, batch, warmup=1, iters=5)

    csv_row("truncate_first_call", first * 1e6, f"traces={tr.n_traces}")
    csv_row("truncate_cached_call", steady * 1e6,
            f"speedup={first / steady:.1f}x")
    assert tr.n_traces == 1, "cached wrapper must not re-trace"
    return first / steady


def bench_autosearch(budget: int = 48, threshold: float = 5e-3):
    cfg, model, params = bench_model()
    batch = bench_batch(cfg)

    t0 = time.perf_counter()
    result = search.autosearch(
        model.loss, (params, batch), search.loss_degradation, budget,
        threshold=threshold)
    wall = time.perf_counter() - t0

    csv_row("autosearch_wall_us", wall * 1e6,
            f"evals={result.evals_used}/{budget}"
            f";converged={result.converged}")
    rep = profile_counts(model.loss, result.policy())(params, batch)
    est = estimate_speedup(rep)
    csv_row("autosearch_truncated_flops_pct",
            rep.truncated_fraction * 100,
            f"predicted_speedup={est.predicted:.2f}x")
    print("\n" + result.table())
    return result


def run():
    print("name,us_per_call,derived")
    ratio = bench_trace_cache()
    result = bench_autosearch()
    print(f"\ntrace-cache speedup {ratio:.1f}x; "
          f"search used {result.evals_used} evals "
          f"({'converged' if result.converged else 'NOT converged'})")


if __name__ == "__main__":
    run()
