"""Search-convergence benchmark: trace-cache hit rate, zero-recompile policy
sweeps, and autosearch cost.

Three numbers the tentpoles promise, measured on the ~10M-param bench model:

  1. trace caching — first call of a cached ``truncate`` wrapper (trace +
     jaxpr walk + compile) vs its steady-state call (executable-cache hit).
     The ratio is the payoff of caching the transformed computation.
  2. policy sweep — evaluating a ladder of candidate policies through the
     runtime-parameterized ``truncate_sweep`` executable (one compile for
     ALL candidates) vs the per-policy ``truncate`` path (one trace + one
     compile per candidate). The per-candidate ratio is the payoff of
     making formats runtime values.
  3. search convergence — evaluations, wall time, and XLA compilations
     ``autosearch`` needs to land a per-scope assignment under the error
     threshold (compiles stay O(1) regardless of budget).

    PYTHONPATH=src python -m benchmarks.search_convergence
"""
import time

import jax

from benchmarks.common import bench_model, bench_batch, csv_row, timeit
from repro import search
from repro.core import truncate, truncate_sweep, TruncationPolicy, \
    profile_counts, estimate_speedup


def bench_trace_cache():
    cfg, model, params = bench_model()
    batch = bench_batch(cfg)
    pol = TruncationPolicy.everywhere("e5m7")
    tr = truncate(model.loss, pol)

    t0 = time.perf_counter()
    jax.block_until_ready(tr(params, batch))
    first = time.perf_counter() - t0
    steady, _ = timeit(tr, params, batch, warmup=1, iters=5)

    csv_row("truncate_first_call", first * 1e6, f"traces={tr.n_traces}")
    csv_row("truncate_cached_call", steady * 1e6,
            f"speedup={first / steady:.1f}x")
    assert tr.n_traces == 1, "cached wrapper must not re-trace"
    return first / steady


def bench_policy_sweep(n_candidates: int = 6):
    """A width-ladder sweep: per-policy retrace/recompile (`truncate`) vs one
    runtime-parameterized executable (`truncate_sweep`)."""
    cfg, model, params = bench_model()
    batch = bench_batch(cfg)
    ladder = [TruncationPolicy.everywhere(f"e8m{m}")
              for m in (15, 10, 7, 5, 3, 2)[:n_candidates]]

    t0 = time.perf_counter()
    for pol in ladder:
        jax.block_until_ready(truncate(model.loss, pol)(params, batch))
    per_policy = (time.perf_counter() - t0) / len(ladder)

    sw = truncate_sweep(model.loss, TruncationPolicy.everywhere("e8m2"))
    t0 = time.perf_counter()
    handle = sw(params, batch)
    jax.block_until_ready(handle.batch(handle.tables(ladder)))
    sweep_total = time.perf_counter() - t0
    per_table = sweep_total / len(ladder)
    # steady state: new candidate ladders reuse the compiled executable
    t0 = time.perf_counter()
    jax.block_until_ready(handle.batch(handle.tables(ladder[::-1])))
    steady_per_table = (time.perf_counter() - t0) / len(ladder)

    csv_row("policy_sweep_per_candidate_static", per_policy * 1e6,
            f"candidates={len(ladder)};compiles={len(ladder)}")
    csv_row("policy_sweep_per_candidate_table", per_table * 1e6,
            f"candidates={len(ladder)};compiles=1"
            f";speedup={per_policy / per_table:.1f}x"
            f";sites={handle.num_sites}")
    csv_row("policy_sweep_per_candidate_steady", steady_per_table * 1e6,
            f"speedup={per_policy / steady_per_table:.1f}x")
    # the first-call ratio as a gated dimensionless row: even paying its one
    # trace + compile, the table sweep must not lose to the per-policy
    # static path (it used to, 0.9x, when each site's format row was
    # assembled with a scatter — ~276 scatters dominated the sweep trace)
    csv_row("policy_sweep_first_call_speedup", per_policy / per_table,
            f"static_us={per_policy * 1e6:.1f};table_us={per_table * 1e6:.1f}")
    assert sw.n_traces == 1, "sweep wrapper must walk the jaxpr once"
    return per_policy / per_table


def bench_autosearch(budget: int = 48, threshold: float = 5e-3):
    cfg, model, params = bench_model()
    batch = bench_batch(cfg)

    t0 = time.perf_counter()
    result = search.autosearch(
        model.loss, (params, batch), search.loss_degradation, budget,
        threshold=threshold)
    wall = time.perf_counter() - t0

    csv_row("autosearch_wall_us", wall * 1e6,
            f"evals={result.evals_used}/{budget}"
            f";compiles={result.n_compiles}"
            f";sites={result.n_sites}"
            f";converged={result.converged}")
    rep = profile_counts(model.loss, result.policy())(params, batch)
    est = estimate_speedup(rep)
    csv_row("autosearch_truncated_flops_pct",
            rep.truncated_fraction * 100,
            f"predicted_speedup={est.predicted:.2f}x")
    print("\n" + result.table())
    return result


def bench_sharded_sweep(n_candidates: int = 8):
    """Mesh-parallel ladder throughput: the same K-candidate table batch
    evaluated through probe meshes of growing device count (the leading
    candidate axis sharded, inputs replicated). Reports per-device-count
    candidates/s — the payoff of distributing probe evaluations that the
    single-device zero-recompile sweep leaves on the table. On a
    single-device host only the ndev=1 row is emitted; run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` for the curve."""
    from repro.launch.mesh import make_probe_mesh

    cfg, model, params = bench_model()
    batch = bench_batch(cfg)
    ladder = [TruncationPolicy.everywhere(f"e8m{m}")
              for m in (15, 10, 7, 5, 3, 2, 23, 11)[:n_candidates]]
    site = TruncationPolicy.everywhere("e8m2")

    total = len(jax.devices())
    counts = [n for n in (1, 2, 4, 8) if n <= total and total % n == 0]
    base_rate = None
    for ndev in counts:
        mesh = make_probe_mesh(ndev)
        sw = truncate_sweep(model.loss, site, mesh=mesh)
        handle = sw(params, batch)
        tables = handle.tables(ladder)
        t, _ = timeit(lambda: handle.batch(tables), warmup=1, iters=3)
        rate = len(ladder) / t
        base_rate = base_rate or rate
        csv_row(f"sharded_sweep_dev{ndev}", t / len(ladder) * 1e6,
                f"ndev={ndev};candidates={len(ladder)}"
                f";cands_per_s={rate:.1f}"
                f";scaling={rate / base_rate:.2f}x")
    return counts


def run_sharded():
    print("name,us_per_call,derived")
    counts = bench_sharded_sweep()
    print(f"\nsharded sweep measured at device counts {counts} "
          f"(of {len(jax.devices())} visible)")


def run():
    print("name,us_per_call,derived")
    ratio = bench_trace_cache()
    sweep_ratio = bench_policy_sweep()
    result = bench_autosearch()
    print(f"\ntrace-cache speedup {ratio:.1f}x; "
          f"table-sweep speedup {sweep_ratio:.1f}x/candidate; "
          f"search used {result.evals_used} evals, "
          f"{result.n_compiles} compile(s) "
          f"({'converged' if result.converged else 'NOT converged'})")


if __name__ == "__main__":
    run()
