# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV blocks (plus per-benchmark headers) and writes a machine-readable
# ``BENCH_<name>.json`` artifact per benchmark (us_per_call + derived
# metrics + wall time) into $BENCH_OUT (default: cwd) so the perf
# trajectory is tracked across PRs. ``python -m benchmarks.run``.
#
# A raising benchmark is recorded, the remaining benchmarks still run (their
# artifacts stay comparable), no artifact is written for the failed one, and
# the process exits nonzero — so a CI bench job can never upload partial
# artifacts and still pass.
from __future__ import annotations

import json
import os
import platform
import sys
import time
import traceback


def _write_artifact(out_dir: str, name: str, wall_s: float, rows) -> str:
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{name}.json")
    payload = {
        "benchmark": name,
        "wall_s": round(wall_s, 3),
        "rows": rows,
        "meta": {
            "python": platform.python_version(),
            "platform": platform.platform(),
        },
    }
    try:
        import jax
        payload["meta"]["jax"] = jax.__version__
        payload["meta"]["backend"] = jax.default_backend()
    except Exception:
        pass
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def run_benches(benches, only: str | None, out_dir: str) -> list:
    """Run the selected benchmarks, writing one artifact per SUCCESS.
    Returns the list of (name, exception) failures instead of dying on the
    first one, so a broken benchmark can't silently skip the rest while the
    survivors' artifacts still upload."""
    from benchmarks import common

    failures = []
    for name, fn in benches:
        if only and only not in name:
            continue
        print(f"\n===== {name} =====", flush=True)
        common.reset_results()
        t0 = time.time()
        try:
            fn()
        except Exception as e:  # noqa: BLE001 — report and keep going
            traceback.print_exc()
            print(f"# {name} FAILED after {time.time() - t0:.1f}s: {e!r}",
                  flush=True)
            failures.append((name, e))
            continue
        wall = time.time() - t0
        path = _write_artifact(out_dir, name, wall, list(common.RESULTS))
        print(f"# {name} done in {wall:.1f}s -> {path}", flush=True)
    return failures


def main() -> int:
    from benchmarks import (
        fig7_truncation_sweep, table2_memmode, table3_overhead,
        fig8_speedup_model, kernels_micro, perf_fp8_dot, roofline_table,
        search_convergence, apps_e2e, instability_profile,
        serving_throughput, static_prune,
    )
    benches = [
        ("apps_e2e", apps_e2e.run),
        ("instability_profile", instability_profile.run),
        ("serving_throughput", serving_throughput.run),
        ("static_prune", static_prune.run),
        ("fig7_truncation_sweep", fig7_truncation_sweep.run),
        ("table2_memmode", table2_memmode.run),
        ("table3_overhead", table3_overhead.run),
        ("fig8_speedup_model", fig8_speedup_model.run),
        ("kernels_micro", kernels_micro.run),
        ("perf_fp8_dot", perf_fp8_dot.run),
        ("roofline_table", roofline_table.run),
        ("search_convergence", search_convergence.run),
        ("search_sharded", search_convergence.run_sharded),
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    out_dir = os.environ.get("BENCH_OUT", ".")
    failures = run_benches(benches, only, out_dir)
    if failures:
        names = ", ".join(n for n, _ in failures)
        print(f"\n# {len(failures)} benchmark(s) FAILED: {names}",
              file=sys.stderr, flush=True)
        return 1
    return 0


if __name__ == '__main__':
    sys.exit(main())
