# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV blocks (plus per-benchmark headers). ``python -m benchmarks.run``.
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (
        fig7_truncation_sweep, table2_memmode, table3_overhead,
        fig8_speedup_model, kernels_micro, perf_fp8_dot, roofline_table,
        search_convergence,
    )
    benches = [
        ("fig7_truncation_sweep", fig7_truncation_sweep.run),
        ("table2_memmode", table2_memmode.run),
        ("table3_overhead", table3_overhead.run),
        ("fig8_speedup_model", fig8_speedup_model.run),
        ("kernels_micro", kernels_micro.run),
        ("perf_fp8_dot", perf_fp8_dot.run),
        ("roofline_table", roofline_table.run),
        ("search_convergence", search_convergence.run),
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    for name, fn in benches:
        if only and only not in name:
            continue
        print(f"\n===== {name} =====", flush=True)
        t0 = time.time()
        fn()
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)


if __name__ == '__main__':
    main()
