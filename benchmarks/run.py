# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV blocks (plus per-benchmark headers) and writes a machine-readable
# ``BENCH_<name>.json`` artifact per benchmark (us_per_call + derived
# metrics + wall time) into $BENCH_OUT (default: cwd) so the perf
# trajectory is tracked across PRs. ``python -m benchmarks.run``.
from __future__ import annotations

import json
import os
import platform
import sys
import time


def _write_artifact(out_dir: str, name: str, wall_s: float, rows) -> str:
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{name}.json")
    payload = {
        "benchmark": name,
        "wall_s": round(wall_s, 3),
        "rows": rows,
        "meta": {
            "python": platform.python_version(),
            "platform": platform.platform(),
        },
    }
    try:
        import jax
        payload["meta"]["jax"] = jax.__version__
        payload["meta"]["backend"] = jax.default_backend()
    except Exception:
        pass
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def main() -> None:
    from benchmarks import (
        common, fig7_truncation_sweep, table2_memmode, table3_overhead,
        fig8_speedup_model, kernels_micro, perf_fp8_dot, roofline_table,
        search_convergence,
    )
    benches = [
        ("fig7_truncation_sweep", fig7_truncation_sweep.run),
        ("table2_memmode", table2_memmode.run),
        ("table3_overhead", table3_overhead.run),
        ("fig8_speedup_model", fig8_speedup_model.run),
        ("kernels_micro", kernels_micro.run),
        ("perf_fp8_dot", perf_fp8_dot.run),
        ("roofline_table", roofline_table.run),
        ("search_convergence", search_convergence.run),
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    out_dir = os.environ.get("BENCH_OUT", ".")
    for name, fn in benches:
        if only and only not in name:
            continue
        print(f"\n===== {name} =====", flush=True)
        common.reset_results()
        t0 = time.time()
        fn()
        wall = time.time() - t0
        path = _write_artifact(out_dir, name, wall, list(common.RESULTS))
        print(f"# {name} done in {wall:.1f}s -> {path}", flush=True)


if __name__ == '__main__':
    main()
