"""Policy-drift gate: re-search bench_model and diff against the committed
policy artifact.

    PYTHONPATH=src python -m benchmarks.policy_drift            # --check
    PYTHONPATH=src python -m benchmarks.policy_drift --refresh

The committed artifact (``artifacts/bench_model.json``, the bare
``PolicyArtifact`` JSON form) is the *deployed* precision policy for the
bench model: serving loads it by name, training hot-swaps it, checkpoints
record its digest. This gate runs the same autosearch CI always ran
(budget=128, threshold=5e-3 — the @slow acceptance test's parameters) and
fails when the fresh per-scope ASSIGNMENTS drift from the committed ones,
printing a side-by-side diff. Timing-like provenance (wall clock, history)
is deliberately not gated — only what changes numerics in deployment:
which scopes are truncated, to how many mantissa bits, and what is
excluded.

Drift is not automatically a bug — an interpreter or search change may
legitimately move an assignment — but it must be *deliberate*: refresh and
commit the artifact in the same PR so reviewers see the policy change
side by side with the code change that caused it:

    PYTHONPATH=src python -m benchmarks.policy_drift --refresh
    git add artifacts/bench_model.json

Exit status: 0 = no drift, 1 = drift or missing/unreadable artifact,
2 = usage error.
"""
from __future__ import annotations

import argparse
import sys

COMMITTED = "artifacts/bench_model.json"
BUDGET, THRESHOLD = 128, 5e-3   # match tests/test_search.py @slow acceptance


def fresh_artifact():
    """Run the gate's autosearch: bench_model under loss degradation."""
    from benchmarks.common import bench_model, bench_batch
    from repro import search

    cfg, model, params = bench_model()
    batch = bench_batch(cfg)
    res = search.autosearch(model.loss, (params, batch),
                            search.loss_degradation, BUDGET,
                            threshold=THRESHOLD)
    return res.to_artifact("bench_model")


def _model_scope_paths():
    """The bench model's current enumerable scope frontier — what the
    committed artifact's assignments are linted for drift against."""
    import jax

    from benchmarks.common import bench_model, bench_batch
    from repro.search.scopes import discover_scopes

    cfg, model, params = bench_model()
    closed = jax.make_jaxpr(model.loss)(params, bench_batch(cfg))
    return [s.path for s in discover_scopes(closed)]


def _assignment_rows(artifact):
    """{scope: (man_bits_or_None, excluded)} — the gated surface."""
    return {path: (None if row.man_bits is None else int(row.man_bits),
                   bool(row.excluded))
            for path, row in artifact.assignments.items()}


def _fmt(entry):
    if entry is None:
        return "--"
    man, excl = entry
    if excl:
        return "excluded"
    return "fp32" if man is None or man >= 23 else f"m={man}"


def diff_assignments(committed, fresh, log=print):
    """Side-by-side diff of per-scope assignments; returns drift lines."""
    base, new = _assignment_rows(committed), _assignment_rows(fresh)
    scopes = sorted(set(base) | set(new))
    width = max([len(s) for s in scopes] + [len("scope")])
    log(f"  {'scope':<{width}}  {'committed':>10}  {'fresh':>10}")
    drift = []
    for s in scopes:
        b, n = base.get(s), new.get(s)
        bad = b != n
        log(f"  {s:<{width}}  {_fmt(b):>10}  {_fmt(n):>10}"
            f"{'  <-- DRIFT' if bad else ''}")
        if bad:
            drift.append(f"{s}: {_fmt(b)} -> {_fmt(n)}")
    return drift


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--committed", default=COMMITTED,
                    help=f"committed artifact JSON (default {COMMITTED})")
    ap.add_argument("--refresh", action="store_true",
                    help="re-search and overwrite the committed artifact "
                         "instead of gating against it")
    args = ap.parse_args(argv)

    from repro.artifacts import load_artifact_file, save_artifact_file
    from repro.artifacts.artifact import ArtifactSchemaError

    # In --check mode the committed artifact is validated BEFORE the
    # expensive autosearch: a missing or schema-newer file must fail in
    # milliseconds with the refresh command, not after minutes of search
    # (and never with a raw traceback).
    committed = None
    if not args.refresh:
        try:
            committed = load_artifact_file(args.committed)
        except FileNotFoundError:
            print(f"no committed artifact at {args.committed}; run\n"
                  f"  PYTHONPATH=src python -m benchmarks.policy_drift"
                  f" --refresh\n"
                  f"and commit the result", file=sys.stderr)
            return 1
        except ArtifactSchemaError as e:
            print(f"committed artifact {args.committed} is not readable by "
                  f"this build:\n  {e}\n"
                  f"if the schema bump is intended, refresh + commit:\n"
                  f"  PYTHONPATH=src python -m benchmarks.policy_drift"
                  f" --refresh", file=sys.stderr)
            return 1

    if committed is not None:
        # lint the committed artifact before the expensive search: a policy
        # that cannot be what deployment thinks it is (dead/shadowed rules,
        # scopes that drifted off the current model) fails in seconds
        from repro.analysis.lint import lint_artifact
        findings = lint_artifact(committed, scopes=_model_scope_paths())
        for f in findings:
            print(f"  lint: {f.render()}",
                  file=sys.stderr if f.level == "error" else sys.stdout)
        if any(f.level == "error" for f in findings):
            print(f"policy-drift FAILED: committed artifact "
                  f"{args.committed} fails lint; refresh + commit:\n"
                  f"  PYTHONPATH=src python -m benchmarks.policy_drift"
                  f" --refresh", file=sys.stderr)
            return 1

    print(f"policy-drift: autosearch bench_model "
          f"(budget={BUDGET}, threshold={THRESHOLD})", flush=True)
    fresh = fresh_artifact()
    prov = fresh.provenance
    print(f"  searched {prov.get('n_sites', '?')} sites, "
          f"{prov.get('evals_used', '?')} evals, "
          f"final_error={prov.get('final_error', float('nan')):.2e}, "
          f"digest {fresh.digest[:12]}", flush=True)

    if args.refresh:
        save_artifact_file(fresh, args.committed)
        print(f"refreshed {args.committed} — commit it alongside the code "
              f"change that moved the policy")
        return 0

    drift = diff_assignments(committed, fresh)
    if drift:
        print(f"\npolicy-drift FAILED ({len(drift)} scope(s) moved):",
              file=sys.stderr)
        for d in drift:
            print(f"  - {d}", file=sys.stderr)
        print("if the new policy is intended, refresh + commit:\n"
              "  PYTHONPATH=src python -m benchmarks.policy_drift --refresh",
              file=sys.stderr)
        return 1
    print(f"policy-drift passed: {len(_assignment_rows(fresh))} scopes "
          f"match {args.committed}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
