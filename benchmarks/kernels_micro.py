"""Microbenchmarks for the three Pallas kernels (jnp/XLA path on CPU; the
kernels themselves are validated in interpret mode by tests). Reports
us/call + achieved GB/s or GFLOP/s of the XLA reference path so §Perf has a
host-side sanity line per kernel contract."""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels.quantize_em.ops import quantize, quantize_dynamic, format_row
from repro.core.formats import FPFormat
from repro.models.attention import flash_attention
from repro.kernels.flash_attention.ops import flash_attention as fa_fused
from repro.kernels.rwkv6.ops import wkv6
from repro.kernels.rwkv6.ref import wkv6_ref
from benchmarks.common import timeit, timeit_pair, csv_row


def run():
    print("name,us_per_call,derived")
    r = np.random.RandomState(0)

    # quantizer: elementwise bit math
    x = jnp.asarray(r.randn(4 * 1024 * 1024), jnp.float32)
    fn = jax.jit(lambda v: quantize(v, FPFormat(5, 7), impl="ref"))
    t, _ = timeit(fn, x)
    gbs = x.size * 8 / t / 1e9
    csv_row("quantize_e5m7_4M", t * 1e6, f"{gbs:.1f}GB/s")

    # flash attention (chunked XLA path)
    q = jnp.asarray(r.randn(1, 8, 1024, 64), jnp.float32)
    k = jnp.asarray(r.randn(1, 4, 1024, 64), jnp.float32)
    v = jnp.asarray(r.randn(1, 4, 1024, 64), jnp.float32)
    fa = jax.jit(lambda a, b, c: flash_attention(a, b, c, causal=True))
    t, _ = timeit(fa, q, k, v)
    flops = 4 * 1 * 8 * 1024 * 1024 * 64 / 2  # causal ~ half
    csv_row("flash_attn_B1H8S1024D64", t * 1e6, f"{flops / t / 1e9:.1f}GFLOP/s")

    # wkv6 recurrence
    B, H, S, hd = 1, 8, 512, 64
    args = [jnp.asarray(r.randn(B, H, S, hd), jnp.float32) for _ in range(3)]
    w = jnp.asarray(1 / (1 + np.exp(-r.randn(B, H, S, hd))), jnp.float32)
    u = jnp.asarray(r.randn(H, hd) * 0.1, jnp.float32)
    s0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    wk = jax.jit(lambda a, b, c, d: wkv6_ref(a, b, c, d, u, s0)[0])
    t, _ = timeit(wk, args[0], args[1], args[2], w)
    flops = B * H * S * hd * hd * 4
    csv_row("wkv6_B1H8S512hd64", t * 1e6, f"{flops / t / 1e9:.1f}GFLOP/s")

    # ---- fused quantize epilogue vs kernel + separate quantize dispatch ----
    # The interpreter routes a truncation site's format row into the
    # producing kernel's epilogue instead of appending a standalone quantize
    # (kernels/fused.py). Fused: one executable carrying the epilogue.
    # Unfused: the kernel executable, then a second dispatch quantizing its
    # output — an extra launch plus a full round-trip of the output array.
    # The ratio is dimensionless, so it gates cross-machine (compare.py).
    row = jnp.asarray(format_row("e4m3"), jnp.int32)
    qz = jax.jit(lambda y, fr: quantize_dynamic(y, fr, impl="ref"))

    fuse_fa = jax.jit(
        lambda a, b, c, fr: fa_fused(a, b, c, causal=True, out_fmt=fr))
    base_fa = jax.jit(lambda a, b, c: fa_fused(a, b, c, causal=True))
    t_f, t_u = timeit_pair(lambda: fuse_fa(q, k, v, row),
                           lambda: qz(base_fa(q, k, v), row))
    csv_row("flash_attn_fused_speedup", t_u / t_f,
            f"fused_us={t_f * 1e6:.1f};unfused_us={t_u * 1e6:.1f}")

    fuse_wk = jax.jit(
        lambda a, b, c, d, fr: wkv6(a, b, c, d, u, s0, out_fmt=fr)[0])
    base_wk = jax.jit(lambda a, b, c, d: wkv6(a, b, c, d, u, s0)[0])
    t_f, t_u = timeit_pair(
        lambda: fuse_wk(args[0], args[1], args[2], w, row),
        lambda: qz(base_wk(args[0], args[1], args[2], w), row))
    csv_row("wkv6_fused_speedup", t_u / t_f,
            f"fused_us={t_f * 1e6:.1f};unfused_us={t_u * 1e6:.1f}")


def main():
    run()


if __name__ == "__main__":
    main()
