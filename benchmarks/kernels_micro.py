"""Microbenchmarks for the three Pallas kernels (jnp/XLA path on CPU; the
kernels themselves are validated in interpret mode by tests). Reports
us/call + achieved GB/s or GFLOP/s of the XLA reference path so §Perf has a
host-side sanity line per kernel contract."""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels.quantize_em.ops import quantize
from repro.core.formats import FPFormat
from repro.models.attention import flash_attention
from repro.kernels.rwkv6.ref import wkv6_ref
from benchmarks.common import timeit, csv_row


def run():
    print("name,us_per_call,derived")
    r = np.random.RandomState(0)

    # quantizer: elementwise bit math
    x = jnp.asarray(r.randn(4 * 1024 * 1024), jnp.float32)
    fn = jax.jit(lambda v: quantize(v, FPFormat(5, 7), impl="ref"))
    t, _ = timeit(fn, x)
    gbs = x.size * 8 / t / 1e9
    csv_row("quantize_e5m7_4M", t * 1e6, f"{gbs:.1f}GB/s")

    # flash attention (chunked XLA path)
    q = jnp.asarray(r.randn(1, 8, 1024, 64), jnp.float32)
    k = jnp.asarray(r.randn(1, 4, 1024, 64), jnp.float32)
    v = jnp.asarray(r.randn(1, 4, 1024, 64), jnp.float32)
    fa = jax.jit(lambda a, b, c: flash_attention(a, b, c, causal=True))
    t, _ = timeit(fa, q, k, v)
    flops = 4 * 1 * 8 * 1024 * 1024 * 64 / 2  # causal ~ half
    csv_row("flash_attn_B1H8S1024D64", t * 1e6, f"{flops / t / 1e9:.1f}GFLOP/s")

    # wkv6 recurrence
    B, H, S, hd = 1, 8, 512, 64
    args = [jnp.asarray(r.randn(B, H, S, hd), jnp.float32) for _ in range(3)]
    w = jnp.asarray(1 / (1 + np.exp(-r.randn(B, H, S, hd))), jnp.float32)
    u = jnp.asarray(r.randn(H, hd) * 0.1, jnp.float32)
    s0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    wk = jax.jit(lambda a, b, c, d: wkv6_ref(a, b, c, d, u, s0)[0])
    t, _ = timeit(wk, args[0], args[1], args[2], w)
    flops = B * H * S * hd * hd * 4
    csv_row("wkv6_B1H8S512hd64", t * 1e6, f"{flops / t / 1e9:.1f}GFLOP/s")


def main():
    run()


if __name__ == "__main__":
    main()
