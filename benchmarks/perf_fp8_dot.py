"""Beyond-paper perf experiment: fp8 (e4m3) matmul-input quantization.

The paper's co-design model asks "what if these ops ran on a cheaper FPU" —
on TPU the cheaper unit exists (fp8 MXU at 2x bf16 peak). We (a) apply the
op-mode rule `quantize_dot_inputs` to every layer matmul of the
deepseek-coder-33b train step, splitting its FLOPs by precision with the
static counters, (b) recompute the roofline compute term with per-precision
peaks, (c) *measure* the native-fp8-storage dot (kernels/fp8_dot.py)
against the emulated one and reconcile measured vs modeled
(core.speedup.reconcile), and (d) measure the numerical cost on the smoke
config. This is the paper's technique driving OUR roofline — profile
first, then claim the hardware win (EXPERIMENTS.md §Perf pair 3).

Rows land in BENCH_perf_fp8_dot.json via csv_row (an earlier version
printed a bare ``metric,value`` CSV that never reached the artifact
recorder, so the committed JSON had no rows and nothing here could gate).
Dimensionless rows (fractions, speedups, the measured/modeled gap) carry
the value in ``us_per_call`` like the other ratio rows the gate consumes.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import get_config, SHAPES
from repro.core import (
    truncate, profile_counts, TruncationPolicy, TruncationRule, E4M3,
)
from repro.core.speedup import (
    tpu_relative_throughput, reconcile, PEAK_BF16_FLOPS,
)
from repro.core.formats import parse_format
from repro.kernels.fp8_dot import fp8_dot_general, quantize_dot_operand
from repro.models import Model
from benchmarks.common import timeit_pair, csv_row

CHIPS = 256
DOT_N = 2048  # native-vs-emulated microbench: N^3 matmul


def fp8_policy():
    rule = TruncationRule(fmt=E4M3, scope="*layer*",
                          ops=("dot_general",), quantize_dot_inputs=True)
    return TruncationPolicy(rules=(rule,))


def run():
    print("name,us_per_call,derived")
    # ---- (a)+(b): FLOP split and compute term on the FULL 33B train step
    cfg = get_config("deepseek-coder-33b")
    model = Model(cfg)
    shape = SHAPES["train_4k"]
    from repro.launch import specs as sp
    from repro.train.trainer import TrainConfig, make_train_step
    step_fn = make_train_step(model, TrainConfig(grad_accum=cfg.grad_accum))
    params = sp.params_specs(model, None)
    opt = sp.opt_state_specs(model, None)
    batch = sp.input_specs(cfg, shape, None)
    rep = profile_counts(
        lambda p, o, b: step_fn(p, o, b, jnp.int32(0)),
        fp8_policy())(params, opt, batch)

    t_base = rep.total_flops / (CHIPS * PEAK_BF16_FLOPS)
    t_mix = sum(
        fl / (CHIPS * PEAK_BF16_FLOPS *
              tpu_relative_throughput(parse_format(k) if k != "full"
                                      else parse_format("bf16")))
        for k, fl in rep.flops_by_fmt.items())
    modeled = t_base / t_mix
    csv_row("fp8_flop_fraction", rep.truncated_fraction,
            f"T_compute_bf16_s={t_base:.3f};T_compute_fp8mix_s={t_mix:.3f}")
    csv_row("fp8_compute_term_speedup", modeled,
            "modeled=roofline compute term, fp8 MXU at 2x bf16 peak")

    # ---- (c): measured native-fp8-storage dot vs emulated-rounding dot.
    # Both sides pre-round operands with the same bit-exact quantizer; the
    # native side then *stores* them as float8_e4m3fn and accumulates in
    # f32 — the execution path a policy found by the search actually runs.
    # The ratio is dimensionless so it gates cross-machine; the reconcile
    # row records what fraction of the modeled win this backend delivers
    # (CPU has no fp8 matrix unit, so the gap is the honest number the
    # modeled 1.28x must be read against until a TPU run refreshes it).
    r = np.random.RandomState(0)
    a = jnp.asarray(r.randn(DOT_N, DOT_N), jnp.float32)
    b = jnp.asarray(r.randn(DOT_N, DOT_N), jnp.float32)
    dn = (((1,), (0,)), ((), ()))
    # operands are *arguments*, not closure constants — a zero-arg jit
    # constant-folds the whole contraction and times a memcpy
    native = jax.jit(lambda x, y: fp8_dot_general(x, y, dn))
    emulated = jax.jit(lambda x, y: lax.dot_general(
        quantize_dot_operand(x), quantize_dot_operand(y), dn,
        preferred_element_type=jnp.float32))
    t_nat, t_emu = timeit_pair(native, emulated, a, b, iters=6)
    measured = t_emu / t_nat
    csv_row("fp8_dot_emulated_us", t_emu * 1e6, f"n={DOT_N}")
    csv_row("fp8_dot_native_us", t_nat * 1e6, f"n={DOT_N}")
    csv_row("fp8_dot_native_speedup", measured,
            f"native_us={t_nat * 1e6:.1f};emulated_us={t_emu * 1e6:.1f}")
    rec = reconcile(measured, modeled)
    csv_row("fp8_dot_measured_vs_modeled", rec.gap,
            f"measured={rec.measured:.3f}x;modeled={rec.modeled:.3f}x;"
            f"backend={jax.default_backend()}")

    # ---- (d): numerical cost, smoke config logit L1 + short training
    scfg = get_config("deepseek-coder-33b", "smoke")
    smodel = Model(scfg)
    sp_params = smodel.init(jax.random.PRNGKey(0))
    toks = r.randint(0, scfg.vocab, (4, 65))
    sbatch = {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
              "labels": jnp.asarray(toks[:, 1:], jnp.int32)}
    full = smodel.forward(sp_params, sbatch)
    lossy = truncate(smodel.forward, fp8_policy(), impl="ref")(
        sp_params, sbatch)
    l1 = float(jnp.mean(jnp.abs(full - lossy)))
    rel = l1 / float(jnp.mean(jnp.abs(full)))
    csv_row("fp8_logit_rel_err", rel, f"logit_l1={l1:.6e}")


def main():
    run()


if __name__ == "__main__":
    main()
