"""Beyond-paper perf experiment: fp8 (e4m3) matmul-input quantization.

The paper's co-design model asks "what if these ops ran on a cheaper FPU" —
on TPU the cheaper unit exists (fp8 MXU at 2x bf16 peak). We (a) apply the
op-mode rule `quantize_dot_inputs` to every layer matmul of the
deepseek-coder-33b train step, splitting its FLOPs by precision with the
static counters, (b) recompute the roofline compute term with per-precision
peaks, and (c) measure the numerical cost on the smoke config. This is the
paper's technique driving OUR roofline — profile first, then claim the
hardware win (EXPERIMENTS.md §Perf pair 3).

Output: CSV  metric,value
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, SHAPES
from repro.core import (
    truncate, profile_counts, TruncationPolicy, TruncationRule, E4M3,
)
from repro.core.speedup import tpu_relative_throughput, PEAK_BF16_FLOPS
from repro.core.formats import parse_format
from repro.models import Model

CHIPS = 256


def fp8_policy():
    rule = TruncationRule(fmt=E4M3, scope="*layer*",
                          ops=("dot_general",), quantize_dot_inputs=True)
    return TruncationPolicy(rules=(rule,))


def run():
    print("metric,value")
    # ---- (a)+(b): FLOP split and compute term on the FULL 33B train step
    cfg = get_config("deepseek-coder-33b")
    model = Model(cfg)
    shape = SHAPES["train_4k"]
    from repro.launch import specs as sp
    from repro.train.trainer import TrainConfig, make_train_step
    step_fn = make_train_step(model, TrainConfig(grad_accum=cfg.grad_accum))
    params = sp.params_specs(model, None)
    opt = sp.opt_state_specs(model, None)
    batch = sp.input_specs(cfg, shape, None)
    rep = profile_counts(
        lambda p, o, b: step_fn(p, o, b, jnp.int32(0)),
        fp8_policy())(params, opt, batch)

    t_base = rep.total_flops / (CHIPS * PEAK_BF16_FLOPS)
    t_mix = sum(
        fl / (CHIPS * PEAK_BF16_FLOPS *
              tpu_relative_throughput(parse_format(k) if k != "full"
                                      else parse_format("bf16")))
        for k, fl in rep.flops_by_fmt.items())
    print(f"fp8_flop_fraction,{rep.truncated_fraction:.4f}")
    print(f"T_compute_bf16_s,{t_base:.3f}")
    print(f"T_compute_fp8mix_s,{t_mix:.3f}")
    print(f"compute_term_speedup,{t_base / t_mix:.3f}")

    # ---- (c): numerical cost, smoke config logit L1 + short training
    scfg = get_config("deepseek-coder-33b", "smoke")
    smodel = Model(scfg)
    sp_params = smodel.init(jax.random.PRNGKey(0))
    r = np.random.RandomState(0)
    toks = r.randint(0, scfg.vocab, (4, 65))
    sbatch = {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
              "labels": jnp.asarray(toks[:, 1:], jnp.int32)}
    full = smodel.forward(sp_params, sbatch)
    lossy = truncate(smodel.forward, fp8_policy(), impl="ref")(
        sp_params, sbatch)
    l1 = float(jnp.mean(jnp.abs(full - lossy)))
    rel = l1 / float(jnp.mean(jnp.abs(full)))
    print(f"logit_l1,{l1:.6e}")
    print(f"logit_rel_err,{rel:.6e}")


def main():
    run()


if __name__ == "__main__":
    main()
