"""Bench regression gate: compare fresh ``BENCH_<name>.json`` artifacts
against committed baselines and fail on significant regressions.

    python -m benchmarks.compare benchmarks/baselines bench-artifacts \
        [--threshold 0.25]

For every baseline artifact, the matching fresh artifact must exist and
every GATED row (time-like metrics where lower is better, plus
throughput-like metrics where higher is better) must stay within
``threshold`` (default 25%) of the baseline. Non-gated rows — counts,
percentages, anything machine-sensitive we haven't opted in — are reported
but never fail the gate. Exit status: 0 = pass, 1 = regression or missing
artifact, 2 = usage error.

**Machine normalization.** Baselines are committed from one machine and CI
runs on another, so absolute wall-clock comparisons would gate on hardware,
not code. Every gated timing is therefore divided by the common machine
factor measured on the CALIBRATION row (a pure-bandwidth kernel no search/
interpreter change touches): a uniformly 2x-slower runner moves the
calibration row too and passes, while a 2x regression in a gated code path
leaves the calibration row alone and fails. The calibration row itself is
gated un-normalized with a deliberately loose ``CAL_THRESHOLD`` so only a
catastrophic kernel regression (not runner variance) trips it.

Baselines are refreshed by running the bench job and committing the JSON:
``BENCH_OUT=benchmarks/baselines python -m benchmarks.run <name>``.
"""
from __future__ import annotations

import argparse
import glob
import json
import math
import os
import sys

# Explicit opt-in per benchmark: row name -> direction. "lower" gates
# fresh > baseline * (1 + threshold); "higher" gates
# fresh < baseline / (1 + threshold). Rows absent from an artifact are
# skipped with a note (benchmarks evolve), unknown rows are ignored.
GATED = {
    "apps_e2e": {
        # mini-app profiling trajectory: the steady-state interpreter-path
        # solver runs are the hot e2e code this repo owns. Plain `<app>_run`
        # rows stay ungated (they time XLA's solver codegen) and so do the
        # `<app>_autosearch` walls (dominated by the one-off XLA compile of
        # the batched executable, i.e. compile speed, not dispatch cost).
        "sod_truncated_run": "lower",
        "poisson_truncated_run": "lower",
    },
    "search_convergence": {
        "truncate_cached_call": "lower",
        "policy_sweep_per_candidate_table": "lower",
        "policy_sweep_per_candidate_steady": "lower",
        # dimensionless first-call ratio: the table sweep's one trace +
        # compile must keep beating six static traces + compiles (was 0.9x
        # before the per-site format-row scatter was removed)
        "policy_sweep_first_call_speedup": "higher",
        "autosearch_wall_us": "lower",
    },
    "kernels_micro": {
        "quantize_e5m7_4M": "lower",
        "flash_attn_B1H8S1024D64": "lower",
        "wkv6_B1H8S512hd64": "lower",
        # fused quantize epilogue vs kernel + separate quantize dispatch:
        # dimensionless, must stay >= the committed measured speedup band
        "flash_attn_fused_speedup": "higher",
        "wkv6_fused_speedup": "higher",
    },
    "search_sharded": {
        "sharded_sweep_dev1": "lower",
    },
    "perf_fp8_dot": {
        # measured native-fp8-storage dot vs emulated-rounding dot, and the
        # fraction of the roofline's modeled compute-term speedup that
        # measurement delivers. Both dimensionless, so they gate
        # cross-machine; the absolute *_us rows stay ungated.
        "fp8_dot_native_speedup": "higher",
        "fp8_dot_measured_vs_modeled": "higher",
    },
    "serving_throughput": {
        # the structural win: tick-count ratio of aligned-wave admission
        # over continuous batching on the same ragged workload. Pure
        # dispatch-count arithmetic — deterministic, machine-independent —
        # so the gate holds it "higher" (continuous must keep beating the
        # wave baseline). The wall-clock rows stay ungated (tiny-model CPU
        # serving is dominated by per-tick dispatch noise); their tok/s
        # trajectory is visible in the uploaded artifacts.
        "continuous_over_aligned_speedup": "higher",
    },
    "static_prune": {
        # eval-count ratio of the unpruned over the statically-pruned
        # autosearch on the bf16 Sod tube: pure counter arithmetic (the
        # benchmark asserts the assignments are bit-identical), so it is
        # deterministic and machine-independent and gates raw. The wall
        # rows stay ungated (compile-dominated on CI runners).
        "autosearch_evals_pruned_ratio": "higher",
    },
    "instability_profile": {
        # the paired-eval interpreter paths this repo owns: plain shadow
        # execution and the tentpole's per-step trajectory accumulation.
        # The warm-start walls stay ungated (compile-dominated); their
        # dispatch/eval reductions are asserted inside the benchmark.
        "heat_memtrace_run": "lower",
        "heat_trajectory_run": "lower",
        # dimensionless trajectory-vs-memtrace overhead ratio: pins the
        # per-step accumulation cost (site-filtered buffers, folded writes)
        # cross-machine, where the absolute walls above cannot
        "heat_trajectory_overhead": "lower",
    },
}

# Dimensionless (benchmark, row) pairs — speedup/overhead ratios whose two
# sides were measured on the same machine in the same process. They are
# machine-independent by construction, so dividing them by the machine
# factor would *introduce* a hardware dependence (a 2x-slower runner would
# halve every committed speedup and trip the "higher" gates); they gate raw.
RATIO_ROWS = {
    ("search_convergence", "policy_sweep_first_call_speedup"),
    ("kernels_micro", "flash_attn_fused_speedup"),
    ("kernels_micro", "wkv6_fused_speedup"),
    ("serving_throughput", "continuous_over_aligned_speedup"),
    ("instability_profile", "heat_trajectory_overhead"),
    ("static_prune", "autosearch_evals_pruned_ratio"),
    ("perf_fp8_dot", "fp8_dot_native_speedup"),
    ("perf_fp8_dot", "fp8_dot_measured_vs_modeled"),
}

# (benchmark, row) whose fresh/baseline ratio measures the MACHINE, not the
# code: raw elementwise quantize bandwidth on 4M floats — no interpreter,
# search, or sharding code in its path. Every other gated ratio is divided
# by it. Gated directly (un-normalized) against CAL_THRESHOLD.
#
# Known blind spot of cross-machine normalization: a code change that slows
# the calibration kernel AND the other gated paths by the same factor is
# normalized away until it exceeds CAL_THRESHOLD. That's the price of not
# gating on runner hardware; the un-normalized trajectory stays visible in
# the uploaded per-commit artifacts.
CALIBRATION = ("kernels_micro", "quantize_e5m7_4M")
CAL_THRESHOLD = 3.0  # limit 4x: catches a broken kernel, not a slower runner


def load_artifacts(dirpath: str) -> dict:
    """Load ``BENCH_*.json`` artifacts to ``{bench: {row: us_per_call}}``.

    Freshly-added or hand-edited artifacts may carry rows without a
    ``name``/``us_per_call`` (derived-only rows) or with non-numeric
    values; those rows are skipped with a note instead of KeyError/
    ValueError-crashing the whole gate."""
    out = {}
    for path in sorted(glob.glob(os.path.join(dirpath, "BENCH_*.json"))):
        with open(path) as f:
            data = json.load(f)
        name = data.get("benchmark") or os.path.basename(path)[6:-5]
        rows = {}
        for r in data.get("rows", []):
            try:
                rows[r["name"]] = float(r["us_per_call"])
            except (KeyError, TypeError, ValueError):
                print(f"  {name}: skipping malformed row {r!r}")
        out[name] = rows
    return out


def machine_factor(baselines: dict, fresh: dict,
                   calibration=CALIBRATION) -> float:
    """fresh/baseline ratio of the calibration row (1.0 when absent)."""
    if calibration is None:
        return 1.0
    bench, row = calibration
    base = baselines.get(bench, {}).get(row)
    new = fresh.get(bench, {}).get(row)
    if not base or not new or base <= 0 or new <= 0:
        return 1.0
    return new / base


def compare(baselines: dict, fresh: dict, threshold: float,
            gated: dict | None = None, calibration=CALIBRATION,
            log=print) -> list:
    """Return the list of failure strings (empty = gate passes)."""
    gated = GATED if gated is None else gated
    cal = machine_factor(baselines, fresh, calibration)
    if cal != 1.0:
        log(f"  machine factor {cal:.2f}x "
            f"(calibration row {calibration[0]}/{calibration[1]}; "
            f"gated ratios are divided by it)")
    failures = []
    for bench, base_rows in sorted(baselines.items()):
        rules = gated.get(bench, {})
        if bench not in fresh:
            if rules:
                failures.append(f"{bench}: fresh artifact missing "
                                f"(benchmark did not run or failed)")
            else:
                log(f"  {bench}: no fresh artifact (not gated) — skipped")
            continue
        fresh_rows = fresh[bench]
        for row, direction in sorted(rules.items()):
            if row not in base_rows:
                log(f"  {bench}/{row}: not in baseline — skipped")
                continue
            if row not in fresh_rows:
                failures.append(f"{bench}/{row}: gated row missing from "
                                f"fresh artifact")
                continue
            base, new = base_rows[row], fresh_rows[row]
            # a zero/negative/NaN baseline means the metric did not exist
            # when the baseline was committed (freshly-added benchmark or
            # placeholder row): no gate, warn — refresh the baseline to arm
            # it. Dividing by it would ZeroDivisionError/teach nonsense.
            if not math.isfinite(base) or base <= 0:
                log(f"  {bench}/{row}: no usable baseline ({base!r}) — "
                    f"not gated, refresh benchmarks/baselines to arm")
                continue
            if not math.isfinite(new):
                failures.append(f"{bench}/{row}: fresh value {new!r} is not "
                                f"finite")
                continue
            is_cal = calibration is not None and (bench, row) == calibration
            limit = CAL_THRESHOLD if is_cal else threshold
            raw = is_cal or (bench, row) in RATIO_ROWS
            ratio = (new / base) / (1.0 if raw else cal)
            if direction == "lower":
                bad = ratio > 1.0 + limit
                verdict = f"{ratio:.2f}x baseline (limit {1 + limit:.2f}x)"
            else:
                bad = ratio < 1.0 / (1.0 + limit)
                verdict = (f"{ratio:.2f}x baseline "
                           f"(limit {1 / (1 + limit):.2f}x)")
            status = "FAIL" if bad else "ok"
            note = " [calibration]" if is_cal else ""
            log(f"  {bench}/{row}: {base:.1f} -> {new:.1f} us  "
                f"{verdict}  [{status}]{note}")
            if bad:
                failures.append(f"{bench}/{row}: {verdict}")
    # a freshly-added gated benchmark whose baseline is not committed yet
    # must not crash (KeyError) or silently pass unmentioned: no gate, warn
    for bench in sorted(set(gated) & set(fresh) - set(baselines)):
        log(f"  {bench}: gated but no committed baseline — not gated, "
            f"commit BENCH_{bench}.json to benchmarks/baselines to arm")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline_dir")
    ap.add_argument("fresh_dir")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="allowed relative regression (default 0.25 = 25%%)")
    args = ap.parse_args(argv)

    baselines = load_artifacts(args.baseline_dir)
    fresh = load_artifacts(args.fresh_dir)
    if not baselines:
        print(f"no BENCH_*.json baselines in {args.baseline_dir}",
              file=sys.stderr)
        return 2
    print(f"bench-gate: {len(baselines)} baseline artifact(s), "
          f"threshold {args.threshold * 100:.0f}%")
    failures = compare(baselines, fresh, args.threshold)
    if failures:
        print(f"\nbench-gate FAILED ({len(failures)} regression(s)):",
              file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print("bench-gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
