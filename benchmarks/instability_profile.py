"""Instability-profiling benchmark: trajectory overhead vs plain shadow
execution, and the error-guided warm start's probe/dispatch reduction.

Rows:

  * ``heat_memtrace_run``    — steady-state paired (truncated, shadow) run
                               of the heat mini-app under plain mem-mode
  * ``heat_trajectory_run``  — the same run with per-step trajectory ring
                               buffers (the tentpole's added cost; derived
                               carries the overhead ratio)
  * ``heat_trajectory_overhead`` — that overhead as a gated dimensionless
                               ratio (trajectory/memtrace wall). Ratios of
                               same-machine timings gate cleanly across
                               runners where the raw walls would not.
  * ``heat_trajectory_blamed_run`` — the trajectory rerun restricted to the
                               worst columns of the full profile
                               (``profile_trajectory(sites=...)``): the
                               focused-followup cost once blame has named
                               its sites
  * ``bench_autosearch_unguided`` — full-ladder search on the bench model
  * ``bench_profile_trajectory``  — the one-off profiling run feeding hints
  * ``bench_autosearch_warm``     — the warm-started search; derived
                               carries dispatch/eval counts and the
                               reduction percentages

The scientific claim rides in the assertions (same contract as
benchmarks/apps_e2e.py): the warm-started search must reproduce the
unguided assignments with strictly fewer probe dispatches, or the
benchmark fails loudly.

    PYTHONPATH=src python -m benchmarks.instability_profile
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import csv_row, timeit, bench_model, bench_batch
from repro import search
from repro.apps import get_app
from repro.core import memtrace, profile_trajectory, TruncationPolicy
from repro.core.api import TruncationRule
from repro.core.formats import FPFormat
from repro.profile import ladder_hints


def bench_trajectory_overhead():
    app = get_app("heat", n=16, n_explicit=32, n_implicit=2, cg_iters=12)
    state = app.init_state()
    pol = app.uniform_policy(app.probe_format)

    mem = memtrace(app.run_observables, pol, threshold=app.search_threshold)
    t_mem, (_, rep) = timeit(mem, state, warmup=1, iters=3)
    csv_row("heat_memtrace_run", t_mem * 1e6,
            f"n_loc={len(rep.locations)};steps={app.n_steps}")

    traj_fn = profile_trajectory(app.run_observables, pol,
                                 threshold=app.search_threshold,
                                 n_steps=app.n_steps + 1)
    t_traj, (_, traj) = timeit(traj_fn, state, warmup=1, iters=3)
    csv_row("heat_trajectory_run", t_traj * 1e6,
            f"overhead_vs_memtrace={t_traj / t_mem:.2f}x"
            f";rows={traj.n_steps};n_loc={traj.n_locations}"
            f";steps_seen={int(jax.device_get(traj.steps_seen))}")
    assert int(jax.device_get(traj.steps_seen)) == app.n_steps
    csv_row("heat_trajectory_overhead", t_traj / t_mem,
            f"trajectory_us={t_traj * 1e6:.1f};memtrace_us={t_mem * 1e6:.1f}")

    # focused follow-up: rerun with ring buffers threaded only through the
    # worst columns of the full profile — the sites blame would name
    peaks = traj.rel_traj().max(axis=0)
    cols = traj.column_locations()
    worst = sorted(range(len(cols)), key=lambda c: -peaks[c])[:4]
    sites = [traj.totals.locations[cols[c]] for c in worst]
    sel_fn = profile_trajectory(app.run_observables, pol,
                                threshold=app.search_threshold,
                                n_steps=app.n_steps + 1, sites=sites)
    t_sel, (_, sel) = timeit(sel_fn, state, warmup=1, iters=3)
    csv_row("heat_trajectory_blamed_run", t_sel * 1e6,
            f"overhead_vs_memtrace={t_sel / t_mem:.2f}x"
            f";cols={len(sel.scopes)};n_loc={sel.n_locations}")
    assert len(sel.scopes) == len(sites)
    # the filtered columns must be the full profile's rows, bit-for-bit
    import numpy as np
    full = np.asarray(traj.rel_traj())
    filt = np.asarray(sel.rel_traj())
    col_of = {loc: c for c, loc in enumerate(cols)}
    for c_sel, loc in enumerate(sel.column_locations()):
        assert np.array_equal(filt[:, c_sel], full[:, col_of[loc]]), \
            "site-filtered trajectory diverged from the full profile"


def bench_warm_start():
    cfg, model, params = bench_model()
    batch = bench_batch(cfg)
    budget, thr = 128, 5e-3   # non-binding for the 17-scope x 6-rung ladder

    t0 = time.perf_counter()
    r0 = search.autosearch(model.loss, (params, batch),
                           search.loss_degradation, budget, threshold=thr)
    t_un = time.perf_counter() - t0
    csv_row("bench_autosearch_unguided", t_un * 1e6,
            f"dispatches={r0.n_dispatches};evals={r0.evals_used}"
            f";scopes={len(r0.assignments)}")

    probe = TruncationPolicy(rules=tuple(
        TruncationRule(fmt=FPFormat(8, 5), scope=p) for p in r0.assignments))
    t0 = time.perf_counter()
    out_lo, traj = profile_trajectory(model.loss, probe, threshold=thr,
                                      n_steps=8)(params, batch)
    joint = search.loss_degradation((model.loss(params, batch),), (out_lo,))
    hints = ladder_hints(traj, search.DEFAULT_WIDTHS, thr, 5,
                         joint_metric=joint)
    t_prof = time.perf_counter() - t0
    csv_row("bench_profile_trajectory", t_prof * 1e6,
            f"n_loc={traj.n_locations};hints={len(hints)}"
            f";joint_metric={joint:.3e}")

    t0 = time.perf_counter()
    r1 = search.autosearch(model.loss, (params, batch),
                           search.loss_degradation, budget, threshold=thr,
                           warm_start=hints)
    t_warm = time.perf_counter() - t0
    d_red = 100.0 * (1.0 - r1.n_dispatches / max(r0.n_dispatches, 1))
    e_red = 100.0 * (1.0 - r1.evals_used / max(r0.evals_used, 1))
    csv_row("bench_autosearch_warm", t_warm * 1e6,
            f"dispatches={r1.n_dispatches};evals={r1.evals_used}"
            f";dispatch_reduction_pct={d_red:.1f}"
            f";eval_reduction_pct={e_red:.1f}")

    a0 = {p: (a.man_bits, a.excluded) for p, a in r0.assignments.items()}
    a1 = {p: (a.man_bits, a.excluded) for p, a in r1.assignments.items()}
    assert a0 == a1, (
        f"warm start changed the assignments:\n{r0.table()}\n{r1.table()}")
    assert r1.n_dispatches < r0.n_dispatches, (
        f"warm start must reduce probe dispatches "
        f"({r0.n_dispatches} -> {r1.n_dispatches})")
    assert r1.evals_used < r0.evals_used


def run():
    bench_trajectory_overhead()
    bench_warm_start()


if __name__ == "__main__":
    run()
