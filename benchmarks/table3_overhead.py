"""Paper Table 3: profiling overhead vs baseline.

RAPTOR reports 36x (op-mode, optimized) to 148x (mem-mode) slowdowns from
scalar MPFR emulation. Our vectorized bit-math quantizer is the claimed
win: measure wall-clock of
  baseline forward | op-mode (ref = XLA-fused bit math) | op-mode
  (pallas-interpret = kernel semantics) | mem-mode | hardware-format
  fast path (convert pair, RAPTOR's zero-overhead mode)
Output: CSV  mode,us_per_call,overhead_x
"""
from __future__ import annotations

import jax

from repro.core import truncate, memtrace, TruncationPolicy
from benchmarks.common import bench_model, bench_batch, timeit, csv_row


def run():
    cfg, model, params = bench_model()
    batch = bench_batch(cfg)
    base = jax.jit(model.forward)
    t_base, _ = timeit(base, params, batch)

    pol_arb = TruncationPolicy.everywhere("e8m4")      # arbitrary format
    pol_hw = TruncationPolicy.everywhere("bf16")       # hardware convert pair

    t_op, _ = timeit(jax.jit(truncate(model.forward, pol_arb, impl="ref")),
                     params, batch)
    t_hw, _ = timeit(jax.jit(truncate(model.forward, pol_hw)),
                     params, batch)
    mem = jax.jit(memtrace(model.loss, pol_arb, threshold=1e-3, impl="ref"))
    t_mem, _ = timeit(mem, params, batch)

    print("mode,us_per_call,overhead_x")
    csv_row("baseline", t_base * 1e6, "1.00")
    csv_row("op-mode_e8m4_bitmath", t_op * 1e6, f"{t_op / t_base:.2f}")
    csv_row("op-mode_bf16_hw_fast_path", t_hw * 1e6, f"{t_hw / t_base:.2f}")
    csv_row("mem-mode_e8m4_shadow", t_mem * 1e6, f"{t_mem / t_base:.2f}")
    print(f"# paper (MPFR, scalar): op-mode 36.3x, mem-mode 148x; "
          f"ours: op-mode {t_op / t_base:.1f}x, mem-mode {t_mem / t_base:.1f}x",
          flush=True)


def main():
    run()


if __name__ == "__main__":
    main()
