"""Paper Table 2: mem-mode numerical debugging via iterative exclusion.

Truncate the whole model, rank source locations by shadow-deviation flags,
then exclude the top-flagged module(s) and measure the error change —
the Spark/Recon/Riemann workflow on the LM stack.
Output: CSV  excluded,logit_l1,flags_total,truncated_frac
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import (
    truncate, memtrace, profile_counts, TruncationPolicy,
)
from benchmarks.common import bench_model, bench_batch


def run():
    cfg, model, params = bench_model()
    batch = bench_batch(cfg)
    full = model.forward(params, batch)
    base_pol = TruncationPolicy.everywhere("e8m4")

    def fwd_sum(p, b):
        return jnp.sum(model.forward(p, b))

    def evaluate(pol, label):
        tr = truncate(model.forward, pol, impl="ref")(params, batch)
        err = float(jnp.mean(jnp.abs(full - tr)))
        _, rep = memtrace(fwd_sum, pol, threshold=1e-3, impl="ref")(params, batch)
        flags = int(jnp.sum(rep.flags))
        frac = profile_counts(model.forward, pol)(params, batch) \
            .truncated_fraction
        print(f"{label},{err:.6e},{flags},{frac:.4f}", flush=True)
        return rep

    print("excluded,logit_l1,flags_total,truncated_frac")
    rep = evaluate(base_pol, "baseline")
    # iteratively exclude the top-flagged scope (paper's workflow)
    excluded = []
    pol = base_pol
    for step in range(3):
        top_scopes = [loc.split(" ")[0] for loc, n, _ in rep.top(50) if n > 0]
        top_scopes = [s for s in top_scopes if s not in excluded
                      and s != "<root>"]
        if not top_scopes:
            break
        worst = top_scopes[0]
        excluded.append(worst)
        pol = pol.excluding(worst)
        rep = evaluate(pol, "+".join(excluded))


def main():
    run()


if __name__ == "__main__":
    main()
