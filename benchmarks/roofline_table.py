"""Roofline-term table from the dry-run artifacts (deliverable g as a
benchmark: one row per (arch x shape) cell, single-pod mesh). Requires a
prior `python -m repro.launch.dryrun --all`; prints a placeholder note when
artifacts are absent (e.g. fresh clone)."""
from __future__ import annotations

import os

from repro.launch import roofline


def run():
    d = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")
    recs = [r for r in roofline.load(d) if r.get("ok")]
    if not recs:
        print("# no dry-run artifacts found — run "
              "`python -m repro.launch.dryrun --all` first")
        return
    rows = [roofline.analyze(r) for r in recs]
    print(roofline.table(rows))
    doms = {}
    for r in rows:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    print(f"# dominant-term census: {doms}")


def main():
    run()


if __name__ == "__main__":
    main()
