"""Serving throughput: continuous batching vs the aligned-wave baseline.

The seed engine admitted requests in aligned waves — every slot free, all
prompts the same length, drain before the next wave — so a finished slot
idled until the slowest request in its wave drained. The continuous
engine admits into any free slot mid-stream. Both drivers here run the
SAME engine over the SAME ragged workload; only admission differs:

  * ``aligned_wave_run``  — submit in waves of ``batch`` requests and
    drain between waves (a conservative stand-in for the seed: a drained
    ragged wave never ticks more than the seed's padded equal-length
    wave did).
  * ``continuous_run``    — submit everything up front; the engine keeps
    every slot busy.

``continuous_over_aligned_speedup`` is the tick-count ratio — the
deterministic structural win (fewer decode dispatches for the same
tokens), immune to runner noise, and the row the CI gate holds ``higher``.
A third row measures shadow-profiling overhead at rate=1.0 (every tick
through the memtrace-shadowed step), an upper bound on what any sampled
rate can cost.
"""
from __future__ import annotations

import time

import numpy as np

import jax

from benchmarks.common import csv_row

from repro.configs.base import ArchConfig
from repro.core import TruncationPolicy
from repro.models import Model
from repro.serving import Engine, ShadowConfig

BATCH = 4
MAX_SEQ = 64
POLICY = TruncationPolicy.scoped("**/mlp", "e5m7")


def _workload(cfg, n=16, seed=0):
    """Ragged requests: prompt lengths 4..20, budgets 6..22 — the shape
    that makes wave alignment expensive (spans differ up to ~3x)."""
    r = np.random.RandomState(seed)
    return [(r.randint(1, cfg.vocab, int(r.randint(4, 21))).astype(np.int32),
             int(r.randint(6, 23)))
            for _ in range(n)]


def _drive(eng, workload, aligned: bool):
    """Run the workload; returns (wall_s, ticks, tokens). A tiny warm
    request first so compiles (decode, reset, shadow) land outside the
    timed span for both drivers alike."""
    eng.submit(np.array([1, 2], np.int32), max_new_tokens=2)
    eng.run()
    tick0 = eng._tick
    t0 = time.perf_counter()
    if aligned:
        for i in range(0, len(workload), eng.B):
            for prompt, m in workload[i:i + eng.B]:
                eng.submit(prompt, max_new_tokens=m)
            eng.run()                      # the wave barrier: drain
    else:
        for prompt, m in workload:
            eng.submit(prompt, max_new_tokens=m)
        eng.run()
    wall = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in eng._done.values()) - 2
    return wall, eng._tick - tick0, toks


def run():
    cfg = ArchConfig(name="serve_bench", family="dense", n_layers=2,
                     d_model=64, n_heads=4, n_kv_heads=2, d_ff=256,
                     vocab=256, dtype="float32", remat=False,
                     scan_layers=False)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    workload = _workload(cfg)

    eng_a = Engine(model, params, batch_size=BATCH, max_seq_len=MAX_SEQ,
                   policy=POLICY)
    wall_a, ticks_a, toks_a = _drive(eng_a, workload, aligned=True)
    csv_row("aligned_wave_run", wall_a * 1e6,
            f"tok_s={toks_a / wall_a:.1f};ticks={ticks_a};toks={toks_a}")

    eng_c = Engine(model, params, batch_size=BATCH, max_seq_len=MAX_SEQ,
                   policy=POLICY)
    wall_c, ticks_c, toks_c = _drive(eng_c, workload, aligned=False)
    assert toks_c == toks_a, "drivers must serve identical token counts"
    assert ticks_c < ticks_a, (
        f"continuous batching must need fewer decode ticks than aligned "
        f"waves on a ragged workload ({ticks_c} vs {ticks_a})")
    sizes = eng_c.cache_sizes()
    assert sizes["decode"] == 1 and sizes["reset"] == 1, sizes
    csv_row("continuous_run", wall_c * 1e6,
            f"tok_s={toks_c / wall_c:.1f};ticks={ticks_c};"
            f"wall_speedup={wall_a / wall_c:.2f}")

    # deterministic gate row: structural speedup as the tick-count ratio
    csv_row("continuous_over_aligned_speedup", ticks_a / ticks_c,
            f"basis=tick_ratio;aligned_ticks={ticks_a};"
            f"continuous_ticks={ticks_c}")

    eng_s = Engine(model, params, batch_size=BATCH, max_seq_len=MAX_SEQ,
                   policy=POLICY, shadow=ShadowConfig(rate=1.0))
    wall_s, ticks_s, toks_s = _drive(eng_s, workload, aligned=False)
    assert toks_s == toks_c and ticks_s == ticks_c
    csv_row("shadow_rate100_run", wall_s * 1e6,
            f"tok_s={toks_s / wall_s:.1f};"
            f"overhead_vs_plain={wall_s / wall_c:.2f}")


if __name__ == "__main__":
    run()
