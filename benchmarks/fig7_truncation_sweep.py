"""Paper Fig. 7: error vs mantissa width under three truncation strategies.

LM mapping of the AMR experiment (DESIGN.md §3):
  * panel M-0: global truncation (all scopes)
  * panel M-1/M-2: layer-depth cutoffs — exclude the last l layers + the
    logits head (the "finest blocks": closest to the loss)
  * plus the operation-count bars (truncated vs full), from the same static
    counters the §7.2 speedup model consumes.
Output: CSV  strategy,mantissa,logit_l1,truncated_frac
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import truncate, profile_counts, TruncationPolicy
from benchmarks.common import bench_model, bench_batch, csv_row


def strategies(cfg):
    n = cfg.n_layers
    yield "M-0_global", TruncationPolicy.everywhere("e8m2")
    yield "M-1_skip_last", TruncationPolicy.everywhere("e8m2").excluding(
        f"layer{n-1}", "final_norm", "logits", "loss")
    yield "M-2_skip_last2", TruncationPolicy.everywhere("e8m2").excluding(
        f"layer{n-1}", f"layer{n-2}", "final_norm", "logits", "loss")


def run():
    cfg, model, params = bench_model()
    batch = bench_batch(cfg)
    full = model.forward(params, batch)
    import dataclasses
    print("strategy,mantissa,logit_l1,truncated_frac")
    for name, base_pol in strategies(cfg):
        for m in (2, 3, 4, 6, 8, 10, 14, 18, 23):
            rules = tuple(dataclasses.replace(r, fmt=r.fmt.with_mantissa(m))
                          for r in base_pol.rules)
            pol = dataclasses.replace(base_pol, rules=rules)
            tr = truncate(model.forward, pol, impl="ref")(params, batch)
            err = float(jnp.mean(jnp.abs(full - tr)))
            frac = profile_counts(model.forward, pol)(
                params, batch).truncated_fraction
            print(f"{name},{m},{err:.6e},{frac:.4f}", flush=True)


def main():
    run()


if __name__ == "__main__":
    main()
