"""Shared benchmark harness utilities."""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import Model


def bench_model(seed: int = 0, **overrides):
    """A ~10M-param GPT-style model: large enough that truncation effects
    are measurable, small enough for CPU sweeps."""
    kw = dict(name="bench", family="dense", n_layers=4, d_model=128,
              n_heads=8, n_kv_heads=4, d_ff=512, vocab=512,
              dtype="float32", remat=False, scan_layers=False)
    kw.update(overrides)
    cfg = ArchConfig(**kw)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    return cfg, model, params


def bench_batch(cfg, B=8, S=64, seed=0):
    r = np.random.RandomState(seed)
    toks = r.randint(0, cfg.vocab, (B, S + 1))
    return {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "labels": jnp.asarray(toks[:, 1:], jnp.int32)}


def timeit(fn, *args, warmup=1, iters=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters, out


def timeit_pair(fn_a, fn_b, *args, warmup=1, iters=8):
    """Median times of two callables on the same args, alternating A/B each
    iteration. For A-vs-B speedup ratios, back-to-back `timeit` calls let a
    frequency ramp or background-load shift land entirely on one side and
    flip the ratio; interleaving exposes both sides to the same drift, and
    the median drops stray outliers."""
    for _ in range(warmup):
        jax.block_until_ready(fn_a(*args))
        jax.block_until_ready(fn_b(*args))
    ta, tb = [], []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_a(*args))
        ta.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fn_b(*args))
        tb.append(time.perf_counter() - t0)
    return float(np.median(ta)), float(np.median(tb))


# rows recorded by csv_row since the last reset_results(); benchmarks/run.py
# snapshots these into machine-readable BENCH_<name>.json artifacts so the
# perf trajectory is tracked across PRs
RESULTS = []


def reset_results():
    RESULTS.clear()


def _parse_derived(derived: str):
    """Best-effort 'k=v;k=v' -> dict (numbers coerced); raw string otherwise."""
    out = {}
    for part in str(derived).split(";"):
        k, sep, v = part.partition("=")
        if not sep or not k.strip():
            return str(derived)
        v = v.strip()
        try:
            out[k.strip()] = int(v)
        except ValueError:
            try:
                out[k.strip()] = float(v)
            except ValueError:
                out[k.strip()] = v
    return out


def csv_row(name: str, us_per_call: float, derived: str):
    RESULTS.append({"name": name, "us_per_call": float(us_per_call),
                    "derived": _parse_derived(derived)})
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)
