"""Paper Table 4 + Fig. 8: hardware co-design speedup predictions.

Feeds the static truncated/full op counters of each truncation strategy
into (a) the paper's FPNew CPU area-density model and (b) the TPU v5e
re-parameterization, for compute-bound and memory-bound regimes.
Output: CSV  strategy,trunc_frac,cpu_fp16_x,cpu_fp32_x,tpu_compute_x,tpu_memory_x,bound
"""
from __future__ import annotations

import dataclasses

from repro.core import profile_counts, TruncationPolicy
from repro.core.speedup import estimate_speedup, fpu_area_model
from benchmarks.common import bench_model, bench_batch
from benchmarks.fig7_truncation_sweep import strategies


def run():
    cfg, model, params = bench_model()
    batch = bench_batch(cfg)
    print("strategy,trunc_frac,cpu_fp16_x,cpu_fp32_x,tpu_compute_x,"
          "tpu_memory_x,bound")
    for name, base_pol in strategies(cfg):
        for m, key in ((10, "fp16"), (2, "e5m2")):
            rules = tuple(dataclasses.replace(r, fmt=r.fmt.with_mantissa(m))
                          for r in base_pol.rules)
            pol = dataclasses.replace(base_pol, rules=rules)
            rep = profile_counts(model.loss, pol)(params, batch)
            cpu = fpu_area_model(rep.flops_by_fmt)
            est = estimate_speedup(rep)
            print(f"{name}_m{m},{rep.truncated_fraction:.3f},"
                  f"{cpu.get('fp16', 1.0):.2f},{cpu.get('fp32', 1.0):.2f},"
                  f"{est.compute_bound:.2f},{est.memory_bound:.2f},"
                  f"{est.bound}", flush=True)


def main():
    run()


if __name__ == "__main__":
    main()
