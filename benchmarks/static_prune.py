"""Static-pruning benchmark: abstract interpretation vs dynamic probing.

``autosearch(static_prune=True)`` runs the jaxpr range/exactness analysis
before probing and skips every rung it can decide statically. The contract
is *bit-identical assignments for strictly less work*, so this benchmark:

  * runs the unpruned and pruned searches on the bf16 Sod shock tube and
    ASSERTS the per-scope assignments match exactly,
  * emits the eval and dispatch reduction ratios as gated rows —
    dimensionless counter arithmetic (no wall clocks), deterministic and
    machine-independent, so they gate raw (RATIO_ROWS in compare.py),
  * times the analysis itself (ungated: a few ms of pure-Python abstract
    interpretation; the trajectory is visible in uploaded artifacts).

    PYTHONPATH=src python -m benchmarks.static_prune
"""
import time

import jax.numpy as jnp

from benchmarks.common import csv_row
from repro.apps import get_app

BUDGET = 64


def _run(app, state, **kw):
    from repro.search import driver
    return driver.autosearch(app.run_observables, (state,),
                             app.error_metric, BUDGET,
                             threshold=app.search_threshold, **kw)


def run():
    app = get_app("sod")
    state = app.init_state(jnp.bfloat16)

    t0 = time.perf_counter()
    base = _run(app, state)
    base_wall = time.perf_counter() - t0

    t0 = time.perf_counter()
    pruned = _run(app, state, static_prune=True)
    pruned_wall = time.perf_counter() - t0

    table = lambda r: {p: (a.man_bits, a.excluded)
                       for p, a in r.assignments.items()}
    assert table(pruned) == table(base), (
        "static pruning changed the search result:\n"
        f"  base   {table(base)}\n  pruned {table(pruned)}")
    assert pruned.evals_used < base.evals_used
    assert pruned.n_dispatches < base.n_dispatches

    # standalone analysis wall time (trace + abstract interpretation +
    # verdicts), measured apart from the search
    import jax

    from repro.analysis import analyze_closed, scope_rung_verdicts
    from repro.core import interpreter
    from repro.core.formats import FPFormat
    from repro.core.policy import TruncationPolicy, TruncationRule
    from repro.search.scopes import discover_scopes

    closed = jax.make_jaxpr(app.run_observables)(state)
    leaves = jax.tree_util.tree_leaves(((state,), {}))
    t0 = time.perf_counter()
    res = analyze_closed(closed, leaves)
    paths = [s.path for s in discover_scopes(closed)]
    index = interpreter.enumerate_sites(closed, TruncationPolicy(rules=(
        TruncationRule(fmt=FPFormat(8, 0), scope="**"),)))
    sv = scope_rung_verdicts(res, index, paths, [15, 10, 7, 5, 3, 2], 8)
    analysis_wall = time.perf_counter() - t0

    evals_ratio = base.evals_used / pruned.evals_used
    disp_ratio = base.n_dispatches / pruned.n_dispatches
    csv_row("autosearch_unpruned_wall", base_wall * 1e6,
            f"evals={base.evals_used};dispatches={base.n_dispatches}")
    csv_row("autosearch_pruned_wall", pruned_wall * 1e6,
            f"evals={pruned.evals_used};dispatches={pruned.n_dispatches};"
            f"rungs_decided={pruned.n_pruned}")
    # gated, dimensionless: the search must keep skipping work statically
    csv_row("autosearch_evals_pruned_ratio", evals_ratio,
            f"base={base.evals_used};pruned={pruned.evals_used}")
    csv_row("autosearch_dispatch_pruned_ratio", disp_ratio,
            f"base={base.n_dispatches};pruned={pruned.n_dispatches}")
    csv_row("static_analysis_wall", analysis_wall * 1e6,
            f"sites={len(index)};records={len(res.records)};"
            f"decided={sv.n_decided}")
    return evals_ratio


if __name__ == "__main__":
    run()
