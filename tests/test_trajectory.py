"""Instability profiling: per-step trajectories, onset/blame, report
reductions, and the ladder_hints bridge into the warm-started search.

Merge/allreduce edge cases mirror tests/test_report_merge.py for the
trajectory pytree (single-step buffers, mismatched step counts, the
empty-location-table sentinel).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import (
    memtrace, profile_trajectory, TruncationPolicy, TrajectoryReport, scope,
)
from repro.core.memmode import RaptorReport
from repro.profile import ladder_hints, scope_of_location


def _get(x):
    return np.asarray(jax.device_get(x))


# exact vs lossy per-step factors: x2.0 only shifts the exponent (exact in
# every e?m? format), x1.09 rounds at 2 mantissa bits
EXACT, LOSSY = 2.0, 1.09


def _staged(n_exact: int, n_total: int):
    """A stepped workload whose truncation error first appears at step
    ``n_exact``: earlier steps multiply by an exactly-representable factor."""
    def f(x):
        def body(c, t):
            with scope("stage"):
                fac = jnp.where(t < n_exact, jnp.asarray(EXACT, c.dtype),
                                jnp.asarray(LOSSY, c.dtype))
                c = c * fac
            return c, None
        y, _ = lax.scan(body, x, jnp.arange(n_total, dtype=jnp.int32))
        return jnp.sum(y)
    return f


def _profile(fn, x, n_steps, fmt="e5m2", threshold=1e-3):
    return profile_trajectory(fn, TruncationPolicy.everywhere(fmt),
                              threshold, n_steps=n_steps)(x)


def test_trajectory_totals_match_memtrace():
    """The trajectory report's whole-run totals are bit-identical to plain
    mem-mode, and outputs are unchanged."""
    f = _staged(0, 6)
    x = jnp.asarray([1.0, 2.0], jnp.float32)
    out_t, traj = _profile(f, x, n_steps=8)
    out_m, rep = memtrace(f, TruncationPolicy.everywhere("e5m2"), threshold=1e-3)(x)
    assert float(out_t) == float(out_m)
    assert isinstance(traj, TrajectoryReport)
    assert traj.locations == rep.locations
    for a, b in ((traj.totals.flags, rep.flags),
                 (traj.totals.max_rel, rep.max_rel),
                 (traj.totals.op_counts, rep.op_counts)):
        np.testing.assert_array_equal(_get(a), _get(b))


def test_divergence_onset_detected_at_the_right_step():
    """Error that first appears at scan iteration k>0 must onset exactly
    there — the signal plain mem-mode collapses away."""
    k, n = 3, 8
    out, traj = _profile(_staged(k, n), jnp.asarray([1.0, 1.5], jnp.float32),
                         n_steps=n + 1)
    assert int(_get(traj.steps_seen)) == n
    (i,) = [j for j, s in enumerate(traj.scopes) if s == "stage"]
    onsets = traj.onset_steps(1e-3)
    assert onsets[i] == k
    # per-step rows: exact steps carry zero deviation, lossy steps don't
    m = _get(traj.max_rel)
    assert np.all(m[:k, i] == 0.0)
    assert np.all(m[k:n, i] > 0.0)
    blame = traj.blame(1e-3)
    assert blame[0].scope == "stage" and blame[0].onset == k


def test_onset_through_while_loop_carry():
    """Trajectory stats thread the while carry: a deviation appearing only
    after while-iteration k>1 is recorded at that step, and op counts
    reflect every iteration."""
    k, n = 2, 5

    def f(x):
        def cond(c):
            return c[0] < n

        def body(c):
            i, v = c
            with scope("w"):
                fac = jnp.where(i < k, jnp.asarray(EXACT, v.dtype),
                                jnp.asarray(LOSSY, v.dtype))
                v = v * fac
            return (i + 1, v)

        return jnp.sum(lax.while_loop(cond, body, (jnp.int32(0), x))[1])

    out, traj = _profile(f, jnp.asarray([1.0, 2.0], jnp.float32), n_steps=n)
    (i,) = [j for j, s in enumerate(traj.scopes) if s == "w"]
    assert int(_get(traj.steps_seen)) == n
    assert traj.onset_steps(1e-3)[i] == k
    # all n iterations counted (2 elements each)
    assert int(_get(traj.op_counts)[:, i].sum()) == 2 * n


def test_ring_buffer_wraps_and_reports_steps_seen():
    n = 10
    out, traj = _profile(_staged(0, n), jnp.asarray([1.0], jnp.float32),
                         n_steps=4)
    assert traj.n_steps == 4
    assert int(_get(traj.steps_seen)) == n
    assert traj.used_rows() == 4
    # wrapped rows still carry data for the folded steps
    (i,) = [j for j, s in enumerate(traj.scopes) if s == "stage"]
    assert np.all(_get(traj.op_counts)[:, i] > 0)


def test_post_loop_ops_visible_to_blame():
    """Truncated ops AFTER the outermost loop accumulate in the trailing
    row (index steps_seen); the analysis must see that row — a site whose
    only errors are post-loop must not rank as fully stable."""
    n = 3

    def f(x):
        def body(c, _):
            with scope("loop"):
                c = c * jnp.asarray(2.0, c.dtype)   # exact: no deviation
            return c, None
        y, _ = lax.scan(body, x, None, length=n)
        with scope("tail"):
            return jnp.sum(y * jnp.asarray(1.09, y.dtype))

    out, traj = _profile(f, jnp.asarray([1.0, 2.0], jnp.float32),
                         n_steps=n + 1)
    assert int(_get(traj.steps_seen)) == n
    assert traj.used_rows() == n + 1
    idxs = [j for j, s in enumerate(traj.scopes) if s == "tail"]
    assert idxs
    onsets = traj.onset_steps(1e-3)
    assert all(onsets[i] == n for i in idxs)       # the trailing row
    blame = {b.scope: b for b in traj.blame(1e-3)}
    assert blame["tail"].peak_rel > 0 and blame["tail"].onset == n


def test_straight_line_program_lands_in_row_zero():
    def f(x):
        with scope("s"):
            return jnp.sum(x * 1.09)

    out, traj = _profile(f, jnp.asarray([1.0, 2.0], jnp.float32), n_steps=3)
    assert int(_get(traj.steps_seen)) == 0
    assert traj.used_rows() == 1
    assert int(_get(traj.op_counts)[0].sum()) > 0
    assert int(_get(traj.op_counts)[1:].sum()) == 0


# --------------------------------------------------------------------------
# merge / allreduce edge cases (mirroring test_report_merge.py)
# --------------------------------------------------------------------------

def _traj(locs, scopes, max_rel, abs_sum, mag_sum, ops, steps):
    totals = RaptorReport(tuple(locs),
                          jnp.asarray(np.sum(np.asarray(ops), 0), jnp.int32),
                          jnp.asarray(np.max(np.asarray(max_rel), 0),
                                      jnp.float32),
                          jnp.asarray(np.sum(np.asarray(ops), 0), jnp.int32))
    return TrajectoryReport(
        totals=totals, scopes=tuple(scopes),
        max_rel=jnp.asarray(max_rel, jnp.float32),
        abs_sum=jnp.asarray(abs_sum, jnp.float32),
        mag_sum=jnp.asarray(mag_sum, jnp.float32),
        op_counts=jnp.asarray(ops, jnp.int32),
        steps_seen=jnp.int32(steps))


def test_merge_sums_and_maxes_per_step():
    a = _traj(["l0", "l1"], ["a", "b"], [[0.5, 0.0], [0.125, 0.25]],
              [[1.0, 0.0], [0.5, 2.0]], [[4.0, 1.0], [4.0, 1.0]],
              [[2, 1], [2, 1]], 2)
    b = _traj(["l0", "l1"], ["a", "b"], [[0.25, 1.5], [0.0, 0.0]],
              [[1.0, 1.0], [0.5, 0.0]], [[4.0, 1.0], [4.0, 1.0]],
              [[2, 1], [2, 1]], 2)
    m = a.merge(b)
    assert _get(m.max_rel).tolist() == [[0.5, 1.5], [0.125, 0.25]]
    assert _get(m.abs_sum).tolist() == [[2.0, 1.0], [1.0, 2.0]]
    assert _get(m.op_counts).tolist() == [[4, 2], [4, 2]]
    assert int(_get(m.steps_seen)) == 2


def test_merge_single_step_buffer():
    a = _traj(["l0"], ["s"], [[0.5]], [[1.0]], [[2.0]], [[3]], 1)
    m = TrajectoryReport.merge_all([a])
    assert m is a  # single shard: identity, no copy
    m2 = a.merge(a)
    assert m2.n_steps == 1
    assert _get(m2.op_counts).tolist() == [[6]]


def test_merge_mismatched_step_counts_raises():
    a = _traj(["l0"], ["s"], [[0.5]], [[1.0]], [[2.0]], [[3]], 1)
    b = _traj(["l0"], ["s"], [[0.5], [0.5]], [[1.0], [1.0]],
              [[2.0], [2.0]], [[3], [3]], 2)
    with pytest.raises(ValueError, match="step buffers differ"):
        a.merge(b)


def test_merge_mismatched_locations_raises():
    a = _traj(["l0"], ["s"], [[0.5]], [[1.0]], [[2.0]], [[3]], 1)
    b = _traj(["OTHER"], ["s"], [[0.5]], [[1.0]], [[2.0]], [[3]], 1)
    with pytest.raises(ValueError, match="location tables differ"):
        a.merge(b)


def test_merge_all_empty_raises():
    with pytest.raises(ValueError, match="at least one report"):
        TrajectoryReport.merge_all([])


def test_empty_location_table_sentinel():
    """A computation with no truncated locations produces the sentinel
    single-location report; merging and analysing it must stay consistent."""
    def f(x):
        return x * 2.0

    out, traj = profile_trajectory(f, TruncationPolicy(rules=()), threshold=1e-3,
                                   n_steps=2)(jnp.ones((3,), jnp.float32))
    assert traj.locations == ("<no truncated locations>",)
    assert traj.scopes == ("",)
    m = traj.merge(traj)
    assert int(_get(m.op_counts).sum()) == 0
    assert traj.blame(1e-3) == []          # the sentinel is never blamed
    assert traj.onset_steps(1e-3).tolist() == [-1]


def test_allreduce_on_single_device_mesh():
    """allreduce is the in-SPMD reduction; on a 1-shard mesh it must be the
    identity (psum/pmax over one shard)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P

    f = _staged(1, 4)
    x = jnp.asarray([1.0, 2.0], jnp.float32)
    out0, t0 = _profile(f, x, n_steps=4)
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))

    def body(xs):
        _, t = profile_trajectory(
            f, TruncationPolicy.everywhere("e5m2"), threshold=1e-3, n_steps=4)(xs)
        return t.allreduce("data")

    t1 = shard_map(body, mesh=mesh, in_specs=(P("data"),), out_specs=P(),
                   check_rep=False)(x)
    for name in ("max_rel", "abs_sum", "mag_sum", "op_counts", "steps_seen"):
        np.testing.assert_array_equal(_get(getattr(t0, name)),
                                      _get(getattr(t1, name)))


# --------------------------------------------------------------------------
# blame -> warm-start hints
# --------------------------------------------------------------------------

def test_scope_of_location():
    assert scope_of_location("hydro/eos div @ sod.py:81") == "hydro/eos"
    assert scope_of_location("<root> add @ f.py:1") == ""
    assert scope_of_location("<no truncated locations>") == ""
    # autodiff decorations normalize away
    assert scope_of_location("transpose(jvp(mlp))/dot mul @ m.py:3") == \
        "mlp/dot"


def test_ladder_hints_stable_aggressive_unstable_pinned():
    widths = (23, 15, 10, 7, 5, 3, 2)
    # two scopes profiled at m5: one bit-exact, one catastrophically off
    t = _traj(["a x @ f:1", "b x @ f:2"], ["calm", "wild"],
              [[0.0, 1.9]], [[0.0, 8.0]], [[4.0, 4.0]], [[4, 4]], 1)
    hints = ladder_hints(t, widths, threshold=1e-3, probe_man_bits=5)
    assert hints["calm"] == 2          # aggressive: narrowest rung
    assert hints["wild"] is None       # pinned high: off the ladder's end
    # calibration rescales every peak so the worst scope predicts the
    # measured joint metric: with joint == threshold the worst scope is
    # predicted admissible at (about) the probe width itself
    hints_cal = ladder_hints(t, widths, threshold=1e-3, probe_man_bits=5,
                             joint_metric=1e-3, margin=0)
    assert hints_cal["wild"] == 5
    assert hints_cal["calm"] == 2


def test_ladder_hints_nonfinite_peak_pins():
    t = _traj(["a x @ f:1"], ["boom"], [[np.inf]], [[np.inf]], [[1.0]],
              [[4]], 1)
    hints = ladder_hints(t, (23, 10, 2), threshold=1e-3, probe_man_bits=5)
    assert hints["boom"] is None


def test_profile_trajectory_validates_and_caches():
    with pytest.raises(ValueError, match="n_steps"):
        profile_trajectory(lambda x: x, TruncationPolicy(rules=()),
                           n_steps=0)
    f = _staged(0, 3)
    wrapped = profile_trajectory(f, TruncationPolicy.everywhere("e5m2"),
                                 1e-3, n_steps=3)
    x = jnp.asarray([1.0], jnp.float32)
    r1 = wrapped(x)
    r2 = wrapped(x)
    assert wrapped.n_traces == 1       # trace-cached like memtrace
    np.testing.assert_array_equal(_get(r1[1].max_rel), _get(r2[1].max_rel))


# --------------------------------------------------------------------------
# the tier-1 smoke of the ISSUE acceptance: HeatDiffusion's explicit stencil
# --------------------------------------------------------------------------

def test_heat_blame_pinpoints_stencil_onset_under_e5m2():
    """On the small heat config the blame ranking must (a) localize the
    explicit-stencil scope's divergence onset inside the explicit phase and
    (b) place the implicit-phase scopes' onset exactly at the phase switch —
    the 'when, not just how much' capability of the subsystem."""
    from repro.apps import get_app

    app = get_app("heat", n=8, n_explicit=8, n_implicit=1, cg_iters=6)
    obs, traj = app.profile_trajectory(
        policy=app.uniform_policy("e5m2"), threshold=1e-3)
    assert int(_get(traj.steps_seen)) == app.n_steps
    blame = {b.scope: b for b in traj.blame(1e-3)}
    st = blame["heat/stencil"]
    assert st.onset is not None and 0 <= st.onset < app.n_explicit
    for sc, b in blame.items():
        if sc.startswith("heat/implicit"):
            # implicit scopes only run after the explicit phase: their
            # first threshold crossing is the phase-switch step
            assert b.onset is None or b.onset >= app.n_explicit
    assert any(sc.startswith("heat/implicit") and b.onset == app.n_explicit
               for sc, b in blame.items())
