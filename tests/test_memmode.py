"""Mem-mode: shadow correctness, flag heatmaps, the Table-2 exclusion flow."""
import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import (
    memtrace, truncate, TruncationPolicy, E5M2, FP16, scope,
)


def model(w, x):
    with scope("attn"):
        h = jnp.tanh(x @ w)
    with scope("mlp"):
        h = jax.nn.relu(h @ w.T) @ w
    with scope("norm"):
        h = h / (jnp.sqrt(jnp.mean(h * h, -1, keepdims=True)) + 1e-5)
    return jnp.sum(h * h)


def data():
    r = np.random.RandomState(0)
    return (jnp.asarray(r.randn(8, 8), jnp.float32),
            jnp.asarray(r.randn(4, 8), jnp.float32))


def test_outputs_match_opmode():
    """mem-mode low lane == op-mode output (same truncation points)."""
    w, x = data()
    pol = TruncationPolicy.everywhere(E5M2)
    out_op = truncate(model, pol)(w, x)
    out_mem, _ = memtrace(model, pol, 1e-3)(w, x)
    assert float(out_op) == float(out_mem)


def test_shadow_is_full_precision():
    """With an identity policy nothing is flagged."""
    w, x = data()
    pol = TruncationPolicy.everywhere("fp32")
    out, report = memtrace(model, pol, 1e-6)(w, x)
    assert float(out) == float(model(w, x))
    assert int(jnp.sum(report.flags)) == 0


def test_flags_grow_with_coarser_format():
    w, x = data()
    _, rep_fine = memtrace(model, TruncationPolicy.everywhere(FP16), 1e-3)(w, x)
    _, rep_coarse = memtrace(model, TruncationPolicy.everywhere(E5M2), 1e-3)(w, x)
    assert int(jnp.sum(rep_coarse.flags)) > int(jnp.sum(rep_fine.flags))


def test_heatmap_locates_scopes():
    w, x = data()
    _, rep = memtrace(model, TruncationPolicy.everywhere(E5M2), 1e-2)(w, x)
    locs = [loc for loc, n, _ in rep.top(100) if n > 0]
    assert any("attn" in l for l in locs)
    assert any("mlp" in l for l in locs)


def test_exclusion_workflow_table2():
    """Paper §6.3: exclude the worst-flagged module, re-run, error drops."""
    w, x = data()
    pol = TruncationPolicy.everywhere(E5M2)
    ref = float(model(w, x))
    out0, rep0 = memtrace(model, pol, 1e-2)(w, x)
    worst = rep0.top(1)[0][0].split(" ")[0].split("/")[0]
    out1, rep1 = memtrace(model, pol.excluding(worst), 1e-2)(w, x)
    err0 = abs(float(out0) - ref)
    err1 = abs(float(out1) - ref)
    # excluding the most-flagged scope must not make things worse
    assert err1 <= err0 * 1.5
    assert int(jnp.sum(rep1.flags)) <= int(jnp.sum(rep0.flags))


def test_memmode_through_scan():
    def f(x):
        def body(c, _):
            return jnp.sin(c * 1.01), c
        y, ys = lax.scan(body, x, None, length=4)
        return jnp.sum(y) + jnp.sum(ys)
    x = jnp.asarray(np.random.RandomState(2).randn(8), jnp.float32)
    pol = TruncationPolicy.everywhere(E5M2)
    out, rep = memtrace(f, pol, 1e-3)(x)
    assert np.isfinite(float(out))
    assert int(jnp.sum(rep.op_counts)) > 0
    # op counts accumulate across the 4 scan iterations
    assert int(jnp.max(rep.op_counts)) >= 4 * 8


def test_memmode_jits():
    w, x = data()
    pol = TruncationPolicy.everywhere(E5M2)
    fn = jax.jit(memtrace(model, pol, 1e-3))
    out1, rep1 = fn(w, x)
    out2, rep2 = fn(w, x)
    assert float(out1) == float(out2)
    np.testing.assert_array_equal(np.asarray(rep1.flags),
                                  np.asarray(rep2.flags))
