"""Mem-mode: shadow correctness, flag heatmaps, the Table-2 exclusion flow."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import (
    memtrace, truncate, TruncationPolicy, E5M2, FP16, scope,
)


def model(w, x):
    with scope("attn"):
        h = jnp.tanh(x @ w)
    with scope("mlp"):
        h = jax.nn.relu(h @ w.T) @ w
    with scope("norm"):
        h = h / (jnp.sqrt(jnp.mean(h * h, -1, keepdims=True)) + 1e-5)
    return jnp.sum(h * h)


def data():
    r = np.random.RandomState(0)
    return (jnp.asarray(r.randn(8, 8), jnp.float32),
            jnp.asarray(r.randn(4, 8), jnp.float32))


def test_outputs_match_opmode():
    """mem-mode low lane == op-mode output (same truncation points)."""
    w, x = data()
    pol = TruncationPolicy.everywhere(E5M2)
    out_op = truncate(model, pol)(w, x)
    out_mem, _ = memtrace(model, pol, threshold=1e-3)(w, x)
    assert float(out_op) == float(out_mem)


def test_shadow_is_full_precision():
    """With an identity policy nothing is flagged."""
    w, x = data()
    pol = TruncationPolicy.everywhere("fp32")
    out, report = memtrace(model, pol, threshold=1e-6)(w, x)
    assert float(out) == float(model(w, x))
    assert int(jnp.sum(report.flags)) == 0


def test_flags_grow_with_coarser_format():
    w, x = data()
    _, rep_fine = memtrace(model, TruncationPolicy.everywhere(FP16), threshold=1e-3)(w, x)
    _, rep_coarse = memtrace(model, TruncationPolicy.everywhere(E5M2), threshold=1e-3)(w, x)
    assert int(jnp.sum(rep_coarse.flags)) > int(jnp.sum(rep_fine.flags))


def test_heatmap_locates_scopes():
    w, x = data()
    _, rep = memtrace(model, TruncationPolicy.everywhere(E5M2), threshold=1e-2)(w, x)
    locs = [loc for loc, n, _ in rep.top(100) if n > 0]
    assert any("attn" in l for l in locs)
    assert any("mlp" in l for l in locs)


def test_exclusion_workflow_table2():
    """Paper §6.3: exclude the worst-flagged module, re-run, error drops."""
    w, x = data()
    pol = TruncationPolicy.everywhere(E5M2)
    ref = float(model(w, x))
    out0, rep0 = memtrace(model, pol, threshold=1e-2)(w, x)
    worst = rep0.top(1)[0][0].split(" ")[0].split("/")[0]
    out1, rep1 = memtrace(model, pol.excluding(worst), threshold=1e-2)(w, x)
    err0 = abs(float(out0) - ref)
    err1 = abs(float(out1) - ref)
    # excluding the most-flagged scope must not make things worse
    assert err1 <= err0 * 1.5
    assert int(jnp.sum(rep1.flags)) <= int(jnp.sum(rep0.flags))


def test_memmode_through_scan():
    def f(x):
        def body(c, _):
            return jnp.sin(c * 1.01), c
        y, ys = lax.scan(body, x, None, length=4)
        return jnp.sum(y) + jnp.sum(ys)
    x = jnp.asarray(np.random.RandomState(2).randn(8), jnp.float32)
    pol = TruncationPolicy.everywhere(E5M2)
    out, rep = memtrace(f, pol, threshold=1e-3)(x)
    assert np.isfinite(float(out))
    assert int(jnp.sum(rep.op_counts)) > 0
    # op counts accumulate across the 4 scan iterations
    assert int(jnp.max(rep.op_counts)) >= 4 * 8


def test_memmode_jits():
    w, x = data()
    pol = TruncationPolicy.everywhere(E5M2)
    fn = jax.jit(memtrace(model, pol, threshold=1e-3))
    out1, rep1 = fn(w, x)
    out2, rep2 = fn(w, x)
    assert float(out1) == float(out2)
    np.testing.assert_array_equal(np.asarray(rep1.flags),
                                  np.asarray(rep2.flags))


# --------------------------------------------------------------------------
# hybrid deviation metric: zero/denormal shadow values must not poison the
# per-location max with inf/nan (regression for the divide-by-zero bug)
# --------------------------------------------------------------------------

def test_deviation_zero_and_denormal_shadow():
    from repro.core.memmode import deviation

    def dev(lo, sh):
        return float(deviation(jnp.float32(lo), jnp.float32(sh)))

    # exactly-zero shadow vs nonzero low: finite, bounded — measured
    # absolutely, never |low|/eps blow-up
    assert 0.0 < dev(1e-3, 0.0) <= 2.0
    assert 0.0 < dev(2.0, 0.0) <= 2.0
    # denormal noise around a denormal shadow is invisible, not infinite
    assert dev(1e-40, 0.0) < 1e-3
    assert dev(0.0, 1e-40) < 1e-3
    # equal lanes are exactly zero deviation — including both-inf
    assert dev(0.0, 0.0) == 0.0
    assert dev(jnp.inf, jnp.inf) == 0.0
    # genuine finiteness disagreement is maximal
    assert dev(jnp.inf, 3e9) == float("inf")
    assert dev(jnp.nan, 1.0) == float("inf")
    # ordinary relative deviation in the normal regime is preserved
    assert dev(1.0, 1.001) == pytest.approx(1e-3, rel=1e-2)


def test_zero_crossing_input_does_not_poison_max_rel():
    """End-to-end regression with a zero-crossing shadow value: two
    different op orders produce the same exact shadow but different
    truncated values, so the subtraction site sees shadow == 0 with a
    nonzero low lane. max_rel must stay finite and bounded."""
    def f(x):
        with scope("zc"):
            u = (x * jnp.asarray(1.1, x.dtype)) * jnp.asarray(5.0, x.dtype)
            v = (x * jnp.asarray(5.0, x.dtype)) * jnp.asarray(1.1, x.dtype)
            d = u - v          # shadow: exactly 0; low: quantized u != v
        return jnp.sum(d)

    x = jnp.asarray([2.0, 4.0], jnp.float32)
    out, rep = memtrace(f, TruncationPolicy.everywhere(E5M2), threshold=1e-3)(x)
    mr = np.asarray(jax.device_get(rep.max_rel))
    # the shadow subtraction really is a zero crossing and the low lane
    # really deviates (otherwise this regression tests nothing)
    assert int(jnp.sum(rep.flags)) > 0
    assert np.all(np.isfinite(mr)), mr
    assert np.all(mr <= 2.0), mr


def test_while_loop_error_appearing_after_iteration_k():
    """Per-site stats must reflect ALL while iterations (threaded via the
    carry): an error that only appears from iteration k>1 is flagged, and
    op counts cover every trip."""
    k, n = 2, 5

    def f(x):
        def cond(c):
            return c[0] < n

        def body(c):
            i, v = c
            with scope("w"):
                # x2.0 is exact in e5m2; x1.09 rounds — error exists only
                # from iteration k onward
                fac = jnp.where(i < k, jnp.asarray(2.0, v.dtype),
                                jnp.asarray(1.09, v.dtype))
                v = v * fac
            return (i + 1, v)

        return jnp.sum(lax.while_loop(cond, body, (jnp.int32(0), x))[1])

    x = jnp.asarray([1.0, 2.0], jnp.float32)
    out, rep = memtrace(f, TruncationPolicy.everywhere(E5M2), threshold=1e-3)(x)
    (i,) = [j for j, l in enumerate(rep.locations) if l.startswith("w ")]
    ops = np.asarray(jax.device_get(rep.op_counts))
    flags = np.asarray(jax.device_get(rep.flags))
    assert ops[i] == 2 * n            # every iteration counted
    # iterations k..n-1 all deviate on both elements
    assert flags[i] == 2 * (n - k)


def test_cond_branch_stats_accumulate_across_scan_iterations():
    """Stats ride the switch operand through every scan trip: errors from
    both branches accumulate, whichever iteration selects them."""
    def f(x):
        def body(c, t):
            def exact(v):
                with scope("b_exact"):
                    return v * jnp.asarray(2.0, v.dtype)

            def lossy(v):
                with scope("b_lossy"):
                    return v * jnp.asarray(1.09, v.dtype)

            return lax.switch(t % 2, [exact, lossy], c), None

        y, _ = lax.scan(body, x, jnp.arange(4, dtype=jnp.int32))
        return jnp.sum(y)

    x = jnp.asarray([1.0, 2.0], jnp.float32)
    out, rep = memtrace(f, TruncationPolicy.everywhere(E5M2), threshold=1e-3)(x)
    by = {l.split(" ")[0]: i for i, l in enumerate(rep.locations)}
    ops = np.asarray(jax.device_get(rep.op_counts))
    flags = np.asarray(jax.device_get(rep.flags))
    # each branch ran twice over 2 elements
    assert ops[by["b_exact"]] == 4 and ops[by["b_lossy"]] == 4
    # the lossy branch deviates on both its trips (t=1, t=3); the exact
    # branch is clean on t=0 but inherits the drifted carry on t=2 — the
    # shadow lane measures accumulated divergence, per iteration
    assert flags[by["b_lossy"]] == 4
    assert flags[by["b_exact"]] == 2
