"""Chaos acceptance tier (@chaos, excluded from tier-1): inject overflow
faults at the top blamed sites of a live run and assert the guardrail loop
holds end to end —

  * the unguarded run demonstrably diverges (non-finite or >10x loss),
  * the guarded run detects the fault, escalates the blamed sites in the
    runtime table (zero recompiles, asserted via the jit cache), rolls back
    to the last durable checkpoint, and lands within 10% of the fault-free
    final loss,
  * every intervention is recorded in a GuardrailLog that round-trips
    through the deployed PolicyArtifact's provenance.

Every run dumps its GuardrailLog into $RAPTOR_ARTIFACTS_DIR (default
``chaos-artifacts/``); the CI chaos job uploads the directory on failure so
a red run explains exactly which interventions fired (or didn't).
"""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.core  # noqa: F401
from repro.apps import get_app
from repro.artifacts import load_artifact_file
from repro.checkpoint.checkpointer import Checkpointer
from repro.guardrails import (
    FaultPlan, FaultSpec, GuardedTrainer, GuardrailConfig, GuardrailLog,
    make_guarded_app_loop, sites_for_scope,
)
from repro.guardrails.monitor import probe_blame
from repro.kernels.quantize_em.ops import IDENTITY_ROW
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import (
    TrainConfig, init_opt_state, make_hotswap_train_step,
)

pytestmark = pytest.mark.chaos

REPO = os.path.join(os.path.dirname(__file__), "..")
# 30 steps keeps the comparison in the smooth-descent region of the bench
# loss curve; past ~step 40 (lr=1e-2, fixed batch) the run enters a noisy
# plateau where point-wise loss comparison is meaningless.
N_STEPS, FAULT_STEP = 30, 12


def _dump_log(name: str, log: GuardrailLog) -> str:
    out = os.environ.get("RAPTOR_ARTIFACTS_DIR", "chaos-artifacts")
    path = os.path.join(out, f"{name}.json")
    log.save(path)
    return path


def _top_blamed_sites(blame, site_index, top_k=2):
    """Top-``top_k`` blamed scopes -> their table rows; ranked worst-first by
    the trajectory profile, exactly what the paper's blame ranking names."""
    sites, scopes = [], []
    for b in blame:
        if not b.scope:
            continue
        rows = sites_for_scope(site_index, b.scope)
        if rows:
            scopes.append(b.scope)
            sites.extend(r for r in rows if r not in sites)
        if len(scopes) >= top_k:
            break
    return sites, scopes


def test_bench_model_overflow_fault_guarded_recovery(tmp_path):
    from benchmarks.common import bench_model, bench_batch

    cfg, model, params = bench_model()
    batch = bench_batch(cfg)
    art = load_artifact_file(
        os.path.join(REPO, "artifacts", "bench_model.json"))
    tc = TrainConfig(optimizer=AdamWConfig(lr=1e-2), policy=art.policy)

    # ---- blame ranking picks the fault targets ---------------------------
    blame, _peak = probe_blame(model.loss, art.policy, (params, batch),
                               threshold=1e-4, n_steps=3)
    step_fn, sites = make_hotswap_train_step(model, tc, art.policy,
                                             params, batch)
    fault_sites, fault_scopes = _top_blamed_sites(blame, sites)
    assert fault_sites, f"blame ranking found no faultable sites: {blame}"

    def plan():
        return FaultPlan([FaultSpec(site=s, step=FAULT_STEP, kind="overflow")
                          for s in fault_sites])

    # ---- unguarded: same executable, faults applied, nobody watching -----
    jit_step = jax.jit(step_fn)
    p, o = params, init_opt_state(model, params, tc)
    table = sites.table_for(art.policy)
    fp = plan()
    unguarded_loss = None
    for step in range(N_STEPS):
        table, _ = fp.apply(table, step)
        p, o, m = jit_step(p, o, batch, jnp.int32(step),
                           jnp.asarray(table, jnp.int32))
        unguarded_loss = float(m["loss"])
        if not np.isfinite(unguarded_loss):
            break

    # ---- guarded: fault-free reference, then the faulted run -------------
    def run(fault_plan, ckdir):
        ck = Checkpointer(str(ckdir), async_save=False)
        gt = GuardedTrainer(model, tc, art, params, lambda step: batch,
                            checkpointer=ck,
                            cfg=GuardrailConfig(save_every=5),
                            fault_plan=fault_plan)
        return gt.run(N_STEPS), gt

    r0, _ = run(None, tmp_path / "ff")
    rg, gt = run(plan(), tmp_path / "guarded")
    _dump_log("bench_model_fault_free", r0.log)
    _dump_log("bench_model_guarded", rg.log)

    # acceptance: unguarded diverges, guarded recovers within 10%
    diverged = (not np.isfinite(unguarded_loss)
                or unguarded_loss > 10 * abs(r0.final_loss))
    assert diverged, (f"unguarded run did not diverge (loss "
                      f"{unguarded_loss} vs fault-free {r0.final_loss}) — "
                      f"faulted sites {fault_sites} ({fault_scopes})")
    assert np.isfinite(rg.final_loss)
    assert abs(rg.final_loss - r0.final_loss) <= 0.10 * abs(r0.final_loss), \
        (rg.final_loss, r0.final_loss)

    # escalation was table-only: one executable, zero recompiles
    assert gt.cache_size() == 1

    # every intervention in the artifact-attached log
    kinds = rg.log.kinds()
    assert kinds["fault_injected"] == len(fault_sites)
    assert kinds.get("alarm", 0) >= 1
    assert kinds.get("escalate_sites", 0) >= 1
    assert rg.rollbacks >= 1 and kinds.get("rollback", 0) == rg.rollbacks
    audited = rg.log.attach(art)
    assert GuardrailLog.from_artifact(audited).to_json() == rg.log.to_json()
    # the faulted rows were widened by the ladder
    for s in fault_sites:
        assert np.array_equal(rg.table[s], IDENTITY_ROW)


def test_sod_app_overflow_fault_guarded_recovery(tmp_path):
    app = get_app("sod", n_cells=32, t_end=0.2)     # 32 solver steps
    policy = app.uniform_policy("e8m5")

    # blame the app's own trajectory profile to pick the fault targets
    _obs, traj = app.profile_trajectory(policy=policy, threshold=1e-6)
    blame = traj.blame(1e-6)

    def build(fault_plan, ckdir):
        ck = Checkpointer(str(ckdir), async_save=False)
        return make_guarded_app_loop(
            app, policy, checkpointer=ck, fault_plan=fault_plan,
            cfg=GuardrailConfig(save_every=5, warmup=4, window=8))

    loop0, sweep = build(None, tmp_path / "ff")
    handle0 = sweep(app.init_state())
    fault_sites, fault_scopes = _top_blamed_sites(blame, handle0)
    if not fault_sites:          # blame may rank harness-only scopes
        fault_sites = [0, 1]

    def plan():
        return FaultPlan([FaultSpec(site=s, step=10, kind="overflow")
                          for s in fault_sites])

    # unguarded: drive the same sweep executable with the faulted table
    table = np.asarray(handle0.table(policy), np.int32)
    fp = plan()
    state = app.init_state()
    for step in range(app.n_steps):
        table, _ = fp.apply(table, step)
        state = sweep(state)(jnp.asarray(table, jnp.int32))
    unguarded_sig = max(float(jnp.max(jnp.abs(leaf)))
                        for leaf in jax.tree_util.tree_leaves(state))
    assert not np.isfinite(unguarded_sig), \
        f"unguarded sod run stayed finite under faults at {fault_sites}"

    # guarded: fault-free reference vs faulted run
    res0 = loop0.run(app.n_steps)
    loopg, _ = build(plan(), tmp_path / "guarded")
    resg = loopg.run(app.n_steps)
    _dump_log("sod_fault_free", res0.log)
    _dump_log("sod_guarded", resg.log)

    assert np.isfinite(resg.final_loss)
    err = app.error_metric(app.observables(res0.state),
                           app.observables(resg.state))
    assert err <= 0.10, f"guarded sod deviates {err:.3g} from fault-free"
    kinds = resg.log.kinds()
    assert kinds["fault_injected"] == len(fault_sites)
    assert kinds.get("rollback", 0) >= 1
    for s in fault_sites:
        assert np.array_equal(resg.table[s], IDENTITY_ROW)
