"""Bench regression gate (CI satellite): compare.py must pass on
unchanged metrics, demonstrably fail on an injected 2x slowdown, and
benchmarks/run.py must exit nonzero when a benchmark raises (no more
green jobs on partial artifacts)."""
import json
import os

from benchmarks import run as bench_run
from benchmarks.compare import GATED, compare, load_artifacts, main as gate_main


def _artifact(name, rows):
    return {"benchmark": name, "wall_s": 1.0, "meta": {},
            "rows": [{"name": n, "us_per_call": v, "derived": {}}
                     for n, v in rows.items()]}


def _write(dirpath, art):
    os.makedirs(dirpath, exist_ok=True)
    with open(os.path.join(dirpath, f"BENCH_{art['benchmark']}.json"),
              "w") as f:
        json.dump(art, f)


BASE_ROWS = {"truncate_cached_call": 100.0,
             "policy_sweep_per_candidate_table": 200.0,
             "policy_sweep_per_candidate_steady": 50.0,
             "autosearch_wall_us": 1e6,
             "autosearch_truncated_flops_pct": 90.0}  # not gated


def test_gate_passes_on_identical_and_noise_within_threshold(tmp_path):
    base = tmp_path / "base"
    fresh = tmp_path / "fresh"
    _write(base, _artifact("search_convergence", BASE_ROWS))
    _write(fresh, _artifact("search_convergence",
                            {k: v * 1.2 for k, v in BASE_ROWS.items()}))
    failures = compare(load_artifacts(str(base)), load_artifacts(str(fresh)),
                       0.25, log=lambda *_: None)
    assert failures == []
    assert gate_main([str(base), str(fresh)]) == 0


def test_gate_fails_on_injected_2x_slowdown(tmp_path):
    """The acceptance check: a 2x regression on a gated metric must fail."""
    base = tmp_path / "base"
    fresh = tmp_path / "fresh"
    _write(base, _artifact("search_convergence", BASE_ROWS))
    slow = dict(BASE_ROWS)
    slow["policy_sweep_per_candidate_table"] *= 2.0
    _write(fresh, _artifact("search_convergence", slow))
    failures = compare(load_artifacts(str(base)), load_artifacts(str(fresh)),
                       0.25, log=lambda *_: None)
    assert len(failures) == 1
    assert "policy_sweep_per_candidate_table" in failures[0]
    assert gate_main([str(base), str(fresh)]) == 1


def test_gate_ignores_ungated_regressions(tmp_path):
    """Counts/percentages (not opted into GATED) never fail the gate."""
    base = tmp_path / "base"
    fresh = tmp_path / "fresh"
    _write(base, _artifact("search_convergence", BASE_ROWS))
    noisy = dict(BASE_ROWS)
    noisy["autosearch_truncated_flops_pct"] *= 10
    _write(fresh, _artifact("search_convergence", noisy))
    assert compare(load_artifacts(str(base)), load_artifacts(str(fresh)),
                   0.25, log=lambda *_: None) == []


def test_gate_fails_on_missing_fresh_artifact(tmp_path):
    """A gated benchmark that silently didn't run must fail the gate (the
    failure mode the run.py bugfix closes at the producer end)."""
    base = tmp_path / "base"
    fresh = tmp_path / "fresh"
    _write(base, _artifact("search_convergence", BASE_ROWS))
    os.makedirs(fresh, exist_ok=True)
    failures = compare(load_artifacts(str(base)), load_artifacts(str(fresh)),
                       0.25, log=lambda *_: None)
    assert failures and "missing" in failures[0]


def test_gate_direction_higher_is_better(tmp_path):
    base = tmp_path / "base"
    fresh = tmp_path / "fresh"
    _write(base, _artifact("tp", {"throughput": 100.0}))
    _write(fresh, _artifact("tp", {"throughput": 40.0}))
    gated = {"tp": {"throughput": "higher"}}
    failures = compare(load_artifacts(str(base)), load_artifacts(str(fresh)),
                       0.25, gated=gated, log=lambda *_: None)
    assert len(failures) == 1
    ok = compare(load_artifacts(str(base)), load_artifacts(str(base)),
                 0.25, gated=gated, log=lambda *_: None)
    assert ok == []


KERNEL_ROWS = {"quantize_e5m7_4M": 1000.0,
               "flash_attn_B1H8S1024D64": 5000.0,
               "wkv6_B1H8S512hd64": 800.0}


def test_gate_normalizes_out_a_uniformly_slower_machine(tmp_path):
    """Committed baselines come from a different machine than CI: a uniform
    3x slowdown (runner hardware) moves the calibration row too and must
    PASS, while the same fresh artifacts with an ADDITIONAL 2x regression
    in a search metric must still FAIL."""
    base = tmp_path / "base"
    fresh = tmp_path / "fresh"
    _write(base, _artifact("search_convergence", BASE_ROWS))
    _write(base, _artifact("kernels_micro", KERNEL_ROWS))
    _write(fresh, _artifact("search_convergence",
                            {k: v * 3.0 for k, v in BASE_ROWS.items()}))
    _write(fresh, _artifact("kernels_micro",
                            {k: v * 3.0 for k, v in KERNEL_ROWS.items()}))
    failures = compare(load_artifacts(str(base)), load_artifacts(str(fresh)),
                       0.25, log=lambda *_: None)
    assert failures == [], failures

    worse = {k: v * 3.0 for k, v in BASE_ROWS.items()}
    worse["autosearch_wall_us"] *= 2.0          # real regression on top
    _write(fresh, _artifact("search_convergence", worse))
    failures = compare(load_artifacts(str(base)), load_artifacts(str(fresh)),
                       0.25, log=lambda *_: None)
    assert len(failures) == 1 and "autosearch_wall_us" in failures[0]


def test_gate_calibration_row_catches_catastrophic_kernel_regression(
        tmp_path):
    """The calibration row is gated un-normalized with the loose threshold:
    5x on the quantize kernel itself fails even though it IS the machine
    factor."""
    base = tmp_path / "base"
    fresh = tmp_path / "fresh"
    _write(base, _artifact("kernels_micro", KERNEL_ROWS))
    broken = dict(KERNEL_ROWS)
    broken["quantize_e5m7_4M"] *= 5.0
    _write(fresh, _artifact("kernels_micro", broken))
    failures = compare(load_artifacts(str(base)), load_artifacts(str(fresh)),
                       0.25, log=lambda *_: None)
    assert any("quantize_e5m7_4M" in f for f in failures), failures


def test_gate_zero_baseline_is_no_gate_warn(tmp_path):
    """Bugfix: a 0/negative/NaN baseline value (placeholder row of a
    freshly-added benchmark) must warn and skip — never ZeroDivisionError
    or fail the gate — while other rows in the same artifact stay gated."""
    base, fresh = str(tmp_path / "b"), str(tmp_path / "f")
    rows = dict(BASE_ROWS)
    rows["truncate_cached_call"] = 0.0            # zero baseline
    rows["policy_sweep_per_candidate_table"] = float("nan")
    _write(base, _artifact("search_convergence", rows))
    _write(fresh, _artifact("search_convergence", BASE_ROWS))
    logs = []
    failures = compare(load_artifacts(base), load_artifacts(fresh),
                       0.25, log=logs.append)
    assert failures == [], failures
    assert any("truncate_cached_call" in l and "not gated" in l
               for l in logs), logs
    assert any("policy_sweep_per_candidate_table" in l
               and "not gated" in l for l in logs), logs
    # ...but a real regression on a row with a usable baseline in the
    # same artifact still fails
    slow = dict(BASE_ROWS)
    slow["autosearch_wall_us"] *= 2.0
    _write(fresh, _artifact("search_convergence", slow))
    failures = compare(load_artifacts(base), load_artifacts(fresh),
                       0.25, log=lambda *_: None)
    assert len(failures) == 1 and "autosearch_wall_us" in failures[0]


def test_gate_nonfinite_fresh_value_fails_loudly(tmp_path):
    """A NaN/inf fresh measurement is a broken benchmark, not a pass."""
    base = tmp_path / "base"
    fresh = tmp_path / "fresh"
    _write(base, _artifact("search_convergence", BASE_ROWS))
    broken = dict(BASE_ROWS)
    broken["autosearch_wall_us"] = float("inf")
    _write(fresh, _artifact("search_convergence", broken))
    failures = compare(load_artifacts(str(base)), load_artifacts(str(fresh)),
                       0.25, log=lambda *_: None)
    assert len(failures) == 1 and "not finite" in failures[0]


def test_gate_freshly_added_benchmark_without_baseline_warns(tmp_path):
    """Bugfix: a benchmark newly added to GATED whose baseline is not
    committed yet must not crash (KeyError) or fail — it warns that the
    gate is unarmed until the baseline lands."""
    base = tmp_path / "base"
    fresh = tmp_path / "fresh"
    _write(base, _artifact("search_convergence", BASE_ROWS))
    _write(fresh, _artifact("search_convergence", BASE_ROWS))
    _write(fresh, _artifact("brand_new_bench", {"hot_loop": 10.0}))
    gated = {"search_convergence": GATED["search_convergence"],
             "brand_new_bench": {"hot_loop": "lower"}}
    logs = []
    failures = compare(load_artifacts(str(base)), load_artifacts(str(fresh)),
                       0.25, gated=gated, log=logs.append)
    assert failures == [], failures
    assert any("brand_new_bench" in l and "no committed baseline" in l
               for l in logs), logs


def test_load_artifacts_skips_malformed_rows(tmp_path):
    """Derived-only rows (no us_per_call) or non-numeric values must not
    KeyError the whole gate."""
    art = {"benchmark": "weird", "wall_s": 1.0, "meta": {},
           "rows": [{"name": "ok", "us_per_call": 5.0, "derived": {}},
                    {"name": "derived_only", "derived": {"n": 3}},
                    {"us_per_call": 1.0},
                    {"name": "stringy", "us_per_call": "fast"}]}
    os.makedirs(tmp_path, exist_ok=True)
    with open(os.path.join(tmp_path, "BENCH_weird.json"), "w") as f:
        json.dump(art, f)
    arts = load_artifacts(str(tmp_path))
    assert arts["weird"] == {"ok": 5.0}


def test_committed_baselines_cover_the_gated_ci_benchmarks():
    """The gate only has teeth if baselines for the gated benchmarks are
    committed; keep GATED and benchmarks/baselines/ in sync."""
    here = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "baselines")
    arts = load_artifacts(here)
    for bench, rules in GATED.items():
        assert bench in arts, f"no committed baseline for gated '{bench}'"
        for row in rules:
            assert row in arts[bench], f"baseline {bench} lacks row '{row}'"


def test_run_py_exits_nonzero_when_a_benchmark_raises(tmp_path):
    """Bugfix: a raising benchmark must fail the run (exit nonzero), not
    write its artifact — while later benchmarks still run and write theirs."""
    calls = []

    def ok():
        from benchmarks.common import csv_row
        calls.append("ok")
        csv_row("fine", 1.0, "x=1")

    def boom():
        calls.append("boom")
        raise RuntimeError("injected benchmark failure")

    failures = bench_run.run_benches(
        [("boom", boom), ("ok", ok)], only=None, out_dir=str(tmp_path))
    assert [n for n, _ in failures] == ["boom"]
    assert calls == ["boom", "ok"]          # later benchmarks still ran
    assert not (tmp_path / "BENCH_boom.json").exists()
    assert (tmp_path / "BENCH_ok.json").exists()
    # and main()'s contract: failures -> nonzero exit status
    assert bench_run.run_benches([("ok", ok)], None, str(tmp_path)) == []
