import os
import sys
import types

# tests must see exactly ONE device (the dry-run sets its own flags in a
# subprocess); keep any user XLA_FLAGS out of the way.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


# ---------------------------------------------------------------------------
# hypothesis fallback shim: the container may not ship hypothesis (see
# requirements-dev.txt). Property tests then collect but skip gracefully
# instead of killing the whole run at import time.
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ImportError:
    import pytest

    class _Strategy:
        """Opaque stand-in: supports the combinator surface tests use at
        module scope (map/filter/flatmap chains) without generating data."""

        def map(self, f):
            return self

        def filter(self, f):
            return self

        def flatmap(self, f):
            return self

    _STRATEGY = _Strategy()

    def _given(*_a, **_k):
        def deco(fn):
            def skipped():
                pytest.skip("hypothesis not installed "
                            "(pip install -r requirements-dev.txt)")
            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            skipped.__module__ = fn.__module__
            return skipped
        return deco

    def _settings(*_a, **_k):
        if len(_a) == 1 and callable(_a[0]) and not _k:
            return _a[0]
        return lambda fn: fn

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.assume = lambda *_a, **_k: True
    _hyp.example = lambda *_a, **_k: (lambda fn: fn)
    _hyp.HealthCheck = types.SimpleNamespace(all=lambda: [])

    _st = types.ModuleType("hypothesis.strategies")

    def _make_strategy(*_a, **_k):
        return _STRATEGY

    for _name in ("integers", "floats", "lists", "tuples", "booleans",
                  "sampled_from", "one_of", "just", "text", "binary",
                  "composite", "builds", "none", "dictionaries"):
        setattr(_st, _name, _make_strategy)

    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
