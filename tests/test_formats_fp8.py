"""E4M3/E4M3FN registry audit against ml_dtypes/OCP conventions.

OCP e4m3fn has no inf (the top exponent is reclaimed for normals, all-ones
mantissa at the top exponent is NaN): max finite 448, smallest subnormal
2^-9. Our two registry entries share that grid and differ only in overflow
handling — E4M3FN maps overflow to NaN like an ml_dtypes cast, E4M3
saturates to +/-448. These tests pin the grid bit-for-bit to the reference
implementation.
"""
import numpy as np
import pytest

import jax.numpy as jnp

ml_dtypes = pytest.importorskip("ml_dtypes")

from repro.core.formats import E4M3, E4M3FN, E5M2
from repro.kernels.quantize_em.ops import quantize


def _all_fp8_values(dtype):
    bits = np.arange(256, dtype=np.uint8)
    return bits.view(dtype).astype(np.float32)


def test_registry_constants_match_ml_dtypes():
    fi = ml_dtypes.finfo(ml_dtypes.float8_e4m3fn)
    for fmt in (E4M3, E4M3FN):
        assert fmt.max_finite == float(fi.max)
        assert fmt.min_normal == float(fi.smallest_normal)
        assert fmt.min_subnormal == float(fi.smallest_subnormal)
        assert fmt.bits == 8
    fi2 = ml_dtypes.finfo(ml_dtypes.float8_e5m2)
    assert E5M2.max_finite == float(fi2.max)
    assert E5M2.min_subnormal == float(fi2.smallest_subnormal)


@pytest.mark.parametrize("fmt", [E4M3, E4M3FN], ids=["e4m3", "e4m3fn"])
def test_grid_fixed_points(fmt):
    """Every finite ml_dtypes e4m3fn value must be a fixed point of our
    quantizer — the representable grids are identical."""
    vals = _all_fp8_values(ml_dtypes.float8_e4m3fn)
    finite = vals[np.isfinite(vals)]
    q = np.asarray(quantize(jnp.asarray(finite), fmt, impl="ref"))
    np.testing.assert_array_equal(q, finite)


def test_e4m3fn_cast_agreement():
    """quantize(x, E4M3FN) == f32 -> float8_e4m3fn -> f32 for finite x,
    including the rounding boundaries around overflow (464 is the midpoint
    between 448 and the absent 512: at-or-below rounds down, above is NaN)."""
    rng = np.random.RandomState(0)
    x = np.concatenate([
        rng.randn(2048).astype(np.float32)
        * 10 ** rng.uniform(-6, 4, 2048).astype(np.float32),
        np.array([448.0, 449.0, 463.9, 464.0, 464.0001, 465.0, 1000.0,
                  -448.0, -464.0, -465.0, 2.0 ** -9, 2.0 ** -10,
                  1.5 * 2.0 ** -9, 0.0, -0.0], np.float32)])
    ours = np.asarray(quantize(jnp.asarray(x), E4M3FN, impl="ref"))
    with np.errstate(over="ignore"):
        theirs = x.astype(ml_dtypes.float8_e4m3fn).astype(np.float32)
    same = ((ours == theirs) | (np.isnan(ours) & np.isnan(theirs))
            | ((ours == 0) & (theirs == 0)))
    bad = np.where(~same)[0]
    assert len(bad) == 0, [(x[i], ours[i], theirs[i]) for i in bad[:5]]


def test_e4m3_saturates_where_fn_nans():
    x = jnp.asarray([465.0, 1000.0, -2048.0, np.inf, -np.inf], jnp.float32)
    sat = np.asarray(quantize(x, E4M3, impl="ref"))
    fn = np.asarray(quantize(x, E4M3FN, impl="ref"))
    # documented convention: inf passes through both (profiling wants the
    # overflow signal); finite overflow differs
    np.testing.assert_array_equal(sat[:3], [448.0, 448.0, -448.0])
    assert np.all(np.isnan(fn[:3]))
    assert np.isinf(sat[3]) and np.isinf(fn[3])


def test_e4m3_subnormal_grid():
    """Gradual underflow onto the 2^-9 fixed-point grid, RNE."""
    step = 2.0 ** -9
    x = jnp.asarray([0.5 * step, 1.5 * step, 2.5 * step, 0.49 * step,
                     3.1 * step], jnp.float32)
    q = np.asarray(quantize(x, E4M3FN, impl="ref"))
    np.testing.assert_allclose(q, [0.0, 2 * step, 2 * step, 0.0, 3 * step])
