"""Policy/artifact linter (``repro.analysis.lint``): structural coverage
rules, model-aware dead/shadowed detection, the Registry publish gate,
the policy-drift pre-search lint, and zero findings on everything this
repo commits (artifacts + each config's frontier policy)."""
import glob

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.analysis.lint import (
    ArtifactLintError, Finding, covers, lint_artifact, lint_policy, main,
)
from repro.artifacts import PolicyArtifact, load_artifact_file, \
    save_artifact_file
from repro.artifacts.registry import Registry
from repro.core import interpreter
from repro.core.policy import (
    TruncationPolicy, TruncationRule, magnitude_below,
)


def _codes(findings, level=None):
    return [f.code for f in findings
            if level is None or f.level == level]


# --------------------------------------------------------------------------
# structural coverage
# --------------------------------------------------------------------------


def test_covers_scope_prefix_and_wildcards():
    r = lambda scope, **kw: TruncationRule(fmt="bf16", scope=scope, **kw)
    assert covers(r("**"), r("a/b"))
    assert covers(r("hydro"), r("hydro/flux"))     # scope match extends over /
    assert covers(r("hydro/*"), r("hydro/flux"))
    assert not covers(r("hydro/flux"), r("hydro"))
    # pb with wildcards is never provably covered except exact/** cases
    assert not covers(r("hydro"), r("hydro/*"))
    assert covers(r("hydro/*"), r("hydro/*"))


def test_covers_ops_and_width_filters():
    r = lambda **kw: TruncationRule(fmt="bf16", scope="x", **kw)
    assert covers(r(), r(ops=("add",)))
    assert not covers(r(ops=("add",)), r())
    assert covers(r(ops=("add", "mul")), r(ops=("add",)))
    assert not covers(r(ops=("add",)), r(ops=("add", "mul")))
    assert covers(r(exclude_ops=("add",)), r(ops=("mul",)))
    assert not covers(r(exclude_ops=("add",)), r(ops=("add", "mul")))
    assert covers(r(exclude_ops=("add",)), r(exclude_ops=("add", "mul")))
    assert not covers(r(from_width=32), r(from_width=16))
    assert covers(r(from_width=32), r(from_width=32))
    assert not covers(r(from_width=32), r())


def test_seeded_shadowed_rule_is_caught():
    """The canonical fixture: 'hydro' before 'hydro/flux' makes the second
    rule dead under first-match-wins."""
    pol = TruncationPolicy(rules=(
        TruncationRule(fmt="bf16", scope="hydro"),
        TruncationRule(fmt="e5m2", scope="hydro/flux")))
    findings = lint_policy(pol)
    assert _codes(findings) == ["shadowed-rule"]
    assert findings[0].rule_index == 1
    # swapped order (specific first) is clean
    assert not lint_policy(TruncationPolicy(rules=tuple(pol.rules[::-1])))


def test_excluded_rule_is_caught():
    pol = TruncationPolicy(rules=(
        TruncationRule(fmt="bf16", scope="hydro/flux"),),
        excludes=("hydro",))
    assert _codes(lint_policy(pol)) == ["excluded-rule"]


def test_mask_rule_level_depends_on_serialization_requirement():
    pol = TruncationPolicy(rules=(
        TruncationRule(fmt="bf16", scope="x", mask=magnitude_below(1.0)),))
    assert _codes(lint_policy(pol), "warning") == ["mask-not-serializable"]
    strict = lint_policy(pol, serializable_required=True)
    assert _codes(strict, "error") == ["mask-not-serializable"]


# --------------------------------------------------------------------------
# model-aware checks
# --------------------------------------------------------------------------


def _traced():
    from repro.core import scope

    def f(x, w):
        with scope("blk"):
            with scope("mm"):
                h = x @ w
            h = jnp.tanh(h)
        return jnp.sum(h * h)

    x = np.float32(np.ones((4, 8))) * 1e20
    w = np.float32(np.ones((8, 4))) * 1e20
    closed = jax.make_jaxpr(f)(x, w)
    everywhere = TruncationPolicy(rules=(
        TruncationRule(fmt="e8m0", scope="**"),))
    return closed, interpreter.enumerate_sites(closed, everywhere), [x, w]


def test_dead_and_model_shadowed_rules():
    closed, index, _ = _traced()
    pol = TruncationPolicy(rules=(
        TruncationRule(fmt="bf16", scope="blk"),
        TruncationRule(fmt="e5m2", scope="blk/mm", ops=("dot_general",)),
        TruncationRule(fmt="e5m2", scope="no/such/region")))
    findings = lint_policy(pol, sites=index.sites)
    # rule 1 structurally survives ('blk' doesn't cover the ops filter?
    # it does: no ops filter on rule 0 -> structural shadow), rule 2 is dead
    by_rule = {f.rule_index: f.code for f in findings}
    assert by_rule[1] == "shadowed-rule"
    assert by_rule[2] == "dead-rule"


def test_dot_accumulator_risk():
    from repro.analysis import analyze_closed
    closed, index, args = _traced()
    res = analyze_closed(closed, args)
    risky = TruncationPolicy(rules=(
        TruncationRule(fmt="bf16", scope="blk/mm",
                       quantize_dot_inputs=True),))
    findings = lint_policy(risky, sites=index.sites,
                           analysis_result=res, index=index)
    assert "dot-accumulator-risk" in _codes(findings, "warning")
    # a saturating narrow input format clamps the operands into safety
    safe = TruncationPolicy(rules=(
        TruncationRule(fmt="e4m3", scope="blk/mm",
                       quantize_dot_inputs=True),))
    findings = lint_policy(safe, sites=index.sites,
                           analysis_result=res, index=index)
    assert "dot-accumulator-risk" not in _codes(findings)


def test_artifact_scope_drift():
    from repro.artifacts.artifact import ScopeRow
    art = PolicyArtifact(
        name="m", policy=TruncationPolicy.everywhere("e5m7"),
        assignments={"gone/scope": ScopeRow(man_bits=7,
                                            error_at_accept=0.0)})
    findings = lint_artifact(art, scopes=["live/scope"])
    assert _codes(findings, "error") == ["scope-drift-missing"]
    assert "scope-drift-new" in _codes(findings, "warning")
    assert not lint_artifact(art, scopes=["gone/scope"])


# --------------------------------------------------------------------------
# registry publish gate
# --------------------------------------------------------------------------


def test_registry_save_blocks_error_findings(tmp_path):
    reg = Registry(str(tmp_path))
    bad = PolicyArtifact(name="bad", policy=TruncationPolicy(rules=(
        TruncationRule(fmt="bf16", scope="x",
                       mask=magnitude_below(1.0)),)))
    with pytest.raises(ArtifactLintError) as ei:
        reg.save(bad)
    assert "mask-not-serializable" in str(ei.value)
    assert reg.versions("bad") == []          # nothing published


def test_registry_save_records_warnings_and_keeps_clean_digest(tmp_path):
    reg = Registry(str(tmp_path))
    clean = PolicyArtifact(name="ok",
                           policy=TruncationPolicy.everywhere("e5m7"))
    ref = reg.save(clean)
    back = reg.load(ref.ref)
    assert back.digest == clean.digest        # byte-identical publication
    assert "lint_warnings" not in back.provenance

    shadow = PolicyArtifact(name="warn", policy=TruncationPolicy(rules=(
        TruncationRule(fmt="bf16", scope="hydro"),
        TruncationRule(fmt="e5m2", scope="hydro/flux"))))
    pub = reg.load(reg.save(shadow).ref)
    assert any("shadowed-rule" in w
               for w in pub.provenance["lint_warnings"])
    assert ref.digest != pub.digest


# --------------------------------------------------------------------------
# policy-drift gate lints before searching
# --------------------------------------------------------------------------


def test_policy_drift_check_fails_fast_on_lint_error(tmp_path, monkeypatch,
                                                     capsys):
    from benchmarks import policy_drift
    from repro.artifacts.artifact import ScopeRow

    def boom():
        raise AssertionError("search ran despite a lint error")

    monkeypatch.setattr(policy_drift, "fresh_artifact", boom)
    monkeypatch.setattr(policy_drift, "_model_scope_paths",
                        lambda: ["live/scope"])
    art = PolicyArtifact(
        name="bench_model", policy=TruncationPolicy.everywhere("e5m7"),
        assignments={"gone/scope": ScopeRow(man_bits=7,
                                            error_at_accept=0.0)})
    path = str(tmp_path / "bench_model.json")
    save_artifact_file(art, path)
    assert policy_drift.main(["--committed", path]) == 1
    err = capsys.readouterr().err
    assert "scope-drift-missing" in err
    assert "fails lint" in err


# --------------------------------------------------------------------------
# CLI + everything this repo commits lints clean
# --------------------------------------------------------------------------


def test_cli_exit_codes(tmp_path, capsys):
    good = PolicyArtifact(name="good",
                          policy=TruncationPolicy.everywhere("e5m7"))
    save_artifact_file(good, str(tmp_path / "good.json"))
    assert main([str(tmp_path), "--no-model"]) == 0
    out = capsys.readouterr().out
    assert "clean" in out

    warn = PolicyArtifact(name="warn", policy=TruncationPolicy(rules=(
        TruncationRule(fmt="bf16", scope="hydro"),
        TruncationRule(fmt="e5m2", scope="hydro/flux"))))
    save_artifact_file(warn, str(tmp_path / "warn.json"))
    assert main([str(tmp_path), "--no-model"]) == 0          # warnings pass
    assert main([str(tmp_path), "--no-model", "--strict"]) == 1
    capsys.readouterr()

    (tmp_path / "broken.json").write_text("{not json")
    assert main([str(tmp_path / "broken.json")]) == 1
    assert "unreadable" in capsys.readouterr().out


def test_committed_artifacts_lint_clean():
    """Every artifact committed under artifacts/ must have zero findings —
    errors AND warnings (structural pass; CI runs the model-aware pass)."""
    files = sorted(glob.glob("artifacts/**/*.json", recursive=True))
    assert files, "no committed artifacts found (run from the repo root)"
    for path in files:
        art = load_artifact_file(path)
        findings = lint_artifact(art)
        assert not findings, (path, [f.render() for f in findings])


_FAST_ARCHS = ("h2o-danube-1.8b", "olmoe-1b-7b")


def _arch_params():
    from repro.configs.base import ARCH_IDS
    return [a if a in _FAST_ARCHS else pytest.param(
        a, marks=pytest.mark.slow) for a in ARCH_IDS]


@pytest.mark.parametrize("arch_id", _arch_params())
def test_config_default_policies_lint_clean(arch_id):
    """Each architecture's default deployment policy — one rule per
    discovered frontier scope of its traced loss — lints with zero
    findings against its own model (frontier scopes are disjoint, so
    nothing can shadow, die, or drift)."""
    from repro.configs.base import get_config
    from repro.models import Model
    from repro.search.scopes import discover_scopes
    from tests.test_arch_smoke import make_batch

    cfg = get_config(arch_id, "smoke")
    model = Model(cfg)
    rng = np.random.default_rng(0)
    params = model.init(jax.random.PRNGKey(0))
    closed = jax.make_jaxpr(model.loss)(params, make_batch(cfg, rng))
    paths = [s.path for s in discover_scopes(closed)]
    assert paths
    policy = TruncationPolicy(rules=tuple(
        TruncationRule(fmt="bf16", scope=p) for p in paths))
    everywhere = TruncationPolicy(rules=(
        TruncationRule(fmt="e8m0", scope="**"),))
    index = interpreter.enumerate_sites(closed, everywhere)
    findings = lint_policy(policy, sites=index.sites,
                           serializable_required=True)
    assert not findings, [f.render() for f in findings]


@pytest.mark.parametrize("app_name", ["sod", "heat", "poisson"])
def test_app_uniform_policies_lint_clean(app_name):
    from repro.apps import get_app
    app = get_app(app_name)
    assert not lint_policy(app.uniform_policy(), serializable_required=True)


def test_finding_render_is_stable():
    f = Finding(code="dead-rule", level="warning", message="m",
                scope="s", rule_index=3)
    assert f.render() == "WARNING dead-rule [rule #3]: m"
    g = Finding(code="scope-drift-missing", level="error", message="m",
                scope="s")
    assert g.render() == "ERROR scope-drift-missing [s]: m"
