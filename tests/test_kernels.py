"""Per-kernel shape/dtype sweeps against the pure-jnp oracles
(interpret=True executes the Pallas kernel bodies on CPU)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rwkv6.kernel import wkv6_pallas
from repro.kernels.rwkv6.ref import wkv6_ref
from repro.kernels.quantize_em.kernel import quantize_2d, LANES
from repro.kernels.quantize_em import ref as qref
from repro.models.attention import flash_attention as flash_xla


# ---- flash attention ---------------------------------------------------------

FLASH_CASES = [
    # B, Hq, Hkv, S, D, window, causal, dtype
    (2, 4, 2, 128, 32, None, True, jnp.float32),
    (1, 8, 8, 64, 16, None, True, jnp.float32),
    (2, 4, 1, 128, 32, 32, True, jnp.float32),
    (1, 2, 2, 256, 64, None, False, jnp.float32),
    (2, 6, 3, 128, 32, None, True, jnp.bfloat16),
    (1, 4, 4, 128, 128, 64, True, jnp.float32),
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_pallas_vs_ref(case):
    B, Hq, Hkv, S, D, win, causal, dtype = case
    r = np.random.RandomState(hash(case) % 2 ** 31)
    q = jnp.asarray(r.randn(B, Hq, S, D), dtype)
    k = jnp.asarray(r.randn(B, Hkv, S, D), dtype)
    v = jnp.asarray(r.randn(B, Hkv, S, D), dtype)
    o = flash_attention_pallas(q, k, v, causal=causal, window=win,
                               block_q=64, block_k=64, interpret=True)
    o_ref = attention_ref(q, k, v, causal=causal, window=win)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    assert float(jnp.max(jnp.abs(o.astype(jnp.float32)
                                 - o_ref.astype(jnp.float32)))) < tol


@pytest.mark.parametrize("case", FLASH_CASES[:4])
def test_flash_xla_vs_ref(case):
    B, Hq, Hkv, S, D, win, causal, dtype = case
    r = np.random.RandomState(hash(case) % 2 ** 31)
    q = jnp.asarray(r.randn(B, Hq, S, D), dtype)
    k = jnp.asarray(r.randn(B, Hkv, S, D), dtype)
    v = jnp.asarray(r.randn(B, Hkv, S, D), dtype)
    o = flash_xla(q, k, v, causal=causal, window=win, q_chunk=64, kv_chunk=64)
    o_ref = attention_ref(q, k, v, causal=causal, window=win)
    assert float(jnp.max(jnp.abs(o - o_ref))) < 2e-5


def test_flash_blocks_sweep():
    r = np.random.RandomState(0)
    q = jnp.asarray(r.randn(1, 2, 256, 32), jnp.float32)
    k = jnp.asarray(r.randn(1, 2, 256, 32), jnp.float32)
    v = jnp.asarray(r.randn(1, 2, 256, 32), jnp.float32)
    o_ref = attention_ref(q, k, v, causal=True)
    for bq, bk in [(64, 64), (128, 64), (64, 128), (256, 256)]:
        o = flash_attention_pallas(q, k, v, causal=True, block_q=bq,
                                   block_k=bk, interpret=True)
        assert float(jnp.max(jnp.abs(o - o_ref))) < 2e-5, (bq, bk)


# ---- rwkv6 -------------------------------------------------------------------

@pytest.mark.parametrize("case", [
    (2, 3, 64, 16, 16), (1, 2, 128, 32, 64), (2, 1, 32, 8, 32),
    (1, 4, 64, 64, 64),
])
def test_wkv6_pallas_vs_ref(case):
    B, H, S, hd, chunk = case
    r = np.random.RandomState(hash(case) % 2 ** 31)
    rr = jnp.asarray(r.randn(B, H, S, hd), jnp.float32)
    k = jnp.asarray(r.randn(B, H, S, hd), jnp.float32)
    v = jnp.asarray(r.randn(B, H, S, hd), jnp.float32)
    w = jnp.asarray(1 / (1 + np.exp(-r.randn(B, H, S, hd))), jnp.float32) * 0.98 + 0.01
    u = jnp.asarray(r.randn(H, hd) * 0.1, jnp.float32)
    s0 = jnp.asarray(r.randn(B, H, hd, hd) * 0.1, jnp.float32)
    y1, s1 = wkv6_pallas(rr, k, v, w, u, s0, chunk=chunk, interpret=True)
    y2, s2 = wkv6_ref(rr, k, v, w, u, s0)
    assert float(jnp.max(jnp.abs(y1 - y2))) < 1e-4
    assert float(jnp.max(jnp.abs(s1 - s2))) < 1e-4


def test_wkv6_chunk_invariance():
    """Different chunk sizes must give identical results (state carry)."""
    r = np.random.RandomState(7)
    B, H, S, hd = 1, 2, 128, 16
    rr = jnp.asarray(r.randn(B, H, S, hd), jnp.float32)
    k = jnp.asarray(r.randn(B, H, S, hd), jnp.float32)
    v = jnp.asarray(r.randn(B, H, S, hd), jnp.float32)
    w = jnp.asarray(1 / (1 + np.exp(-r.randn(B, H, S, hd))), jnp.float32)
    u = jnp.asarray(r.randn(H, hd) * 0.1, jnp.float32)
    s0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    outs = [wkv6_pallas(rr, k, v, w, u, s0, chunk=c, interpret=True)[0]
            for c in (16, 32, 128)]
    for o in outs[1:]:
        assert float(jnp.max(jnp.abs(o - outs[0]))) < 1e-4


# ---- quantize_em block shapes -------------------------------------------------

@pytest.mark.parametrize("rows", [1, 7, 8, 256, 1024])
@pytest.mark.parametrize("block_rows", [8, 256, 1024])
def test_quantize2d_block_sweep(rows, block_rows):
    if rows % min(block_rows, rows):
        pytest.skip("partial blocks handled by ops-level padding")
    r = np.random.RandomState(rows)
    x = jnp.asarray(r.randn(rows, LANES) * 1e3, jnp.float32)
    a = quantize_2d(x, exp_bits=5, man_bits=7, block_rows=block_rows,
                    interpret=True)
    b = qref.quantize_ref(x, 5, 7)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---- property: attention invariances -------------------------------------------

@given(seed=st.integers(0, 10 ** 6))
@settings(max_examples=10, deadline=None)
def test_flash_softmax_rowsum_property(seed):
    """Attention output of constant V must be that constant (softmax sums
    to 1 over the causal mask)."""
    r = np.random.RandomState(seed)
    q = jnp.asarray(r.randn(1, 2, 64, 16), jnp.float32)
    k = jnp.asarray(r.randn(1, 2, 64, 16), jnp.float32)
    v = jnp.ones((1, 2, 64, 16), jnp.float32) * 3.5
    o = flash_attention_pallas(q, k, v, causal=True, block_q=32, block_k=32,
                               interpret=True)
    assert float(jnp.max(jnp.abs(o - 3.5))) < 1e-5
