"""Quantizer correctness: ml_dtypes oracles, Pallas kernel sweeps, and
hypothesis property tests on the (e,m)-grid invariants."""
import ml_dtypes
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core.formats import (
    FPFormat, parse_format, BF16, FP16, E5M2, E4M3, E4M3FN,
)
from repro.kernels.quantize_em.ops import quantize
from repro.kernels.quantize_em.ref import quantize_ref_fmt


def _test_vector(n=4096, seed=0):
    rng = np.random.RandomState(seed)
    x = np.concatenate([
        rng.randn(n).astype(np.float32)
        * 10 ** rng.uniform(-12, 12, n).astype(np.float32),
        np.array([0.0, -0.0, 1.0, -1.0, np.inf, -np.inf, np.nan,
                  65504.0, 65505.0, 448.0, 464.0, 480.0, 3e-5,
                  5.96e-8, 2.98e-8, 1e-45, -1e-45, 2 ** -126, 2 ** -133],
                 np.float32)])
    return x.astype(np.float32)


HW = [(BF16, ml_dtypes.bfloat16), (FP16, np.float16),
      (E5M2, None), (E4M3FN, ml_dtypes.float8_e4m3fn)]


@pytest.mark.parametrize("impl", ["ref", "interpret"])
@pytest.mark.parametrize("fmt,mld", [
    (BF16, ml_dtypes.bfloat16), (FP16, np.float16),
    (E5M2, ml_dtypes.float8_e5m2), (E4M3FN, ml_dtypes.float8_e4m3fn)])
def test_matches_ml_dtypes(fmt, mld, impl):
    x = _test_vector()
    ours = np.asarray(quantize(jnp.asarray(x), fmt, impl=impl))
    with np.errstate(over="ignore"):
        theirs = x.astype(mld).astype(np.float32)
    same = ((ours == theirs) | (np.isnan(ours) & np.isnan(theirs))
            | ((ours == 0) & (theirs == 0)))
    # documented convention difference: we pass inf through even for fn
    # layouts (profiling wants the overflow signal); ml_dtypes maps inf->nan
    same |= np.isinf(x)
    bad = np.where(~same)[0]
    assert len(bad) == 0, [(x[i], ours[i], theirs[i]) for i in bad[:5]]


@pytest.mark.parametrize("e,m", [(5, 14), (3, 8), (8, 3), (2, 1), (6, 20),
                                 (4, 0), (5, 2), (8, 23)])
@pytest.mark.parametrize("shape", [(7,), (128,), (33, 65), (2, 3, 129)])
def test_pallas_matches_ref_sweep(e, m, shape):
    rng = np.random.RandomState(e * 100 + m)
    x = jnp.asarray(rng.randn(*shape) * 10 ** rng.uniform(-8, 8, shape),
                    jnp.float32)
    fmt = FPFormat(e, m)
    a = quantize(x, fmt, impl="ref")
    b = quantize(x, fmt, impl="interpret")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float16, jnp.float32])
def test_dtype_roundtrip(dtype):
    x = jnp.asarray(np.random.RandomState(0).randn(256), dtype)
    y = quantize(x, FPFormat(5, 2), impl="ref")
    assert y.dtype == x.dtype


def test_f64_carrier():
    from repro.compat import enable_x64
    with enable_x64():
        # genuine f64 values (not f32-exact upcasts)
        x64 = jnp.asarray(np.random.RandomState(0).randn(64).astype(np.float64)
                          / 3.0)
        y = quantize(x64, FPFormat(8, 30), impl="ref")
        assert y.dtype == jnp.float64
        # m=30: coarser than the f64 inputs, finer than f32
        assert not np.array_equal(np.asarray(y), np.asarray(x64))
        assert not np.array_equal(np.asarray(y),
                                  np.asarray(x64.astype(jnp.float32)
                                             .astype(jnp.float64)))
        # RAPTOR's original use case: 64_to_5_14 style truncation
        z = quantize(x64, parse_format("5_14"), impl="ref")
        q2 = quantize(z, parse_format("5_14"), impl="ref")
        np.testing.assert_array_equal(np.asarray(z), np.asarray(q2))


# ---- hypothesis property tests ---------------------------------------------

fmts = st.tuples(st.integers(2, 8), st.integers(0, 20)).map(
    lambda em: FPFormat(*em))
floats = st.floats(allow_nan=False, allow_infinity=False, width=32)


@given(fmt=fmts, xs=st.lists(floats, min_size=1, max_size=32))
@settings(max_examples=200, deadline=None)
def test_idempotent(fmt, xs):
    x = jnp.asarray(np.asarray(xs, np.float32))
    q1 = quantize(x, fmt, impl="ref")
    q2 = quantize(q1, fmt, impl="ref")
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))


@given(fmt=fmts, xs=st.lists(floats, min_size=2, max_size=32))
@settings(max_examples=200, deadline=None)
def test_monotone(fmt, xs):
    """x <= y implies q(x) <= q(y) — rounding preserves order."""
    x = np.sort(np.asarray(xs, np.float32))
    q = np.asarray(quantize(jnp.asarray(x), fmt, impl="ref"))
    finite = np.isfinite(q)
    qq = q[finite]
    assert np.all(np.diff(qq) >= 0)


@given(fmt=fmts, x=floats)
@settings(max_examples=300, deadline=None)
def test_error_bound(fmt, x):
    """|q(x) - x| <= max(ulp/2, sub_scale/2) within the finite range."""
    xa = np.float32(x)
    if abs(float(xa)) > fmt.max_finite:
        return
    q = float(np.asarray(quantize(jnp.asarray([xa]), fmt, impl="ref"))[0])
    if abs(float(xa)) < fmt.min_normal:
        tol = fmt.min_subnormal / 2
    else:
        import math
        e = math.floor(math.log2(abs(float(xa)))) if xa != 0 else fmt.min_exp
        tol = 2.0 ** (e - fmt.man_bits) / 2 * 1.0000001
    assert abs(q - float(xa)) <= tol, (float(xa), q, tol)


@given(fmt=fmts, x=floats)
@settings(max_examples=200, deadline=None)
def test_sign_preserved(fmt, x):
    xa = np.float32(x)
    q = float(np.asarray(quantize(jnp.asarray([xa]), fmt, impl="ref"))[0])
    if q != 0 and np.isfinite(q):
        assert np.sign(q) == np.sign(xa)


def test_ties_to_even():
    # e4m3 (ieee): grid step at [1,2) is 1/8; midpoints round to even mantissa
    fmt = FPFormat(4, 3)
    x = jnp.asarray([1.0625, 1.1875], jnp.float32)   # midpoints
    q = np.asarray(quantize(x, fmt, impl="ref"))
    np.testing.assert_allclose(q, [1.0, 1.25])        # both to even


def test_identity_fast_path():
    x = jnp.asarray(np.random.RandomState(0).randn(64), jnp.float32)
    y = quantize(x, parse_format("fp32"))
    assert y is x  # no-op object identity


def test_raptor_flag_formats():
    f = parse_format("5_14")
    assert (f.exp_bits, f.man_bits) == (5, 14)
    f2 = parse_format("e6m9s")
    assert f2.saturate and f2.man_bits == 9
