"""Runtime numerical guardrails: fault injection over the live format
table, online divergence detection, the escalation ladder, rollback
recovery, the serving quarantine, and the registry's publish-race retry.

The full fault -> alarm -> escalate -> rollback -> recover acceptance on
bench_model and a mini-app lives in tests/test_chaos.py (@chaos tier);
this file is the tier-1 slice: every component, plus one short guarded
training run on a tiny model.
"""
import os
import threading
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.core  # noqa: F401  (anchor the kernels<->core import cycle)
from repro.artifacts import PolicyArtifact, Registry
from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import ArchConfig
from repro.core.policy import TruncationPolicy
from repro.guardrails import (
    EscalationLadder, FaultPlan, FaultSpec, GuardedLoop, GuardedTrainer,
    GuardrailConfig, GuardrailLog, NumericalFaultError, StepMonitor,
    TrendFilter, Verdict, bitflip_row, clean_row, overflow_row,
    sites_for_scope,
)
from repro.guardrails.faults import OVERFLOW_ROW
from repro.kernels.quantize_em.ops import IDENTITY_ROW, quantize_dynamic
from repro.models import Model
from repro.optim.adamw import AdamWConfig
from repro.profile import fit_log2_trend
from repro.serving.engine import Engine
from repro.train.trainer import TrainConfig


# ---------------------------------------------------------------------------
# fault rows and the quantizer fault channel
# ---------------------------------------------------------------------------

def test_overflow_row_sends_o1_values_to_inf():
    x = jnp.asarray([0.1, 0.9, 1.0, 1.5, 3.0, -2.0], jnp.float32)
    y = np.asarray(quantize_dynamic(x, overflow_row()))
    assert np.isposinf(y[3]) and np.isposinf(y[4]) and np.isneginf(y[5])
    assert np.isfinite(y[:3]).all()


def test_bitflip_row_armed_channel_flips_exponent_bit():
    # bit 30 is the f32 top exponent bit: 1.0 -> inf-scale, 2.0 stays
    # finite but lands 2^64 away; the carrier format itself is unchanged
    row = bitflip_row(IDENTITY_ROW, 30)
    assert row[0] == IDENTITY_ROW[0] and row[1] == IDENTITY_ROW[1]
    x = jnp.asarray([1.0, -1.0], jnp.float32)
    y = np.asarray(quantize_dynamic(x, row))
    assert np.isposinf(y[0]) and np.isneginf(y[1])
    # stripping the channel restores bit-exact identity passthrough
    x2 = jnp.asarray(np.random.RandomState(0).randn(64), jnp.float32)
    y2 = np.asarray(quantize_dynamic(x2, clean_row(row)))
    np.testing.assert_array_equal(y2, np.asarray(x2))


def test_clean_row_strips_fault_channel_only():
    armed = bitflip_row(np.array([5, 10, 0, 1], np.int32), 7)
    assert armed[3] == 1 | ((7 + 1) << 1)
    np.testing.assert_array_equal(clean_row(armed),
                                  np.array([5, 10, 0, 1], np.int32))
    with pytest.raises(ValueError, match=r"\[0, 62\]"):
        bitflip_row(IDENTITY_ROW, 63)


# ---------------------------------------------------------------------------
# FaultPlan
# ---------------------------------------------------------------------------

def test_fault_plan_fires_once_and_persists():
    table = np.tile(np.array([8, 10, 0, 1], np.int32), (4, 1))
    plan = FaultPlan([FaultSpec(site=1, step=5, kind="overflow"),
                      FaultSpec(site=2, step=9, kind="bitflip", bit=30)])
    t0, fired = plan.apply(table, 0)
    assert fired == [] and np.array_equal(t0, table)
    t5, fired = plan.apply(table, 5)
    assert [f.site for f in fired] == [1]
    assert np.array_equal(t5[1], OVERFLOW_ROW)
    assert np.array_equal(table[1], [8, 10, 0, 1])   # input never mutated
    # already-fired specs stay fired; the later spec triggers at >= its step
    t10, fired = plan.apply(t5, 10)
    assert [f.site for f in fired] == [2]
    assert t10[2][3] == 1 | ((30 + 1) << 1)
    _, fired = plan.apply(t10, 11)
    assert fired == [] and plan.pending() == []
    plan.reset()
    assert len(plan.pending()) == 2


def test_fault_plan_out_of_range_site_raises():
    plan = FaultPlan([FaultSpec(site=7, step=0)])
    with pytest.raises(IndexError, match="site 7"):
        plan.apply(np.tile(IDENTITY_ROW, (3, 1)), 0)


def test_swap_row_fault_accepts_format_spec():
    plan = FaultPlan([FaultSpec(site=0, step=0, kind="swap_row", row="e2m1")])
    t, fired = plan.apply(np.tile(IDENTITY_ROW, (1, 1)), 0)
    assert len(fired) == 1
    assert t[0][0] == 2 and t[0][1] == 1


# ---------------------------------------------------------------------------
# monitor + trend filter
# ---------------------------------------------------------------------------

def test_step_monitor_nonfinite_alarms_immediately():
    m = StepMonitor()
    v = m.update(0, float("nan"))
    assert v.alarm and v.nonfinite
    v = m.update(1, 1.0, nonfinite=True)   # in-graph flag, finite loss
    assert v.alarm and v.nonfinite


def test_step_monitor_spike_and_z_after_warmup():
    m = StepMonitor(warmup=4, z_threshold=6.0, spike_factor=10.0)
    for s in range(4):
        assert m.update(s, 1.0 + 0.01 * s).ok    # warmup: never alarms
    v = m.update(4, 50.0)                        # > 10x median
    assert v.alarm and not v.nonfinite and "spike" in v.reason
    # the alarmed sample was NOT admitted: baseline still ~1.0
    assert m.update(5, 1.02).ok
    m.reset()
    assert m.update(6, 50.0).ok                  # fresh window: re-warming


def test_trend_filter_predicts_budget_crossing():
    f = TrendFilter(window=8)
    assert f.predicted_crossing(1e-2) is None    # under-sampled
    for s in range(6):
        f.update(s * 10, 1e-6 * 2 ** (0.1 * s * 10))  # 0.1 bits/step
    assert f.slope() == pytest.approx(0.1, rel=1e-6)
    # from 2^-20ish up to log2(1e-2) ~ -6.6 at 0.1 bits/step
    eta = f.predicted_crossing(1e-2)
    exact = (np.log2(1e-2) - np.log2(1e-6 * 2 ** 5.0)) / 0.1
    assert eta == int(np.ceil(exact))
    assert f.predicted_crossing(1e-9) == 0       # already above
    f.reset()
    assert f.predicted_crossing(1e-2) is None


def test_fit_log2_trend_slope_and_level():
    steps = np.arange(5) * 2.0
    slope, level = fit_log2_trend(steps, 1e-3 * 2 ** (0.25 * steps))
    assert slope == pytest.approx(0.25)
    assert level == pytest.approx(np.log2(1e-3) + 0.25 * 8.0)
    slope, level = fit_log2_trend([0.0], [0.5])
    assert slope == 0.0 and level == pytest.approx(-1.0)
    slope, level = fit_log2_trend([], [])
    assert slope == 0.0 and level == float("-inf")


# ---------------------------------------------------------------------------
# GuardrailLog
# ---------------------------------------------------------------------------

def test_guardrail_log_round_trip_and_attach(tmp_path):
    log = GuardrailLog()
    log.record(3, "fault_injected", site=1, fault="overflow")
    log.record(7, "alarm", reason="spike")
    log.record(7, "escalate_sites", sites=[1], rollback=True)
    log.record(7, "rollback", reason="spike")
    with pytest.raises(ValueError, match="unknown intervention"):
        log.record(8, "made_coffee")
    assert log.kinds() == {"fault_injected": 1, "alarm": 1,
                           "escalate_sites": 1, "rollback": 1}
    path = str(tmp_path / "glog.json")
    log.save(path)
    back = GuardrailLog.load(path)
    assert back.to_json() == log.to_json()
    assert [iv.step for iv in back.by_kind("rollback")] == [7]

    art = PolicyArtifact(name="t",
                         policy=TruncationPolicy.everywhere("e5m7"))
    audited = log.attach(art)
    assert GuardrailLog.from_artifact(audited).to_json() == log.to_json()
    assert GuardrailLog.from_artifact(art) is None
    # the attach survives the artifact's own JSON round trip
    again = PolicyArtifact.loads(audited.dumps())
    assert GuardrailLog.from_artifact(again).to_json() == log.to_json()
    assert "rollback=1" in log.summary()


# ---------------------------------------------------------------------------
# EscalationLadder
# ---------------------------------------------------------------------------

class _FakeSite:
    def __init__(self, index, scope):
        self.index, self.scope = index, scope


class _FakeIndex:
    def __init__(self, scopes):
        self.sites = [_FakeSite(i, s) for i, s in enumerate(scopes)]


def test_ladder_corrupted_rows_are_prime_suspects():
    base = np.tile(np.array([8, 10, 0, 1], np.int32), (4, 1))
    ladder = EscalationLadder(base)
    tab = base.copy()
    tab[2] = OVERFLOW_ROW
    assert ladder.suspects(tab) == [2]


def test_ladder_blamed_scopes_then_narrowest_fallback():
    base = np.array([[8, 10, 0, 1], [8, 2, 0, 1], [8, 10, 0, 1]], np.int32)
    idx = _FakeIndex(["layer0/mlp", "layer1/attn", "layer0/mlp"])
    ladder = EscalationLadder(base, site_index=idx,
                              cfg=GuardrailConfig(top_k=2))
    ladder.suspect_scopes = ["layer0/mlp"]
    assert ladder.suspects(base) == [0, 2]       # blamed scope wins
    ladder.suspect_scopes = []
    assert ladder.suspects(base)[0] == 1         # narrowest (m=2) first


def test_ladder_climbs_to_fp32_degrade():
    base = np.tile(np.array([8, 2, 0, 1], np.int32), (3, 1))
    log = GuardrailLog()
    ladder = EscalationLadder(base, log=log, cfg=GuardrailConfig(top_k=4))
    t1, rb = ladder.escalate(base, 10, Verdict(False, "spike", z=8.0))
    assert not rb and ladder.level == 1          # rung 1: in-place widen
    assert all(np.array_equal(r, IDENTITY_ROW) for r in t1)
    # every row is identity now -> no suspects -> final rung
    t2, rb = ladder.escalate(t1, 20, Verdict(False, "spike again"))
    assert rb and ladder.level == 3
    assert np.array_equal(t2, np.tile(IDENTITY_ROW, (3, 1)))
    kinds = log.kinds()
    assert kinds["alarm"] == 2 and kinds["escalate_sites"] == 1
    assert kinds["degrade_fp32"] == 1


def test_ladder_nonfinite_alarm_goes_straight_to_rollback():
    base = np.tile(np.array([8, 2, 0, 1], np.int32), (2, 1))
    ladder = EscalationLadder(base)
    _, rb = ladder.escalate(base, 5, Verdict(False, "nan", nonfinite=True))
    assert rb and ladder.level == 2


# ---------------------------------------------------------------------------
# GuardedLoop on a synthetic (model-free) step
# ---------------------------------------------------------------------------

def _synthetic_step(state, step, table):
    """Loss explodes to inf while any table row sits at OVERFLOW_ROW."""
    tab = np.asarray(table, np.int32)
    bad = any(np.array_equal(r, OVERFLOW_ROW) for r in tab)
    loss = float("inf") if bad else 1.0 / (1.0 + state["x"])
    return {"x": state["x"] + 1.0}, loss, not np.isfinite(loss)


def test_guarded_loop_detects_escalates_and_recovers(tmp_path):
    base = np.tile(np.array([8, 10, 0, 1], np.int32), (3, 1))
    ck = Checkpointer(str(tmp_path), async_save=False)
    loop = GuardedLoop(
        _synthetic_step, {"x": np.float64(0.0)}, base,
        checkpointer=ck, cfg=GuardrailConfig(save_every=4),
        fault_plan=FaultPlan([FaultSpec(site=1, step=10, kind="overflow")]))
    res = loop.run(20)
    assert res.final_step == 20
    assert np.isfinite(res.final_loss)
    assert res.rollbacks == 1
    # the faulted row was widened; untouched rows keep the baseline format
    assert np.array_equal(res.table[1], IDENTITY_ROW)
    assert np.array_equal(res.table[0], base[0])
    kinds = res.log.kinds()
    assert kinds == {"fault_injected": 1, "alarm": 1,
                     "escalate_sites": 1, "rollback": 1}
    # rollback restored the durable step-8 checkpoint, not step 0
    assert res.log.by_kind("rollback")[0].step == 10


def test_guarded_loop_without_checkpointer_restarts_from_init():
    base = np.tile(np.array([8, 10, 0, 1], np.int32), (2, 1))
    loop = GuardedLoop(
        _synthetic_step, {"x": np.float64(0.0)}, base,
        fault_plan=FaultPlan([FaultSpec(site=0, step=3, kind="overflow")]))
    res = loop.run(8)
    assert res.final_step == 8 and res.rollbacks == 1
    assert np.isfinite(res.final_loss)


def test_guarded_loop_exhausts_rollbacks_and_raises():
    # a step that is ALWAYS non-finite: every retry alarms again until the
    # supervisor's restart budget (max_rollbacks + 1) is spent
    def bad_step(state, step, table):
        return state, float("nan"), True
    loop = GuardedLoop(bad_step, {}, np.tile(IDENTITY_ROW, (2, 1)),
                       cfg=GuardrailConfig(max_rollbacks=2))
    with pytest.raises(NumericalFaultError):
        loop.run(5)
    assert loop.rollbacks >= 3


# ---------------------------------------------------------------------------
# GuardedTrainer (tier-1 slice on a tiny model)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny():
    cfg = ArchConfig(name="tiny", family="dense", n_layers=2, d_model=32,
                     n_heads=4, n_kv_heads=2, d_ff=64, vocab=64,
                     dtype="float32", remat=False, scan_layers=False)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    r = np.random.RandomState(0)
    toks = r.randint(0, cfg.vocab, (4, 17))
    batch = {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
             "labels": jnp.asarray(toks[:, 1:], jnp.int32)}
    return model, params, batch


def test_guarded_trainer_bitflip_fault_recovers(tiny, tmp_path):
    model, params, batch = tiny
    tc = TrainConfig(optimizer=AdamWConfig(lr=1e-2),
                     policy=TruncationPolicy.scoped("**/mlp", "e8m10"))
    ck = Checkpointer(str(tmp_path), async_save=False)
    gt = GuardedTrainer(
        model, tc, tc.policy, params, lambda step: batch,
        checkpointer=ck, cfg=GuardrailConfig(save_every=5),
        fault_plan=FaultPlan([FaultSpec(site=0, step=8, kind="bitflip")]))
    res = gt.run(16)
    assert res.final_step == 16
    assert np.isfinite(res.final_loss)
    assert res.rollbacks >= 1
    assert gt.cache_size() == 1          # escalation was table-only
    kinds = res.log.kinds()
    assert kinds["fault_injected"] == 1 and kinds["rollback"] >= 1
    assert np.array_equal(gt.table[0], IDENTITY_ROW)


def test_guarded_trainer_fault_free_run_logs_nothing(tiny, tmp_path):
    model, params, batch = tiny
    tc = TrainConfig(optimizer=AdamWConfig(lr=1e-2),
                     policy=TruncationPolicy.scoped("**/mlp", "e8m10"))
    gt = GuardedTrainer(model, tc, tc.policy, params, lambda step: batch,
                        cfg=GuardrailConfig(save_every=5))
    res = gt.run(10)
    assert res.rollbacks == 0 and len(res.log) == 0
    assert np.isfinite(res.final_loss)
    assert gt.cache_size() == 1


# ---------------------------------------------------------------------------
# serving quarantine
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def serve_lm():
    cfg = ArchConfig(name="g", family="dense", n_layers=2, d_model=48,
                     n_heads=4, n_kv_heads=2, head_dim=12, d_ff=96, vocab=64,
                     dtype="float32", remat=False, scan_layers=False)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_engine_quarantines_nonfinite_decode(serve_lm):
    cfg, model, params = serve_lm
    poisoned = jax.tree_util.tree_map(lambda p: p * jnp.nan, params)
    eng = Engine(model, poisoned, batch_size=2, max_seq_len=16)
    eng.submit(np.array([1, 2, 3]), max_new_tokens=4)
    eng.submit(np.array([4, 5, 6]), max_new_tokens=4)
    done = eng.run()
    assert set(done) == {0, 1}
    for rid in (0, 1):
        req = done[rid]
        assert req.done and req.status == "error_nonfinite"
        assert "non-finite logits" in req.error
        assert req.out_tokens == []      # no garbage argmax tokens emitted
    assert all(s is None for s in eng.slots)     # slots were freed
    assert (eng.lengths == 0).all()


def test_engine_healthy_requests_keep_ok_status(serve_lm):
    cfg, model, params = serve_lm
    eng = Engine(model, params, batch_size=2, max_seq_len=16)
    eng.submit(np.array([1, 2, 3]), max_new_tokens=3)
    done = eng.run()
    assert done[0].status == "ok" and done[0].error == ""
    assert len(done[0].out_tokens) == 3


# ---------------------------------------------------------------------------
# registry publish-race retry
# ---------------------------------------------------------------------------

def _art(name="racy"):
    return PolicyArtifact(name=name,
                          policy=TruncationPolicy.everywhere("e5m7"))


def test_registry_load_retries_through_publish_window(tmp_path):
    reg = Registry(str(tmp_path), retries=20, backoff=0.02)
    reg.save(_art())
    # simulate the torn window: LATEST already names v2 but the version dir
    # has not landed yet (reader sees the half-renamed state)
    with open(os.path.join(str(tmp_path), "racy", "LATEST"), "w") as f:
        f.write("v0002")

    def publish_late():
        time.sleep(0.1)
        Registry(str(tmp_path)).save(_art())

    t = threading.Thread(target=publish_late)
    t.start()
    try:
        art = reg.load("racy@v2")        # pinned at the in-flight version
    finally:
        t.join()
    assert art.name == "racy"
    assert reg.latest_version("racy") == 2


def test_registry_retry_is_bounded(tmp_path):
    reg = Registry(str(tmp_path), retries=2, backoff=0.01)
    reg.save(_art())
    with open(os.path.join(str(tmp_path), "racy", "LATEST"), "w") as f:
        f.write("v0009")                 # torn forever: nobody publishes
    t0 = time.monotonic()
    with pytest.raises(FileNotFoundError, match="racy@v9"):
        reg.load("racy@v9")
    assert time.monotonic() - t0 < 5.0
    # bare-name load self-heals to the newest durable version, no retry
    assert reg.load("racy").name == "racy"


def test_registry_missing_artifact_fails_fast(tmp_path):
    # retries huge + backoff huge: if a plain miss retried, this would hang
    reg = Registry(str(tmp_path), retries=100, backoff=30.0)
    t0 = time.monotonic()
    with pytest.raises(FileNotFoundError, match="no artifact named"):
        reg.load("never_published")
    assert time.monotonic() - t0 < 2.0


def test_registry_sites_for_scope_helper():
    idx = _FakeIndex(["layer0/mlp", "layer0/mlp/sub", "layer1/mlp",
                      "layer0/mlpx"])
    assert sites_for_scope(idx, "layer0/mlp") == [0, 1]
    assert sites_for_scope(idx, "layer1") == [2]
    assert sites_for_scope(idx, "nope") == []
