"""The CI policy-drift gate's --check failure modes: a missing or
schema-newer committed artifact must fail in milliseconds with the exact
refresh command — never a raw traceback, and never after minutes of
autosearch."""
import json

import pytest

from benchmarks import policy_drift
from repro.artifacts import PolicyArtifact, save_artifact_file
from repro.artifacts.artifact import SCHEMA_VERSION, ScopeRow
from repro.core.policy import TruncationPolicy


@pytest.fixture(autouse=True)
def _no_autosearch(monkeypatch):
    """The gate must validate the committed artifact BEFORE searching;
    any fresh_artifact call in these tests is a bug. The model's scope
    frontier (for the pre-search artifact lint) is pinned so these tests
    never trace the real bench model either."""
    def boom():
        raise AssertionError(
            "fresh_artifact ran before the committed artifact was "
            "validated — --check must fail fast")
    monkeypatch.setattr(policy_drift, "fresh_artifact", boom)
    monkeypatch.setattr(policy_drift, "_model_scope_paths",
                        lambda: ["layer0/mlp"])


def test_check_missing_artifact_names_refresh_command(tmp_path, capsys):
    rc = policy_drift.main(["--committed", str(tmp_path / "nope.json")])
    assert rc == 1
    err = capsys.readouterr().err
    assert "no committed artifact" in err
    assert "python -m benchmarks.policy_drift --refresh" in err


def test_check_schema_newer_artifact_is_actionable(tmp_path, capsys):
    art = PolicyArtifact(name="bench_model",
                         policy=TruncationPolicy.everywhere("e5m7"))
    path = tmp_path / "bench_model.json"
    data = art.to_json()
    data["schema_version"] = SCHEMA_VERSION + 1
    path.write_text(json.dumps(data))
    rc = policy_drift.main(["--committed", str(path)])
    assert rc == 1
    err = capsys.readouterr().err
    assert "not readable by this build" in err
    assert "schema version" in err
    assert "python -m benchmarks.policy_drift --refresh" in err


def _artifact(man_bits):
    return PolicyArtifact(
        name="bench_model",
        policy=TruncationPolicy.everywhere("e5m7"),
        assignments={"layer0/mlp": ScopeRow(man_bits=man_bits,
                                            error_at_accept=1e-4)})


def test_check_diffs_fresh_against_committed(tmp_path, monkeypatch, capsys):
    path = str(tmp_path / "bench_model.json")
    save_artifact_file(_artifact(7), path)
    monkeypatch.setattr(policy_drift, "fresh_artifact",
                        lambda: _artifact(7))
    assert policy_drift.main(["--committed", path]) == 0
    assert "policy-drift passed" in capsys.readouterr().out

    monkeypatch.setattr(policy_drift, "fresh_artifact",
                        lambda: _artifact(3))
    assert policy_drift.main(["--committed", path]) == 1
    err = capsys.readouterr().err
    assert "policy-drift FAILED" in err and "layer0/mlp" in err
