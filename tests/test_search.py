"""Automated precision search: scope discovery, bisection, budget
discipline, the greedy-exclusion refinement loop, and the policy round-trip.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax import lax

from repro import search
from repro.core import truncate, TruncationPolicy, scope


def _toy(w1, w2, x):
    with scope("attn"):
        h = jnp.tanh(x @ w1)
    with scope("mlp"):
        h = jax.nn.relu(h @ w2) @ w2.T
    with scope("head"):
        return jnp.mean(h * h)


def _toy_args(seed=0):
    r = np.random.RandomState(seed)
    return (jnp.asarray(r.randn(32, 64) / 8, jnp.float32),
            jnp.asarray(r.randn(64, 64) / 8, jnp.float32),
            jnp.asarray(r.randn(16, 32), jnp.float32))


def test_discover_scopes_frontier():
    args = _toy_args()
    closed = jax.make_jaxpr(_toy)(*args)
    scopes = search.discover_scopes(closed)
    paths = [s.path for s in scopes]
    assert "mlp" in paths and "attn" in paths
    # disjoint frontier, ordered by work, fractions sane
    assert len(paths) == len(set(paths))
    fracs = [s.fraction for s in scopes]
    assert fracs == sorted(fracs, reverse=True)
    assert all(0.0 < f <= 1.0 for f in fracs)
    assert sum(fracs) <= 1.0 + 1e-9


def test_discover_scopes_counts_scan_trips():
    def f(x):
        with scope("loop"):
            def body(c, _):
                return c @ c, None
            y, _ = lax.scan(body, x, None, length=5)
        return y

    x = jnp.eye(8, dtype=jnp.float32)
    closed = jax.make_jaxpr(f)(x)
    (si,) = [s for s in search.discover_scopes(closed) if s.path == "loop"]
    assert si.flops == pytest.approx(5 * 2 * 8 ** 3)


def test_autosearch_converges_within_budget():
    args = _toy_args()
    res = search.autosearch(_toy, args, search.rel_error, 32,
                            threshold=1e-2)
    assert res.converged
    assert res.evals_used <= 32
    assert res.final_error <= 1e-2
    # something actually got truncated
    assert len(res.policy().rules) >= 1
    # the table renders every discovered scope
    table = res.table()
    for path in res.assignments:
        assert path in table


def test_autosearch_policy_roundtrip():
    """Applying result.policy() via the public truncate API reproduces the
    search's final metric."""
    args = _toy_args()
    res = search.autosearch(_toy, args, search.rel_error, 32, threshold=1e-2)
    ref = float(_toy(*args))
    lossy = float(truncate(_toy, res.policy())(*args))
    got = abs(lossy - ref) / max(abs(ref), 1e-12)
    assert got == pytest.approx(res.final_error, rel=1e-3, abs=1e-9)


def test_autosearch_budget_one_degrades_gracefully():
    args = _toy_args()
    res = search.autosearch(_toy, args, search.rel_error, 1, threshold=1e-2)
    assert res.evals_used <= 1
    # nothing searched -> everything stays full precision, which trivially
    # meets the threshold
    assert res.policy().rules == ()
    assert res.converged


def test_autosearch_tight_threshold_prefers_fine_formats():
    args = _toy_args()
    loose = search.autosearch(_toy, args, search.rel_error, 32,
                              threshold=1e-1)
    tight = search.autosearch(_toy, args, search.rel_error, 32,
                              threshold=1e-6)
    for path, a in tight.assignments.items():
        if path in loose.assignments:
            assert a.man_bits >= loose.assignments[path].man_bits


def test_exclusion_refinement_loop():
    """Force the paper's §6.3 dynamic: every scope passes its solo check but
    the composed policy misses the threshold, so the search must exclude
    fragile scopes until the joint metric fits."""
    args = _toy_args(seed=3)  # seed where composition amplifies the error
    widths = (23, 2)          # solo checks only ever try e8m2

    # self-calibrate: measure solo and joint errors at e8m2
    ref = float(_toy(*args))

    def err_of(*scopes_):
        pol = TruncationPolicy(rules=tuple(
            search.driver.TruncationRule(
                fmt=search.driver.FPFormat(8, 2), scope=s)
            for s in scopes_))
        lossy = float(truncate(_toy, pol)(*args))
        return abs(lossy - ref) / abs(ref)

    solo = {s: err_of(s) for s in ("attn", "mlp", "head")}
    joint = err_of("attn", "mlp", "head")
    if joint <= max(solo.values()):
        pytest.skip("errors cancelled for this seed; no composition gap")
    thr = (max(solo.values()) + joint) / 2.0

    res = search.autosearch(_toy, args, search.rel_error, 32,
                            threshold=thr, widths=widths,
                            min_fraction=1e-4)  # keep 'head' in the frontier
    assert res.converged
    assert any(a.excluded for a in res.assignments.values()), res.table()
    # excluded scopes fall out of the policy
    pol_scopes = {r.scope for r in res.policy().rules}
    for path, a in res.assignments.items():
        if a.excluded:
            assert path not in pol_scopes


@pytest.mark.slow
def test_autosearch_quickstart_model():
    """Acceptance: autosearch on the quickstart model converges to a
    per-scope assignment meeting the error threshold within the budget."""
    from repro.configs.base import get_config
    from repro.models import Model

    cfg = get_config("olmoe-1b-7b", "smoke")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    r = np.random.RandomState(0)
    toks = r.randint(0, cfg.vocab, (4, 33))
    batch = {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
             "labels": jnp.asarray(toks[:, 1:], jnp.int32)}

    budget = 48
    res = search.autosearch(model.loss, (params, batch),
                            search.loss_degradation, budget, threshold=5e-3)
    assert res.converged, res.table()
    assert res.evals_used <= budget
    assert res.final_error <= 5e-3
    assert len(res.policy().rules) >= 1  # something got truncated
    full = float(model.loss(params, batch))
    lossy = float(truncate(model.loss, res.policy())(params, batch))
    assert abs(lossy - full) / abs(full) <= 5e-3


# --------------------------------------------------------------------------
# error-guided warm start (repro.profile -> autosearch)
# --------------------------------------------------------------------------

def _assigns(res):
    return {p: (a.man_bits, a.excluded) for p, a in res.assignments.items()}


def test_warm_start_identical_assignments_fewer_evals():
    """Accurate hints must reproduce the unguided assignments while spending
    strictly fewer probe evaluations (the bisection skips certain rungs)."""
    args = _toy_args()
    r0 = search.autosearch(_toy, args, search.rel_error, 48, threshold=1e-2)
    hints = {p: a.man_bits for p, a in r0.assignments.items()}
    r1 = search.autosearch(_toy, args, search.rel_error, 48, threshold=1e-2,
                           warm_start=hints)
    assert _assigns(r1) == _assigns(r0)
    assert r1.final_error == r0.final_error
    assert r1.evals_used < r0.evals_used
    assert r1.n_dispatches <= r0.n_dispatches
    assert r1.n_warm_hints == len(r0.assignments)


def test_warm_start_wrong_hints_still_measured():
    """Hints shape the probe schedule, never the verdict: absurd hints
    (everything pinned high / everything narrowest) still land on the same
    assignments for a monotone workload, just with more bisection probes."""
    args = _toy_args()
    r0 = search.autosearch(_toy, args, search.rel_error, 48, threshold=1e-2)
    for bad in ({p: None for p in r0.assignments},
                {p: 2 for p in r0.assignments},
                {p: 15 for p in r0.assignments}):
        r1 = search.autosearch(_toy, args, search.rel_error, 48,
                               threshold=1e-2, warm_start=bad)
        assert _assigns(r1) == _assigns(r0), bad


def test_warm_start_prefix_hints_project_onto_frontier():
    """Hint keys may be deeper (site scopes) or shallower (user prefixes)
    than the discovered frontier; pinned-high dominates on conflict."""
    from repro.search.driver import _frontier_hints

    args = _toy_args()
    closed = jax.make_jaxpr(_toy)(*args)
    scopes = search.discover_scopes(closed)
    deep = _frontier_hints({"mlp/deeper/site": 5, "mlp": 7}, scopes)
    assert deep["mlp"] == 7                  # finest prediction wins
    pinned = _frontier_hints({"mlp/deeper": None, "mlp": 7}, scopes)
    assert pinned["mlp"] is None             # pin dominates
    assert "attn" not in deep                # unhinted scopes stay unhinted
    with pytest.raises(TypeError, match="ladder_hints"):
        search.autosearch(_toy, args, search.rel_error, 8,
                          warm_start="not-a-mapping")


def test_warm_start_profile_to_search_on_sod():
    """The full tentpole loop on the smallest app: profile_trajectory ->
    blame -> ladder_hints -> autosearch. Assignments must match the
    unguided search with strictly fewer probe dispatches (the ISSUE
    acceptance, small-config tier-1 slice; bench_model and the full trio
    run in the @slow tier)."""
    from repro.apps import get_app

    app = get_app("sod", n_cells=32, t_end=0.04)
    state = app.init_state(jnp.float32)
    r0 = search.autosearch(app.run_observables, (state,),
                           metric=app.error_metric, budget=48,
                           threshold=app.search_threshold)
    hints = app.warm_hints(state)
    r1 = search.autosearch(app.run_observables, (state,),
                           metric=app.error_metric, budget=48,
                           threshold=app.search_threshold, warm_start=hints)
    assert _assigns(r1) == _assigns(r0)
    assert r1.final_error == r0.final_error
    assert r1.n_dispatches < r0.n_dispatches, (r0.n_dispatches,
                                               r1.n_dispatches)
    assert r1.evals_used < r0.evals_used


@pytest.mark.slow
def test_warm_start_acceptance_miniapps_and_bench_model():
    """ISSUE acceptance: the error-guided warm start reduces probe
    dispatches on all three mini-apps AND the bench model while producing
    identical final scope assignments (non-binding budgets, so the
    unguided baseline fully probes its ladder)."""
    from repro.apps import get_app
    from benchmarks.common import bench_model, bench_batch
    from repro.core import profile_trajectory
    from repro.core.formats import FPFormat
    from repro.profile import ladder_hints

    small = {"sod": dict(n_cells=32, t_end=0.04),
             "heat": dict(n=8, n_explicit=8, n_implicit=1, cg_iters=6),
             "poisson": dict(n=8, cg_iters=12)}
    for name, cfg in small.items():
        app = get_app(name, **cfg)
        state = app.init_state(jnp.float32)
        thr = 5e-2 if name == "poisson" else app.search_threshold
        r0 = search.autosearch(app.run_observables, (state,),
                               metric=app.error_metric, budget=48,
                               threshold=thr)
        hints = app.warm_hints(state, threshold=thr)
        r1 = search.autosearch(app.run_observables, (state,),
                               metric=app.error_metric, budget=48,
                               threshold=thr, warm_start=hints)
        assert _assigns(r1) == _assigns(r0), name
        assert r1.n_dispatches < r0.n_dispatches, (name, r0.n_dispatches,
                                                   r1.n_dispatches)

    cfg, model, params = bench_model()
    batch = bench_batch(cfg)
    budget, thr = 128, 5e-3   # non-binding: 17 scopes x 6-rung ladder fits
    r0 = search.autosearch(model.loss, (params, batch),
                           search.loss_degradation, budget, threshold=thr)
    probe = TruncationPolicy(rules=tuple(
        search.driver.TruncationRule(fmt=FPFormat(8, 5), scope=p)
        for p in r0.assignments))
    out_lo, traj = profile_trajectory(model.loss, probe, threshold=thr,
                                      n_steps=8)(params, batch)
    joint = search.loss_degradation((model.loss(params, batch),), (out_lo,))
    hints = ladder_hints(traj, search.DEFAULT_WIDTHS, thr, 5,
                         joint_metric=joint)
    r1 = search.autosearch(model.loss, (params, batch),
                           search.loss_degradation, budget, threshold=thr,
                           warm_start=hints)
    assert _assigns(r1) == _assigns(r0)
    assert r1.n_dispatches < r0.n_dispatches, (r0.n_dispatches,
                                               r1.n_dispatches)
    assert r1.evals_used < r0.evals_used


def test_metrics_flag_nonfinite():
    assert search.rel_error(jnp.float32(1.0), jnp.float32(jnp.nan)) == float("inf")
    assert search.loss_degradation((jnp.float32(2.0),),
                                   (jnp.float32(jnp.inf),)) == float("inf")
    assert search.rel_error(jnp.float32(2.0), jnp.float32(2.0)) == 0.0
