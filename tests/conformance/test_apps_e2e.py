"""End-to-end precision profiling on the PDE mini-apps vs the FP64 oracle.

The acceptance contract of the suite, per app (Sod shock tube, 2D heat
diffusion, CG Poisson):

  * ``autosearch`` with the app's solver-level ``error_metric`` converges
    within budget and returns a genuinely mixed assignment;
  * applying the searched policy keeps the app inside its FP64-oracle
    error budget (conserved-quantity drift / field L2 / residual norm);
  * the searched assignment strictly beats the uniform-low-precision
    strawman, which itself must bust the budget (the paper's core claim:
    per-region assignment reaches precision that uniform truncation
    cannot);
  * ``truncate_sweep`` evaluates candidate policies on the apps bit-for-bit
    identically to per-policy ``truncate``.

On a budget failure the observables and search table are dumped as an
artifact so a nightly red run carries its own reproducer.
"""
import functools

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import search
from repro.apps import get_app, oracle
from repro.core import truncate, truncate_sweep, TruncationPolicy
from harness import dump_artifact

pytestmark = pytest.mark.conformance

APP_NAMES = ["sod", "heat", "poisson"]
SEARCH_BUDGET = 32


@functools.lru_cache(maxsize=None)
def _setup(name):
    """One shared (app, f32 state, fp64 oracle obs, search result) per app —
    the expensive pieces every test in this module grades against."""
    app = get_app(name)
    state = app.init_state(jnp.float32)
    ref64 = tuple(sorted(oracle.fp64_reference(app).items()))
    res = search.autosearch(app.run_observables, (state,),
                            metric=app.error_metric, budget=SEARCH_BUDGET,
                            threshold=app.search_threshold)
    return app, state, dict(ref64), res


def _leaves_bits(tree):
    return [np.asarray(jax.device_get(l)).view(np.uint32)
            for l in jax.tree_util.tree_leaves(tree)]


@pytest.mark.parametrize("name", APP_NAMES)
def test_autosearch_converges_mixed(name):
    app, _state, _ref, res = _setup(name)
    assert res.converged, res.table()
    assert res.evals_used <= SEARCH_BUDGET
    assert res.n_compiles <= 1, "search must stay O(1)-compile on the apps"
    # a *mixed* assignment: something truncated, per-scope widths free to
    # differ (not a uniform policy in disguise is checked by the beats-
    # uniform test below)
    assert len(res.policy().rules) >= 1, res.table()


@pytest.mark.parametrize("name", APP_NAMES)
def test_autosearch_meets_oracle_budget(name):
    """The searched policy keeps the app inside its FP64-oracle budget."""
    app, state, ref64, res = _setup(name)
    obs = truncate(app.run_observables, res.policy())(state)
    v = oracle.verdict(app, obs, ref64)
    if not v.passed:
        path = dump_artifact(
            f"app-budget-{name}",
            **{f"obs_{k}": np.asarray(jax.device_get(x))
               for k, x in obs.items()})
        pytest.fail(f"{v}\n{res.table()}\nreproducer -> {path}")
    # the searched policy must not ride on the f32 floor alone: the budget
    # has to have real headroom left (otherwise the thresholds are mistuned
    # and the test is vacuous)
    assert v.floor <= app.error_budget / 10.0, v


@pytest.mark.parametrize("name", APP_NAMES)
def test_mixed_beats_uniform_low(name):
    """Uniform low precision busts the budget; the searched mixed
    assignment strictly beats it on the oracle metric."""
    app, state, ref64, res = _setup(name)
    obs_mixed = truncate(app.run_observables, res.policy())(state)
    obs_uni = truncate(app.run_observables, app.uniform_policy())(state)
    err_mixed = oracle.oracle_error(app, obs_mixed, ref64)
    err_uni = oracle.oracle_error(app, obs_uni, ref64)
    assert err_uni > app.error_budget, (
        f"uniform {app.uniform_low} unexpectedly fits the budget "
        f"({err_uni:.3e} <= {app.error_budget:.1e}) — strawman mistuned")
    assert err_mixed <= app.error_budget
    assert err_mixed < err_uni


@pytest.mark.parametrize("name", APP_NAMES)
def test_truncate_sweep_bitwise_parity_on_app(name):
    """The zero-recompile sweep path reproduces per-policy truncate
    bit-for-bit on a real solver trajectory (scan + stencils + reductions),
    for a ladder of uniform policies over the app's scopes."""
    app, state, _ref, _res = _setup(name)
    site_policy = TruncationPolicy(rules=tuple(
        search.driver.TruncationRule(fmt=search.driver.FPFormat(8, 0),
                                     scope=s)
        for s in app.default_policy_scopes()))
    handle = truncate_sweep(app.run_observables, site_policy)(state)
    ladder = [app.uniform_policy(f"e8m{m}") for m in (10, 5, 3)]
    batched = handle.batch(handle.tables(ladder))
    for k, pol in enumerate(ladder):
        row = jax.tree_util.tree_map(lambda a, k=k: a[k], batched)
        direct = truncate(app.run_observables, pol)(state)
        for rb, db in zip(_leaves_bits(row), _leaves_bits(direct)):
            assert np.array_equal(rb, db), (name, pol.rules[0].fmt.key)


@pytest.mark.parametrize("name", APP_NAMES)
def test_memtrace_flags_truncated_scopes(name):
    """mem-mode on the uniform-low policy attributes flags to the app's
    solver scopes (the heatmap the paper debugging flow starts from)."""
    from repro.core import memtrace

    app, state, _ref, _res = _setup(name)
    _out, rep = memtrace(app.run_observables, app.uniform_policy(),
                         threshold=1e-3)(state)
    flags = np.asarray(jax.device_get(rep.flags))
    assert flags.sum() > 0, rep.summary()
    locs = " ".join(rep.locations)
    root = app.default_policy_scopes()[0].split("/")[0]
    assert root in locs, rep.summary()
