"""Independent bit-level quantization oracle (exact integer arithmetic).

The production quantizer (``repro.kernels.quantize_em.ref``) rounds with a
carrier-grid bit trick plus lane-wise ``where`` gates. This oracle takes a
deliberately different route so the two can cross-examine each other:

  * decompose each f32 into an exact integer significand and exponent,
  * round-to-nearest-even by integer divmod onto the target grid
    (normal ulp ``2^(E-m)``, subnormal ulp ``2^(min_exp-m)``),
  * reconstruct the result exactly via ``ldexp`` in f64,
  * apply the overflow convention (saturate / IEEE inf / fn-NaN) by
    comparing against the exact ``max_finite``.

Semantics mirror the documented contract of ``quantize_ref``: two-stage
rounding (RNE on the unbounded grid first, THEN the overflow check against
``max_finite``), NaN/Inf/±0 pass-through, and input-magnitude selection of
the subnormal path (``|x| < min_normal``). Valid for f32 inputs and targets
with ``exp_bits <= 8`` and ``1 <= man_bits <= 23`` — the whole
search/profiling format space on the f32 carrier. ``man_bits == 0`` is
excluded by design: with a single-significand grid "ties to even" is
convention-dependent (the implementation ties on carrier-encoding parity,
grid-units parity would differ at every half-way power of two), and no
ladder rung or hardware format is m=0.

Everything is numpy int64/float64; no jax, no shared code with the
implementation under test.
"""
from __future__ import annotations

import numpy as np


def format_constants(e: int, m: int, ieee_inf: bool):
    """(bias, min_exp, max_exp, max_finite) of the (e, m) target, exact."""
    bias = (1 << (e - 1)) - 1
    min_exp = 1 - bias
    max_exp = (1 << e) - (2 if ieee_inf else 1) - bias
    top_sig = (1 << (m + 1)) - (1 if ieee_inf else 2)  # in units of 2^-m
    max_finite = float(np.ldexp(np.float64(top_sig), max_exp - m))
    return bias, min_exp, max_exp, max_finite


def oracle_quantize(x, e: int, m: int, saturate: bool, ieee_inf: bool):
    """Quantize a float32 array onto the (e, m) grid; returns float32.

    Exact-integer RNE, independent of the jax implementation (see module
    docstring). Requires ``1 <= e <= 8`` and ``1 <= m <= 23``.
    """
    if not (1 <= e <= 8 and 1 <= m <= 23):
        raise ValueError(f"oracle domain is e<=8, 1<=m<=23, got e{e}m{m}")
    x = np.asarray(x, np.float32)
    bits = x.view(np.uint32).astype(np.int64)
    sign = (bits >> 31) & 1
    efield = (bits >> 23) & 0xFF
    mfield = bits & 0x7FFFFF
    special = efield == 255                       # nan / inf pass through
    is_zero = (efield == 0) & (mfield == 0)

    # exact value = sig * 2^(E - 23); f32 subnormals have E = -126, sig < 2^23
    sig = np.where(efield > 0, mfield | (1 << 23), mfield)
    E = np.where(efield > 0, efield.astype(np.int64) - 127,
                 np.int64(-126))

    _, min_exp, _, max_finite = format_constants(e, m, ieee_inf)

    # target ulp exponent: normal 2^(E-m) when |x| >= 2^min_exp, else the
    # fixed subnormal spacing 2^(min_exp - m)
    subnormal = E < min_exp
    t = np.where(subnormal, np.int64(min_exp - m), E - m)
    # units on the target grid: sig * 2^((E-23) - t), always a right shift
    # for m <= 23; shifts past 62 cannot round up (sig < 2^24 << half) and
    # are clamped to keep int64 shifts defined
    s = np.minimum((E - 23 - t) * -1, 62)
    s = np.maximum(s, 0)
    d = np.left_shift(np.int64(1), s)
    q, r = np.divmod(sig, d)
    half = d >> 1
    round_up = (r > half) | ((r == half) & (half > 0) & ((q & 1) == 1))
    n = q + round_up.astype(np.int64)

    # exact reconstruction (n <= 2^24, |t| <= 149: exact in f64, and the
    # result lies on the f32 grid so the final cast is exact too)
    mag = np.ldexp(n.astype(np.float64), t)

    ovf = mag > max_finite
    if saturate:
        mag = np.where(ovf, max_finite, mag)
    elif ieee_inf:
        mag = np.where(ovf, np.inf, mag)

    out = np.where(sign == 1, -mag, mag)
    if not saturate and not ieee_inf:
        # fn-layout overflow is the canonical (positive) NaN for either
        # sign, matching the implementation's unsigned NaN constant
        out = np.where(ovf, np.nan, out)
    with np.errstate(over="ignore"):
        # e8 targets can round the top f32 binade up to 2^128: exactly the
        # carrier's own overflow-to-inf, not an oracle error
        out = out.astype(np.float32)
    # ±0 and specials keep their input bits (incl. NaN payload, -0 sign)
    out_bits = out.view(np.uint32).copy()
    passthru = special | is_zero
    out_bits[passthru] = x.view(np.uint32)[passthru]
    return out_bits.view(np.float32)


def all_float16_values() -> np.ndarray:
    """Every f16 bit pattern, exactly widened to f32 (the exhaustive
    conformance input space: 65536 values covering normals, subnormals,
    ±0, ±inf and every NaN payload)."""
    return np.arange(1 << 16, dtype=np.uint16).view(np.float16) \
        .astype(np.float32)
