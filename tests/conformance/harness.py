"""Shared conformance-harness utilities: bitwise assertions that dump a
machine-readable reproducer artifact on mismatch.

On any oracle disagreement the failing inputs/outputs are written as an
``.npz`` into ``$RAPTOR_ARTIFACTS_DIR`` (default ``conformance-artifacts/``)
before the assertion fires — CI uploads the directory on failure, so a
nightly red run always carries the exact bit patterns needed to replay it:

    data = np.load("mismatch-<tag>.npz")
    x = data["x_bits"].view(np.float32)          # the offending inputs
    # data["fmt"] = [exp_bits, man_bits, saturate, ieee_inf]
"""
from __future__ import annotations

import os
import re

import numpy as np


def artifact_dir() -> str:
    return os.environ.get("RAPTOR_ARTIFACTS_DIR", "conformance-artifacts")


def dump_artifact(name: str, **arrays) -> str:
    """Write arrays as ``<artifact_dir>/<name>.npz``; returns the path."""
    out = artifact_dir()
    os.makedirs(out, exist_ok=True)
    path = os.path.join(out, f"{re.sub(r'[^A-Za-z0-9_.-]', '_', name)}.npz")
    np.savez(path, **arrays)
    return path


def assert_bits_equal(tag: str, x, got, want, fmt=None, max_show: int = 5,
                      nan_payload_free: bool = False):
    """Bitwise equality of two f32 arrays; on mismatch, dump a reproducer
    npz (input bits, both result sides, the format row) and fail with the
    first few offending values + the artifact path.

    ``nan_payload_free=True`` relaxes only NaN *payload* bits (positions
    where both sides are NaN count as equal) — for legs that cross a
    hardware cast (``astype`` convert pairs, ml_dtypes) which canonicalize
    payloads; NaN-ness itself, infinities and zero signs stay bit-strict."""
    x = np.asarray(x, np.float32)
    got = np.asarray(got, np.float32)
    want = np.asarray(want, np.float32)
    gb, wb = got.view(np.uint32), want.view(np.uint32)
    differ = gb != wb
    if nan_payload_free:
        differ &= ~(np.isnan(got) & np.isnan(want))
    bad = np.nonzero(differ)[0]
    if bad.size == 0:
        return
    path = dump_artifact(
        f"mismatch-{tag}",
        x_bits=x.view(np.uint32)[bad],
        got_bits=gb[bad],
        want_bits=wb[bad],
        fmt=np.asarray(fmt if fmt is not None else [], np.int32))
    sample = [(hex(int(x.view(np.uint32)[i])), float(x[i]),
               float(got[i]), float(want[i])) for i in bad[:max_show]]
    raise AssertionError(
        f"[{tag}] {bad.size} bitwise mismatches "
        f"(x_bits, x, got, want): {sample}; reproducer -> {path}")
