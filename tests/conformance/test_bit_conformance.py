"""Bit-level conformance of the quantizer against an independent oracle.

Three mutually checking implementations must agree bit-for-bit on the
entire float16 value space (all 65536 patterns — every normal, subnormal,
±0, ±inf and NaN payload, exactly widened to f32):

  * ``quantize_ref_dynamic``   — the runtime-parameterized jnp path the
                                 whole sweep/search stack runs on,
  * the Pallas kernel          — ``quantize_dynamic(impl='interpret')``,
  * ``bit_oracle``             — exact-integer RNE, no shared code,

plus the static trace-time path (``quantize``) and, where a hardware cast
exists, ``ml_dtypes``. Randomized (e, m, saturate, ieee_inf) corners extend
the same contract across the full format space on adversarial values
(overflow boundaries, subnormal ties, half-way points). Any mismatch dumps
a bit-exact reproducer artifact (see ``harness.py``).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.core  # noqa: F401  — import order: core before kernels
from repro.core.formats import FPFormat
from repro.kernels.quantize_em.ops import quantize, quantize_dynamic, \
    format_row
from bit_oracle import all_float16_values, format_constants, oracle_quantize
from harness import assert_bits_equal

pytestmark = pytest.mark.conformance

# (exp_bits, man_bits, saturate, ieee_inf): every hardware format, several
# search-ladder rungs, both overflow conventions, and range extremes
EXHAUSTIVE_FORMATS = [
    (5, 10, 0, 1),   # fp16 (the input grid itself: must be identity)
    (5, 2, 0, 1),    # e5m2
    (4, 3, 1, 0),    # e4m3 (saturating OCP)
    (4, 3, 0, 0),    # e4m3fn (NaN-overflow OCP)
    (8, 7, 0, 1),    # bf16
    (8, 10, 0, 1),   # tf32 rung
    (8, 5, 0, 1),    # ladder rung
    (8, 3, 0, 1),    # ladder rung
    (8, 23, 0, 1),   # carrier-fine: exact identity via the in-kernel gate
    (5, 14, 0, 1),   # RAPTOR's 5_14
    (3, 4, 0, 1),    # narrow-range ieee
    (2, 1, 1, 1),    # extreme narrow, saturating
    (6, 9, 1, 1),    # mid-range saturating
    (1, 5, 0, 1),    # degenerate exponent range
]


def _fmt_id(f):
    e, m, s, i = f
    return f"e{e}m{m}{'s' if s else ''}{'' if i else 'fn'}"


def _dyn(x, e, m, s, i, impl="ref"):
    row = np.array([e, m, s, i], np.int32)
    return np.asarray(jax.device_get(
        quantize_dynamic(jnp.asarray(x), row, impl=impl)))


@pytest.fixture(scope="module")
def f16_space():
    return all_float16_values()


@pytest.mark.parametrize("fmt", EXHAUSTIVE_FORMATS, ids=_fmt_id)
def test_exhaustive_fp16_dynamic_vs_oracle(fmt, f16_space):
    """The runtime-parameterized quantizer agrees with the exact-integer
    oracle on every float16 bit pattern."""
    e, m, s, i = fmt
    got = _dyn(f16_space, e, m, s, i)
    want = oracle_quantize(f16_space, e, m, bool(s), bool(i))
    assert_bits_equal(f"dynamic-vs-oracle-{_fmt_id(fmt)}",
                      f16_space, got, want, fmt=fmt)


@pytest.mark.parametrize("fmt", EXHAUSTIVE_FORMATS, ids=_fmt_id)
def test_exhaustive_fp16_three_way_parity(fmt, f16_space):
    """static trace-time path == dynamic jnp path == Pallas kernel
    (interpret mode), bit for bit, over the whole fp16 space. The static
    leg is NaN-payload-free: for bf16/fp16 it lowers to a hardware
    ``astype`` pair, which canonicalizes NaN payloads the pass-through
    dynamic path preserves."""
    e, m, s, i = fmt
    f = FPFormat(e, m, saturate=bool(s), ieee_inf=bool(i))
    static = np.asarray(jax.device_get(
        quantize(jnp.asarray(f16_space), f, impl="ref")))
    dyn = _dyn(f16_space, e, m, s, i, impl="ref")
    pallas = _dyn(f16_space, e, m, s, i, impl="interpret")
    assert_bits_equal(f"static-vs-dynamic-{_fmt_id(fmt)}",
                      f16_space, dyn, static, fmt=fmt,
                      nan_payload_free=True)
    assert_bits_equal(f"pallas-vs-dynamic-{_fmt_id(fmt)}",
                      f16_space, pallas, dyn, fmt=fmt)


def test_exhaustive_fp16_grid_idempotent(f16_space):
    """Quantizing to (5, 10) is the identity on the fp16 set (the values
    already lie on that grid) — the numpy f16 widening cross-check."""
    got = _dyn(f16_space, 5, 10, 0, 1)
    assert_bits_equal("fp16-idempotent", f16_space, got, f16_space,
                      fmt=(5, 10, 0, 1))


_ML_LEGS = []
try:
    import ml_dtypes

    _ML_LEGS = [
        ("fp16", (5, 10, 0, 1), np.float16),
        ("bf16", (8, 7, 0, 1), ml_dtypes.bfloat16),
        ("e5m2", (5, 2, 0, 1), ml_dtypes.float8_e5m2),
        ("e4m3fn", (4, 3, 0, 0), ml_dtypes.float8_e4m3fn),
    ]
except ImportError:
    pass


@pytest.mark.parametrize("leg", _ML_LEGS, ids=lambda l: l[0])
def test_exhaustive_fp16_vs_ml_dtypes(leg, f16_space):
    """For formats with a storage dtype, the oracle (hence the quantizer,
    by the tests above) matches the ml_dtypes RNE cast on every finite
    fp16 input. Non-finite inputs differ by documented convention: this
    repo's op-mode quantize passes ±inf/NaN through unchanged, while an
    fn-layout ml_dtypes cast maps inf to NaN."""
    name, (e, m, s, i), dt = leg
    x = f16_space
    fin = np.isfinite(x)
    want = oracle_quantize(x, e, m, bool(s), bool(i))
    cast = x.astype(dt).astype(np.float32)
    # NaN-payload-free: fn-layout overflow NaNs carry cast-specific payloads
    assert_bits_equal(f"mldtypes-{name}", x[fin], want[fin], cast[fin],
                      fmt=(e, m, s, i), nan_payload_free=True)
    # convention check on specials: quantize preserves them exactly
    assert np.array_equal(want[np.isinf(x)], x[np.isinf(x)])
    assert np.all(np.isnan(want[np.isnan(x)]))


# --------------------------------------------------------------------------
# randomized format/value corners (seeded — always runs in this tier)
# --------------------------------------------------------------------------

def _corner_values(rng, e, m, ieee_inf, n_random=512):
    """Adversarial inputs for one format: overflow boundary, subnormal
    range, grid half-way (tie) points, plus wide log-uniform noise."""
    _, min_exp, max_exp, max_finite = format_constants(e, m, bool(ieee_inf))
    with np.errstate(over="ignore", invalid="ignore"):
        mf32 = np.float32(max_finite)  # may be inf for e8 fn layouts
        specials = [0.0, -0.0, np.inf, -np.inf, np.nan, 1.0, -1.0,
                    max_finite,
                    float(np.nextafter(mf32, np.float32(np.inf))),
                    float(np.nextafter(mf32, np.float32(0))),
                    float(np.ldexp(1.0, min(max_exp + 1, 127))),
                    float(np.ldexp(1.0, min_exp)),
                    float(np.ldexp(1.0, min_exp - m)),      # smallest subn.
                    float(np.ldexp(1.0, min_exp - m - 1)),  # below the grid
                    float(np.ldexp(3.0, min_exp - m - 1))]  # subnormal tie
        # half-way (RNE tie) points n+0.5 grid units at random exponents
        # (e1-ieee formats have an empty normal range: no ties to draw)
        for _ in range(64 if max_exp >= min_exp else 0):
            E = int(rng.randint(min_exp, max_exp + 1))
            n = int(rng.randint(1 << m, 1 << (m + 1)))
            specials.append(float(np.ldexp(n + 0.5, E - m)))
        rand = (rng.randn(n_random)
                * np.power(10.0, rng.uniform(-42, 42, n_random)))
        vals = np.concatenate([np.asarray(specials, np.float64), rand])
        vals = vals.astype(np.float32)
    return np.concatenate([vals, -vals])


def _check_format(e, m, s, i, vals, tag):
    want = oracle_quantize(vals, e, m, bool(s), bool(i))
    got = _dyn(vals, e, m, s, i)
    assert_bits_equal(f"{tag}-dynamic", vals, got, want, fmt=(e, m, s, i))
    static = np.asarray(jax.device_get(quantize(
        jnp.asarray(vals), FPFormat(e, m, saturate=bool(s),
                                    ieee_inf=bool(i)), impl="ref")))
    # nan_payload_free: (8,7)/(5,10) draws hit the hardware astype path
    assert_bits_equal(f"{tag}-static", vals, static, want, fmt=(e, m, s, i),
                      nan_payload_free=True)


def test_randomized_format_corners():
    """60 random (e, m, saturate, ieee_inf) formats x ~1200 adversarial
    values each: dynamic and static paths vs the oracle, bit for bit."""
    rng = np.random.RandomState(20260728)
    for trial in range(60):
        e = int(rng.randint(1, 9))
        m = int(rng.randint(1, 24))
        s = int(rng.randint(2))
        i = int(rng.randint(2))
        vals = _corner_values(rng, e, m, i)
        _check_format(e, m, s, i, vals, f"corners-t{trial}-e{e}m{m}s{s}i{i}")


def test_randomized_pallas_parity():
    """The Pallas kernel (interpret mode) tracks the dynamic jnp path on
    randomized corner batches across random formats."""
    rng = np.random.RandomState(31337)
    for trial in range(12):
        e = int(rng.randint(1, 9))
        m = int(rng.randint(1, 24))
        s = int(rng.randint(2))
        i = int(rng.randint(2))
        vals = _corner_values(rng, e, m, i, n_random=256)
        ref = _dyn(vals, e, m, s, i, impl="ref")
        pal = _dyn(vals, e, m, s, i, impl="interpret")
        assert_bits_equal(f"pallas-t{trial}-e{e}m{m}s{s}i{i}",
                          vals, pal, ref, fmt=(e, m, s, i))


# --------------------------------------------------------------------------
# hypothesis property form (skips gracefully when hypothesis is absent —
# see the shim in tests/conftest.py)
# --------------------------------------------------------------------------

from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402


@settings(max_examples=100, deadline=None)
@given(e=st.integers(1, 8), m=st.integers(1, 23),
       s=st.booleans(), i=st.booleans(),
       seed=st.integers(0, 2 ** 31 - 1))
def test_hypothesis_format_space(e, m, s, i, seed):
    """Property form of the corner contract: for ANY format in the search
    space and any adversarial value batch, dynamic == static == oracle."""
    rng = np.random.RandomState(seed % (2 ** 31))
    vals = _corner_values(rng, e, m, i, n_random=128)
    _check_format(e, m, int(s), int(i), vals,
                  f"hyp-e{e}m{m}s{int(s)}i{int(i)}-{seed}")
