"""Bit-level conformance of the native fp8 dot's input quantize.

``kernels/fp8_dot.py`` pre-rounds each dot operand onto the e4m3 grid with
the repo's quantizer before casting to ``float8_e4m3fn`` storage — because
XLA's hardware cast double-rounds through bf16 on CPU. This suite pins
that contract against the independent exact-integer oracle on the entire
float16 value space, for both fp8 overflow conventions, and verifies the
storage cast is exact on everything the pre-rounding can produce (every
finite e4m3 grid value survives the f32 -> fp8 -> f32 round trip
bit-for-bit; infinities degrade to NaN, the fn-storage behaviour)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.core  # noqa: F401  — import order: core before kernels
from repro.kernels.fp8_dot import (
    F8_DTYPE, encode_e4m3, fp8_dot_general, quantize_dot_operand,
)
from bit_oracle import all_float16_values, oracle_quantize
from harness import assert_bits_equal

pytestmark = pytest.mark.conformance


@pytest.fixture(scope="module")
def f16_space():
    return all_float16_values()


@pytest.mark.parametrize("saturate", [True, False],
                         ids=["saturating", "fn-nan"])
def test_dot_input_quantize_vs_oracle(saturate, f16_space):
    """The operand pre-rounding agrees with the exact-integer oracle on
    every float16 bit pattern, both overflow conventions."""
    got = np.asarray(jax.device_get(
        quantize_dot_operand(jnp.asarray(f16_space), saturate=saturate)))
    want = oracle_quantize(f16_space, 4, 3, saturate, False)
    assert_bits_equal(f"fp8-dot-input-{'sat' if saturate else 'fn'}",
                      f16_space, got, want, fmt=[4, 3, int(saturate), 0])


@pytest.mark.parametrize("saturate", [True, False],
                         ids=["saturating", "fn-nan"])
def test_storage_cast_exact_on_grid(saturate, f16_space):
    """Casting pre-rounded values to fp8 storage and back is the identity
    on finite values: every e4m3 grid point is exactly representable in
    bf16 and f32, so any double-rounding inside the cast is harmless. The
    non-finite lanes (NaN always; +/-inf, which fn storage cannot hold)
    must come back as NaN."""
    xq = np.asarray(jax.device_get(
        quantize_dot_operand(jnp.asarray(f16_space), saturate=saturate)))
    back = np.asarray(jax.device_get(
        encode_e4m3(jnp.asarray(xq)).astype(jnp.float32)))
    finite = np.isfinite(xq)
    assert_bits_equal(f"fp8-storage-roundtrip-{'sat' if saturate else 'fn'}",
                      f16_space[finite], back[finite], xq[finite],
                      fmt=[4, 3, int(saturate), 0])
    assert np.all(np.isnan(back[~finite]))


def test_fp8_dot_matches_emulated_dot():
    """The native-storage dot equals an f32 dot over identically
    pre-rounded operands to accumulation-order tolerance (operand values
    are bit-identical by the tests above; only the contraction differs)."""
    r = np.random.RandomState(0)
    a = jnp.asarray(r.randn(128, 64) * 8, jnp.float32)
    b = jnp.asarray(r.randn(64, 96) * 8, jnp.float32)
    dn = (((1,), (0,)), ((), ()))
    nat = fp8_dot_general(a, b, dn)
    emu = jax.lax.dot_general(quantize_dot_operand(a),
                              quantize_dot_operand(b), dn,
                              preferred_element_type=jnp.float32)
    np.testing.assert_allclose(np.asarray(nat), np.asarray(emu),
                               rtol=1e-6, atol=1e-4)
    assert np.asarray(nat).dtype == np.float32
