"""Mesh-parallel profiling (@spmd tier): sharded truncate_sweep /
mem-mode / autosearch must be bit-for-bit consistent with the single-device
path, and the sharded ladder must keep the O(1)-compile contract while
putting >1 effective probe per dispatch on every device.

Each test runs in a subprocess that forces
``XLA_FLAGS=--xla_force_host_platform_device_count=8``, so the suite passes
on any host; CI's `spmd` job additionally sets the flag at the job level.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.spmd


def _run_subproc(code: str, timeout: int = 600) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    res = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert res.returncode == 0, res.stderr[-4000:]
    line = [l for l in res.stdout.splitlines() if l.startswith("RESULT")][0]
    return json.loads(line[len("RESULT"):])


_PRELUDE = textwrap.dedent("""
    import json
    import numpy as np
    import jax, jax.numpy as jnp
    from jax import lax
    from repro.core import (truncate, truncate_sweep, memtrace,
                            TruncationPolicy, scope)
    from repro.launch.mesh import make_probe_mesh, make_profile_mesh
    from repro.distributed.sharding import batch_sharding
    from repro import search

    assert len(jax.devices()) == 8, jax.devices()

    def _toy(w1, w2, x):
        with scope("attn"):
            h = jnp.tanh(x @ w1)
        with scope("mlp"):
            def body(c, _):
                return jax.nn.relu(c @ w2), None
            h, _ = lax.scan(body, h, None, length=3)
        with scope("head"):
            return jnp.mean(h * h)

    r = np.random.RandomState(0)
    args = (jnp.asarray(r.randn(32, 64) / 8, jnp.float32),
            jnp.asarray(r.randn(64, 64) / 8, jnp.float32),
            jnp.asarray(r.randn(16, 32), jnp.float32))
""")


def test_sharded_sweep_bit_for_bit_2x4_mesh():
    """truncate_sweep on a (probe=2, data=4) mesh: every ladder width's
    output must equal the single-device path bit-for-bit, including a K not
    divisible by the probe axis (identity-padded, sliced back)."""
    out = _run_subproc(_PRELUDE + textwrap.dedent("""
        mesh = make_profile_mesh(2, 4)
        site = TruncationPolicy.everywhere("e5m2")
        pols = [TruncationPolicy.everywhere(f"e8m{m}")
                for m in (15, 10, 7, 5, 3, 2)]
        h0 = truncate_sweep(_toy, site)(*args)
        h1 = truncate_sweep(_toy, site, mesh=mesh)(*args)
        t6 = h0.tables(pols)
        eq6 = bool(np.array_equal(jax.device_get(h0.batch(t6)),
                                  jax.device_get(h1.batch(t6))))
        t5 = h0.tables(pols[:5])   # K=5: not divisible by probe axis (2)
        b5 = jax.device_get(h1.batch(t5))
        eq5 = bool(np.array_equal(jax.device_get(h0.batch(t5)), b5))
        singles = [float(h0(h0.table(p))) for p in pols[:5]]
        print("RESULT" + json.dumps({
            "eq6": eq6, "eq5": eq5, "k5": list(np.shape(b5)),
            "singles_match": bool(np.allclose(singles, b5, rtol=0, atol=0)),
        }))
    """))
    assert out["eq6"], "sharded ladder diverged from single-device"
    assert out["eq5"], "identity-padded sharded ladder diverged"
    assert out["k5"] == [5], "padding leaked into the output batch"
    assert out["singles_match"]


def test_raptor_report_reductions_2x4_mesh():
    """Mem-mode exactness under data parallelism — the thing RAPTOR cannot
    do (§6.3): (1) GSPMD path: memtrace with the batch sharded 4-way must
    reproduce the single-device report bit-for-bit — including the
    cross-shard mean, which XLA lowers to a global collective; (2)
    shard_map path: per-shard reports of a per-example program reduced with
    RaptorReport.allreduce (psum/pmax) must match the global report (a
    shard_map body computes per-SHARD semantics, so this contract is for
    programs whose sharded execution is a slice of the global one — batch
    reductions belong on the GSPMD path); (3) host-side merge doubles
    counts and keeps maxes."""
    out = _run_subproc(_PRELUDE + textwrap.dedent("""
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        mesh = make_profile_mesh(2, 4)
        pol = TruncationPolicy.everywhere("e5m2")
        out0, rep0 = memtrace(_toy, pol)(*args)
        sh = [None, None, batch_sharding(mesh, "data")]
        out1, rep1 = memtrace(_toy, pol, mesh=mesh, in_shardings=sh)(*args)

        def eqs(a, b):
            return bool(np.array_equal(jax.device_get(a), jax.device_get(b)))

        # shard_map lane: a PER-EXAMPLE program (no cross-batch reduction,
        # so each shard's execution is exactly its slice of the global
        # program); each shard runs mem-mode on its batch slice (the
        # memtrace wrapper falls back to inline interpretation under the
        # outer trace), then allreduces the report over the data axis
        def _toy_ew(w1, w2, x):
            with scope("attn"):
                h = jnp.tanh(x @ w1)
            with scope("mlp"):
                def bd(c, _):
                    return jax.nn.relu(c @ w2), None
                h, _ = lax.scan(bd, h, None, length=3)
            with scope("head"):
                return h * h

        _, rep_ew = memtrace(_toy_ew, pol)(*args)

        def body(w1, w2, xs):
            _, rep = memtrace(_toy_ew, pol)(w1, w2, xs)
            return rep.allreduce("data")

        rep2 = shard_map(
            body, mesh=mesh,
            in_specs=(P(), P(), P("data")),
            out_specs=P(), check_rep=False)(*args)

        merged = rep0.merge(rep1)
        print("RESULT" + json.dumps({
            "gspmd_flags": eqs(rep0.flags, rep1.flags),
            "gspmd_max_rel": eqs(rep0.max_rel, rep1.max_rel),
            "gspmd_ops": eqs(rep0.op_counts, rep1.op_counts),
            "smap_flags": eqs(rep_ew.flags, rep2.flags),
            "smap_max_rel": eqs(rep_ew.max_rel, rep2.max_rel),
            "smap_ops": eqs(rep_ew.op_counts, rep2.op_counts),
            "out_close": bool(np.allclose(float(out0), float(out1),
                                          rtol=1e-6)),
            "merge_flags": eqs(merged.flags,
                               2 * jnp.asarray(rep0.flags)),
            "merge_max": eqs(merged.max_rel, rep0.max_rel),
            "n_loc": len(rep0.locations),
            "any_flags": int(jax.device_get(rep0.flags).sum()) > 0,
        }))
    """))
    for k in ("gspmd_flags", "gspmd_max_rel", "gspmd_ops", "smap_flags",
              "smap_max_rel", "smap_ops", "out_close", "merge_flags",
              "merge_max", "any_flags"):
        assert out[k], (k, out)
    assert out["n_loc"] >= 3


def test_sharded_ladder_single_compile_multi_probe_per_device():
    """Compile-cache contract under sharding: repeated sharded ladder
    dispatches reuse ONE executable, and each dispatch evaluates more than
    one probe per device (K=8 on a 4-device probe mesh -> 2/device)."""
    out = _run_subproc(_PRELUDE + textwrap.dedent("""
        from jax._src import test_util as _jtu
        mesh = make_probe_mesh(4)
        site = TruncationPolicy.everywhere("e5m2")
        pols = [TruncationPolicy.everywhere(f"e8m{m}")
                for m in (15, 10, 7, 5, 3, 2, 23, 11)]
        handle = truncate_sweep(_toy, site, mesh=mesh)(*args)
        tables = handle.tables(pols)
        with _jtu.count_jit_compilation_cache_miss() as n:
            a = jax.device_get(handle.batch(tables))
            b = jax.device_get(handle.batch(handle.tables(pols[::-1])))
        print("RESULT" + json.dumps({
            "compiles": int(n[0]),
            "k": len(pols), "ndev": 4,
            "consistent": bool(np.array_equal(a, b[::-1])),
        }))
    """))
    assert out["compiles"] == 1, f"sharded ladder recompiled: {out}"
    assert out["k"] / out["ndev"] > 1, "fewer than 2 probes per device"
    assert out["consistent"]


def test_trajectory_reduces_exactly_under_mesh():
    """profile_trajectory over a (probe=2, data=4) mesh: every signal the
    temporal analysis decides on — per-step max deviation, op counts, the
    step counter — must equal the single-device trajectory bit-for-bit on
    both the GSPMD path and the shard_map + TrajectoryReport.allreduce
    path. The float SUM buffers (abs_sum/mag_sum) are exact up to
    cross-shard summation order (the usual float-reduction contract), so
    they are pinned to tight allclose instead."""
    out = _run_subproc(_PRELUDE + textwrap.dedent("""
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.core import profile_trajectory

        mesh = make_profile_mesh(2, 4)
        pol = TruncationPolicy.everywhere("e5m2")

        def _steps(w1, w2, x):
            def body(c, _):
                with scope("mlp"):
                    c = jnp.tanh(c @ w2)
                return c, None
            h, _ = lax.scan(body, jnp.tanh(x @ w1), None, length=5)
            return h * h          # per-example output (shard_map-exact)

        args2 = args
        out0, t0 = profile_trajectory(_steps, pol, threshold=1e-3, n_steps=6)(*args2)
        sh = [None, None, batch_sharding(mesh, "data")]
        out1, t1 = profile_trajectory(_steps, pol, threshold=1e-3, n_steps=6,
                                      mesh=mesh, in_shardings=sh)(*args2)

        def eqs(a, b):
            return bool(np.array_equal(jax.device_get(a), jax.device_get(b)))

        def body(w1, w2, xs):
            _, t = profile_trajectory(_steps, pol, threshold=1e-3, n_steps=6)(
                w1, w2, xs)
            return t.allreduce("data")

        t2 = shard_map(body, mesh=mesh,
                       in_specs=(P(), P(), P("data")),
                       out_specs=P(), check_rep=False)(*args2)

        def close(a, b):
            return bool(np.allclose(jax.device_get(a), jax.device_get(b),
                                    rtol=1e-5, atol=1e-5))

        print("RESULT" + json.dumps({
            "gspmd": all(eqs(getattr(t0, k), getattr(t1, k))
                         for k in ("max_rel", "op_counts", "steps_seen")),
            "smap": all(eqs(getattr(t0, k), getattr(t2, k))
                        for k in ("max_rel", "op_counts", "steps_seen")),
            "gspmd_sums": (close(t0.abs_sum, t1.abs_sum)
                           and close(t0.mag_sum, t1.mag_sum)),
            "smap_sums": (close(t0.abs_sum, t2.abs_sum)
                          and close(t0.mag_sum, t2.mag_sum)),
            "steps": int(jax.device_get(t0.steps_seen)),
            "any_err": float(np.sum(jax.device_get(t0.abs_sum))) > 0,
            "out_eq": eqs(out0, out1),
        }))
    """))
    assert out["gspmd"], "sharded trajectory diverged from single-device"
    assert out["smap"], "allreduced per-shard trajectories diverged"
    assert out["gspmd_sums"] and out["smap_sums"]
    assert out["steps"] == 5 and out["any_err"] and out["out_eq"]


def test_sharded_autosearch_dispatch_stats_match_unsharded():
    """Identity-padded candidate rows must never leak into accounting:
    with a ladder whose logical width (7) does NOT divide the probe axis
    (8), the sharded search must report bit-identical n_dispatches,
    max_dispatch_rows, evals and history to the unsharded run — padding
    only widens the physical signature (probe_batch)."""
    out = _run_subproc(_PRELUDE + textwrap.dedent("""
        mesh = make_probe_mesh()   # 8 devices; k_logical = 6 + 1 = 7
        kw = dict(threshold=1e-2, budget=48)
        r0 = search.autosearch(_toy, args, search.rel_error, **kw)
        r1 = search.autosearch(_toy, args, search.rel_error, mesh=mesh, **kw)
        a0 = {p: [a.man_bits, a.excluded] for p, a in r0.assignments.items()}
        a1 = {p: [a.man_bits, a.excluded] for p, a in r1.assignments.items()}
        print("RESULT" + json.dumps({
            "same": a0 == a1,
            "evals": [r0.evals_used, r1.evals_used],
            "dispatches": [r0.n_dispatches, r1.n_dispatches],
            "max_rows": [r0.max_dispatch_rows, r1.max_dispatch_rows],
            "history": r0.history == r1.history,
            "k": [r0.probe_batch, r1.probe_batch],
            "ndev": r1.n_devices,
        }))
    """))
    assert out["same"]
    assert out["evals"][0] == out["evals"][1]
    assert out["dispatches"][0] == out["dispatches"][1], out
    assert out["max_rows"][0] == out["max_rows"][1], out
    assert out["history"]
    # the physical batch IS padded (7 -> 8): the contract is that padding
    # never shows up in the derived stats, not that it doesn't exist
    assert out["k"] == [7, 8] and out["ndev"] == 8


def test_autosearch_mesh_matches_single_device_bench_model():
    """Acceptance: autosearch on the bench model over an 8-device host
    probe mesh returns the SAME per-scope assignments as the single-device
    search, within the O(1) compile budget, with >1 effective probe per
    compile-dispatch per device (widths ladder of 10 on 8 shards)."""
    out = _run_subproc(_PRELUDE + textwrap.dedent("""
        import sys, os
        sys.path.insert(0, os.getcwd())
        from jax._src import test_util as _jtu
        from benchmarks.common import bench_model, bench_batch

        cfg, model, params = bench_model()
        batch = bench_batch(cfg)
        widths = (23, 15, 12, 10, 8, 7, 6, 5, 3, 2)
        r0 = search.autosearch(model.loss, (params, batch),
                               search.loss_degradation, 48,
                               threshold=5e-3, widths=widths)
        mesh = make_probe_mesh()   # all 8 devices
        with _jtu.count_jit_compilation_cache_miss() as n:
            r1 = search.autosearch(model.loss, (params, batch),
                                   search.loss_degradation, 48,
                                   threshold=5e-3, widths=widths, mesh=mesh)
        a0 = {p: [a.man_bits, a.excluded] for p, a in r0.assignments.items()}
        a1 = {p: [a.man_bits, a.excluded] for p, a in r1.assignments.items()}
        print("RESULT" + json.dumps({
            "same": a0 == a1, "a0": a0, "a1": a1,
            "compiles": int(n[0]),
            "n_compiles": r1.n_compiles,
            "converged": bool(r0.converged) and bool(r1.converged),
            "evals": [r0.evals_used, r1.evals_used],
            "budget_ok": r1.evals_used <= 48,
            "ppd": r1.probes_per_dispatch_per_device,
            "ndev": r1.n_devices,
        }))
    """), timeout=900)
    assert out["same"], f"assignments diverged: {out['a0']} vs {out['a1']}"
    assert out["converged"]
    assert out["budget_ok"] and out["evals"][0] == out["evals"][1]
    assert out["compiles"] <= 2, out
    assert out["n_compiles"] <= 2
    assert out["ndev"] == 8
    assert out["ppd"] > 1, ("sharded ladder must batch >1 probe per device "
                            f"per dispatch, got {out['ppd']}")
