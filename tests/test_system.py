"""End-to-end system tests: the paper's methodology exercised against the
LM stack (hypothesis -> truncate -> profile -> conclude), plus the serving
engine and the speedup model."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import (
    truncate, memtrace, profile_counts, TruncationPolicy, TruncationRule,
    estimate_speedup, fpu_area_model,
)
from repro.models import Model
from repro.serving.engine import Engine


@pytest.fixture(scope="module")
def setup():
    cfg = ArchConfig(name="sys", family="dense", n_layers=3, d_model=48,
                     n_heads=4, n_kv_heads=2, head_dim=12, d_ff=96, vocab=64,
                     dtype="float32", remat=False, scan_layers=False)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    r = np.random.RandomState(0)
    toks = r.randint(0, cfg.vocab, (4, 33))
    batch = {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
             "labels": jnp.asarray(toks[:, 1:], jnp.int32)}
    return cfg, model, params, batch


def _logit_l1(model, params, batch, policy):
    full = model.forward(params, batch)
    tr = truncate(model.forward, policy, impl="ref")(params, batch)
    return float(jnp.mean(jnp.abs(full - tr)))


@pytest.mark.slow
def test_error_vs_mantissa_monotone(setup):
    """Fig. 7 panel-1 analogue: global truncation error decreases with
    mantissa width (on average over the sweep)."""
    cfg, model, params, batch = setup
    errs = [
        _logit_l1(model, params, batch,
                  TruncationPolicy.everywhere(f"e8m{m}"))
        for m in (2, 6, 10, 23)
    ]
    assert errs[0] > errs[2] > errs[3]
    # identity format: only interpreter-rebind 1-ulp noise remains
    assert errs[3] < 1e-6


@pytest.mark.slow
def test_layer_cutoff_reduces_error(setup):
    """AMR M-l analogue: fencing the last layers (the 'finest blocks' —
    closest to the loss) reduces error vs truncating everything."""
    cfg, model, params, batch = setup
    pol_all = TruncationPolicy.everywhere("e8m4")
    err_all = _logit_l1(model, params, batch, pol_all)
    pol_m1 = pol_all.excluding("layer2", "final_norm", "logits")
    err_m1 = _logit_l1(model, params, batch, pol_m1)
    assert err_m1 < err_all


@pytest.mark.slow
def test_module_truncation_norms_are_fragile(setup):
    """Cellular/EOS analogue: truncating the (cheap) norms harms more than
    truncating the (expensive) MLPs, per unit of truncated work."""
    cfg, model, params, batch = setup
    err_mlp = _logit_l1(model, params, batch,
                        TruncationPolicy.scoped("**/mlp", "e8m2"))
    err_norm = _logit_l1(model, params, batch,
                         TruncationPolicy.scoped("**/pre_norm", "e8m2"))
    cnt_mlp = profile_counts(model.forward,
                             TruncationPolicy.scoped("**/mlp", "e8m2"))(
        params, batch)
    cnt_norm = profile_counts(model.forward,
                              TruncationPolicy.scoped("**/pre_norm", "e8m2"))(
        params, batch)
    frac_mlp = cnt_mlp.truncated_fraction
    frac_norm = cnt_norm.truncated_fraction
    assert frac_mlp > frac_norm  # mlp is most of the flops
    # error per truncated-flop-fraction is worse for norms
    assert err_norm / max(frac_norm, 1e-9) > err_mlp / max(frac_mlp, 1e-9)


@pytest.mark.slow
def test_memmode_flags_consistent_with_error(setup):
    cfg, model, params, batch = setup
    pol = TruncationPolicy.everywhere("e8m3")

    def fwd_sum(p, b):
        return jnp.sum(model.forward(p, b))
    out, rep = memtrace(fwd_sum, pol, threshold=1e-3, impl="ref")(params, batch)
    assert int(jnp.sum(rep.flags)) > 0
    top = rep.top(3)
    assert top[0][1] >= top[-1][1]


def test_speedup_model_paper_numbers():
    """Table 4 / Fig. 8 sanity: with the paper's Sod M-0 profile (86.3%
    truncated ops) the FPNew-density model lands near the paper's reported
    compute-bound predictions (~3.7x for half, ~2.2x for single)."""
    sod = {"full": 13.7}
    sp16 = fpu_area_model({**sod, "fp16": 86.3})["fp16"]
    assert 2.8 < sp16 < 4.2, sp16
    sp32 = fpu_area_model({**sod, "fp32": 86.3})["fp32"]
    assert 1.4 < sp32 < 2.6, sp32
    # pure truncation is the upper bound; partial truncation speeds up less
    pure = fpu_area_model({"full": 0.0, "fp16": 100.0})["fp16"]
    assert sp16 < pure


def test_estimate_speedup_bounds(setup):
    cfg, model, params, batch = setup
    pol = TruncationPolicy.everywhere("e5m2")
    rep = profile_counts(model.loss, pol)(params, batch)
    est = estimate_speedup(rep)
    assert est.compute_bound >= 1.0
    assert est.memory_bound >= 1.0
    assert est.bound in ("compute", "memory")


def test_serving_engine(setup):
    cfg, model, params, batch = setup
    eng = Engine(model, params, batch_size=2, max_seq_len=32)
    eng.submit(np.array([1, 2, 3]), max_new_tokens=4)
    eng.submit(np.array([4, 5, 6]), max_new_tokens=4)
    eng.submit(np.array([7, 8, 9]), max_new_tokens=2)
    done = eng.run()
    assert set(done) == {0, 1, 2}
    assert len(done[0].out_tokens) == 4
    assert len(done[2].out_tokens) == 2
    assert all(0 <= t < cfg.vocab for t in done[0].out_tokens)


def test_truncated_serving(setup):
    """Serving under a truncation policy (deployment-style mixed precision)."""
    cfg, model, params, batch = setup
    pol = TruncationPolicy.scoped("**/mlp", "fp16")
    full_logits, _ = jax.jit(model.decode_step)(
        params, model.init_cache(2, 8), jnp.zeros((2,), jnp.int32))
    tr_step = truncate(model.decode_step, pol, impl="ref")
    tr_logits, _ = tr_step(params, model.init_cache(2, 8),
                           jnp.zeros((2,), jnp.int32))
    assert tr_logits.shape == full_logits.shape
    assert bool(jnp.all(jnp.isfinite(tr_logits)))
