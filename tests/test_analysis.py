"""Static numerical analysis (``repro.analysis``): abstract-domain
soundness, per-rung verdicts, and the autosearch static-pruning
acceptance — pruned searches must return bit-identical assignments with
strictly fewer evals AND dispatches.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.analysis import (
    AbsVal, Verdict, analyze_closed, from_concrete, join, leq,
    scope_rung_verdicts, top_for_dtype, universally_exact,
)
from repro.analysis.verdicts import rne_overflow_boundary
from repro.core import interpreter
from repro.core.formats import BF16, FP16, FPFormat
from repro.core.policy import TruncationPolicy, TruncationRule

_EVERYWHERE = TruncationPolicy(rules=(
    TruncationRule(fmt=FPFormat(8, 0), scope="**"),))


# --------------------------------------------------------------------------
# abstract domain
# --------------------------------------------------------------------------


def test_from_concrete_exact_facts():
    v = from_concrete(np.float32([0.5, 2.0, -1.5]))
    assert v.hi == 2.0 and v.lo == 2.0           # max |x| known exactly
    assert v.min_nz == 0.5
    assert v.ulp_exp == -1                       # all multiples of 2^-1
    assert v.rel_bits == 1                       # 1.5 needs one mantissa bit
    assert v.finite and not v.nonneg

    nn = from_concrete(np.float32([0.0, 4.0]))
    assert nn.nonneg and nn.ulp_exp >= 2 and nn.rel_bits == 0


def test_from_concrete_nonfinite_falls_to_top():
    v = from_concrete(np.float32([1.0, np.nan]))
    assert not v.finite
    top = top_for_dtype(np.float32)
    assert leq(v, top) or v.hi == np.inf


def test_join_is_lattice_upper_bound():
    a = from_concrete(np.float32([0.5]))
    b = from_concrete(np.float32([-8.0, 3.0]))
    j = join(a, b)
    assert leq(a, j) and leq(b, j)
    assert leq(a, join(a, a)) and leq(join(a, a), a)   # idempotent


def test_universal_exactness_matches_carrier_grids():
    # e8m>=7 covers the whole bfloat16 grid; e8m<7 cannot
    for m in (7, 10, 15, 23):
        assert universally_exact(FPFormat(8, m), jnp.bfloat16)
    for m in (2, 3, 5):
        assert not universally_exact(FPFormat(8, m), jnp.bfloat16)
    # fp16 needs both the mantissa AND the subnormal reach: e8m10 keeps the
    # mantissa but its grid still covers fp16's subnormals via its own
    # wider exponent range; e5m10 is fp16 itself
    assert universally_exact(FPFormat(5, 10), jnp.float16)
    assert not universally_exact(FPFormat(8, 7), jnp.float16)
    # float32 is only covered from m23 up
    assert universally_exact(FPFormat(8, 23), jnp.float32)
    assert not universally_exact(FPFormat(8, 15), jnp.float32)


def _abs_check(v: AbsVal, arr: np.ndarray):
    """Concrete array is contained in the abstract value."""
    a = np.abs(np.asarray(arr, np.float64))
    if not np.all(np.isfinite(a)):
        assert v.hi == np.inf
        return
    amax = float(a.max()) if a.size else 0.0
    assert amax <= v.hi * (1 + 1e-9) + 1e-300, (amax, v.hi)
    assert v.lo <= amax * (1 + 1e-9) + 1e-300, (v.lo, amax)
    nz = a[a != 0]
    if nz.size:
        assert v.min_nz <= float(nz.min()) * (1 + 1e-9), (v.min_nz, nz.min())
    if np.isfinite(v.ulp_exp) and v.ulp_exp > -1000:
        q = np.asarray(arr, np.float64) / 2.0 ** v.ulp_exp
        assert np.allclose(q, np.round(q), rtol=0, atol=0), v.ulp_exp


@pytest.mark.parametrize("fn,args", [
    (lambda x: jnp.exp(-x * x) + 1.0, (np.float32([0.5, -2.0, 3.0]),)),
    (lambda x: jnp.sum(x ** 2) / np.float32(4.0), (np.float32([1.0, 2.0]),)),
    (lambda x, w: jnp.tanh(x @ w),
     (np.float32(np.arange(6).reshape(2, 3)) / 8,
      np.float32(np.ones((3, 2))) * 0.25)),
    (lambda x: jax.lax.scan(lambda c, t: (c * 0.5 + t, c), 0.0 * x[0], x)[1],
     (np.float32([1.0, 0.5, 0.25, 2.0]),)),
])
def test_outputs_sound_vs_concrete_eval(fn, args):
    """Every concrete program output lies inside its abstract envelope."""
    closed = jax.make_jaxpr(fn)(*args)
    res = analyze_closed(closed, list(args))
    concrete = fn(*args)
    leaves = jax.tree_util.tree_leaves(concrete)
    assert len(leaves) == len(res.out_vals)
    for v, out in zip(res.out_vals, leaves):
        _abs_check(v, np.asarray(out))


def test_scan_carry_fixpoint_terminates_and_widens():
    # a strictly growing carry cannot stabilize: the fixpoint must widen
    # (to the carrier top) instead of looping, and stay sound
    def f(x):
        def body(c, t):
            return c * 2.0 + t, c
        return jax.lax.scan(body, x[0], x)

    x = np.float32([1.0, 1.0, 1.0, 1.0])
    closed = jax.make_jaxpr(f)(x)
    res = analyze_closed(closed, [x])
    assert res.n_widened >= 1
    carry, ys = f(x)
    _abs_check(res.out_vals[0], np.asarray(carry))


# --------------------------------------------------------------------------
# per-rung verdicts
# --------------------------------------------------------------------------


def _sod_closed_bf16():
    from repro.apps import get_app
    app = get_app("sod")
    state = app.init_state(jnp.bfloat16)
    closed = jax.make_jaxpr(app.run_observables)(state)
    leaves = jax.tree_util.tree_leaves(((state,), {}))
    return app, state, closed, leaves


def test_sod_bf16_rung_verdicts():
    """bf16-carrier state: every e8m>=7 rung is statically EXACT (and
    universally so), narrower rungs stay dynamic."""
    from repro.search.scopes import discover_scopes
    app, state, closed, leaves = _sod_closed_bf16()
    res = analyze_closed(closed, leaves)
    paths = [s.path for s in discover_scopes(closed)]
    assert paths
    index = interpreter.enumerate_sites(closed, _EVERYWHERE)
    sv = scope_rung_verdicts(res, index, paths, [15, 10, 7, 5, 3, 2], 8)
    for p in paths:
        for w in (15, 10, 7):
            assert sv.get(p, w) == Verdict.EXACT
            assert sv.is_universal(p, w)
        for w in (5, 3, 2):
            assert sv.get(p, w) == Verdict.UNKNOWN
            assert not sv.is_universal(p, w)
    assert sv.n_decided == 3 * len(paths)
    js = sv.to_json()
    assert js[paths[0]]["m7"] == "EXACT"


def test_synthetic_overflow_splits_ladder():
    """A value provably at 3.3e38 overflows e8m2/e8m3 (RNE boundaries
    3.19e38 / 3.296e38) but not e8m5 (3.378e38) — and the verdict requires
    the inf to provably reach an output."""
    big = np.float32(3.3e38)
    assert rne_overflow_boundary(FPFormat(8, 2)) < float(big)
    assert rne_overflow_boundary(FPFormat(8, 3)) < float(big)
    assert rne_overflow_boundary(FPFormat(8, 5)) > float(big)

    def f(x):
        return x * big

    x = np.float32([1.0, -1.0])
    closed = jax.make_jaxpr(f)(x)
    res = analyze_closed(closed, [x])
    index = interpreter.enumerate_sites(closed, _EVERYWHERE)
    sv = scope_rung_verdicts(res, index, ["**"], [5, 3, 2], 8)
    assert sv.get("**", 2) == Verdict.OVERFLOW_CERTAIN
    assert sv.get("**", 3) == Verdict.OVERFLOW_CERTAIN
    assert sv.get("**", 5) == Verdict.UNKNOWN


def test_overflow_needs_criticality():
    """The same overflowing site feeding only a bounded output (tanh) is
    not certain to surface: the verdict must stay UNKNOWN."""
    big = np.float32(3.3e38)

    def f(x):
        return jnp.tanh(x * big)

    x = np.float32([1.0])
    closed = jax.make_jaxpr(f)(x)
    res = analyze_closed(closed, [x])
    index = interpreter.enumerate_sites(closed, _EVERYWHERE)
    sv = scope_rung_verdicts(res, index, ["**"], [2], 8)
    assert sv.get("**", 2) == Verdict.UNKNOWN


# --------------------------------------------------------------------------
# autosearch static pruning: bit-identical, strictly cheaper
# --------------------------------------------------------------------------


def _table(result):
    return {p: (a.man_bits, a.excluded)
            for p, a in result.assignments.items()}


def test_autosearch_static_prune_sod_bf16():
    """Tier-1 acceptance: on the bf16 Sod tube, static_prune=True returns
    bit-identical assignments with strictly fewer evals AND dispatches,
    and records the verdicts in artifact provenance."""
    from repro.apps import get_app
    from repro.search import driver

    app = get_app("sod")
    state = app.init_state(jnp.bfloat16)

    def run(**kw):
        return driver.autosearch(
            app.run_observables, (state,), app.error_metric, 64,
            threshold=app.search_threshold, **kw)

    base = run()
    pruned = run(static_prune=True)
    assert _table(pruned) == _table(base)
    assert pruned.final_error == base.final_error
    assert pruned.evals_used < base.evals_used
    assert pruned.n_dispatches < base.n_dispatches
    assert pruned.n_pruned > 0
    assert base.static_verdicts is None and pruned.static_verdicts

    art = pruned.to_artifact("sod_static")
    assert art.provenance["static_pruned"] == pruned.n_pruned
    assert art.provenance["static_verdicts"] == pruned.static_verdicts
    base_art = base.to_artifact("sod_dynamic")
    assert "static_verdicts" not in base_art.provenance

    # warm-started searches prune too, and stay bit-identical
    warm_base = run(warm_start=base.hints())
    warm_pruned = run(warm_start=base.hints(), static_prune=True)
    assert _table(warm_pruned) == _table(warm_base)
    assert warm_pruned.evals_used < warm_base.evals_used
    assert warm_pruned.n_dispatches < warm_base.n_dispatches


def test_static_prune_explicit_calibration():
    """static_prune accepts explicit per-invar ranges (AbsVals or arrays)
    instead of calibrating from the call's own arguments."""
    from repro.apps import get_app
    from repro.search import driver

    app = get_app("sod")
    state = app.init_state(jnp.bfloat16)
    leaves = jax.tree_util.tree_leaves(((state,), {}))
    calib = [from_concrete(x) for x in leaves]
    base = driver.autosearch(app.run_observables, (state,),
                             app.error_metric, 64,
                             threshold=app.search_threshold)
    pruned = driver.autosearch(app.run_observables, (state,),
                               app.error_metric, 64,
                               threshold=app.search_threshold,
                               static_prune=calib)
    assert _table(pruned) == _table(base)
    assert pruned.evals_used < base.evals_used


# --------------------------------------------------------------------------
# fixpoint termination across the arch-config zoo
# --------------------------------------------------------------------------

from repro.configs.base import ARCH_IDS, get_config  # noqa: E402

_FAST_ARCHS = {"h2o-danube-1.8b", "olmoe-1b-7b"}
_ARCH_PARAMS = [
    a if a in _FAST_ARCHS else pytest.param(a, marks=pytest.mark.slow)
    for a in ARCH_IDS
]


@pytest.mark.parametrize("arch_id", _ARCH_PARAMS)
def test_analysis_terminates_on_arch_configs(arch_id):
    """The widening fixpoint must terminate on every architecture's traced
    loss (scan carries, while loops, conds included), from dtype tops."""
    from repro.models import Model
    from tests.test_arch_smoke import make_batch

    cfg = get_config(arch_id, "smoke")
    model = Model(cfg)
    rng = np.random.default_rng(0)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, rng)
    closed = jax.make_jaxpr(model.loss)(params, batch)
    res = analyze_closed(closed)          # no inputs: dtype tops
    assert len(res.records) > 0
    assert len(res.out_vals) == len(closed.jaxpr.outvars)


# --------------------------------------------------------------------------
# @slow acceptance: bench model + remaining PDE apps
# --------------------------------------------------------------------------


@pytest.mark.slow
def test_autosearch_static_prune_bench_model_bf16():
    from benchmarks.common import bench_batch, bench_model
    from repro import search

    cfg, model, params = bench_model(dtype="bfloat16")
    batch = bench_batch(cfg)

    def run(**kw):
        return search.autosearch(model.loss, (params, batch),
                                 search.loss_degradation, 128,
                                 threshold=5e-3, **kw)

    base = run()
    pruned = run(static_prune=True)
    assert _table(pruned) == _table(base)
    assert pruned.evals_used < base.evals_used
    assert pruned.n_dispatches < base.n_dispatches


@pytest.mark.slow
@pytest.mark.parametrize("app_name", ["heat", "poisson"])
def test_autosearch_static_prune_pde_apps_bf16(app_name):
    from repro.apps import get_app
    from repro.search import driver

    app = get_app(app_name)
    state = app.init_state(jnp.bfloat16)

    def run(**kw):
        return driver.autosearch(
            app.run_observables, (state,), app.error_metric, 64,
            threshold=app.search_threshold, **kw)

    base = run()
    pruned = run(static_prune=True)
    assert _table(pruned) == _table(base)
    assert pruned.evals_used < base.evals_used
    assert pruned.n_dispatches < base.n_dispatches
