"""Continuous-batching serving: ragged admission into a busy batch,
bit-identity with isolated decoding, the zero-recompile discipline,
sampled shadow profiling with drift detection, and the unified
policy-resolution / profiling API surface (submit handles, keyword-only
thresholds, shared ``resolve_policy``)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.artifacts import PolicyArtifact, Registry
from repro.configs.base import ArchConfig
from repro.core import TruncationPolicy
from repro.core.api import memtrace, profile_counts, profile_trajectory
from repro.core.policy import ResolvedPolicy, parse_policy, resolve_policy
from repro.models import Model
from repro.serving import DriftEvent, Engine, Request, ShadowConfig


@pytest.fixture(scope="module")
def lm():
    cfg = ArchConfig(name="srv", family="dense", n_layers=2, d_model=48,
                     n_heads=4, n_kv_heads=2, head_dim=12, d_ff=96, vocab=64,
                     dtype="float32", remat=False, scan_layers=False)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _ragged_workload(cfg, seed=0, n=5):
    """Prompts of mixed length with mixed token budgets — the shape aligned
    waves cannot serve without padding every request to the longest."""
    r = np.random.RandomState(seed)
    lens = [3, 7, 5, 9, 2][:n]
    budgets = [4, 6, 3, 5, 8][:n]
    return [(r.randint(1, cfg.vocab, L).astype(np.int32), m)
            for L, m in zip(lens, budgets)]


def _isolated_outputs(model, params, workload, policy=None):
    """Reference: each request decoded alone in a batch-1 engine."""
    outs = []
    for prompt, m in workload:
        eng = Engine(model, params, batch_size=1, max_seq_len=32,
                     policy=policy)
        eng.submit(prompt, max_new_tokens=m)
        done = eng.run()
        outs.append(tuple(done[0].out_tokens))
    return outs


# --------------------------------------------------------------------------
# ragged admission + bit-identity
# --------------------------------------------------------------------------

def test_mixed_prompt_lengths_one_batch(lm):
    """Requests with different prompt lengths coexist in one decode batch:
    nothing waits for a wave, and every request runs to its own budget."""
    cfg, model, params = lm
    workload = _ragged_workload(cfg)
    eng = Engine(model, params, batch_size=3, max_seq_len=32)
    handles = [eng.submit(p, max_new_tokens=m) for p, m in workload]
    done = eng.run()
    assert len(done) == len(workload)
    for h, (_, m) in zip(handles, workload):
        assert h.done and h.status == "ok"
        assert len(h.out_tokens) == m
    assert done[handles[0].rid] is handles[0]   # dict returns the handles


def test_continuous_bit_identical_to_isolated(lm):
    """The acceptance bar: output tokens of continuous-batched decode are
    bit-identical to decoding each request alone — masked prefill into a
    busy batch and per-slot cursors change scheduling, never values."""
    cfg, model, params = lm
    workload = _ragged_workload(cfg)
    ref = _isolated_outputs(model, params, workload)
    eng = Engine(model, params, batch_size=3, max_seq_len=32)
    handles = [eng.submit(p, max_new_tokens=m) for p, m in workload]
    eng.run()
    assert [tuple(h.out_tokens) for h in handles] == ref


def test_continuous_bit_identical_under_policy(lm):
    cfg, model, params = lm
    pol = TruncationPolicy.scoped("**/mlp", "e5m4")
    workload = _ragged_workload(cfg, seed=1)
    ref = _isolated_outputs(model, params, workload, policy=pol)
    eng = Engine(model, params, batch_size=2, max_seq_len=32, policy=pol)
    handles = [eng.submit(p, max_new_tokens=m) for p, m in workload]
    eng.run()
    assert [tuple(h.out_tokens) for h in handles] == ref


def test_midstream_admission_into_freed_slot(lm):
    """More requests than slots: the queue drains into slots as they free
    mid-stream, while the other slot keeps decoding — and the jit cache
    never grows past one entry per path."""
    cfg, model, params = lm
    workload = _ragged_workload(cfg)          # 5 requests, 2 slots
    eng = Engine(model, params, batch_size=2, max_seq_len=32)
    handles = [eng.submit(p, max_new_tokens=m) for p, m in workload]
    ticks = 0
    admitted_midstream = False
    while eng.step():
        ticks += 1
        live = [s for s in eng.slots if s is not None]
        # once the first finishers drain, later submissions are live while
        # earlier ones still decode
        if any(h.done for h in handles) and any(
                not h.done and h in live for h in handles[2:]):
            admitted_midstream = True
    assert admitted_midstream
    assert all(h.done for h in handles)
    sizes = eng.cache_sizes()
    assert sizes["decode"] == 1 and sizes["reset"] == 1
    # no wave barrier: total ticks well under the sum of per-request spans
    spans = [len(p) + m for p, m in workload]
    assert ticks < sum(spans)


def test_quarantined_slot_immediately_reusable(lm):
    """A quarantined request frees its slot for the next admission on the
    same tick cadence as a healthy completion."""
    cfg, model, params = lm
    poisoned = jax.tree_util.tree_map(lambda p: p * jnp.nan, params)
    eng = Engine(model, poisoned, batch_size=2, max_seq_len=16)
    handles = [eng.submit(np.arange(1, 4, dtype=np.int32), max_new_tokens=4)
               for _ in range(3)]
    done = eng.run()
    assert len(done) == 3                     # the 3rd got a recycled slot
    for h in handles:
        assert h.done and h.status == "error_nonfinite"
        assert "quarantined" in h.error
    assert all(s is None for s in eng.slots)


# --------------------------------------------------------------------------
# engine handles: auto-rid, legacy shim, stream()
# --------------------------------------------------------------------------

def test_submit_returns_handle_with_auto_rid(lm):
    cfg, model, params = lm
    eng = Engine(model, params, batch_size=2, max_seq_len=16)
    a = eng.submit(np.array([1, 2, 3]), max_new_tokens=2)
    b = eng.submit(np.array([4, 5]), max_new_tokens=2)
    assert isinstance(a, Request) and (a.rid, b.rid) == (0, 1)
    c = eng.submit(np.array([6]), rid=7, max_new_tokens=2)
    assert c.rid == 7
    d = eng.submit(np.array([7]), max_new_tokens=2)
    assert d.rid == 8                          # auto-rids skip past explicit


def test_legacy_positional_submit_warns_and_works(lm):
    cfg, model, params = lm
    eng = Engine(model, params, batch_size=2, max_seq_len=16)
    with pytest.warns(DeprecationWarning, match="submit"):
        req = eng.submit(3, np.array([1, 2, 3]), max_new_tokens=2)
    assert req.rid == 3
    done = eng.run()
    assert done[3].out_tokens == req.out_tokens and len(req.out_tokens) == 2


def test_stream_yields_in_completion_order(lm):
    cfg, model, params = lm
    workload = _ragged_workload(cfg)
    eng = Engine(model, params, batch_size=2, max_seq_len=32)
    handles = [eng.submit(p, max_new_tokens=m) for p, m in workload]
    order = [r.rid for r in eng.stream()]
    assert sorted(order) == [h.rid for h in handles]
    assert all(h.done for h in handles)
    # short requests admitted early finish before long ones: completion
    # order is not submission order on a ragged workload
    assert order != [h.rid for h in handles]


def test_submit_validation_messages(lm):
    cfg, model, params = lm
    eng = Engine(model, params, batch_size=2, max_seq_len=16)
    with pytest.raises(ValueError, match="non-empty 1-D"):
        eng.submit(np.array([], np.int32))
    with pytest.raises(ValueError, match="max_seq_len=16"):
        eng.submit(np.arange(1, 17))
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.submit(np.array([1]), max_new_tokens=0)


# --------------------------------------------------------------------------
# shadow profiling + drift
# --------------------------------------------------------------------------

def test_shadow_serving_bit_identical_and_reports(lm):
    """Shadow-sampled requests serve the truncated lane's tokens — turning
    shadow profiling on changes observability, never the stream — and the
    per-request + rolling serving reports fill in."""
    cfg, model, params = lm
    pol = TruncationPolicy.scoped("**/mlp", "e5m7")
    workload = _ragged_workload(cfg)
    plain = Engine(model, params, batch_size=2, max_seq_len=32, policy=pol)
    ph = [plain.submit(p, max_new_tokens=m) for p, m in workload]
    plain.run()

    shadow = ShadowConfig(rate=1.0, threshold=1e-3)
    eng = Engine(model, params, batch_size=2, max_seq_len=32, policy=pol,
                 shadow=shadow)
    sh = [eng.submit(p, max_new_tokens=m) for p, m in workload]
    eng.run()
    assert all(h.shadowed for h in sh)
    assert [tuple(a.out_tokens) for a in sh] == \
           [tuple(a.out_tokens) for a in ph]
    assert eng.serving_report is not None
    assert eng.serving_report.top(1)           # merged rolling report
    assert all(h.report is not None for h in sh)
    sizes = eng.cache_sizes()
    assert sizes["shadow"] == 1 and sizes["reset"] == 1


def test_shadow_rate_zero_samples_nothing(lm):
    cfg, model, params = lm
    pol = TruncationPolicy.scoped("**/mlp", "e5m7")
    eng = Engine(model, params, batch_size=2, max_seq_len=16, policy=pol,
                 shadow=ShadowConfig(rate=0.0))
    h = eng.submit(np.array([1, 2, 3]), max_new_tokens=3)
    eng.run()
    assert not h.shadowed and h.report is None
    assert eng.serving_report is None or not eng.serving_report.top(1)


def test_drift_detection_pages_and_lands_in_provenance(lm):
    """A deployed artifact whose recorded budget the live traffic blows
    through fires exactly one drift event: hook called, blame ranked, and
    the guardrail log attached to the (new) artifact's provenance."""
    cfg, model, params = lm
    art = PolicyArtifact(name="drifty",
                         policy=TruncationPolicy.everywhere("e5m2"),
                         provenance={"threshold": 1e-7})
    events = []
    shadow = ShadowConfig(rate=1.0, threshold=1e-6, min_shadow_ticks=4,
                          drift_margin=4.0, on_drift=events.append)
    eng = Engine(model, params, batch_size=2, max_seq_len=32, policy=art,
                 shadow=shadow)
    for p, m in _ragged_workload(cfg):
        eng.submit(p, max_new_tokens=m)
    eng.run()
    assert len(events) == 1                    # latched: fires once
    ev = events[0]
    assert isinstance(ev, DriftEvent)
    assert ev.budget == pytest.approx(1e-7)
    assert ev.peak > 4.0 * ev.budget
    assert ev.blame and isinstance(ev.blame[0][0], str)
    assert eng.drift_events == [ev]
    kinds = eng.guardrail_log.kinds()
    assert kinds["drift_detected"] == 1 and kinds["research_paged"] == 1
    # the re-deployed artifact carries the evidence
    prov = eng.artifact.provenance["guardrail_log"]
    assert any(e["kind"] == "drift_detected" for e in prov)


def test_no_drift_within_budget(lm):
    cfg, model, params = lm
    art = PolicyArtifact(name="stable",
                         policy=TruncationPolicy.scoped("**/mlp", "e8m10"),
                         provenance={"threshold": 1e-1})
    events = []
    eng = Engine(model, params, batch_size=2, max_seq_len=32, policy=art,
                 shadow=ShadowConfig(rate=1.0, threshold=1e-3,
                                     min_shadow_ticks=2,
                                     on_drift=events.append))
    for p, m in _ragged_workload(cfg, n=2):
        eng.submit(p, max_new_tokens=m)
    eng.run()
    assert events == [] and eng.drift_events == []


# --------------------------------------------------------------------------
# unified profiling surface: keyword-only tails + deprecation shims
# --------------------------------------------------------------------------

def _f(x):
    return jnp.sin(x) * x


def test_memtrace_positional_threshold_deprecated():
    pol = TruncationPolicy.everywhere("e5m2")
    x = jnp.linspace(0.1, 2.0, 8)
    with pytest.warns(DeprecationWarning, match="threshold"):
        legacy = memtrace(_f, pol, 1e-2)
    modern = memtrace(_f, pol, threshold=1e-2)
    out_l, rep_l = legacy(x)
    out_m, rep_m = modern(x)
    assert np.array_equal(np.asarray(out_l), np.asarray(out_m))
    assert rep_l.top(2) == rep_m.top(2)


def test_profile_trajectory_positional_threshold_deprecated():
    pol = TruncationPolicy.everywhere("e5m2")
    x = jnp.linspace(0.1, 2.0, 8)
    with pytest.warns(DeprecationWarning, match="threshold"):
        legacy = profile_trajectory(_f, pol, 1e-2, n_steps=3)
    modern = profile_trajectory(_f, pol, threshold=1e-2, n_steps=3)
    assert legacy(x)[1].totals.top(1) == modern(x)[1].totals.top(1)


def test_profile_counts_signature_cache():
    pol = TruncationPolicy.everywhere("e5m7")
    counts = profile_counts(_f, pol)
    x = jnp.linspace(0.1, 2.0, 8)
    r1 = counts(x)
    r2 = counts(x)
    assert r1 == r2
    assert counts.n_traces == 1 and counts.cache_size() == 1
    counts.cache_clear()
    assert counts.cache_size() == 0


# --------------------------------------------------------------------------
# shared policy resolution (core.policy.resolve_policy)
# --------------------------------------------------------------------------

def test_resolve_policy_flag_string():
    res = resolve_policy("scope:**/mlp=e5m7")
    assert isinstance(res, ResolvedPolicy)
    assert res.policy == parse_policy("scope:**/mlp=e5m7")
    assert res.artifact is None and res.ref is None


def test_resolve_policy_none_and_empty():
    assert resolve_policy(None) == ResolvedPolicy()
    assert resolve_policy("") == ResolvedPolicy()


def test_resolve_policy_exclusive():
    with pytest.raises(ValueError, match="exclusive"):
        resolve_policy("scope:**/mlp=e5m7", "name@v1")


def test_resolve_policy_passthrough():
    pol = TruncationPolicy.everywhere("e5m4")
    assert resolve_policy(pol).policy is pol
    art = PolicyArtifact(name="pt", policy=pol)
    res = resolve_policy(art)
    assert res.policy is art.policy and res.artifact is art


def test_resolve_policy_registry_ref(tmp_path):
    pol = TruncationPolicy.scoped("**/attn", "e8m7")
    reg = Registry(str(tmp_path))
    ref = reg.save(PolicyArtifact(name="served", policy=pol))
    res = resolve_policy(ref.ref, registry=str(tmp_path))
    assert res.policy == pol
    assert res.ref is not None and res.ref.name == "served"
    # artifact_ref argument form (launch flags)
    res2 = resolve_policy(None, ref.ref, registry=reg)
    assert res2.policy == pol and res2.ref == res.ref


def test_launch_serve_resolve_policy_wrapper(tmp_path):
    """launch.serve keeps its (policy, artifact) convenience wrapper but
    routes through the shared core resolver."""
    from repro.launch.serve import resolve_policy as serve_resolve
    pol, art = serve_resolve("scope:**/mlp=e5m7", None)
    assert art is None and pol == parse_policy("scope:**/mlp=e5m7")
    with pytest.raises(SystemExit):
        serve_resolve("scope:**/mlp=e5m7", "x@v1")
