"""Checkpointing + fault tolerance: atomicity, resume, elastic re-shard,
supervisor retry, data-cursor determinism."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import DataConfig, Pipeline, Prefetcher
from repro.distributed.fault_tolerance import (
    StragglerMonitor, SupervisorConfig, run_supervised, best_mesh_shape,
)


def tree(seed=0):
    r = np.random.RandomState(seed)
    return {"w": jnp.asarray(r.randn(4, 4), jnp.float32),
            "nested": {"b": jnp.asarray(r.randn(3), jnp.float32),
                       "none": None},
            "step": jnp.int32(7)}


def assert_tree_equal(a, b):
    fa = jax.tree_util.tree_leaves(a)
    fb = jax.tree_util.tree_leaves(b)
    assert len(fa) == len(fb)
    for x, y in zip(fa, fb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_save_restore_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=False)
    t = tree()
    ck.save(3, t, extra={"data_step": 11})
    out, manifest = ck.restore(t)
    assert_tree_equal(out, t)
    assert manifest["extra"]["data_step"] == 11
    assert ck.latest_step() == 3


def test_async_save_with_wait(tmp_path):
    ck = Checkpointer(str(tmp_path), async_save=True)
    ck.save(1, tree(1))
    ck.save(2, tree(2))
    ck.wait()
    out, _ = ck.restore(tree(2))
    assert_tree_equal(out, tree(2))


def test_keep_k_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep_k=2, async_save=False)
    for s in (1, 2, 3, 4):
        ck.save(s, tree(s))
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == ["step_000000003", "step_000000004"]


def test_latest_pointer_atomic(tmp_path):
    """A stale tmp dir from a 'crashed' save never shadows LATEST."""
    ck = Checkpointer(str(tmp_path), async_save=False)
    ck.save(5, tree(5))
    os.makedirs(tmp_path / ".tmp_step_000000009_zombie", exist_ok=True)
    assert ck.latest_step() == 5
    out, _ = ck.restore(tree(5))
    assert_tree_equal(out, tree(5))


def test_elastic_reshard(tmp_path):
    """Save replicated, restore with explicit shardings on a 1-dev mesh
    (the same code path re-shards onto any elastic mesh shape)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.compat import make_mesh
    mesh = make_mesh((1,), ("data",))
    ck = Checkpointer(str(tmp_path), async_save=False)
    t = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    ck.save(1, t)
    sh = {"w": NamedSharding(mesh, P("data", None))}
    out, _ = ck.restore(t, shardings=sh)
    assert out["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(t["w"]))


def test_best_mesh_shape_elastic():
    assert best_mesh_shape(512, 16) == (32, 16)
    assert best_mesh_shape(256, 16) == (16, 16)
    assert best_mesh_shape(24, 16) == (3, 8)   # degraded pod: fewer chips
    assert best_mesh_shape(7, 16) == (7, 1)


def test_supervisor_restarts_on_failure(tmp_path):
    """step_fn dies twice; supervisor restores and completes the run."""
    state = {"restored": 0, "completed": [], "saved_at": 0}
    failures = {8: True, 13: True}

    def step_fn(step):
        if failures.pop(step, None):
            raise RuntimeError("collective timeout (simulated node death)")
        state["completed"].append(step)

    def save_fn(step):
        state["saved_at"] = step

    def restore_fn():
        state["restored"] += 1
        return state["saved_at"]

    final, restarts, _ = run_supervised(
        step_fn, save_fn, restore_fn, total_steps=20,
        cfg=SupervisorConfig(save_every=5))
    assert final == 20
    assert restarts == 2
    assert state["restored"] == 3  # initial + 2 failures
    assert 20 in [state["saved_at"]]


def test_straggler_monitor():
    m = StragglerMonitor(straggle_factor=2.0)
    for _ in range(10):
        assert not m.record(1.0)
    assert m.record(5.0)      # 5x median flags
    assert not m.record(1.1)


def test_straggler_monitor_rolling_window_eviction():
    """The window evicts oldest samples, so the median tracks the *current*
    regime: after a durable slowdown, old fast samples must age out and the
    new normal must stop alarming."""
    m = StragglerMonitor(straggle_factor=2.0, window=10)
    for _ in range(10):
        m.record(1.0)
    assert len(m._times) == 10
    # regime change: every step is now 3s. The first ones straggle vs the
    # old 1s median...
    assert m.record(3.0)
    # ...but once the window is full of 3s samples, the median has moved
    # and 3s is the new normal
    for _ in range(10):
        m.record(3.0)
    assert len(m._times) == 10          # bounded: evicted, not accumulated
    assert all(t == 3.0 for t in m._times)
    assert not m.record(3.0)
    # and the monitor still alarms relative to the NEW baseline
    assert m.record(7.0)


def test_data_pipeline_resume_determinism():
    cfg = DataConfig(seq_len=16, global_batch=4, vocab=100)
    p1 = Pipeline(cfg)
    batches = [p1.next() for _ in range(5)]
    state = p1.state_dict()
    more1 = [p1.next() for _ in range(3)]
    p2 = Pipeline(cfg)
    p2.load_state_dict(state)
    more2 = [p2.next() for _ in range(3)]
    for a, b in zip(more1, more2):
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # and the stream itself is deterministic from step 0
    p3 = Pipeline(cfg)
    np.testing.assert_array_equal(p3.next()["tokens"], batches[0]["tokens"])


def test_prefetcher():
    cfg = DataConfig(seq_len=8, global_batch=2, vocab=50)
    pf = Prefetcher(Pipeline(cfg))
    a = pf.next()
    b = pf.next()
    assert a["tokens"].shape == (2, 8)
    assert not np.array_equal(a["tokens"], b["tokens"])
    pf.close()


def test_memmap_pipeline(tmp_path):
    from repro.data.pipeline import write_token_file
    toks = np.arange(10_000, dtype=np.int32) % 97
    path = str(tmp_path / "tokens.bin")
    write_token_file(path, toks)
    cfg = DataConfig(seq_len=16, global_batch=4, vocab=97, kind="memmap",
                     path=path)
    p = Pipeline(cfg)
    b = p.next()
    assert b["tokens"].shape == (4, 16)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
