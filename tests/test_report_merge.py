"""RaptorReport.merge / merge_all edge cases (per-branch coverage).

The cross-shard reduction contract is documented in
``repro.core.memmode.RaptorReport`` but its edge branches — empty input,
single report, mismatched location tables, the no-truncated-locations
sentinel — were previously untested.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import memtrace, TruncationPolicy
from repro.core.memmode import RaptorReport


def _report(locs, flags, max_rel, op_counts):
    return RaptorReport(tuple(locs),
                        jnp.asarray(flags, jnp.int32),
                        jnp.asarray(max_rel, jnp.float32),
                        jnp.asarray(op_counts, jnp.int32))


def _get(x):
    return np.asarray(jax.device_get(x))


def test_merge_sums_and_maxes():
    a = _report(["l0", "l1"], [3, 0], [0.5, 0.0], [10, 4])
    b = _report(["l0", "l1"], [1, 2], [0.25, 1.5], [10, 4])
    m = a.merge(b)
    assert m.locations == ("l0", "l1")
    assert _get(m.flags).tolist() == [4, 2]
    assert _get(m.max_rel).tolist() == [0.5, 1.5]
    assert _get(m.op_counts).tolist() == [20, 8]


def test_merge_mismatched_locations_raises():
    """Reports from different computations must refuse to merge — including
    same-length tables whose location keys differ (the op_counts arrays
    would silently add misaligned rows otherwise)."""
    a = _report(["l0", "l1"], [1, 1], [0.1, 0.1], [2, 2])
    b = _report(["l0", "OTHER"], [1, 1], [0.1, 0.1], [2, 2])
    with pytest.raises(ValueError, match="location tables differ"):
        a.merge(b)
    # differing lengths hit the same guard, not a numpy broadcast error
    c = _report(["l0"], [1], [0.1], [2])
    with pytest.raises(ValueError, match="location tables differ"):
        a.merge(c)


def test_merge_all_empty_raises():
    with pytest.raises(ValueError, match="at least one report"):
        RaptorReport.merge_all([])


def test_merge_all_single_is_identity():
    a = _report(["l0"], [5], [0.75], [9])
    m = RaptorReport.merge_all([a])
    assert m is a  # single shard: no reduction work, no copy


def test_merge_all_many_is_left_fold():
    reports = [_report(["l0", "l1"], [i, 1], [0.1 * i, 0.2], [i, i])
               for i in range(1, 5)]
    m = RaptorReport.merge_all(reports)
    assert _get(m.flags).tolist() == [1 + 2 + 3 + 4, 4]
    assert _get(m.max_rel).tolist() == pytest.approx([0.4, 0.2])
    assert _get(m.op_counts).tolist() == [10, 10]


def test_merge_empty_sentinel_reports():
    """A computation with no truncated locations produces the sentinel
    single-row report; merging two of them must stay consistent rather than
    tripping on the placeholder table."""
    def f(x):
        return x * 2.0

    x = jnp.ones((4,), jnp.float32)
    _out, rep = memtrace(f, TruncationPolicy(rules=()), threshold=1e-3)(x)
    assert rep.locations == ("<no truncated locations>",)
    assert int(_get(rep.flags).sum()) == 0
    m = rep.merge(rep)
    assert m.locations == rep.locations
    assert int(_get(m.flags).sum()) == 0
    assert int(_get(m.op_counts).sum()) == 0


def test_merge_numpy_inputs_promote():
    """Host-side merging accepts numpy-stat reports (e.g. deserialized from
    another process) thanks to the jnp.asarray coercion in merge."""
    a = RaptorReport(("l0",), np.asarray([2]), np.asarray([0.5], np.float32),
                     np.asarray([7]))
    b = _report(["l0"], [3], [0.125], [5])
    m = RaptorReport.merge_all([a, b])
    assert _get(m.flags).tolist() == [5]
    assert _get(m.max_rel).tolist() == [0.5]
    assert _get(m.op_counts).tolist() == [12]
