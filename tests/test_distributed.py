"""Distribution: logical-axis resolution unit tests + an 8-fake-device
subprocess that executes a sharded train step and a sharded decode step
end-to-end (real multi-device SPMD on CPU)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

import jax
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd


def mk_mesh(shape, names):
    from repro.compat import make_mesh
    return make_mesh(shape, names)


def test_resolve_basic():
    mesh = mk_mesh((1, 1), ("data", "model"))
    spec = shd._resolve(mesh, shd.DEFAULT_PARAM_RULES,
                        ("embed", "heads"), (64, 64))
    # axes of size 1 are dropped by the divisibility guard
    assert spec == P()


def test_resolve_divisibility_guard():
    # kv_heads=2 on a 4-way model axis must fall back to cache_seq sharding
    # (AbstractMesh-style stand-in: _resolve only reads mesh.shape)
    import types
    mesh = types.SimpleNamespace(shape={"data": 2, "model": 4})
    spec = shd._resolve(mesh, shd.DEFAULT_ACT_RULES,
                        ("batch", "kv_heads", "cache_seq", None),
                        (8, 2, 64, 4))
    assert spec == P("data", None, "model")
    # divisible kv_heads win the model axis; cache_seq then drops (axis used)
    spec2 = shd._resolve(mesh, shd.DEFAULT_ACT_RULES,
                         ("batch", "kv_heads", "cache_seq", None),
                         (8, 8, 64, 4))
    assert spec2 == P("data", "model")
    # batch=1 (long_500k): batch sharding dropped
    spec3 = shd._resolve(mesh, shd.DEFAULT_ACT_RULES,
                         ("batch", "kv_heads", "cache_seq", None),
                         (1, 2, 64, 4))
    assert spec3 == P(None, None, "model")


def test_constrain_noop_without_mesh():
    import jax.numpy as jnp
    x = jnp.ones((4, 4))
    assert shd.constrain(x, "batch", "embed") is x


def test_probe_sharding_helpers():
    """Single-device-safe checks of the mesh-parallel profiling helpers:
    axis sizing, pad-to-shard-multiple arithmetic, and the replication
    fallback when a mesh lacks the requested axis."""
    import types
    mesh = types.SimpleNamespace(shape={"probe": 4, "data": 2})
    assert shd.probe_axis_size(None) == 1
    assert shd.probe_axis_size(mesh, "probe") == 4
    assert shd.probe_axis_size(mesh, "nope") == 1
    assert shd.pad_to_shards(7, None) == 7
    assert shd.pad_to_shards(0, mesh, "probe") == 0
    assert shd.pad_to_shards(1, mesh, "probe") == 4
    assert shd.pad_to_shards(7, mesh, "probe") == 8
    assert shd.pad_to_shards(8, mesh, "probe") == 8

    real = mk_mesh((1, 1), ("probe", "data"))
    assert shd.probe_sharding(real, "probe").spec == P("probe")
    assert shd.probe_sharding(real, "absent").spec == P()
    assert shd.batch_sharding(real, "data").spec == P("data")
    assert shd.replicated(real).spec == P()


def test_flatten_arg_shardings():
    """Per-argument prefix broadcasting onto the flat (args, kwargs) leaf
    list: one prefix entry covers its whole argument subtree, a single
    sharding broadcasts to positional leaves only, and kwargs leaves ALWAYS
    replicate (a scalar kwarg must never inherit a rank-1 batch spec)."""
    mesh = mk_mesh((1, 1), ("probe", "data"))
    params = {"w1": 1, "w2": 2}        # leaf identity is all that matters
    batch = {"x": 3, "y": 4}

    flat = shd.flatten_arg_shardings(mesh, None, (params, batch), {})
    assert [s.spec for s in flat] == [P()] * 4

    flat = shd.flatten_arg_shardings(
        mesh, [None, shd.batch_sharding(mesh, "data")], (params, batch), {})
    assert [s.spec for s in flat] == [P(), P(), P("data"), P("data")]

    # single sharding: positional leaves sharded, kwargs replicated
    flat = shd.flatten_arg_shardings(
        mesh, P("data"), (params,), {"scale": 5})
    assert [s.spec for s in flat] == [P("data"), P("data"), P()]

    # PartitionSpec entries resolve against the mesh; kwargs replicate
    flat = shd.flatten_arg_shardings(
        mesh, (P("data"), None), (params, batch), {"k": 0})
    assert [s.spec for s in flat] == [P("data"), P("data"), P(), P(), P()]

    assert shd.flatten_arg_shardings(None, None, (params,), {}) is None
    import pytest as _pytest
    with _pytest.raises(ValueError):
        shd.flatten_arg_shardings(mesh, [None, None, None], (params, batch),
                                  {})


SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs.base import get_config, SHAPES, InputShape
    from repro.distributed import sharding as shd
    from repro.launch import specs as sp
    from repro.launch.mesh import make_host_mesh
    from repro.models import Model
    from repro.train.trainer import TrainConfig, make_train_step, init_opt_state
    from repro.optim.adamw import AdamWConfig

    from repro.compat import make_mesh
    mesh = make_mesh((4, 2), ("data", "model"))
    cfg = get_config("olmoe-1b-7b", "smoke").replace(dtype="float32")
    model = Model(cfg)
    out = {}
    with shd.use_mesh(mesh):
        tc = TrainConfig(optimizer=AdamWConfig(lr=1e-2, weight_decay=0.0))
        step_fn = jax.jit(make_train_step(model, tc))
        params = model.init(jax.random.PRNGKey(0))
        # place params according to the FSDP x TP rules
        defs = model.param_defs()
        from repro.models.common import ParamDef
        sh = jax.tree_util.tree_map(
            lambda pd: shd.param_sharding(pd.shape, pd.axes, mesh),
            defs, is_leaf=lambda x: isinstance(x, ParamDef))
        params = jax.tree_util.tree_map(jax.device_put, params, sh)
        opt = init_opt_state(model, params, tc)
        r = np.random.RandomState(0)
        toks = r.randint(0, cfg.vocab, (8, 17))
        batch = {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
                 "labels": jnp.asarray(toks[:, 1:], jnp.int32)}
        losses = []
        for i in range(5):
            params, opt, m = step_fn(params, opt, batch, jnp.int32(i))
            losses.append(float(m["loss"]))
        out["losses"] = losses
        # sharded param survived: check one TP-sharded tensor
        wi = params["layers"]["moe"]["wi"]
        out["wi_sharded"] = str(wi.sharding.spec)

        # decode under the same mesh
        shape = InputShape("d", 32, 8, "decode")
        cache = model.init_cache(8, 32)
        logits, cache = jax.jit(model.decode_step)(
            params, cache, jnp.zeros((8,), jnp.int32))
        out["decode_finite"] = bool(jnp.all(jnp.isfinite(logits)))
    print("RESULT" + json.dumps(out))
""")


@pytest.mark.slow
def test_multidevice_train_and_decode():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("JAX_PLATFORMS", None)
    env["JAX_PLATFORMS"] = "cpu"
    res = subprocess.run([sys.executable, "-c", SUBPROC], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stderr[-3000:]
    line = [l for l in res.stdout.splitlines() if l.startswith("RESULT")][0]
    out = json.loads(line[len("RESULT"):])
    assert out["losses"][-1] < out["losses"][0]
    assert out["decode_finite"]
    assert "model" in out["wi_sharded"]
