"""Launch-layer tests: specs factories, roofline analysis, census parsing,
serve/zero1 sharding modes (single-device where possible; the 512-device
paths are covered by the dry-run sweep itself)."""
import json
import types

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import get_config, SHAPES, cells, ARCH_IDS
from repro.launch import specs as sp
from repro.launch import roofline
from repro.models import Model


def test_input_specs_no_mesh():
    cfg = get_config("glm4-9b")
    batch = sp.input_specs(cfg, SHAPES["train_4k"], None)
    assert batch["tokens"].shape == (256, 4096)
    assert batch["labels"].dtype == jnp.int32


def test_input_specs_stub_frontends():
    vlm = get_config("qwen2-vl-7b")
    b = sp.input_specs(vlm, SHAPES["train_4k"], None)
    assert b["embeds"].shape == (256, 4096, vlm.d_model)
    assert b["positions"].shape == (3, 256, 4096)
    enc = get_config("seamless-m4t-large-v2")
    b2 = sp.input_specs(enc, SHAPES["train_4k"], None)
    assert b2["src_embeds"].shape == (256, 4096, enc.d_model)
    assert b2["tokens"].shape == (256, 4096)


def test_params_specs_abstract():
    model = Model(get_config("olmoe-1b-7b"))
    specs = sp.params_specs(model, None)
    leaves = jax.tree_util.tree_leaves(specs)
    assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
    total = sum(np.prod(l.shape) for l in leaves)
    assert total == model.n_params()


def test_cache_specs_shapes():
    model = Model(get_config("deepseek-v2-236b"))
    cache = sp.cache_specs(model, SHAPES["decode_32k"], None)
    m = model.cfg.mla
    # MLA compressed cache: (L-1 scanned, B, S, kv_lora)
    assert cache["layers"]["c_kv"].shape == (59, 128, 32768, m.kv_lora)
    assert cache["lead"][0]["c_kv"].shape == (128, 32768, m.kv_lora)
    # per-slot cursor: one int32 per batch lane (continuous batching)
    assert cache["pos"].shape == (128,)


def test_cells_skip_rule():
    for arch in ARCH_IDS:
        shapes = dict((s.name, run) for s, run in cells(arch))
        assert shapes["train_4k"] and shapes["decode_32k"]
        expect_long = arch in ("hymba-1.5b", "h2o-danube-1.8b", "rwkv6-7b")
        assert shapes["long_500k"] == expect_long, arch


def test_roofline_analyze():
    rec = {
        "arch": "x", "shape": "train_4k", "n_devices": 256,
        "jaxpr_flops": 256 * 197e12,          # exactly 1 s compute
        "jaxpr_bytes": 1.0, "jaxpr_bytes_fused": 256 * 819e9 * 0.5,
        "model_flops": 256 * 197e12 * 0.7,
        "collectives": {"total_bytes": 50e9 * 0.25},
        "memory": {"argument_bytes": 1e9, "temp_bytes": 2e9},
    }
    row = roofline.analyze(rec)
    assert row["t_compute_s"] == pytest.approx(1.0)
    assert row["t_memory_s"] == pytest.approx(0.5)
    assert row["t_collective_s"] == pytest.approx(0.25)
    assert row["dominant"] == "compute"
    assert row["useful_ratio"] == pytest.approx(0.7)
    assert row["roofline_frac"] == pytest.approx(0.7)


def test_collective_census_trip_expansion():
    from repro.launch.dryrun import collective_census
    hlo = """
%cond_1 (p: (s32[])) -> pred[] {
  %p = (s32[]) parameter(0)
  %g = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(7)
  ROOT %lt = pred[] compare(%g, %c), direction=LT
}

%body_1 (p: (s32[])) -> (s32[]) {
  %p = (s32[]) parameter(0)
  %ar = f32[1024,256] all-reduce(%x), channel_id=1
  ROOT %t = (s32[]) tuple(%g)
}

ENTRY %main () -> f32[] {
  %w = (s32[]) while(%init), condition=%cond_1, body=%body_1
  %ag = f32[512] all-gather(%y), channel_id=2
}
"""
    census = collective_census(hlo)
    # all-reduce inside the 7-trip loop: 7 * 1024*256*4 bytes
    assert census["bytes_by_kind"]["all-reduce"] == 7 * 1024 * 256 * 4
    assert census["bytes_by_kind"]["all-gather"] == 512 * 4


def test_zero1_spec_shards_state():
    mesh = types.SimpleNamespace(shape={"data": 4, "model": 2})
    from repro.models.common import ParamDef
    # this test only exercises the resolution logic; build via _resolve
    from repro.distributed import sharding as shd
    pd = ParamDef((8, 64, 32), ("layers", "embed", "mlp"))
    base = shd._resolve(mesh, shd.SERVE_PARAM_RULES, pd.axes, pd.shape)
    # TP-only: embed not sharded, mlp on model
    assert base == P(None, None, "model")


def test_launch_entrypoints_import():
    import repro.launch.train
    import repro.launch.serve
    import repro.launch.dryrun
    assert callable(repro.launch.train.main)
    assert callable(repro.launch.serve.main)
    pol = repro.launch.train.parse_policy("scope:**/mlp=e5m7")
    assert pol.rules[0].fmt.man_bits == 7
    pol2 = repro.launch.train.parse_policy("32_to_5_14")
    assert pol2.rules[0].from_width == 32
