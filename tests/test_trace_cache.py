"""Trace caching: the op-mode/mem-mode pipeline must walk the jaxpr once
per input signature, not once per call, and scope normalization must keep
matching through grad + scan composition (the cache serves the search's
inner loop, so a silent re-trace would undo the tentpole)."""
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import (
    truncate, memtrace, TruncationPolicy, E5M2, BF16, scope,
)
from repro.core.policy import normalize_stack


def _model():
    r = np.random.RandomState(0)
    w = jnp.asarray(r.randn(64, 64), jnp.float32)
    x = jnp.asarray(r.randn(32, 64), jnp.float32)

    def f(w, x):
        with scope("mlp"):
            h = jnp.tanh(x @ w)
        return jnp.sum(h ** 2)

    return f, w, x


def test_second_call_does_not_retrace():
    """The trace-counting side effect: fn's python body runs only during a
    trace, so a counter inside it counts jaxpr walks."""
    traces = []
    f, w, x = _model()

    def counted(w, x):
        traces.append(1)
        return f(w, x)

    tr = truncate(counted, TruncationPolicy.everywhere(E5M2))
    a = float(tr(w, x))
    n_after_first = len(traces)
    b = float(tr(w, x))
    c = float(tr(w, x))
    assert a == b == c
    assert n_after_first >= 1
    assert len(traces) == n_after_first  # calls 2 and 3 hit the cache
    assert tr.n_traces == 1


def test_cached_call_is_5x_faster():
    f, w, x = _model()
    tr = truncate(f, TruncationPolicy.everywhere(E5M2))
    t0 = time.perf_counter()
    jax.block_until_ready(tr(w, x))
    first = time.perf_counter() - t0
    # best of 5 to keep CI noise out of the denominator
    second = min(
        _timed(lambda: jax.block_until_ready(tr(w, x))) for _ in range(5))
    assert first / second >= 5.0, (first, second)


def _timed(thunk):
    t0 = time.perf_counter()
    thunk()
    return time.perf_counter() - t0


def test_cache_keyed_on_input_signature():
    f, w, x = _model()
    tr = truncate(f, TruncationPolicy.everywhere(E5M2))
    tr(w, x)
    tr(w, x)
    assert tr.n_traces == 1
    # a new shape is a new signature -> exactly one more trace
    tr(w, x[:16])
    tr(w, x[:16])
    assert tr.n_traces == 2
    assert tr.cache_size() == 2


def test_cache_distinguishes_policies():
    """Two wrappers over the same fn with different policies must not share
    results (stable policy cache keys)."""
    f, w, x = _model()
    coarse = truncate(f, TruncationPolicy.everywhere(E5M2))
    fine = truncate(f, TruncationPolicy.everywhere(BF16))
    assert float(coarse(w, x)) != float(fine(w, x))


def test_grad_composition_falls_back_uncached():
    """Under an outer trace the wrapper must not cache tracer-laden
    jaxprs — and must still differentiate correctly."""
    f, w, x = _model()
    pol = TruncationPolicy.everywhere(E5M2)
    tr = truncate(f, pol)
    g = jax.grad(lambda w_: tr(w_, x))(w)
    assert bool(jnp.all(jnp.isfinite(g)))
    assert tr.cache_size() == 0  # nothing cached from the traced call
    # concrete call afterwards still populates and reuses the cache
    tr(w, x)
    tr(w, x)
    assert tr.cache_size() == 1


def test_memtrace_cached_reports_stable():
    f, w, x = _model()
    mt = memtrace(f, TruncationPolicy.everywhere(E5M2), threshold=1e-3)
    out1, rep1 = mt(w, x)
    out2, rep2 = mt(w, x)
    assert mt.n_traces == 1
    assert float(out1) == float(out2)
    np.testing.assert_array_equal(np.asarray(rep1.flags),
                                  np.asarray(rep2.flags))
    assert rep1.locations == rep2.locations


def test_jit_of_cached_wrapper_matches():
    f, w, x = _model()
    tr = truncate(f, TruncationPolicy.everywhere(E5M2))
    assert float(jax.jit(tr)(w, x)) == float(tr(w, x))


# --------------------------------------------------------------------------
# normalize_stack under grad + scan composition
# --------------------------------------------------------------------------

def _scan_loss(w, x):
    def body(c, _):
        with scope("cell"):
            c = jnp.tanh(c @ w)
        return c, None

    y, _ = lax.scan(body, x, None, length=3)
    return jnp.sum(y ** 2)


def test_normalize_stack_strings():
    assert normalize_stack("transpose(jvp(cell))/dot") == "cell/dot"
    assert normalize_stack("jvp(mlp)") == "mlp"
    assert normalize_stack("checkpoint/rematted_computation/mlp") == "mlp"
    assert normalize_stack("vmap(jvp(a))/b") == "a/b"


def test_scope_matching_under_grad_and_scan():
    """Backward-pass eqns inside the scanned cell keep matching the 'cell'
    scope; a non-matching policy is numerically inert."""
    r = np.random.RandomState(3)
    w = jnp.asarray(r.randn(16, 16) * 0.4, jnp.float32)
    x = jnp.asarray(r.randn(8, 16), jnp.float32)

    g_full = jax.grad(_scan_loss)(w, x)
    g_hit = truncate(jax.grad(_scan_loss),
                     TruncationPolicy.scoped("cell", E5M2))(w, x)
    assert not np.allclose(np.asarray(g_full), np.asarray(g_hit))
    g_miss = truncate(jax.grad(_scan_loss),
                      TruncationPolicy.scoped("no_such_scope", E5M2))(w, x)
    np.testing.assert_allclose(np.asarray(g_full), np.asarray(g_miss),
                               rtol=1e-6)


def test_backward_stacks_normalize_into_scope():
    """The traced grad jaxpr really contains transpose/jvp-wrapped stacks
    that normalize back onto the user scope (the regression: a jax upgrade
    changing the decoration format would silently stop matching)."""
    r = np.random.RandomState(3)
    w = jnp.asarray(r.randn(16, 16) * 0.4, jnp.float32)
    x = jnp.asarray(r.randn(8, 16), jnp.float32)

    def plain_loss(w, x):
        with scope("cell"):
            h = jnp.tanh(x @ w)
        return jnp.sum(h ** 2)

    closed = jax.make_jaxpr(jax.grad(plain_loss))(w, x)

    decorated = []

    def walk(jaxpr):
        for eqn in jaxpr.eqns:
            ns = str(eqn.source_info.name_stack)
            if ns:
                decorated.append(ns)
            for key in ("jaxpr", "call_jaxpr", "cond_jaxpr", "body_jaxpr"):
                sub = eqn.params.get(key)
                if sub is not None:
                    walk(sub.jaxpr if hasattr(sub, "jaxpr") else sub)
            for br in eqn.params.get("branches", ()):
                walk(br.jaxpr)

    walk(closed.jaxpr)
    wrapped = [ns for ns in decorated if "(" in ns]
    assert wrapped, "expected autodiff-decorated name stacks in grad jaxpr"
    assert any(normalize_stack(ns).startswith("cell") for ns in wrapped)
    assert all("(" not in normalize_stack(ns) for ns in decorated)


# --------------------------------------------------------------------------
# mask cache-key identity: tokens, not raw id()s
# --------------------------------------------------------------------------


def test_mask_cache_key_stable_and_distinct():
    from repro.core.policy import TruncationRule, magnitude_below

    m = magnitude_below(0.5)
    r1 = TruncationRule(fmt="bf16", mask=m)
    r2 = TruncationRule(fmt="bf16", mask=m)
    # same mask object -> same key (policies sharing a mask alias), and the
    # key is stable across repeated computation
    assert r1.cache_key() == r2.cache_key() == r1.cache_key()
    # a distinct closure with the same __name__ must NOT alias
    m2 = magnitude_below(0.5)
    assert TruncationRule(fmt="bf16", mask=m2).cache_key() != r1.cache_key()


def test_mask_cache_key_survives_id_reuse():
    """A cache key computed from a now-dead mask must never collide with a
    later mask that CPython happens to allocate at the same address —
    otherwise a trace cache keyed on the old policy serves its executable
    (the OLD predicate) for the new one."""
    from repro.core.policy import TruncationRule, magnitude_below

    # freeing the mask and immediately re-allocating an identical closure
    # lands on the recycled address essentially always under pymalloc's
    # LIFO free lists; retry a few times in case something intervenes
    reborn = key1 = None
    for _ in range(50):
        mask = magnitude_below(0.5)
        key1 = TruncationRule(fmt="bf16", mask=mask).cache_key()
        dead = id(mask)
        del mask
        for _ in range(100):
            cand = magnitude_below(0.5)   # same __name__ as the dead mask
            if id(cand) == dead:
                reborn = cand
                break
            del cand
        if reborn is not None:
            break
    if reborn is None:
        pytest.skip("allocator never reused the dead mask's address")
    key2 = TruncationRule(fmt="bf16", mask=reborn).cache_key()
    assert key1 != key2, "recycled id() aliased two distinct masks"
