"""Runtime-parameterized quantization must be bit-for-bit identical to the
static-format path: the whole point of `quantize_dynamic` is that swapping a
format table cell is indistinguishable from retracing with a new constant
format — across the full DEFAULT_WIDTHS ladder, both impls, the
saturate/ieee_inf overflow corners, float8 storage dtypes, and the f64
carrier."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.formats import FPFormat, parse_format
from repro.kernels.quantize_em.ops import (
    quantize, quantize_dynamic, format_row, IDENTITY_ROW,
)
from repro.search.driver import DEFAULT_WIDTHS


def _vec(n=4096, seed=0):
    rng = np.random.RandomState(seed)
    x = np.concatenate([
        rng.randn(n).astype(np.float32)
        * 10 ** rng.uniform(-12, 12, n).astype(np.float32),
        np.array([0.0, -0.0, 1.0, -1.0, np.inf, -np.inf, np.nan,
                  65504.0, 65505.0, 448.0, 464.0, 480.0, 3e-5,
                  5.96e-8, 2.98e-8, 1e-45, -1e-45, 2 ** -126, 2 ** -133],
                 np.float32)])
    return jnp.asarray(x)


def _assert_same_bits(a, b, fmt):
    an = np.asarray(jax.device_get(a))
    bn = np.asarray(jax.device_get(b))
    assert an.dtype == bn.dtype
    av = an.view(np.uint8 if an.dtype.itemsize == 1 else
                 np.int64 if an.dtype.itemsize == 8 else np.int32)
    bv = bn.view(av.dtype)
    bad = np.where(av != bv)[0]
    assert len(bad) == 0, (fmt, [(an[i], bn[i]) for i in bad[:5]])


@pytest.mark.parametrize("impl", ["ref", "interpret"])
@pytest.mark.parametrize("m", DEFAULT_WIDTHS)
def test_ladder_bit_for_bit(m, impl):
    """Every rung of the search ladder, static vs runtime-table formats.
    m=23 exercises the in-kernel identity gate against the static identity
    fast path; m=7/10 exercise it against the hardware convert pair."""
    x = _vec()
    fmt = FPFormat(8, m)
    _assert_same_bits(quantize(x, fmt, impl=impl),
                      quantize_dynamic(x, format_row(fmt), impl=impl), fmt)


@pytest.mark.parametrize("impl", ["ref", "interpret"])
@pytest.mark.parametrize("spec", ["e4m3", "e4m3fn", "e5m2", "fp16", "bf16",
                                  "e6m9s", "e2m1", "e5m14", "e4m0"])
def test_overflow_corners_bit_for_bit(spec, impl):
    """saturate (e4m3/e6m9s), fn-layout NaN overflow (e4m3fn), IEEE inf
    (e5m2), and the hardware formats — same bits through both entry points."""
    x = _vec(seed=7)
    fmt = parse_format(spec)
    _assert_same_bits(quantize(x, fmt, impl=impl),
                      quantize_dynamic(x, format_row(fmt), impl=impl), fmt)


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float16,
                                   jnp.float8_e4m3fn, jnp.float8_e5m2])
def test_narrow_storage_dtypes(dtype):
    """Sub-f32 storage (incl. float8): dtype round-trips and the values
    agree bitwise with the static path."""
    x = jnp.asarray(np.random.RandomState(3).randn(512), jnp.float32)
    xs = x.astype(dtype)
    for spec in ("e4m2", "e3m1", "e4m3", "fp32"):
        fmt = parse_format(spec)
        a = quantize(xs, fmt, impl="ref")
        b = quantize_dynamic(xs, format_row(fmt), impl="ref")
        assert b.dtype == xs.dtype
        _assert_same_bits(a, b, (dtype, fmt))


def test_f64_carrier_bit_for_bit():
    from repro.compat import enable_x64
    with enable_x64():
        x64 = jnp.asarray(
            np.random.RandomState(0).randn(256).astype(np.float64) / 3.0)
        for fmt in (parse_format("5_14"), FPFormat(8, 30), FPFormat(11, 52),
                    parse_format("e4m3")):
            _assert_same_bits(quantize(x64, fmt, impl="ref"),
                              quantize_dynamic(x64, format_row(fmt),
                                               impl="ref"), fmt)


def test_identity_row_is_bitwise_identity():
    x = _vec()
    y = quantize_dynamic(x, IDENTITY_ROW, impl="ref")
    _assert_same_bits(x, y, "identity")


@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_vmap_over_format_table(impl):
    """A (K, 4) table vmapped over its leading axis equals K separate static
    quantizations — the batched-policy-sweep building block."""
    x = _vec(n=512, seed=1)
    fmts = [FPFormat(8, m) for m in (15, 7, 3)] + [parse_format("e4m3")]
    table = jnp.asarray(np.stack([format_row(f) for f in fmts]))
    rows = jax.vmap(lambda r: quantize_dynamic(x, r, impl=impl))(table)
    for i, fmt in enumerate(fmts):
        _assert_same_bits(rows[i], quantize(x, fmt, impl=impl), fmt)


def test_traced_format_single_compile():
    """The format is runtime data: one jitted callable serves every format
    without retracing (the executable-level zero-recompile guarantee)."""
    x = _vec(n=256, seed=2)
    traces = []

    @jax.jit
    def q(row):
        traces.append(1)
        return quantize_dynamic(x, row, impl="ref")

    for fmt in (FPFormat(8, 7), FPFormat(5, 2), parse_format("e4m3")):
        _assert_same_bits(q(jnp.asarray(format_row(fmt))),
                          quantize(x, fmt, impl="ref"), fmt)
    assert len(traces) == 1
