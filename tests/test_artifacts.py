"""Policy artifacts: lossless policy/artifact JSON round trips, the
file-backed versioned registry, and the profile -> registry -> deploy loop
(serving equivalence, warm-start re-search, checkpoint identity, the CI
drift gate's diff)."""
import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import search
from repro.artifacts import (
    ArtifactRef, ArtifactSchemaError, PolicyArtifact, Registry, ScopeRow,
    SCHEMA_VERSION, load_artifact_file, parse_ref, save_artifact_file,
)
from repro.configs.base import ArchConfig
from repro.core import (
    truncate, NotSerializableError, TruncationPolicy, TruncationRule, scope,
)
from repro.core.formats import E4M3, E4M3FN, FPFormat
from repro.core.policy import magnitude_below, parse_policy
from repro.models import Model
from repro.serving.engine import Engine

try:
    from jax._src import test_util as _jtu
    _count_compiles = _jtu.count_jit_compilation_cache_miss
except (ImportError, AttributeError):  # jax moved the helper
    _count_compiles = None

needs_compile_counter = pytest.mark.skipif(
    _count_compiles is None, reason="no jax compile-cache counter available")


# --------------------------------------------------------------------------
# policy / format JSON round trips
# --------------------------------------------------------------------------

EVERY_RULE_KIND = [
    # RAPTOR width-conditional flag rules
    TruncationPolicy.from_flag("64_to_5_14;32_to_3_8"),
    # scoped single rule
    TruncationPolicy.scoped("**/mlp", "e5m7"),
    # op whitelist / blacklist granularity
    TruncationPolicy(rules=(TruncationRule(
        fmt=FPFormat(8, 10), scope="layer*/attn", ops=("dot_general", "add"),
        exclude_ops=("exp", "tanh")),)),
    # MXU-input emulation
    TruncationPolicy(rules=(TruncationRule(
        fmt=FPFormat(8, 7), quantize_dot_inputs=True),)),
    # non-default format conventions: saturating and "fn" (no-inf) layouts
    TruncationPolicy(rules=(TruncationRule(fmt=E4M3, scope="a/**"),
                            TruncationRule(fmt=E4M3FN, scope="b"),
                            TruncationRule(fmt=FPFormat(5, 2, saturate=True),
                                           from_width=32))),
    # fenced-off scopes + multiple ordered rules
    TruncationPolicy(rules=(TruncationRule(fmt=FPFormat(8, 2), scope="**"),
                            TruncationRule(fmt=FPFormat(8, 10),
                                           scope="head")),
                     excludes=("recon", "layer0/attn")),
]


@pytest.mark.parametrize("pol", EVERY_RULE_KIND,
                         ids=lambda p: f"{len(p.rules)}rules")
def test_policy_json_round_trip_every_rule_kind(pol):
    """Every serializable rule kind survives JSON bit-exactly: dataclass
    equality AND trace-cache identity (cache_key) hold after the trip —
    through a real json.dumps, not just dict passing."""
    back = TruncationPolicy.from_json(json.loads(json.dumps(pol.to_json())))
    assert back == pol
    assert back.cache_key() == pol.cache_key()


def test_mini_app_default_policies_round_trip():
    from repro.apps import get_app

    for name in ("sod", "heat", "poisson"):
        app = get_app(name)
        uni = app.uniform_policy()
        assert TruncationPolicy.from_json(uni.to_json()) == uni
        scoped = TruncationPolicy(rules=tuple(
            TruncationRule(fmt=FPFormat(8, m), scope=s)
            for m, s in enumerate(app.default_policy_scopes(), start=3)))
        assert TruncationPolicy.from_json(scoped.to_json()) == scoped


def test_mask_rule_raises_not_serializable():
    pol = TruncationPolicy(rules=(TruncationRule(
        fmt=FPFormat(8, 4), scope="**/mlp", mask=magnitude_below(1e-3)),))
    with pytest.raises(NotSerializableError, match="magnitude_below"):
        pol.to_json()
    art = PolicyArtifact(name="masked", policy=pol)
    with pytest.raises(NotSerializableError):
        art.to_json()
    # NotSerializableError is a TypeError: existing `except TypeError`
    # call sites keep working
    assert issubclass(NotSerializableError, TypeError)


def test_future_schema_version_fails_naming_versions():
    art = PolicyArtifact(name="x", policy=TruncationPolicy.scoped("a", "e8m4"))
    data = art.to_json()
    data["schema_version"] = 99
    with pytest.raises(ArtifactSchemaError) as ei:
        PolicyArtifact.from_json(data)
    assert "99" in str(ei.value) and str(SCHEMA_VERSION) in str(ei.value)


def test_artifact_round_trip_and_digest():
    pol = TruncationPolicy.from_flag("32_to_5_7")
    art = PolicyArtifact(
        name="demo", policy=pol,
        assignments={"mlp": ScopeRow(man_bits=4, error_at_accept=1e-4,
                                     flops=100.0, fraction=0.5, n_eqns=3),
                     "attn": ScopeRow(man_bits=23, error_at_accept=0.0,
                                      excluded=True)},
        provenance={"threshold": 1e-3, "history": [["probe", 0.1]]},
        hints={"mlp": 4, "attn": None})
    back = PolicyArtifact.loads(art.dumps())
    assert back == art
    assert back.digest == art.digest
    # digest is over canonical bytes: construction order must not matter
    art2 = PolicyArtifact(
        name="demo", policy=pol,
        assignments=dict(reversed(list(art.assignments.items()))),
        provenance={"history": [["probe", 0.1]], "threshold": 1e-3},
        hints={"attn": None, "mlp": 4})
    assert art2.digest == art.digest


def test_parse_policy_grammar_and_back_compat():
    assert parse_policy(None) is None
    assert parse_policy("") is None
    pol = TruncationPolicy.scoped("**/mlp", "e5m7")
    assert parse_policy(pol) is pol
    assert parse_policy("scope:**/mlp=e5m7") == pol
    assert parse_policy("64_to_5_14;32_to_3_8") == \
        TruncationPolicy.from_flag("64_to_5_14;32_to_3_8")
    # parse_policy moved core-side; the old launch.train import keeps working
    from repro.launch.train import parse_policy as launch_parse_policy
    assert launch_parse_policy is parse_policy


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------

def _artifact(name="m", man_bits=4):
    return PolicyArtifact(
        name=name,
        policy=TruncationPolicy.scoped("**/mlp", FPFormat(8, man_bits)),
        assignments={"mlp": ScopeRow(man_bits=man_bits,
                                     error_at_accept=1e-4)},
        hints={"mlp": man_bits})


def test_parse_ref():
    assert parse_ref("bench_model") == ("bench_model", None)
    assert parse_ref("bench_model@v3") == ("bench_model", 3)
    with pytest.raises(ValueError, match="name@vN"):
        parse_ref("bench_model@three")


def test_registry_save_load_versions_latest(tmp_path):
    reg = Registry(str(tmp_path))
    refs = [reg.save(_artifact(man_bits=m)) for m in (2, 4, 7)]
    assert [r.version for r in refs] == [1, 2, 3]
    assert refs[0].ref == "m@v1"
    assert reg.names() == ["m"]
    assert reg.versions("m") == [1, 2, 3]
    assert reg.latest_version("m") == 3
    # pinned load, latest load, ref resolution, digest verification
    assert reg.load("m@v1") == _artifact(man_bits=2)
    assert reg.load("m") == _artifact(man_bits=7)
    art, ref = reg.load_ref("m")
    assert ref.version == 3 and ref.digest == art.digest
    assert reg.digest("m@v2") == _artifact(man_bits=4).digest
    # ArtifactRef JSON round trip (the checkpoint-manifest form)
    assert ArtifactRef.from_json(refs[1].to_json()) == refs[1]


def test_registry_missing_refs_fail_clearly(tmp_path):
    reg = Registry(str(tmp_path))
    with pytest.raises(FileNotFoundError, match="empty registry"):
        reg.load("nope")
    reg.save(_artifact())
    with pytest.raises(FileNotFoundError, match="m@v9"):
        reg.load("m@v9")


def test_registry_keep_k_gc_and_latest_self_heal(tmp_path):
    reg = Registry(str(tmp_path), keep_k=2)
    for m in (2, 3, 4, 5):
        reg.save(_artifact(man_bits=m))
    assert reg.versions("m") == [3, 4]          # GC kept the newest two
    assert reg.load("m") == _artifact(man_bits=5)
    # LATEST pointer lost (crash between the two renames): self-heals to
    # the newest durable version instead of failing
    os.remove(tmp_path / "m" / "LATEST")
    assert reg.latest_version("m") == 4
    assert reg.load("m") == _artifact(man_bits=5)


def test_registry_ignores_stale_tmp_dirs(tmp_path):
    reg = Registry(str(tmp_path))
    reg.save(_artifact())
    # a crashed writer's leftover tmp dir must be invisible to readers and
    # must not block the next save
    os.makedirs(tmp_path / "m" / ".tmp_v0002_99999")
    os.makedirs(tmp_path / ".half-written")
    assert reg.versions("m") == [1]
    assert reg.names() == ["m"]
    ref = reg.save(_artifact(man_bits=9))
    assert ref.version == 2


def test_artifact_file_round_trip(tmp_path):
    path = str(tmp_path / "committed" / "m.json")
    art = _artifact()
    save_artifact_file(art, path)
    assert load_artifact_file(path) == art
    # pretty-printed + trailing newline: reviewable, stable git diffs
    text = open(path).read()
    assert text.endswith("\n") and "\n  " in text


def test_committed_bench_model_artifact_is_valid():
    """The CI drift gate's committed artifact must stay loadable and
    internally consistent (hints cover exactly the searched scopes)."""
    path = os.path.join(os.path.dirname(__file__), os.pardir,
                        "artifacts", "bench_model.json")
    art = load_artifact_file(path)
    assert art.name == "bench_model"
    assert len(art.policy.rules) >= 1
    assert art.assignments and set(art.hints) == set(art.assignments)
    assert art.provenance["threshold"] == 5e-3
    assert art.schema_version == SCHEMA_VERSION


def test_policy_drift_diff_detects_assignment_moves():
    from benchmarks.policy_drift import diff_assignments

    committed = _artifact(man_bits=4)
    lines = []
    assert diff_assignments(committed, _artifact(man_bits=4),
                            log=lines.append) == []
    drift = diff_assignments(committed, _artifact(man_bits=7),
                             log=lines.append)
    assert len(drift) == 1 and "mlp" in drift[0] and "m=7" in drift[0]
    assert any("DRIFT" in ln for ln in lines)


# --------------------------------------------------------------------------
# producers: search + oracle
# --------------------------------------------------------------------------

def _toy(w1, w2, x):
    with scope("attn"):
        h = jnp.tanh(x @ w1)
    with scope("mlp"):
        h = jax.nn.relu(h @ w2) @ w2.T
    with scope("head"):
        return jnp.mean(h * h)


def _toy_args(seed=0):
    r = np.random.RandomState(seed)
    return (jnp.asarray(r.randn(32, 64) / 8, jnp.float32),
            jnp.asarray(r.randn(64, 64) / 8, jnp.float32),
            jnp.asarray(r.randn(16, 32), jnp.float32))


def _assigns(res):
    return {p: (a.man_bits, a.excluded) for p, a in res.assignments.items()}


def test_search_result_to_artifact_provenance(tmp_path):
    args = _toy_args()
    res = search.autosearch(_toy, args, search.rel_error, 48, threshold=1e-2)
    art = res.to_artifact("toy")
    assert art.policy == res.policy()
    assert set(art.assignments) == set(res.assignments)
    for p, a in res.assignments.items():
        row = art.assignments[p]
        assert (row.man_bits, row.excluded) == (a.man_bits, a.excluded)
        assert row.fraction == pytest.approx(a.scope.fraction)
    prov = art.provenance
    assert prov["threshold"] == 1e-2 and prov["budget"] == 48
    assert prov["evals_used"] == res.evals_used
    assert prov["n_dispatches"] == res.n_dispatches
    assert prov["history"] and all(len(h) == 2 for h in prov["history"])
    assert art.hints == res.hints()
    # the whole bundle survives the registry byte round trip
    reg = Registry(str(tmp_path))
    ref = reg.save(art)
    assert reg.load(ref.ref) == art


def test_oracle_verdict_attach():
    from repro.apps.oracle import OracleVerdict

    v = OracleVerdict(app="sod", error=2e-4, budget=1e-3, floor=5e-5)
    art = v.attach(_artifact("sod"))
    assert art.oracle == {"app": "sod", "error": 2e-4, "budget": 1e-3,
                          "floor": 5e-5, "passed": True}
    assert OracleVerdict.from_json(art.oracle).passed
    assert "oracle PASS" in str(art)
    back = PolicyArtifact.loads(art.dumps())
    assert back.oracle == art.oracle


# --------------------------------------------------------------------------
# consumers: engine, checkpointer, hot-swap trainer
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def lm():
    cfg = ArchConfig(name="art", family="dense", n_layers=2, d_model=48,
                     n_heads=4, n_kv_heads=2, head_dim=12, d_ff=96, vocab=64,
                     dtype="float32", remat=False, scan_layers=False)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_engine_submit_validation(lm):
    cfg, model, params = lm
    eng = Engine(model, params, batch_size=2, max_seq_len=16)
    with pytest.raises(ValueError, match="max_seq_len=16"):
        eng.submit(np.arange(1, 17))             # 16 tokens: can't decode
    eng.submit(np.arange(1, 16))                 # 15 tokens: exactly fits
    with pytest.raises(ValueError, match="non-empty 1-D"):
        eng.submit(np.array([], np.int32))
    with pytest.raises(ValueError, match="non-empty 1-D"):
        eng.submit(np.array([[1, 2]]))
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.submit(np.array([1, 2]), max_new_tokens=0)


def test_engine_serves_artifact_bit_identical_to_policy(lm, tmp_path):
    """Serve-path acceptance (small-model tier-1 slice; bench_model runs
    in @slow): an Engine under a registry-reloaded artifact decodes the
    exact token stream of the in-process policy."""
    cfg, model, params = lm
    pol = TruncationPolicy.scoped("**/mlp", "e5m4")
    reg = Registry(str(tmp_path))
    ref = reg.save(PolicyArtifact(name="lm", policy=pol))
    art = reg.load(ref.ref)

    prompts = np.random.RandomState(0).randint(1, cfg.vocab, (4, 6))
    outs = []
    for policy in (pol, art):
        eng = Engine(model, params, batch_size=2, max_seq_len=24,
                     policy=policy)
        for p in prompts:
            eng.submit(p, max_new_tokens=5)
        done = eng.run()
        outs.append({rid: tuple(r.out_tokens) for rid, r in done.items()})
    assert outs[0] == outs[1]
    # and the policy actually changes decoding vs the untruncated engine
    eng = Engine(model, params, batch_size=2, max_seq_len=24)
    for p in prompts:
        eng.submit(p, max_new_tokens=5)
    assert eng._decode is not None  # smoke: plain engine still runs
    eng.run()


def test_checkpoint_manifest_records_artifact(tmp_path):
    from repro.checkpoint.checkpointer import Checkpointer

    tree = {"w": jnp.ones((4, 4), jnp.float32)}
    ck = Checkpointer(str(tmp_path), async_save=False)
    ref = ArtifactRef(name="bench_model", version=3, digest="ab" * 32)
    ck.save(7, tree, policy_artifact=ref, block=True)
    _, manifest = ck.restore(tree)
    assert manifest["policy_artifact"] == ref.to_json()
    assert ArtifactRef.from_json(manifest["policy_artifact"]) == ref
    # a raw PolicyArtifact records name + content digest (version unknown)
    art = _artifact("adhoc")
    ck.save(8, tree, policy_artifact=art, block=True)
    _, manifest = ck.restore(tree)
    assert manifest["policy_artifact"] == {
        "name": "adhoc", "version": None, "digest": art.digest}
    # and absent stays absent (back compat with pre-artifact checkpoints)
    ck.save(9, tree, block=True)
    _, manifest = ck.restore(tree)
    assert manifest["policy_artifact"] is None


@needs_compile_counter
def test_hotswap_train_step_zero_recompile(lm):
    """Deploying a different artifact mid-run is a new table VALUE, not a
    new executable: two different policies through one compiled step, with
    losses bit-identical to the statically-truncated train steps."""
    from repro.data.pipeline import DataConfig, Pipeline
    from repro.optim.adamw import AdamWConfig
    from repro.train.trainer import (
        TrainConfig, init_opt_state, make_hotswap_train_step,
        make_train_step,
    )

    cfg, model, params = lm
    r = np.random.RandomState(0)
    toks = r.randint(0, cfg.vocab, (2, 17))
    batch = {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
             "labels": jnp.asarray(toks[:, 1:], jnp.int32)}
    pol_a = TruncationPolicy.scoped("**/mlp", "e5m4")
    pol_b = TruncationPolicy.scoped("**/attn", "e8m7")
    site_policy = TruncationPolicy(rules=tuple(pol_a.rules + pol_b.rules))
    tc = TrainConfig(optimizer=AdamWConfig(lr=1e-3))

    step_fn, sites = make_hotswap_train_step(model, tc, site_policy,
                                             params, batch)
    jit_step = jax.jit(step_fn)
    opt = init_opt_state(model, params, tc)
    with _count_compiles() as n:
        losses = {}
        for key, table in (("id", sites.identity_table()),
                           ("a", sites.table_for(pol_a)),
                           ("b", sites.table_for(pol_b))):
            _, _, m = jit_step(params, opt, batch, jnp.int32(0),
                               jnp.asarray(table, jnp.int32))
            losses[key] = float(m["loss"])
    assert n[0] == 1, f"policy swap recompiled ({n[0]} compiles)"
    assert jit_step._cache_size() == 1

    # bit-equality against the statically-baked train steps
    for key, policy in (("id", None), ("a", pol_a), ("b", pol_b)):
        tc_k = TrainConfig(optimizer=AdamWConfig(lr=1e-3), policy=policy)
        _, _, m = jax.jit(make_train_step(model, tc_k))(
            params, init_opt_state(model, params, tc_k), batch, jnp.int32(0))
        assert losses[key] == float(m["loss"]), key


# --------------------------------------------------------------------------
# e2e acceptance: profile -> registry -> fresh-state deploy -> re-search
# --------------------------------------------------------------------------

def test_e2e_sod_search_registry_reload_warm_start(tmp_path):
    """Tier-1 acceptance slice on the smallest app: autosearch -> artifact
    -> registry save -> reload after jax.clear_caches() (fresh compile
    state) -> truncated run bit-identical under the reloaded policy ->
    ``warm_start=artifact.hints`` reproduces the assignments with fewer
    dispatches and NO re-profiling."""
    from repro.apps import get_app

    app = get_app("sod", n_cells=32, t_end=0.04)
    state = app.init_state(jnp.float32)
    r0 = search.autosearch(app.run_observables, (state,),
                           metric=app.error_metric, budget=48,
                           threshold=app.search_threshold)
    ref = Registry(str(tmp_path)).save(r0.to_artifact("sod"))

    out0 = truncate(app.run_observables, r0.policy())(state)
    jax.clear_caches()   # fresh interpreter/compile state: re-deploy cold
    art = Registry(str(tmp_path)).load("sod")
    assert art.digest == ref.digest
    out1 = truncate(app.run_observables, art.policy)(state)
    assert all(np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(jax.tree_util.tree_leaves(out0),
                               jax.tree_util.tree_leaves(out1)))

    r1 = search.autosearch(app.run_observables, (state,),
                           metric=app.error_metric, budget=48,
                           threshold=app.search_threshold,
                           warm_start=art.hints)
    assert _assigns(r1) == _assigns(r0)
    assert r1.n_dispatches < r0.n_dispatches
    # the artifact object itself is accepted as warm_start sugar
    r2 = search.autosearch(app.run_observables, (state,),
                           metric=app.error_metric, budget=48,
                           threshold=app.search_threshold, warm_start=art)
    assert _assigns(r2) == _assigns(r0)


@pytest.mark.slow
def test_acceptance_bench_model_artifact_loop(tmp_path):
    """ISSUE acceptance on bench_model: the persisted trajectory-blame
    hints make a registry-reloaded re-search hit <=4 dispatches WITHOUT
    recomputing the trajectory profile, and the serving engine under the
    reloaded artifact decodes bit-identically to the in-process policy."""
    from benchmarks.common import bench_model, bench_batch
    from repro.core import profile_trajectory
    from repro.core.formats import FPFormat as FPF
    from repro.profile import ladder_hints

    cfg, model, params = bench_model()
    batch = bench_batch(cfg)
    budget, thr = 128, 5e-3
    r0 = search.autosearch(model.loss, (params, batch),
                           search.loss_degradation, budget, threshold=thr)
    probe = TruncationPolicy(rules=tuple(
        TruncationRule(fmt=FPF(8, 5), scope=p) for p in r0.assignments))
    out_lo, traj = profile_trajectory(model.loss, probe, threshold=thr,
                                      n_steps=8)(params, batch)
    joint = search.loss_degradation((model.loss(params, batch),), (out_lo,))
    hints = ladder_hints(traj, search.DEFAULT_WIDTHS, thr, 5,
                         joint_metric=joint)
    ref = Registry(str(tmp_path)).save(
        r0.to_artifact("bench_model", hints=hints))

    jax.clear_caches()
    art = Registry(str(tmp_path)).load("bench_model")
    assert art.digest == ref.digest
    r1 = search.autosearch(model.loss, (params, batch),
                           search.loss_degradation, budget, threshold=thr,
                           warm_start=art.hints)
    assert _assigns(r1) == _assigns(r0)
    assert r1.n_dispatches <= 4, r1.n_dispatches

    prompts = np.random.RandomState(1).randint(1, cfg.vocab, (2, 8))
    outs = []
    for policy in (r0.policy(), art):
        eng = Engine(model, params, batch_size=2, max_seq_len=32,
                     policy=policy)
        for p in prompts:
            eng.submit(p, max_new_tokens=8)
        outs.append({rid: tuple(r.out_tokens)
                     for rid, r in eng.run().items()})
    assert outs[0] == outs[1]
